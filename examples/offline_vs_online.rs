//! Online serving vs conventional hourly batch re-evaluation (Fig. 9):
//! one patient monitored for a simulated hour; HOLMES evaluates every
//! 30 s window as it completes while the batch job scores the whole
//! backlog once at the hour mark — an order of magnitude slower, on
//! stale data.
//!
//! ```bash
//! cargo run --release --example offline_vs_online
//! ```

use holmes::exp::fig9_timeline;
use holmes::zoo::Zoo;

fn main() -> holmes::Result<()> {
    let zoo = Zoo::load("artifacts")?;
    let out = std::path::PathBuf::from("results");
    // quick = true → 600× virtual clock: the hour runs in ~6 s wall
    fig9_timeline::run(&zoo, &out, true)?;
    println!("timeline CSV: results/fig9.csv (mode,sim_time_s,latency_s,kind)");
    Ok(())
}
