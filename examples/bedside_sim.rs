//! 64-bed CICU serving simulation — the paper's headline workload.
//!
//! Streams 3-lead 250 Hz ECG + 1 Hz vitals from 64 simulated post-Norwood
//! patients through the full Fig.-4 pipeline (sharded stateful
//! aggregators → ensemble queue → stateless model actors on 2 device
//! workers, collector-less direct completion) and reports p50/p95/p99
//! end-to-end latency plus step-down-readiness ROC-AUC against the
//! simulator's ground-truth labels.
//!
//! Without compiled artifacts on disk it falls back to a paper-shaped
//! toy zoo on the deterministic sim backend — so the full serving path
//! is exercisable anywhere (CI smoke runs use exactly this).
//!
//! ```bash
//! cargo run --release --example bedside_sim \
//!     [patients] [speedup] [duration_s] [workers] \
//!     [--adaptive-batch] [--slo-ms MS] [--http] \
//!     [--govern] [--chaos] [--control-tick-ms MS] [--floor-acc AUC]
//! ```
//!
//! `--adaptive-batch` swaps the static 1 ms batch fill deadline for the
//! SLO-aware controller; an explicit `--slo-ms` turns the p95-vs-SLO
//! comparison into a hard check (nonzero exit on violation) — this is
//! how the CI smoke exercises the controller path on every PR.
//! `--http` routes every bedside stream over a real TCP connection
//! into the event-driven ingest edge (`POST /ingest.bin`, keep-alive)
//! and hard-checks the edge gauges afterwards: one accepted connection
//! per patient, zero refusals — the CI smoke for the epoll edge.
//! `--govern` spawns the ensemble governor; `--chaos` (implies
//! `--govern`) injects a scripted backend fault plus a 4×-bed ghost
//! admission storm on a slowed backend, then hard-checks the outcome:
//! an over-SLO tail without a degrade step-down, any unresolved
//! admitted query, a never-reinstated healed lane, or fewer than two
//! hot swaps all exit nonzero — the CI chaos smoke for the governor.

use holmes::exp::bedside::{run_bedside, BedsideConfig};
use holmes::zoo::{testkit, Zoo};

fn main() -> holmes::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    // the crate's own parser handles --flag, --opt value AND --opt=value
    // (and errors on malformed forms instead of silently shifting the
    // positionals, which would disable the SLO gate below)
    let args = holmes::cli::parse(&argv, &["slo-ms", "control-tick-ms", "floor-acc"])?;
    let adaptive = args.flag("adaptive-batch");
    let over_http = args.flag("http");
    let chaos = args.flag("chaos");
    let govern = args.flag("govern") || chaos;
    let slo_is_a_gate = args.get("slo-ms").is_some();
    let slo_ms = args.f64_or("slo-ms", 1000.0)?;
    // cli::parse files the first bare argument as a "subcommand" — for
    // this example it is simply the first positional
    let mut pos: Vec<String> = Vec::new();
    pos.extend(args.subcommand.clone());
    pos.extend(args.positionals.iter().cloned());
    let patients: usize = pos.first().and_then(|s| s.parse().ok()).unwrap_or(64);
    let speedup: f64 = pos.get(1).and_then(|s| s.parse().ok()).unwrap_or(8.0);
    // enough simulated time for several windows per patient
    let duration_s: f64 = pos.get(2).and_then(|s| s.parse().ok()).unwrap_or(16.0);
    // executor pool threads (0 = device-permit-capped core default)
    let workers: usize = pos.get(3).and_then(|s| s.parse().ok()).unwrap_or(0);
    let zoo = match Zoo::load("artifacts") {
        Ok(zoo) => zoo,
        Err(_) => {
            println!("no compiled artifacts found — using the toy zoo on the sim backend");
            testkit::toy_zoo_with(9, 64, 21, 2500, &[1, 8])
        }
    };
    let report = run_bedside(
        &zoo,
        BedsideConfig {
            patients,
            gpus: 2,
            window_s: 30.0,
            speedup,
            duration_s,
            http_addr: over_http.then(|| "127.0.0.1:0".to_string()),
            edge_threads: 0,
            seed: 42,
            shards: 0,
            workers,
            slo_ms,
            adaptive,
            govern,
            control_tick_ms: args.f64_or("control-tick-ms", 100.0)?,
            floor_acc: args.f64_or("floor-acc", 0.8)?,
            chaos,
        },
    )?;
    if over_http {
        // edge smoke: every bedside monitor held one keep-alive
        // connection, none were refused, and frames flowed over TCP
        if report.conns_accepted < patients as u64 || report.conns_refused != 0 {
            eprintln!(
                "FAIL: edge accepted {} connections (expected ≥ {patients}), refused {}",
                report.conns_accepted, report.conns_refused
            );
            std::process::exit(1);
        }
        let ready: u64 = report.edge_ready_events.iter().sum();
        println!(
            "✓ HTTP edge: {} connections accepted, {} readiness events across {} loop(s)",
            report.conns_accepted,
            ready,
            report.edge_ready_events.len().max(1)
        );
    }
    // the paper's claim: sub-second p95 at 64 beds
    if report.e2e_p95 < 1.15 {
        println!("\n✓ within the paper's 1.15 s p95 envelope at {patients} beds");
    } else {
        println!("\n✗ above the paper's 1.15 s p95 envelope ({:.3}s)", report.e2e_p95);
    }
    if chaos {
        // chaos smoke: the storm is DESIGNED to breach the SLO — what
        // must hold is that the governor answered it. An over-SLO tail
        // with no degrade step-down is the failure; a breach that was
        // met with degradation is the scenario working as intended.
        let mut failed = false;
        if report.e2e_p95 > report.slo_s && report.governor_degraded_entered == 0 {
            eprintln!(
                "FAIL: chaos storm breached the SLO (p95 {:.3}s > {:.3}s) but the governor \
                 never stepped down to the floor",
                report.e2e_p95, report.slo_s
            );
            failed = true;
        }
        if report.unresolved != 0 {
            eprintln!(
                "FAIL: {} admitted queries left unresolved (hot swaps or lane faults \
                 dropped in-flight work)",
                report.unresolved
            );
            failed = true;
        }
        if report.governor_reinstated < 1 {
            eprintln!(
                "FAIL: the faulted lane healed mid-run but was never reinstated \
                 ({} canary probes fired)",
                report.governor_probes
            );
            failed = true;
        }
        if report.governor_swaps < 2 {
            eprintln!(
                "FAIL: expected at least 2 membership hot swaps (quarantine + recovery), \
                 saw {}",
                report.governor_swaps
            );
            failed = true;
        }
        if failed {
            std::process::exit(1);
        }
        println!(
            "✓ chaos: degraded {}× under storm pressure, {} hot swaps, {} lane(s) \
             reinstated after {} probe(s), 0 unresolved queries",
            report.governor_degraded_entered,
            report.governor_swaps,
            report.governor_reinstated,
            report.governor_probes
        );
    } else if slo_is_a_gate && report.e2e_p95 > report.slo_s {
        eprintln!(
            "FAIL: e2e p95 {:.3}s exceeds the configured {:.0} ms SLO",
            report.e2e_p95, slo_ms
        );
        std::process::exit(1);
    }
    Ok(())
}
