//! 64-bed CICU serving simulation — the paper's headline workload.
//!
//! Streams 3-lead 250 Hz ECG + 1 Hz vitals from 64 simulated post-Norwood
//! patients through the full Fig.-4 pipeline (stateful aggregators →
//! ensemble queue → stateless model actors on 2 device workers) and
//! reports p50/p95/p99 end-to-end latency plus step-down-readiness
//! ROC-AUC against the simulator's ground-truth labels.
//!
//! ```bash
//! cargo run --release --example bedside_sim [patients] [speedup]
//! ```

use holmes::exp::bedside::{run_bedside, BedsideConfig};
use holmes::zoo::Zoo;

fn main() -> holmes::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let patients: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let speedup: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8.0);
    let zoo = Zoo::load("artifacts")?;
    let report = run_bedside(
        &zoo,
        BedsideConfig {
            patients,
            gpus: 2,
            window_s: 30.0,
            speedup,
            // enough simulated time for several windows per patient
            duration_s: 16.0,
            http_addr: None,
            seed: 42,
        },
    )?;
    // the paper's claim: sub-second p95 at 64 beds
    if report.e2e_p95 < 1.15 {
        println!("\n✓ within the paper's 1.15 s p95 envelope at {patients} beds");
    } else {
        println!("\n✗ above the paper's 1.15 s p95 envelope ({:.3}s)", report.e2e_p95);
    }
    Ok(())
}
