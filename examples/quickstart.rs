//! Quickstart: load the zoo, compose an ensemble under a latency budget,
//! deploy it on the serving pipeline, and run one ensemble query.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use holmes::composer::Composer;
use holmes::config::{ComposerConfig, SystemConfig};
use holmes::data;
use holmes::ingest::synth::SynthConfig;
use holmes::profiler::{AnalyticLatencyProfiler, ServiceTimes, ValidationAccuracyProfiler};
use holmes::runtime::Engine;
use holmes::serving::pipeline::{Pipeline, PipelineConfig, Query};
use holmes::zoo::Zoo;

fn main() -> holmes::Result<()> {
    // 1. The model zoo built by `make artifacts`: 60 Table-3 profiles,
    //    18 with AOT-compiled HLO artifacts.
    let zoo = Zoo::load("artifacts")?;
    println!("zoo: {} models, {} servable", zoo.n(), zoo.servable_indices().len());

    // 2. Compose: maximise validation accuracy subject to f_l ≤ 200 ms
    //    (Eq. 1), restricted to servable models so we can deploy it.
    let system = SystemConfig { gpus: 2, patients: 32, window_s: 30.0 };
    let acc = ValidationAccuracyProfiler::from_zoo(&zoo);
    let lat = AnalyticLatencyProfiler::new(ServiceTimes::from_macs(&zoo, 5e-4, 2e10));
    let cfg = ComposerConfig { servable_only: true, ..Default::default() };
    let composer = Composer::new(&zoo, &acc, &lat, cfg, system);
    let result = composer.search(&[]);
    let best = &result.best;
    println!(
        "composed {}-model ensemble: AUC {:.4}, predicted latency {:.3}s",
        best.selector.len(),
        best.accuracy.roc_auc,
        best.latency
    );
    for &i in best.selector.indices() {
        println!("  - {}", zoo.model(i).id);
    }

    // 3. Deploy on the real PJRT pipeline (2 device workers = "2 GPUs").
    //    Warm-compile each member so the demo query measures steady state.
    let engine = Engine::new(&zoo, 2)?;
    for &i in best.selector.indices() {
        engine.profile_model((i, 1), 1)?;
    }
    let pipeline = Pipeline::spawn(&zoo, &engine, PipelineConfig::new(best.selector.clone()))?;

    // 4. One synthetic patient window → bagging prediction (Eq. 5).
    let clip = data::make_clips(1, zoo.manifest.clip_len, 7, &SynthConfig::default());
    let prediction =
        pipeline.query(Query::from_vecs(0, 0, 30.0, clip.clips[0].clone()))?;
    println!(
        "prediction: P(stable) = {:.3} (label was {}), e2e latency {:?}",
        prediction.score, clip.labels[0], prediction.e2e
    );
    Ok(())
}
