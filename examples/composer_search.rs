//! Accuracy/latency trade-off exploration: run the HOLMES composer and
//! all §4.2 baselines across a range of latency budgets and print the
//! frontier each method reaches (the Fig. 1 / Fig. 7 story).
//!
//! ```bash
//! cargo run --release --example composer_search
//! ```

use holmes::config::{ComposerConfig, SystemConfig};
use holmes::exp::common::{Method, SearchContext};
use holmes::zoo::Zoo;

fn main() -> holmes::Result<()> {
    let zoo = Zoo::load("artifacts")?;
    let system = SystemConfig { gpus: 2, patients: 32, window_s: 30.0 };
    let ctx = SearchContext::new(&zoo, system);
    let cfg = ComposerConfig::default();

    println!(
        "{:<9} {:>8} {:>9} {:>9} {:>6} {:>7}",
        "budget", "method", "ROC-AUC", "latency", "|b|", "calls"
    );
    for budget in [0.05, 0.1, 0.2, 0.5] {
        for m in Method::ALL {
            let r = ctx.run(m, budget, 0, &cfg);
            println!(
                "{:<9} {:>8} {:>9.4} {:>8.3}s {:>6} {:>7}",
                format!("{budget}s"),
                m.name(),
                r.best.accuracy.roc_auc,
                r.best.latency,
                r.best.selector.len(),
                r.profiler_calls
            );
        }
        println!();
    }

    // show HOLMES' chosen ensemble at the paper's 200 ms operating point
    let r = ctx.run(Method::Holmes, 0.2, 0, &cfg);
    println!("HOLMES @ 200 ms picks:");
    for &i in r.best.selector.indices() {
        let m = zoo.model(i);
        println!(
            "  {} (lead {}, width {}, blocks {}, val AUC {:.4})",
            m.id, m.lead, m.width, m.blocks, m.val_auc
        );
    }
    Ok(())
}
