//! Serving-pipeline benches: end-to-end query latency, burst handling
//! (the Fig.-10 hot path), aggregator ingest throughput, and the
//! measured latency profiler.
//!
//! `cargo bench --bench serving`

use std::time::Instant;

use holmes::bench::{black_box, Bencher};
use holmes::config::SystemConfig;
use holmes::data;
use holmes::ingest::synth::SynthConfig;
use holmes::ingest::{Frame, Modality};
use holmes::runtime::Engine;
use holmes::serving::aggregator::WindowAggregator;
use holmes::serving::pipeline::{Pipeline, PipelineConfig, Query};
use holmes::serving::profile::{profile_ensemble, ProfileEffort};
use holmes::zoo::{Selector, Zoo};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bencher::quick() } else { Bencher::default() };
    println!("== serving benches ==");
    let zoo = Zoo::load(std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
        .expect("run `make artifacts` first");
    let engine = Engine::new(&zoo, 2).expect("engine");
    let clip_len = zoo.manifest.clip_len;

    // ---- aggregator ingest throughput (pure L3, no device)
    let mut agg = WindowAggregator::new(0, clip_len);
    let frame = Frame {
        patient: 0,
        modality: Modality::Ecg,
        sim_time: 0.0,
        values: vec![0.1, 0.2, 0.3],
    };
    b.bench("aggregator/push_ecg_frame", || black_box(agg.push(&frame).is_some()));

    // ---- pipeline end-to-end, 3-model cross-lead ensemble
    let members: Vec<usize> = zoo.servable_indices().into_iter().take(3).collect();
    let ensemble = Selector::from_indices(zoo.n(), members);
    for &m in ensemble.indices() {
        for &bs in engine.batch_sizes() {
            engine.profile_model((m, bs), 1).unwrap();
        }
    }
    let pipeline = Pipeline::spawn(&zoo, &engine, PipelineConfig::new(ensemble.clone())).unwrap();
    let clips = data::make_clips(4, clip_len, 21, &SynthConfig::default());
    let mut w = 0u64;
    b.bench("pipeline/query_e2e/3-models", || {
        w += 1;
        let p = pipeline
            .query(Query {
                patient: 0,
                window_id: w,
                sim_end: 0.0,
                leads: clips.clips[(w as usize) % clips.len()].clone(),
                emitted: Instant::now(),
            })
            .unwrap();
        black_box(p.score)
    });

    // ---- 16-query burst (batching + 2-worker contention)
    b.bench("pipeline/burst16/3-models", || {
        let mut replies = Vec::with_capacity(16);
        for i in 0..16usize {
            w += 1;
            replies.push(
                pipeline
                    .submit(Query {
                        patient: i,
                        window_id: w,
                        sim_end: 0.0,
                        leads: clips.clips[i % clips.len()].clone(),
                        emitted: Instant::now(),
                    })
                    .unwrap(),
            );
        }
        let mut acc = 0.0;
        for r in replies {
            acc += r.recv().unwrap().score;
        }
        black_box(acc)
    });
    drop(pipeline);

    // ---- measured latency profiler (one full μ/T_s/T_q cycle)
    let system = SystemConfig { gpus: 2, patients: 16, window_s: 30.0 };
    let effort = ProfileEffort { closed_loop_queries: 8, open_loop_queries: 8 };
    let t0 = Instant::now();
    let m = profile_ensemble(&zoo, &engine, &ensemble, &system, effort).unwrap();
    println!(
        "{:<44} one cycle: {:?} (μ={:.1} qps, T_s p95={:.4}s, T_q≤{:.4}s)",
        "profile/measured_f_l/3-models",
        t0.elapsed(),
        m.mu,
        m.ts_p95,
        m.tq_bound
    );
}
