//! Serving-pipeline benches: per-layer admission-path measurements
//! (ingest decode, pending-table admission, batch packing), end-to-end
//! query latency, burst handling (the Fig.-10 hot path), aggregator
//! ingest throughput, and the measured latency profiler.
//!
//! Runs entirely on the zero-latency [`SimBackend`], so what is being
//! measured is the **data plane itself** (copies, locks, allocation,
//! channel hops) — not model FLOPs. To track the perf trajectory, the
//! bench also drives `legacy`, an in-bench replica of the pre-refactor
//! plane (JSON-parsed ingest frames, per-member window clones,
//! mutex-striped pending table, a fresh padded allocation per flush),
//! and writes all medians plus the new-vs-legacy speedups to
//! `BENCH_serving.json` at the repo root. Layer groups:
//!
//! * `ingest/decode_frame`  — binary wire decode vs recursive-descent
//!   JSON (`legacy_ingest/...`), one 3-sample ECG frame each.
//! * `ingest/edge-concurrency/{1k,10k}-conns` — the event-driven epoll
//!   ingest edge vs the thread-per-connection edge
//!   (`legacy_ingest/...`), N mostly-idle keep-alive connections held
//!   open while a rotating 64-connection subset each posts one 16-frame
//!   binary body per round. The legacy plane pays one OS thread per
//!   held connection; the epoll plane serves the same load from a
//!   fixed pool of event loops. (10k runs in full mode only.)
//! * `aggregate/shard-fanin` — sharded aggregation front-end (patients
//!   partitioned over N workers on bounded channels) vs the single
//!   `mpsc::Sender<Frame>` + one aggregation loop
//!   (`legacy_aggregate/...`), same multi-producer frame trace.
//! * `admission/insert_remove/8-threads` — lock-free pending slot
//!   arena vs the mutex-striped table (`legacy_admission/...`) under
//!   8-thread insert+score+remove contention.
//! * `complete/direct-vs-collector` — worker threads completing slots
//!   directly through `Completer` (inline finish) vs funneling every
//!   member report through one MPSC channel into a single collector
//!   thread (`legacy_complete/...`).
//! * `execute/steal-vs-thread-per-model/{1,4,16}-models` — the
//!   work-stealing executor (fixed 4-worker pool, lock-free lanes,
//!   inline `DirectWorker` execution) vs one OS thread per model
//!   looping recv → pack → `execute_batch` through the engine FIFO
//!   (`legacy_execute/...`), identical query load per model count. The
//!   16-model case is the headline: 4 threads instead of 16.
//! * `execute/adaptive-vs-static/{burst,trickle}` — the SLO-aware
//!   adaptive deadline controller vs the static fill window
//!   (`legacy_execute/...`) on identical pools and loads: the burst
//!   shape leaves a partial tail batch per lane, where the static
//!   policy always waits the full window and the controller arms only
//!   the depth-scaled remainder; the trickle shape checks the relaxed
//!   (launch-amortizing) wait stays comparable.
//! * `aggregate/pooled-vs-alloc` — window aggregation into recycled
//!   per-shard slab buffers (`LeadPool` leases, dropped → reused) vs
//!   the old emit path allocating fresh `Vec` + `Arc<[f32]>` per lead
//!   per window (`legacy_aggregate/pooled-vs-alloc`).
//! * `pack/batch8` — chunked copy into the persistent 64-byte-aligned
//!   arena vs a fresh `vec![0.0; n]` per flush (`legacy_pack/...`).
//! * `pack/unroll/batch8-2500` — the 128-float (8-lane) `pack_slot`
//!   chunking vs an in-bench replica of the previous 64-float (4-lane)
//!   chunking, fixed paper-shaped 2500-float windows.
//!
//! `cargo bench --bench serving [-- --quick]`

use std::collections::{BTreeMap, HashMap};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use holmes::bench::{black_box, BenchResult, Bencher};
use holmes::config::SystemConfig;
use holmes::data;
use holmes::http::{serve_legacy_with, serve_with, HttpConfig, HttpServer};
use holmes::ingest::synth::SynthConfig;
use holmes::ingest::{Frame, Modality};
use holmes::json::Value;
use holmes::runtime::{AlignedBatch, Engine, SimBackend};
use holmes::serving::aggregator::{WindowAggregator, WindowData};
use holmes::serving::arena::{LeadPool, WindowLease};
use holmes::serving::batcher::{BatchItem, BatchPolicy};
use holmes::serving::control::DEFAULT_SLO;
use holmes::serving::executor::Executor;
use holmes::serving::pipeline::{
    Completer, PendingMeta, PendingSlots, Pipeline, PipelineConfig, Query,
};
use holmes::serving::profile::{profile_ensemble, ProfileEffort};
use holmes::serving::shards::{ShardConfig, ShardRouter};
use holmes::serving::{ShardSender, Telemetry};
use holmes::zoo::{testkit, Selector, Zoo};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bencher::quick() } else { Bencher::default() };
    println!("== serving benches ==");
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let zoo = if artifacts.join("zoo_manifest.json").exists() {
        Zoo::load(&artifacts).expect("artifacts load")
    } else {
        // paper-shaped stand-in: 10 s × 250 Hz windows, batch-8 variants
        testkit::toy_zoo_with(9, 64, 21, 2500, &[1, 8])
    };
    let engine =
        Engine::with_backend(&zoo, 2, Arc::new(SimBackend::instant(&zoo))).expect("engine");
    let clip_len = zoo.manifest.clip_len;

    // ---- aggregator ingest throughput (pure L3, no device)
    let mut agg = WindowAggregator::new(0, clip_len);
    let frame = Frame {
        patient: 0,
        modality: Modality::Ecg,
        sim_time: 0.0,
        values: [0.1, 0.2, 0.3].into(),
    };
    b.bench("aggregator/push_ecg_frame", || black_box(agg.push(&frame).is_some()));

    // ---- layer 0: aggregation fan-in — sharded front-end vs the
    // single-channel single-loop plane, same multi-producer trace
    bench_shard_fanin(&mut b);

    // ---- layer 1: ingest decode — binary wire vs JSON, one ECG frame
    let wire_frame = Frame {
        patient: 12,
        modality: Modality::Ecg,
        sim_time: 3.252,
        values: [0.215, -0.083, 0.127].into(),
    };
    let wire_bytes = wire_frame.to_bytes();
    let json_text = wire_frame.to_json().to_string();
    b.bench("ingest/decode_frame", || {
        let (f, used) = Frame::from_bytes(&wire_bytes).expect("wire decode");
        black_box((f.patient, used))
    });
    b.bench("legacy_ingest/decode_frame", || {
        let f = Frame::from_json(&Value::parse(&json_text).expect("json parse"))
            .expect("json decode");
        black_box(f.patient)
    });

    // ---- layer 1b: the ingest edge itself — epoll readiness loops vs
    // one OS thread per held keep-alive connection
    bench_edge_concurrency(&mut b, quick);

    // ---- layer 2: admission — lock-free slot arena vs mutex-striped
    // table, 8 threads each doing insert + per-member score + remove
    let slots = PendingSlots::new(ADM_MEMBERS);
    b.bench("admission/insert_remove/8-threads", || {
        admission_round_lockfree(&slots);
        black_box(slots.len())
    });
    let striped = legacy::StripedPending::new(ADM_MEMBERS);
    b.bench("legacy_admission/insert_remove/8-threads", || {
        admission_round_striped(&striped);
        black_box(striped.len())
    });

    // ---- layer 2b: completion — direct inline finish on the scoring
    // thread vs one collector thread draining an MPSC fan-in
    bench_direct_vs_collector(&mut b);

    // ---- layer 2c: execution — work-stealing worker pool vs one OS
    // thread per model, 1/4/16-model ensembles at a fixed pool size
    bench_steal_vs_thread_per_model(&mut b);

    // ---- layer 2d: fill deadlines — SLO-aware adaptive controller vs
    // the static policy, burst (tail-batch wait) and trickle shapes
    bench_adaptive_vs_static(&mut b);

    // ---- layer 2e: governed membership — epoch hot swaps stepping a
    // round down to a two-lane floor mid-stream vs a static full set
    bench_govern_swap_vs_static(&mut b);

    // ---- layer 0b: window arenas — pooled slab buffers vs a fresh
    // Vec + Arc allocation per emitted lead window
    bench_pooled_vs_alloc(&mut b);

    // ---- layer 3: batch packing — persistent aligned arena (chunked
    // copy) vs a fresh padded allocation per flush
    let window = vec![0.37f32; clip_len];
    let mut arena = AlignedBatch::new();
    b.bench("pack/batch8", || {
        arena.reset(8 * clip_len);
        for slot in 0..8 {
            arena.pack_slot(slot, clip_len, &window);
        }
        black_box(arena.as_slice()[7 * clip_len])
    });
    b.bench("legacy_pack/batch8", || {
        let mut buf = vec![0.0f32; 8 * clip_len];
        for slot in 0..8 {
            buf[slot * clip_len..(slot + 1) * clip_len].copy_from_slice(&window);
        }
        black_box(buf[7 * clip_len])
    });

    // ---- layer 3b: pack_slot chunk width — the 128-float (8-lane)
    // chunking vs the previous 64-float (4-lane) chunking, both through
    // the same aligned arena on fixed paper-shaped 2500-float windows
    let w2500 = vec![0.37f32; 2500];
    let mut arena8 = AlignedBatch::new();
    b.bench("pack/unroll/batch8-2500", || {
        arena8.reset(8 * 2500);
        for slot in 0..8 {
            arena8.pack_slot(slot, 2500, &w2500);
        }
        black_box(arena8.as_slice()[7 * 2500])
    });
    let mut arena4 = AlignedBatch::new();
    b.bench("legacy_pack/unroll/batch8-2500", || {
        arena4.reset(8 * 2500);
        for slot in 0..8 {
            pack_slot_4lane(&mut arena4, slot, 2500, &w2500);
        }
        black_box(arena4.as_slice()[7 * 2500])
    });

    // ---- pipeline end-to-end, 3-model cross-lead ensemble; zero fill
    // wait so the measurement is pure data-plane overhead
    let members: Vec<usize> = zoo.servable_indices().into_iter().take(3).collect();
    let ensemble = Selector::from_indices(zoo.n(), members);
    let policy = BatchPolicy { max_batch: 8, timeout: Duration::ZERO, ..BatchPolicy::default() };
    let clips = data::make_clips(4, clip_len, 21, &SynthConfig::default());
    let shared = clips.shared();

    let pipeline = Pipeline::spawn(
        &zoo,
        &engine,
        PipelineConfig::new(ensemble.clone()).with_policy(policy),
    )
    .unwrap();
    let mut w = 0u64;
    b.bench("pipeline/query_e2e/3-models", || {
        w += 1;
        let p = pipeline
            .query(Query {
                patient: 0,
                window_id: w,
                sim_end: 0.0,
                leads: shared[(w as usize) % shared.len()].clone(),
                emitted: Instant::now(),
            })
            .unwrap();
        black_box(p.score)
    });

    // ---- 16-query burst (batching + 2-worker contention)
    b.bench("pipeline/burst16/3-models", || {
        let mut replies = Vec::with_capacity(16);
        for i in 0..16usize {
            w += 1;
            replies.push(
                pipeline
                    .submit(Query {
                        patient: i,
                        window_id: w,
                        sim_end: 0.0,
                        leads: shared[i % shared.len()].clone(),
                        emitted: Instant::now(),
                    })
                    .unwrap(),
            );
        }
        let mut acc = 0.0;
        for r in replies {
            acc += r.recv().unwrap().score;
        }
        black_box(acc)
    });
    drop(pipeline);

    // ---- the same workload on the pre-refactor plane (see `legacy`)
    let lp = legacy::LegacyPipeline::spawn(&zoo, &engine, ensemble.clone(), policy);
    b.bench("legacy_pipeline/query_e2e/3-models", || {
        w += 1;
        let p = lp
            .query(legacy::LegacyQuery {
                leads: clips.clips[(w as usize) % clips.len()].clone(),
                emitted: Instant::now(),
            })
            .unwrap();
        black_box(p)
    });
    b.bench("legacy_pipeline/burst16/3-models", || {
        let mut replies = Vec::with_capacity(16);
        for i in 0..16usize {
            replies.push(
                lp.submit(legacy::LegacyQuery {
                    leads: clips.clips[i % clips.len()].clone(),
                    emitted: Instant::now(),
                })
                .unwrap(),
            );
        }
        let mut acc = 0.0;
        for r in replies {
            acc += r.recv().unwrap();
        }
        black_box(acc)
    });
    drop(lp);

    // ---- measured latency profiler (one full μ/T_s/T_q cycle)
    let system = SystemConfig { gpus: 2, patients: 64, window_s: 3.0 };
    let effort = ProfileEffort { closed_loop_queries: 8, open_loop_queries: 8 };
    let t0 = Instant::now();
    let m = profile_ensemble(&zoo, &engine, &ensemble, &system, effort).unwrap();
    println!(
        "{:<44} one cycle: {:?} (μ={:.1} qps, T_s p95={:.4}s, T_q≤{:.4}s)",
        "profile/measured_f_l/3-models",
        t0.elapsed(),
        m.mu,
        m.ts_p95,
        m.tq_bound
    );

    write_bench_json(b.results(), quick, engine.backend_name());
}

/// Admission-bench shape: 8 threads × 2048 queries × 3 members. With
/// 1024 slots the 16k ids per round wrap the arena repeatedly, so the
/// round exercises genuine inter-thread contention on the arena (and
/// on the stripes of the legacy table). The per-thread query count is
/// deliberately large so the ~8 thread spawns + joins per measured
/// round (hundreds of µs) are noise next to the ~65k admission ops
/// being compared.
const ADM_THREADS: usize = 8;
const ADM_QUERIES_PER_THREAD: usize = 2048;
const ADM_MEMBERS: usize = 3;

fn adm_meta() -> PendingMeta {
    PendingMeta { patient: 0, window_id: 0, sim_end: 0.0, emitted: Instant::now(), reply: None }
}

/// One contention round on the lock-free arena: every thread inserts
/// its own ids and scores all members (the last score removes).
fn admission_round_lockfree(slots: &PendingSlots) {
    std::thread::scope(|s| {
        for t in 0..ADM_THREADS {
            s.spawn(move || {
                for q in 0..ADM_QUERIES_PER_THREAD {
                    let id = (t * ADM_QUERIES_PER_THREAD + q) as u64;
                    slots.insert(id, adm_meta());
                    for pos in 0..ADM_MEMBERS {
                        black_box(matches!(
                            slots.score(id, pos, 0.5, Duration::ZERO),
                            holmes::serving::ScoreOutcome::Completed(_)
                        ));
                    }
                }
            });
        }
    });
}

/// The pre-PR `pack_slot` chunking: 64-float (4-lane) chunks through
/// the same aligned arena — kept in-bench so the 8-lane change is
/// measured, not assumed.
fn pack_slot_4lane(buf: &mut AlignedBatch, slot: usize, clip_len: usize, src: &[f32]) {
    let start = slot * clip_len;
    let dst = &mut buf.as_mut_slice()[start..start + src.len()];
    const CHUNK: usize = 64; // 4 lanes × 16 f32
    let mut src_chunks = src.chunks_exact(CHUNK);
    let mut dst_chunks = dst.chunks_exact_mut(CHUNK);
    for (d, s) in dst_chunks.by_ref().zip(src_chunks.by_ref()) {
        d.copy_from_slice(s);
    }
    dst_chunks.into_remainder().copy_from_slice(src_chunks.remainder());
}

/// Edge-concurrency bench shape: hold `N` keep-alive connections open
/// against a live ingest server; one measured round picks a rotating
/// [`EDGE_ACTIVE`]-connection subset, posts one
/// [`EDGE_FRAMES_PER_BODY`]-frame binary body on each, then reads all
/// the responses. The held-but-idle majority is what distinguishes the
/// planes: the thread-per-connection edge (`legacy_`) keeps one parked
/// OS thread per connection (all spawned during warm-up, outside the
/// measured rounds), the epoll edge keeps a slab slot. Admitted frames
/// drain through a channel into one counting thread, as in production.
const EDGE_ACTIVE: usize = 64;
const EDGE_FRAMES_PER_BODY: usize = 16;

struct EdgeConn {
    s: TcpStream,
    resp: Vec<u8>,
}

impl EdgeConn {
    fn connect(addr: std::net::SocketAddr) -> std::io::Result<EdgeConn> {
        let s = TcpStream::connect(addr)?;
        s.set_nodelay(true)?;
        Ok(EdgeConn { s, resp: Vec::with_capacity(256) })
    }

    fn send(&mut self, request: &[u8]) {
        self.s.write_all(request).expect("edge request write");
    }

    /// Read exactly one `200` response (headers + content-length body),
    /// leaving the stream on a clean framing boundary.
    fn read_response(&mut self) {
        self.resp.clear();
        let mut chunk = [0u8; 2048];
        let header_end = loop {
            if let Some(pos) = self.resp.windows(4).position(|w| w == b"\r\n\r\n") {
                break pos + 4;
            }
            let n = self.s.read(&mut chunk).expect("edge response read");
            assert!(n > 0, "edge closed mid-response");
            self.resp.extend_from_slice(&chunk[..n]);
        };
        let content_length: usize = self.resp[..header_end]
            .split(|&b| b == b'\n')
            .filter_map(|l| {
                let colon = l.iter().position(|&b| b == b':')?;
                l[..colon]
                    .eq_ignore_ascii_case(b"content-length")
                    .then(|| std::str::from_utf8(&l[colon + 1..]).ok()?.trim().parse().ok())
                    .flatten()
            })
            .next()
            .unwrap_or(0);
        while self.resp.len() < header_end + content_length {
            let n = self.s.read(&mut chunk).expect("edge response read");
            assert!(n > 0, "edge closed mid-body");
            self.resp.extend_from_slice(&chunk[..n]);
        }
        assert!(
            self.resp.starts_with(b"HTTP/1.1 200"),
            "edge replied {}",
            String::from_utf8_lossy(&self.resp[..header_end])
        );
    }
}

/// One round: `active` connections starting at `start` (wrapping) each
/// send one request, then all responses are read back.
fn edge_round(conns: &mut [EdgeConn], start: usize, active: usize, request: &[u8]) {
    let n = conns.len();
    for i in 0..active {
        conns[(start + i) % n].send(request);
    }
    for i in 0..active {
        conns[(start + i) % n].read_response();
    }
}

fn bench_edge_concurrency(b: &mut Bencher, quick: bool) {
    // each held connection costs two fds in this process (client end +
    // server end); raise the limit and scale down — loudly — if the
    // box still can't hold the full count
    #[cfg(target_os = "linux")]
    let fd_limit = holmes::http::sys::raise_nofile_limit();
    #[cfg(not(target_os = "linux"))]
    let fd_limit = 1024u64;
    let budget = (fd_limit.saturating_sub(128) / 2) as usize;

    let mut sizes: Vec<(usize, &str)> = vec![(1_000, "1k-conns")];
    if !quick {
        sizes.push((10_000, "10k-conns"));
    }

    // one binary request shared by every round
    let frames: Vec<Frame> = (0..EDGE_FRAMES_PER_BODY)
        .map(|i| Frame {
            patient: i,
            modality: Modality::Ecg,
            sim_time: i as f64 * 0.004,
            values: [0.21, -0.08, 0.12].into(),
        })
        .collect();
    let mut body = Vec::new();
    for f in &frames {
        f.write_bytes(&mut body);
    }
    let mut request = format!(
        "POST /ingest.bin HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n",
        body.len()
    )
    .into_bytes();
    request.extend_from_slice(&body);

    type ServeFn = fn(&str, ShardSender, Arc<Telemetry>, HttpConfig) -> holmes::Result<HttpServer>;
    for (want, label) in sizes {
        let n = want.min(budget.max(EDGE_ACTIVE));
        if n < want {
            println!("   (fd limit {fd_limit}: scaled {label} down to {n} connections)");
        }
        for (prefix, serve) in [("", serve_with as ServeFn), ("legacy_", serve_legacy_with)] {
            let (tx, rx) = mpsc::sync_channel::<Frame>(1 << 15);
            let drainer = std::thread::spawn(move || {
                let mut acc = 0u64;
                for f in rx {
                    acc = acc.wrapping_add(f.patient as u64);
                }
                black_box(acc)
            });
            let tel = Arc::new(Telemetry::default());
            let server = serve(
                "127.0.0.1:0",
                ShardSender::from_senders(vec![tx]),
                Arc::clone(&tel),
                HttpConfig {
                    max_connections: n + EDGE_ACTIVE,
                    // idle held connections must survive between their
                    // turns in the rotation
                    read_timeout: Duration::from_secs(120),
                    edge_threads: 0,
                },
            )
            .expect("edge server");
            let mut conns: Vec<EdgeConn> = (0..n)
                .map(|_| EdgeConn::connect(server.addr).expect("edge connect"))
                .collect();
            // warm-up: every connection serves one request — the legacy
            // plane pays its per-connection thread spawns here, the
            // epoll plane fills its slab, and both planes prove all n
            // connections are truly accepted and working
            for start in (0..n).step_by(EDGE_ACTIVE) {
                edge_round(&mut conns, start, EDGE_ACTIVE.min(n - start), &request);
            }
            let mut round = 0usize;
            b.bench(&format!("{prefix}ingest/edge-concurrency/{label}"), || {
                let start = (round * EDGE_ACTIVE) % n;
                round += 1;
                edge_round(&mut conns, start, EDGE_ACTIVE, &request);
                black_box(round)
            });
            assert_eq!(
                tel.conns_refused.load(Ordering::Relaxed),
                0,
                "no held connection may be refused"
            );
            drop(conns);
            drop(server);
            drainer.join().expect("edge drainer");
        }
    }
}

/// Fan-in bench shape: 2 producer threads stream one 250-sample window
/// per patient for 64 patients (16k frames/round). The sharded plane
/// spreads aggregation over 2 workers on bounded channels; the legacy
/// plane funnels every frame through ONE `mpsc::Sender` into ONE
/// aggregation loop — the serial choke point this PR removes. Both
/// routers persist across bench rounds (aggregators keep state, each
/// round completes exactly one window per patient) and a round ends
/// when the consumer side has emitted all 64 windows, so consumer lag
/// is inside the measurement. The shape is kept at 2+2 threads (vs the
/// admission bench's 8) so CI's ≥ 1.0× gate measures the fan-in, not
/// oversubscription noise on a 4-core shared runner.
const FANIN_PRODUCERS: usize = 2;
const FANIN_PATIENTS: usize = 64;
const FANIN_WINDOW: usize = 250;
const FANIN_SHARDS: usize = 2;

fn fanin_traces() -> Vec<Vec<Frame>> {
    (0..FANIN_PATIENTS)
        .map(|pid| {
            (0..FANIN_WINDOW)
                .map(|i| Frame {
                    patient: pid,
                    modality: Modality::Ecg,
                    sim_time: i as f64 / 250.0,
                    values: [0.21, -0.08, 0.12].into(),
                })
                .collect()
        })
        .collect()
}

/// One multi-producer round: producer p streams the full trace of every
/// patient with `pid % FANIN_PRODUCERS == p` (frames are `Copy` — each
/// send is a stack copy into the routing layer under test).
fn fanin_round<S: Fn(Frame) + Sync>(traces: &[Vec<Frame>], send: S) {
    std::thread::scope(|s| {
        for p in 0..FANIN_PRODUCERS {
            let send = &send;
            s.spawn(move || {
                for trace in traces.iter().skip(p).step_by(FANIN_PRODUCERS) {
                    for f in trace {
                        send(*f);
                    }
                }
            });
        }
    });
}

fn wait_for(counter: &AtomicU64, target: u64) {
    while counter.load(Ordering::Acquire) < target {
        std::thread::yield_now();
    }
}

fn bench_shard_fanin(b: &mut Bencher) {
    let traces = fanin_traces();

    // sharded plane: FANIN_SHARDS aggregation workers, bounded queues;
    // producer p owns patients ≡ p (mod FANIN_PRODUCERS), which with
    // FANIN_SHARDS == FANIN_PRODUCERS pairs each producer with one
    // shard — the per-patient affinity a real bedside fleet has
    let windows_sharded = Arc::new(AtomicU64::new(0));
    let (shard_router, shard_tx) = ShardRouter::spawn(
        ShardConfig { shards: FANIN_SHARDS, ..ShardConfig::default() },
        FANIN_WINDOW,
        Arc::new(Telemetry::default()),
        |_shard| {
            let done = Arc::clone(&windows_sharded);
            move |w: WindowData| {
                black_box(w.window_id);
                done.fetch_add(1, Ordering::Release);
            }
        },
    )
    .expect("shard router");
    let mut expected = 0u64;
    b.bench("aggregate/shard-fanin", || {
        fanin_round(&traces, |f| {
            shard_tx.send(f).expect("shard plane alive");
        });
        expected += FANIN_PATIENTS as u64;
        wait_for(&windows_sharded, expected);
        black_box(expected)
    });
    drop(shard_tx);
    shard_router.join().expect("shard join");

    // legacy plane: every producer contends on one channel, one thread
    // aggregates every frame
    let windows_legacy = Arc::new(AtomicU64::new(0));
    let (ltx, lrx) = mpsc::channel::<Frame>();
    let legacy_loop = {
        let done = Arc::clone(&windows_legacy);
        std::thread::spawn(move || {
            let mut aggs: HashMap<usize, WindowAggregator> = HashMap::new();
            for frame in lrx {
                let agg = aggs
                    .entry(frame.patient)
                    .or_insert_with(|| WindowAggregator::new(frame.patient, FANIN_WINDOW));
                if let Some(w) = agg.push(&frame) {
                    black_box(w.window_id);
                    done.fetch_add(1, Ordering::Release);
                }
            }
        })
    };
    let mut expected = 0u64;
    b.bench("legacy_aggregate/shard-fanin", || {
        fanin_round(&traces, |f| {
            ltx.send(f).expect("legacy aggregation loop alive");
        });
        expected += FANIN_PATIENTS as u64;
        wait_for(&windows_legacy, expected);
        black_box(expected)
    });
    drop(ltx);
    legacy_loop.join().expect("legacy aggregation join");
}

/// Completion bench shape: 4 threads × 1024 queries × 3 members. The
/// direct plane scores through per-member `Completer`s — whichever
/// thread lands the last member runs the finish inline, fully parallel.
/// The legacy plane sends every member report through one MPSC channel
/// to a single collector thread that does the scoring + finishing — the
/// fan-in this PR deletes. A round ends when every query of the round
/// has completed.
const CMP_THREADS: usize = 4;
const CMP_QUERIES_PER_THREAD: usize = 1024;
const CMP_MEMBERS: usize = 3;

fn bench_direct_vs_collector(b: &mut Bencher) {
    // direct: batcher-side completion handles, one per member
    let pending = Arc::new(PendingSlots::new(CMP_MEMBERS));
    let telemetry = Arc::new(Telemetry::default());
    let completers: Vec<Completer> = (0..CMP_MEMBERS)
        .map(|pos| Completer::new(Arc::clone(&pending), Arc::clone(&telemetry), pos))
        .collect();
    b.bench("complete/direct-vs-collector", || {
        std::thread::scope(|s| {
            for t in 0..CMP_THREADS {
                let pending = &pending;
                let completers = &completers;
                s.spawn(move || {
                    for q in 0..CMP_QUERIES_PER_THREAD {
                        let id = (t * CMP_QUERIES_PER_THREAD + q) as u64;
                        pending.insert(id, adm_meta());
                        for c in completers {
                            c.score(id, 0.5, Duration::ZERO, Duration::ZERO);
                        }
                    }
                });
            }
        });
        black_box(pending.len())
    });

    // legacy: identical insert+score work, but every report crosses one
    // channel into one collector thread (replica of the pre-refactor
    // collector_loop: telemetry + score + finish, serialized)
    let lg_pending = Arc::new(PendingSlots::new(CMP_MEMBERS));
    let lg_tel = Arc::new(Telemetry::default());
    let lg_done = Arc::new(AtomicU64::new(0));
    let (report_tx, report_rx) = mpsc::channel::<(u64, usize, f32)>();
    let collector = {
        let pending = Arc::clone(&lg_pending);
        let tel = Arc::clone(&lg_tel);
        let done = Arc::clone(&lg_done);
        std::thread::spawn(move || {
            for (id, pos, score) in report_rx {
                tel.exec.record(Duration::ZERO);
                tel.model_jobs.fetch_add(1, Ordering::Relaxed);
                if let holmes::serving::ScoreOutcome::Completed(c) =
                    pending.score(id, pos, score, Duration::ZERO)
                {
                    // finish() replica: bagging mean + telemetry
                    tel.e2e.record(c.meta.emitted.elapsed());
                    tel.queueing.record(c.min_queue_wait);
                    tel.queries.fetch_add(1, Ordering::Relaxed);
                    black_box(c.score_sum / CMP_MEMBERS as f64);
                    done.fetch_add(1, Ordering::Release);
                }
            }
        })
    };
    let mut expected = 0u64;
    b.bench("legacy_complete/direct-vs-collector", || {
        std::thread::scope(|s| {
            for t in 0..CMP_THREADS {
                let pending = &lg_pending;
                let report_tx = report_tx.clone();
                s.spawn(move || {
                    for q in 0..CMP_QUERIES_PER_THREAD {
                        let id = (t * CMP_QUERIES_PER_THREAD + q) as u64;
                        pending.insert(id, adm_meta());
                        for pos in 0..CMP_MEMBERS {
                            report_tx.send((id, pos, 0.5)).expect("collector alive");
                        }
                    }
                });
            }
        });
        expected += (CMP_THREADS * CMP_QUERIES_PER_THREAD) as u64;
        wait_for(&lg_done, expected);
        black_box(lg_pending.len())
    });
    drop(report_tx);
    collector.join().expect("collector join");
}

/// Execution-layer bench shape: one round submits [`EXE_QUERIES`]
/// ensemble queries (each fanning to every member) and waits for all
/// predictions. Both planes share the lock-free pending arena and
/// direct `Completer` completion — what differs is purely the execution
/// layer: a fixed [`EXE_WORKERS`]-thread work-stealing pool running
/// models inline vs one OS thread per model blocking on the engine's
/// job FIFO. At 16 models the legacy plane runs 16 threads (plus the
/// engine pool); the executor still runs 4.
const EXE_WORKERS: usize = 4;
const EXE_QUERIES: usize = 128;
const EXE_CLIP: usize = 400;
const EXE_MODEL_COUNTS: [usize; 3] = [1, 4, 16];

fn exe_round<F: FnMut(usize, BatchItem)>(
    pending: &PendingSlots,
    leads: &[WindowLease; 3],
    lane_leads: &[usize],
    next_id: &mut u64,
    mut push: F,
) -> f64 {
    let m = lane_leads.len();
    let mut replies = Vec::with_capacity(EXE_QUERIES);
    for _ in 0..EXE_QUERIES {
        let id = *next_id;
        *next_id += 1;
        let (tx, rx) = mpsc::sync_channel(1);
        pending.insert(
            id,
            PendingMeta {
                patient: 0,
                window_id: id,
                sim_end: 0.0,
                emitted: Instant::now(),
                reply: Some(tx),
            },
        );
        for pos in 0..m {
            push(
                pos,
                BatchItem {
                    query_id: id,
                    input: leads[lane_leads[pos]].clone(),
                    enqueued: Instant::now(),
                },
            );
        }
        replies.push(rx);
    }
    let mut acc = 0.0;
    for rx in replies {
        acc += rx.recv().expect("every query predicts").score;
    }
    acc
}

fn bench_steal_vs_thread_per_model(b: &mut Bencher) {
    // fixed paper-shaped toy zoo (the executor bench must not depend on
    // which artifacts are on disk): 16 models over 3 leads
    let zoo = testkit::toy_zoo_with(16, 16, 7, EXE_CLIP, &[1, 8]);
    let engine =
        Engine::with_backend(&zoo, 2, Arc::new(SimBackend::instant(&zoo))).expect("engine");
    let policy = BatchPolicy { max_batch: 8, timeout: Duration::ZERO, ..BatchPolicy::default() };
    let leads: [WindowLease; 3] = [
        WindowLease::from_vec((0..EXE_CLIP).map(|i| (i as f32 * 0.01).sin()).collect()),
        WindowLease::from_vec((0..EXE_CLIP).map(|i| (i as f32 * 0.02).cos()).collect()),
        WindowLease::from_vec((0..EXE_CLIP).map(|i| (i as f32 * 0.03).sin()).collect()),
    ];
    for m in EXE_MODEL_COUNTS {
        let lane_leads: Vec<usize> = (0..m).map(|i| zoo.model(i).lead).collect();

        // work-stealing pool, driven through the executor's lane API
        let pending = Arc::new(PendingSlots::new(m));
        let telemetry = Arc::new(Telemetry::default());
        let members: Vec<(usize, Completer)> = (0..m)
            .map(|pos| {
                (pos, Completer::new(Arc::clone(&pending), Arc::clone(&telemetry), pos))
            })
            .collect();
        let (exec, lanes) =
            Executor::spawn(&engine, members, policy, EXE_WORKERS, DEFAULT_SLO, None)
                .expect("executor");
        let mut next_id = 0u64;
        b.bench(&format!("execute/steal-vs-thread-per-model/{m}-models"), || {
            black_box(exe_round(&pending, &leads, &lane_leads, &mut next_id, |pos, item| {
                lanes.push(pos, item).expect("lane alive")
            }))
        });
        drop(lanes);
        drop(exec);

        // thread-per-model replica: the pre-refactor execution layer
        let pending = Arc::new(PendingSlots::new(m));
        let telemetry = Arc::new(Telemetry::default());
        let plane = legacy::ThreadPerModel::spawn(&engine, &pending, &telemetry, m, policy);
        let mut next_id = 0u64;
        b.bench(&format!("legacy_execute/steal-vs-thread-per-model/{m}-models"), || {
            black_box(exe_round(&pending, &leads, &lane_leads, &mut next_id, |pos, item| {
                plane.push(pos, item)
            }))
        });
        plane.shutdown();
    }
}

/// Deadline-controller bench shape: the SAME executor pool and load,
/// differing only in the fill-deadline source — the SLO-aware
/// [`DeadlineController`] (adaptive, `timeout_max` = the static
/// timeout) vs the static [`BatchPolicy::timeout`] (`legacy_` prefix).
///
/// * **burst** — one round submits [`ADP_BURST`] queries back to back
///   and waits for every prediction. `ADP_BURST % max_batch != 0`, so
///   after the full batches drain each lane holds a partial tail: the
///   static policy waits the whole 2 ms fill window for stragglers that
///   never come, while the controller — seeing backlog burn down and a
///   wide-open SLO — arms only the depth-scaled remainder.
/// * **trickle** — closed loop, one query in flight at a time: depth
///   never exceeds 1, so the controller relaxes toward the cap and both
///   planes pay a comparable (deliberate, launch-amortizing) wait.
const ADP_MODELS: usize = 3;
const ADP_BURST: usize = 36; // 36 % 8 = 4 → a partial tail batch per lane
const ADP_TRICKLE: usize = 4;
const ADP_FILL: Duration = Duration::from_millis(2);

/// Submit `n` queries over `m` lanes; `closed_loop` waits for each
/// prediction before submitting the next (trickle), otherwise all are
/// in flight together (burst).
fn adp_round<F: FnMut(usize, BatchItem)>(
    pending: &PendingSlots,
    leads: &[WindowLease; 3],
    lane_leads: &[usize],
    next_id: &mut u64,
    n: usize,
    closed_loop: bool,
    mut push: F,
) -> f64 {
    let m = lane_leads.len();
    let mut acc = 0.0;
    let mut replies = Vec::with_capacity(n);
    for _ in 0..n {
        let id = *next_id;
        *next_id += 1;
        let (tx, rx) = mpsc::sync_channel(1);
        pending.insert(
            id,
            PendingMeta {
                patient: 0,
                window_id: id,
                sim_end: 0.0,
                emitted: Instant::now(),
                reply: Some(tx),
            },
        );
        for pos in 0..m {
            push(
                pos,
                BatchItem {
                    query_id: id,
                    input: leads[lane_leads[pos]].clone(),
                    enqueued: Instant::now(),
                },
            );
        }
        if closed_loop {
            acc += rx.recv().expect("every query predicts").score;
        } else {
            replies.push(rx);
        }
    }
    for rx in replies {
        acc += rx.recv().expect("every query predicts").score;
    }
    acc
}

fn bench_adaptive_vs_static(b: &mut Bencher) {
    let zoo = testkit::toy_zoo_with(ADP_MODELS, 16, 7, EXE_CLIP, &[1, 8]);
    let engine =
        Engine::with_backend(&zoo, 2, Arc::new(SimBackend::instant(&zoo))).expect("engine");
    let leads: [WindowLease; 3] = [
        WindowLease::from_vec((0..EXE_CLIP).map(|i| (i as f32 * 0.01).sin()).collect()),
        WindowLease::from_vec((0..EXE_CLIP).map(|i| (i as f32 * 0.02).cos()).collect()),
        WindowLease::from_vec((0..EXE_CLIP).map(|i| (i as f32 * 0.03).sin()).collect()),
    ];
    let lane_leads: Vec<usize> = (0..ADP_MODELS).map(|i| zoo.model(i).lead).collect();
    let adaptive_policy = BatchPolicy {
        max_batch: 8,
        timeout: ADP_FILL,
        timeout_min: Duration::ZERO,
        timeout_max: ADP_FILL, // same cap as the static window: apples to apples
        adaptive: true,
    };
    let static_policy = BatchPolicy { max_batch: 8, timeout: ADP_FILL, ..BatchPolicy::default() };
    for (prefix, policy) in [("", adaptive_policy), ("legacy_", static_policy)] {
        for (shape, n, closed_loop) in
            [("burst", ADP_BURST, false), ("trickle", ADP_TRICKLE, true)]
        {
            let pending = Arc::new(PendingSlots::new(ADP_MODELS));
            let telemetry = Arc::new(Telemetry::default());
            let members: Vec<(usize, Completer)> = (0..ADP_MODELS)
                .map(|pos| {
                    (pos, Completer::new(Arc::clone(&pending), Arc::clone(&telemetry), pos))
                })
                .collect();
            // the adaptive controller reads the live T_q/T_s split the
            // completers record — the full feedback loop is in-bench
            let (exec, lanes) = Executor::spawn(
                &engine,
                members,
                policy,
                EXE_WORKERS,
                Duration::from_secs(1),
                Some(Arc::clone(&telemetry)),
            )
            .expect("executor");
            let mut next_id = 0u64;
            b.bench(&format!("{prefix}execute/adaptive-vs-static/{shape}"), || {
                black_box(adp_round(
                    &pending,
                    &leads,
                    &lane_leads,
                    &mut next_id,
                    n,
                    closed_loop,
                    |pos, item| lanes.push(pos, item).expect("lane alive"),
                ))
            });
            drop(lanes);
            drop(exec);
        }
    }
}

/// Governed-membership bench shape: [`GOV_ROUND`] closed-loop queries
/// per round through a [`GOV_MODELS`]-lane pipeline. The governed arm
/// hot-swaps membership twice per round (full universe for the first
/// half, a two-lane degraded floor for the second — what the governor
/// does under overload); the static arm serves the whole round on the
/// full set. The floor half executes 2 model jobs per query instead of
/// [`GOV_MODELS`], so governed throughput must beat static by more
/// than the two router-FIFO installs cost — the `govern/swap-vs-static`
/// ratio CI gates at ≥ 1.0×.
const GOV_MODELS: usize = 5;
const GOV_CLIP: usize = 256;
const GOV_ROUND: usize = 64;

fn gov_leads(w: u64) -> [Vec<f32>; 3] {
    let mut leads: [Vec<f32>; 3] = Default::default();
    for (l, lead) in leads.iter_mut().enumerate() {
        *lead = (0..GOV_CLIP)
            .map(|i| ((w as usize * 17 + l * 5 + i) as f32 * 0.01).sin())
            .collect();
    }
    leads
}

fn bench_govern_swap_vs_static(b: &mut Bencher) {
    let zoo = testkit::toy_zoo_with(GOV_MODELS, 16, 11, GOV_CLIP, &[1, 8]);
    let engine =
        Engine::with_backend(&zoo, 2, Arc::new(SimBackend::instant(&zoo))).expect("engine");
    let ensemble = Selector::from_indices(zoo.n(), 0..GOV_MODELS);
    for (name, swap) in
        [("govern/swap-vs-static", true), ("legacy_govern/swap-vs-static", false)]
    {
        let pipeline =
            Pipeline::spawn(&zoo, &engine, PipelineConfig::new(ensemble.clone()))
                .expect("pipeline");
        let mut w = 0u64;
        b.bench(name, || {
            let mut acc = 0.0f64;
            for half in 0..2usize {
                if swap {
                    let members: Vec<usize> =
                        if half == 0 { (0..GOV_MODELS).collect() } else { vec![0, 1] };
                    pipeline.install_membership(&members).expect("install");
                }
                let mut replies = Vec::with_capacity(GOV_ROUND / 2);
                for _ in 0..GOV_ROUND / 2 {
                    w += 1;
                    replies
                        .push(pipeline.submit(Query::from_vecs(0, w, 0.0, gov_leads(w))).unwrap());
                }
                for r in replies {
                    acc += r.recv().unwrap().score;
                }
            }
            black_box(acc)
        });
        drop(pipeline);
    }
}

/// Window-arena bench shape: one round streams [`ARENA_ROUND_WINDOWS`]
/// full ECG windows through one aggregator; the sink drops each window
/// immediately (as the executor does once a batch is packed), so the
/// pooled plane recycles its three lead buffers every window while the
/// legacy replica pays `Vec` + `Arc<[f32]>` allocations and a full copy
/// per lead per window.
const ARENA_WINDOW: usize = 2500; // the paper's 10 s × 250 Hz clip
const ARENA_ROUND_WINDOWS: usize = 4;

fn arena_frame(i: usize) -> Frame {
    Frame {
        patient: 0,
        modality: Modality::Ecg,
        sim_time: i as f64 / 250.0,
        values: [0.21, -0.08, 0.12].into(),
    }
}

fn bench_pooled_vs_alloc(b: &mut Bencher) {
    let pool = LeadPool::new(ARENA_WINDOW);
    let mut agg = WindowAggregator::with_pool(0, ARENA_WINDOW, pool);
    b.bench("aggregate/pooled-vs-alloc", || {
        let mut emitted = 0usize;
        for i in 0..ARENA_WINDOW * ARENA_ROUND_WINDOWS {
            if let Some(w) = agg.push(&arena_frame(i)) {
                black_box(w.leads[2][ARENA_WINDOW - 1]);
                emitted += 1; // dropping `w` returns the buffers
            }
        }
        black_box(emitted)
    });

    let mut lagg = legacy::AllocAggregator::new(ARENA_WINDOW);
    b.bench("legacy_aggregate/pooled-vs-alloc", || {
        let mut emitted = 0usize;
        for i in 0..ARENA_WINDOW * ARENA_ROUND_WINDOWS {
            if let Some(leads) = lagg.push(&arena_frame(i)) {
                black_box(leads[2][ARENA_WINDOW - 1]);
                emitted += 1;
            }
        }
        black_box(emitted)
    });
}

/// The same round on the in-bench mutex-striped replica.
fn admission_round_striped(table: &legacy::StripedPending) {
    std::thread::scope(|s| {
        for t in 0..ADM_THREADS {
            s.spawn(move || {
                for q in 0..ADM_QUERIES_PER_THREAD {
                    let id = (t * ADM_QUERIES_PER_THREAD + q) as u64;
                    table.insert(id);
                    for m in 0..ADM_MEMBERS {
                        black_box(table.score(id, m, 0.5).is_some());
                    }
                }
            });
        }
    });
}

/// Emit medians + new-vs-legacy speedups to `<repo root>/BENCH_serving.json`.
fn write_bench_json(results: &[BenchResult], quick: bool, backend: &str) {
    let mut benches = BTreeMap::new();
    for r in results {
        benches.insert(
            r.name.clone(),
            Value::obj(vec![
                ("median_ns", Value::Num(r.median.as_nanos() as f64)),
                ("mean_ns", Value::Num(r.mean.as_nanos() as f64)),
                ("p95_ns", Value::Num(r.p95.as_nanos() as f64)),
                ("iters", Value::Num(r.iters as f64)),
            ]),
        );
    }
    let mut speedups = BTreeMap::new();
    for r in results {
        if let Some(stripped) = r.name.strip_prefix("legacy_") {
            if let Some(new) = results.iter().find(|n| n.name == stripped) {
                let ratio = r.median.as_secs_f64() / new.median.as_secs_f64().max(1e-12);
                speedups.insert(stripped.to_string(), Value::Num((ratio * 1000.0).round() / 1000.0));
            }
        }
    }
    let doc = Value::obj(vec![
        ("bench", Value::Str("serving".into())),
        ("backend", Value::Str(backend.into())),
        ("quick", Value::Bool(quick)),
        (
            "note",
            Value::Str(
                "medians of the lock-free zero-copy data plane vs the in-bench legacy \
                 replica, per layer (event-driven ingest edge vs thread-per-conn, \
                 sharded aggregation fan-in, pooled window arenas, ingest decode, \
                 pending-table admission, direct vs collector completion, \
                 work-stealing executor vs thread-per-model, batch packing) and \
                 end to end; regenerate with \
                 `cargo bench --bench serving -- --quick`"
                    .into(),
            ),
        ),
        ("benches", Value::Obj(benches)),
        ("speedup_vs_legacy", Value::Obj(speedups)),
    ]);
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_serving.json"))
        .expect("manifest dir has a parent");
    match std::fs::write(&path, doc.to_string() + "\n") {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

/// A faithful replica of the **pre-refactor** serving data plane, kept
/// here (not in the library) purely as the perf baseline: per-member
/// `Vec` window clones in the router, one global `Mutex<HashMap>`
/// pending table shared by router and collector, and a freshly
/// allocated padded batch buffer per flush via `execute_blocking`.
mod legacy {
    use std::collections::HashMap;
    use std::sync::{mpsc, Arc, Mutex};
    use std::time::Instant;

    use holmes::runtime::{AlignedBatch, Engine};
    use holmes::serving::batcher::{BatchItem, BatchPolicy};
    use holmes::serving::pipeline::{Completer, PendingSlots};
    use holmes::serving::Telemetry;
    use holmes::zoo::{Selector, Zoo};

    /// Replica of the pre-refactor **execution layer**: one OS thread
    /// per ensemble member looping recv → fill → pack → blocking
    /// `Engine::execute_batch` through the engine's job FIFO, completing
    /// directly through its `Completer` (completion was already direct
    /// before this PR — only the threading model is under test).
    pub struct ThreadPerModel {
        txs: Vec<mpsc::Sender<BatchItem>>,
        threads: Vec<std::thread::JoinHandle<()>>,
    }

    impl ThreadPerModel {
        pub fn spawn(
            engine: &Engine,
            pending: &Arc<PendingSlots>,
            telemetry: &Arc<Telemetry>,
            n_models: usize,
            policy: BatchPolicy,
        ) -> Self {
            let mut txs = Vec::with_capacity(n_models);
            let mut threads = Vec::with_capacity(n_models);
            for pos in 0..n_models {
                let (tx, rx) = mpsc::channel::<BatchItem>();
                let done = Completer::new(Arc::clone(pending), Arc::clone(telemetry), pos);
                let engine = engine.clone();
                threads.push(std::thread::spawn(move || {
                    actor_batch_loop(pos, engine, rx, done, policy)
                }));
                txs.push(tx);
            }
            ThreadPerModel { txs, threads }
        }

        pub fn push(&self, pos: usize, item: BatchItem) {
            self.txs[pos].send(item).expect("model actor alive");
        }

        pub fn shutdown(self) {
            drop(self.txs);
            for t in self.threads {
                let _ = t.join();
            }
        }
    }

    /// The pre-refactor per-model actor loop, verbatim in shape:
    /// blocking first recv, fast drain, one bounded straggler wait,
    /// flush through the engine FIFO.
    fn actor_batch_loop(
        model_index: usize,
        engine: Engine,
        rx: mpsc::Receiver<BatchItem>,
        done: Completer,
        policy: BatchPolicy,
    ) {
        let clip_len = engine.clip_len();
        let max_take = policy
            .max_batch
            .min(engine.batch_sizes().iter().copied().max().unwrap_or(1))
            .max(1);
        let mut pending: Vec<BatchItem> = Vec::with_capacity(max_take);
        let mut buf = AlignedBatch::new();
        loop {
            if pending.is_empty() {
                match rx.recv() {
                    Ok(item) => pending.push(item),
                    Err(_) => break,
                }
            }
            let mut closed = false;
            while pending.len() < max_take {
                match rx.try_recv() {
                    Ok(item) => pending.push(item),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        closed = true;
                        break;
                    }
                }
            }
            if !closed && pending.len() < max_take && !policy.timeout.is_zero() {
                if let Ok(item) = rx.recv_timeout(policy.timeout) {
                    pending.push(item);
                }
            }
            actor_flush(model_index, &engine, clip_len, &mut pending, &mut buf, &done, max_take);
            if closed && pending.is_empty() {
                break;
            }
        }
        while !pending.is_empty() {
            actor_flush(model_index, &engine, clip_len, &mut pending, &mut buf, &done, max_take);
        }
    }

    fn actor_flush(
        model_index: usize,
        engine: &Engine,
        clip_len: usize,
        pending: &mut Vec<BatchItem>,
        buf: &mut AlignedBatch,
        done: &Completer,
        max_take: usize,
    ) {
        if pending.is_empty() {
            return;
        }
        let take = pending.len().min(max_take);
        let batch = engine.batch_for(take);
        buf.reset(batch * clip_len);
        for (slot, item) in pending[..take].iter().enumerate() {
            buf.pack_slot(slot, clip_len, &item.input);
        }
        let started = Instant::now();
        match engine.execute_batch((model_index, batch), buf) {
            Ok(result) => {
                for (slot, item) in pending.drain(..take).enumerate() {
                    done.score(
                        item.query_id,
                        result.scores[slot],
                        started.duration_since(item.enqueued),
                        result.exec_time,
                    );
                }
            }
            Err(_) => {
                for item in pending.drain(..take) {
                    done.fail(item.query_id);
                }
            }
        }
    }

    /// Replica of the pre-refactor aggregator **emit path**: collect
    /// into `Vec`s, move each into a fresh `Arc<[f32]>` per window
    /// (alloc + full copy), re-grow the vecs — the per-window churn the
    /// pooled slab removes.
    pub struct AllocAggregator {
        window: usize,
        leads: [Vec<f32>; 3],
    }

    impl AllocAggregator {
        pub fn new(window: usize) -> Self {
            AllocAggregator {
                window,
                leads: [
                    Vec::with_capacity(window),
                    Vec::with_capacity(window),
                    Vec::with_capacity(window),
                ],
            }
        }

        pub fn push(&mut self, frame: &holmes::ingest::Frame) -> Option<[Arc<[f32]>; 3]> {
            for (lead, &v) in self.leads.iter_mut().zip(frame.values.iter()) {
                lead.push(v);
            }
            if self.leads[0].len() >= self.window {
                let out: [Arc<[f32]>; 3] = [
                    Arc::from(std::mem::take(&mut self.leads[0])),
                    Arc::from(std::mem::take(&mut self.leads[1])),
                    Arc::from(std::mem::take(&mut self.leads[2])),
                ];
                for lead in self.leads.iter_mut() {
                    lead.reserve(self.window);
                }
                Some(out)
            } else {
                None
            }
        }
    }

    pub struct LegacyQuery {
        pub leads: [Vec<f32>; 3],
        /// Never read — mirrors the real `Query` so the submission cost
        /// matches the pre-refactor load generator.
        #[allow(dead_code)]
        pub emitted: Instant,
    }

    /// Replica of the pre-refactor pending table — 16 mutex stripes
    /// over `HashMap<u64, entry>`, a `Vec<(model, score)>` per entry,
    /// sorted + summed at completion — kept as the admission-bench
    /// baseline now that the library uses the lock-free slot arena.
    pub struct StripedPending {
        stripes: Vec<Mutex<HashMap<u64, StripedEntry>>>,
        n_models: usize,
    }

    struct StripedEntry {
        remaining: usize,
        member_scores: Vec<(usize, f32)>,
    }

    const STRIPES: usize = 16;

    impl StripedPending {
        pub fn new(n_models: usize) -> Self {
            StripedPending {
                stripes: (0..STRIPES).map(|_| Mutex::new(HashMap::new())).collect(),
                n_models,
            }
        }

        fn stripe(&self, id: u64) -> &Mutex<HashMap<u64, StripedEntry>> {
            &self.stripes[(id % STRIPES as u64) as usize]
        }

        pub fn insert(&self, id: u64) {
            self.stripe(id).lock().unwrap().insert(
                id,
                StripedEntry {
                    remaining: self.n_models,
                    member_scores: Vec::with_capacity(self.n_models),
                },
            );
        }

        /// Record one member score; returns the deterministic bagging
        /// sum when the last member lands (and removes the entry).
        pub fn score(&self, id: u64, model: usize, score: f32) -> Option<f64> {
            let mut table = self.stripe(id).lock().unwrap();
            let entry = table.get_mut(&id)?;
            entry.member_scores.push((model, score));
            entry.remaining -= 1;
            if entry.remaining > 0 {
                return None;
            }
            let mut entry = table.remove(&id)?;
            entry.member_scores.sort_unstable_by_key(|&(m, _)| m);
            Some(entry.member_scores.iter().map(|&(_, s)| s as f64).sum())
        }

        pub fn len(&self) -> usize {
            self.stripes.iter().map(|s| s.lock().unwrap().len()).sum()
        }
    }

    struct Item {
        query_id: u64,
        input: Vec<f32>,
    }

    struct Score {
        query_id: u64,
        score: f32,
    }

    struct PendingQuery {
        remaining: usize,
        sum: f64,
        n_models: usize,
        reply: Option<mpsc::SyncSender<f64>>,
    }

    type PendingTable = Arc<Mutex<HashMap<u64, PendingQuery>>>;

    pub struct LegacyPipeline {
        tx: mpsc::Sender<(LegacyQuery, Option<mpsc::SyncSender<f64>>)>,
    }

    impl LegacyPipeline {
        pub fn spawn(
            zoo: &Zoo,
            engine: &Engine,
            ensemble: Selector,
            policy: BatchPolicy,
        ) -> LegacyPipeline {
            let pending: PendingTable = Arc::new(Mutex::new(HashMap::new()));
            let (score_tx, score_rx) = mpsc::channel::<Score>();
            let mut model_txs: HashMap<usize, mpsc::Sender<Item>> = HashMap::new();
            for &i in ensemble.indices() {
                let (btx, brx) = mpsc::channel::<Item>();
                model_txs.insert(i, btx);
                let engine = engine.clone();
                let stx = score_tx.clone();
                std::thread::spawn(move || batch_loop(i, engine, brx, stx, policy));
            }
            drop(score_tx);
            {
                let pending = Arc::clone(&pending);
                std::thread::spawn(move || {
                    for s in score_rx {
                        let done = {
                            let mut table = pending.lock().unwrap();
                            let Some(entry) = table.get_mut(&s.query_id) else { continue };
                            entry.sum += s.score as f64;
                            entry.remaining -= 1;
                            if entry.remaining == 0 { table.remove(&s.query_id) } else { None }
                        };
                        if let Some(entry) = done {
                            if let Some(reply) = entry.reply {
                                let _ = reply.send(entry.sum / entry.n_models as f64);
                            }
                        }
                    }
                });
            }
            let (tx, query_rx) =
                mpsc::channel::<(LegacyQuery, Option<mpsc::SyncSender<f64>>)>();
            {
                let pending = Arc::clone(&pending);
                let leads: HashMap<usize, usize> =
                    ensemble.indices().iter().map(|&i| (i, zoo.model(i).lead)).collect();
                std::thread::spawn(move || {
                    let mut next_id = 0u64;
                    for (q, reply) in query_rx {
                        let id = next_id;
                        next_id += 1;
                        pending.lock().unwrap().insert(
                            id,
                            PendingQuery {
                                remaining: ensemble.len(),
                                sum: 0.0,
                                n_models: ensemble.len(),
                                reply,
                            },
                        );
                        for &m in ensemble.indices() {
                            // the copy the zero-copy plane eliminated:
                            let item =
                                Item { query_id: id, input: q.leads[leads[&m]].clone() };
                            if model_txs[&m].send(item).is_err() {
                                pending.lock().unwrap().remove(&id);
                                break;
                            }
                        }
                    }
                });
            }
            LegacyPipeline { tx }
        }

        pub fn submit(&self, q: LegacyQuery) -> Result<mpsc::Receiver<f64>, ()> {
            let (tx, rx) = mpsc::sync_channel(1);
            self.tx.send((q, Some(tx))).map_err(|_| ())?;
            Ok(rx)
        }

        pub fn query(&self, q: LegacyQuery) -> Result<f64, ()> {
            self.submit(q)?.recv().map_err(|_| ())
        }
    }

    fn batch_loop(
        model_index: usize,
        engine: Engine,
        rx: mpsc::Receiver<Item>,
        out: mpsc::Sender<Score>,
        policy: BatchPolicy,
    ) {
        let clip_len = engine.clip_len();
        let max_take = policy
            .max_batch
            .min(engine.batch_sizes().iter().copied().max().unwrap_or(1))
            .max(1);
        let mut pending: Vec<Item> = Vec::with_capacity(max_take);
        loop {
            if pending.is_empty() {
                match rx.recv() {
                    Ok(item) => pending.push(item),
                    Err(_) => break,
                }
            }
            let mut closed = false;
            while pending.len() < max_take {
                match rx.try_recv() {
                    Ok(item) => pending.push(item),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        closed = true;
                        break;
                    }
                }
            }
            if !closed && pending.len() < max_take && !policy.timeout.is_zero() {
                if let Ok(item) = rx.recv_timeout(policy.timeout) {
                    pending.push(item);
                }
            }
            flush(model_index, &engine, clip_len, &mut pending, &out, max_take);
            if closed && pending.is_empty() {
                break;
            }
        }
        while !pending.is_empty() {
            flush(model_index, &engine, clip_len, &mut pending, &out, max_take);
        }
    }

    fn flush(
        model_index: usize,
        engine: &Engine,
        clip_len: usize,
        pending: &mut Vec<Item>,
        out: &mpsc::Sender<Score>,
        max_take: usize,
    ) {
        if pending.is_empty() {
            return;
        }
        let take = pending.len().min(max_take);
        let items: Vec<Item> = pending.drain(..take).collect();
        let batch = engine.batch_for(items.len());
        // fresh allocation per flush — the pre-refactor behaviour
        let mut input = vec![0.0f32; batch * clip_len];
        for (slot, item) in items.iter().enumerate() {
            input[slot * clip_len..(slot + 1) * clip_len].copy_from_slice(&item.input);
        }
        let Ok(result) = engine.execute_blocking((model_index, batch), input) else {
            return;
        };
        for (slot, item) in items.into_iter().enumerate() {
            let _ = out.send(Score { query_id: item.query_id, score: result.scores[slot] });
        }
    }

}
