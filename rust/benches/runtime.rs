//! Runtime benches: raw executable latency per zoo variant and batch
//! size — the numbers behind the latency profiler's calibration and the
//! Fig. 13 Timeit legend. Uses the feature-selected backend (PJRT with
//! `--features xla`, the sim otherwise); without built artifacts it
//! falls back to a toy zoo on the sim backend.
//!
//! `cargo bench --bench runtime`

use holmes::bench::{black_box, Bencher};
use holmes::runtime::{bench_hlo_file, Engine};
use holmes::zoo::{testkit, Zoo};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bencher::quick() } else { Bencher::default() };
    println!("== runtime benches ==");
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let zoo = if artifacts.join("zoo_manifest.json").exists() {
        Zoo::load(&artifacts).expect("artifacts load")
    } else {
        testkit::toy_zoo_with(9, 64, 3, 2500, &[1, 8])
    };
    let engine = Engine::new(&zoo, 1).expect("engine");
    let clip_len = zoo.manifest.clip_len;

    // smallest / mid / largest trained model, batch 1 and 8
    let mut servable = zoo.servable_indices();
    servable.sort_by_key(|&i| zoo.model(i).macs);
    let picks = [servable[0], servable[servable.len() / 2], servable[servable.len() - 1]];
    for &idx in &picks {
        let id = &zoo.model(idx).id;
        for &batch in &[1usize, 8] {
            let input = vec![0.1f32; batch * clip_len];
            engine.execute_blocking((idx, batch), input.clone()).unwrap(); // warm
            b.bench(&format!("execute/{id}/b{batch}"), || {
                black_box(
                    engine
                        .execute_blocking((idx, batch), input.clone())
                        .unwrap()
                        .scores[0],
                )
            });
        }
    }

    // Fig-13 window sweep artifacts (per-length raw latency)
    if let Some(sweep) = &zoo.manifest.window_sweep {
        let mut lengths: Vec<usize> =
            sweep.artifacts.keys().filter_map(|k| k.parse().ok()).collect();
        lengths.sort_unstable();
        for len in lengths {
            let path = zoo.root.join(&sweep.artifacts[&len.to_string()]);
            let times = bench_hlo_file(&path, len, if quick { 3 } else { 10 }).unwrap();
            let med = times[times.len() / 2];
            println!(
                "{:<44} window {len:>5} samples: median {:?}",
                format!("window_sweep/{}", sweep.model_id),
                med
            );
        }
    }
}
