//! Runtime benches: raw executable latency per zoo variant and batch
//! size — the numbers behind the latency profiler's calibration and the
//! Fig. 13 Timeit legend. Uses the feature-selected backend (PJRT with
//! `--features xla`, the sim otherwise); without built artifacts it
//! falls back to a toy zoo on the sim backend.
//!
//! Emits `<repo root>/BENCH_runtime.json` with a `modelled` stamp: on
//! the sim backend every duration comes from the analytic cost model,
//! and the JSON says so rather than passing the numbers off as
//! measured XLA times.
//!
//! `cargo bench --bench runtime`

use std::collections::BTreeMap;
use std::path::Path;

use holmes::bench::{black_box, Bencher};
use holmes::json::Value;
use holmes::runtime::{bench_hlo_file, Engine};
use holmes::zoo::{testkit, Zoo};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bencher::quick() } else { Bencher::default() };
    println!("== runtime benches ==");
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let zoo = if artifacts.join("zoo_manifest.json").exists() {
        Zoo::load(&artifacts).expect("artifacts load")
    } else {
        testkit::toy_zoo_with(9, 64, 3, 2500, &[1, 8])
    };
    let engine = Engine::new(&zoo, 1).expect("engine");
    let clip_len = zoo.manifest.clip_len;
    // sim-backend executions are modelled service times, not device
    // measurements; stamp that into everything this bench emits
    let modelled = engine.backend_name() != "pjrt";

    // smallest / mid / largest trained model, batch 1 and 8
    let mut servable = zoo.servable_indices();
    servable.sort_by_key(|&i| zoo.model(i).macs);
    let picks = [servable[0], servable[servable.len() / 2], servable[servable.len() - 1]];
    for &idx in &picks {
        let id = &zoo.model(idx).id;
        for &batch in &[1usize, 8] {
            let input = vec![0.1f32; batch * clip_len];
            engine.execute_blocking((idx, batch), input.clone()).unwrap(); // warm
            b.bench(&format!("execute/{id}/b{batch}"), || {
                black_box(
                    engine
                        .execute_blocking((idx, batch), input.clone())
                        .unwrap()
                        .scores[0],
                )
            });
        }
    }

    // Fig-13 window sweep artifacts (per-length raw latency)
    let mut sweep_medians: Vec<(usize, f64, bool)> = Vec::new();
    if let Some(sweep) = &zoo.manifest.window_sweep {
        let mut lengths: Vec<usize> =
            sweep.artifacts.keys().filter_map(|k| k.parse().ok()).collect();
        lengths.sort_unstable();
        for len in lengths {
            let path = zoo.root.join(&sweep.artifacts[&len.to_string()]);
            let hlo = bench_hlo_file(&path, len, if quick { 3 } else { 10 }).unwrap();
            let med = hlo.median();
            println!(
                "{:<44} window {len:>5} samples: median {:?}{}",
                format!("window_sweep/{}", sweep.model_id),
                med,
                if hlo.modelled { "  (modelled)" } else { "" }
            );
            sweep_medians.push((len, med.as_nanos() as f64, hlo.modelled));
        }
    }

    write_bench_json(&b, &sweep_medians, quick, engine.backend_name(), modelled);
}

/// Emit medians to `<repo root>/BENCH_runtime.json`, stamped with
/// whether the backend modelled the durations.
fn write_bench_json(
    b: &Bencher,
    sweep: &[(usize, f64, bool)],
    quick: bool,
    backend: &str,
    modelled: bool,
) {
    let mut benches = BTreeMap::new();
    for r in b.results() {
        benches.insert(
            r.name.clone(),
            Value::obj(vec![
                ("median_ns", Value::Num(r.median.as_nanos() as f64)),
                ("mean_ns", Value::Num(r.mean.as_nanos() as f64)),
                ("p95_ns", Value::Num(r.p95.as_nanos() as f64)),
                ("iters", Value::Num(r.iters as f64)),
                ("modelled", Value::Bool(modelled)),
            ]),
        );
    }
    for (len, median_ns, m) in sweep {
        benches.insert(
            format!("window_sweep/{len}"),
            Value::obj(vec![
                ("median_ns", Value::Num(*median_ns)),
                ("modelled", Value::Bool(*m)),
            ]),
        );
    }
    let doc = Value::obj(vec![
        ("bench", Value::Str("runtime".into())),
        ("backend", Value::Str(backend.into())),
        ("quick", Value::Bool(quick)),
        ("modelled", Value::Bool(modelled)),
        (
            "note",
            Value::Str(
                "raw executable latency per zoo variant/batch plus the Fig-13 \
                 window sweep; modelled=true means the durations come from the \
                 sim cost model (build with --features xla for measured times); \
                 regenerate with `cargo bench --bench runtime -- --quick`"
                    .into(),
            ),
        ),
        ("benches", Value::Obj(benches)),
    ]);
    if modelled {
        println!("\nnote: durations are MODELLED (sim backend) — not measured XLA times");
    }
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_runtime.json"))
        .expect("manifest dir has a parent");
    match std::fs::write(&path, doc.to_string() + "\n") {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
