//! Composer benches: full Table-2-configuration searches per method, a
//! single SMBO iteration's components, and the ablations DESIGN.md calls
//! out (genetic vs random exploration).
//!
//! `cargo bench --bench composer`

use holmes::bench::{black_box, Bencher};
use holmes::composer::{explore, Composer};
use holmes::config::{ComposerConfig, SystemConfig};
use holmes::exp::common::{Method, SearchContext};
use holmes::profiler::{AccuracyProfiler, ValidationAccuracyProfiler};
use holmes::rng::Rng;
use holmes::zoo::{Selector, Zoo};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bencher::quick() } else { Bencher::default() };
    println!("== composer benches ==");
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let zoo = if artifacts.join("zoo_manifest.json").exists() {
        Zoo::load(&artifacts).expect("artifacts load")
    } else {
        holmes::zoo::testkit::toy_zoo(60, 200, 7)
    };
    let system = SystemConfig { gpus: 2, patients: 32, window_s: 30.0 };
    let ctx = SearchContext::new(&zoo, system);
    let cfg = ComposerConfig::default();

    // ---- end-to-end searches (one per Table-2 method)
    for m in Method::ALL {
        b.bench(&format!("search/{}@200ms", m.name()), || {
            black_box(ctx.run(m, 0.2, 0, &cfg).best.accuracy.roc_auc)
        });
    }

    // ---- components of one SMBO iteration
    let acc = ValidationAccuracyProfiler::from_zoo(&zoo);
    let ten = Selector::from_indices(zoo.n(), (0..10).map(|i| i * 5));
    b.bench("profiler/f_a/10-model-ensemble", || black_box(acc.accuracy(&ten).roc_auc));

    let mut rng = Rng::seed_from_u64(9);
    let b_set: Vec<Selector> = (0..24)
        .map(|i| Selector::from_indices(zoo.n(), [i, i + 7, i + 13]))
        .collect();
    b.bench("explore/64-candidates", || {
        black_box(explore(&b_set, zoo.n(), 64, 3, 0.8, 0.5, None, &mut rng).len())
    });

    // ---- ablation: genetic exploration vs pure random (p_genetic = 0)
    let cfg_random = ComposerConfig { p_genetic: 0.0, ..Default::default() };
    let lat = holmes::profiler::AnalyticLatencyProfiler::new(
        holmes::exp::common::default_service_times(&zoo),
    );
    for (name, c) in [("genetic", &cfg), ("random-explore", &cfg_random)] {
        b.bench(&format!("ablation/holmes-{name}"), || {
            let composer = Composer::new(&zoo, &acc, &lat, c.clone(), system);
            black_box(composer.search(&[]).best.accuracy.roc_auc)
        });
    }
}
