//! Substrate micro-benchmarks: metrics, surrogate forest, network
//! calculus, JSON, RNG, and the synthetic ECG generator — the pieces
//! under the composer's profiler calls and the ingest hot path.
//!
//! `cargo bench --bench substrates` (add `-- --quick` for a short run).

use holmes::bench::{black_box, Bencher};
use holmes::ingest::synth::{PatientSim, SynthConfig};
use holmes::json::Value;
use holmes::metrics::{pr_auc, roc_auc};
use holmes::netcalc::{queueing_bound, ArrivalCurve, ServiceCurve};
use holmes::rng::Rng;
use holmes::surrogate::{ForestConfig, RandomForest, Surrogate};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bencher::quick() } else { Bencher::default() };
    println!("== substrate benches ==");

    // ---- metrics on a profiler-sized validation set (560 samples)
    let mut rng = Rng::seed_from_u64(1);
    let labels: Vec<u8> = (0..560).map(|_| rng.bool(0.5) as u8).collect();
    let scores: Vec<f64> = (0..560).map(|_| rng.f64()).collect();
    b.bench("metrics/roc_auc/560", || black_box(roc_auc(&labels, &scores)));
    b.bench("metrics/pr_auc/560", || black_box(pr_auc(&labels, &scores)));

    // ---- random-forest surrogate: SMBO-sized fit + predict
    let x: Vec<Vec<f64>> =
        (0..150).map(|_| (0..67).map(|_| rng.f64().round()).collect()).collect();
    let y: Vec<f64> = (0..150).map(|_| rng.f64()).collect();
    b.bench("surrogate/rf_fit/150x67/60trees", || {
        let mut rf = RandomForest::new(ForestConfig::default());
        rf.fit(&x, &y);
        black_box(rf.n_trees())
    });
    let mut rf = RandomForest::new(ForestConfig::default());
    rf.fit(&x, &y);
    b.bench("surrogate/rf_predict/67f", || black_box(rf.predict(&x[0])));

    // ---- network calculus on a profiling-sized trace
    let ts: Vec<f64> = (0..48).map(|i| i as f64 * 0.03).collect();
    b.bench("netcalc/exact_curve+bound/48", || {
        let ac = ArrivalCurve::from_timestamps_exact(&ts);
        black_box(queueing_bound(&ac, &ServiceCurve::new(50.0, 0.01)))
    });

    // ---- JSON: parse a manifest-like document
    let manifest = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/zoo_manifest.json"),
    )
    .ok();
    if let Some(text) = manifest {
        b.bench("json/parse_zoo_manifest", || black_box(Value::parse(&text).unwrap()));
    }

    // ---- RNG + ECG synthesis (ingest-side load generator)
    let mut r = Rng::seed_from_u64(2);
    b.bench("rng/normal", || black_box(r.normal()));
    let mut sim = PatientSim::new(0, 3, SynthConfig::default());
    b.bench("synth/ecg_sample_3lead", || black_box(sim.next_ecg()));
    b.bench("synth/one_second_250hz", || {
        let mut acc = 0.0f32;
        for _ in 0..250 {
            acc += sim.next_ecg()[1];
        }
        black_box(acc)
    });
}
