//! API-compatible stand-in for the `xla` (PJRT) crate.
//!
//! Exists so `--features xla` type-checks offline; every device entry
//! point returns [`Error`] telling the operator to vendor the real
//! crate (see README.md). The default build never compiles these paths.

use std::fmt;

/// Error type mirroring `xla::Error` (a plain message here).
#[derive(Debug, Clone)]
pub struct Error(pub String);

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "PJRT is not vendored in this build: replace rust/vendor/xla with the real \
         xla crate (see rust/vendor/xla/README.md), or build without --features xla \
         to use the pure-Rust SimBackend"
            .to_string(),
    )
}

/// PJRT client handle (CPU platform in this reproduction).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Parsed HLO module (text format).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host literal (typed dense array).
pub struct Literal;

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Compiled + loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}
