//! Model zoo: Table-3 profiles, validation score vectors, artifact paths.
//!
//! The zoo is materialised by `make artifacts` (python, build-time) into
//! `artifacts/zoo_manifest.json` + `artifacts/val_scores.json` +
//! `artifacts/models/*.hlo.txt`. This module is the rust view of it: the
//! profile matrix `V ∈ R^{n×m}` the composer searches over, and the
//! per-model validation scores the accuracy profiler `f_a(V, b)`
//! aggregates (paper Eq. 5).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::json::Value;
use crate::{Error, Result};

/// One zoo model's profile — the fields of the paper's Table 3.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub index: usize,
    pub id: String,
    pub lead: usize,
    pub width: usize,
    pub blocks: usize,
    pub depth: usize,
    pub cardinality: usize,
    pub macs: u64,
    pub params: u64,
    pub memory_bytes: u64,
    pub input_modality: String,
    pub input_len: usize,
    pub val_auc: f64,
    /// True when real weights were trained and HLO artifacts exist.
    pub trained: bool,
    /// batch-size (as string key) → HLO path relative to the artifact dir.
    pub artifacts: HashMap<String, String>,
}

impl ModelProfile {
    fn from_json(v: &Value) -> Result<Self> {
        let num = |k: &str| -> Result<f64> {
            v.req(k)?.as_f64().ok_or_else(|| Error::json(format!("field '{k}' not numeric")))
        };
        let mut artifacts = HashMap::new();
        if let Some(obj) = v.req("artifacts")?.as_obj() {
            for (k, p) in obj {
                artifacts.insert(
                    k.clone(),
                    p.as_str().ok_or_else(|| Error::json("artifact path not a string"))?.to_string(),
                );
            }
        }
        Ok(ModelProfile {
            index: num("index")? as usize,
            id: v.req("id")?.as_str().ok_or_else(|| Error::json("id"))?.to_string(),
            lead: num("lead")? as usize,
            width: num("width")? as usize,
            blocks: num("blocks")? as usize,
            depth: num("depth")? as usize,
            cardinality: num("cardinality")? as usize,
            macs: num("macs")? as u64,
            params: num("params")? as u64,
            memory_bytes: num("memory_bytes")? as u64,
            input_modality: v
                .req("input_modality")?
                .as_str()
                .ok_or_else(|| Error::json("input_modality"))?
                .to_string(),
            input_len: num("input_len")? as usize,
            val_auc: num("val_auc")?,
            trained: v.req("trained")?.as_bool().ok_or_else(|| Error::json("trained"))?,
            artifacts,
        })
    }
    /// Feature vector for the surrogate models: the profile columns that
    /// describe model capacity/cost (not the binary selector itself).
    pub fn feature_row(&self) -> Vec<f64> {
        vec![
            self.lead as f64,
            (self.width as f64).log2(),
            (self.blocks as f64).log2(),
            (self.macs as f64).ln(),
            self.val_auc,
        ]
    }

    pub fn servable(&self) -> bool {
        self.trained && !self.artifacts.is_empty()
    }

    pub fn artifact_for_batch(&self, batch: usize) -> Option<&str> {
        self.artifacts.get(&batch.to_string()).map(|s| s.as_str())
    }
}

/// Synthetic-generator calibration constants (mirror of python data.py).
#[derive(Debug, Clone)]
pub struct Calibration {
    pub fs: u32,
    pub lead_amp: Vec<f64>,
    pub lead_noise: Vec<f64>,
    pub hr_base: f64,
    pub hr_sev_gain: f64,
    pub hrv_base: f64,
    pub hrv_stable_gain: f64,
    pub st_depression: f64,
    pub noise_base: f64,
    pub noise_sev_gain: f64,
}

impl Calibration {
    fn from_json(v: &Value) -> Result<Self> {
        let num = |k: &str| -> Result<f64> {
            v.req(k)?.as_f64().ok_or_else(|| Error::json(format!("calibration '{k}'")))
        };
        Ok(Calibration {
            fs: num("fs")? as u32,
            lead_amp: v.req("lead_amp")?.as_f64_vec()?,
            lead_noise: v.req("lead_noise")?.as_f64_vec()?,
            hr_base: num("hr_base")?,
            hr_sev_gain: num("hr_sev_gain")?,
            hrv_base: num("hrv_base")?,
            hrv_stable_gain: num("hrv_stable_gain")?,
            st_depression: num("st_depression")?,
            noise_base: num("noise_base")?,
            noise_sev_gain: num("noise_sev_gain")?,
        })
    }
}

/// Fig.-13 window-sweep artifacts: one model lowered at several input
/// lengths (`length → HLO path`).
#[derive(Debug, Clone)]
pub struct WindowSweep {
    pub model_id: String,
    pub artifacts: HashMap<String, String>,
}

/// `artifacts/zoo_manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub clip_len: usize,
    pub fs: u32,
    pub batch_sizes: Vec<usize>,
    pub n_models: usize,
    pub calibration: Calibration,
    pub val_n: usize,
    pub window_sweep: Option<WindowSweep>,
    pub models: Vec<ModelProfile>,
}

impl Manifest {
    pub fn from_json_text(text: &str) -> Result<Self> {
        let v = Value::parse(text)?;
        let num = |k: &str| -> Result<f64> {
            v.req(k)?.as_f64().ok_or_else(|| Error::json(format!("manifest '{k}'")))
        };
        let models = v
            .req("models")?
            .as_arr()
            .ok_or_else(|| Error::json("models not an array"))?
            .iter()
            .map(ModelProfile::from_json)
            .collect::<Result<Vec<_>>>()?;
        let window_sweep = match v.get("window_sweep") {
            Some(Value::Obj(o)) => {
                let mut artifacts = HashMap::new();
                if let Some(a) = o.get("artifacts").and_then(|a| a.as_obj()) {
                    for (k, p) in a {
                        artifacts.insert(
                            k.clone(),
                            p.as_str().ok_or_else(|| Error::json("sweep path"))?.to_string(),
                        );
                    }
                }
                Some(WindowSweep {
                    model_id: o
                        .get("model_id")
                        .and_then(|m| m.as_str())
                        .ok_or_else(|| Error::json("sweep model_id"))?
                        .to_string(),
                    artifacts,
                })
            }
            _ => None,
        };
        Ok(Manifest {
            version: num("version")? as u32,
            clip_len: num("clip_len")? as usize,
            fs: num("fs")? as u32,
            batch_sizes: v
                .req("batch_sizes")?
                .as_f64_vec()?
                .into_iter()
                .map(|b| b as usize)
                .collect(),
            n_models: num("n_models")? as usize,
            calibration: Calibration::from_json(v.req("calibration")?)?,
            val_n: num("val_n")? as usize,
            window_sweep,
            models,
        })
    }
}

/// `artifacts/val_scores.json`: per-model scores on the shared
/// patient-held-out validation split.
#[derive(Debug, Clone)]
pub struct ValScores {
    pub labels: Vec<u8>,
    pub model_ids: Vec<String>,
    pub scores: Vec<Vec<f64>>,
}

impl ValScores {
    pub fn from_json_text(text: &str) -> Result<Self> {
        let v = Value::parse(text)?;
        Ok(ValScores {
            labels: v
                .req("labels")?
                .as_f64_vec()?
                .into_iter()
                .map(|l| l as u8)
                .collect(),
            model_ids: v
                .req("model_ids")?
                .as_arr()
                .ok_or_else(|| Error::json("model_ids"))?
                .iter()
                .map(|s| {
                    s.as_str().map(String::from).ok_or_else(|| Error::json("model_id not str"))
                })
                .collect::<Result<Vec<_>>>()?,
            scores: v
                .req("scores")?
                .as_arr()
                .ok_or_else(|| Error::json("scores"))?
                .iter()
                .map(|row| row.as_f64_vec())
                .collect::<Result<Vec<_>>>()?,
        })
    }
}

/// The loaded zoo: manifest + scores + artifact root.
#[derive(Debug, Clone)]
pub struct Zoo {
    pub root: PathBuf,
    pub manifest: Manifest,
    pub val: ValScores,
}

impl Zoo {
    /// Load from an artifact directory (usually `artifacts/`).
    pub fn load(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let manifest =
            Manifest::from_json_text(&std::fs::read_to_string(root.join("zoo_manifest.json"))?)?;
        let val =
            ValScores::from_json_text(&std::fs::read_to_string(root.join("val_scores.json"))?)?;
        let zoo = Zoo { root, manifest, val };
        zoo.validate()?;
        Ok(zoo)
    }

    fn validate(&self) -> Result<()> {
        let n = self.manifest.models.len();
        if n != self.manifest.n_models {
            return Err(Error::artifact("manifest n_models mismatch"));
        }
        if self.val.scores.len() != n {
            return Err(Error::artifact("val_scores rows != n_models"));
        }
        for (i, (m, s)) in self.manifest.models.iter().zip(&self.val.scores).enumerate() {
            if m.index != i {
                return Err(Error::artifact(format!("model {} index out of order", m.id)));
            }
            if s.len() != self.val.labels.len() {
                return Err(Error::artifact(format!("score row {} length mismatch", m.id)));
            }
            if m.trained && m.artifacts.is_empty() {
                return Err(Error::artifact(format!("trained model {} has no artifacts", m.id)));
            }
        }
        Ok(())
    }

    pub fn n(&self) -> usize {
        self.manifest.models.len()
    }

    pub fn model(&self, index: usize) -> &ModelProfile {
        &self.manifest.models[index]
    }

    pub fn by_id(&self, id: &str) -> Option<&ModelProfile> {
        self.manifest.models.iter().find(|m| m.id == id)
    }

    /// Indices of models with compiled artifacts (deployable subset).
    pub fn servable_indices(&self) -> Vec<usize> {
        self.manifest
            .models
            .iter()
            .filter(|m| m.servable())
            .map(|m| m.index)
            .collect()
    }

    /// Absolute path of a model's HLO artifact for a batch size.
    pub fn artifact_path(&self, index: usize, batch: usize) -> Result<PathBuf> {
        let m = self.model(index);
        let rel = m.artifact_for_batch(batch).ok_or_else(|| {
            Error::artifact(format!("model {} has no batch-{} artifact", m.id, batch))
        })?;
        Ok(self.root.join(rel))
    }

    /// HLO program bytes for a model's batch variant — the payload a
    /// [`crate::registry::ArtifactBundle`] is built around. Reads the
    /// compiled file when it exists on disk; when the manifest declares
    /// a variant but the file is absent (toy zoos, artifact-less router
    /// peers), a deterministic sim-grade placeholder program is
    /// synthesised from the profile so content-addressed identities
    /// stay stable across processes without `make artifacts`.
    pub fn artifact_bytes(&self, index: usize, batch: usize) -> Result<Vec<u8>> {
        let path = self.artifact_path(index, batch)?;
        match std::fs::read(&path) {
            Ok(bytes) => Ok(bytes),
            Err(_) => {
                let m = self.model(index);
                Ok(format!(
                    "HloModule sim_{id}_b{batch}, placeholder=true\n\
                     // sim-grade stand-in for {rel}: deterministic identity,\n\
                     // not an executable program\n\
                     // profile: macs={macs} params={params} input_len={len} lead={lead}\n",
                    id = m.id,
                    rel = m.artifact_for_batch(batch).unwrap_or("?"),
                    macs = m.macs,
                    params = m.params,
                    len = m.input_len,
                    lead = m.lead,
                )
                .into_bytes())
            }
        }
    }

    /// The profile matrix V (n × m) as feature rows for surrogates.
    pub fn profile_matrix(&self) -> Vec<Vec<f64>> {
        self.manifest.models.iter().map(|m| m.feature_row()).collect()
    }
}

/// A model ensemble: the binary selector b ∈ {0,1}^n (paper §3.3.1),
/// stored as the set of selected indices plus the zoo size.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Selector {
    n: usize,
    selected: Vec<usize>, // sorted, unique
}

impl Selector {
    pub fn empty(n: usize) -> Self {
        Selector { n, selected: Vec::new() }
    }

    pub fn from_indices(n: usize, idx: impl IntoIterator<Item = usize>) -> Self {
        let mut selected: Vec<usize> = idx.into_iter().filter(|&i| i < n).collect();
        selected.sort_unstable();
        selected.dedup();
        Selector { n, selected }
    }

    pub fn from_bits(bits: &[bool]) -> Self {
        Selector {
            n: bits.len(),
            selected: bits
                .iter()
                .enumerate()
                .filter(|(_, &b)| b)
                .map(|(i, _)| i)
                .collect(),
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn indices(&self) -> &[usize] {
        &self.selected
    }

    pub fn len(&self) -> usize {
        self.selected.len()
    }

    pub fn is_empty(&self) -> bool {
        self.selected.is_empty()
    }

    pub fn contains(&self, i: usize) -> bool {
        self.selected.binary_search(&i).is_ok()
    }

    pub fn insert(&mut self, i: usize) {
        assert!(i < self.n);
        if let Err(pos) = self.selected.binary_search(&i) {
            self.selected.insert(pos, i);
        }
    }

    pub fn remove(&mut self, i: usize) {
        if let Ok(pos) = self.selected.binary_search(&i) {
            self.selected.remove(pos);
        }
    }

    pub fn flip(&mut self, i: usize) {
        if self.contains(i) {
            self.remove(i)
        } else {
            self.insert(i)
        }
    }

    pub fn to_bits(&self) -> Vec<bool> {
        let mut bits = vec![false; self.n];
        for &i in &self.selected {
            bits[i] = true;
        }
        bits
    }

    /// Binary feature vector (f64) — surrogate model input.
    pub fn to_f64(&self) -> Vec<f64> {
        self.to_bits().into_iter().map(|b| b as u8 as f64).collect()
    }

    /// Manhattan (Hamming) distance between two selectors.
    pub fn hamming(&self, other: &Selector) -> usize {
        assert_eq!(self.n, other.n);
        let a = self.to_bits();
        let b = other.to_bits();
        a.iter().zip(&b).filter(|(x, y)| x != y).count()
    }

    /// Paper Eq. 4 recombination: b = concat(b1[..i], b2[i..]).
    pub fn recombine(&self, other: &Selector, point: usize) -> Selector {
        assert_eq!(self.n, other.n);
        let a = self.to_bits();
        let b = other.to_bits();
        let bits: Vec<bool> = (0..self.n)
            .map(|j| if j < point { a[j] } else { b[j] })
            .collect();
        Selector::from_bits(&bits)
    }
}

/// Test/bench helpers: synthetic in-memory zoos (no artifact files).
#[doc(hidden)]
pub mod testkit {
    use super::*;

    /// A zoo of `n` profile-only models with a controllable accuracy
    /// landscape: model i's scores mix an oracle margin with noise so
    /// val AUC rises with index; MACs also rise with index so accuracy
    /// and latency trade off, like the real zoo.
    pub fn toy_zoo(n: usize, n_val: usize, seed: u64) -> Zoo {
        toy_zoo_with(n, n_val, seed, 100, &[1])
    }

    /// [`toy_zoo`] with explicit clip length and compiled batch sizes —
    /// the serving/engine tests and benches need multi-batch zoos with
    /// realistically sized windows.
    pub fn toy_zoo_with(
        n: usize,
        n_val: usize,
        seed: u64,
        clip_len: usize,
        batch_sizes: &[usize],
    ) -> Zoo {
        let mut rng = crate::rng::Rng::seed_from_u64(seed);
        let labels: Vec<u8> = (0..n_val).map(|_| rng.bool(0.5) as u8).collect();
        let mut models = Vec::with_capacity(n);
        let mut scores = Vec::with_capacity(n);
        for i in 0..n {
            let strength = 0.4 + 1.6 * (i as f64 / n.max(1) as f64);
            let row: Vec<f64> = labels
                .iter()
                .map(|&l| {
                    let z = strength * (2.0 * l as f64 - 1.0) + rng.normal();
                    1.0 / (1.0 + (-z).exp())
                })
                .collect();
            let auc = crate::metrics::roc_auc(&labels, &row);
            models.push(ModelProfile {
                index: i,
                id: format!("m{i}"),
                lead: i % 3,
                width: 8 << (i % 4),
                blocks: 2 << (i % 3),
                depth: 6,
                cardinality: 4,
                macs: 2_000_000 * (i as u64 + 1),
                params: 10_000 * (i as u64 + 1),
                memory_bytes: 40_000,
                input_modality: format!("ECG-lead-{}", i % 3),
                input_len: clip_len,
                val_auc: auc,
                trained: true,
                artifacts: batch_sizes
                    .iter()
                    .map(|&b| (b.to_string(), format!("models/m{i}_b{b}.hlo.txt")))
                    .collect(),
            });
            scores.push(row);
        }
        Zoo {
            root: std::path::PathBuf::from("/nonexistent-toy-zoo"),
            manifest: Manifest {
                version: 1,
                clip_len,
                fs: 250,
                batch_sizes: batch_sizes.to_vec(),
                n_models: n,
                calibration: Calibration {
                    fs: 250,
                    lead_amp: vec![0.8, 1.0, 0.6],
                    lead_noise: vec![1.2, 0.8, 1.5],
                    hr_base: 95.0,
                    hr_sev_gain: 75.0,
                    hrv_base: 0.012,
                    hrv_stable_gain: 0.09,
                    st_depression: -0.18,
                    noise_base: 0.035,
                    noise_sev_gain: 0.09,
                },
                val_n: n_val,
                window_sweep: None,
                models,
            },
            val: ValScores {
                labels,
                model_ids: (0..n).map(|i| format!("m{i}")).collect(),
                scores,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sel(n: usize, idx: &[usize]) -> Selector {
        Selector::from_indices(n, idx.iter().copied())
    }

    #[test]
    fn selector_roundtrip_bits() {
        let s = sel(6, &[0, 3, 5]);
        assert_eq!(Selector::from_bits(&s.to_bits()), s);
        assert_eq!(s.len(), 3);
        assert!(s.contains(3) && !s.contains(2));
    }

    #[test]
    fn selector_flip_insert_remove() {
        let mut s = sel(4, &[1]);
        s.flip(1);
        assert!(s.is_empty());
        s.flip(2);
        s.insert(2); // idempotent
        assert_eq!(s.indices(), &[2]);
        s.remove(3); // absent: no-op
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn selector_hamming() {
        assert_eq!(sel(5, &[0, 1]).hamming(&sel(5, &[1, 2])), 2);
        assert_eq!(sel(5, &[]).hamming(&sel(5, &[0, 1, 2, 3, 4])), 5);
    }

    #[test]
    fn selector_recombination_point_semantics() {
        let a = sel(4, &[0, 1]);
        let b = sel(4, &[2, 3]);
        assert_eq!(a.recombine(&b, 0), b);
        assert_eq!(a.recombine(&b, 4), a);
        assert_eq!(a.recombine(&b, 2), sel(4, &[0, 1, 2, 3]));
    }

    #[test]
    fn selector_dedup_and_bound_filter() {
        let s = Selector::from_indices(3, [2, 2, 9, 0]);
        assert_eq!(s.indices(), &[0, 2]);
    }
}
