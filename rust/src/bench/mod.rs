//! Micro-benchmark harness (criterion is unavailable offline): warm-up,
//! adaptive iteration count targeting a fixed measurement time, and
//! median/mean/p95-of-batches reporting. Used by the `cargo bench`
//! binaries under `rust/benches/`.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} iters  mean {:>12}  median {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_dur(self.mean),
            fmt_dur(self.median),
            fmt_dur(self.p95)
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Benchmark runner with a total time budget per benchmark.
pub struct Bencher {
    /// Target measurement time per benchmark.
    pub measure_for: Duration,
    /// Number of timed batches (percentiles come from these).
    pub batches: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { measure_for: Duration::from_secs(2), batches: 20, results: Vec::new() }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { measure_for: Duration::from_millis(300), batches: 8, results: Vec::new() }
    }

    /// Time `f` adaptively; `f` should perform ONE unit of work and
    /// return a value (black-boxed to keep the optimizer honest).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // warm-up + calibration: how many iters fit one batch?
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed() < self.measure_for / 10 || calib_iters < 3 {
            black_box(f());
            calib_iters += 1;
            if calib_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = t0.elapsed().as_secs_f64() / calib_iters as f64;
        let batch_time = self.measure_for.as_secs_f64() / self.batches as f64;
        let iters_per_batch = ((batch_time / per_iter.max(1e-12)) as u64).clamp(1, 10_000_000);

        let mut batch_means: Vec<f64> = Vec::with_capacity(self.batches);
        for _ in 0..self.batches {
            let t = Instant::now();
            for _ in 0..iters_per_batch {
                black_box(f());
            }
            batch_means.push(t.elapsed().as_secs_f64() / iters_per_batch as f64);
        }
        batch_means.sort_by(f64::total_cmp);
        let mean = batch_means.iter().sum::<f64>() / batch_means.len() as f64;
        let median = batch_means[batch_means.len() / 2];
        let p95 = batch_means[(batch_means.len() as f64 * 0.95) as usize - 1];
        let result = BenchResult {
            name: name.to_string(),
            iters: iters_per_batch * self.batches as u64,
            mean: Duration::from_secs_f64(mean),
            median: Duration::from_secs_f64(median),
            p95: Duration::from_secs_f64(p95),
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Optimizer barrier (stable-rust version of `std::hint::black_box` —
/// re-exported so benches don't depend on feature availability).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something_sane() {
        let mut b = Bencher { measure_for: Duration::from_millis(50), batches: 4, results: vec![] };
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..100 {
                // black_box the input so release mode can't const-fold
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert!(r.mean > Duration::ZERO);
        assert!(r.median <= r.p95);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(5)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}
