//! CART regression tree — variance-reduction splits, the building block
//! of the random-forest surrogates (and of the vitals-side RF
//! classifier, which regresses on {0,1} labels).

use crate::rng::Rng;

/// Flat array-of-nodes tree; `left == usize::MAX` marks a leaf.
#[derive(Debug, Clone)]
pub struct Tree {
    nodes: Vec<Node>,
}

#[derive(Debug, Clone)]
struct Node {
    feature: usize,
    threshold: f64,
    left: usize,
    right: usize,
    value: f64, // leaf prediction (mean of targets)
}

const LEAF: usize = usize::MAX;

/// Tree growth hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct TreeConfig {
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// Number of random features tried per split (None = all).
    pub mtry: Option<usize>,
}

impl Default for TreeConfig {
    fn default() -> Self {
        TreeConfig { max_depth: 16, min_samples_leaf: 2, mtry: None }
    }
}

impl Tree {
    /// Fit on row-major features `x[i]` with targets `y[i]`, restricted to
    /// the `rows` index subset (the caller's bootstrap sample).
    pub fn fit(
        x: &[Vec<f64>],
        y: &[f64],
        rows: &[usize],
        cfg: &TreeConfig,
        rng: &mut Rng,
    ) -> Self {
        assert_eq!(x.len(), y.len());
        assert!(!rows.is_empty(), "cannot fit a tree on zero rows");
        let mut tree = Tree { nodes: Vec::new() };
        let mut rows = rows.to_vec();
        tree.grow(x, y, &mut rows, 0, cfg, rng);
        tree
    }

    fn grow(
        &mut self,
        x: &[Vec<f64>],
        y: &[f64],
        rows: &mut [usize],
        depth: usize,
        cfg: &TreeConfig,
        rng: &mut Rng,
    ) -> usize {
        let mean = rows.iter().map(|&r| y[r]).sum::<f64>() / rows.len() as f64;
        let node_id = self.nodes.len();
        self.nodes.push(Node { feature: 0, threshold: 0.0, left: LEAF, right: LEAF, value: mean });

        if depth >= cfg.max_depth || rows.len() < 2 * cfg.min_samples_leaf {
            return node_id;
        }
        let Some((feat, thr)) = best_split(x, y, rows, cfg, rng) else {
            return node_id;
        };
        // partition in place
        let mut split = 0;
        for i in 0..rows.len() {
            if x[rows[i]][feat] <= thr {
                rows.swap(i, split);
                split += 1;
            }
        }
        if split == 0 || split == rows.len() {
            return node_id;
        }
        let (l_rows, r_rows) = rows.split_at_mut(split);
        let left = self.grow(x, y, l_rows, depth + 1, cfg, rng);
        let right = self.grow(x, y, r_rows, depth + 1, cfg, rng);
        self.nodes[node_id].feature = feat;
        self.nodes[node_id].threshold = thr;
        self.nodes[node_id].left = left;
        self.nodes[node_id].right = right;
        node_id
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        let mut i = 0;
        loop {
            let n = &self.nodes[i];
            if n.left == LEAF {
                return n.value;
            }
            i = if x[n.feature] <= n.threshold { n.left } else { n.right };
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }
}

/// Exhaustive variance-reduction split over a random feature subset.
/// For binary features (the selector bits) the only candidate threshold
/// is 0.5; continuous profile features get midpoint candidates.
fn best_split(
    x: &[Vec<f64>],
    y: &[f64],
    rows: &[usize],
    cfg: &TreeConfig,
    rng: &mut Rng,
) -> Option<(usize, f64)> {
    let n_features = x[0].len();
    let mtry = cfg.mtry.unwrap_or(n_features).min(n_features).max(1);
    // sample features without replacement (partial Fisher–Yates)
    let mut feats: Vec<usize> = (0..n_features).collect();
    for i in 0..mtry {
        let j = rng.range(i, n_features);
        feats.swap(i, j);
    }

    let total: f64 = rows.iter().map(|&r| y[r]).sum();
    let total_sq: f64 = rows.iter().map(|&r| y[r] * y[r]).sum();
    let n = rows.len() as f64;
    let base_sse = total_sq - total * total / n;

    let mut best: Option<(usize, f64, f64)> = None; // (feat, thr, gain)
    for &feat in &feats[..mtry] {
        // candidate thresholds: midpoints of sorted unique values
        let mut vals: Vec<f64> = rows.iter().map(|&r| x[r][feat]).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        if vals.len() < 2 {
            continue;
        }
        // incremental left/right statistics over the sorted rows
        let mut sorted: Vec<usize> = rows.to_vec();
        sorted.sort_by(|&a, &b| x[a][feat].partial_cmp(&x[b][feat]).unwrap());
        let mut lsum = 0.0;
        let mut lsq = 0.0;
        let mut lcount = 0usize;
        let mut vi = 0;
        for w in 0..sorted.len() - 1 {
            let r = sorted[w];
            lsum += y[r];
            lsq += y[r] * y[r];
            lcount += 1;
            // split only between distinct feature values
            if x[sorted[w]][feat] == x[sorted[w + 1]][feat] {
                continue;
            }
            while vi + 1 < vals.len() && vals[vi + 1] <= x[sorted[w]][feat] {
                vi += 1;
            }
            let thr = 0.5 * (x[sorted[w]][feat] + x[sorted[w + 1]][feat]);
            let rcount = rows.len() - lcount;
            if lcount < cfg.min_samples_leaf || rcount < cfg.min_samples_leaf {
                continue;
            }
            let rsum = total - lsum;
            let rsq = total_sq - lsq;
            let sse = (lsq - lsum * lsum / lcount as f64)
                + (rsq - rsum * rsum / rcount as f64);
            let gain = base_sse - sse;
            if gain > best.map(|(_, _, g)| g).unwrap_or(1e-12) {
                best = Some((feat, thr, gain));
            }
        }
    }
    best.map(|(f, t, _)| (f, t))
}

#[cfg(test)]
mod tests {
    use super::*;
    fn rng() -> Rng {
        Rng::seed_from_u64(0)
    }

    #[test]
    fn fits_a_step_function_exactly() {
        let x: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..20).map(|i| if i < 10 { 1.0 } else { 5.0 }).collect();
        let rows: Vec<usize> = (0..20).collect();
        let t = Tree::fit(&x, &y, &rows, &TreeConfig { min_samples_leaf: 1, ..Default::default() }, &mut rng());
        assert_eq!(t.predict(&[3.0]), 1.0);
        assert_eq!(t.predict(&[15.0]), 5.0);
    }

    #[test]
    fn constant_target_is_single_leaf() {
        let x: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64]).collect();
        let y = vec![2.5; 8];
        let rows: Vec<usize> = (0..8).collect();
        let t = Tree::fit(&x, &y, &rows, &TreeConfig::default(), &mut rng());
        assert_eq!(t.n_nodes(), 1);
        assert_eq!(t.predict(&[100.0]), 2.5);
    }

    #[test]
    fn binary_features_split_on_half() {
        // y = 3*b0 + b1 over all 4 binary combos, replicated
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..5 {
            for b0 in 0..2 {
                for b1 in 0..2 {
                    x.push(vec![b0 as f64, b1 as f64]);
                    y.push(3.0 * b0 as f64 + b1 as f64);
                }
            }
        }
        let rows: Vec<usize> = (0..x.len()).collect();
        let t = Tree::fit(&x, &y, &rows, &TreeConfig { min_samples_leaf: 1, ..Default::default() }, &mut rng());
        for b0 in 0..2 {
            for b1 in 0..2 {
                let want = 3.0 * b0 as f64 + b1 as f64;
                assert!((t.predict(&[b0 as f64, b1 as f64]) - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn respects_min_samples_leaf() {
        let x: Vec<Vec<f64>> = (0..6).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let rows: Vec<usize> = (0..6).collect();
        let t = Tree::fit(
            &x,
            &y,
            &rows,
            &TreeConfig { min_samples_leaf: 3, ..Default::default() },
            &mut rng(),
        );
        // only the 3/3 split is admissible
        assert!(t.n_nodes() <= 3);
    }
}
