//! Surrogate probability models for the SMBO composer (paper §3.3.2b).
//!
//! HOLMES fits two random forests [6] on the profiled set B — one
//! approximating the accuracy profiler `f̂_a`, one the latency profiler
//! `f̂_l` — so the genetic explorer can rank candidate ensembles without
//! spending profiler-call budget. Implemented from scratch: bootstrap-
//! bagged CART variance-reduction trees with feature subsampling.

mod tree;

pub use tree::{Tree, TreeConfig};

use crate::rng::Rng;

/// Common interface the composer uses for `f̂_a` / `f̂_l`.
pub trait Surrogate {
    /// Fit on row-major features and targets (replaces prior fit).
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]);
    /// Point prediction for one feature vector.
    fn predict(&self, x: &[f64]) -> f64;
    /// True once `fit` has been called with ≥1 sample.
    fn is_fitted(&self) -> bool;
}

/// Random-forest regressor (the paper's surrogate choice).
#[derive(Debug, Clone)]
pub struct RandomForest {
    pub config: ForestConfig,
    trees: Vec<Tree>,
}

#[derive(Debug, Clone, Copy)]
pub struct ForestConfig {
    pub n_trees: usize,
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// Features per split; None → ceil(sqrt(n_features)).
    pub mtry: Option<usize>,
    pub seed: u64,
}

impl Default for ForestConfig {
    fn default() -> Self {
        ForestConfig { n_trees: 60, max_depth: 14, min_samples_leaf: 2, mtry: None, seed: 17 }
    }
}

impl RandomForest {
    pub fn new(config: ForestConfig) -> Self {
        RandomForest { config, trees: Vec::new() }
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

impl Surrogate for RandomForest {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert_eq!(x.len(), y.len());
        self.trees.clear();
        if x.is_empty() {
            return;
        }
        let n = x.len();
        let n_features = x[0].len();
        let mtry = self
            .config
            .mtry
            .unwrap_or_else(|| (n_features as f64).sqrt().ceil() as usize);
        let tree_cfg = TreeConfig {
            max_depth: self.config.max_depth,
            min_samples_leaf: self.config.min_samples_leaf,
            mtry: Some(mtry),
        };
        let mut rng = Rng::seed_from_u64(self.config.seed);
        for _ in 0..self.config.n_trees {
            // bootstrap sample (with replacement)
            let rows: Vec<usize> = (0..n).map(|_| rng.range(0, n)).collect();
            self.trees.push(Tree::fit(x, y, &rows, &tree_cfg, &mut rng));
        }
    }

    fn predict(&self, x: &[f64]) -> f64 {
        if self.trees.is_empty() {
            return 0.0;
        }
        self.trees.iter().map(|t| t.predict(x)).sum::<f64>() / self.trees.len() as f64
    }

    fn is_fitted(&self) -> bool {
        !self.trees.is_empty()
    }
}

/// Ridge linear regressor — a cheap alternative surrogate used in the
/// ablation benches (DESIGN.md calls out surrogate choice as a design
/// decision worth ablating).
#[derive(Debug, Clone)]
pub struct RidgeRegression {
    pub l2: f64,
    weights: Vec<f64>, // last entry = intercept
}

impl RidgeRegression {
    pub fn new(l2: f64) -> Self {
        RidgeRegression { l2, weights: Vec::new() }
    }
}

impl Surrogate for RidgeRegression {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert_eq!(x.len(), y.len());
        self.weights.clear();
        if x.is_empty() {
            return;
        }
        let d = x[0].len() + 1; // + intercept
        // normal equations (XᵀX + λI) w = Xᵀy, Gaussian elimination
        let mut a = vec![vec![0.0f64; d]; d];
        let mut b = vec![0.0f64; d];
        for (row, &target) in x.iter().zip(y) {
            let aug: Vec<f64> = row.iter().copied().chain(std::iter::once(1.0)).collect();
            for i in 0..d {
                b[i] += aug[i] * target;
                for j in 0..d {
                    a[i][j] += aug[i] * aug[j];
                }
            }
        }
        for (i, row) in a.iter_mut().enumerate().take(d - 1) {
            row[i] += self.l2; // don't regularise the intercept
        }
        self.weights = solve(a, b);
    }

    fn predict(&self, x: &[f64]) -> f64 {
        if self.weights.is_empty() {
            return 0.0;
        }
        x.iter()
            .zip(&self.weights)
            .map(|(xi, wi)| xi * wi)
            .sum::<f64>()
            + self.weights[self.weights.len() - 1]
    }

    fn is_fitted(&self) -> bool {
        !self.weights.is_empty()
    }
}

/// Gaussian elimination with partial pivoting.
fn solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Vec<f64> {
    let n = b.len();
    for col in 0..n {
        // pivot
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let diag = a[col][col];
        if diag.abs() < 1e-12 {
            continue;
        }
        for row in col + 1..n {
            let f = a[row][col] / diag;
            for k in col..n {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for col in row + 1..n {
            acc -= a[row][col] * x[col];
        }
        x[row] = if a[row][row].abs() < 1e-12 { 0.0 } else { acc / a[row][row] };
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::r2;

    fn grid_xy(f: impl Fn(&[f64]) -> f64, n_bits: usize, n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::seed_from_u64(seed);
        let x: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..n_bits).map(|_| rng.range(0, 2) as f64).collect())
            .collect();
        let y: Vec<f64> = x.iter().map(|r| f(r)).collect();
        (x, y)
    }

    #[test]
    fn forest_learns_additive_binary_function() {
        let f = |r: &[f64]| 2.0 * r[0] + r[3] - 0.5 * r[7];
        let (x, y) = grid_xy(f, 10, 300, 1);
        let mut rf = RandomForest::new(ForestConfig::default());
        rf.fit(&x, &y);
        let (xt, yt) = grid_xy(f, 10, 100, 2);
        let pred: Vec<f64> = xt.iter().map(|r| rf.predict(r)).collect();
        assert!(r2(&yt, &pred) > 0.9, "r2 = {}", r2(&yt, &pred));
    }

    #[test]
    fn forest_is_deterministic_given_seed() {
        let (x, y) = grid_xy(|r| r[0] + r[1], 4, 50, 3);
        let mut a = RandomForest::new(ForestConfig::default());
        let mut b = RandomForest::new(ForestConfig::default());
        a.fit(&x, &y);
        b.fit(&x, &y);
        for row in &x {
            assert_eq!(a.predict(row), b.predict(row));
        }
    }

    #[test]
    fn forest_unfitted_predicts_zero() {
        let rf = RandomForest::new(ForestConfig::default());
        assert!(!rf.is_fitted());
        assert_eq!(rf.predict(&[1.0, 2.0]), 0.0);
    }

    #[test]
    fn ridge_recovers_linear_coefficients() {
        let (x, y) = grid_xy(|r| 3.0 * r[0] - 2.0 * r[1] + 1.0, 2, 80, 5);
        let mut lr = RidgeRegression::new(1e-6);
        lr.fit(&x, &y);
        assert!((lr.predict(&[1.0, 0.0]) - 4.0).abs() < 1e-3);
        assert!((lr.predict(&[0.0, 1.0]) + 1.0).abs() < 1e-3);
    }

    #[test]
    fn solve_identity() {
        let a = vec![vec![1.0, 0.0], vec![0.0, 2.0]];
        let x = solve(a, vec![3.0, 8.0]);
        assert!((x[0] - 3.0).abs() < 1e-12 && (x[1] - 4.0).abs() < 1e-12);
    }
}
