//! The paper's §4.2 baselines: Random (RD), Accuracy First (AF), Latency
//! First (LF) greedy builders, and Non-Parametric Optimization (NPO,
//! modified from Snoek et al. [32]).
//!
//! Each greedy baseline iteratively adds one model "till the ensemble
//! model exceeds the latency constraint"; the returned optimum is the
//! best *feasible* profiled point (under the hard δ, infeasible points
//! have −∞ utility), while the trace keeps the exceeding step — that is
//! what Fig. 6 plots above the budget line.

use super::{ProfiledPoint, SearchResult};
use crate::rng::Rng;
use crate::composer::Delta;
use crate::config::SystemConfig;
use crate::profiler::{AccuracyProfiler, LatencyProfiler};
use crate::zoo::{Selector, Zoo};

/// Greedy model-ordering strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Greedy {
    Random,
    AccuracyFirst,
    LatencyFirst,
}

/// Shared driver for RD / AF / LF.
#[allow(clippy::too_many_arguments)]
pub fn greedy_search<A: AccuracyProfiler, L: LatencyProfiler>(
    kind: Greedy,
    zoo: &Zoo,
    acc: &A,
    lat: &L,
    system: &SystemConfig,
    budget: f64,
    servable_only: bool,
    seed: u64,
) -> SearchResult {
    let universe: Vec<usize> = if servable_only {
        zoo.servable_indices()
    } else {
        (0..zoo.n()).collect()
    };
    // per-model single-model latency for the LF ordering
    let order: Vec<usize> = {
        let mut idx = universe.clone();
        match kind {
            Greedy::Random => {
                let mut rng = Rng::seed_from_u64(seed);
                rng.shuffle(&mut idx);
            }
            Greedy::AccuracyFirst => {
                idx.sort_by(|&a, &b| {
                    zoo.model(b)
                        .val_auc
                        .partial_cmp(&zoo.model(a).val_auc)
                        .unwrap()
                });
            }
            Greedy::LatencyFirst => {
                idx.sort_by(|&a, &b| {
                    let la = lat.latency(&Selector::from_indices(zoo.n(), [a]), system);
                    let lb = lat.latency(&Selector::from_indices(zoo.n(), [b]), system);
                    la.partial_cmp(&lb).unwrap()
                });
            }
        }
        idx
    };

    let mut profile_set: Vec<ProfiledPoint> = Vec::new();
    let mut current = Selector::empty(zoo.n());
    let mut calls = 0usize;
    for (step, &i) in order.iter().enumerate() {
        current.insert(i);
        let point = ProfiledPoint {
            accuracy: acc.accuracy(&current),
            latency: lat.latency(&current, system),
            selector: current.clone(),
            iteration: step,
        };
        calls += 1;
        let exceeded = point.latency > budget;
        profile_set.push(point);
        if exceeded {
            break; // paper: stop after exceeding the constraint
        }
    }
    let best = best_feasible(&profile_set, budget);
    SearchResult { best, profile_set, surrogate_r2: Vec::new(), profiler_calls: calls }
}

/// Best point under the hard constraint; if nothing is feasible, the
/// lowest-latency point (degenerate but well-defined).
pub fn best_feasible(points: &[ProfiledPoint], budget: f64) -> ProfiledPoint {
    points
        .iter()
        .max_by(|a, b| {
            a.utility(budget, Delta::HardStep)
                .partial_cmp(&b.utility(budget, Delta::HardStep))
                .unwrap()
        })
        .filter(|p| p.latency <= budget)
        .cloned()
        .unwrap_or_else(|| {
            points
                .iter()
                .min_by(|a, b| a.latency.partial_cmp(&b.latency).unwrap())
                .expect("no profiled points")
                .clone()
        })
}

/// NPO: random-subset hill climbing with the same profiler-call budget
/// as HOLMES. Subset size is bounded by |LF solution| (the paper's
/// bound); each accepted merge grows the current set; every profiled
/// point is recorded and the final answer is the true-utility argmax.
#[allow(clippy::too_many_arguments)]
pub fn npo_search<A: AccuracyProfiler, L: LatencyProfiler>(
    zoo: &Zoo,
    acc: &A,
    lat: &L,
    system: &SystemConfig,
    budget: f64,
    max_profiler_calls: usize,
    seeds: &[Selector],
    servable_only: bool,
    seed: u64,
) -> SearchResult {
    let mut rng = Rng::seed_from_u64(seed);
    let universe: Vec<usize> = if servable_only {
        zoo.servable_indices()
    } else {
        (0..zoo.n()).collect()
    };
    // LF bound on the merge-subset size
    let lf = greedy_search(Greedy::LatencyFirst, zoo, acc, lat, system, budget, servable_only, seed);
    let bound = lf.best.selector.len().max(1);

    let mut profile_set: Vec<ProfiledPoint> = Vec::new();
    let mut calls = 0usize;
    let profile = |b: Selector, it: usize, set: &mut Vec<ProfiledPoint>, calls: &mut usize| {
        let p = ProfiledPoint {
            accuracy: acc.accuracy(&b),
            latency: lat.latency(&b, system),
            selector: b,
            iteration: it,
        };
        *calls += 1;
        set.push(p.clone());
        p
    };

    for s in seeds {
        if !s.is_empty() && calls < max_profiler_calls {
            profile(s.clone(), 0, &mut profile_set, &mut calls);
        }
    }
    let mut current = best_feasible(
        &(if profile_set.is_empty() {
            vec![profile(
                Selector::from_indices(zoo.n(), [universe[0]]),
                0,
                &mut profile_set,
                &mut calls,
            )]
        } else {
            profile_set.clone()
        }),
        budget,
    )
    .selector;

    let mut it = 1;
    while calls < max_profiler_calls {
        // random subset of size 1..=bound
        let k = rng.range(1, bound + 1);
        let mut subset = universe.clone();
        rng.shuffle(&mut subset);
        let candidate = Selector::from_indices(
            zoo.n(),
            current.indices().iter().copied().chain(subset.into_iter().take(k)),
        );
        if candidate == current {
            it += 1;
            continue;
        }
        let p = profile(candidate, it, &mut profile_set, &mut calls);
        let cur_point = profile_set
            .iter()
            .find(|q| q.selector == current)
            .cloned()
            .unwrap_or_else(|| p.clone());
        if p.utility(budget, Delta::HardStep) > cur_point.utility(budget, Delta::HardStep) {
            current = p.selector.clone();
        }
        it += 1;
    }
    let best = best_feasible(&profile_set, budget);
    SearchResult { best, profile_set, surrogate_r2: Vec::new(), profiler_calls: calls }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::{
        AnalyticLatencyProfiler, EnsembleAccuracy, ServiceTimes, ValidationAccuracyProfiler,
    };

    /// Tiny synthetic zoo for baseline unit tests.
    fn toy_zoo(n: usize) -> Zoo {
        use crate::zoo::*;
        let models: Vec<ModelProfile> = (0..n)
            .map(|i| ModelProfile {
                index: i,
                id: format!("m{i}"),
                lead: i % 3,
                width: 8 << (i % 3),
                blocks: 2,
                depth: 6,
                cardinality: 1,
                macs: 1_000_000 * (i as u64 + 1),
                params: 1000,
                memory_bytes: 4000,
                input_modality: "ECG".into(),
                input_len: 100,
                val_auc: 0.8 + 0.01 * i as f64,
                trained: true,
                artifacts: [("1".to_string(), format!("m{i}.hlo.txt"))].into_iter().collect(),
            })
            .collect();
        // alternating labels; model i's scores get noisier as i decreases
        let labels: Vec<u8> = (0..40).map(|s| (s % 2) as u8).collect();
        let scores: Vec<Vec<f64>> = (0..n)
            .map(|i| {
                labels
                    .iter()
                    .enumerate()
                    .map(|(s, &l)| {
                        let sign = if l == 1 { 1.0 } else { -1.0 };
                        0.5 + sign * (0.1 + 0.02 * i as f64) + 0.1 * ((s * 7 + i) % 5) as f64 / 5.0
                            - 0.05
                    })
                    .collect()
            })
            .collect();
        Zoo {
            root: std::path::PathBuf::from("/tmp"),
            manifest: Manifest {
                version: 1,
                clip_len: 100,
                fs: 250,
                batch_sizes: vec![1],
                n_models: n,
                calibration: Calibration {
                    fs: 250,
                    lead_amp: vec![0.8, 1.0, 0.6],
                    lead_noise: vec![1.2, 0.8, 1.5],
                    hr_base: 95.0,
                    hr_sev_gain: 75.0,
                    hrv_base: 0.012,
                    hrv_stable_gain: 0.09,
                    st_depression: -0.18,
                    noise_base: 0.035,
                    noise_sev_gain: 0.09,
                },
                val_n: 40,
                window_sweep: None,
                models,
            },
            val: ValScores {
                labels,
                model_ids: (0..n).map(|i| format!("m{i}")).collect(),
                scores,
            },
        }
    }

    fn profilers(zoo: &Zoo) -> (ValidationAccuracyProfiler, AnalyticLatencyProfiler) {
        let acc = ValidationAccuracyProfiler::from_zoo(zoo);
        let times = ServiceTimes {
            seconds: zoo.manifest.models.iter().map(|m| m.macs as f64 / 5e9).collect(),
        };
        (acc, AnalyticLatencyProfiler::new(times))
    }

    #[test]
    fn greedy_af_orders_by_auc() {
        let zoo = toy_zoo(8);
        let (acc, lat) = profilers(&zoo);
        let sys = SystemConfig { gpus: 2, patients: 8, window_s: 30.0 };
        let r = greedy_search(Greedy::AccuracyFirst, &zoo, &acc, &lat, &sys, 0.5, false, 1);
        // first profiled ensemble must be the single highest-AUC model (index 7)
        assert_eq!(r.profile_set[0].selector.indices(), &[7]);
    }

    #[test]
    fn greedy_lf_starts_with_cheapest() {
        let zoo = toy_zoo(8);
        let (acc, lat) = profilers(&zoo);
        let sys = SystemConfig { gpus: 2, patients: 8, window_s: 30.0 };
        let r = greedy_search(Greedy::LatencyFirst, &zoo, &acc, &lat, &sys, 0.5, false, 1);
        assert_eq!(r.profile_set[0].selector.indices(), &[0]);
    }

    #[test]
    fn greedy_best_is_feasible() {
        let zoo = toy_zoo(8);
        let (acc, lat) = profilers(&zoo);
        let sys = SystemConfig { gpus: 1, patients: 64, window_s: 30.0 };
        for kind in [Greedy::Random, Greedy::AccuracyFirst, Greedy::LatencyFirst] {
            let r = greedy_search(kind, &zoo, &acc, &lat, &sys, 0.003, false, 2);
            assert!(r.best.latency <= 0.003 || r.profile_set.len() == 1);
        }
    }

    #[test]
    fn npo_respects_profiler_budget() {
        let zoo = toy_zoo(10);
        let (acc, lat) = profilers(&zoo);
        let sys = SystemConfig::default();
        let r = npo_search(&zoo, &acc, &lat, &sys, 0.01, 30, &[], false, 3);
        // LF pre-pass is accounted separately; the NPO loop itself ≤ 30
        assert!(r.profiler_calls <= 30, "calls = {}", r.profiler_calls);
        assert!(!r.profile_set.is_empty());
    }

    #[test]
    fn accuracy_identity() {
        // make sure the toy zoo's profiled accuracy behaves (bigger index ⇒ better)
        let zoo = toy_zoo(4);
        let (acc, _) = profilers(&zoo);
        let a0: EnsembleAccuracy = acc.accuracy(&Selector::from_indices(4, [0]));
        let a3: EnsembleAccuracy = acc.accuracy(&Selector::from_indices(4, [3]));
        assert!(a3.roc_auc >= a0.roc_auc);
    }
}
