//! The Ensemble Composer — the paper's Algorithm 1.
//!
//! Sequential Model-Based (Bayesian) Optimisation over the binary
//! ensemble space B = {0,1}ⁿ: random-forest surrogates approximate the
//! accuracy/latency profilers; a genetic explorer ([`explore`]) proposes
//! candidates; the top-K by *approximated* utility (Eq. 2) get truly profiled
//! and appended to the profile set B; after N iterations the true-utility
//! argmax over B is returned.

pub mod baselines;
mod explore;

pub use explore::{explore, mutate, random_selector};

use std::collections::HashSet;

use crate::config::{ComposerConfig, SystemConfig};
use crate::profiler::{AccuracyProfiler, EnsembleAccuracy, LatencyProfiler};
use crate::rng::Rng;
use crate::surrogate::{ForestConfig, RandomForest, Surrogate};
use crate::zoo::{Selector, Zoo};

/// δ of Eq. (2)/(3): hard step (−∞ below 0) or soft linear (λ·x).
#[derive(Debug, Clone, Copy)]
pub enum Delta {
    HardStep,
    Linear(f64),
}

impl Delta {
    pub fn eval(&self, x: f64) -> f64 {
        match self {
            Delta::HardStep => {
                if x < 0.0 {
                    f64::NEG_INFINITY
                } else {
                    0.0
                }
            }
            Delta::Linear(lambda) => lambda * x.min(0.0), // penalise violation only
        }
    }
}

/// Utility L_a(b) = f_a + δ(L − f_l) (Eq. 2).
pub fn utility(acc: f64, lat: f64, budget: f64, delta: Delta) -> f64 {
    acc + delta.eval(budget - lat)
}

/// One truly-profiled point of the profile set B.
#[derive(Debug, Clone)]
pub struct ProfiledPoint {
    pub selector: Selector,
    pub accuracy: EnsembleAccuracy,
    pub latency: f64,
    /// Search iteration at which the point was profiled (0 = warm start).
    pub iteration: usize,
}

impl ProfiledPoint {
    pub fn utility(&self, budget: f64, delta: Delta) -> f64 {
        utility(self.accuracy.roc_auc, self.latency, budget, delta)
    }
}

/// Search output: the optimum plus the full trace (Figs. 6, 8, 11).
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub best: ProfiledPoint,
    /// Every profiled point, in profiling order.
    pub profile_set: Vec<ProfiledPoint>,
    /// Per-iteration surrogate quality on a held-out probe set (Fig. 8):
    /// (iteration, accuracy-surrogate R², latency-surrogate R²).
    pub surrogate_r2: Vec<(usize, f64, f64)>,
    /// Total profiler invocations (accuracy+latency pairs).
    pub profiler_calls: usize,
}

impl SearchResult {
    /// Running best-so-far trajectory (Fig. 6): at each profiled point,
    /// the (accuracy, latency) of the incumbent under the given budget.
    pub fn trajectory(&self, budget: f64, delta: Delta) -> Vec<(f64, f64)> {
        let mut best: Option<&ProfiledPoint> = None;
        let mut out = Vec::with_capacity(self.profile_set.len());
        for p in &self.profile_set {
            let better = match best {
                None => true,
                Some(b) => p.utility(budget, delta) > b.utility(budget, delta),
            };
            if better {
                best = Some(p);
            }
            let b = best.unwrap();
            out.push((b.accuracy.roc_auc, b.latency));
        }
        out
    }
}

/// Feature map for the surrogates: the raw selector bits plus cheap
/// profile-derived aggregates (ensemble size, Σlog-MACs, mean/max member
/// AUC, per-lead counts) — binary-only features starve the forest at the
/// small sample sizes SMBO operates with.
pub struct FeatureMap {
    macs: Vec<f64>,
    auc: Vec<f64>,
    lead: Vec<usize>,
}

impl FeatureMap {
    pub fn from_zoo(zoo: &Zoo) -> Self {
        FeatureMap {
            macs: zoo.manifest.models.iter().map(|m| m.macs as f64).collect(),
            auc: zoo.manifest.models.iter().map(|m| m.val_auc).collect(),
            lead: zoo.manifest.models.iter().map(|m| m.lead).collect(),
        }
    }

    pub fn features(&self, b: &Selector) -> Vec<f64> {
        let mut f = b.to_f64();
        let k = b.len() as f64;
        let sum_macs: f64 = b.indices().iter().map(|&i| self.macs[i]).sum();
        let mean_auc = if b.is_empty() {
            0.5
        } else {
            b.indices().iter().map(|&i| self.auc[i]).sum::<f64>() / k
        };
        let max_auc = b
            .indices()
            .iter()
            .map(|&i| self.auc[i])
            .fold(0.5, f64::max);
        let mut lead_counts = [0.0f64; 3];
        for &i in b.indices() {
            if self.lead[i] < 3 {
                lead_counts[self.lead[i]] += 1.0;
            }
        }
        f.push(k);
        f.push((1.0 + sum_macs).ln());
        f.push(mean_auc);
        f.push(max_auc);
        f.extend_from_slice(&lead_counts);
        f
    }
}

/// The SMBO + genetic-exploration composer (Algorithm 1).
pub struct Composer<'a, A: AccuracyProfiler, L: LatencyProfiler> {
    pub cfg: ComposerConfig,
    pub system: SystemConfig,
    pub delta: Delta,
    zoo: &'a Zoo,
    acc_profiler: &'a A,
    lat_profiler: &'a L,
    features: FeatureMap,
}

impl<'a, A: AccuracyProfiler, L: LatencyProfiler> Composer<'a, A, L> {
    pub fn new(
        zoo: &'a Zoo,
        acc_profiler: &'a A,
        lat_profiler: &'a L,
        cfg: ComposerConfig,
        system: SystemConfig,
    ) -> Self {
        let features = FeatureMap::from_zoo(zoo);
        Composer { cfg, system, delta: Delta::HardStep, zoo, acc_profiler, lat_profiler, features }
    }

    pub fn with_delta(mut self, delta: Delta) -> Self {
        self.delta = delta;
        self
    }

    fn allowed(&self) -> Option<Vec<usize>> {
        if self.cfg.servable_only {
            Some(self.zoo.servable_indices())
        } else {
            None
        }
    }

    fn profile(&self, b: Selector, iteration: usize) -> ProfiledPoint {
        ProfiledPoint {
            accuracy: self.acc_profiler.accuracy(&b),
            latency: self.lat_profiler.latency(&b, &self.system),
            selector: b,
            iteration,
        }
    }

    /// Run Algorithm 1. `seeds` are extra warm-start selectors (the
    /// paper seeds HOLMES and NPO with the RD/AF/LF solutions).
    pub fn search(&self, seeds: &[Selector]) -> SearchResult {
        let n = self.zoo.n();
        let mut rng = Rng::seed_from_u64(self.cfg.seed);
        let allowed = self.allowed();
        let universe: Vec<usize> = allowed.clone().unwrap_or_else(|| (0..n).collect());

        // -- warm start: seeds + random selectors (line 6)
        let mut seen: HashSet<Selector> = HashSet::new();
        let mut profile_set: Vec<ProfiledPoint> = Vec::new();
        let mut profiler_calls = 0usize;
        let add = |b: Selector,
                       it: usize,
                       seen: &mut HashSet<Selector>,
                       set: &mut Vec<ProfiledPoint>,
                       calls: &mut usize| {
            if b.is_empty() || seen.contains(&b) {
                return;
            }
            seen.insert(b.clone());
            set.push(self.profile(b, it));
            *calls += 1;
        };
        for s in seeds {
            add(s.clone(), 0, &mut seen, &mut profile_set, &mut profiler_calls);
        }
        while profile_set.len() < self.cfg.warm_start {
            let b = explore::random_selector(n, &universe, &mut rng);
            add(b, 0, &mut seen, &mut profile_set, &mut profiler_calls);
        }

        // held-out probe set for Fig. 8's surrogate-quality tracking
        let probe: Vec<ProfiledPoint> = {
            let mut probe_rng = Rng::seed_from_u64(self.cfg.seed ^ 0xABCD);
            let mut v = Vec::new();
            let mut guard = 0;
            while v.len() < 32 && guard < 1000 {
                guard += 1;
                let b = explore::random_selector(n, &universe, &mut probe_rng);
                if !seen.contains(&b) {
                    v.push(self.profile(b, usize::MAX));
                }
            }
            v
        };

        let mut surrogate_r2 = Vec::new();
        let mut f_a_hat = RandomForest::new(ForestConfig { seed: self.cfg.seed + 1, ..Default::default() });
        let mut f_l_hat = RandomForest::new(ForestConfig { seed: self.cfg.seed + 2, ..Default::default() });

        // -- SMBO loop (lines 8–22)
        for it in 1..=self.cfg.iterations {
            // fit surrogates on the profiled set (line 13)
            let x: Vec<Vec<f64>> =
                profile_set.iter().map(|p| self.features.features(&p.selector)).collect();
            let ya: Vec<f64> = profile_set.iter().map(|p| p.accuracy.roc_auc).collect();
            let yl: Vec<f64> = profile_set.iter().map(|p| p.latency).collect();
            f_a_hat.fit(&x, &ya);
            f_l_hat.fit(&x, &yl);

            // surrogate quality on the held-out probe set (Fig. 8)
            let pa: Vec<f64> =
                probe.iter().map(|p| f_a_hat.predict(&self.features.features(&p.selector))).collect();
            let pl: Vec<f64> =
                probe.iter().map(|p| f_l_hat.predict(&self.features.features(&p.selector))).collect();
            let ta: Vec<f64> = probe.iter().map(|p| p.accuracy.roc_auc).collect();
            let tl: Vec<f64> = probe.iter().map(|p| p.latency).collect();
            surrogate_r2.push((it, crate::metrics::r2(&ta, &pa), crate::metrics::r2(&tl, &pl)));

            // genetic exploration (line 15, Algorithm 2)
            let b_current: Vec<Selector> =
                profile_set.iter().map(|p| p.selector.clone()).collect();
            let candidates = explore::explore(
                &b_current,
                n,
                self.cfg.explore_samples,
                self.cfg.mutation_degree,
                self.cfg.p_genetic,
                self.cfg.q_mutation,
                allowed.as_deref(),
                &mut rng,
            );
            if candidates.is_empty() {
                break; // space exhausted
            }

            // approximate utility L̂_a over B' (line 17) — the soft-λ form
            // of Algorithm 1 so ranking stays informative out of budget
            let mut scored: Vec<(f64, Selector)> = candidates
                .into_iter()
                .map(|b| {
                    let f = self.features.features(&b);
                    let u = f_a_hat.predict(&f)
                        + self.cfg.lambda
                            * (self.cfg.latency_budget - f_l_hat.predict(&f)).min(0.0);
                    (u, b)
                })
                .collect();
            // top-K by approximated utility (line 19, argsort_K) —
            // total_cmp so a NaN surrogate prediction ranks last
            // instead of panicking mid-search
            scored.sort_by(|a, b| b.0.total_cmp(&a.0));
            for (_, b) in scored.into_iter().take(self.cfg.top_k) {
                add(b, it, &mut seen, &mut profile_set, &mut profiler_calls);
            }
        }

        // -- argmax of the true utility over B (line 24)
        let best = profile_set
            .iter()
            .max_by(|a, b| {
                a.utility(self.cfg.latency_budget, self.delta)
                    .total_cmp(&b.utility(self.cfg.latency_budget, self.delta))
            })
            .expect("profile set cannot be empty")
            .clone();
        SearchResult { best, profile_set, surrogate_r2, profiler_calls }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_hard_step() {
        assert_eq!(Delta::HardStep.eval(0.1), 0.0);
        assert_eq!(Delta::HardStep.eval(0.0), 0.0);
        assert_eq!(Delta::HardStep.eval(-0.1), f64::NEG_INFINITY);
    }

    #[test]
    fn delta_linear_penalises_violation_only() {
        let d = Delta::Linear(2.0);
        assert_eq!(d.eval(0.5), 0.0);
        assert_eq!(d.eval(-0.5), -1.0);
    }

    #[test]
    fn utility_respects_budget() {
        let u_ok = utility(0.9, 0.15, 0.2, Delta::HardStep);
        let u_bad = utility(0.99, 0.25, 0.2, Delta::HardStep);
        assert_eq!(u_ok, 0.9);
        assert_eq!(u_bad, f64::NEG_INFINITY);
    }
}
