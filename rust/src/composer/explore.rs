//! Algorithm 2: candidate exploration with genetic operators.
//!
//! With probability `1 − p`: a uniformly random selector. Otherwise a
//! genetic step: with probability `q` an S-degree **mutation** of a
//! random member of B (≤ S bit flips ⇒ Manhattan distance ≤ S), else a
//! single-point **recombination** of two members (Eq. 4). Duplicates —
//! against both B and the growing B′ — are rejected, matching the
//! paper's "not add duplicates" guard.

use std::collections::HashSet;

use crate::rng::Rng;
use crate::zoo::Selector;

/// Generate up to `m` novel candidates. `allowed` restricts the index
/// universe (e.g. servable-only search); `None` = all of 0..n.
#[allow(clippy::too_many_arguments)]
pub fn explore(
    b_set: &[Selector],
    n: usize,
    m: usize,
    mutation_degree: usize,
    p_genetic: f64,
    q_mutation: f64,
    allowed: Option<&[usize]>,
    rng: &mut Rng,
) -> Vec<Selector> {
    let universe: Vec<usize> = match allowed {
        Some(a) => a.to_vec(),
        None => (0..n).collect(),
    };
    assert!(!universe.is_empty());
    let seen: HashSet<&Selector> = b_set.iter().collect();
    let mut out: Vec<Selector> = Vec::with_capacity(m);
    let mut out_seen: HashSet<Selector> = HashSet::new();
    // Bounded attempts: the binary space may be nearly exhausted.
    let max_attempts = 50 * m + 200;
    let mut attempts = 0;
    while out.len() < m && attempts < max_attempts {
        attempts += 1;
        let cand = if b_set.is_empty() || rng.f64() > p_genetic {
            random_selector(n, &universe, rng)
        } else if rng.f64() <= q_mutation {
            let b3 = &b_set[rng.range(0, b_set.len())];
            mutate(b3, mutation_degree, &universe, rng)
        } else {
            let b1 = &b_set[rng.range(0, b_set.len())];
            let b2 = &b_set[rng.range(0, b_set.len())];
            let point = rng.range(0, n + 1);
            restrict(&b1.recombine(b2, point), &universe)
        };
        if seen.contains(&cand) || out_seen.contains(&cand) {
            continue;
        }
        out_seen.insert(cand.clone());
        out.push(cand);
    }
    out
}

/// Uniformly random selector over the allowed universe: each allowed bit
/// independently with probability that favours small/medium ensembles
/// (expected size ~uniform in [1, |universe|/4], mirroring realistic
/// ensemble sizes rather than n/2-sized monsters).
pub fn random_selector(n: usize, universe: &[usize], rng: &mut Rng) -> Selector {
    let target = rng.range(1, (universe.len() / 4).max(2) + 1);
    let p = target as f64 / universe.len() as f64;
    let mut idx = Vec::new();
    for &i in universe {
        if rng.f64() < p {
            idx.push(i);
        }
    }
    if idx.is_empty() {
        idx.push(universe[rng.range(0, universe.len())]);
    }
    Selector::from_indices(n, idx)
}

/// Mutation(b₃, S): flip S random (allowed) positions ⇒ Manhattan
/// distance ≤ S from b₃ (repeat flips can cancel, hence ≤).
pub fn mutate(b3: &Selector, degree: usize, universe: &[usize], rng: &mut Rng) -> Selector {
    let mut out = restrict(b3, universe);
    for _ in 0..degree.max(1) {
        let i = universe[rng.range(0, universe.len())];
        out.flip(i);
    }
    out
}

/// Drop any indices outside the allowed universe.
fn restrict(b: &Selector, universe: &[usize]) -> Selector {
    let allowed: HashSet<usize> = universe.iter().copied().collect();
    Selector::from_indices(b.n(), b.indices().iter().copied().filter(|i| allowed.contains(i)))
}

#[cfg(test)]
mod tests {
    use super::*;
    fn rng() -> Rng {
        Rng::seed_from_u64(7)
    }

    #[test]
    fn explore_returns_m_unique_novel_candidates() {
        let n = 30;
        let b: Vec<Selector> = (0..5)
            .map(|i| Selector::from_indices(n, [i, i + 1, i + 2]))
            .collect();
        let out = explore(&b, n, 40, 3, 0.8, 0.5, None, &mut rng());
        assert_eq!(out.len(), 40);
        let set: HashSet<_> = out.iter().collect();
        assert_eq!(set.len(), 40, "duplicates inside B'");
        for c in &out {
            assert!(!b.contains(c), "candidate already in B");
        }
    }

    #[test]
    fn mutation_within_manhattan_radius() {
        let n = 20;
        let universe: Vec<usize> = (0..n).collect();
        let b3 = Selector::from_indices(n, [1, 5, 9]);
        for s in [1usize, 3, 5] {
            for _ in 0..50 {
                let m = mutate(&b3, s, &universe, &mut rng());
                assert!(m.hamming(&b3) <= s, "distance {} > {}", m.hamming(&b3), s);
            }
        }
    }

    #[test]
    fn explore_respects_allowed_universe() {
        let n = 40;
        let allowed: Vec<usize> = (0..10).collect();
        let b = vec![Selector::from_indices(n, [0, 3])];
        let out = explore(&b, n, 30, 3, 0.8, 0.5, Some(&allowed), &mut rng());
        for c in &out {
            assert!(c.indices().iter().all(|&i| i < 10), "index outside universe");
        }
    }

    #[test]
    fn explore_handles_tiny_space_without_hanging() {
        // universe of 2 ⇒ only 3 non-empty selectors of interest
        let n = 2;
        let out = explore(&[], n, 50, 1, 0.5, 0.5, None, &mut rng());
        assert!(out.len() <= 3 + 1); // at most the whole space
        let set: HashSet<_> = out.iter().collect();
        assert_eq!(set.len(), out.len());
    }

    #[test]
    fn random_selector_never_empty() {
        let universe: Vec<usize> = (0..12).collect();
        for _ in 0..100 {
            assert!(!random_selector(12, &universe, &mut rng()).is_empty());
        }
    }
}
