//! Raw Linux syscall bindings for the event-driven ingest edge.
//!
//! std already links libc on Linux, so declaring the handful of
//! prototypes the epoll edge needs (`epoll_create1` / `epoll_ctl` /
//! `epoll_wait` / `accept4` / `readv` / `writev` / `eventfd` /
//! `fcntl` / rlimit) costs **zero new dependencies** — the symbols
//! resolve against the libc every Rust binary on Linux already
//! carries. Everything here is a thin, EINTR-retrying wrapper; policy
//! (slabs, state machines, telemetry) lives in
//! [`edge`](super::edge) and [`conn`](super::conn).

#![allow(clippy::missing_safety_doc)]

use std::io;
use std::net::{SocketAddrV4, TcpListener};
use std::os::raw::{c_int, c_uint, c_void};
use std::os::unix::io::FromRawFd;

// ---- constants (x86_64/aarch64 Linux; values are ABI-stable) ----

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;
pub const EPOLLET: u32 = 1 << 31;
/// Wake exactly one of the epoll instances sharing a listener
/// (kernel ≥ 4.5) — the accept path's thundering-herd guard.
pub const EPOLLEXCLUSIVE: u32 = 1 << 28;

const EPOLL_CLOEXEC: c_int = 0o2000000;
const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;

const EFD_CLOEXEC: c_int = 0o2000000;
const EFD_NONBLOCK: c_int = 0o4000;

const SOCK_NONBLOCK: c_int = 0o4000;
const SOCK_CLOEXEC: c_int = 0o2000000;

const F_GETFL: c_int = 3;
const F_SETFL: c_int = 4;
const O_NONBLOCK: c_int = 0o4000;

const IPPROTO_TCP: c_int = 6;
const TCP_NODELAY: c_int = 1;

const AF_INET: c_int = 2;
const SOCK_STREAM: c_int = 1;
const SOL_SOCKET: c_int = 1;
const SO_REUSEADDR: c_int = 2;
const LISTEN_BACKLOG: c_int = 1024;

const RLIMIT_NOFILE: c_int = 7;

pub const EAGAIN: i32 = 11;
const EINTR: i32 = 4;

/// Kernel epoll event record. x86_64 packs it (no padding between the
/// mask and the 64-bit payload); other architectures use natural
/// alignment.
#[cfg(target_arch = "x86_64")]
#[repr(C, packed)]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

#[cfg(not(target_arch = "x86_64"))]
#[repr(C)]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

#[repr(C)]
struct IoVec {
    base: *mut c_void,
    len: usize,
}

#[repr(C)]
struct Rlimit {
    cur: u64,
    max: u64,
}

/// `struct sockaddr_in` (network byte order for port and address).
#[repr(C)]
struct SockAddrIn {
    sin_family: u16,
    sin_port: u16,
    sin_addr: u32,
    sin_zero: [u8; 8],
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int)
        -> c_int;
    fn eventfd(initval: c_uint, flags: c_int) -> c_int;
    fn accept4(sockfd: c_int, addr: *mut c_void, addrlen: *mut u32, flags: c_int) -> c_int;
    fn readv(fd: c_int, iov: *const IoVec, iovcnt: c_int) -> isize;
    fn writev(fd: c_int, iov: *const IoVec, iovcnt: c_int) -> isize;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn close(fd: c_int) -> c_int;
    fn fcntl(fd: c_int, cmd: c_int, ...) -> c_int;
    fn setsockopt(
        fd: c_int,
        level: c_int,
        optname: c_int,
        optval: *const c_void,
        optlen: u32,
    ) -> c_int;
    fn getrlimit(resource: c_int, rlim: *mut Rlimit) -> c_int;
    fn setrlimit(resource: c_int, rlim: *const Rlimit) -> c_int;
    fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
    fn bind(fd: c_int, addr: *const c_void, addrlen: u32) -> c_int;
    fn listen(fd: c_int, backlog: c_int) -> c_int;
}

fn errno() -> i32 {
    io::Error::last_os_error().raw_os_error().unwrap_or(0)
}

/// Close a raw descriptor (best effort — the edge owns its fds
/// directly, no std wrappers on the hot path).
pub fn close_fd(fd: i32) {
    unsafe { close(fd) };
}

/// An epoll instance owning its descriptor.
pub struct Epoll {
    fd: i32,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Epoll { fd })
    }

    /// Register `fd` for `events`, tagging readiness with `token`.
    pub fn add(&self, fd: i32, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        if unsafe { epoll_ctl(self.fd, EPOLL_CTL_ADD, fd, &mut ev) } < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Deregister `fd` (kernels < 2.6.9 needed a non-null event; every
    /// supported kernel accepts null semantics via a dummy).
    pub fn del(&self, fd: i32) {
        let mut ev = EpollEvent { events: 0, data: 0 };
        unsafe { epoll_ctl(self.fd, EPOLL_CTL_DEL, fd, &mut ev) };
    }

    /// Wait for readiness, retrying EINTR; returns the filled prefix.
    pub fn wait<'a>(
        &self,
        events: &'a mut [EpollEvent],
        timeout_ms: i32,
    ) -> io::Result<&'a [EpollEvent]> {
        loop {
            let n = unsafe {
                epoll_wait(self.fd, events.as_mut_ptr(), events.len() as c_int, timeout_ms)
            };
            if n >= 0 {
                return Ok(&events[..n as usize]);
            }
            if errno() != EINTR {
                return Err(io::Error::last_os_error());
            }
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        close_fd(self.fd);
    }
}

/// A nonblocking eventfd used to wake an event loop from another
/// thread (shutdown, cross-thread nudges).
pub struct EventFd {
    fd: i32,
}

impl EventFd {
    pub fn new() -> io::Result<EventFd> {
        let fd = unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(EventFd { fd })
    }

    pub fn raw(&self) -> i32 {
        self.fd
    }

    /// Post one wakeup (best effort; a full counter still wakes).
    pub fn notify(&self) {
        let one: u64 = 1;
        unsafe { write(self.fd, (&one as *const u64).cast(), 8) };
    }

    /// Consume pending wakeups so the next notify re-arms readiness.
    pub fn drain(&self) {
        let mut buf: u64 = 0;
        unsafe { read(self.fd, (&mut buf as *mut u64).cast(), 8) };
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        close_fd(self.fd);
    }
}

/// Nonblocking accept: `Ok(None)` when the backlog is empty (EAGAIN),
/// the accepted socket arrives already `SOCK_NONBLOCK | SOCK_CLOEXEC`.
pub fn accept_nonblocking(listener: i32) -> io::Result<Option<i32>> {
    loop {
        let fd = unsafe {
            accept4(listener, std::ptr::null_mut(), std::ptr::null_mut(), SOCK_NONBLOCK | SOCK_CLOEXEC)
        };
        if fd >= 0 {
            return Ok(Some(fd));
        }
        match errno() {
            EAGAIN => return Ok(None),
            EINTR => continue,
            _ => return Err(io::Error::last_os_error()),
        }
    }
}

/// Put a descriptor into nonblocking mode (the shared listener).
pub fn set_nonblocking(fd: i32) -> io::Result<()> {
    let flags = unsafe { fcntl(fd, F_GETFL) };
    if flags < 0 {
        return Err(io::Error::last_os_error());
    }
    if unsafe { fcntl(fd, F_SETFL, flags | O_NONBLOCK) } < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

/// Disable Nagle on an accepted connection so small pipelined
/// responses flush without coalescing delay.
pub fn set_nodelay(fd: i32) {
    let one: c_int = 1;
    unsafe { setsockopt(fd, IPPROTO_TCP, TCP_NODELAY, (&one as *const c_int).cast(), 4) };
}

/// Outcome of a nonblocking read/write attempt.
pub enum IoStep {
    /// n bytes transferred (0 on read = peer closed).
    Done(usize),
    /// EAGAIN — re-arm and wait for readiness.
    WouldBlock,
    /// Hard transport error — close the connection.
    Err,
}

/// Vectored read into two windows (receive-buffer spare + overflow
/// scratch), retrying EINTR.
///
/// # Safety
/// `(a, a_len)` and `(b, b_len)` must be valid writable windows.
pub unsafe fn readv2(fd: i32, a: *mut u8, a_len: usize, b: *mut u8, b_len: usize) -> IoStep {
    let iov = [
        IoVec { base: a.cast(), len: a_len },
        IoVec { base: b.cast(), len: b_len },
    ];
    let cnt = if b_len == 0 { 1 } else { 2 };
    loop {
        let n = unsafe { readv(fd, iov.as_ptr(), cnt) };
        if n >= 0 {
            return IoStep::Done(n as usize);
        }
        match errno() {
            EAGAIN => return IoStep::WouldBlock,
            EINTR => continue,
            _ => return IoStep::Err,
        }
    }
}

/// Vectored write of the output ring's ≤ 2 contiguous segments,
/// retrying EINTR.
pub fn writev2(fd: i32, a: &[u8], b: &[u8]) -> IoStep {
    let iov = [
        IoVec { base: a.as_ptr() as *mut c_void, len: a.len() },
        IoVec { base: b.as_ptr() as *mut c_void, len: b.len() },
    ];
    let cnt = if b.is_empty() { 1 } else { 2 };
    loop {
        let n = unsafe { writev(fd, iov.as_ptr(), cnt) };
        if n >= 0 {
            return IoStep::Done(n as usize);
        }
        match errno() {
            EAGAIN => return IoStep::WouldBlock,
            EINTR => continue,
            _ => return IoStep::Err,
        }
    }
}

/// Best-effort single write (the 503 refusal path on a fresh socket —
/// a ~100-byte response always fits a new socket's send buffer).
pub fn write_best_effort(fd: i32, bytes: &[u8]) {
    unsafe { write(fd, bytes.as_ptr().cast(), bytes.len()) };
}

/// Best-effort bounded drain of already-buffered input before a
/// refusal close (avoids an RST discarding the queued response).
pub fn drain_best_effort(fd: i32, limit: usize) {
    let mut buf = [0u8; 4096];
    let mut drained = 0usize;
    while drained < limit {
        let n = unsafe { read(fd, buf.as_mut_ptr().cast(), buf.len()) };
        if n <= 0 {
            break;
        }
        drained += n as usize;
    }
}

/// Bind an IPv4 listener with `SO_REUSEADDR` set before the bind —
/// a restarted peer (rolling upgrade, node-loss recovery) must be
/// able to re-claim its old port while the kernel still holds
/// TIME_WAIT remnants of the previous incarnation's connections.
/// `std::net::TcpListener::bind` offers no pre-bind socket options,
/// hence the raw construction; the returned listener is an ordinary
/// std listener owning the descriptor.
pub fn bind_reuse(addr: SocketAddrV4) -> io::Result<TcpListener> {
    let fd = unsafe { socket(AF_INET, SOCK_STREAM | SOCK_CLOEXEC, 0) };
    if fd < 0 {
        return Err(io::Error::last_os_error());
    }
    let one: c_int = 1;
    if unsafe {
        setsockopt(fd, SOL_SOCKET, SO_REUSEADDR, (&one as *const c_int).cast(), 4)
    } < 0
    {
        let err = io::Error::last_os_error();
        close_fd(fd);
        return Err(err);
    }
    let sa = SockAddrIn {
        sin_family: AF_INET as u16,
        sin_port: addr.port().to_be(),
        sin_addr: u32::from(*addr.ip()).to_be(),
        sin_zero: [0u8; 8],
    };
    if unsafe {
        bind(fd, (&sa as *const SockAddrIn).cast(), std::mem::size_of::<SockAddrIn>() as u32)
    } < 0
    {
        let err = io::Error::last_os_error();
        close_fd(fd);
        return Err(err);
    }
    if unsafe { listen(fd, LISTEN_BACKLOG) } < 0 {
        let err = io::Error::last_os_error();
        close_fd(fd);
        return Err(err);
    }
    // SAFETY: fd is a freshly created, bound, listening socket we own.
    Ok(unsafe { TcpListener::from_raw_fd(fd) })
}

/// Raise the open-file soft limit to the hard limit (benches and
/// high-fan-in deployments need ~2 fds per held connection). Returns
/// the resulting soft limit; errors degrade to the current value.
pub fn raise_nofile_limit() -> u64 {
    let mut lim = Rlimit { cur: 0, max: 0 };
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
        return 1024;
    }
    if lim.cur < lim.max {
        let want = Rlimit { cur: lim.max, max: lim.max };
        if unsafe { setrlimit(RLIMIT_NOFILE, &want) } == 0 {
            return lim.max;
        }
    }
    lim.cur
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eventfd_notify_drain_roundtrip() {
        let efd = EventFd::new().unwrap();
        efd.notify();
        efd.notify();
        efd.drain(); // consumes the whole counter
        // after drain the fd is quiet again: another notify still works
        efd.notify();
    }

    #[test]
    fn epoll_sees_eventfd_readiness() {
        let ep = Epoll::new().unwrap();
        let efd = EventFd::new().unwrap();
        ep.add(efd.raw(), EPOLLIN, 7).unwrap();
        let mut events = [EpollEvent { events: 0, data: 0 }; 4];
        // nothing pending: immediate timeout
        assert_eq!(ep.wait(&mut events, 0).unwrap().len(), 0);
        efd.notify();
        let ready = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(ready.len(), 1);
        let data = ready[0].data; // copy out of the packed struct
        assert_eq!(data, 7);
        efd.drain();
        ep.del(efd.raw());
    }

    #[test]
    fn bind_reuse_rebinds_a_just_used_port() {
        let l1 = bind_reuse("127.0.0.1:0".parse().unwrap()).unwrap();
        let addr = l1.local_addr().unwrap();
        // leave connection remnants behind on the port, then drop the
        // listener — the REUSEADDR rebind must still succeed
        let c = std::net::TcpStream::connect(addr).unwrap();
        let (a, _) = l1.accept().unwrap();
        drop(a);
        drop(c);
        drop(l1);
        let l2 = bind_reuse(SocketAddrV4::new(
            std::net::Ipv4Addr::LOCALHOST,
            addr.port(),
        ))
        .unwrap();
        assert_eq!(l2.local_addr().unwrap().port(), addr.port());
    }

    #[test]
    fn nofile_limit_is_queryable_and_monotone() {
        let lim = raise_nofile_limit();
        assert!(lim >= 256, "any sane CI box allows ≥ 256 fds, got {lim}");
        // idempotent: a second raise reports at least the same limit
        assert!(raise_nofile_limit() >= lim);
    }
}
