//! Event-driven ingest edge: a fixed pool of epoll readiness loops
//! replacing thread-per-connection.
//!
//! ```text
//!   monitors ──► shared nonblocking listener
//!                   │ EPOLLEXCLUSIVE accept (one loop wakes per conn)
//!        ┌──────────┼──────────┐
//!        ▼          ▼          ▼
//!   edge loop 0  edge loop 1  …   (--edge-threads, default cores/4)
//!   epoll + slab of per-connection states
//!        │ edge-triggered readv → RecvBuf (contiguous, compacting)
//!        │ in-place wire decode (decode_step, no body Vec)
//!        ▼
//!   FrameSink (ShardSender: patient % shards ──► aggregation shards,
//!        ▲     or RouterSink: ring route ──► downstream peer links)
//!        └ responses: OutRing → writev (≤ 2 segments, pipelined)
//! ```
//!
//! Scaling shape: thread count follows `--edge-threads`, not the
//! connection count — 10k mostly-idle keep-alive monitors cost slab
//! slots and buffers, not OS threads. Each loop owns its connections
//! outright (slab, generation-tagged epoll tokens), so there is no
//! cross-loop locking; the only shared state is the accept gate and
//! the telemetry counters, both atomics.
//!
//! Backpressure is physical: a full shard queue blocks the owning
//! loop's `ShardSender::send` (bounded channels), a full socket send
//! buffer parks the response in the connection's [`OutRing`] until
//! `EPOLLOUT`, and a client that stops reading eventually stalls its
//! own connection only. Stalled *half-requests* are reaped by the
//! idle sweep ([`HttpConfig::read_timeout`]), counted in
//! `conns_reaped`.

use std::net::TcpListener;
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::serving::{EdgeGauges, Telemetry};
use crate::{Error, Result};

use super::conn::HttpConn;
use super::sys::{self, IoStep};
use super::{FrameSink, HttpConfig, HttpServer};

/// epoll token of the shared listener.
const TOKEN_LISTEN: u64 = u64::MAX;
/// epoll token of the per-loop wake eventfd.
const TOKEN_WAKE: u64 = u64::MAX - 1;

/// Refusal sent when the connection gate is full — byte-identical to
/// the fallback edge's 503 (the flood test asserts the body text).
const REFUSAL_503: &[u8] = b"HTTP/1.1 503 Service Unavailable\r\nContent-Type: application/json\r\nContent-Length: 36\r\nConnection: close\r\n\r\n{\"error\":\"connection limit reached\"}";

/// Connection-slot token: slot index in the low 32 bits, a 31-bit
/// generation above it (stale events for a recycled slot are dropped
/// by the generation check; the top bit stays clear of the special
/// tokens).
fn token(slot: usize, gen: u32) -> u64 {
    (((gen & 0x7fff_ffff) as u64) << 32) | slot as u64
}

/// Resolve `--edge-threads`: 0 = auto (a quarter of the cores,
/// clamped to [1, 4] — ingest parsing is cheap relative to model
/// execution, which owns the rest of the box).
pub(crate) fn effective_edge_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested.min(64);
    }
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    (cores / 4).clamp(1, 4)
}

struct Slot {
    conn: HttpConn,
    fd: i32,
    gen: u32,
    open: bool,
    /// Peer sent FIN: stop reading, close once the response flushes.
    peer_eof: bool,
    last_activity: Instant,
}

struct EdgeLoop<S: FrameSink> {
    ep: sys::Epoll,
    waker: Arc<sys::EventFd>,
    listener_fd: i32,
    sink: S,
    telemetry: Arc<Telemetry>,
    stop: Arc<AtomicBool>,
    ready_events: Arc<[AtomicU64]>,
    loop_idx: usize,
    max_connections: usize,
    read_timeout: Duration,
    slots: Vec<Slot>,
    free: Vec<usize>,
    scratch: Vec<u8>,
}

enum Flush {
    Empty,
    Pending,
    Error,
}

impl<S: FrameSink> EdgeLoop<S> {
    fn run(mut self) {
        let tick = (self.read_timeout / 4)
            .clamp(Duration::from_millis(10), Duration::from_secs(1));
        let timeout_ms = tick.as_millis() as i32;
        let mut events = [sys::EpollEvent { events: 0, data: 0 }; 256];
        let mut last_sweep = Instant::now();
        loop {
            let ready = match self.ep.wait(&mut events, timeout_ms) {
                Ok(r) => r,
                Err(_) => break, // epoll itself failed: give up the loop
            };
            let n_ready = ready.len();
            self.ready_events[self.loop_idx].fetch_add(n_ready as u64, Ordering::Relaxed);
            for i in 0..n_ready {
                // copy the (possibly packed) record fields by value
                let (tok, mask) = (events[i].data, events[i].events);
                match tok {
                    TOKEN_WAKE => self.waker.drain(),
                    TOKEN_LISTEN => self.accept_burst(),
                    t => {
                        let slot = (t & 0xffff_ffff) as usize;
                        let gen = (t >> 32) as u32;
                        if slot < self.slots.len()
                            && self.slots[slot].open
                            && self.slots[slot].gen & 0x7fff_ffff == gen
                        {
                            self.conn_event(slot, mask);
                        }
                    }
                }
            }
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            if last_sweep.elapsed() >= tick {
                last_sweep = Instant::now();
                self.sweep();
            }
        }
        // orderly teardown: close every connection this loop owns
        for i in 0..self.slots.len() {
            if self.slots[i].open {
                self.close(i, false);
            }
        }
    }

    fn accept_burst(&mut self) {
        // bounded per readiness so one flood cannot starve live
        // connections on this loop; leftover backlog re-arms
        // (level-triggered listener registration)
        for _ in 0..256 {
            let fd = match sys::accept_nonblocking(self.listener_fd) {
                Ok(Some(fd)) => fd,
                Ok(None) | Err(_) => break,
            };
            // gate: add-then-check against the shared live count (the
            // same counter is the `conns_active` gauge, so the gate
            // and the observable metric cannot disagree)
            if self.telemetry.conns_active.fetch_add(1, Ordering::Relaxed)
                >= self.max_connections
            {
                self.telemetry.conns_active.fetch_sub(1, Ordering::Relaxed);
                self.telemetry.conns_refused.fetch_add(1, Ordering::Relaxed);
                self.telemetry.conns_refused_overcap.fetch_add(1, Ordering::Relaxed);
                sys::write_best_effort(fd, REFUSAL_503);
                sys::drain_best_effort(fd, 64 * 1024);
                sys::close_fd(fd);
                continue;
            }
            self.telemetry.conns_accepted.fetch_add(1, Ordering::Relaxed);
            sys::set_nodelay(fd);
            let slot = match self.free.pop() {
                Some(i) => {
                    let s = &mut self.slots[i];
                    s.conn = HttpConn::new();
                    s.fd = fd;
                    s.open = true;
                    s.peer_eof = false;
                    s.last_activity = Instant::now();
                    i
                }
                None => {
                    self.slots.push(Slot {
                        conn: HttpConn::new(),
                        fd,
                        gen: 0,
                        open: true,
                        peer_eof: false,
                        last_activity: Instant::now(),
                    });
                    self.slots.len() - 1
                }
            };
            let tok = token(slot, self.slots[slot].gen);
            let interest =
                sys::EPOLLIN | sys::EPOLLOUT | sys::EPOLLRDHUP | sys::EPOLLET;
            if self.ep.add(fd, interest, tok).is_err() {
                // accepted but never registered: the peer sees a close
                // with no response — a handshake-level refusal, counted
                // per cause so a registration leak can't hide inside the
                // accept totals
                self.telemetry.conns_refused.fetch_add(1, Ordering::Relaxed);
                self.telemetry.conns_refused_handshake.fetch_add(1, Ordering::Relaxed);
                self.close(slot, false);
                continue;
            }
            // data may already be waiting (registration reports the
            // initial readiness edge, but don't depend on it)
            self.conn_event(slot, sys::EPOLLIN);
        }
    }

    /// Drive one connection through read → parse/respond → flush until
    /// it quiesces, closes, or blocks.
    fn conn_event(&mut self, slot: usize, mask: u32) {
        if mask & (sys::EPOLLHUP | sys::EPOLLERR) != 0 {
            self.close(slot, false);
            return;
        }
        self.slots[slot].last_activity = Instant::now();
        loop {
            // 1. drain the socket (edge-triggered: read to EAGAIN)
            let mut read_any = false;
            let mut eof = false;
            let mut dead = false;
            {
                let s = &mut self.slots[slot];
                if !s.peer_eof && s.conn.wants_read() {
                    loop {
                        let (ptr, len) = s.conn.recv_mut().spare_ptr(4096);
                        let step = unsafe {
                            sys::readv2(
                                s.fd,
                                ptr,
                                len,
                                self.scratch.as_mut_ptr(),
                                self.scratch.len(),
                            )
                        };
                        match step {
                            IoStep::Done(0) => {
                                eof = true;
                                break;
                            }
                            IoStep::Done(n) => {
                                let direct = n.min(len);
                                // SAFETY: the kernel initialized
                                // `direct` bytes of the spare window
                                unsafe { s.conn.recv_mut().commit(direct) };
                                if n > direct {
                                    // burst overflowed into scratch:
                                    // copy the spill in (rare)
                                    s.conn.recv_mut().extend(&self.scratch[..n - direct]);
                                }
                                read_any = true;
                                if n < len + self.scratch.len() {
                                    break; // short read: socket drained
                                }
                            }
                            IoStep::WouldBlock => break,
                            IoStep::Err => {
                                dead = true;
                                break;
                            }
                        }
                    }
                }
            }
            if dead {
                self.close(slot, false);
                return;
            }
            if eof {
                self.slots[slot].peer_eof = true;
            }
            // 2. parse and respond until quiescent or backpressured
            loop {
                let progressed = {
                    let s = &mut self.slots[slot];
                    s.conn.advance(&self.sink, &self.telemetry)
                };
                let flush = self.flush(slot);
                if matches!(flush, Flush::Error) {
                    self.close(slot, false);
                    return;
                }
                if self.slots[slot].conn.ready_to_close() {
                    self.close(slot, false);
                    return;
                }
                if !progressed || matches!(flush, Flush::Pending) {
                    break;
                }
            }
            // 3. half-closed peer: once the response has flushed there
            // is nothing left to do on this connection
            if self.slots[slot].peer_eof && self.slots[slot].conn.out_mut().is_empty() {
                self.close(slot, false);
                return;
            }
            if !read_any {
                return; // wait for the next readiness edge
            }
        }
    }

    fn flush(&mut self, slot: usize) -> Flush {
        let s = &mut self.slots[slot];
        loop {
            if s.conn.out_mut().is_empty() {
                return Flush::Empty;
            }
            let (a, b) = s.conn.out_mut().segments();
            match sys::writev2(s.fd, a, b) {
                IoStep::Done(0) => return Flush::Error,
                IoStep::Done(n) => s.conn.out_mut().consume(n),
                IoStep::WouldBlock => return Flush::Pending,
                IoStep::Err => return Flush::Error,
            }
        }
    }

    fn close(&mut self, slot: usize, reaped: bool) {
        let s = &mut self.slots[slot];
        debug_assert!(s.open);
        self.ep.del(s.fd);
        sys::close_fd(s.fd);
        s.open = false;
        s.fd = -1;
        s.gen = s.gen.wrapping_add(1);
        self.free.push(slot);
        self.telemetry.conns_active.fetch_sub(1, Ordering::Relaxed);
        if reaped {
            self.telemetry.conns_reaped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Reap connections idle past the read deadline — the slow-loris
    /// guard: a drip-feeding or silent client frees its slot instead
    /// of pinning it forever.
    fn sweep(&mut self) {
        let now = Instant::now();
        for i in 0..self.slots.len() {
            if self.slots[i].open
                && now.duration_since(self.slots[i].last_activity) > self.read_timeout
            {
                self.close(i, true);
            }
        }
    }
}

/// Spawn the epoll edge: bind, start `--edge-threads` event loops,
/// return the server handle whose drop stops and joins them.
pub(crate) fn serve_edge<S: FrameSink>(
    addr: &str,
    sink: S,
    telemetry: Arc<Telemetry>,
    cfg: HttpConfig,
) -> Result<HttpServer> {
    // SO_REUSEADDR before the bind: a restarted peer must re-claim its
    // port through the previous incarnation's TIME_WAIT remnants
    // (rolling upgrades, node-loss recovery). Non-IPv4 address forms
    // fall back to the plain std bind.
    let listener = match addr.parse::<std::net::SocketAddrV4>() {
        Ok(v4) => sys::bind_reuse(v4)?,
        Err(_) => TcpListener::bind(addr)?,
    };
    let local = listener.local_addr()?;
    let listener_fd = listener.as_raw_fd();
    sys::set_nonblocking(listener_fd).map_err(Error::Io)?;

    let n_loops = effective_edge_threads(cfg.edge_threads);
    let ready_events: Arc<[AtomicU64]> = (0..n_loops).map(|_| AtomicU64::new(0)).collect();
    telemetry.install_edge(EdgeGauges::new(Arc::clone(&ready_events)));
    let stop = Arc::new(AtomicBool::new(false));

    let mut wakers: Vec<Arc<sys::EventFd>> = Vec::with_capacity(n_loops);
    let mut joins = Vec::with_capacity(n_loops);
    for i in 0..n_loops {
        let ep = sys::Epoll::new().map_err(Error::Io)?;
        let waker = Arc::new(sys::EventFd::new().map_err(Error::Io)?);
        ep.add(waker.raw(), sys::EPOLLIN, TOKEN_WAKE).map_err(Error::Io)?;
        // level-triggered + EPOLLEXCLUSIVE: exactly one sleeping loop
        // wakes per connection burst, unconsumed backlog re-arms
        ep.add(listener_fd, sys::EPOLLIN | sys::EPOLLEXCLUSIVE, TOKEN_LISTEN)
            .map_err(Error::Io)?;
        let lp = EdgeLoop {
            ep,
            waker: Arc::clone(&waker),
            listener_fd,
            sink: sink.clone(),
            telemetry: Arc::clone(&telemetry),
            stop: Arc::clone(&stop),
            ready_events: Arc::clone(&ready_events),
            loop_idx: i,
            max_connections: cfg.max_connections,
            read_timeout: cfg.read_timeout,
            slots: Vec::new(),
            free: Vec::new(),
            scratch: vec![0u8; 64 * 1024],
        };
        wakers.push(waker);
        joins.push(
            std::thread::Builder::new()
                .name(format!("http-edge-{i}"))
                .spawn(move || lp.run())
                .map_err(Error::Io)?,
        );
    }

    let stop2 = Arc::clone(&stop);
    let shutdown = Box::new(move || {
        stop2.store(true, Ordering::SeqCst);
        for w in &wakers {
            w.notify();
        }
        for j in joins {
            let _ = j.join();
        }
        drop(listener); // closed only after every loop has exited
    });
    Ok(HttpServer { addr: local, stop, shutdown: Some(shutdown) })
}
