//! Per-connection HTTP protocol core, shared by both ingest edges.
//!
//! Everything here is **pure state + bytes** — no sockets, no
//! syscalls — so the exact production parsing and framing logic can be
//! driven deterministically by tests at arbitrary fragmentation
//! (`tests/edge.rs` replays requests split at every byte boundary).
//!
//! Three pieces:
//!
//! * [`RecvBuf`] — a compacting receive buffer that keeps unconsumed
//!   bytes **contiguous**, so the wire decoder reads frames in place
//!   (the single buffer a 250 Hz sample touches between the socket and
//!   the shard-owned lead slot).
//! * [`OutRing`] — a circular response buffer whose ≤ 2 contiguous
//!   segments flush with one vectored write (`writev`), batching
//!   pipelined keep-alive responses into single syscalls.
//! * [`HttpConn`] — the incremental request state machine: head →
//!   (streaming binary body | buffered body | drain), tolerant of any
//!   read fragmentation, admitting `/ingest.bin` frames straight into
//!   the connection's [`FrameSink`] (local shards on a serve node, the
//!   router's peer links on a router) as their bytes complete. The
//!   streaming decoder speaks the full envelope: plain `HLM1` frames,
//!   `HLMB` batch headers, and `HLMH` heartbeats (whose response
//!   reports the node's drain state).
//!
//! The steady-state `/ingest.bin` path allocates nothing: the receive
//! buffer and output ring reuse their grown capacity, frames decode
//! into inline [`Frame`](crate::ingest::Frame) values, responses are
//! formatted with [`fmt_u64`] into stack scratch, and the bounded
//! shard channels are preallocated. `tests/edge.rs` asserts this with
//! a counting global allocator.

use crate::ingest::wire::{self, EnvelopeStep};
use crate::serving::Telemetry;
use std::sync::atomic::Ordering;

use super::{route_parsed, FrameSink, MAX_BODY_BYTES};

/// Largest accepted request head (request line + headers).
pub const MAX_HEAD_BYTES: usize = 1 << 20;

/// Stop parsing further pipelined requests once this many response
/// bytes are queued; parsing resumes after the ring flushes (TCP
/// backpressure, bounded memory per connection).
pub const OUT_BACKPRESSURE_BYTES: usize = 64 * 1024;

/// Write `v` in decimal into `scratch`, returning the digits as a
/// slice (no heap, no `format!` — the hot-path response formatter).
pub fn fmt_u64(scratch: &mut [u8; 20], mut v: u64) -> &[u8] {
    let mut i = scratch.len();
    loop {
        i -= 1;
        scratch[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    &scratch[i..]
}

/// Compacting receive buffer: unconsumed bytes stay contiguous at
/// [`RecvBuf::data`], consumed space is reclaimed by memmove (never by
/// reallocation once capacity has grown).
#[derive(Debug, Default)]
pub struct RecvBuf {
    buf: Vec<u8>,
    start: usize,
}

impl RecvBuf {
    pub fn with_capacity(n: usize) -> Self {
        RecvBuf { buf: Vec::with_capacity(n), start: 0 }
    }

    /// Unconsumed bytes, contiguous.
    pub fn data(&self) -> &[u8] {
        &self.buf[self.start..]
    }

    pub fn len(&self) -> usize {
        self.buf.len() - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.buf.len() == self.start
    }

    /// Discard `n` bytes from the front (they were processed in place).
    pub fn consume(&mut self, n: usize) {
        debug_assert!(n <= self.len());
        self.start += n;
        if self.start == self.buf.len() {
            // everything consumed: reset without memmove, keep capacity
            self.buf.clear();
            self.start = 0;
        }
    }

    /// Append bytes (copying path — tests, scratch-spill overflow).
    pub fn extend(&mut self, bytes: &[u8]) {
        if self.start > 0 && self.buf.len() + bytes.len() > self.buf.capacity() {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Expose the spare tail (≥ `min` bytes) for a kernel read,
    /// compacting consumed space first and growing only when the live
    /// bytes plus `min` genuinely exceed capacity. Returns the raw
    /// window; pair with [`RecvBuf::commit`] after the read.
    pub fn spare_ptr(&mut self, min: usize) -> (*mut u8, usize) {
        if self.start > 0 && self.buf.len() + min > self.buf.capacity() {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        if self.buf.len() + min > self.buf.capacity() {
            self.buf.reserve(min);
        }
        let len = self.buf.len();
        let spare = self.buf.capacity() - len;
        // SAFETY: pointer to the (possibly uninitialized) tail inside
        // the Vec's allocation; `spare` bytes are owned and writable.
        unsafe { (self.buf.as_mut_ptr().add(len), spare) }
    }

    /// Declare `n` tail bytes initialized (the kernel wrote them
    /// through the pointer from [`RecvBuf::spare_ptr`]).
    ///
    /// # Safety
    /// The first `n` spare bytes returned by the immediately preceding
    /// [`RecvBuf::spare_ptr`] call must have been initialized, with no
    /// intervening mutation of the buffer.
    pub unsafe fn commit(&mut self, n: usize) {
        debug_assert!(self.buf.len() + n <= self.buf.capacity());
        unsafe { self.buf.set_len(self.buf.len() + n) };
    }
}

/// Circular response buffer: appended bytes wrap around, and the live
/// contents are exposed as at most two contiguous [`OutRing::segments`]
/// for a single vectored write. Grows (linearizing) only when a
/// response exceeds the remaining capacity; steady state recycles.
#[derive(Debug)]
pub struct OutRing {
    buf: Box<[u8]>,
    lo: usize,
    len: usize,
}

impl Default for OutRing {
    fn default() -> Self {
        Self::with_capacity(4 * 1024)
    }
}

impl OutRing {
    pub fn with_capacity(n: usize) -> Self {
        OutRing { buf: vec![0u8; n.max(64)].into_boxed_slice(), lo: 0, len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The queued bytes as (head, tail) — `tail` is empty unless the
    /// live region wraps. `writev` both in one call.
    pub fn segments(&self) -> (&[u8], &[u8]) {
        let cap = self.buf.len();
        let end = self.lo + self.len;
        if end <= cap {
            (&self.buf[self.lo..end], &self.buf[..0])
        } else {
            (&self.buf[self.lo..], &self.buf[..end - cap])
        }
    }

    /// Drop `n` bytes from the front (they were written to the socket).
    pub fn consume(&mut self, n: usize) {
        debug_assert!(n <= self.len);
        self.lo = (self.lo + n) % self.buf.len();
        self.len -= n;
        if self.len == 0 {
            self.lo = 0;
        }
    }

    pub fn append(&mut self, bytes: &[u8]) {
        if self.len + bytes.len() > self.buf.len() {
            self.grow(self.len + bytes.len());
        }
        let cap = self.buf.len();
        let at = (self.lo + self.len) % cap;
        let first = bytes.len().min(cap - at);
        self.buf[at..at + first].copy_from_slice(&bytes[..first]);
        self.buf[..bytes.len() - first].copy_from_slice(&bytes[first..]);
        self.len += bytes.len();
    }

    fn grow(&mut self, need: usize) {
        let new_cap = (self.buf.len() * 2).max(need.next_power_of_two());
        let mut next = vec![0u8; new_cap].into_boxed_slice();
        let (a, b) = self.segments();
        next[..a.len()].copy_from_slice(a);
        next[a.len()..a.len() + b.len()].copy_from_slice(b);
        self.buf = next;
        self.lo = 0;
    }
}

/// The routes the edge serves (parsed from the request line in place,
/// no `String`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    IngestJson,
    IngestBin,
    /// `POST /drain` — flag this node as draining for a rolling
    /// upgrade (heartbeat responses advertise it; the router re-homes
    /// this peer's patients with zero frame loss).
    Drain,
    /// `GET /artifact/<64-hex id>` — serve a content-addressed model
    /// bundle from this node's local registry store (the peer-to-peer
    /// distribution edge a cold node fetches its models over). The id
    /// is parsed in place; a malformed id is `Unknown` (404).
    Artifact(crate::registry::ArtifactId),
    Stats,
    Healthz,
    Unknown,
}

/// Everything both edges need from a request head.
#[derive(Debug, Clone, Copy)]
pub struct HeadInfo {
    pub route: Route,
    pub content_length: usize,
    /// Keep-alive after this request (HTTP/1.1 default, HTTP/1.0 must
    /// opt in, `Connection: close` wins).
    pub keep_alive: bool,
    /// Body framing we cannot trust (chunked transfer encoding, or an
    /// unparseable Content-Length): `400` + close.
    pub bad_framing: bool,
}

fn parse_usize_ascii(b: &[u8]) -> Option<usize> {
    if b.is_empty() {
        return None;
    }
    let mut n: usize = 0;
    for &c in b {
        if !c.is_ascii_digit() {
            return None;
        }
        n = n.checked_mul(10)?.checked_add((c - b'0') as usize)?;
    }
    Some(n)
}

/// Parse a complete request head (through the blank line) **in
/// place** — byte-slice comparisons only, no allocation.
pub fn parse_head(head: &[u8]) -> HeadInfo {
    let mut lines = head.split(|&b| b == b'\n').map(|l| l.strip_suffix(b"\r").unwrap_or(l));
    let request_line = lines.next().unwrap_or(b"");
    let mut parts = request_line.split(|&b| b == b' ').filter(|p| !p.is_empty());
    let method = parts.next().unwrap_or(b"");
    let path = parts.next().unwrap_or(b"");
    let route = match (method, path) {
        (b"POST", b"/ingest") => Route::IngestJson,
        (b"POST", b"/ingest.bin") => Route::IngestBin,
        (b"POST", b"/drain") => Route::Drain,
        (b"GET", p) if p.starts_with(b"/artifact/") => std::str::from_utf8(&p[10..])
            .ok()
            .and_then(crate::registry::ArtifactId::from_hex)
            .map_or(Route::Unknown, Route::Artifact),
        (b"GET", b"/stats") => Route::Stats,
        (b"GET", b"/healthz") => Route::Healthz,
        _ => Route::Unknown,
    };
    let http10 = request_line.ends_with(b"HTTP/1.0");

    let mut content_length = 0usize;
    let mut bad_framing = false;
    let mut close_requested = false;
    let mut keep_alive_requested = false;
    for line in lines {
        let Some(colon) = line.iter().position(|&b| b == b':') else { continue };
        let name = &line[..colon];
        let value = line[colon + 1..].trim_ascii();
        if name.eq_ignore_ascii_case(b"content-length") {
            match parse_usize_ascii(value) {
                Some(n) => content_length = n,
                // an unparseable length (e.g. duplicate headers merged
                // to "123, 123") must not default to 0: the body bytes
                // would be re-parsed as the next request
                None => bad_framing = true,
            }
        } else if name.eq_ignore_ascii_case(b"transfer-encoding") {
            bad_framing = true; // chunked bodies are unsupported
        } else if name.eq_ignore_ascii_case(b"connection") {
            close_requested = value.eq_ignore_ascii_case(b"close");
            keep_alive_requested = value.eq_ignore_ascii_case(b"keep-alive");
        }
    }
    HeadInfo {
        route,
        content_length,
        keep_alive: !close_requested && (!http10 || keep_alive_requested),
        bad_framing,
    }
}

/// What went wrong inside a streaming `/ingest.bin` body (reported
/// after the body is fully consumed, so keep-alive framing survives).
#[derive(Debug)]
enum BinError {
    /// Malformed wire bytes — the message lands in the 400 payload.
    /// (Error path only: this `String` never exists for valid input.)
    Malformed(String),
    /// The aggregation plane hung up: 503.
    PipelineClosed,
}

#[derive(Debug)]
enum Phase {
    /// Accumulating the request head.
    Head,
    /// Streaming a `/ingest.bin` body: frames decode in place and go
    /// straight to the frame sink as their bytes complete. `batch_left`
    /// tracks an open `HLMB` envelope (its announced frames must all
    /// arrive within this body); `seq` holds a pending `HLMS`
    /// idempotency tag for the next batch header; `skip` marks the
    /// open batch as an already-admitted duplicate whose frames are
    /// acknowledged (counted in `frames`) but not delivered;
    /// `heartbeat` records that the body carried an `HLMH` probe,
    /// which switches the response to the drain-state-reporting form.
    BinBody {
        remaining: usize,
        keep_alive: bool,
        frames: u64,
        err: Option<BinError>,
        batch_left: u32,
        seq: Option<(u64, u64)>,
        skip: bool,
        heartbeat: bool,
    },
    /// Buffering a (small, bounded) body for a non-streaming route.
    BufBody { route: Route, remaining: usize, keep_alive: bool },
    /// Discarding an oversized body (bounded) so the queued `413`
    /// survives the close instead of being discarded by an RST.
    Drain { remaining: usize },
}

/// Incremental per-connection HTTP state machine. I/O-free: the driver
/// appends received bytes to [`HttpConn::recv_mut`], calls
/// [`HttpConn::advance`], flushes [`HttpConn::out_mut`], and closes
/// when [`HttpConn::ready_to_close`] says so.
#[derive(Debug)]
pub struct HttpConn {
    recv: RecvBuf,
    out: OutRing,
    phase: Phase,
    /// Request-head bytes already scanned for the blank line (the
    /// `\r\n\r\n` search restarts near the fragmentation boundary, not
    /// from zero).
    head_scanned: usize,
    /// Close once the output ring drains (error responses, explicit
    /// `Connection: close`, header overflow).
    close_after_out: bool,
}

impl Default for HttpConn {
    fn default() -> Self {
        Self::new()
    }
}

impl HttpConn {
    pub fn new() -> Self {
        HttpConn {
            recv: RecvBuf::with_capacity(8 * 1024),
            out: OutRing::default(),
            phase: Phase::Head,
            head_scanned: 0,
            close_after_out: false,
        }
    }

    pub fn recv_mut(&mut self) -> &mut RecvBuf {
        &mut self.recv
    }

    pub fn out_mut(&mut self) -> &mut OutRing {
        &mut self.out
    }

    /// True once the connection should close as soon as the output
    /// ring has flushed (and any drain obligation is met).
    pub fn ready_to_close(&self) -> bool {
        self.close_after_out
            && self.out.is_empty()
            && match self.phase {
                Phase::Drain { remaining } => remaining == 0 || self.recv.is_empty(),
                _ => true,
            }
    }

    /// Whether the driver should keep reading from the socket — false
    /// once the connection is closing and owes no drain.
    pub fn wants_read(&self) -> bool {
        !self.close_after_out || matches!(self.phase, Phase::Drain { .. })
    }

    fn respond(&mut self, status: &str, body: &[u8], keep_alive: bool) {
        let mut scratch = [0u8; 20];
        self.out.append(b"HTTP/1.1 ");
        self.out.append(status.as_bytes());
        self.out.append(b"\r\nContent-Type: application/json\r\nContent-Length: ");
        let digits = fmt_u64(&mut scratch, body.len() as u64);
        self.out.append(digits);
        self.out.append(b"\r\nConnection: ");
        self.out.append(if keep_alive { b"keep-alive" } else { b"close" });
        self.out.append(b"\r\n\r\n");
        self.out.append(body);
        if !keep_alive {
            self.close_after_out = true;
        }
    }

    /// Run the state machine over whatever bytes are in the receive
    /// buffer. Returns `true` if any input was consumed or output
    /// produced (the driver loops while progress is being made).
    pub fn advance<S: FrameSink>(&mut self, sink: &S, telemetry: &Telemetry) -> bool {
        let mut progressed = false;
        loop {
            match std::mem::replace(&mut self.phase, Phase::Head) {
                Phase::Head => {
                    if self.close_after_out || self.out.len() >= OUT_BACKPRESSURE_BYTES {
                        break; // closing, or resume after the ring flushes
                    }
                    let data = self.recv.data();
                    let from = self.head_scanned.saturating_sub(3);
                    let found = data[from..]
                        .windows(4)
                        .position(|w| w == b"\r\n\r\n")
                        .map(|p| from + p + 4);
                    let Some(head_end) = found else {
                        self.head_scanned = data.len();
                        if self.recv.len() > MAX_HEAD_BYTES {
                            // mirror the fallback edge: oversized heads
                            // close without a response
                            self.close_after_out = true;
                            progressed = true;
                        }
                        break;
                    };
                    let info = parse_head(&self.recv.data()[..head_end]);
                    self.recv.consume(head_end);
                    self.head_scanned = 0;
                    progressed = true;
                    if info.bad_framing {
                        self.respond(
                            "400 Bad Request",
                            b"{\"error\":\"unsupported or malformed body framing\"}",
                            false,
                        );
                        break;
                    }
                    if info.content_length > MAX_BODY_BYTES {
                        let body = format!("{{\"error\":\"body exceeds {MAX_BODY_BYTES} bytes\"}}");
                        self.respond("413 Payload Too Large", body.as_bytes(), false);
                        // drain (bounded) before the close so the
                        // kernel doesn't RST the queued 413 away
                        self.phase = Phase::Drain {
                            remaining: info.content_length.min(2 * MAX_BODY_BYTES),
                        };
                        continue;
                    }
                    self.phase = match info.route {
                        Route::IngestBin => Phase::BinBody {
                            remaining: info.content_length,
                            keep_alive: info.keep_alive,
                            frames: 0,
                            err: None,
                            batch_left: 0,
                            seq: None,
                            skip: false,
                            heartbeat: false,
                        },
                        route => Phase::BufBody {
                            route,
                            remaining: info.content_length,
                            keep_alive: info.keep_alive,
                        },
                    };
                }
                Phase::BinBody {
                    mut remaining,
                    keep_alive,
                    mut frames,
                    mut err,
                    mut batch_left,
                    mut seq,
                    mut skip,
                    mut heartbeat,
                } => {
                    // decode envelope records in place from the receive
                    // buffer as their bytes complete; after an error the
                    // rest of the body is still consumed, so keep-alive
                    // framing survives a bad body
                    while remaining > 0 && !self.recv.is_empty() {
                        if err.is_some() {
                            let discard = self.recv.len().min(remaining);
                            self.recv.consume(discard);
                            remaining -= discard;
                            progressed = true;
                            continue;
                        }
                        let avail = self.recv.len().min(remaining);
                        match wire::decode_envelope_step(&self.recv.data()[..avail]) {
                            Ok(EnvelopeStep::Frame(frame, used)) => {
                                if batch_left > 0 && skip {
                                    // duplicate batch: acknowledge the
                                    // frame without re-delivering it
                                    telemetry
                                        .frames_deduped
                                        .fetch_add(1, Ordering::Relaxed);
                                    frames += 1;
                                } else if sink.deliver(frame).is_err() {
                                    err = Some(BinError::PipelineClosed);
                                } else {
                                    frames += 1;
                                }
                                batch_left = batch_left.saturating_sub(1);
                                if batch_left == 0 {
                                    skip = false;
                                }
                                self.recv.consume(used);
                                remaining -= used;
                                progressed = true;
                            }
                            Ok(EnvelopeStep::Heartbeat { used, .. }) => {
                                heartbeat = true;
                                self.recv.consume(used);
                                remaining -= used;
                                progressed = true;
                            }
                            Ok(EnvelopeStep::BatchSeq { token, seq: s, used }) => {
                                if batch_left > 0 {
                                    err = Some(BinError::Malformed(
                                        "batch-seq tag inside an open batch".to_string(),
                                    ));
                                    continue;
                                }
                                seq = Some((token, s));
                                self.recv.consume(used);
                                remaining -= used;
                                progressed = true;
                            }
                            Ok(EnvelopeStep::BatchStart { n_frames, used }) => {
                                if batch_left > 0 {
                                    err = Some(BinError::Malformed(
                                        "batch header inside an open batch".to_string(),
                                    ));
                                    continue;
                                }
                                batch_left = n_frames;
                                skip = match seq.take() {
                                    Some((token, s)) if n_frames > 0 => {
                                        !telemetry.admit_batch(token, s)
                                    }
                                    _ => false,
                                };
                                self.recv.consume(used);
                                remaining -= used;
                                progressed = true;
                            }
                            Ok(EnvelopeStep::NeedMore(need)) => {
                                if need > remaining {
                                    // the record cannot complete within
                                    // this body: malformed
                                    err = Some(BinError::Malformed(format!(
                                        "truncated frame: body ends {} bytes short",
                                        need - remaining
                                    )));
                                    continue;
                                }
                                break; // wait for more bytes
                            }
                            Err(e) => err = Some(BinError::Malformed(e.to_string())),
                        }
                    }
                    if remaining > 0 {
                        // body incomplete: park and wait for more bytes
                        self.phase = Phase::BinBody {
                            remaining,
                            keep_alive,
                            frames,
                            err,
                            batch_left,
                            seq,
                            skip,
                            heartbeat,
                        };
                        break;
                    }
                    if err.is_none() && batch_left > 0 {
                        // an HLMB header promised more frames than the
                        // body delivered — refuse rather than let a
                        // half-replicated batch look complete
                        err = Some(BinError::Malformed(format!(
                            "batch truncated: {batch_left} frames missing"
                        )));
                    }
                    if err.is_none() && seq.is_some() {
                        err = Some(BinError::Malformed(
                            "dangling batch-seq tag with no batch".to_string(),
                        ));
                    }
                    match err {
                        None if heartbeat => {
                            // heartbeat responses report the drain flag
                            // and artifact residency; probes are off the
                            // hot path, so the format! allocation is
                            // fine here (the pure frame path below
                            // stays allocation-free)
                            let body = super::heartbeat_body(frames, telemetry);
                            self.respond("200 OK", body.as_bytes(), keep_alive);
                        }
                        None => {
                            const PRE: &[u8] = b"{\"ok\":true,\"frames\":";
                            let mut body = [0u8; 41];
                            body[..PRE.len()].copy_from_slice(PRE);
                            let mut scratch = [0u8; 20];
                            let digits = fmt_u64(&mut scratch, frames);
                            let end = PRE.len() + digits.len();
                            body[PRE.len()..end].copy_from_slice(digits);
                            body[end] = b'}';
                            self.respond("200 OK", &body[..end + 1], keep_alive);
                        }
                        Some(BinError::Malformed(msg)) => {
                            let body = format!("{{\"error\":\"{msg}\"}}");
                            self.respond("400 Bad Request", body.as_bytes(), keep_alive);
                        }
                        Some(BinError::PipelineClosed) => {
                            self.respond(
                                "503 Service Unavailable",
                                b"{\"error\":\"pipeline closed\"}",
                                keep_alive,
                            );
                        }
                    }
                    progressed = true;
                }
                Phase::BufBody { route, remaining, keep_alive } => {
                    if self.recv.len() < remaining {
                        self.phase = Phase::BufBody { route, remaining, keep_alive };
                        break; // body incomplete
                    }
                    let (status, payload) =
                        route_parsed(route, &self.recv.data()[..remaining], sink, telemetry);
                    self.recv.consume(remaining);
                    self.respond(status, &payload, keep_alive);
                    progressed = true;
                }
                Phase::Drain { mut remaining } => {
                    let take = self.recv.len().min(remaining);
                    if take > 0 {
                        self.recv.consume(take);
                        remaining -= take;
                        progressed = true;
                    }
                    // bytes beyond the drain bound are abandoned — the
                    // connection is closing anyway
                    self.phase = Phase::Drain { remaining };
                    break;
                }
            }
        }
        progressed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ingest::{Frame, Modality};
    use crate::serving::ShardSender;
    use std::sync::mpsc;

    fn sink() -> (ShardSender, mpsc::Receiver<Frame>) {
        let (tx, rx) = mpsc::sync_channel(1024);
        (ShardSender::from_senders(vec![tx]), rx)
    }

    fn frame(patient: usize) -> Frame {
        Frame {
            patient,
            modality: Modality::Ecg,
            sim_time: 0.5,
            values: [0.1, 0.2, 0.3].into(),
        }
    }

    fn drain_out(conn: &mut HttpConn) -> String {
        let (a, b) = conn.out_mut().segments();
        let mut v = a.to_vec();
        v.extend_from_slice(b);
        let n = v.len();
        conn.out_mut().consume(n);
        String::from_utf8_lossy(&v).to_string()
    }

    #[test]
    fn fmt_u64_formats_boundaries() {
        let mut s = [0u8; 20];
        assert_eq!(fmt_u64(&mut s, 0), b"0");
        let mut s = [0u8; 20];
        assert_eq!(fmt_u64(&mut s, 12345), b"12345");
        let mut s = [0u8; 20];
        assert_eq!(fmt_u64(&mut s, u64::MAX), u64::MAX.to_string().as_bytes());
    }

    #[test]
    fn recv_buf_compacts_instead_of_growing() {
        let mut r = RecvBuf::with_capacity(8);
        r.extend(b"abcdefgh");
        r.consume(6);
        r.extend(b"1234"); // would overflow without compaction
        assert_eq!(r.data(), b"gh1234");
    }

    #[test]
    fn recv_buf_spare_ptr_commit_roundtrip() {
        let mut r = RecvBuf::with_capacity(16);
        r.extend(b"abc");
        r.consume(2);
        let (ptr, spare) = r.spare_ptr(8);
        assert!(spare >= 8);
        // simulate a kernel read of 4 bytes
        unsafe {
            for (i, &b) in b"wxyz".iter().enumerate() {
                ptr.add(i).write(b);
            }
            r.commit(4);
        }
        assert_eq!(r.data(), b"cwxyz");
    }

    #[test]
    fn out_ring_wraps_and_segments_cover_all_bytes() {
        let mut o = OutRing::with_capacity(64);
        o.append(&[1u8; 48]);
        o.consume(40);
        o.append(&[2u8; 40]); // wraps
        let (a, b) = o.segments();
        assert_eq!(a.len() + b.len(), 48);
        assert!(!b.is_empty(), "live region must wrap");
        let mut all = a.to_vec();
        all.extend_from_slice(b);
        assert_eq!(&all[..8], &[1u8; 8]);
        assert_eq!(&all[8..], &[2u8; 40]);
    }

    #[test]
    fn out_ring_grows_preserving_order() {
        let mut o = OutRing::with_capacity(64);
        o.append(&[1u8; 48]);
        o.consume(40);
        o.append(&[2u8; 100]); // forces growth while wrapped
        let (a, b) = o.segments();
        assert!(b.is_empty(), "growth linearizes");
        assert_eq!(&a[..8], &[1u8; 8]);
        assert_eq!(&a[8..], &[2u8; 100]);
    }

    #[test]
    fn parse_head_extracts_framing() {
        let h =
            parse_head(b"POST /ingest.bin HTTP/1.1\r\nHost: x\r\nContent-Length: 42\r\n\r\n");
        assert_eq!(h.route, Route::IngestBin);
        assert_eq!(h.content_length, 42);
        assert!(h.keep_alive);
        assert!(!h.bad_framing);
        // HTTP/1.0 must opt in to keep-alive
        let h = parse_head(b"GET /healthz HTTP/1.0\r\n\r\n");
        assert!(!h.keep_alive);
        let h = parse_head(b"GET /healthz HTTP/1.0\r\nConnection: keep-alive\r\n\r\n");
        assert!(h.keep_alive);
        // merged duplicate content-length is bad framing, not zero
        let h = parse_head(b"POST /ingest.bin HTTP/1.1\r\nContent-Length: 12, 12\r\n\r\n");
        assert!(h.bad_framing);
        let h = parse_head(b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n");
        assert!(h.bad_framing);
        assert_eq!(h.route, Route::Unknown);
    }

    #[test]
    fn parse_head_routes_artifact_ids() {
        let id = crate::registry::ArtifactId::digest_of(b"some bundle");
        let req = format!("GET /artifact/{id} HTTP/1.1\r\nHost: x\r\n\r\n");
        assert_eq!(parse_head(req.as_bytes()).route, Route::Artifact(id));
        // uppercase hex is the same id
        let req = format!("GET /artifact/{} HTTP/1.1\r\n\r\n", id.to_hex().to_uppercase());
        assert_eq!(parse_head(req.as_bytes()).route, Route::Artifact(id));
        // short, long, and non-hex ids all 404 as Unknown
        for bad in ["/artifact/abc", "/artifact/", &format!("/artifact/{id}ff")] {
            let req = format!("GET {bad} HTTP/1.1\r\n\r\n");
            assert_eq!(parse_head(req.as_bytes()).route, Route::Unknown, "{bad}");
        }
        // POST on the artifact path is not a route (the store is pull-only)
        let req = format!("POST /artifact/{id} HTTP/1.1\r\n\r\n");
        assert_eq!(parse_head(req.as_bytes()).route, Route::Unknown);
    }

    #[test]
    fn streaming_bin_body_admits_frames_at_any_fragmentation() {
        let (sink, rx) = sink();
        let tel = Telemetry::default();
        let mut body = Vec::new();
        for p in 0..3usize {
            frame(p).write_bytes(&mut body);
        }
        let mut req = format!(
            "POST /ingest.bin HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
            body.len()
        )
        .into_bytes();
        req.extend_from_slice(&body);

        // one byte at a time — worst-case fragmentation
        let mut conn = HttpConn::new();
        for &b in &req {
            conn.recv_mut().extend(&[b]);
            conn.advance(&sink, &tel);
        }
        let resp = drain_out(&mut conn);
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        assert!(resp.contains("\"frames\":3"), "{resp}");
        for p in 0..3usize {
            assert_eq!(rx.try_recv().unwrap().patient, p);
        }
        assert!(rx.try_recv().is_err());
        assert!(!conn.ready_to_close(), "keep-alive survives");
    }

    #[test]
    fn pipelined_requests_in_one_buffer_all_answer() {
        let (sink, rx) = sink();
        let tel = Telemetry::default();
        let mut stream = Vec::new();
        for p in 0..2usize {
            let mut body = Vec::new();
            frame(p).write_bytes(&mut body);
            stream.extend_from_slice(
                format!(
                    "POST /ingest.bin HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
                    body.len()
                )
                .as_bytes(),
            );
            stream.extend_from_slice(&body);
        }
        stream.extend_from_slice(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n");
        let mut conn = HttpConn::new();
        conn.recv_mut().extend(&stream);
        conn.advance(&sink, &tel);
        let resp = drain_out(&mut conn);
        assert_eq!(resp.matches("HTTP/1.1 200").count(), 3, "{resp}");
        assert!(resp.contains("\"status\":\"up\""));
        assert_eq!(rx.try_recv().unwrap().patient, 0);
        assert_eq!(rx.try_recv().unwrap().patient, 1);
    }

    #[test]
    fn streaming_batch_envelope_admits_frames_at_any_fragmentation() {
        let (sink, rx) = sink();
        let tel = Telemetry::default();
        let mut body = Vec::new();
        wire::write_batch_header(3, &mut body);
        for p in 0..3usize {
            frame(p).write_bytes(&mut body);
        }
        let mut req = format!(
            "POST /ingest.bin HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
            body.len()
        )
        .into_bytes();
        req.extend_from_slice(&body);
        let mut conn = HttpConn::new();
        for &b in &req {
            conn.recv_mut().extend(&[b]);
            conn.advance(&sink, &tel);
        }
        let resp = drain_out(&mut conn);
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        assert!(resp.contains("\"frames\":3"), "{resp}");
        for p in 0..3usize {
            assert_eq!(rx.try_recv().unwrap().patient, p);
        }
        assert!(!conn.ready_to_close(), "keep-alive survives");
    }

    #[test]
    fn truncated_batch_envelope_is_400() {
        let (sink, _rx) = sink();
        let tel = Telemetry::default();
        let mut body = Vec::new();
        wire::write_batch_header(2, &mut body);
        frame(0).write_bytes(&mut body); // 1 of the announced 2
        let mut req = format!(
            "POST /ingest.bin HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
            body.len()
        )
        .into_bytes();
        req.extend_from_slice(&body);
        let mut conn = HttpConn::new();
        conn.recv_mut().extend(&req);
        conn.advance(&sink, &tel);
        let resp = drain_out(&mut conn);
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        assert!(resp.contains("batch truncated"), "{resp}");
        assert!(!conn.ready_to_close(), "keep-alive framing survives");
    }

    #[test]
    fn heartbeat_reports_drain_state() {
        let (sink, rx) = sink();
        let tel = Telemetry::default();
        let mut conn = HttpConn::new();
        let hb = wire::encode_heartbeat(5);
        let mut req = format!(
            "POST /ingest.bin HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
            hb.len()
        )
        .into_bytes();
        req.extend_from_slice(&hb);
        conn.recv_mut().extend(&req);
        conn.advance(&sink, &tel);
        let resp = drain_out(&mut conn);
        assert!(resp.contains("\"draining\":false"), "{resp}");
        // no registry in play: zero artifacts, trivially resident
        assert!(resp.contains("\"artifacts\":0"), "{resp}");
        assert!(resp.contains("\"resident\":true"), "{resp}");
        assert!(rx.try_recv().is_err(), "a heartbeat admits no frames");
        // POST /drain flips the flag for subsequent heartbeats
        conn.recv_mut().extend(
            b"POST /drain HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n",
        );
        conn.advance(&sink, &tel);
        let resp = drain_out(&mut conn);
        assert!(resp.contains("\"draining\":true"), "{resp}");
        conn.recv_mut().extend(&req);
        conn.advance(&sink, &tel);
        let resp = drain_out(&mut conn);
        assert!(resp.contains("\"draining\":true"), "{resp}");
        // a node missing required artifacts advertises not-resident
        tel.artifacts_required.store(3, Ordering::Relaxed);
        tel.artifacts_resident.store(1, Ordering::Relaxed);
        conn.recv_mut().extend(&req);
        conn.advance(&sink, &tel);
        let resp = drain_out(&mut conn);
        assert!(resp.contains("\"artifacts\":1"), "{resp}");
        assert!(resp.contains("\"resident\":false"), "{resp}");
        // ...and flips back once the full set is resident
        tel.artifacts_resident.store(3, Ordering::Relaxed);
        conn.recv_mut().extend(&req);
        conn.advance(&sink, &tel);
        let resp = drain_out(&mut conn);
        assert!(resp.contains("\"resident\":true"), "{resp}");
    }

    #[test]
    fn malformed_bin_body_is_400_and_connection_survives() {
        let (sink, rx) = sink();
        let tel = Telemetry::default();
        let mut conn = HttpConn::new();
        let body = vec![0xDEu8; 40];
        let mut req = format!(
            "POST /ingest.bin HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
            body.len()
        )
        .into_bytes();
        req.extend_from_slice(&body);
        // follow with a pipelined healthz: the 400 must not desync
        req.extend_from_slice(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n");
        conn.recv_mut().extend(&req);
        conn.advance(&sink, &tel);
        let resp = drain_out(&mut conn);
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        assert!(resp.contains("HTTP/1.1 200"), "{resp}");
        assert!(rx.try_recv().is_err(), "nothing admitted from a corrupt body");
        assert!(!conn.ready_to_close());
    }

    #[test]
    fn bad_framing_and_oversize_close_the_connection() {
        let (sink, _rx) = sink();
        let tel = Telemetry::default();
        let mut conn = HttpConn::new();
        conn.recv_mut()
            .extend(b"POST /ingest.bin HTTP/1.1\r\nContent-Length: 12, 12\r\n\r\n");
        conn.advance(&sink, &tel);
        let resp = drain_out(&mut conn);
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        assert!(resp.contains("Connection: close"));
        assert!(conn.ready_to_close());

        let mut conn = HttpConn::new();
        let req =
            format!("POST /ingest.bin HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        conn.recv_mut().extend(req.as_bytes());
        conn.advance(&sink, &tel);
        let resp = drain_out(&mut conn);
        assert!(resp.starts_with("HTTP/1.1 413"), "{resp}");
        assert!(resp.contains("Connection: close"));
        // nothing left to drain → ready to close
        assert!(conn.ready_to_close());
    }

    #[test]
    fn oversized_head_closes_without_response() {
        let (sink, _rx) = sink();
        let tel = Telemetry::default();
        let mut conn = HttpConn::new();
        // endless header bytes, never a blank line
        let chunk = vec![b'a'; 64 * 1024];
        for _ in 0..20 {
            conn.recv_mut().extend(&chunk);
            conn.advance(&sink, &tel);
        }
        assert!(conn.ready_to_close());
        assert!(conn.out_mut().is_empty(), "no response for a header flood");
    }
}
