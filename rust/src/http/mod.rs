//! Minimal HTTP/1.1 ingest server (paper Fig. 4: "the HTTP server that
//! simplifies data ingest into the serving system").
//!
//! Endpoints:
//! * `POST /ingest`      — JSON [`Frame`] body; NaN / non-finite
//!   payloads are rejected with `400` at the boundary.
//! * `POST /ingest.bin`  — binary body of one or more back-to-back
//!   wire-encoded frames (see below); the hot path at 25k frames/s.
//! * `GET /stats`        — telemetry snapshot (JSON).
//! * `GET /healthz`      — liveness.
//!
//! Hand-rolled on std TCP with a thread per connection: the request
//! path needs exactly these routes and zero framework overhead.
//! Connections are **keep-alive by default** (HTTP/1.1): a bedside
//! load generator pays one TCP handshake per stream, not one per
//! frame. `Connection: close` (or HTTP/1.0 without an explicit
//! keep-alive) closes after the response. Request bodies are bounded
//! by [`MAX_BODY_BYTES`]; oversized requests get `413` and the
//! connection is closed (the unread body would desynchronise framing).
//! The thread-per-connection spawn is gated by an atomic connection
//! count ([`HttpConfig::max_connections`]): past the limit the accept
//! loop answers `503 Service Unavailable` + `Connection: close`
//! without spawning anything, so a connection flood cannot exhaust the
//! serving box.
//!
//! Admitted frames are routed into the sharded aggregation front-end
//! through a [`ShardSender`] (`patient % shards`, bounded per-shard
//! queues): many connection threads ingest concurrently without any
//! single channel seeing every frame.
//!
//! ## Binary wire format (`/ingest.bin`)
//!
//! Each frame is self-delimiting, little-endian throughout (full
//! reference: [`crate::ingest::wire`]):
//!
//! ```text
//!  offset  size  field
//!  0       4     magic     = b"HLM1"
//!  4       1     version   = 1
//!  5       1     modality  (0 = ecg, 1 = vitals, 2 = labs)
//!  6       2     reserved  = 0
//!  8       8     patient   (u64)
//!  16      8     sim_time  (f64, finite)
//!  24      4     n_values  (u32)
//!  28      4·n   values    (f32 each, finite)
//! ```
//!
//! A body may concatenate any number of frames; the route decodes all
//! of them or rejects the whole body with `400` (malformed, truncated,
//! or non-finite input — nothing partial is admitted). The response is
//! `{"ok":true,"frames":N}`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::ingest::{wire, Frame};
use crate::json::Value;
use crate::serving::{ShardSender, Telemetry};
use crate::{Error, Result};

/// Largest accepted request body; larger requests are refused with
/// `413 Payload Too Large`. A one-second 64-bed binary burst
/// (64 × 251 frames ≈ 400 KiB) fits with an order of magnitude to
/// spare.
pub const MAX_BODY_BYTES: usize = 4 << 20;

/// Server tunables.
#[derive(Debug, Clone, Copy)]
pub struct HttpConfig {
    /// Concurrent-connection cap: connection `max_connections + 1`
    /// gets `503 Service Unavailable` + `Connection: close` instead of
    /// a handler thread. Plenty for 100 keep-alive bedside streams,
    /// small enough that a flood cannot exhaust the 64-bed box.
    pub max_connections: usize,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig { max_connections: 256 }
    }
}

/// Running server handle; the listener thread stops accepting when this
/// is dropped (connections in flight finish their current request).
pub struct HttpServer {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock accept() with a dummy connection
        let _ = TcpStream::connect(self.addr);
    }
}

/// Decrements the live-connection gate when a handler thread exits,
/// however it exits.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Start the ingest server with default [`HttpConfig`]; admitted frames
/// are routed into the sharded aggregation plane through `sink`. Bind
/// with port 0 to auto-pick.
pub fn serve(addr: &str, sink: ShardSender, telemetry: Arc<Telemetry>) -> Result<HttpServer> {
    serve_with(addr, sink, telemetry, HttpConfig::default())
}

/// [`serve`] with explicit tunables.
pub fn serve_with(
    addr: &str,
    sink: ShardSender,
    telemetry: Arc<Telemetry>,
    cfg: HttpConfig,
) -> Result<HttpServer> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let active = Arc::new(AtomicUsize::new(0));
    std::thread::Builder::new()
        .name("http-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(mut stream) = stream else { continue };
                // connection gate: refuse before spawning. The accept
                // loop is the only incrementer, so add-then-check is
                // race-free; handler threads decrement via ConnGuard.
                if active.fetch_add(1, Ordering::Relaxed) >= cfg.max_connections {
                    active.fetch_sub(1, Ordering::Relaxed);
                    // best-effort refusal: bound the write so a
                    // non-reading client cannot stall the accept loop
                    let _ = stream
                        .set_write_timeout(Some(std::time::Duration::from_millis(250)));
                    if write_response(
                        &mut stream,
                        "503 Service Unavailable",
                        "{\"error\":\"connection limit reached\"}",
                        false,
                    )
                    .is_ok()
                    {
                        // a flooding client usually wrote its request
                        // right after connect; closing with those bytes
                        // unread makes the kernel RST the connection,
                        // which can discard the queued 503 before the
                        // client reads it (same failure mode the 413
                        // path drains for). Drain what is already
                        // buffered — non-blocking, so the accept loop
                        // never waits on a silent peer.
                        let _ = stream.set_nonblocking(true);
                        let mut sink = [0u8; 4096];
                        let mut drained = 0usize;
                        while drained < 64 * 1024 {
                            match stream.read(&mut sink) {
                                Ok(0) | Err(_) => break,
                                Ok(n) => drained += n,
                            }
                        }
                    }
                    continue;
                }
                let guard = ConnGuard(Arc::clone(&active));
                let tx = sink.clone();
                let tel = Arc::clone(&telemetry);
                std::thread::spawn(move || {
                    let _guard = guard;
                    let _ = handle_connection(stream, tx, tel);
                });
            }
        })
        .map_err(Error::Io)?;
    Ok(HttpServer { addr: local, stop })
}

fn handle_connection(
    mut stream: TcpStream,
    frame_tx: ShardSender,
    telemetry: Arc<Telemetry>,
) -> Result<()> {
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    loop {
        // read until end of headers
        let header_end = loop {
            if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
                break pos + 4;
            }
            let mut chunk = [0u8; 4096];
            let n = stream.read(&mut chunk)?;
            if n == 0 {
                return Ok(()); // connection closed
            }
            buf.extend_from_slice(&chunk[..n]);
            if buf.len() > 1 << 20 {
                return Err(Error::serving("request headers too large"));
            }
        };
        let head = String::from_utf8_lossy(&buf[..header_end]).to_string();
        let mut lines = head.lines();
        let request_line = lines.next().unwrap_or_default().to_string();
        let mut content_length: usize = 0;
        let mut bad_framing = false;
        let mut close_requested = false;
        let mut keep_alive_requested = false;
        for l in lines {
            let Some((k, v)) = l.split_once(':') else { continue };
            let v = v.trim();
            if k.eq_ignore_ascii_case("content-length") {
                match v.parse() {
                    Ok(n) => content_length = n,
                    // an unparseable length (e.g. duplicate headers
                    // merged to "123, 123") must not default to 0: the
                    // body bytes would be re-parsed as the next request
                    // on this keep-alive connection
                    Err(_) => bad_framing = true,
                }
            } else if k.eq_ignore_ascii_case("transfer-encoding") {
                bad_framing = true; // chunked bodies are unsupported
            } else if k.eq_ignore_ascii_case("connection") {
                close_requested = v.eq_ignore_ascii_case("close");
                keep_alive_requested = v.eq_ignore_ascii_case("keep-alive");
            }
        }
        // HTTP/1.1 defaults to keep-alive; HTTP/1.0 must opt in
        let http10 = request_line.ends_with("HTTP/1.0");
        let keep_alive = !close_requested && (!http10 || keep_alive_requested);

        // body framing we cannot trust → 400 and close (we don't know
        // where this request's body ends, so the connection cannot be
        // reused)
        if bad_framing {
            write_response(
                &mut stream,
                "400 Bad Request",
                "{\"error\":\"unsupported or malformed body framing\"}",
                false,
            )?;
            return Ok(());
        }

        // refuse oversized bodies before buffering them; the unread
        // body bytes would desync request framing, so close afterwards
        if content_length > MAX_BODY_BYTES {
            write_response(
                &mut stream,
                "413 Payload Too Large",
                &format!("{{\"error\":\"body exceeds {MAX_BODY_BYTES} bytes\"}}"),
                false,
            )?;
            // drain (bounded) what the client already sent: closing
            // with unread data in the receive queue makes the kernel
            // RST the connection, which can discard the queued 413
            // before the client reads it
            let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(250)));
            let mut sink = [0u8; 4096];
            let mut drained = buf.len().saturating_sub(header_end);
            while drained < content_length.min(2 * MAX_BODY_BYTES) {
                match stream.read(&mut sink) {
                    Ok(0) | Err(_) => break,
                    Ok(n) => drained += n,
                }
            }
            return Ok(());
        }
        // read the body
        while buf.len() < header_end + content_length {
            let mut chunk = [0u8; 4096];
            let n = stream.read(&mut chunk)?;
            if n == 0 {
                return Err(Error::serving("truncated body"));
            }
            buf.extend_from_slice(&chunk[..n]);
        }
        let body = buf[header_end..header_end + content_length].to_vec();
        buf.drain(..header_end + content_length);

        let (status, payload) = route(&request_line, &body, &frame_tx, &telemetry);
        write_response(&mut stream, status, &payload, keep_alive)?;
        if !keep_alive {
            return Ok(());
        }
    }
}

fn write_response(
    stream: &mut TcpStream,
    status: &str,
    payload: &str,
    keep_alive: bool,
) -> Result<()> {
    let connection = if keep_alive { "keep-alive" } else { "close" };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {connection}\r\n\r\n",
        payload.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.write_all(payload.as_bytes())?;
    Ok(())
}

fn route(
    request_line: &str,
    body: &[u8],
    frame_tx: &ShardSender,
    telemetry: &Telemetry,
) -> (&'static str, String) {
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    match (method, path) {
        ("POST", "/ingest") => {
            let parsed = std::str::from_utf8(body)
                .map_err(|_| Error::json("body not utf-8"))
                .and_then(Value::parse)
                .and_then(|v| Frame::from_json(&v));
            match parsed {
                Ok(frame) => {
                    if frame_tx.send(frame).is_ok() {
                        ("200 OK", "{\"ok\":true}".to_string())
                    } else {
                        ("503 Service Unavailable", "{\"error\":\"pipeline closed\"}".to_string())
                    }
                }
                Err(e) => ("400 Bad Request", format!("{{\"error\":\"{e}\"}}")),
            }
        }
        ("POST", "/ingest.bin") => match wire::decode_stream(body) {
            Ok(frames) => {
                let n = frames.len();
                for frame in frames {
                    if frame_tx.send(frame).is_err() {
                        return (
                            "503 Service Unavailable",
                            "{\"error\":\"pipeline closed\"}".to_string(),
                        );
                    }
                }
                ("200 OK", format!("{{\"ok\":true,\"frames\":{n}}}"))
            }
            Err(e) => ("400 Bad Request", format!("{{\"error\":\"{e}\"}}")),
        },
        ("GET", "/stats") => ("200 OK", telemetry.snapshot().to_json().to_string()),
        ("GET", "/healthz") => ("200 OK", "{\"status\":\"up\"}".to_string()),
        _ => ("404 Not Found", "{\"error\":\"no such route\"}".to_string()),
    }
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Keep-alive binary ingest client for load generators and `exp/`
/// drivers: one TCP connection per stream, one `POST /ingest.bin`
/// request per batch of frames, one encode buffer reused across
/// batches.
pub struct IngestClient {
    stream: TcpStream,
    body: Vec<u8>,
    resp: Vec<u8>,
}

impl IngestClient {
    pub fn connect(addr: SocketAddr) -> Result<IngestClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(IngestClient { stream, body: Vec::with_capacity(16 * 1024), resp: Vec::new() })
    }

    /// POST one batch of frames as a single binary body and wait for
    /// the response. Errors on transport failure or a non-2xx status.
    pub fn send_frames(&mut self, frames: &[Frame]) -> Result<()> {
        self.body.clear();
        for f in frames {
            f.write_bytes(&mut self.body);
        }
        let head = format!(
            "POST /ingest.bin HTTP/1.1\r\nHost: ingest\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n",
            self.body.len()
        );
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(&self.body)?;
        let status = self.read_response()?;
        if (200..300).contains(&status) {
            Ok(())
        } else {
            Err(Error::serving(format!("ingest server replied {status}")))
        }
    }

    pub fn send_frame(&mut self, frame: &Frame) -> Result<()> {
        let one = std::slice::from_ref(frame);
        self.send_frames(one)
    }

    /// Read one full response (headers + content-length body) off the
    /// connection so the next request starts on a clean framing
    /// boundary; returns the status code.
    fn read_response(&mut self) -> Result<u16> {
        self.resp.clear();
        let mut chunk = [0u8; 2048];
        let header_end = loop {
            if let Some(pos) = find_subslice(&self.resp, b"\r\n\r\n") {
                break pos + 4;
            }
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                return Err(Error::serving("ingest server closed mid-response"));
            }
            self.resp.extend_from_slice(&chunk[..n]);
        };
        let head = String::from_utf8_lossy(&self.resp[..header_end]).to_string();
        let status: u16 = head
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::serving("malformed response status line"))?;
        let content_length: usize = head
            .lines()
            .filter_map(|l| {
                let (k, v) = l.split_once(':')?;
                k.eq_ignore_ascii_case("content-length")
                    .then(|| v.trim().parse().ok())
                    .flatten()
            })
            .next()
            .unwrap_or(0);
        while self.resp.len() < header_end + content_length {
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                return Err(Error::serving("ingest server closed mid-body"));
            }
            self.resp.extend_from_slice(&chunk[..n]);
        }
        Ok(status)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ingest::Modality;
    use std::sync::mpsc;

    /// Single-shard sink: every admitted frame lands on one receiver.
    fn test_server() -> (HttpServer, mpsc::Receiver<Frame>) {
        let (tx, rx) = mpsc::sync_channel(1024);
        let tel = Arc::new(Telemetry::default());
        (serve("127.0.0.1:0", ShardSender::from_senders(vec![tx]), tel).unwrap(), rx)
    }

    #[test]
    fn ingest_roundtrip_over_tcp() {
        let (server, rx) = test_server();
        let frame = Frame {
            patient: 3,
            modality: Modality::Ecg,
            sim_time: 1.5,
            values: [0.1, 0.2, 0.3].into(),
        };
        let body = frame.to_json().to_string();
        let mut s = TcpStream::connect(server.addr).unwrap();
        let req = format!(
            "POST /ingest HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        s.write_all(req.as_bytes()).unwrap();
        let mut resp = vec![0u8; 1024];
        let n = s.read(&mut resp).unwrap();
        assert!(String::from_utf8_lossy(&resp[..n]).starts_with("HTTP/1.1 200"));
        let got = rx.recv().unwrap();
        assert_eq!(got.patient, 3);
        assert_eq!(got.values.len(), 3);
    }

    #[test]
    fn binary_ingest_multi_frame_keep_alive() {
        let (server, rx) = test_server();
        let mut client = IngestClient::connect(server.addr).unwrap();
        // two requests over ONE connection, multi-frame bodies
        for round in 0..2u64 {
            let frames: Vec<Frame> = (0..5usize)
                .map(|i| Frame {
                    patient: i,
                    modality: Modality::Ecg,
                    sim_time: round as f64 + i as f64 * 0.004,
                    values: [0.5, -0.25, 1.0].into(),
                })
                .collect();
            client.send_frames(&frames).unwrap();
            for i in 0..5usize {
                let got = rx.recv().unwrap();
                assert_eq!(got.patient, i, "round {round}");
                assert_eq!(got.values, vec![0.5, -0.25, 1.0]);
            }
        }
    }

    #[test]
    fn binary_ingest_rejects_corrupt_and_nan_bodies() {
        let (server, rx) = test_server();
        let frame = Frame {
            patient: 1,
            modality: Modality::Vitals,
            sim_time: 2.0,
            values: crate::ingest::FrameValues::from_slice(&[f32::NAN]).unwrap(),
        };
        let mut client = IngestClient::connect(server.addr).unwrap();
        // NaN payload → 400, nothing admitted
        assert!(client.send_frames(std::slice::from_ref(&frame)).is_err());
        // corrupt magic → 400 (reconnect: a 400 keeps the connection,
        // but exercise a fresh one anyway)
        let mut s = TcpStream::connect(server.addr).unwrap();
        let body = vec![0xDEu8; 40];
        let req = format!(
            "POST /ingest.bin HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
            body.len()
        );
        s.write_all(req.as_bytes()).unwrap();
        s.write_all(&body).unwrap();
        let mut resp = vec![0u8; 1024];
        let n = s.read(&mut resp).unwrap();
        assert!(String::from_utf8_lossy(&resp[..n]).starts_with("HTTP/1.1 400"));
        assert!(rx.try_recv().is_err(), "no frame may be admitted");
    }

    #[test]
    fn json_nan_payload_is_400() {
        let (server, rx) = test_server();
        let mut s = TcpStream::connect(server.addr).unwrap();
        // 1e39 overflows f32 to +inf — must be refused at the boundary
        let body = r#"{"patient":1,"modality":"ecg","sim_time":0.0,"values":[1e39]}"#;
        let req = format!(
            "POST /ingest HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        s.write_all(req.as_bytes()).unwrap();
        let mut resp = vec![0u8; 1024];
        let n = s.read(&mut resp).unwrap();
        assert!(String::from_utf8_lossy(&resp[..n]).starts_with("HTTP/1.1 400"));
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn oversized_body_is_413_and_connection_closes() {
        let (server, _rx) = test_server();
        let mut s = TcpStream::connect(server.addr).unwrap();
        let req = format!(
            "POST /ingest.bin HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        s.write_all(req.as_bytes()).unwrap();
        let text = read_full_response(&mut s);
        assert!(text.starts_with("HTTP/1.1 413"), "{text}");
        assert!(text.contains("Connection: close"));
        // server closed its side: further reads hit EOF
        let mut rest = [0u8; 64];
        assert_eq!(s.read(&mut rest).unwrap_or(0), 0);
    }

    /// Read headers + full content-length body (may span TCP segments).
    fn read_full_response(s: &mut TcpStream) -> String {
        let mut buf = Vec::new();
        let mut chunk = [0u8; 2048];
        loop {
            let n = s.read(&mut chunk).unwrap();
            if n == 0 {
                break;
            }
            buf.extend_from_slice(&chunk[..n]);
            if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
                let head = String::from_utf8_lossy(&buf[..pos]).to_string();
                let clen: usize = head
                    .lines()
                    .filter_map(|l| {
                        let (k, v) = l.split_once(':')?;
                        k.eq_ignore_ascii_case("content-length")
                            .then(|| v.trim().parse().ok())
                            .flatten()
                    })
                    .next()
                    .unwrap_or(0);
                if buf.len() >= pos + 4 + clen {
                    break;
                }
            }
        }
        String::from_utf8_lossy(&buf).to_string()
    }

    #[test]
    fn connection_flood_is_rejected_with_503_and_recovers() {
        let (tx, _rx) = mpsc::sync_channel(16);
        let tel = Arc::new(Telemetry::default());
        let server = serve_with(
            "127.0.0.1:0",
            ShardSender::from_senders(vec![tx]),
            tel,
            HttpConfig { max_connections: 2 },
        )
        .unwrap();

        // two keep-alive connections occupy the whole budget; a request
        // each proves they were accepted (not just queued in the kernel)
        let mut held = Vec::new();
        for _ in 0..2 {
            let mut s = TcpStream::connect(server.addr).unwrap();
            s.write_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
            let mut resp = [0u8; 512];
            let n = s.read(&mut resp).unwrap();
            assert!(String::from_utf8_lossy(&resp[..n]).starts_with("HTTP/1.1 200"));
            held.push(s);
        }

        // the third connection is refused at the accept gate
        let mut s3 = TcpStream::connect(server.addr).unwrap();
        let text = read_full_response(&mut s3);
        assert!(text.starts_with("HTTP/1.1 503"), "{text}");
        assert!(text.contains("Connection: close"), "{text}");
        assert!(text.contains("connection limit"), "{text}");

        // releasing a slot lets new connections in again (the handler
        // notices the close asynchronously, so poll briefly)
        drop(held.pop());
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            let mut s = TcpStream::connect(server.addr).unwrap();
            s.write_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n")
                .unwrap();
            let text = read_full_response(&mut s);
            if text.starts_with("HTTP/1.1 200") {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "freed connection slot never became available: {text}"
            );
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
    }

    #[test]
    fn malformed_content_length_is_400_and_closes() {
        let (server, rx) = test_server();
        let mut s = TcpStream::connect(server.addr).unwrap();
        // a proxy merging duplicate Content-Length headers produces
        // exactly this shape; trusting "0" would desync the connection
        let req = "POST /ingest.bin HTTP/1.1\r\nHost: x\r\nContent-Length: 12, 12\r\n\r\n";
        s.write_all(req.as_bytes()).unwrap();
        let text = read_full_response(&mut s);
        assert!(text.starts_with("HTTP/1.1 400"), "{text}");
        assert!(text.contains("Connection: close"));
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn stats_health_and_404_endpoints() {
        let (server, _rx) = test_server();
        for (path, expect) in [("/healthz", "up"), ("/stats", "e2e_p95"), ("/nope", "no such")] {
            let mut s = TcpStream::connect(server.addr).unwrap();
            let req = format!("GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n");
            s.write_all(req.as_bytes()).unwrap();
            let text = read_full_response(&mut s);
            assert!(text.contains(expect), "{path}: {text}");
        }
    }

    #[test]
    fn malformed_body_is_400() {
        let (server, _rx) = test_server();
        let mut s = TcpStream::connect(server.addr).unwrap();
        let body = "{not json";
        let req = format!(
            "POST /ingest HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        s.write_all(req.as_bytes()).unwrap();
        let mut resp = vec![0u8; 1024];
        let n = s.read(&mut resp).unwrap();
        assert!(String::from_utf8_lossy(&resp[..n]).starts_with("HTTP/1.1 400"));
    }

    #[test]
    fn find_subslice_works() {
        assert_eq!(find_subslice(b"abc\r\n\r\ndef", b"\r\n\r\n"), Some(3));
        assert_eq!(find_subslice(b"abc", b"xyz"), None);
    }
}
