//! Minimal HTTP/1.1 ingest server (paper Fig. 4: "the HTTP server that
//! simplifies data ingest into the serving system").
//!
//! Endpoints:
//! * `POST /ingest`  — JSON [`Frame`] body; forwarded to the pipeline's
//!   aggregator stage.
//! * `GET /stats`    — telemetry snapshot (JSON).
//! * `GET /healthz`  — liveness.
//!
//! Hand-rolled on std TCP with a thread per connection: the request
//! path needs exactly these three routes and zero framework overhead.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use crate::ingest::Frame;
use crate::json::Value;
use crate::serving::Telemetry;
use crate::{Error, Result};

/// Running server handle; the listener thread stops accepting when this
/// is dropped (connections in flight finish their current request).
pub struct HttpServer {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock accept() with a dummy connection
        let _ = TcpStream::connect(self.addr);
    }
}

/// Start the ingest server; frames are forwarded to `frame_tx`.
/// Bind with port 0 to auto-pick.
pub fn serve(
    addr: &str,
    frame_tx: mpsc::Sender<Frame>,
    telemetry: Arc<Telemetry>,
) -> Result<HttpServer> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    std::thread::Builder::new()
        .name("http-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let tx = frame_tx.clone();
                let tel = Arc::clone(&telemetry);
                std::thread::spawn(move || {
                    let _ = handle_connection(stream, tx, tel);
                });
            }
        })
        .map_err(Error::Io)?;
    Ok(HttpServer { addr: local, stop })
}

fn handle_connection(
    mut stream: TcpStream,
    frame_tx: mpsc::Sender<Frame>,
    telemetry: Arc<Telemetry>,
) -> Result<()> {
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    loop {
        // read until end of headers
        let header_end = loop {
            if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
                break pos + 4;
            }
            let mut chunk = [0u8; 4096];
            let n = stream.read(&mut chunk)?;
            if n == 0 {
                return Ok(()); // connection closed
            }
            buf.extend_from_slice(&chunk[..n]);
            if buf.len() > 1 << 20 {
                return Err(Error::serving("request headers too large"));
            }
        };
        let head = String::from_utf8_lossy(&buf[..header_end]).to_string();
        let mut lines = head.lines();
        let request_line = lines.next().unwrap_or_default().to_string();
        let content_length: usize = lines
            .filter_map(|l| {
                let (k, v) = l.split_once(':')?;
                if k.eq_ignore_ascii_case("content-length") {
                    v.trim().parse().ok()
                } else {
                    None
                }
            })
            .next()
            .unwrap_or(0);
        // read the body
        while buf.len() < header_end + content_length {
            let mut chunk = [0u8; 4096];
            let n = stream.read(&mut chunk)?;
            if n == 0 {
                return Err(Error::serving("truncated body"));
            }
            buf.extend_from_slice(&chunk[..n]);
        }
        let body = buf[header_end..header_end + content_length].to_vec();
        buf.drain(..header_end + content_length);

        let (status, payload) = route(&request_line, &body, &frame_tx, &telemetry);
        let response = format!(
            "HTTP/1.1 {status}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n",
            payload.len()
        );
        stream.write_all(response.as_bytes())?;
        stream.write_all(payload.as_bytes())?;
    }
}

fn route(
    request_line: &str,
    body: &[u8],
    frame_tx: &mpsc::Sender<Frame>,
    telemetry: &Telemetry,
) -> (&'static str, String) {
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    match (method, path) {
        ("POST", "/ingest") => {
            let parsed = std::str::from_utf8(body)
                .map_err(|_| Error::json("body not utf-8"))
                .and_then(Value::parse)
                .and_then(|v| Frame::from_json(&v));
            match parsed {
                Ok(frame) => {
                    if frame_tx.send(frame).is_ok() {
                        ("200 OK", "{\"ok\":true}".to_string())
                    } else {
                        ("503 Service Unavailable", "{\"error\":\"pipeline closed\"}".to_string())
                    }
                }
                Err(e) => ("400 Bad Request", format!("{{\"error\":\"{e}\"}}")),
            }
        }
        ("GET", "/stats") => ("200 OK", telemetry.snapshot().to_json().to_string()),
        ("GET", "/healthz") => ("200 OK", "{\"status\":\"up\"}".to_string()),
        _ => ("404 Not Found", "{\"error\":\"no such route\"}".to_string()),
    }
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ingest::Modality;

    #[test]
    fn ingest_roundtrip_over_tcp() {
        let (tx, rx) = mpsc::channel();
        let tel = Arc::new(Telemetry::default());
        let server = serve("127.0.0.1:0", tx, tel).unwrap();
        let frame = Frame {
            patient: 3,
            modality: Modality::Ecg,
            sim_time: 1.5,
            values: vec![0.1, 0.2, 0.3],
        };
        let body = frame.to_json().to_string();
        let mut s = TcpStream::connect(server.addr).unwrap();
        let req = format!(
            "POST /ingest HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        s.write_all(req.as_bytes()).unwrap();
        let mut resp = vec![0u8; 1024];
        let n = s.read(&mut resp).unwrap();
        assert!(String::from_utf8_lossy(&resp[..n]).starts_with("HTTP/1.1 200"));
        let got = rx.recv().unwrap();
        assert_eq!(got.patient, 3);
        assert_eq!(got.values.len(), 3);
    }

    /// Read headers + full content-length body (may span TCP segments).
    fn read_full_response(s: &mut TcpStream) -> String {
        let mut buf = Vec::new();
        let mut chunk = [0u8; 2048];
        loop {
            let n = s.read(&mut chunk).unwrap();
            if n == 0 {
                break;
            }
            buf.extend_from_slice(&chunk[..n]);
            if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
                let head = String::from_utf8_lossy(&buf[..pos]).to_string();
                let clen: usize = head
                    .lines()
                    .filter_map(|l| {
                        let (k, v) = l.split_once(':')?;
                        k.eq_ignore_ascii_case("content-length")
                            .then(|| v.trim().parse().ok())
                            .flatten()
                    })
                    .next()
                    .unwrap_or(0);
                if buf.len() >= pos + 4 + clen {
                    break;
                }
            }
        }
        String::from_utf8_lossy(&buf).to_string()
    }

    #[test]
    fn stats_health_and_404_endpoints() {
        let (tx, _rx) = mpsc::channel();
        let tel = Arc::new(Telemetry::default());
        let server = serve("127.0.0.1:0", tx, tel).unwrap();
        for (path, expect) in [("/healthz", "up"), ("/stats", "e2e_p95"), ("/nope", "no such")] {
            let mut s = TcpStream::connect(server.addr).unwrap();
            let req = format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n");
            s.write_all(req.as_bytes()).unwrap();
            let text = read_full_response(&mut s);
            assert!(text.contains(expect), "{path}: {text}");
        }
    }

    #[test]
    fn malformed_body_is_400() {
        let (tx, _rx) = mpsc::channel();
        let tel = Arc::new(Telemetry::default());
        let server = serve("127.0.0.1:0", tx, tel).unwrap();
        let mut s = TcpStream::connect(server.addr).unwrap();
        let body = "{not json";
        let req = format!(
            "POST /ingest HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        s.write_all(req.as_bytes()).unwrap();
        let mut resp = vec![0u8; 1024];
        let n = s.read(&mut resp).unwrap();
        assert!(String::from_utf8_lossy(&resp[..n]).starts_with("HTTP/1.1 400"));
    }

    #[test]
    fn find_subslice_works() {
        assert_eq!(find_subslice(b"abc\r\n\r\ndef", b"\r\n\r\n"), Some(3));
        assert_eq!(find_subslice(b"abc", b"xyz"), None);
    }
}
