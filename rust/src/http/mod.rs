//! Minimal HTTP/1.1 ingest server (paper Fig. 4: "the HTTP server that
//! simplifies data ingest into the serving system").
//!
//! Endpoints:
//! * `POST /ingest`      — JSON [`Frame`] body; NaN / non-finite
//!   payloads are rejected with `400` at the boundary.
//! * `POST /ingest.bin`  — binary body of one or more back-to-back
//!   wire-encoded frames (see below); the hot path at 25k frames/s.
//!   Also accepts the router envelope records: `HLMB` frame-batch
//!   headers, `HLMS` batch-sequence tags (idempotency: a retried
//!   batch the node already admitted is acknowledged but not
//!   re-delivered — counted in `frames_deduped`), and `HLMH`
//!   heartbeats (a heartbeat response reports whether this node is
//!   draining).
//! * `POST /drain`       — operator-initiated rolling-upgrade drain:
//!   sets the `draining` flag so heartbeat responses advertise it and
//!   the router re-homes this peer's patients before it exits.
//! * `GET /artifact/<id>` — content-addressed model bundle by 64-hex
//!   [`crate::registry::ArtifactId`], served from the node's local
//!   registry store (404 when no store is installed or the id is
//!   absent). This is the peer-to-peer distribution edge: a cold node
//!   points an [`crate::registry::HttpRegistry`] here and pulls every
//!   bundle the active member set requires, digest-verifying each one.
//! * `GET /stats`        — telemetry snapshot (JSON).
//! * `GET /healthz`      — liveness.
//!
//! Heartbeat (`HLMH`) responses carry
//! `{"ok":true,"frames":N,"draining":b,"artifacts":A,"resident":r}`:
//! `A` is how many required artifacts the node holds and `r` whether
//! the full required set is resident — the router refuses to (re)admit
//! a peer that answers `"resident":false`.
//!
//! ## The router tier above the edge
//!
//! A `holmes route` process stacks one more tier on top of this one
//! ([`crate::router`]): it owns the ingest edge, hashes each decoded
//! frame's patient id on a consistent ring, and forwards it over a
//! persistent link to the owning `holmes serve` peer — which runs this
//! same edge:
//!
//! ```text
//!   bedside monitors ──► router edge (this module, sink = RouterSink)
//!                              │ ring.route(patient) → peer link
//!                              ▼ HLMB batches over /ingest.bin
//!                        serve peers (this module, sink = ShardSender)
//!                              ▼ shards → lanes → completer
//! ```
//!
//! The edge itself is **sink-generic** ([`FrameSink`]): the router's
//! forwarding sink and a serve node's local shard sink are
//! interchangeable behind the same byte-identical protocol core.
//!
//! ## Two edges, one protocol core
//!
//! On Linux the edge is **event-driven** ([`edge`]): a fixed pool of
//! epoll readiness loops (`--edge-threads`, default cores/4) shares one
//! nonblocking listener via `EPOLLEXCLUSIVE`, and each loop owns a slab
//! of connection states driven edge-triggered:
//!
//! ```text
//!            shared nonblocking listener (EPOLLEXCLUSIVE)
//!          ┌──────────────┼──────────────┐
//!          ▼              ▼              ▼
//!     edge loop 0    edge loop 1    edge loop k      (epoll_wait)
//!     ┌ slab of HttpConn states, generation-tagged tokens
//!     │  readv ──► RecvBuf (contiguous) ──► incremental parse
//!     │                │ /ingest.bin: in-place wire decode
//!     │                ▼   (Frame is Copy — no body Vec, no alloc)
//!     │           ShardSender (patient % shards)
//!     │  OutRing ◄── responses; flushed by writev (≤ 2 segments)
//!     └ idle sweep: read_timeout reaps stalled half-requests
//! ```
//!
//! Thread count follows the flag, not the connection count: 10k
//! mostly-idle keep-alive bedside monitors cost slab slots and ring
//! buffers, not OS threads. Everywhere else (and as the `legacy_`
//! bench replica, [`serve_legacy_with`]) the original
//! thread-per-connection edge remains: one blocking handler thread per
//! accepted connection, same routes, same status/framing semantics,
//! same [`conn::parse_head`] protocol core — the two edges are
//! byte-compatible on the wire and bit-identical downstream.
//!
//! Connections are **keep-alive by default** (HTTP/1.1): a bedside
//! load generator pays one TCP handshake per stream, not one per
//! frame. `Connection: close` (or HTTP/1.0 without an explicit
//! keep-alive) closes after the response. Request bodies are bounded
//! by [`MAX_BODY_BYTES`]; oversized requests get `413` and the
//! connection is closed (the unread body would desynchronise framing).
//! Both edges gate admission on the same live-connection counter
//! ([`HttpConfig::max_connections`], surfaced as the `conns_active`
//! gauge): past the limit the connection is answered `503 Service
//! Unavailable` + `Connection: close` without dedicating any state to
//! it, so a connection flood cannot exhaust the serving box. A client
//! that stalls mid-request is reaped after
//! [`HttpConfig::read_timeout`] (`conns_reaped`) — the slow-loris
//! guard.
//!
//! Admitted frames are routed into the sharded aggregation front-end
//! through a [`ShardSender`] (`patient % shards`, bounded per-shard
//! queues): many connections ingest concurrently without any single
//! channel seeing every frame.
//!
//! ## Binary wire format (`/ingest.bin`)
//!
//! Each frame is self-delimiting, little-endian throughout (full
//! reference: [`crate::ingest::wire`]):
//!
//! ```text
//!  offset  size  field
//!  0       4     magic     = b"HLM1"
//!  4       1     version   = 1
//!  5       1     modality  (0 = ecg, 1 = vitals, 2 = labs)
//!  6       2     reserved  = 0
//!  8       8     patient   (u64)
//!  16      8     sim_time  (f64, finite)
//!  24      4     n_values  (u32)
//!  28      4·n   values    (f32 each, finite)
//! ```
//!
//! A body may concatenate any number of frames. The fallback edge
//! decodes all of them or rejects the whole body with `400` (nothing
//! partial admitted); the event-driven edge decodes **streaming, in
//! place** from the connection's receive buffer — frames preceding a
//! malformed byte are already admitted when the `400` goes out (the
//! response still reports the error, and the connection survives).
//! The success response is `{"ok":true,"frames":N}` on both edges.

pub mod conn;
#[cfg(target_os = "linux")]
mod edge;
#[cfg(target_os = "linux")]
pub mod sys;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::ingest::{wire, Frame};
use crate::json::Value;
use crate::serving::{ShardSender, Telemetry};
use crate::{Error, Result};

/// Destination for decoded ingest frames. The edge is generic over its
/// sink so a router process (forwarding to remote peers through
/// `crate::router::RouterSink`) and a serve node (local aggregation
/// shards, [`ShardSender`]) share one edge implementation.
pub trait FrameSink: Clone + Send + 'static {
    /// Deliver one admitted frame. `Err` means the downstream is gone
    /// and the edge answers `503`.
    fn deliver(&self, frame: Frame) -> Result<()>;
}

impl FrameSink for ShardSender {
    fn deliver(&self, frame: Frame) -> Result<()> {
        self.send(frame)
    }
}

/// Largest accepted request body; larger requests are refused with
/// `413 Payload Too Large`. A one-second 64-bed binary burst
/// (64 × 251 frames ≈ 400 KiB) fits with an order of magnitude to
/// spare.
pub const MAX_BODY_BYTES: usize = 4 << 20;

/// Server tunables.
#[derive(Debug, Clone, Copy)]
pub struct HttpConfig {
    /// Concurrent-connection cap: connection `max_connections + 1`
    /// gets `503 Service Unavailable` + `Connection: close` instead of
    /// any per-connection state. Plenty for 100 keep-alive bedside
    /// streams, small enough that a flood cannot exhaust the 64-bed
    /// box.
    pub max_connections: usize,
    /// Reap a connection whose request has stalled for this long
    /// (slow-loris guard). The event-driven edge sweeps idle
    /// connections against this deadline; the thread-per-connection
    /// fallback applies it as the socket read timeout. Reaps count in
    /// the `conns_reaped` gauge.
    pub read_timeout: Duration,
    /// Event-loop threads for the epoll edge (Linux). `0` = auto: a
    /// quarter of the cores, clamped to `[1, 4]` — ingest parsing is
    /// cheap next to model execution, which owns the rest of the box.
    /// Ignored by the thread-per-connection fallback.
    pub edge_threads: usize,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            max_connections: 256,
            read_timeout: Duration::from_secs(30),
            edge_threads: 0,
        }
    }
}

/// Running server handle; dropping it stops the edge (event loops are
/// joined; the fallback's accept thread stops accepting and
/// connections in flight finish their current request).
pub struct HttpServer {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    /// Edge-specific teardown (notify + join the event loops). `None`
    /// for the fallback edge, which is unblocked by a dummy connect.
    shutdown: Option<Box<dyn FnOnce() + Send>>,
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        match self.shutdown.take() {
            Some(f) => f(),
            // unblock the fallback's blocking accept() with a dummy
            // connection
            None => {
                let _ = TcpStream::connect(self.addr);
            }
        }
    }
}

/// Decrements the live-connection gauge when a fallback handler thread
/// exits, however it exits.
struct ConnGuard(Arc<Telemetry>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.conns_active.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Start the ingest server with default [`HttpConfig`]; admitted frames
/// are routed into the sharded aggregation plane through `sink`. Bind
/// with port 0 to auto-pick.
pub fn serve<S: FrameSink>(
    addr: &str,
    sink: S,
    telemetry: Arc<Telemetry>,
) -> Result<HttpServer> {
    serve_with(addr, sink, telemetry, HttpConfig::default())
}

/// [`serve`] with explicit tunables. On Linux this starts the
/// event-driven epoll edge; elsewhere the thread-per-connection
/// fallback ([`serve_legacy_with`]).
pub fn serve_with<S: FrameSink>(
    addr: &str,
    sink: S,
    telemetry: Arc<Telemetry>,
    cfg: HttpConfig,
) -> Result<HttpServer> {
    #[cfg(target_os = "linux")]
    {
        edge::serve_edge(addr, sink, telemetry, cfg)
    }
    #[cfg(not(target_os = "linux"))]
    {
        serve_legacy_with(addr, sink, telemetry, cfg)
    }
}

/// The thread-per-connection edge: one blocking handler thread per
/// accepted connection. The portable fallback on non-Linux targets,
/// and the `legacy_` baseline the edge-concurrency benches measure the
/// epoll edge against. Same routes, same status and framing semantics.
pub fn serve_legacy_with<S: FrameSink>(
    addr: &str,
    sink: S,
    telemetry: Arc<Telemetry>,
    cfg: HttpConfig,
) -> Result<HttpServer> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    std::thread::Builder::new()
        .name("http-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(mut stream) = stream else { continue };
                // connection gate: refuse before spawning. The gate and
                // the `conns_active` gauge are the same atomic, so they
                // cannot disagree; handler threads decrement via
                // ConnGuard.
                if telemetry.conns_active.fetch_add(1, Ordering::Relaxed)
                    >= cfg.max_connections
                {
                    telemetry.conns_active.fetch_sub(1, Ordering::Relaxed);
                    telemetry.conns_refused.fetch_add(1, Ordering::Relaxed);
                    telemetry.conns_refused_overcap.fetch_add(1, Ordering::Relaxed);
                    // best-effort refusal: bound the write so a
                    // non-reading client cannot stall the accept loop
                    let _ = stream
                        .set_write_timeout(Some(std::time::Duration::from_millis(250)));
                    if write_response(
                        &mut stream,
                        "503 Service Unavailable",
                        b"{\"error\":\"connection limit reached\"}",
                        false,
                    )
                    .is_ok()
                    {
                        // a flooding client usually wrote its request
                        // right after connect; closing with those bytes
                        // unread makes the kernel RST the connection,
                        // which can discard the queued 503 before the
                        // client reads it (same failure mode the 413
                        // path drains for). Drain what is already
                        // buffered — non-blocking, so the accept loop
                        // never waits on a silent peer.
                        let _ = stream.set_nonblocking(true);
                        let mut sink = [0u8; 4096];
                        let mut drained = 0usize;
                        while drained < 64 * 1024 {
                            match stream.read(&mut sink) {
                                Ok(0) | Err(_) => break,
                                Ok(n) => drained += n,
                            }
                        }
                    }
                    continue;
                }
                telemetry.conns_accepted.fetch_add(1, Ordering::Relaxed);
                // slow-loris guard: a stalled read wakes the handler,
                // which reaps the connection and frees the thread
                let _ = stream.set_read_timeout(Some(cfg.read_timeout));
                let guard = ConnGuard(Arc::clone(&telemetry));
                let tx = sink.clone();
                let tel = Arc::clone(&telemetry);
                let spawned = std::thread::Builder::new().spawn(move || {
                    let _guard = guard;
                    if let Err(Error::Io(e)) = handle_connection(stream, tx, Arc::clone(&tel))
                    {
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) {
                            tel.conns_reaped.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
                // handler spawn failed (thread exhaustion): the
                // connection was accepted but cannot be served — counted
                // as a handshake-failed refusal, mirroring the epoll
                // edge's registration-failure path. The dropped closure
                // took the ConnGuard with it, so `conns_active` is
                // already released.
                if spawned.is_err() {
                    telemetry.conns_refused.fetch_add(1, Ordering::Relaxed);
                    telemetry.conns_refused_handshake.fetch_add(1, Ordering::Relaxed);
                }
            }
        })
        .map_err(Error::Io)?;
    Ok(HttpServer { addr: local, stop, shutdown: None })
}

fn handle_connection<S: FrameSink>(
    mut stream: TcpStream,
    frame_tx: S,
    telemetry: Arc<Telemetry>,
) -> Result<()> {
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    loop {
        // read until end of headers
        let header_end = loop {
            if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
                break pos + 4;
            }
            let mut chunk = [0u8; 4096];
            let n = stream.read(&mut chunk)?;
            if n == 0 {
                return Ok(()); // connection closed
            }
            buf.extend_from_slice(&chunk[..n]);
            if buf.len() > conn::MAX_HEAD_BYTES {
                return Err(Error::serving("request headers too large"));
            }
        };
        let info = conn::parse_head(&buf[..header_end]);

        // body framing we cannot trust → 400 and close (we don't know
        // where this request's body ends, so the connection cannot be
        // reused)
        if info.bad_framing {
            write_response(
                &mut stream,
                "400 Bad Request",
                b"{\"error\":\"unsupported or malformed body framing\"}",
                false,
            )?;
            return Ok(());
        }

        // refuse oversized bodies before buffering them; the unread
        // body bytes would desync request framing, so close afterwards
        if info.content_length > MAX_BODY_BYTES {
            write_response(
                &mut stream,
                "413 Payload Too Large",
                format!("{{\"error\":\"body exceeds {MAX_BODY_BYTES} bytes\"}}").as_bytes(),
                false,
            )?;
            // drain (bounded) what the client already sent: closing
            // with unread data in the receive queue makes the kernel
            // RST the connection, which can discard the queued 413
            // before the client reads it
            let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(250)));
            let mut sink = [0u8; 4096];
            let mut drained = buf.len().saturating_sub(header_end);
            while drained < info.content_length.min(2 * MAX_BODY_BYTES) {
                match stream.read(&mut sink) {
                    Ok(0) | Err(_) => break,
                    Ok(n) => drained += n,
                }
            }
            return Ok(());
        }
        // read the body
        while buf.len() < header_end + info.content_length {
            let mut chunk = [0u8; 4096];
            let n = stream.read(&mut chunk)?;
            if n == 0 {
                return Err(Error::serving("truncated body"));
            }
            buf.extend_from_slice(&chunk[..n]);
        }
        let body = &buf[header_end..header_end + info.content_length];

        let (status, payload) = route_parsed(info.route, body, &frame_tx, &telemetry);
        buf.drain(..header_end + info.content_length);
        write_response(&mut stream, status, &payload, info.keep_alive)?;
        if !info.keep_alive {
            return Ok(());
        }
    }
}

fn write_response(
    stream: &mut TcpStream,
    status: &str,
    payload: &[u8],
    keep_alive: bool,
) -> Result<()> {
    let connection = if keep_alive { "keep-alive" } else { "close" };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {connection}\r\n\r\n",
        payload.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.write_all(payload)?;
    Ok(())
}

/// The `HLMH` heartbeat response body: admitted frame count, drain
/// flag, and artifact residency (`artifacts` = required bundles held
/// locally, `resident` = the full required set is present; a node with
/// no required set — no registry in play — is trivially resident).
/// Shared by both edges so the prober parses one format.
pub(crate) fn heartbeat_body(frames: u64, telemetry: &Telemetry) -> String {
    let draining = telemetry.draining.load(Ordering::Relaxed);
    let required = telemetry.artifacts_required.load(Ordering::Relaxed);
    let resident_n = telemetry.artifacts_resident.load(Ordering::Relaxed);
    let resident = resident_n >= required;
    format!(
        "{{\"ok\":true,\"frames\":{frames},\"draining\":{draining},\
         \"artifacts\":{resident_n},\"resident\":{resident}}}"
    )
}

/// Dispatch one fully-buffered request body on a parsed route. Shared
/// by the fallback edge (every route) and the event-driven edge (every
/// route except `/ingest.bin`, which decodes streaming and in place —
/// see [`conn::HttpConn`]).
pub(crate) fn route_parsed<S: FrameSink>(
    route: conn::Route,
    body: &[u8],
    frame_tx: &S,
    telemetry: &Telemetry,
) -> (&'static str, Vec<u8>) {
    match route {
        conn::Route::IngestJson => {
            let parsed = std::str::from_utf8(body)
                .map_err(|_| Error::json("body not utf-8"))
                .and_then(Value::parse)
                .and_then(|v| Frame::from_json(&v));
            match parsed {
                Ok(frame) => {
                    if frame_tx.deliver(frame).is_ok() {
                        ("200 OK", b"{\"ok\":true}".to_vec())
                    } else {
                        (
                            "503 Service Unavailable",
                            b"{\"error\":\"pipeline closed\"}".to_vec(),
                        )
                    }
                }
                Err(e) => ("400 Bad Request", format!("{{\"error\":\"{e}\"}}").into_bytes()),
            }
        }
        conn::Route::IngestBin => match decode_envelope_body(body, telemetry) {
            Ok((frames, total, heartbeat)) => {
                for frame in frames {
                    if frame_tx.deliver(frame).is_err() {
                        return (
                            "503 Service Unavailable",
                            b"{\"error\":\"pipeline closed\"}".to_vec(),
                        );
                    }
                }
                // `total` counts deduped frames too: a retried batch
                // must be acknowledged exactly like its first delivery
                // or the sender would count it against a lost response
                if heartbeat {
                    ("200 OK", heartbeat_body(total as u64, telemetry).into_bytes())
                } else {
                    ("200 OK", format!("{{\"ok\":true,\"frames\":{total}}}").into_bytes())
                }
            }
            Err(e) => ("400 Bad Request", format!("{{\"error\":\"{e}\"}}").into_bytes()),
        },
        conn::Route::Drain => {
            telemetry.draining.store(true, Ordering::SeqCst);
            ("200 OK", b"{\"ok\":true,\"draining\":true}".to_vec())
        }
        conn::Route::Artifact(id) => match telemetry.artifact_store() {
            Some(store) => match store.fetch_blob(id) {
                Ok(blob) => {
                    telemetry.artifacts_served.fetch_add(1, Ordering::Relaxed);
                    ("200 OK", blob)
                }
                Err(_) => {
                    // present-but-unreadable means the blob failed its
                    // digest check — corruption that must be counted,
                    // never served
                    if store.blob_path(id).exists() {
                        telemetry.artifacts_verify_failed.fetch_add(1, Ordering::Relaxed);
                    }
                    ("404 Not Found", b"{\"error\":\"no such artifact\"}".to_vec())
                }
            },
            None => ("404 Not Found", b"{\"error\":\"no artifact store on this node\"}".to_vec()),
        },
        conn::Route::Stats => {
            ("200 OK", telemetry.snapshot().to_json().to_string().into_bytes())
        }
        conn::Route::Healthz => ("200 OK", b"{\"status\":\"up\"}".to_vec()),
        conn::Route::Unknown => ("404 Not Found", b"{\"error\":\"no such route\"}".to_vec()),
    }
}

/// Decode a whole `/ingest.bin` body of envelope records — plain
/// frames, `HLMS` batch-sequence tags, `HLMB` batch headers, `HLMH`
/// heartbeats — all-or-nothing like [`wire::decode_stream`]. Returns
/// the frames to deliver, the total frame count seen (including frames
/// suppressed by `HLMS` dedupe — the response must acknowledge a
/// retried batch exactly like its first delivery), and whether any
/// heartbeat was present (the response then reports the node's drain
/// state).
fn decode_envelope_body(
    mut buf: &[u8],
    telemetry: &Telemetry,
) -> Result<(Vec<Frame>, usize, bool)> {
    let mut frames = Vec::new();
    let mut total = 0usize;
    let mut heartbeat = false;
    let mut batch_left: u32 = 0;
    // pending HLMS tag: applies to the next batch header
    let mut seq: Option<(u64, u64)> = None;
    // the current batch is a dedupled duplicate: acknowledge its
    // frames without delivering them
    let mut skip = false;
    while !buf.is_empty() {
        match wire::decode_envelope_step(buf)? {
            wire::EnvelopeStep::Frame(f, used) => {
                total += 1;
                if batch_left > 0 && skip {
                    telemetry.frames_deduped.fetch_add(1, Ordering::Relaxed);
                } else {
                    frames.push(f);
                }
                batch_left = batch_left.saturating_sub(1);
                if batch_left == 0 {
                    skip = false;
                }
                buf = &buf[used..];
            }
            wire::EnvelopeStep::Heartbeat { used, .. } => {
                heartbeat = true;
                buf = &buf[used..];
            }
            wire::EnvelopeStep::BatchSeq { token, seq: s, used } => {
                if batch_left > 0 {
                    return Err(Error::wire("batch-seq tag inside an open batch"));
                }
                seq = Some((token, s));
                buf = &buf[used..];
            }
            wire::EnvelopeStep::BatchStart { n_frames, used } => {
                if batch_left > 0 {
                    return Err(Error::wire("batch header inside an open batch"));
                }
                batch_left = n_frames;
                skip = match seq.take() {
                    Some((token, s)) if n_frames > 0 => !telemetry.admit_batch(token, s),
                    _ => false,
                };
                buf = &buf[used..];
            }
            wire::EnvelopeStep::NeedMore(_) => {
                return Err(Error::wire("truncated envelope record"));
            }
        }
    }
    if batch_left > 0 {
        return Err(Error::wire(format!("batch truncated: {batch_left} frames missing")));
    }
    if seq.is_some() {
        return Err(Error::wire("dangling batch-seq tag with no batch"));
    }
    Ok((frames, total, heartbeat))
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Keep-alive binary ingest client for load generators and `exp/`
/// drivers: one TCP connection per stream, one `POST /ingest.bin`
/// request per batch of frames, one encode buffer reused across
/// batches.
///
/// A bedside monitor's link drops and comes back — the client survives
/// that: on a **transport** failure (broken pipe, reset, EOF
/// mid-response) it redials the remembered address with capped,
/// jittered exponential backoff and resends the batch, up to
/// [`Self::with_backoff`]'s attempt budget. Transport semantics are
/// at-least-once per batch: a reply lost after the server admitted the
/// frames makes the retry a duplicate — acceptable for monitor streams
/// (the replay harness severs *before* the request bytes move, so its
/// budgets stay exact), and upgraded to exactly-once for router links
/// via [`Self::send_batch_seq`], whose `HLMS` idempotency tag rides
/// the re-POSTed body verbatim so the server dedupes the retry. A
/// non-2xx **response** is a protocol answer, not a link failure, and
/// is never retried. Redials are counted in [`Self::reconnects`] and
/// surfaced in the bedside report.
pub struct IngestClient {
    stream: TcpStream,
    addr: SocketAddr,
    body: Vec<u8>,
    resp: Vec<u8>,
    reconnects: u64,
    /// Redial attempts per `send_frames` call before giving up.
    max_attempts: u32,
    backoff_base: Duration,
    backoff_cap: Duration,
    /// xorshift state for deterministic backoff jitter.
    jitter: u64,
    /// Socket read/write deadline (None = block forever). Router links
    /// set this so a half-dead peer cannot wedge a forwarder.
    io_timeout: Option<Duration>,
}

impl IngestClient {
    pub fn connect(addr: SocketAddr) -> Result<IngestClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(IngestClient {
            stream,
            addr,
            body: Vec::with_capacity(16 * 1024),
            resp: Vec::new(),
            reconnects: 0,
            max_attempts: 5,
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(500),
            // per-client deterministic jitter stream (port decorrelates
            // clients sharing a server)
            jitter: 0x9E37_79B9_7F4A_7C15 ^ u64::from(addr.port()),
            io_timeout: None,
        })
    }

    /// Bound every socket read and write. A write that exceeds the
    /// deadline surfaces as a transport error and takes the
    /// backoff-and-redial path — the router link's defense against a
    /// peer that accepts the connection but stops draining it.
    pub fn with_io_timeout(mut self, timeout: Duration) -> Self {
        self.io_timeout = Some(timeout);
        let _ = self.stream.set_read_timeout(self.io_timeout);
        let _ = self.stream.set_write_timeout(self.io_timeout);
        self
    }

    /// Override the redial budget and backoff window (tests, replay).
    pub fn with_backoff(mut self, attempts: u32, base: Duration, cap: Duration) -> Self {
        self.max_attempts = attempts;
        self.backoff_base = base;
        self.backoff_cap = cap.max(base);
        self
    }

    /// Transport-level reconnects performed so far.
    pub fn reconnects(&self) -> u64 {
        self.reconnects
    }

    /// Fault-injection hook: kill the underlying socket as a dropped
    /// monitor link would. The next `send_frames` takes the
    /// backoff-and-redial path. (Shutdown is best-effort; the send
    /// error is what matters.)
    pub fn sever(&mut self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }

    /// POST one batch of frames as a single binary body and wait for
    /// the response. Redials on transport failure (see type docs);
    /// errors when the redial budget is exhausted or the server answers
    /// non-2xx.
    pub fn send_frames(&mut self, frames: &[Frame]) -> Result<()> {
        self.body.clear();
        for f in frames {
            f.write_bytes(&mut self.body);
        }
        self.post_with_retry()
    }

    /// POST one batch of frames wrapped in an `HLMB` envelope header —
    /// the router link path. Same retry semantics as
    /// [`Self::send_frames`].
    pub fn send_batch(&mut self, frames: &[Frame]) -> Result<()> {
        self.body.clear();
        wire::write_batch_header(frames.len() as u32, &mut self.body);
        for f in frames {
            f.write_bytes(&mut self.body);
        }
        self.post_with_retry()
    }

    /// POST one batch under an `HLMS` idempotency tag — the router
    /// link path. `token` identifies the link lifetime, `seq` the
    /// batch; a retry of the same `(token, seq)` (redial re-POST here,
    /// or a re-formed batch in the link worker) is acknowledged by the
    /// peer without re-delivering the frames.
    pub fn send_batch_seq(&mut self, token: u64, seq: u64, frames: &[Frame]) -> Result<()> {
        self.body.clear();
        wire::write_batch_seq(token, seq, &mut self.body);
        wire::write_batch_header(frames.len() as u32, &mut self.body);
        for f in frames {
            f.write_bytes(&mut self.body);
        }
        self.post_with_retry()
    }

    /// POST one `HLMH` heartbeat; returns `true` if the peer reported
    /// itself draining. Transport retries as for [`Self::send_frames`]
    /// (the health prober uses its own single-attempt probe instead —
    /// a probe that needs retries IS the failure signal).
    pub fn send_heartbeat(&mut self, seq: u64) -> Result<bool> {
        self.body.clear();
        self.body.extend_from_slice(&wire::encode_heartbeat(seq));
        self.post_with_retry()?;
        Ok(find_subslice(&self.resp, b"\"draining\":true").is_some())
    }

    /// Retry loop around [`Self::post_once`] for whatever body is
    /// currently staged in `self.body`.
    fn post_with_retry(&mut self) -> Result<()> {
        let mut attempt: u32 = 0;
        loop {
            match self.post_once() {
                Ok(status) => {
                    return if (200..300).contains(&status) {
                        Ok(())
                    } else {
                        Err(Error::serving(format!("ingest server replied {status}")))
                    };
                }
                Err(e) => {
                    if attempt >= self.max_attempts {
                        return Err(e);
                    }
                    std::thread::sleep(self.backoff(attempt));
                    attempt += 1;
                    // redial; a refused dial consumes an attempt and
                    // backs off again (the server may still be coming up)
                    match TcpStream::connect(self.addr) {
                        Ok(s) => {
                            let _ = s.set_nodelay(true);
                            let _ = s.set_read_timeout(self.io_timeout);
                            let _ = s.set_write_timeout(self.io_timeout);
                            self.stream = s;
                            self.reconnects += 1;
                        }
                        Err(_) => continue,
                    }
                }
            }
        }
    }

    /// One request/response exchange on the current connection.
    fn post_once(&mut self) -> Result<u16> {
        let head = format!(
            "POST /ingest.bin HTTP/1.1\r\nHost: ingest\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n",
            self.body.len()
        );
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(&self.body)?;
        self.read_response()
    }

    /// Capped exponential backoff with deterministic jitter in
    /// `[0.5, 1.0]×` of the doubled base.
    fn backoff(&mut self, attempt: u32) -> Duration {
        let full = self
            .backoff_base
            .saturating_mul(1u32 << attempt.min(16))
            .min(self.backoff_cap);
        // xorshift64
        self.jitter ^= self.jitter << 13;
        self.jitter ^= self.jitter >> 7;
        self.jitter ^= self.jitter << 17;
        let frac = 0.5 + 0.5 * (self.jitter >> 11) as f64 / (1u64 << 53) as f64;
        full.mul_f64(frac)
    }

    pub fn send_frame(&mut self, frame: &Frame) -> Result<()> {
        let one = std::slice::from_ref(frame);
        self.send_frames(one)
    }

    /// Read one full response (headers + content-length body) off the
    /// connection so the next request starts on a clean framing
    /// boundary; returns the status code.
    fn read_response(&mut self) -> Result<u16> {
        self.resp.clear();
        let mut chunk = [0u8; 2048];
        let header_end = loop {
            if let Some(pos) = find_subslice(&self.resp, b"\r\n\r\n") {
                break pos + 4;
            }
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                return Err(Error::serving("ingest server closed mid-response"));
            }
            self.resp.extend_from_slice(&chunk[..n]);
        };
        let head = String::from_utf8_lossy(&self.resp[..header_end]).to_string();
        let status: u16 = head
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::serving("malformed response status line"))?;
        let content_length: usize = head
            .lines()
            .filter_map(|l| {
                let (k, v) = l.split_once(':')?;
                k.eq_ignore_ascii_case("content-length")
                    .then(|| v.trim().parse().ok())
                    .flatten()
            })
            .next()
            .unwrap_or(0);
        while self.resp.len() < header_end + content_length {
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                return Err(Error::serving("ingest server closed mid-body"));
            }
            self.resp.extend_from_slice(&chunk[..n]);
        }
        Ok(status)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ingest::Modality;
    use std::sync::mpsc;

    /// Single-shard sink: every admitted frame lands on one receiver.
    /// On Linux this exercises the event-driven edge; elsewhere the
    /// fallback (same assertions hold for both).
    fn test_server() -> (HttpServer, mpsc::Receiver<Frame>) {
        let (tx, rx) = mpsc::sync_channel(1024);
        let tel = Arc::new(Telemetry::default());
        (serve("127.0.0.1:0", ShardSender::from_senders(vec![tx]), tel).unwrap(), rx)
    }

    #[test]
    fn ingest_roundtrip_over_tcp() {
        let (server, rx) = test_server();
        let frame = Frame {
            patient: 3,
            modality: Modality::Ecg,
            sim_time: 1.5,
            values: [0.1, 0.2, 0.3].into(),
        };
        let body = frame.to_json().to_string();
        let mut s = TcpStream::connect(server.addr).unwrap();
        let req = format!(
            "POST /ingest HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        s.write_all(req.as_bytes()).unwrap();
        let mut resp = vec![0u8; 1024];
        let n = s.read(&mut resp).unwrap();
        assert!(String::from_utf8_lossy(&resp[..n]).starts_with("HTTP/1.1 200"));
        let got = rx.recv().unwrap();
        assert_eq!(got.patient, 3);
        assert_eq!(got.values.len(), 3);
    }

    #[test]
    fn binary_ingest_multi_frame_keep_alive() {
        let (server, rx) = test_server();
        let mut client = IngestClient::connect(server.addr).unwrap();
        // two requests over ONE connection, multi-frame bodies
        for round in 0..2u64 {
            let frames: Vec<Frame> = (0..5usize)
                .map(|i| Frame {
                    patient: i,
                    modality: Modality::Ecg,
                    sim_time: round as f64 + i as f64 * 0.004,
                    values: [0.5, -0.25, 1.0].into(),
                })
                .collect();
            client.send_frames(&frames).unwrap();
            for i in 0..5usize {
                let got = rx.recv().unwrap();
                assert_eq!(got.patient, i, "round {round}");
                assert_eq!(got.values, vec![0.5, -0.25, 1.0]);
            }
        }
    }

    #[test]
    fn ingest_client_reconnects_after_severed_link() {
        let (server, rx) = test_server();
        let mut client = IngestClient::connect(server.addr)
            .unwrap()
            .with_backoff(3, Duration::from_millis(1), Duration::from_millis(10));
        let frame = |t: f64| Frame {
            patient: 7,
            modality: Modality::Ecg,
            sim_time: t,
            values: [0.1, 0.2, 0.3].into(),
        };
        client.send_frames(&[frame(0.0)]).unwrap();
        assert_eq!(rx.recv().unwrap().patient, 7);
        assert_eq!(client.reconnects(), 0);
        // monitor link drops: the next batch must redial and deliver —
        // the sever happens before any request bytes move, so exactly
        // one copy of the batch is admitted
        client.sever();
        client.send_frames(&[frame(1.0)]).unwrap();
        assert_eq!(client.reconnects(), 1);
        assert_eq!(rx.recv().unwrap().sim_time, 1.0);
        assert!(rx.try_recv().is_err(), "no duplicate admission");
        // a 400 is a protocol answer, not a link failure: no redial
        let nan = Frame {
            patient: 7,
            modality: Modality::Vitals,
            sim_time: 2.0,
            values: crate::ingest::FrameValues::from_slice(&[f32::NAN]).unwrap(),
        };
        assert!(client.send_frames(std::slice::from_ref(&nan)).is_err());
        assert_eq!(client.reconnects(), 1);
    }

    #[test]
    fn binary_ingest_rejects_corrupt_and_nan_bodies() {
        let (server, rx) = test_server();
        let frame = Frame {
            patient: 1,
            modality: Modality::Vitals,
            sim_time: 2.0,
            values: crate::ingest::FrameValues::from_slice(&[f32::NAN]).unwrap(),
        };
        let mut client = IngestClient::connect(server.addr).unwrap();
        // NaN payload → 400, nothing admitted
        assert!(client.send_frames(std::slice::from_ref(&frame)).is_err());
        // corrupt magic → 400 (reconnect: a 400 keeps the connection,
        // but exercise a fresh one anyway)
        let mut s = TcpStream::connect(server.addr).unwrap();
        let body = vec![0xDEu8; 40];
        let req = format!(
            "POST /ingest.bin HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
            body.len()
        );
        s.write_all(req.as_bytes()).unwrap();
        s.write_all(&body).unwrap();
        let mut resp = vec![0u8; 1024];
        let n = s.read(&mut resp).unwrap();
        assert!(String::from_utf8_lossy(&resp[..n]).starts_with("HTTP/1.1 400"));
        assert!(rx.try_recv().is_err(), "no frame may be admitted");
    }

    #[test]
    fn json_nan_payload_is_400() {
        let (server, rx) = test_server();
        let mut s = TcpStream::connect(server.addr).unwrap();
        // 1e39 overflows f32 to +inf — must be refused at the boundary
        let body = r#"{"patient":1,"modality":"ecg","sim_time":0.0,"values":[1e39]}"#;
        let req = format!(
            "POST /ingest HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        s.write_all(req.as_bytes()).unwrap();
        let mut resp = vec![0u8; 1024];
        let n = s.read(&mut resp).unwrap();
        assert!(String::from_utf8_lossy(&resp[..n]).starts_with("HTTP/1.1 400"));
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn oversized_body_is_413_and_connection_closes() {
        let (server, _rx) = test_server();
        let mut s = TcpStream::connect(server.addr).unwrap();
        let req = format!(
            "POST /ingest.bin HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        s.write_all(req.as_bytes()).unwrap();
        let text = read_full_response(&mut s);
        assert!(text.starts_with("HTTP/1.1 413"), "{text}");
        assert!(text.contains("Connection: close"));
        // server closed its side: further reads hit EOF
        let mut rest = [0u8; 64];
        assert_eq!(s.read(&mut rest).unwrap_or(0), 0);
    }

    /// Read headers + full content-length body (may span TCP segments).
    fn read_full_response(s: &mut TcpStream) -> String {
        let mut buf = Vec::new();
        let mut chunk = [0u8; 2048];
        loop {
            let n = s.read(&mut chunk).unwrap();
            if n == 0 {
                break;
            }
            buf.extend_from_slice(&chunk[..n]);
            if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
                let head = String::from_utf8_lossy(&buf[..pos]).to_string();
                let clen: usize = head
                    .lines()
                    .filter_map(|l| {
                        let (k, v) = l.split_once(':')?;
                        k.eq_ignore_ascii_case("content-length")
                            .then(|| v.trim().parse().ok())
                            .flatten()
                    })
                    .next()
                    .unwrap_or(0);
                if buf.len() >= pos + 4 + clen {
                    break;
                }
            }
        }
        String::from_utf8_lossy(&buf).to_string()
    }

    #[test]
    fn connection_flood_is_rejected_with_503_and_recovers() {
        let (tx, _rx) = mpsc::sync_channel(16);
        let tel = Arc::new(Telemetry::default());
        let server = serve_with(
            "127.0.0.1:0",
            ShardSender::from_senders(vec![tx]),
            Arc::clone(&tel),
            HttpConfig { max_connections: 2, ..HttpConfig::default() },
        )
        .unwrap();

        // two keep-alive connections occupy the whole budget; a request
        // each proves they were accepted (not just queued in the kernel)
        let mut held = Vec::new();
        for _ in 0..2 {
            let mut s = TcpStream::connect(server.addr).unwrap();
            s.write_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
            let mut resp = [0u8; 512];
            let n = s.read(&mut resp).unwrap();
            assert!(String::from_utf8_lossy(&resp[..n]).starts_with("HTTP/1.1 200"));
            held.push(s);
        }

        // the third connection is refused at the accept gate
        let mut s3 = TcpStream::connect(server.addr).unwrap();
        let text = read_full_response(&mut s3);
        assert!(text.starts_with("HTTP/1.1 503"), "{text}");
        assert!(text.contains("Connection: close"), "{text}");
        assert!(text.contains("connection limit"), "{text}");
        assert!(tel.conns_refused.load(Ordering::Relaxed) >= 1);
        assert!(tel.conns_accepted.load(Ordering::Relaxed) >= 2);

        // releasing a slot lets new connections in again (the edge
        // notices the close asynchronously, so poll briefly)
        drop(held.pop());
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            let mut s = TcpStream::connect(server.addr).unwrap();
            s.write_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n")
                .unwrap();
            let text = read_full_response(&mut s);
            if text.starts_with("HTTP/1.1 200") {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "freed connection slot never became available: {text}"
            );
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
    }

    #[test]
    fn malformed_content_length_is_400_and_closes() {
        let (server, rx) = test_server();
        let mut s = TcpStream::connect(server.addr).unwrap();
        // a proxy merging duplicate Content-Length headers produces
        // exactly this shape; trusting "0" would desync the connection
        let req = "POST /ingest.bin HTTP/1.1\r\nHost: x\r\nContent-Length: 12, 12\r\n\r\n";
        s.write_all(req.as_bytes()).unwrap();
        let text = read_full_response(&mut s);
        assert!(text.starts_with("HTTP/1.1 400"), "{text}");
        assert!(text.contains("Connection: close"));
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn stats_health_and_404_endpoints() {
        let (server, _rx) = test_server();
        for (path, expect) in [("/healthz", "up"), ("/stats", "e2e_p95"), ("/nope", "no such")] {
            let mut s = TcpStream::connect(server.addr).unwrap();
            let req = format!("GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n");
            s.write_all(req.as_bytes()).unwrap();
            let text = read_full_response(&mut s);
            assert!(text.contains(expect), "{path}: {text}");
        }
    }

    #[test]
    fn batch_envelope_heartbeat_and_drain_roundtrip() {
        let (tx, rx) = mpsc::sync_channel(1024);
        let tel = Arc::new(Telemetry::default());
        let server =
            serve("127.0.0.1:0", ShardSender::from_senders(vec![tx]), Arc::clone(&tel)).unwrap();
        let mut client = IngestClient::connect(server.addr).unwrap();
        // a batch-envelope body delivers its frames like plain ones
        let frames: Vec<Frame> = (0..3usize)
            .map(|i| Frame {
                patient: i,
                modality: Modality::Ecg,
                sim_time: i as f64 * 0.004,
                values: [0.5, -0.25, 1.0].into(),
            })
            .collect();
        client.send_batch(&frames).unwrap();
        for i in 0..3usize {
            assert_eq!(rx.recv().unwrap().patient, i);
        }
        // heartbeat on a healthy node: not draining, no frame admitted
        assert!(!client.send_heartbeat(1).unwrap());
        assert!(rx.try_recv().is_err());
        // POST /drain flips the flag; subsequent heartbeats advertise it
        let mut s = TcpStream::connect(server.addr).unwrap();
        s.write_all(b"POST /drain HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\nConnection: close\r\n\r\n")
            .unwrap();
        let text = read_full_response(&mut s);
        assert!(text.starts_with("HTTP/1.1 200"), "{text}");
        assert!(text.contains("\"draining\":true"), "{text}");
        assert!(tel.draining.load(Ordering::Relaxed));
        assert!(client.send_heartbeat(2).unwrap(), "heartbeat must advertise the drain");
        // a truncated batch is refused whole
        let mut hdr = Vec::new();
        wire::write_batch_header(2, &mut hdr);
        frames[0].write_bytes(&mut hdr); // only 1 of the announced 2
        let mut s = TcpStream::connect(server.addr).unwrap();
        let req = format!(
            "POST /ingest.bin HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            hdr.len()
        );
        s.write_all(req.as_bytes()).unwrap();
        s.write_all(&hdr).unwrap();
        let text = read_full_response(&mut s);
        assert!(text.starts_with("HTTP/1.1 400"), "{text}");
    }

    #[test]
    fn retried_batch_seq_is_acknowledged_but_not_redelivered() {
        let (tx, rx) = mpsc::sync_channel(1024);
        let tel = Arc::new(Telemetry::default());
        let server =
            serve("127.0.0.1:0", ShardSender::from_senders(vec![tx]), Arc::clone(&tel)).unwrap();
        let mut client = IngestClient::connect(server.addr).unwrap();
        let frames: Vec<Frame> = (0..3usize)
            .map(|i| Frame {
                patient: i,
                modality: Modality::Ecg,
                sim_time: i as f64 * 0.004,
                values: [0.5, -0.25, 1.0].into(),
            })
            .collect();
        // first delivery admits the batch
        client.send_batch_seq(77, 0, &frames).unwrap();
        for i in 0..3usize {
            assert_eq!(rx.recv().unwrap().patient, i);
        }
        // a retry of the same (token, seq) — the lost-response case —
        // answers 2xx with the full frame count but delivers nothing
        client.send_batch_seq(77, 0, &frames).unwrap();
        assert!(find_subslice(&client.resp, b"\"frames\":3").is_some());
        assert!(rx.try_recv().is_err(), "duplicate batch must not be re-delivered");
        assert_eq!(tel.frames_deduped.load(Ordering::Relaxed), 3);
        // the next sequence flows normally
        client.send_batch_seq(77, 1, &frames).unwrap();
        for i in 0..3usize {
            assert_eq!(rx.recv().unwrap().patient, i);
        }
        // a different token is an independent link lifetime
        client.send_batch_seq(99, 0, &frames).unwrap();
        assert_eq!(rx.try_iter().count(), 3);
        assert_eq!(tel.frames_deduped.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn malformed_body_is_400() {
        let (server, _rx) = test_server();
        let mut s = TcpStream::connect(server.addr).unwrap();
        let body = "{not json";
        let req = format!(
            "POST /ingest HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        s.write_all(req.as_bytes()).unwrap();
        let mut resp = vec![0u8; 1024];
        let n = s.read(&mut resp).unwrap();
        assert!(String::from_utf8_lossy(&resp[..n]).starts_with("HTTP/1.1 400"));
    }

    /// The fallback edge stays healthy on every platform — it is both
    /// the non-Linux edge and the `legacy_` bench baseline.
    #[test]
    fn legacy_edge_roundtrip_and_stats() {
        let (tx, rx) = mpsc::sync_channel(1024);
        let tel = Arc::new(Telemetry::default());
        let server = serve_legacy_with(
            "127.0.0.1:0",
            ShardSender::from_senders(vec![tx]),
            Arc::clone(&tel),
            HttpConfig::default(),
        )
        .unwrap();
        let mut client = IngestClient::connect(server.addr).unwrap();
        let frames: Vec<Frame> = (0..4usize)
            .map(|i| Frame {
                patient: i,
                modality: Modality::Ecg,
                sim_time: i as f64 * 0.004,
                values: [1.0, 2.0].into(),
            })
            .collect();
        client.send_frames(&frames).unwrap();
        for i in 0..4usize {
            assert_eq!(rx.recv().unwrap().patient, i);
        }
        assert_eq!(tel.conns_accepted.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn find_subslice_works() {
        assert_eq!(find_subslice(b"abc\r\n\r\n", b"\r\n\r\n"), Some(3));
        assert_eq!(find_subslice(b"abc", b"xyz"), None);
    }

    #[test]
    fn artifact_endpoint_serves_verified_bundles() {
        use crate::registry::{ArtifactBundle, HttpRegistry, LocalFs, Registry};
        let dir =
            std::env::temp_dir().join(format!("holmes-artifact-edge-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(LocalFs::open(&dir).unwrap());
        let bundle =
            ArtifactBundle { input_len: 2500, macs: 9_000_000, hlo: b"HloModule edge_test\n".to_vec() };
        let id = store.store(&bundle).unwrap();

        let (tx, _rx) = mpsc::sync_channel(16);
        let tel = Arc::new(Telemetry::default());
        tel.install_artifact_store(Arc::clone(&store));
        let server =
            serve("127.0.0.1:0", ShardSender::from_senders(vec![tx]), Arc::clone(&tel)).unwrap();

        // the cold-node client pulls and digest-verifies the bundle
        let reg = HttpRegistry::new(server.addr.to_string());
        assert!(reg.has(id));
        assert_eq!(reg.fetch(id).unwrap(), bundle);
        assert!(tel.artifacts_served.load(Ordering::Relaxed) >= 1);

        // an id the store doesn't hold is a 404, not a hang
        let ghost = crate::registry::ArtifactId::digest_of(b"never stored");
        assert!(reg.fetch(ghost).is_err());
        assert!(!reg.has(ghost));

        // corrupt the blob on disk: the edge must refuse to serve it
        let path = store.blob_path(id);
        let mut blob = std::fs::read(&path).unwrap();
        let last = blob.len() - 1;
        blob[last] ^= 0x01;
        std::fs::write(&path, &blob).unwrap();
        assert!(reg.fetch(id).is_err(), "corrupt blob must never be served");
        assert_eq!(tel.artifacts_verify_failed.load(Ordering::Relaxed), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
