//! Deterministic pseudo-random substrate (the build is offline and
//! dependency-free, so `rand` is reimplemented here): SplitMix64 core,
//! uniform/normal/gamma/beta sampling, shuffling.
//!
//! SplitMix64 passes BigCrush for the statistical quality any of our
//! uses need (bootstrap sampling, genetic exploration, synthetic
//! cohorts) and is trivially seedable/forkable for reproducibility.

/// SplitMix64 PRNG. `Clone` is intentional: forked streams are used to
/// give each patient/tree an independent deterministic sequence.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Derive an independent stream (e.g. per patient id).
    pub fn fork(&self, stream: u64) -> Rng {
        let mut r = Rng { state: self.state ^ stream.wrapping_mul(0x9E3779B97F4A7C15) };
        r.next_u64(); // decorrelate
        Rng { state: r.next_u64() }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [lo, hi) — hi must be > lo.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::EPSILON);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Gamma(shape ≥ 1/3) via Marsaglia–Tsang; shapes < 1 are boosted.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Johnk boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            return g * self.f64().max(f64::EPSILON).powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Beta(a, b) via two gammas.
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(b);
        x / (x + y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let base = Rng::seed_from_u64(5);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval_with_uniform_mean() {
        let mut r = Rng::seed_from_u64(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..1000 {
            let v = r.range(3, 7);
            assert!((3..7).contains(&v));
        }
    }

    #[test]
    #[should_panic]
    fn empty_range_panics() {
        Rng::seed_from_u64(0).range(5, 5);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(4);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn beta_moments_match_distribution() {
        let mut r = Rng::seed_from_u64(6);
        let xs: Vec<f64> = (0..20_000).map(|_| r.beta(2.0, 5.0)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        // Beta(2,5) mean = 2/7 ≈ 0.2857
        assert!((mean - 2.0 / 7.0).abs() < 0.01, "mean {mean}");
        assert!(xs.iter().all(|&x| (0.0..=1.0).contains(&x)));
    }

    #[test]
    fn gamma_small_shape_boost() {
        let mut r = Rng::seed_from_u64(7);
        let xs: Vec<f64> = (0..20_000).map(|_| r.gamma(0.5)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.03, "mean {mean}"); // E[Gamma(k,1)] = k
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(8);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
