//! Registry backend that pulls blobs over the serving edge.
//!
//! Any peer with a [`LocalFs`](super::LocalFs) store installed on its
//! telemetry answers `GET /artifact/<hex id>` with the raw blob bytes
//! (see `http::route_parsed`). This client fetches over a short-lived
//! `Connection: close` request — artifact pulls are rare (admission
//! time only), so connection reuse buys nothing and close-delimited
//! bodies keep the client trivial. Every fetch re-digests the body, so
//! a lying or truncating peer yields an error, never a served model.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use super::{ArtifactBundle, ArtifactId, Registry};
use crate::{Error, Result};

/// Pull-only registry client for one remote peer's edge address.
pub struct HttpRegistry {
    /// Peer ingest-edge address, e.g. `127.0.0.1:7272`.
    addr: String,
    connect_timeout: Duration,
    io_timeout: Duration,
}

impl HttpRegistry {
    pub fn new(addr: impl Into<String>) -> HttpRegistry {
        HttpRegistry {
            addr: addr.into(),
            connect_timeout: Duration::from_millis(500),
            io_timeout: Duration::from_secs(5),
        }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// One GET round trip; returns `(status, body)`.
    fn get(&self, path: &str) -> Result<(u16, Vec<u8>)> {
        let sock_addr = self
            .addr
            .parse()
            .map_err(|e| Error::config(format!("registry peer '{}': {e}", self.addr)))?;
        let mut stream = TcpStream::connect_timeout(&sock_addr, self.connect_timeout)
            .map_err(|e| Error::artifact(format!("registry {}: connect: {e}", self.addr)))?;
        stream.set_read_timeout(Some(self.io_timeout))?;
        stream.set_write_timeout(Some(self.io_timeout))?;
        let req = format!(
            "GET {path} HTTP/1.1\r\nHost: {}\r\nConnection: close\r\n\r\n",
            self.addr
        );
        stream
            .write_all(req.as_bytes())
            .map_err(|e| Error::artifact(format!("registry {}: send: {e}", self.addr)))?;
        let mut resp = Vec::new();
        stream
            .read_to_end(&mut resp)
            .map_err(|e| Error::artifact(format!("registry {}: recv: {e}", self.addr)))?;
        // "HTTP/1.1 NNN ..." — status code at bytes 9..12
        if resp.len() < 12 || !resp.starts_with(b"HTTP/1.") {
            return Err(Error::artifact(format!(
                "registry {}: malformed response ({} bytes)",
                self.addr,
                resp.len()
            )));
        }
        let status = std::str::from_utf8(&resp[9..12])
            .ok()
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| Error::artifact(format!("registry {}: bad status line", self.addr)))?;
        let body_at = resp
            .windows(4)
            .position(|w| w == b"\r\n\r\n")
            .map(|p| p + 4)
            .unwrap_or(resp.len());
        Ok((status, resp[body_at..].to_vec()))
    }
}

impl Registry for HttpRegistry {
    fn has(&self, id: ArtifactId) -> bool {
        self.fetch(id).is_ok()
    }

    fn fetch(&self, id: ArtifactId) -> Result<ArtifactBundle> {
        let (status, body) = self.get(&format!("/artifact/{}", id.to_hex()))?;
        if status != 200 {
            return Err(Error::artifact(format!(
                "registry {}: artifact {id} → HTTP {status}",
                self.addr
            )));
        }
        // decode_verified re-digests: transport corruption or a wrong
        // blob from the peer fails here and is never installed
        ArtifactBundle::decode_verified(&body, id)
    }

    fn store(&self, _bundle: &ArtifactBundle) -> Result<ArtifactId> {
        Err(Error::artifact(format!(
            "registry {} is pull-only (no artifact upload endpoint)",
            self.addr
        )))
    }
}
