//! Dependency-free SHA-256 (FIPS 180-4) for content addressing.
//!
//! The registry digests artifact bundles to mint [`super::ArtifactId`]s;
//! nothing here is performance-critical (bundles are digested once per
//! store/fetch), so this is the straightforward single-block-at-a-time
//! implementation, verified against the FIPS known-answer vectors below.

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// Streaming SHA-256 state.
pub struct Sha256 {
    h: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    total_len: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Self {
        Sha256 { h: H0, buf: [0u8; 64], buf_len: 0, total_len: 0 }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let mut block = [0u8; 64];
            block.copy_from_slice(&data[..64]);
            self.compress(&block);
            data = &data[64..];
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total_len.wrapping_mul(8);
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0x00]);
        }
        // update() would re-count the length bytes; pad directly instead
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        self.compress(&block);
        let mut out = [0u8; 32];
        for (i, word) in self.h.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for i in 0..16 {
            w[i] = u32::from_be_bytes([
                block[i * 4],
                block[i * 4 + 1],
                block[i * 4 + 2],
                block[i * 4 + 3],
            ]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.h;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        self.h[0] = self.h[0].wrapping_add(a);
        self.h[1] = self.h[1].wrapping_add(b);
        self.h[2] = self.h[2].wrapping_add(c);
        self.h[3] = self.h[3].wrapping_add(d);
        self.h[4] = self.h[4].wrapping_add(e);
        self.h[5] = self.h[5].wrapping_add(f);
        self.h[6] = self.h[6].wrapping_add(g);
        self.h[7] = self.h[7].wrapping_add(h);
    }
}

/// One-shot digest of `data`.
pub fn digest(data: &[u8]) -> [u8; 32] {
    let mut s = Sha256::new();
    s.update(data);
    s.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(bytes: &[u8; 32]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn fips_empty_vector() {
        assert_eq!(
            hex(&digest(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
    }

    #[test]
    fn fips_abc_vector() {
        assert_eq!(
            hex(&digest(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn fips_two_block_vector() {
        assert_eq!(
            hex(&digest(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq")),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn fips_million_a_vector() {
        let mut s = Sha256::new();
        // feed in awkward chunk sizes to exercise buffering paths
        let data = vec![b'a'; 1_000_000];
        for chunk in data.chunks(617) {
            s.update(chunk);
        }
        assert_eq!(
            hex(&s.finalize()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0u32..4096).map(|i| (i * 31 + 7) as u8).collect();
        for split in [0usize, 1, 63, 64, 65, 1000, 4095, 4096] {
            let mut s = Sha256::new();
            s.update(&data[..split]);
            s.update(&data[split..]);
            assert_eq!(s.finalize(), digest(&data), "split at {split}");
        }
    }
}
