//! Content-addressed model artifact registry.
//!
//! One identity from disk to device to peer: a model artifact is the unit
//! of distribution — its AOT-compiled HLO bytes plus the profile facts
//! the runtime needs to schedule it (input shape, MACs) — and its name is
//! the SHA-256 digest of that bundle:
//!
//! ```text
//!             ┌───────────────────────────────┐
//!  zoo/disk ─▶│ ArtifactBundle                │─ encode ─▶ blob bytes
//!             │   input_len · macs · HLO text │                │
//!             └───────────────────────────────┘             sha256
//!                                                              │
//!                                                              ▼
//!                                                        ArtifactId
//!                                                              │
//!        ┌───────────────────────┬──────────────────────┐      │
//!        ▼                       ▼                      ▼      │
//!   LocalFs store           Http registry          ExecCache key
//!   blobs/ab/abcd…          GET /artifact/<id>     (ArtifactId, batch)
//!   (atomic rename)         (any warm peer)        single-flight compile
//! ```
//!
//! Because the id is recomputable from the blob alone, every fetch path
//! (disk read, peer pull) re-digests before returning: a corrupt or
//! tampered blob is an error, never a served model. [`LocalFs`] is the
//! on-disk store (write-to-temp + atomic rename-into-place, so readers
//! never observe a partial blob); [`HttpRegistry`] pulls blobs over the
//! existing ingest edge from any peer that has them, which is how a cold
//! router peer becomes servable without out-of-band artifact copying.

pub mod http;
pub mod localfs;
pub mod sha256;

pub use http::HttpRegistry;
pub use localfs::LocalFs;

use crate::zoo::Zoo;
use crate::{Error, Result};

/// Content-addressed identity of one compiled model artifact: the
/// SHA-256 digest of its encoded [`ArtifactBundle`].
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ArtifactId(pub [u8; 32]);

impl ArtifactId {
    /// Lower-case 64-char hex form (the wire / path spelling).
    pub fn to_hex(self) -> String {
        let mut s = String::with_capacity(64);
        for b in self.0 {
            s.push(HEX[(b >> 4) as usize] as char);
            s.push(HEX[(b & 0xf) as usize] as char);
        }
        s
    }

    /// Parse the 64-char hex spelling (case-insensitive). Returns `None`
    /// for anything that is not exactly 64 hex digits.
    pub fn from_hex(s: &str) -> Option<ArtifactId> {
        let bytes = s.as_bytes();
        if bytes.len() != 64 {
            return None;
        }
        let mut out = [0u8; 32];
        for i in 0..32 {
            let hi = hex_val(bytes[i * 2])?;
            let lo = hex_val(bytes[i * 2 + 1])?;
            out[i] = (hi << 4) | lo;
        }
        Some(ArtifactId(out))
    }

    /// Digest arbitrary bytes into an id (used by the sim backend to mint
    /// deterministic synthetic identities when no HLO file exists).
    pub fn digest_of(data: &[u8]) -> ArtifactId {
        ArtifactId(sha256::digest(data))
    }
}

const HEX: &[u8; 16] = b"0123456789abcdef";

fn hex_val(b: u8) -> Option<u8> {
    match b {
        b'0'..=b'9' => Some(b - b'0'),
        b'a'..=b'f' => Some(b - b'a' + 10),
        b'A'..=b'F' => Some(b - b'A' + 10),
        _ => None,
    }
}

impl std::fmt::Display for ArtifactId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.to_hex())
    }
}

impl std::fmt::Debug for ArtifactId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // short form: enough to eyeball in logs without 64-char lines
        write!(f, "ArtifactId({}…)", &self.to_hex()[..12])
    }
}

/// The unit of distribution: compiled HLO bytes plus the profile facts
/// the runtime keys scheduling on. The digest covers the whole encoded
/// bundle, so identity changes when *either* the program or its declared
/// shape/cost facts change.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct ArtifactBundle {
    /// Input window length in samples (the model's input shape).
    pub input_len: u64,
    /// Table-3 multiply-accumulate count for one inference at batch 1.
    pub macs: u64,
    /// AOT-compiled HLO program bytes (text proto from `make artifacts`,
    /// or a deterministic sim-grade placeholder for toy zoos).
    pub hlo: Vec<u8>,
}

/// Header magic for the blob encoding. Version-bumping the format mints
/// new ids for every artifact, which is exactly the right behaviour.
const MAGIC: &str = "HLMA1";

impl ArtifactBundle {
    /// Serialise to the canonical blob form the digest is taken over:
    /// one ASCII header line, then the raw HLO bytes.
    pub fn encode(&self) -> Vec<u8> {
        let header = format!(
            "{MAGIC} input_len={} macs={} hlo_len={}\n",
            self.input_len,
            self.macs,
            self.hlo.len()
        );
        let mut out = Vec::with_capacity(header.len() + self.hlo.len());
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(&self.hlo);
        out
    }

    /// Parse a blob produced by [`encode`](Self::encode). Structural
    /// validation only — digest verification is [`Self::decode_verified`].
    pub fn decode(blob: &[u8]) -> Result<ArtifactBundle> {
        let nl = blob
            .iter()
            .position(|&b| b == b'\n')
            .ok_or_else(|| Error::artifact("artifact blob: missing header line"))?;
        let header = std::str::from_utf8(&blob[..nl])
            .map_err(|_| Error::artifact("artifact blob: non-UTF8 header"))?;
        let mut parts = header.split_whitespace();
        if parts.next() != Some(MAGIC) {
            return Err(Error::artifact(format!(
                "artifact blob: bad magic (want {MAGIC})"
            )));
        }
        let mut input_len = None;
        let mut macs = None;
        let mut hlo_len = None;
        for kv in parts {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| Error::artifact(format!("artifact header: bad field '{kv}'")))?;
            let n: u64 = v
                .parse()
                .map_err(|_| Error::artifact(format!("artifact header: bad number '{v}'")))?;
            match k {
                "input_len" => input_len = Some(n),
                "macs" => macs = Some(n),
                "hlo_len" => hlo_len = Some(n),
                other => {
                    return Err(Error::artifact(format!(
                        "artifact header: unknown field '{other}'"
                    )))
                }
            }
        }
        let (input_len, macs, hlo_len) = match (input_len, macs, hlo_len) {
            (Some(i), Some(m), Some(l)) => (i, m, l),
            _ => return Err(Error::artifact("artifact header: missing field")),
        };
        let hlo = &blob[nl + 1..];
        if hlo.len() as u64 != hlo_len {
            return Err(Error::artifact(format!(
                "artifact blob: hlo_len={} but {} payload bytes",
                hlo_len,
                hlo.len()
            )));
        }
        Ok(ArtifactBundle { input_len, macs, hlo: hlo.to_vec() })
    }

    /// Parse a blob *and* prove it is the artifact `want` names: the blob
    /// is re-digested and a mismatch is an error. Every registry fetch
    /// path goes through this, so a corrupt blob is never served.
    pub fn decode_verified(blob: &[u8], want: ArtifactId) -> Result<ArtifactBundle> {
        let got = ArtifactId(sha256::digest(blob));
        if got != want {
            return Err(Error::artifact(format!(
                "artifact digest mismatch: want {want}, blob digests to {got}"
            )));
        }
        Self::decode(blob)
    }

    /// The bundle's content-addressed identity.
    pub fn id(&self) -> ArtifactId {
        ArtifactId(sha256::digest(&self.encode()))
    }

    /// Build the bundle for one `(model, batch)` zoo entry. Reads the
    /// compiled HLO from disk when present; toy zoos (manifest says
    /// trained, but no files on disk) get a deterministic sim-grade
    /// placeholder program synthesised from the profile, so identities
    /// are stable across processes without `make artifacts`.
    pub fn from_zoo(zoo: &Zoo, index: usize, batch: usize) -> Result<ArtifactBundle> {
        let m = zoo.model(index);
        Ok(ArtifactBundle {
            input_len: m.input_len as u64,
            macs: m.macs as u64,
            hlo: zoo.artifact_bytes(index, batch)?,
        })
    }
}

/// A store of content-addressed artifact bundles.
///
/// `fetch` is *verified*: implementations re-digest the blob and must
/// never return a bundle whose content does not match `id`.
pub trait Registry: Send + Sync {
    /// Cheap residency check (no verification).
    fn has(&self, id: ArtifactId) -> bool;
    /// Retrieve and verify the bundle named `id`.
    fn fetch(&self, id: ArtifactId) -> Result<ArtifactBundle>;
    /// Persist `bundle`; returns its id. Idempotent — storing an already
    /// resident bundle is a no-op.
    fn store(&self, bundle: &ArtifactBundle) -> Result<ArtifactId>;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bundle(seed: u8) -> ArtifactBundle {
        ArtifactBundle {
            input_len: 2500 + seed as u64,
            macs: 1_000_000 * (seed as u64 + 1),
            hlo: (0..257u16).map(|i| (i as u8).wrapping_mul(seed | 1)).collect(),
        }
    }

    #[test]
    fn hex_round_trip() {
        let id = bundle(3).id();
        let hex = id.to_hex();
        assert_eq!(hex.len(), 64);
        assert!(hex.bytes().all(|b| b.is_ascii_hexdigit()));
        assert_eq!(ArtifactId::from_hex(&hex), Some(id));
        assert_eq!(ArtifactId::from_hex(&hex.to_uppercase()), Some(id));
        assert_eq!(ArtifactId::from_hex(&hex[..63]), None);
        assert_eq!(ArtifactId::from_hex(&format!("{}g", &hex[..63])), None);
    }

    #[test]
    fn encode_decode_round_trip() {
        for seed in 0..8u8 {
            let b = bundle(seed);
            let blob = b.encode();
            let back = ArtifactBundle::decode(&blob).unwrap();
            assert_eq!(back, b);
            assert_eq!(back.id(), b.id());
        }
    }

    #[test]
    fn id_depends_on_every_field() {
        let base = bundle(1);
        let mut other = base.clone();
        other.input_len += 1;
        assert_ne!(base.id(), other.id());
        let mut other = base.clone();
        other.macs += 1;
        assert_ne!(base.id(), other.id());
        let mut other = base.clone();
        other.hlo[0] ^= 1;
        assert_ne!(base.id(), other.id());
    }

    #[test]
    fn decode_verified_rejects_corruption() {
        let b = bundle(2);
        let id = b.id();
        let mut blob = b.encode();
        assert!(ArtifactBundle::decode_verified(&blob, id).is_ok());
        // flip one payload bit: still structurally valid, digest must catch it
        let last = blob.len() - 1;
        blob[last] ^= 0x40;
        let err = ArtifactBundle::decode_verified(&blob, id).unwrap_err();
        assert!(err.to_string().contains("digest mismatch"), "{err}");
    }

    #[test]
    fn decode_rejects_malformed_headers() {
        assert!(ArtifactBundle::decode(b"").is_err());
        assert!(ArtifactBundle::decode(b"no newline here").is_err());
        assert!(ArtifactBundle::decode(b"WRONG input_len=1 macs=1 hlo_len=0\n").is_err());
        assert!(ArtifactBundle::decode(b"HLMA1 input_len=1 macs=1\n").is_err());
        assert!(ArtifactBundle::decode(b"HLMA1 input_len=1 macs=1 hlo_len=4\nxy").is_err());
        assert!(ArtifactBundle::decode(b"HLMA1 input_len=z macs=1 hlo_len=0\n").is_err());
    }

    #[test]
    fn toy_zoo_bundles_are_deterministic() {
        let z1 = crate::zoo::testkit::toy_zoo_with(4, 16, 21, 2500, &[1, 8]);
        let z2 = crate::zoo::testkit::toy_zoo_with(4, 16, 21, 2500, &[1, 8]);
        for i in 0..4 {
            for &b in &[1usize, 8] {
                let a = ArtifactBundle::from_zoo(&z1, i, b).unwrap();
                let c = ArtifactBundle::from_zoo(&z2, i, b).unwrap();
                assert_eq!(a.id(), c.id(), "model {i} batch {b}");
            }
        }
        // distinct (model, batch) pairs get distinct identities
        let a = ArtifactBundle::from_zoo(&z1, 0, 1).unwrap();
        let b = ArtifactBundle::from_zoo(&z1, 0, 8).unwrap();
        let c = ArtifactBundle::from_zoo(&z1, 1, 1).unwrap();
        assert_ne!(a.id(), b.id());
        assert_ne!(a.id(), c.id());
    }
}
