//! On-disk content-addressed artifact store.
//!
//! Blobs live at `<root>/<hex[0..2]>/<hex>` (fan-out over the first digest
//! byte keeps directories small). Writes go to a temp file under
//! `<root>/tmp/` and are renamed into place, so a concurrent reader —
//! including another process serving `GET /artifact/<id>` off the same
//! store — never observes a partial blob: the path either doesn't exist
//! yet or holds the complete, digest-checkable content.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::{ArtifactBundle, ArtifactId, Registry};
use crate::{Error, Result};

/// Monotonic discriminator for temp-file names within this process.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Content-addressed store rooted at one directory.
pub struct LocalFs {
    root: PathBuf,
}

impl LocalFs {
    /// Open (creating directories as needed) a store at `root`.
    pub fn open(root: impl Into<PathBuf>) -> Result<LocalFs> {
        let root = root.into();
        fs::create_dir_all(root.join("tmp"))?;
        Ok(LocalFs { root })
    }

    /// Final resting path of a blob.
    pub fn blob_path(&self, id: ArtifactId) -> PathBuf {
        let hex = id.to_hex();
        self.root.join(&hex[..2]).join(&hex)
    }

    fn tmp_path(&self, id: ArtifactId) -> PathBuf {
        let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
        let name = format!("{}.{}.{}", &id.to_hex()[..16], std::process::id(), seq);
        self.root.join("tmp").join(name)
    }

    /// Ids of every blob currently resident (directory scan; used by the
    /// serve wiring to seed residency counts after a restart).
    pub fn list(&self) -> Vec<ArtifactId> {
        let mut out = Vec::new();
        let Ok(fans) = fs::read_dir(&self.root) else { return out };
        for fan in fans.flatten() {
            if fan.file_name() == "tmp" || !fan.path().is_dir() {
                continue;
            }
            let Ok(entries) = fs::read_dir(fan.path()) else { continue };
            for e in entries.flatten() {
                if let Some(id) = e.file_name().to_str().and_then(ArtifactId::from_hex) {
                    out.push(id);
                }
            }
        }
        out.sort();
        out
    }

    /// Raw verified blob bytes (what `GET /artifact/<id>` serves). The
    /// digest check runs here too: a bit-rotted file is an error, not a
    /// response body.
    pub fn fetch_blob(&self, id: ArtifactId) -> Result<Vec<u8>> {
        let path = self.blob_path(id);
        let blob = fs::read(&path).map_err(|e| {
            Error::artifact(format!("artifact {id} not in store {}: {e}", self.root.display()))
        })?;
        let got = ArtifactId(super::sha256::digest(&blob));
        if got != id {
            return Err(Error::artifact(format!(
                "store corruption at {}: blob digests to {got}, want {id}",
                path.display()
            )));
        }
        Ok(blob)
    }

    /// Store pre-encoded blob bytes under the id they digest to.
    pub fn store_blob(&self, blob: &[u8]) -> Result<ArtifactId> {
        let id = ArtifactId(super::sha256::digest(blob));
        let dst = self.blob_path(id);
        if dst.exists() {
            return Ok(id); // content-addressed: resident means identical
        }
        if let Some(parent) = dst.parent() {
            fs::create_dir_all(parent)?;
        }
        let tmp = self.tmp_path(id);
        fs::write(&tmp, blob)?;
        // atomic on POSIX: readers see either nothing or the whole blob
        fs::rename(&tmp, &dst)?;
        Ok(id)
    }

    pub fn root(&self) -> &Path {
        &self.root
    }
}

impl Registry for LocalFs {
    fn has(&self, id: ArtifactId) -> bool {
        self.blob_path(id).exists()
    }

    fn fetch(&self, id: ArtifactId) -> Result<ArtifactBundle> {
        let blob = self.fetch_blob(id)?;
        ArtifactBundle::decode_verified(&blob, id)
    }

    fn store(&self, bundle: &ArtifactBundle) -> Result<ArtifactId> {
        self.store_blob(&bundle.encode())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "holmes-registry-{tag}-{}-{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn bundle(seed: u8) -> ArtifactBundle {
        ArtifactBundle {
            input_len: 2500,
            macs: 7_000_000 + seed as u64,
            hlo: vec![seed; 1024],
        }
    }

    #[test]
    fn store_fetch_round_trip() {
        let dir = scratch("roundtrip");
        let store = LocalFs::open(&dir).unwrap();
        for seed in 0..5u8 {
            let b = bundle(seed);
            let id = store.store(&b).unwrap();
            assert_eq!(id, b.id());
            assert!(store.has(id));
            let back = store.fetch(id).unwrap();
            assert_eq!(back, b, "seed {seed}: fetched bundle must be byte-identical");
        }
        assert_eq!(store.list().len(), 5);
        // idempotent re-store
        let b = bundle(0);
        assert_eq!(store.store(&b).unwrap(), b.id());
        assert_eq!(store.list().len(), 5);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_blob_is_never_served() {
        let dir = scratch("corrupt");
        let store = LocalFs::open(&dir).unwrap();
        let b = bundle(9);
        let id = store.store(&b).unwrap();
        // flip a byte in place, simulating disk rot / tampering
        let path = store.blob_path(id);
        let mut blob = fs::read(&path).unwrap();
        let last = blob.len() - 1;
        blob[last] ^= 0x01;
        fs::write(&path, &blob).unwrap();
        assert!(store.fetch(id).is_err(), "decoded fetch must fail digest check");
        assert!(store.fetch_blob(id).is_err(), "raw fetch must fail digest check");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_blob_is_an_error() {
        let dir = scratch("missing");
        let store = LocalFs::open(&dir).unwrap();
        let ghost = bundle(42).id();
        assert!(!store.has(ghost));
        assert!(store.fetch(ghost).is_err());
        let _ = fs::remove_dir_all(&dir);
    }
}
