//! Evaluation metrics used across the composer and the experiment
//! harnesses: ROC-AUC, PR-AUC, F1, accuracy (Table 2 columns), R²
//! (Fig. 8), plus small statistics helpers (mean ± std, percentiles).
//!
//! All metric functions take `labels: &[u8]` with values in {0, 1} and
//! `scores: &[f64]` (higher = more likely positive).

/// ROC-AUC via the Mann–Whitney rank statistic with midranks for ties.
///
/// Returns 0.5 when either class is absent (undefined AUC).
pub fn roc_auc(labels: &[u8], scores: &[f64]) -> f64 {
    assert_eq!(labels.len(), scores.len());
    let n = labels.len();
    if n == 0 {
        return 0.5;
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
    let mut ranks = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[order[j + 1]] == scores[order[i]] {
            j += 1;
        }
        let midrank = (i + j + 2) as f64 / 2.0;
        for &idx in &order[i..=j] {
            ranks[idx] = midrank;
        }
        i = j + 1;
    }
    let n1 = labels.iter().filter(|&&l| l == 1).count() as f64;
    let n0 = n as f64 - n1;
    if n1 == 0.0 || n0 == 0.0 {
        return 0.5;
    }
    let rank_sum: f64 = labels
        .iter()
        .zip(&ranks)
        .filter(|(&l, _)| l == 1)
        .map(|(_, &r)| r)
        .sum();
    (rank_sum - n1 * (n1 + 1.0) / 2.0) / (n1 * n0)
}

/// PR-AUC as average precision: AP = Σ (R_k − R_{k−1}) · P_k over the
/// score-descending sweep (sklearn's `average_precision_score`).
pub fn pr_auc(labels: &[u8], scores: &[f64]) -> f64 {
    assert_eq!(labels.len(), scores.len());
    let total_pos = labels.iter().filter(|&&l| l == 1).count() as f64;
    if total_pos == 0.0 {
        return 0.0;
    }
    let mut order: Vec<usize> = (0..labels.len()).collect();
    order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));
    let mut tp = 0.0f64;
    let mut fp = 0.0f64;
    let mut ap = 0.0f64;
    let mut prev_recall = 0.0f64;
    let mut k = 0;
    while k < order.len() {
        // advance over the tie group so P/R are computed per threshold
        let mut j = k;
        while j + 1 < order.len() && scores[order[j + 1]] == scores[order[k]] {
            j += 1;
        }
        for &idx in &order[k..=j] {
            if labels[idx] == 1 {
                tp += 1.0;
            } else {
                fp += 1.0;
            }
        }
        let precision = tp / (tp + fp);
        let recall = tp / total_pos;
        ap += (recall - prev_recall) * precision;
        prev_recall = recall;
        k = j + 1;
    }
    ap
}

/// Confusion counts at a decision threshold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Confusion {
    pub tp: usize,
    pub fp: usize,
    pub tn: usize,
    pub fn_: usize,
}

pub fn confusion_at(labels: &[u8], scores: &[f64], threshold: f64) -> Confusion {
    let mut c = Confusion { tp: 0, fp: 0, tn: 0, fn_: 0 };
    for (&l, &s) in labels.iter().zip(scores) {
        match (l == 1, s >= threshold) {
            (true, true) => c.tp += 1,
            (false, true) => c.fp += 1,
            (false, false) => c.tn += 1,
            (true, false) => c.fn_ += 1,
        }
    }
    c
}

/// F1 score at a threshold (default 0.5 in the harnesses).
pub fn f1_at(labels: &[u8], scores: &[f64], threshold: f64) -> f64 {
    let c = confusion_at(labels, scores, threshold);
    let denom = 2 * c.tp + c.fp + c.fn_;
    if denom == 0 {
        return 0.0;
    }
    2.0 * c.tp as f64 / denom as f64
}

/// Classification accuracy at a threshold.
pub fn accuracy_at(labels: &[u8], scores: &[f64], threshold: f64) -> f64 {
    if labels.is_empty() {
        return 0.0;
    }
    let c = confusion_at(labels, scores, threshold);
    (c.tp + c.tn) as f64 / labels.len() as f64
}

/// Coefficient of determination R² (Fig. 8's surrogate-quality metric).
pub fn r2(y_true: &[f64], y_pred: &[f64]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    if y_true.is_empty() {
        return 0.0;
    }
    let mean = y_true.iter().sum::<f64>() / y_true.len() as f64;
    let ss_tot: f64 = y_true.iter().map(|y| (y - mean).powi(2)).sum();
    let ss_res: f64 = y_true
        .iter()
        .zip(y_pred)
        .map(|(y, p)| (y - p).powi(2))
        .sum();
    if ss_tot == 0.0 {
        return if ss_res == 0.0 { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

/// Sample mean and (population) standard deviation — Table 2's `a ± b`.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

/// Bootstrap mean ± std of a metric over label/score resamples — the
/// Table-2 `a ± b` uncertainty (the paper's spread comes from its tiny
/// 10-patient test cohort; we expose the same sampling variance by
/// resampling the validation set).
pub fn bootstrap_metric(
    labels: &[u8],
    scores: &[f64],
    metric: impl Fn(&[u8], &[f64]) -> f64,
    n_boot: usize,
    seed: u64,
) -> (f64, f64) {
    assert_eq!(labels.len(), scores.len());
    if labels.is_empty() {
        return (0.0, 0.0);
    }
    let n = labels.len();
    let mut rng = crate::rng::Rng::seed_from_u64(seed);
    let mut vals = Vec::with_capacity(n_boot);
    let mut lb = vec![0u8; n];
    let mut sb = vec![0f64; n];
    for _ in 0..n_boot {
        for i in 0..n {
            let j = rng.range(0, n);
            lb[i] = labels[j];
            sb[i] = scores[j];
        }
        vals.push(metric(&lb, &sb));
    }
    mean_std(&vals)
}

/// Linear-interpolated percentile over an unsorted sample, p ∈ [0, 100].
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v = samples.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auc_perfect_and_inverted() {
        let y = [0u8, 0, 1, 1];
        assert_eq!(roc_auc(&y, &[0.1, 0.2, 0.8, 0.9]), 1.0);
        assert_eq!(roc_auc(&y, &[0.9, 0.8, 0.2, 0.1]), 0.0);
    }

    #[test]
    fn auc_ties_use_midranks() {
        let y = [0u8, 1, 0, 1];
        let s = [0.3, 0.3, 0.1, 0.9];
        assert!((roc_auc(&y, &s) - 3.5 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn auc_degenerate_classes() {
        assert_eq!(roc_auc(&[1, 1], &[0.1, 0.9]), 0.5);
        assert_eq!(roc_auc(&[], &[]), 0.5);
    }

    #[test]
    fn pr_auc_perfect_is_one() {
        let y = [0u8, 0, 1, 1];
        assert!((pr_auc(&y, &[0.1, 0.2, 0.8, 0.9]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pr_auc_random_close_to_prevalence() {
        // For constant scores, AP = prevalence.
        let y = [1u8, 0, 0, 0];
        assert!((pr_auc(&y, &[0.5; 4]) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn f1_and_accuracy_hand_checked() {
        let y = [1u8, 1, 0, 0];
        let s = [0.9, 0.4, 0.6, 0.1];
        // tp=1 fp=1 tn=1 fn=1
        assert!((f1_at(&y, &s, 0.5) - 0.5).abs() < 1e-12);
        assert!((accuracy_at(&y, &s, 0.5) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f1_empty_predictions() {
        assert_eq!(f1_at(&[0, 0], &[0.1, 0.1], 0.5), 0.0);
    }

    #[test]
    fn r2_perfect_and_mean_predictor() {
        let y = [1.0, 2.0, 3.0];
        assert!((r2(&y, &y) - 1.0).abs() < 1e-12);
        assert!(r2(&y, &[2.0, 2.0, 2.0]).abs() < 1e-12);
    }

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[2.0, 4.0]);
        assert_eq!(m, 3.0);
        assert_eq!(s, 1.0);
    }

    #[test]
    fn bootstrap_metric_centers_on_point_estimate() {
        let labels: Vec<u8> = (0..200).map(|i| (i % 2) as u8).collect();
        // overlapping classes so the AUC genuinely varies across resamples
        let scores: Vec<f64> = labels
            .iter()
            .enumerate()
            .map(|(i, &l)| l as f64 * 0.5 + (i % 13) as f64 * 0.06)
            .collect();
        let point = roc_auc(&labels, &scores);
        let (mean, std) = bootstrap_metric(&labels, &scores, roc_auc, 100, 3);
        assert!((mean - point).abs() < 0.03, "mean {mean} vs point {point}");
        assert!(std > 0.0 && std < 0.1);
    }

    #[test]
    fn bootstrap_metric_empty_input() {
        assert_eq!(bootstrap_metric(&[], &[], roc_auc, 10, 0), (0.0, 0.0));
    }

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&v, 95.0) - 3.85).abs() < 1e-9);
    }
}
