//! Network-calculus queueing-latency bound (paper Fig. 5).
//!
//! HOLMES estimates the queueing component `T_q` of end-to-end response
//! time by constructing an **arrival curve** α(Δt) — the maximum number
//! of ensemble queries observed in any interval of length Δt during
//! profiling — and an analytic **service curve** β(Δt) from the measured
//! ensemble throughput capacity μ. The maximum *horizontal* distance
//! between the two curves is a known tight upper bound on queueing delay
//! for such a system.

/// Empirical arrival curve: α(Δt) = max #events in any window of width Δt.
#[derive(Debug, Clone)]
pub struct ArrivalCurve {
    /// (window length Δt seconds, max event count) sorted by Δt.
    pub points: Vec<(f64, f64)>,
}

impl ArrivalCurve {
    /// Build from event timestamps (seconds, any order) over a grid of
    /// window lengths. O(|grid| · n) with a sliding two-pointer scan.
    pub fn from_timestamps(timestamps: &[f64], windows: &[f64]) -> Self {
        let mut ts = timestamps.to_vec();
        ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut points = Vec::with_capacity(windows.len());
        for &dt in windows {
            assert!(dt > 0.0, "window length must be positive");
            let mut best = 0usize;
            let mut lo = 0usize;
            for hi in 0..ts.len() {
                while ts[hi] - ts[lo] > dt {
                    lo += 1;
                }
                best = best.max(hi - lo + 1);
            }
            points.push((dt, best as f64));
        }
        points.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        ArrivalCurve { points }
    }

    /// Exact arrival curve: the window grid is every distinct pairwise
    /// span of the trace, so the queueing bound is *tight* (guaranteed ≥
    /// any FIFO simulation of the same trace). O(n²) — use for profiling
    /// traces (n ≲ 1000); fall back to `from_timestamps` + a grid above.
    pub fn from_timestamps_exact(timestamps: &[f64]) -> Self {
        let mut ts = timestamps.to_vec();
        ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut windows: Vec<f64> = Vec::with_capacity(ts.len() * (ts.len() - 1) / 2 + 1);
        for i in 0..ts.len() {
            for j in i + 1..ts.len() {
                let span = ts[j] - ts[i];
                if span > 0.0 {
                    windows.push(span);
                }
            }
        }
        // include a near-zero window so instantaneous bursts count
        windows.push(1e-9);
        windows.sort_by(|a, b| a.partial_cmp(b).unwrap());
        windows.dedup();
        Self::from_timestamps(&ts, &windows)
    }

    /// Token-bucket abstraction α(t) = burst + rate·t, for analytic use
    /// (e.g. inside the composer's fast latency profiler where no trace
    /// exists yet: `patients` periodic sources of `rate` qps each).
    pub fn token_bucket(burst: f64, rate: f64, windows: &[f64]) -> Self {
        let points = windows
            .iter()
            .map(|&dt| (dt, burst + rate * dt))
            .collect();
        ArrivalCurve { points }
    }

    /// Default window grid: log-spaced from 1 ms to `horizon` seconds.
    pub fn default_windows(horizon: f64) -> Vec<f64> {
        let mut w = Vec::new();
        let mut dt = 1e-3;
        while dt < horizon {
            w.push(dt);
            dt *= 1.5;
        }
        w.push(horizon);
        w
    }
}

/// Rate–latency service curve β(t) = rate · max(0, t − latency):
/// `rate` = measured ensemble throughput capacity μ (qps), `latency` =
/// per-query service time floor (the T_s the closed-loop probe measured).
#[derive(Debug, Clone, Copy)]
pub struct ServiceCurve {
    pub rate: f64,
    pub latency: f64,
}

impl ServiceCurve {
    pub fn new(rate: f64, latency: f64) -> Self {
        assert!(rate > 0.0, "service rate must be positive");
        assert!(latency >= 0.0);
        ServiceCurve { rate, latency }
    }

    /// β(t)
    pub fn eval(&self, t: f64) -> f64 {
        self.rate * (t - self.latency).max(0.0)
    }

    /// Earliest t such that β(t) ≥ work.
    pub fn inverse(&self, work: f64) -> f64 {
        if work <= 0.0 {
            return 0.0;
        }
        self.latency + work / self.rate
    }
}

/// Max horizontal deviation sup_t { inf { d ≥ 0 : α(t) ≤ β(t + d) } } —
/// the tight queueing-delay bound `T_q` (seconds).
pub fn queueing_bound(arrival: &ArrivalCurve, service: &ServiceCurve) -> f64 {
    let mut tq: f64 = 0.0;
    for &(t, a) in &arrival.points {
        let finish = service.inverse(a); // earliest time to serve α(t) work
        tq = tq.max((finish - t).max(0.0));
    }
    tq
}

/// Convenience: `T_q` for `patients` periodic sources each issuing one
/// ensemble query per `period` seconds (phase-aligned worst case: all
/// queries of a window land in a burst), served at capacity `mu` qps
/// with service floor `ts`.
pub fn tq_periodic_sources(patients: usize, period: f64, mu: f64, ts: f64) -> f64 {
    assert!(period > 0.0);
    let windows = ArrivalCurve::default_windows(4.0 * period);
    // worst case: the per-window queries of all patients arrive together
    let arrival = ArrivalCurve::token_bucket(patients as f64, patients as f64 / period, &windows);
    queueing_bound(&arrival, &ServiceCurve::new(mu, ts))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_curve_counts_max_window() {
        // bursts of 3 at t=0 and t=10
        let ts = [0.0, 0.001, 0.002, 10.0, 10.001, 10.002];
        let ac = ArrivalCurve::from_timestamps(&ts, &[0.01, 5.0, 20.0]);
        assert_eq!(ac.points[0].1, 3.0);
        assert_eq!(ac.points[1].1, 3.0);
        assert_eq!(ac.points[2].1, 6.0);
    }

    #[test]
    fn arrival_curve_monotone_in_window() {
        let ts: Vec<f64> = (0..100).map(|i| (i as f64) * 0.013).collect();
        let ac = ArrivalCurve::from_timestamps(&ts, &ArrivalCurve::default_windows(2.0));
        for w in ac.points.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn service_curve_eval_inverse_roundtrip() {
        let sc = ServiceCurve::new(100.0, 0.05);
        assert_eq!(sc.eval(0.05), 0.0);
        assert!((sc.eval(sc.inverse(42.0)) - 42.0).abs() < 1e-9);
        assert_eq!(sc.inverse(0.0), 0.0);
    }

    #[test]
    fn queueing_bound_zero_when_overprovisioned() {
        // 1 query per second, capacity 1000 qps, no latency floor
        let ts: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ac = ArrivalCurve::from_timestamps(&ts, &ArrivalCurve::default_windows(10.0));
        let tq = queueing_bound(&ac, &ServiceCurve::new(1000.0, 0.0));
        assert!(tq < 0.01, "tq = {tq}");
    }

    #[test]
    fn queueing_bound_burst_over_rate() {
        // burst of B jobs at t=0, rate μ ⇒ T_q ≈ B/μ + latency floor
        let ts = vec![0.0; 64];
        let ac = ArrivalCurve::from_timestamps(&ts, &[0.001]);
        let tq = queueing_bound(&ac, &ServiceCurve::new(32.0, 0.1));
        assert!((tq - (64.0 / 32.0 + 0.1 - 0.001)).abs() < 1e-6, "tq = {tq}");
    }

    #[test]
    fn tq_periodic_scales_with_patients() {
        let t1 = tq_periodic_sources(8, 30.0, 100.0, 0.01);
        let t2 = tq_periodic_sources(64, 30.0, 100.0, 0.01);
        assert!(t2 > t1);
    }

    #[test]
    fn bound_dominates_fifo_simulation() {
        // Simulate a FIFO queue fed by the same burst trace; the network-
        // calculus bound must dominate every simulated waiting time.
        let mut ts = Vec::new();
        for burst in 0..5 {
            for k in 0..10 {
                ts.push(burst as f64 * 2.0 + k as f64 * 1e-4);
            }
        }
        let mu = 20.0; // jobs/sec, deterministic service 50 ms
        let service = 1.0 / mu;
        let mut free_at: f64 = 0.0;
        let mut max_delay: f64 = 0.0;
        let mut sorted = ts.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for &t in &sorted {
            let start = free_at.max(t);
            let done = start + service;
            max_delay = max_delay.max(done - t);
            free_at = done;
        }
        let ac = ArrivalCurve::from_timestamps(&ts, &ArrivalCurve::default_windows(12.0));
        let bound = queueing_bound(&ac, &ServiceCurve::new(mu, service));
        assert!(
            bound + 1e-9 >= max_delay,
            "bound {bound} < simulated {max_delay}"
        );
    }
}
