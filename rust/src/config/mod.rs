//! Configuration types for the coordinator — loadable from JSON files or
//! assembled by the CLI. `SystemConfig` is the paper's `c ∈ R^d` vector.

use std::path::Path;

use crate::json::Value;
use crate::Result;

/// The paper's system configuration `c`: resources + offered load.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SystemConfig {
    /// Number of device workers ("GPUs" in the paper's setup).
    pub gpus: usize,
    /// Number of simultaneously monitored patients (beds).
    pub patients: usize,
    /// Observation window ΔT in seconds (paper default: 30 s).
    pub window_s: f64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig { gpus: 2, patients: 64, window_s: 30.0 }
    }
}

impl SystemConfig {
    /// Ensemble-query arrival rate: one query per patient per window.
    pub fn query_rate(&self) -> f64 {
        self.patients as f64 / self.window_s
    }

    /// Feature row for the latency surrogate `f̂_l(V, c, b)`.
    pub fn feature_row(&self) -> Vec<f64> {
        vec![self.gpus as f64, self.patients as f64, self.window_s]
    }
}

/// Ensemble-composer hyper-parameters (paper Algorithm 1 inputs).
#[derive(Debug, Clone)]
pub struct ComposerConfig {
    /// Latency constraint L (seconds).
    pub latency_budget: f64,
    /// λ of the soft-constraint variant; unused under the hard step δ.
    pub lambda: f64,
    /// N — search iterations.
    pub iterations: usize,
    /// N₀ — warm-start samples.
    pub warm_start: usize,
    /// M — candidates generated per exploration round.
    pub explore_samples: usize,
    /// K — top candidates profiled per iteration.
    pub top_k: usize,
    /// S — mutation degree (Manhattan radius).
    pub mutation_degree: usize,
    /// p — probability of genetic (vs random) exploration.
    pub p_genetic: f64,
    /// q — probability of mutation (vs recombination) within genetic.
    pub q_mutation: f64,
    pub seed: u64,
    /// Restrict search to models with compiled artifacts.
    pub servable_only: bool,
}

impl Default for ComposerConfig {
    fn default() -> Self {
        ComposerConfig {
            latency_budget: 0.2,
            lambda: 1.0,
            iterations: 20,
            warm_start: 24,
            explore_samples: 64,
            top_k: 6,
            mutation_degree: 3,
            p_genetic: 0.8,
            q_mutation: 0.5,
            seed: 13,
            servable_only: false,
        }
    }
}

/// Serving-pipeline configuration.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    pub system: SystemConfig,
    /// Virtual-clock acceleration (1.0 = real time).
    pub speedup: f64,
    /// Max queries coalesced into one device batch.
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch (milliseconds).
    pub batch_timeout_ms: u64,
    /// HTTP ingest listen address (None = in-process ingest only).
    pub http_addr: Option<String>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            system: SystemConfig::default(),
            speedup: 1.0,
            max_batch: 8,
            batch_timeout_ms: 5,
            http_addr: None,
        }
    }
}

impl ComposerConfig {
    /// Load from a JSON file; absent fields keep their defaults.
    pub fn from_json_file(path: impl AsRef<Path>) -> Result<Self> {
        let v = Value::parse(&std::fs::read_to_string(path)?)?;
        let mut c = ComposerConfig::default();
        let num = |k: &str| v.get(k).and_then(|x| x.as_f64());
        if let Some(x) = num("latency_budget") {
            c.latency_budget = x;
        }
        if let Some(x) = num("lambda") {
            c.lambda = x;
        }
        if let Some(x) = num("iterations") {
            c.iterations = x as usize;
        }
        if let Some(x) = num("warm_start") {
            c.warm_start = x as usize;
        }
        if let Some(x) = num("explore_samples") {
            c.explore_samples = x as usize;
        }
        if let Some(x) = num("top_k") {
            c.top_k = x as usize;
        }
        if let Some(x) = num("mutation_degree") {
            c.mutation_degree = x as usize;
        }
        if let Some(x) = num("p_genetic") {
            c.p_genetic = x;
        }
        if let Some(x) = num("q_mutation") {
            c.q_mutation = x;
        }
        if let Some(x) = num("seed") {
            c.seed = x as u64;
        }
        if let Some(x) = v.get("servable_only").and_then(|x| x.as_bool()) {
            c.servable_only = x;
        }
        Ok(c)
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("latency_budget", Value::Num(self.latency_budget)),
            ("lambda", Value::Num(self.lambda)),
            ("iterations", Value::Num(self.iterations as f64)),
            ("warm_start", Value::Num(self.warm_start as f64)),
            ("explore_samples", Value::Num(self.explore_samples as f64)),
            ("top_k", Value::Num(self.top_k as f64)),
            ("mutation_degree", Value::Num(self.mutation_degree as f64)),
            ("p_genetic", Value::Num(self.p_genetic)),
            ("q_mutation", Value::Num(self.q_mutation)),
            ("seed", Value::Num(self.seed as f64)),
            ("servable_only", Value::Bool(self.servable_only)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_rate_is_patients_over_window() {
        let c = SystemConfig { gpus: 2, patients: 64, window_s: 30.0 };
        assert!((c.query_rate() - 64.0 / 30.0).abs() < 1e-12);
    }

    #[test]
    fn configs_roundtrip_json() {
        let c = ComposerConfig { latency_budget: 0.35, mutation_degree: 5, ..Default::default() };
        let dir = std::env::temp_dir().join("holmes_cfg_test.json");
        std::fs::write(&dir, c.to_json().to_string()).unwrap();
        let c2 = ComposerConfig::from_json_file(&dir).unwrap();
        assert_eq!(c.latency_budget, c2.latency_budget);
        assert_eq!(c.mutation_degree, c2.mutation_degree);
        assert_eq!(c.iterations, c2.iterations);
    }

    #[test]
    fn defaults_match_paper_setup() {
        let s = SystemConfig::default();
        assert_eq!(s.gpus, 2); // 2× V100 in §4.1.2
        assert_eq!(s.window_s, 30.0); // 30 s segmentation windows
    }
}
