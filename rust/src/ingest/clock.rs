//! Virtual clock: maps simulation time ↔ wall-clock time with an
//! acceleration factor.
//!
//! Latency-measuring experiments (Fig. 10, Fig. 13) run at 1× — real
//! 250 Hz pacing — so queueing is physically real. Long-horizon
//! timelines (Fig. 9's 60-minute online-vs-batch comparison) run
//! accelerated; EXPERIMENTS.md documents the factor per experiment.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct VirtualClock {
    start: Instant,
    /// simulated seconds per wall second (1.0 = real time).
    speedup: f64,
}

impl VirtualClock {
    pub fn new(speedup: f64) -> Self {
        assert!(speedup > 0.0);
        VirtualClock { start: Instant::now(), speedup }
    }

    pub fn real_time() -> Self {
        Self::new(1.0)
    }

    pub fn speedup(&self) -> f64 {
        self.speedup
    }

    /// Current simulation time (seconds since clock start).
    pub fn now_sim(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * self.speedup
    }

    /// Wall-clock duration until the given simulation time (zero if past).
    pub fn wall_until(&self, sim_time: f64) -> Duration {
        let remaining = (sim_time - self.now_sim()) / self.speedup;
        if remaining <= 0.0 {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(remaining)
        }
    }

    /// Blocking sleep until a simulation instant.
    pub fn sleep_until_sim(&self, sim_time: f64) {
        let wall = self.wall_until(sim_time);
        if !wall.is_zero() {
            std::thread::sleep(wall);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_time_scales_with_speedup() {
        let c = VirtualClock::new(100.0);
        std::thread::sleep(Duration::from_millis(20));
        let sim = c.now_sim();
        assert!(sim > 1.0, "sim = {sim}"); // ≥ 2 simulated seconds expected
    }

    #[test]
    fn wall_until_future_and_past() {
        let c = VirtualClock::new(10.0);
        let wall = c.wall_until(5.0);
        assert!(wall <= Duration::from_millis(510) && wall > Duration::from_millis(300));
        assert_eq!(c.wall_until(-1.0), Duration::ZERO);
    }

    #[test]
    #[should_panic]
    fn zero_speedup_rejected() {
        VirtualClock::new(0.0);
    }
}
