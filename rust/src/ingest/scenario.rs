//! Adversarial cohort scenarios: the deterministic workload generators
//! behind `holmes replay` (see `crate::exp::replay`).
//!
//! Everything the bedside simulator produced before this module was
//! steady state — every bed present from t=0, every monitor clock
//! perfect, every wire frame well-formed. Real ICU cohorts (MIMIC-style
//! benchmarks, multi-site sepsis deployments) are none of that: beds
//! churn on admission and discharge, a monitor's leads drop out and
//! resync, clocks between two monitors on the same bed disagree, and
//! shift changes slam the ingest edge all at once. Each [`Scenario`]
//! here reproduces one of those shapes as a **pure function of
//! `(seed, scenario, tick)`** so that a replay is reproducible bit for
//! bit: the same seed must yield the same shed/evict/prediction
//! accounting on 1 shard or 8, 1 worker or 4.
//!
//! The other half of the contract is the [`FaultBudget`]: a dry run of
//! the same generators through a model of the aggregation plane
//! (per-patient monotone ECG filter, per-shard LRU admission) that
//! predicts **exactly** how many frames will be admitted, dropped
//! stale, dropped malformed, how many windows will complete, and how
//! many idle aggregators will be evicted. The live run's telemetry has
//! to match the budget counter for counter — that is what makes the
//! replay harness a property gate instead of a demo.

use std::collections::HashMap;

use super::synth::{PatientSim, SynthConfig};
use super::{Frame, Modality};
use crate::{Error, Result};

/// ECG frames a steady monitor emits per simulated second.
pub const FRAMES_PER_TICK: usize = 250;

/// Total tracked-patient capacity the churn scenario squeezes the shard
/// plane into (split evenly across shards: `CHURN_CAP_TOTAL / shards`
/// per shard). The churn id universe is twice this — the satellite
/// property: a stream churning at 2× `max_patients` must never drop a
/// new admission.
pub const CHURN_CAP_TOTAL: usize = 16;

/// Distinct patient ids the churn scenario cycles through.
pub const CHURN_UNIVERSE: usize = 2 * CHURN_CAP_TOTAL;

/// Admissions per churn tick. Divisible by every supported shard count
/// so each shard sees the same admission rate.
pub const CHURN_WAVE: usize = 8;

/// Simulated seconds one churn admission's window spans. Must stay
/// below the id reappearance period (`CHURN_UNIVERSE / CHURN_WAVE`
/// ticks) so a readmitted patient's frames are never stale.
const CHURN_WINDOW_SPAN_S: f64 = 3.0;

/// First ghost patient id in the burst-storm wave (disjoint from any
/// base cohort).
const GHOST_ID_BASE: usize = 10_000;

/// Named adversarial scenarios. `all()` is the catalog; the CLI and CI
/// address them by `name()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// Admission/discharge churn: `CHURN_WAVE` new beds per tick cycle
    /// through a 2×-capacity id universe; every admission completes one
    /// window and goes idle. Exercises the shard LRU eviction path —
    /// invariant: zero drops, evictions exactly `admissions − capacity`,
    /// identical for any shard count.
    Churn,
    /// Per-modality dropout and resync: each bed's ECG leads vanish
    /// mid-run while vitals continue, then resume with a gap. Over
    /// `--http` the dropout also severs the monitor's TCP link, so the
    /// `IngestClient` backoff-reconnect path is exercised for real.
    DropoutResync,
    /// Bounded clock skew between two monitors on the same bed: the
    /// interleaved stream is out of order by a known amount, and the
    /// stale-frame filter must shed exactly the predicted frames.
    ClockSkew,
    /// Shift-change burst: a 3×-bed ghost admission wave lands at once
    /// on a slowed backend; every admitted query must still resolve and
    /// the p95 must recover after the storm clears.
    BurstStorm,
    /// Hostile clients on the ingest edge: malformed-arity frames,
    /// oversized patient ids, and (over HTTP) corrupt wire bodies, NaN
    /// floods, truncated frames, slow-loris holds, and a connection
    /// flood — none of which may disturb the legitimate cohort.
    HostileEdge,
    /// Correlated vendor clock drift: every bed carries two interleaved
    /// monitors; on odd beds the second monitor is from a vendor whose
    /// clock starts drifting at a fixed rate after an onset tick —
    /// *together*, fleet-wide, the way a bad NTP rollout actually
    /// lands. Once the drift exceeds one sample period, every drifted
    /// sample must shed stale, and the budget predicts the exact count
    /// from the onset and rate. Vendor-A beds must be untouched.
    VendorSkew,
    /// Router-tier node loss: the cohort is served through `holmes
    /// route` over two peers; the peer owning patient 0 is killed
    /// mid-cohort and restarted later. The ring must re-home exactly
    /// the victim's patients to the survivor (minimal movement), every
    /// spilled frame must replay after failover, and the returned peer
    /// is canary-reinstated to serve a second admission wave.
    NodeLoss,
}

impl Scenario {
    pub fn all() -> [Scenario; 7] {
        [
            Scenario::Churn,
            Scenario::DropoutResync,
            Scenario::ClockSkew,
            Scenario::BurstStorm,
            Scenario::HostileEdge,
            Scenario::VendorSkew,
            Scenario::NodeLoss,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            Scenario::Churn => "churn",
            Scenario::DropoutResync => "dropout-resync",
            Scenario::ClockSkew => "clock-skew",
            Scenario::BurstStorm => "burst-storm",
            Scenario::HostileEdge => "hostile-edge",
            Scenario::VendorSkew => "vendor-skew",
            Scenario::NodeLoss => "node-loss",
        }
    }

    pub fn from_name(name: &str) -> Result<Scenario> {
        Scenario::all()
            .into_iter()
            .find(|s| s.name() == name)
            .ok_or_else(|| {
                Error::config(format!(
                    "unknown scenario '{name}' (known: churn, dropout-resync, clock-skew, \
                     burst-storm, hostile-edge, vendor-skew, node-loss, all)"
                ))
            })
    }
}

/// Scenario parameters shared by the live drivers and the budget dry
/// run — both must be built from the *same* value or the budget is
/// meaningless.
#[derive(Debug, Clone)]
pub struct ScenarioCfg {
    pub scenario: Scenario,
    /// Base cohort size (ignored by `churn`, which uses its own id
    /// universe).
    pub patients: usize,
    /// Simulated seconds to run; each monitor emits once per tick.
    pub ticks: u64,
    pub seed: u64,
    /// ECG samples per window (= the zoo's clip length).
    pub window_samples: usize,
    pub synth: SynthConfig,
}

impl ScenarioCfg {
    /// Simulated time after which the injected fault has cleared and
    /// the tail is expected back under the SLO (the recovery-phase
    /// boundary for the p95 invariant).
    pub fn recovery_start_sim(&self) -> f64 {
        match self.scenario {
            Scenario::BurstStorm => {
                let storm_start = self.ticks / 3;
                let ghost_ticks = self.window_samples.div_ceil(FRAMES_PER_TICK) as u64;
                (storm_start + ghost_ticks) as f64
            }
            _ => self.ticks as f64 * 2.0 / 3.0,
        }
    }
}

/// What one monitor emits for one simulated second.
pub struct TickEmit {
    pub frames: Vec<Frame>,
    /// HTTP replay: kill the monitor's TCP link *before* sending this
    /// tick's batch (the link died overnight; the client must redial).
    /// Severing pre-send keeps delivery exactly-once, so the fault
    /// budget stays exact.
    pub sever: bool,
}

enum Kind {
    /// One driver cycles `CHURN_WAVE` admissions/tick over the churn id
    /// universe; each admission streams one full window and goes idle.
    /// Single-threaded on purpose: cross-patient LRU order is the one
    /// thing multi-monitor interleave would make nondeterministic.
    Churn { sims: Vec<PatientSim> },
    /// A steady bed: 250 Hz ECG + 1 Hz vitals, with an optional ECG
    /// dropout interval `[start, end)` during which only vitals flow.
    /// Silent entirely before `admit` (late-wave admissions — the
    /// node-loss scenario's post-recovery cohort).
    Steady { sim: PatientSim, dropout: Option<(u64, u64)>, admit: u64 },
    /// Two virtual ECG monitors on one bed, sample-interleaved; monitor
    /// B's clock runs `skew_s` behind monitor A's.
    Skewed { sim: PatientSim, skew_s: f64 },
    /// Two interleaved monitors where monitor B's clock *drifts*:
    /// `skew(t) = rate_s × (t − onset)` once `t ≥ onset`, zero before.
    /// The correlated-vendor-failure shape — every vendor-B monitor in
    /// the fleet drifts in lockstep.
    VendorDrift { sim: PatientSim, onset: u64, rate_s: f64 },
    /// A shift-change ghost admission: silent until `start`, then
    /// streams exactly one window's worth of ECG and goes silent again.
    Ghost { sim: PatientSim, start: u64, emitted: usize },
    /// The frame-level hostile client: malformed-arity ECG aimed at a
    /// real bed plus valid frames under absurd (near-`usize::MAX`)
    /// patient ids. Byte-level hostility (corrupt bodies, slow loris)
    /// lives in the replay driver — it never becomes a `Frame`.
    Hostile,
}

/// One deterministic traffic source; the replay driver runs each on its
/// own thread (its own `IngestClient` over `--http`).
pub struct Monitor {
    kind: Kind,
    window_samples: usize,
    /// Stable index for logging and connection naming.
    pub index: usize,
}

impl Monitor {
    pub fn tick(&mut self, t: u64) -> TickEmit {
        let mut frames = Vec::new();
        let mut sever = false;
        match &mut self.kind {
            Kind::Churn { sims } => {
                let dt = CHURN_WINDOW_SPAN_S / self.window_samples as f64;
                for k in 0..CHURN_WAVE {
                    let pid = (t as usize * CHURN_WAVE + k) % CHURN_UNIVERSE;
                    let sim = &mut sims[pid];
                    for i in 0..self.window_samples {
                        frames.push(Frame {
                            patient: pid,
                            modality: Modality::Ecg,
                            sim_time: t as f64 + i as f64 * dt,
                            values: sim.next_ecg().into(),
                        });
                    }
                }
            }
            Kind::Steady { sim, dropout, admit } => {
                if t < *admit {
                    return TickEmit { frames, sever };
                }
                let in_dropout = dropout.is_some_and(|(s, e)| t >= s && t < e);
                sever = dropout.is_some_and(|(s, _)| t == s);
                if !in_dropout {
                    frames.extend(sim.ecg_frames(t as f64, FRAMES_PER_TICK));
                }
                frames.push(Frame {
                    patient: self.index,
                    modality: Modality::Vitals,
                    sim_time: t as f64,
                    values: sim.next_vitals().into(),
                });
            }
            Kind::Skewed { sim, skew_s } => {
                let dt = 1.0 / FRAMES_PER_TICK as f64;
                for i in 0..FRAMES_PER_TICK {
                    let true_t = t as f64 + i as f64 * dt;
                    // even samples come from monitor A (true clock),
                    // odd from monitor B (clock behind by skew_s)
                    let stamped = if i % 2 == 0 { true_t } else { true_t - *skew_s };
                    frames.push(Frame {
                        patient: self.index,
                        modality: Modality::Ecg,
                        sim_time: stamped,
                        values: sim.next_ecg().into(),
                    });
                }
            }
            Kind::VendorDrift { sim, onset, rate_s } => {
                let dt = 1.0 / FRAMES_PER_TICK as f64;
                let skew = if t >= *onset { *rate_s * (t - *onset) as f64 } else { 0.0 };
                for i in 0..FRAMES_PER_TICK {
                    let true_t = t as f64 + i as f64 * dt;
                    // even samples: monitor A (true clock); odd:
                    // monitor B (the drifting vendor)
                    let stamped = if i % 2 == 0 { true_t } else { true_t - skew };
                    frames.push(Frame {
                        patient: self.index,
                        modality: Modality::Ecg,
                        sim_time: stamped,
                        values: sim.next_ecg().into(),
                    });
                }
            }
            Kind::Ghost { sim, start, emitted } => {
                if t >= *start && *emitted < self.window_samples {
                    let n = FRAMES_PER_TICK.min(self.window_samples - *emitted);
                    let dt = 1.0 / FRAMES_PER_TICK as f64;
                    for i in 0..n {
                        frames.push(Frame {
                            patient: GHOST_ID_BASE + self.index,
                            modality: Modality::Ecg,
                            sim_time: t as f64 + i as f64 * dt,
                            values: sim.next_ecg().into(),
                        });
                    }
                    *emitted += n;
                }
            }
            Kind::Hostile => {
                // malformed lead arity on a real bed's id: must be
                // counted malformed without touching that bed's windows
                for i in 0..4 {
                    frames.push(Frame {
                        patient: 0,
                        modality: Modality::Ecg,
                        sim_time: t as f64 + i as f64 * 1e-3,
                        values: [9.9].into(),
                    });
                }
                // oversized ids: wire-valid, admitted as (useless)
                // aggregators — bounded by the shard patient cap
                let huge = usize::MAX - (t as usize % 3);
                for i in 0..2 {
                    frames.push(Frame {
                        patient: huge,
                        modality: Modality::Ecg,
                        sim_time: t as f64 + i as f64 * 0.5,
                        values: [0.5, 0.5, 0.5].into(),
                    });
                }
            }
        }
        TickEmit { frames, sever }
    }
}

/// Build the scenario's monitors. Deterministic in `cfg`; the budget
/// dry run and the live drivers each call this once and must feed the
/// monitors the same tick sequence `0..cfg.ticks`.
pub fn monitors(cfg: &ScenarioCfg) -> Vec<Monitor> {
    let sim = |id: usize, stream: u64| {
        PatientSim::new(id, cfg.seed.wrapping_add(stream), cfg.synth.clone())
    };
    let mut out = Vec::new();
    match cfg.scenario {
        Scenario::Churn => {
            let sims = (0..CHURN_UNIVERSE).map(|p| sim(p, p as u64)).collect();
            out.push(Monitor { kind: Kind::Churn { sims }, window_samples: cfg.window_samples, index: 0 });
        }
        Scenario::DropoutResync => {
            for p in 0..cfg.patients {
                let start = cfg.ticks / 3 + (p as u64 % 3);
                let len = (cfg.ticks / 4).max(2);
                let dropout = (start < cfg.ticks).then_some((start, (start + len).min(cfg.ticks)));
                out.push(Monitor {
                    kind: Kind::Steady { sim: sim(p, p as u64), dropout, admit: 0 },
                    window_samples: cfg.window_samples,
                    index: p,
                });
            }
        }
        Scenario::ClockSkew => {
            let dt = 1.0 / FRAMES_PER_TICK as f64;
            for p in 0..cfg.patients {
                // even beds: bounded skew within one sample period —
                // harmless. Odd beds: 2.5 periods behind — every B
                // sample lands behind the window position and must shed.
                let skew_s = if p % 2 == 0 { 0.5 * dt } else { 2.5 * dt };
                out.push(Monitor {
                    kind: Kind::Skewed { sim: sim(p, p as u64), skew_s },
                    window_samples: cfg.window_samples,
                    index: p,
                });
            }
        }
        Scenario::BurstStorm => {
            for p in 0..cfg.patients {
                out.push(Monitor {
                    kind: Kind::Steady { sim: sim(p, p as u64), dropout: None, admit: 0 },
                    window_samples: cfg.window_samples,
                    index: p,
                });
            }
            let storm_start = cfg.ticks / 3;
            for g in 0..3 * cfg.patients {
                out.push(Monitor {
                    kind: Kind::Ghost {
                        sim: sim(GHOST_ID_BASE + g, 7_000 + g as u64),
                        start: storm_start,
                        emitted: 0,
                    },
                    window_samples: cfg.window_samples,
                    index: g,
                });
            }
        }
        Scenario::HostileEdge => {
            for p in 0..cfg.patients {
                out.push(Monitor {
                    kind: Kind::Steady { sim: sim(p, p as u64), dropout: None, admit: 0 },
                    window_samples: cfg.window_samples,
                    index: p,
                });
            }
            out.push(Monitor {
                kind: Kind::Hostile,
                window_samples: cfg.window_samples,
                index: cfg.patients,
            });
        }
        Scenario::VendorSkew => {
            let dt = 1.0 / FRAMES_PER_TICK as f64;
            let onset = cfg.ticks / 3;
            for p in 0..cfg.patients {
                // even beds: both monitors vendor A (no drift). Odd
                // beds: monitor B is the bad vendor — drifting 1.5
                // sample periods further behind per tick, correlated
                // across every vendor-B bed (same onset, same rate).
                let rate_s = if p % 2 == 0 { 0.0 } else { 1.5 * dt };
                out.push(Monitor {
                    kind: Kind::VendorDrift { sim: sim(p, p as u64), onset, rate_s },
                    window_samples: cfg.window_samples,
                    index: p,
                });
            }
        }
        Scenario::NodeLoss => {
            // wave 1: the base cohort, present from t=0 — some of it
            // owned by the peer that will be killed. wave 2: a fresh
            // cohort admitted after the peer restarts, to prove the
            // canary-reinstated peer takes new patients.
            let wave2_admit = cfg.ticks * 2 / 3;
            for p in 0..cfg.patients {
                out.push(Monitor {
                    kind: Kind::Steady { sim: sim(p, p as u64), dropout: None, admit: 0 },
                    window_samples: cfg.window_samples,
                    index: p,
                });
            }
            for p in cfg.patients..2 * cfg.patients {
                out.push(Monitor {
                    kind: Kind::Steady {
                        sim: sim(p, p as u64),
                        dropout: None,
                        admit: wave2_admit,
                    },
                    window_samples: cfg.window_samples,
                    index: p,
                });
            }
        }
    }
    out
}

/// The exact fault budget a scenario injects, predicted by a dry run of
/// the same generators through a model of the aggregation plane. The
/// live run's counters must match these numbers exactly — any
/// difference is an invariant breach.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultBudget {
    /// Total frames the generators emit.
    pub frames_sent: u64,
    /// Frames the aggregators must reject for payload arity.
    pub frames_malformed: u64,
    /// ECG frames behind the window position (clock skew) — shed.
    pub frames_stale: u64,
    /// Frames dropped because the shard was at capacity with no idle
    /// victim (zero in every shipped scenario: churn always leaves
    /// idle aggregators to evict).
    pub frames_overcap: u64,
    /// Windows that complete — each must become exactly one query and
    /// one prediction.
    pub windows: u64,
    /// Idle aggregators evicted for admission churn.
    pub evictions: u64,
    /// Monitor-link severs injected (HTTP replay: the reconnect floor).
    pub severs: u64,
    /// Node-loss only: patients the router must re-home when the
    /// victim peer dies — exactly the wave-1 patients the 2-peer
    /// consistent-hash ring assigns to patient 0's owner (the kill
    /// script always kills that peer). Recomputed offline from
    /// [`crate::router::ring::Ring`], which is deterministic across
    /// processes by construction.
    pub rehomed_patients: u64,
}

/// Dry-run the scenario against a model of the shard plane and return
/// the exact expected counters.
///
/// The model mirrors `serving::shards::shard_loop` + `WindowAggregator`
/// semantics: admission (with LRU idle eviction at `max_patients` per
/// shard) happens for every frame, then the modality checks — arity →
/// malformed, ECG older than the newest accepted sample → stale,
/// otherwise the window fill advances.
///
/// Exactness argument for the interleave: monitors run concurrently in
/// the live system, so the mirror is only exact where its sequential
/// order can't matter. Per-patient decisions (stale, malformed, window
/// completion) depend only on that patient's frame order, which each
/// monitor preserves. Cross-patient decisions (eviction, overcap) are
/// only ever triggered by the churn scenario — which drives all
/// traffic from a single monitor precisely so that global order is
/// deterministic.
pub fn budget(cfg: &ScenarioCfg, shards: usize, max_patients: usize) -> FaultBudget {
    struct AggModel {
        fill: usize,
        last_ecg: f64,
    }
    struct ShardModel {
        aggs: HashMap<usize, AggModel>,
        last_touch: HashMap<usize, u64>,
        touch_seq: u64,
    }
    let mut plane: Vec<ShardModel> = (0..shards.max(1))
        .map(|_| ShardModel { aggs: HashMap::new(), last_touch: HashMap::new(), touch_seq: 0 })
        .collect();
    let mut b = FaultBudget::default();
    let mut mons = monitors(cfg);
    for t in 0..cfg.ticks {
        for mon in &mut mons {
            let emit = mon.tick(t);
            if emit.sever {
                b.severs += 1;
            }
            for f in emit.frames {
                b.frames_sent += 1;
                let sh = &mut plane[f.patient % shards.max(1)];
                if !sh.aggs.contains_key(&f.patient) {
                    if sh.aggs.len() >= max_patients {
                        let victim = sh
                            .aggs
                            .iter()
                            .filter(|(_, a)| a.fill == 0)
                            .map(|(&p, _)| (sh.last_touch.get(&p).copied().unwrap_or(0), p))
                            .min();
                        match victim {
                            Some((_, victim)) => {
                                sh.aggs.remove(&victim);
                                sh.last_touch.remove(&victim);
                                b.evictions += 1;
                            }
                            None => {
                                b.frames_overcap += 1;
                                continue;
                            }
                        }
                    }
                    sh.aggs.insert(f.patient, AggModel { fill: 0, last_ecg: f64::NEG_INFINITY });
                }
                sh.touch_seq += 1;
                sh.last_touch.insert(f.patient, sh.touch_seq);
                let agg = sh.aggs.get_mut(&f.patient).expect("inserted above");
                match f.modality {
                    Modality::Ecg => {
                        if f.values.len() != 3 {
                            b.frames_malformed += 1;
                        } else if f.sim_time < agg.last_ecg {
                            b.frames_stale += 1;
                        } else {
                            agg.last_ecg = f.sim_time;
                            agg.fill += 1;
                            if agg.fill >= cfg.window_samples {
                                agg.fill = 0;
                                b.windows += 1;
                            }
                        }
                    }
                    Modality::Vitals => {
                        if f.values.len() != 7 {
                            b.frames_malformed += 1;
                        }
                    }
                    Modality::Labs => {
                        if f.values.len() != 8 {
                            b.frames_malformed += 1;
                        }
                    }
                }
            }
        }
    }
    if cfg.scenario == Scenario::NodeLoss {
        // mirror the router's ring: the replay kill script kills the
        // peer that owns patient 0, so exactly the wave-1 patients
        // sharing that owner re-home (the ring's minimal-movement
        // property makes this set the whole re-home budget)
        let ring = crate::router::ring::Ring::new(2);
        let victim = ring.route(0);
        b.rehomed_patients =
            (0..cfg.patients).filter(|&p| ring.route(p) == victim).count() as u64;
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(scenario: Scenario) -> ScenarioCfg {
        ScenarioCfg {
            scenario,
            patients: 4,
            ticks: 8,
            seed: 11,
            window_samples: 250,
            synth: SynthConfig::default(),
        }
    }

    #[test]
    fn scenario_names_roundtrip() {
        for s in Scenario::all() {
            assert_eq!(Scenario::from_name(s.name()).unwrap(), s);
        }
        assert!(Scenario::from_name("nope").is_err());
    }

    #[test]
    fn churn_budget_matches_closed_form() {
        let b = budget(&cfg(Scenario::Churn), 2, CHURN_CAP_TOTAL / 2);
        let admissions = 8 * CHURN_WAVE as u64; // ticks × wave
        assert_eq!(b.windows, admissions, "every admission completes one window");
        assert_eq!(b.frames_sent, admissions * 250);
        assert_eq!(b.evictions, admissions - CHURN_CAP_TOTAL as u64);
        assert_eq!(b.frames_overcap, 0, "an idle victim always exists");
        assert_eq!(b.frames_stale + b.frames_malformed, 0);
    }

    #[test]
    fn churn_budget_is_shard_count_invariant() {
        let base = budget(&cfg(Scenario::Churn), 1, CHURN_CAP_TOTAL);
        for shards in [2usize, 4, 8] {
            let b = budget(&cfg(Scenario::Churn), shards, CHURN_CAP_TOTAL / shards);
            assert_eq!(b, base, "{shards} shards");
        }
    }

    #[test]
    fn clock_skew_budget_sheds_only_the_lagging_monitor() {
        let b = budget(&cfg(Scenario::ClockSkew), 1, 1024);
        // odd beds (2 of 4) shed every B sample: 125 per tick × 8 ticks
        assert_eq!(b.frames_stale, 2 * 125 * 8);
        assert_eq!(b.frames_malformed, 0);
        // even beds keep all 2000 samples → 8 windows each at 250/window;
        // odd beds keep 1000 → 4 windows each
        assert_eq!(b.windows, 2 * 8 + 2 * 4);
    }

    #[test]
    fn dropout_budget_counts_severs_and_reduced_windows() {
        let b = budget(&cfg(Scenario::DropoutResync), 4, 1024);
        assert_eq!(b.severs, 4, "one link sever per bed");
        let steady = budget(&cfg(Scenario::BurstStorm), 4, 1024);
        assert!(b.windows < steady.windows, "dropout must cost windows");
        assert_eq!(b.frames_stale, 0, "resync resumes on the true clock");
    }

    #[test]
    fn hostile_budget_isolates_malformed_from_the_cohort() {
        let b = budget(&cfg(Scenario::HostileEdge), 2, 1024);
        assert_eq!(b.frames_malformed, 4 * 8, "4 malformed frames × 8 ticks");
        assert_eq!(b.frames_stale, 0);
        assert_eq!(b.frames_overcap, 0);
        // the legit cohort's windows are untouched by the hostile noise:
        // 4 beds × 8 ticks × 250 = 8000 accepted samples → 32 windows
        assert_eq!(b.windows, 32);
    }

    #[test]
    fn budgets_are_deterministic() {
        for s in Scenario::all() {
            assert_eq!(budget(&cfg(s), 2, 8), budget(&cfg(s), 2, 8), "{}", s.name());
        }
    }

    #[test]
    fn vendor_skew_budget_sheds_exactly_after_drift_onset() {
        let b = budget(&cfg(Scenario::VendorSkew), 1, 1024);
        // onset = ticks/3 = 2, rate 1.5 sample periods per tick: the
        // drift exceeds one period from tick 3 on, so odd (vendor-B)
        // beds shed all 125 B samples on ticks 3..8 — 5 ticks, 2 beds
        assert_eq!(b.frames_stale, 2 * 5 * 125, "correlated drift sheds");
        assert_eq!(b.frames_malformed, 0);
        assert_eq!(b.frames_overcap, 0);
        // vendor-A beds keep all 2000 samples → 8 windows each; B beds
        // keep 3×250 + 5×125 = 1375 → 5 windows each
        assert_eq!(b.windows, 2 * 8 + 2 * 5);
        assert_eq!(b.frames_sent, 4 * 8 * 250);
    }

    #[test]
    fn vendor_skew_budget_is_shard_count_invariant() {
        let base = budget(&cfg(Scenario::VendorSkew), 1, 1024);
        for shards in [2usize, 4, 8] {
            assert_eq!(budget(&cfg(Scenario::VendorSkew), shards, 1024), base, "{shards} shards");
        }
    }

    #[test]
    fn node_loss_budget_mirrors_the_router_ring() {
        let b = budget(&cfg(Scenario::NodeLoss), 2, 1024);
        // the budget's re-home count must agree with the real ring the
        // router routes by — same hash, same vnode count
        let ring = crate::router::ring::Ring::new(2);
        let victim = ring.route(0);
        let expect = (0..4usize).filter(|&p| ring.route(p) == victim).count() as u64;
        assert_eq!(b.rehomed_patients, expect);
        assert!(b.rehomed_patients >= 1, "patient 0's owner owns patient 0");
        assert!(b.rehomed_patients < 4, "the ring must spread 4 patients over 2 peers");
        // wave 2 (4 more beds) joins at tick 2·8/3 = 5: 3 ticks of
        // 250 ECG + 1 vitals per bed
        assert_eq!(b.frames_sent, 4 * 8 * 251 + 4 * 3 * 251);
        assert_eq!(b.windows, 4 * 8 + 4 * 3);
        assert_eq!(b.frames_stale + b.frames_malformed + b.frames_overcap, 0);
        assert_eq!(b.severs, 0, "node loss severs links at the router, not the monitors");
    }

    #[test]
    fn non_node_loss_budgets_have_zero_rehome() {
        for s in Scenario::all() {
            if s != Scenario::NodeLoss {
                assert_eq!(budget(&cfg(s), 2, 1024).rehomed_patients, 0, "{}", s.name());
            }
        }
    }
}
