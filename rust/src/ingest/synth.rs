//! Synthetic bedside-monitor simulator — the rust mirror of
//! `python/compile/data.py` (shared calibration constants live in the
//! zoo manifest; `tests` asserts agreement with them).
//!
//! Each patient carries a latent severity state s ∈ [0,1] that drives
//! ECG morphology (heart rate, HRV, ST level, QRS width, noise/sensor
//! dropouts), the 7 vitals, and the 8 labs. Critical patients (label 0)
//! have high severity, stable ones (label 1) low, with overlapping
//! supports — so the served models face the same distribution they were
//! trained on.

use super::{Frame, Modality};
use crate::rng::Rng;
use crate::zoo::Calibration;

/// Generator configuration (defaults match `data.calibration_constants`).
#[derive(Debug, Clone)]
pub struct SynthConfig {
    pub fs: f64,
    pub lead_amp: [f64; 3],
    pub lead_noise: [f64; 3],
    pub hr_base: f64,
    pub hr_sev_gain: f64,
    pub hrv_base: f64,
    pub hrv_stable_gain: f64,
    pub st_depression: f64,
    pub noise_base: f64,
    pub noise_sev_gain: f64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            fs: 250.0,
            lead_amp: [0.8, 1.0, 0.6],
            lead_noise: [1.2, 0.8, 1.5],
            hr_base: 95.0,
            hr_sev_gain: 75.0,
            hrv_base: 0.012,
            hrv_stable_gain: 0.09,
            st_depression: -0.18,
            noise_base: 0.035,
            noise_sev_gain: 0.09,
        }
    }
}

impl From<&Calibration> for SynthConfig {
    fn from(c: &Calibration) -> Self {
        SynthConfig {
            fs: c.fs as f64,
            lead_amp: [c.lead_amp[0], c.lead_amp[1], c.lead_amp[2]],
            lead_noise: [c.lead_noise[0], c.lead_noise[1], c.lead_noise[2]],
            hr_base: c.hr_base,
            hr_sev_gain: c.hr_sev_gain,
            hrv_base: c.hrv_base,
            hrv_stable_gain: c.hrv_stable_gain,
            st_depression: c.st_depression,
            noise_base: c.noise_base,
            noise_sev_gain: c.noise_sev_gain,
        }
    }
}

/// Latent patient state.
#[derive(Debug, Clone, Copy)]
pub struct PatientState {
    /// Ground-truth outcome: 1 = stable (ready for step-down), 0 = critical.
    pub label: u8,
    /// Latent severity s ∈ [0,1].
    pub severity: f64,
}

/// Streaming simulator for one patient: produces ECG frames at 250 Hz,
/// vitals at 1 Hz and labs every ~5 min of *simulation* time.
pub struct PatientSim {
    pub id: usize,
    pub state: PatientState,
    cfg: SynthConfig,
    rng: Rng,
    // ECG phase machinery
    rr_samples: f64,
    beat_pos: f64, // samples since current beat start
    hr: f64,
    noise_sd: [f64; 3],
    sample_idx: u64,
    // sensor-dropout burst window (sample indices)
    dropout_until: u64,
}

impl PatientSim {
    pub fn new(id: usize, seed: u64, cfg: SynthConfig) -> Self {
        let mut rng = Rng::seed_from_u64(seed.wrapping_mul(1_000_003).wrapping_add(id as u64));
        let label = if rng.f64() < 0.45 { 1 } else { 0 };
        let severity = severity_for_label(&mut rng, label);
        Self::with_state(id, seed, cfg, PatientState { label, severity })
    }

    pub fn with_state(id: usize, seed: u64, cfg: SynthConfig, state: PatientState) -> Self {
        let mut rng =
            Rng::seed_from_u64(seed.wrapping_mul(1_000_003).wrapping_add(id as u64));
        let hr = (cfg.hr_base + cfg.hr_sev_gain * state.severity + 6.0 * rng.normal())
            .clamp(60.0, 220.0);
        let mut noise_sd = [0.0; 3];
        for lead in 0..3 {
            noise_sd[lead] = (cfg.noise_base
                + cfg.noise_sev_gain * state.severity * rng.range_f64(0.5, 1.5))
                * cfg.lead_noise[lead];
        }
        let rr = cfg.fs * 60.0 / hr;
        PatientSim {
            id,
            state,
            cfg,
            rng,
            rr_samples: rr,
            beat_pos: 0.0,
            hr,
            noise_sd,
            sample_idx: 0,
            dropout_until: 0,
        }
    }

    /// Next ECG sample for all 3 leads (advance by 1/fs seconds).
    pub fn next_ecg(&mut self) -> [f32; 3] {
        let s = self.state.severity;
        let phase = self.beat_pos / self.rr_samples;
        let t_abs = self.sample_idx as f64 / self.cfg.fs;
        let mut out = [0.0f32; 3];
        let in_dropout = self.sample_idx < self.dropout_until;
        for lead in 0..3 {
            let v = if in_dropout {
                0.02 * self.rng.normal()
            } else {
                beat_waveform(phase, s, self.cfg.st_depression) * self.cfg.lead_amp[lead]
                    + 0.05 * (2.0 * std::f64::consts::PI * 0.25 * t_abs).sin()
                    + self.noise_sd[lead] * self.rng.normal()
            };
            out[lead] = v as f32;
        }
        self.beat_pos += 1.0;
        self.sample_idx += 1;
        if self.beat_pos >= self.rr_samples {
            self.beat_pos -= self.rr_samples;
            // next RR interval with severity-dependent HRV
            let hrv = self.cfg.hrv_stable_gain * (1.0 - s) + self.cfg.hrv_base;
            self.rr_samples =
                (self.cfg.fs * 60.0 / self.hr * (1.0 + hrv * self.rng.normal()))
                    .max(self.cfg.fs * 60.0 / 230.0);
            // occasional dropout burst, sicker ⇒ likelier
            if self.rng.f64() < (0.002 + 0.006 * s) {
                let len = self.rng.range_f64(0.2, 1.0) * self.cfg.fs;
                self.dropout_until = self.sample_idx + len as u64;
            }
        }
        out
    }

    /// Current 7-vitals vector (1 Hz): HR, mean BP, SpO2, RR, temp, CVP, perfusion.
    pub fn next_vitals(&mut self) -> [f32; 7] {
        let s = self.state.severity;
        let n = |rng: &mut Rng, sd: f64| sd * rng.normal();
        [
            (self.hr + n(&mut self.rng, 3.0)) as f32,
            (72.0 - 18.0 * s + n(&mut self.rng, 4.0)) as f32,
            (98.0 - 9.0 * s + n(&mut self.rng, 1.0)) as f32,
            (22.0 + 16.0 * s + n(&mut self.rng, 2.0)) as f32,
            (36.8 + 0.8 * s + n(&mut self.rng, 0.2)) as f32,
            (6.0 + 6.0 * s + n(&mut self.rng, 1.0)) as f32,
            (1.4 - 0.9 * s + n(&mut self.rng, 0.15)) as f32,
        ]
    }

    /// 8 lab values (irregular): pH, lactate, K, Na, Cr, BUN, Hgb, WBC.
    pub fn next_labs(&mut self) -> [f32; 8] {
        let s = self.state.severity;
        let n = |rng: &mut Rng, sd: f64| sd * rng.normal();
        [
            (7.40 - 0.12 * s + n(&mut self.rng, 0.02)) as f32,
            (1.0 + 4.0 * s + n(&mut self.rng, 0.4)) as f32,
            (4.0 + 0.8 * s + n(&mut self.rng, 0.3)) as f32,
            (140.0 - 3.0 * s + n(&mut self.rng, 2.0)) as f32,
            (0.4 + 0.5 * s + n(&mut self.rng, 0.08)) as f32,
            (12.0 + 14.0 * s + n(&mut self.rng, 2.0)) as f32,
            (14.0 - 2.5 * s + n(&mut self.rng, 0.8)) as f32,
            (9.0 + 7.0 * s + n(&mut self.rng, 1.5)) as f32,
        ]
    }

    /// Produce a batch of ECG frames covering `n` samples from `t0_sim`.
    /// Each frame's payload is the inline fixed-capacity buffer — the
    /// generator allocates nothing per frame.
    pub fn ecg_frames(&mut self, t0_sim: f64, n: usize) -> Vec<Frame> {
        (0..n)
            .map(|i| {
                let v = self.next_ecg();
                Frame {
                    patient: self.id,
                    modality: Modality::Ecg,
                    sim_time: t0_sim + i as f64 / self.cfg.fs,
                    values: v.into(),
                }
            })
            .collect()
    }
}

/// One cardiac cycle evaluated at normalised phase ∈ [0,1): sum of
/// P-QRS-T gaussians with severity-dependent morphology (mirror of
/// `data.beat_template`).
pub fn beat_waveform(phase: f64, severity: f64, st_depression: f64) -> f64 {
    let qrs_width = 0.018 * (1.0 + 0.9 * severity);
    let t_amp = 0.30 * (1.0 - 0.45 * severity);
    let st_level = st_depression * severity;
    let g = |center: f64, width: f64, amp: f64| {
        amp * (-0.5 * ((phase - center) / width).powi(2)).exp()
    };
    g(0.18, 0.025, 0.12) - g(0.385, qrs_width * 0.7, 0.22) + g(0.40, qrs_width, 1.00)
        - g(0.42, qrs_width * 0.8, 0.28)
        + g(0.62, 0.045, t_amp)
        + st_level * g(0.51, 0.05, 1.0)
}

/// Severity prior: stable ~ Beta(2,5), critical ~ Beta(5,2).
pub fn severity_for_label(rng: &mut Rng, label: u8) -> f64 {
    let (a, b) = if label == 1 { (2.0, 5.0) } else { (5.0, 2.0) };
    rng.beta(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = PatientSim::new(3, 42, SynthConfig::default());
        let mut b = PatientSim::new(3, 42, SynthConfig::default());
        for _ in 0..500 {
            assert_eq!(a.next_ecg(), b.next_ecg());
        }
    }

    #[test]
    fn different_patients_differ() {
        let mut a = PatientSim::new(0, 42, SynthConfig::default());
        let mut b = PatientSim::new(1, 42, SynthConfig::default());
        let va: Vec<_> = (0..100).map(|_| a.next_ecg()[1]).collect();
        let vb: Vec<_> = (0..100).map(|_| b.next_ecg()[1]).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn critical_patients_are_tachycardic() {
        let cfg = SynthConfig::default();
        let mut crit_hr = 0.0;
        let mut stab_hr = 0.0;
        let n = 40;
        for i in 0..n {
            let mut rng = Rng::seed_from_u64(i);
            let sc = PatientSim::with_state(
                i as usize,
                i,
                cfg.clone(),
                PatientState { label: 0, severity: severity_for_label(&mut rng, 0) },
            );
            crit_hr += sc.hr;
            let mut rng = Rng::seed_from_u64(i + 1000);
            let ss = PatientSim::with_state(
                i as usize,
                i + 1000,
                cfg.clone(),
                PatientState { label: 1, severity: severity_for_label(&mut rng, 1) },
            );
            stab_hr += ss.hr;
        }
        assert!(crit_hr / n as f64 > stab_hr / n as f64 + 15.0);
    }

    #[test]
    fn beat_waveform_r_peak_dominates() {
        let r = beat_waveform(0.40, 0.2, -0.18);
        let baseline = beat_waveform(0.95, 0.2, -0.18);
        assert!(r > 0.7);
        assert!(baseline.abs() < 0.1);
    }

    #[test]
    fn st_depression_lowers_st_segment_when_severe() {
        let healthy = beat_waveform(0.51, 0.0, -0.18);
        let sick = beat_waveform(0.51, 1.0, -0.18);
        assert!(sick < healthy);
    }

    #[test]
    fn severity_prior_ordering() {
        let mut rng = Rng::seed_from_u64(5);
        let s1: f64 = (0..300).map(|_| severity_for_label(&mut rng, 1)).sum::<f64>() / 300.0;
        let s0: f64 = (0..300).map(|_| severity_for_label(&mut rng, 0)).sum::<f64>() / 300.0;
        assert!(s0 > s1 + 0.2, "critical {s0} vs stable {s1}");
        // Beta(2,5) mean ≈ 0.286, Beta(5,2) mean ≈ 0.714
        assert!((s1 - 0.286).abs() < 0.06);
        assert!((s0 - 0.714).abs() < 0.06);
    }

    #[test]
    fn vitals_and_labs_track_severity() {
        let cfg = SynthConfig::default();
        let mut sick = PatientSim::with_state(
            0,
            1,
            cfg.clone(),
            PatientState { label: 0, severity: 0.95 },
        );
        let mut well =
            PatientSim::with_state(1, 2, cfg, PatientState { label: 1, severity: 0.05 });
        let vs = sick.next_vitals();
        let vw = well.next_vitals();
        assert!(vs[2] < vw[2]); // SpO2 lower when sick
        let ls = sick.next_labs();
        let lw = well.next_labs();
        assert!(ls[1] > lw[1]); // lactate higher when sick
    }

    #[test]
    fn ecg_frames_timestamps_are_uniform() {
        let mut p = PatientSim::new(0, 9, SynthConfig::default());
        let frames = p.ecg_frames(10.0, 5);
        assert_eq!(frames.len(), 5);
        for (i, f) in frames.iter().enumerate() {
            assert!((f.sim_time - (10.0 + i as f64 / 250.0)).abs() < 1e-9);
            assert_eq!(f.values.len(), 3);
        }
    }
}
