//! Dependency-free little-endian wire codec for [`Frame`] — the binary
//! framing behind the HTTP `POST /ingest.bin` route.
//!
//! At 100 beds × 250 Hz the ingest edge sees ~25k frames/s; parsing
//! each frame through the recursive-descent JSON parser costs one
//! `Value` tree plus several `Vec` allocations per sample. The wire
//! format decodes with **zero allocation**: the payload lands directly
//! in the frame's inline fixed-capacity buffer
//! ([`FrameValues`](super::FrameValues)).
//!
//! ## Frame layout (all integers/floats little-endian)
//!
//! ```text
//!  offset  size  field
//!  0       4     magic     = b"HLM1"
//!  4       1     version   = 1
//!  5       1     modality  (0 = ecg, 1 = vitals, 2 = labs)
//!  6       2     reserved  = 0
//!  8       8     patient   (u64)
//!  16      8     sim_time  (f64, finite)
//!  24      4     n_values  (u32, ≤ MAX_WIRE_VALUES = 8)
//!  28      4·n   values    (f32 each, finite — NaN/±inf rejected)
//! ```
//!
//! A request body may carry any number of frames back to back
//! ([`decode_stream`]); each frame is self-delimiting via `n_values`.
//! Decoding is total: truncated or corrupt buffers return
//! [`Error::Wire`], never panic, and never allocate.
//!
//! ## Incremental decoding
//!
//! The event-driven ingest edge decodes frames **in place** from a
//! per-connection receive buffer as bytes arrive, at arbitrary read
//! fragmentation. [`decode_step`] is the resumable entry point: it
//! distinguishes *"this prefix is fine, more bytes will complete it"*
//! ([`DecodeStep::NeedMore`]) from *hard corruption* (`Err`), and it
//! rejects bad magic/version/modality bytes as soon as they are
//! visible — a drip-feeding client sending garbage is refused on the
//! first bad byte, not after a full sham header. [`Frame::from_bytes`]
//! is the one-shot wrapper (`NeedMore` becomes a truncation error).

use super::{Frame, FrameValues, Modality, MAX_FRAME_VALUES};
use crate::{Error, Result};

/// First four body bytes of every wire frame.
pub const WIRE_MAGIC: [u8; 4] = *b"HLM1";

/// Current wire-format version.
pub const WIRE_VERSION: u8 = 1;

/// Fixed header size preceding the f32 payload.
pub const WIRE_HEADER_LEN: usize = 28;

/// Upper bound on `n_values` — the widest real payload is the 8-value
/// labs vector ([`MAX_FRAME_VALUES`]), and the decode target is an
/// inline buffer of exactly that capacity, so a hostile length prefix
/// cannot touch memory at all (it fails the bound check before any
/// payload byte is read).
pub const MAX_WIRE_VALUES: usize = MAX_FRAME_VALUES;

impl Modality {
    /// Wire-format discriminant.
    pub fn wire_code(&self) -> u8 {
        match self {
            Modality::Ecg => 0,
            Modality::Vitals => 1,
            Modality::Labs => 2,
        }
    }

    /// Inverse of [`Modality::wire_code`].
    pub fn from_wire_code(code: u8) -> Result<Modality> {
        match code {
            0 => Ok(Modality::Ecg),
            1 => Ok(Modality::Vitals),
            2 => Ok(Modality::Labs),
            other => Err(Error::wire(format!("unknown modality code {other}"))),
        }
    }
}

impl Frame {
    /// Encoded size of this frame on the wire.
    pub fn wire_len(&self) -> usize {
        WIRE_HEADER_LEN + 4 * self.values.len()
    }

    /// Append the wire encoding to `out` (streaming multi-frame bodies
    /// reuse one buffer across frames).
    pub fn write_bytes(&self, out: &mut Vec<u8>) {
        out.reserve(self.wire_len());
        out.extend_from_slice(&WIRE_MAGIC);
        out.push(WIRE_VERSION);
        out.push(self.modality.wire_code());
        out.extend_from_slice(&[0u8; 2]); // reserved
        out.extend_from_slice(&(self.patient as u64).to_le_bytes());
        out.extend_from_slice(&self.sim_time.to_le_bytes());
        out.extend_from_slice(&(self.values.len() as u32).to_le_bytes());
        for v in self.values.iter() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Encode into a fresh buffer.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_len());
        self.write_bytes(&mut out);
        out
    }

    /// Decode one frame from the front of `buf`; returns the frame and
    /// the number of bytes consumed. Total: truncated, corrupt, or
    /// non-finite input yields `Err`, never a panic.
    pub fn from_bytes(buf: &[u8]) -> Result<(Frame, usize)> {
        match decode_step(buf)? {
            DecodeStep::Frame(frame, used) => Ok((frame, used)),
            DecodeStep::NeedMore(need) => {
                Err(Error::wire(format!("truncated frame: {} of {need} bytes", buf.len())))
            }
        }
    }
}

/// Outcome of one [`decode_step`] attempt on a (possibly partial)
/// buffer prefix.
#[derive(Debug, Clone, Copy)]
pub enum DecodeStep {
    /// A complete frame was decoded from the front of the buffer; the
    /// `usize` is the number of bytes consumed.
    Frame(Frame, usize),
    /// The buffer holds a valid *prefix* of a frame but not a whole
    /// one; the `usize` is the total byte count (from the buffer
    /// start) at which the frame can complete. Resume once more bytes
    /// arrive — no work is repeated beyond re-reading the header.
    NeedMore(usize),
}

/// Resumable single-frame decode for incremental (event-driven)
/// readers: distinguishes *need more bytes* ([`DecodeStep::NeedMore`])
/// from hard corruption (`Err`). Every byte of the fixed header that
/// is already present is validated, so corrupt input fails at the
/// first offending byte even before the header completes.
pub fn decode_step(buf: &[u8]) -> Result<DecodeStep> {
    // validate whatever header prefix has arrived so far
    let have = buf.len().min(WIRE_HEADER_LEN);
    let magic = have.min(4);
    if buf[..magic] != WIRE_MAGIC[..magic] {
        return Err(Error::wire("bad magic (expected HLM1)"));
    }
    if have > 4 && buf[4] != WIRE_VERSION {
        return Err(Error::wire(format!("unsupported wire version {}", buf[4])));
    }
    if have > 5 {
        Modality::from_wire_code(buf[5])?;
    }
    if (have > 6 && buf[6] != 0) || (have > 7 && buf[7] != 0) {
        return Err(Error::wire("nonzero reserved bytes"));
    }
    if buf.len() < WIRE_HEADER_LEN {
        return Ok(DecodeStep::NeedMore(WIRE_HEADER_LEN));
    }
    let modality = Modality::from_wire_code(buf[5])?;
    // the wire field is a u64 but `Frame.patient` is a usize: a
    // lossy `as` cast would silently alias two distinct patients
    // into one aggregator on 32-bit targets — reject instead (the
    // frame counts as malformed/dropped upstream)
    let patient_raw = u64::from_le_bytes(take8(buf, 8));
    let patient = usize::try_from(patient_raw).map_err(|_| {
        Error::wire(format!("patient id {patient_raw} exceeds this platform's usize"))
    })?;
    let sim_time = f64::from_le_bytes(take8(buf, 16));
    if !sim_time.is_finite() {
        return Err(Error::wire("non-finite sim_time"));
    }
    let n = u32::from_le_bytes(take4(buf, 24)) as usize;
    if n > MAX_WIRE_VALUES {
        return Err(Error::wire(format!("payload length {n} exceeds {MAX_WIRE_VALUES}")));
    }
    let total = WIRE_HEADER_LEN + 4 * n;
    if buf.len() < total {
        return Ok(DecodeStep::NeedMore(total));
    }
    let mut values = FrameValues::new();
    for (i, chunk) in buf[WIRE_HEADER_LEN..total].chunks_exact(4).enumerate() {
        let v = f32::from_le_bytes(chunk.try_into().expect("chunks_exact(4)"));
        if !v.is_finite() {
            return Err(Error::wire(format!("non-finite payload value at index {i}")));
        }
        // cannot overflow: n ≤ MAX_WIRE_VALUES = the buffer capacity
        let _ = values.push(v);
    }
    Ok(DecodeStep::Frame(Frame { patient, modality, sim_time, values }, total))
}

/// First four body bytes of a router heartbeat probe.
pub const HEARTBEAT_MAGIC: [u8; 4] = *b"HLMH";

/// First four body bytes of a router frame-batch envelope header.
pub const BATCH_MAGIC: [u8; 4] = *b"HLMB";

/// First four body bytes of a batch-sequence tag: identifies the batch
/// that follows (`HLMB` + frames) by a per-link token and a monotonic
/// sequence number, so a peer can ignore a re-POST of a batch it
/// already admitted (a retry after the response was lost) instead of
/// double-delivering its frames.
pub const BATCH_SEQ_MAGIC: [u8; 4] = *b"HLMS";

/// Encoded size of a heartbeat: magic(4) + version(1) + reserved(3) +
/// seq(8).
pub const HEARTBEAT_LEN: usize = 16;

/// Encoded size of a batch envelope header: magic(4) + version(1) +
/// reserved(3) + n_frames(4). The `n_frames` wire frames follow back
/// to back.
pub const BATCH_HEADER_LEN: usize = 12;

/// Encoded size of a batch-sequence tag: magic(4) + version(1) +
/// reserved(3) + token(8) + seq(8).
pub const BATCH_SEQ_LEN: usize = 24;

/// Encode a router heartbeat probe body.
pub fn encode_heartbeat(seq: u64) -> [u8; HEARTBEAT_LEN] {
    let mut out = [0u8; HEARTBEAT_LEN];
    out[..4].copy_from_slice(&HEARTBEAT_MAGIC);
    out[4] = WIRE_VERSION;
    out[8..16].copy_from_slice(&seq.to_le_bytes());
    out
}

/// Append a batch envelope header announcing `n_frames` frames to
/// `out`; the caller appends the frames themselves with
/// [`Frame::write_bytes`].
pub fn write_batch_header(n_frames: u32, out: &mut Vec<u8>) {
    out.reserve(BATCH_HEADER_LEN);
    out.extend_from_slice(&BATCH_MAGIC);
    out.push(WIRE_VERSION);
    out.extend_from_slice(&[0u8; 3]); // reserved
    out.extend_from_slice(&n_frames.to_le_bytes());
}

/// Append a batch-sequence tag to `out`. The tag applies to the next
/// `HLMB` batch in the body: a peer that has already admitted
/// `(token, seq)` skips the batch's frames (and counts them in its
/// `frames_deduped` gauge) while still answering 2xx, making link
/// retries exactly-once instead of at-least-once.
pub fn write_batch_seq(token: u64, seq: u64, out: &mut Vec<u8>) {
    out.reserve(BATCH_SEQ_LEN);
    out.extend_from_slice(&BATCH_SEQ_MAGIC);
    out.push(WIRE_VERSION);
    out.extend_from_slice(&[0u8; 3]); // reserved
    out.extend_from_slice(&token.to_le_bytes());
    out.extend_from_slice(&seq.to_le_bytes());
}

/// Outcome of one [`decode_envelope_step`] attempt. A superset of
/// [`DecodeStep`]: the router tier speaks heartbeats, batch-sequence
/// tags, and frame-batch envelopes over the same `/ingest.bin` route,
/// and all the record types share the `HLM` magic prefix so early
/// garbage rejection is as eager as for plain frames.
#[derive(Debug, Clone, Copy)]
pub enum EnvelopeStep {
    /// A complete plain wire frame (same as [`DecodeStep::Frame`]).
    Frame(Frame, usize),
    /// A complete heartbeat probe.
    Heartbeat { seq: u64, used: usize },
    /// A complete batch-sequence tag: applies to the next batch.
    BatchSeq { token: u64, seq: u64, used: usize },
    /// A batch envelope header: `n_frames` wire frames follow.
    BatchStart { n_frames: u32, used: usize },
    /// Valid prefix of one of the above; resume with more bytes.
    NeedMore(usize),
}

/// Resumable decode of the router envelope stream: plain frames
/// (`HLM1`, delegated to [`decode_step`]), heartbeats (`HLMH`), batch
/// headers (`HLMB`), and batch-sequence tags (`HLMS`). Unknown fourth
/// bytes after a valid `HLM` prefix are hard errors, as are bad
/// version/reserved bytes, detected as soon as the offending byte is
/// visible.
pub fn decode_envelope_step(buf: &[u8]) -> Result<EnvelopeStep> {
    let prefix = buf.len().min(3);
    if buf[..prefix] != WIRE_MAGIC[..prefix] {
        return Err(Error::wire("bad magic (expected HLM prefix)"));
    }
    if buf.len() < 4 {
        return Ok(EnvelopeStep::NeedMore(4));
    }
    match buf[3] {
        b'1' => Ok(match decode_step(buf)? {
            DecodeStep::Frame(frame, used) => EnvelopeStep::Frame(frame, used),
            DecodeStep::NeedMore(need) => EnvelopeStep::NeedMore(need),
        }),
        b'H' => {
            let total = HEARTBEAT_LEN;
            if buf.len() > 4 && buf[4] != WIRE_VERSION {
                return Err(Error::wire(format!("unsupported wire version {}", buf[4])));
            }
            for at in 5..8usize.min(buf.len()) {
                if buf[at] != 0 {
                    return Err(Error::wire("nonzero reserved bytes"));
                }
            }
            if buf.len() < total {
                return Ok(EnvelopeStep::NeedMore(total));
            }
            let seq = u64::from_le_bytes(take8(buf, 8));
            Ok(EnvelopeStep::Heartbeat { seq, used: total })
        }
        b'B' => {
            let total = BATCH_HEADER_LEN;
            if buf.len() > 4 && buf[4] != WIRE_VERSION {
                return Err(Error::wire(format!("unsupported wire version {}", buf[4])));
            }
            for at in 5..8usize.min(buf.len()) {
                if buf[at] != 0 {
                    return Err(Error::wire("nonzero reserved bytes"));
                }
            }
            if buf.len() < total {
                return Ok(EnvelopeStep::NeedMore(total));
            }
            let n_frames = u32::from_le_bytes(take4(buf, 8));
            Ok(EnvelopeStep::BatchStart { n_frames, used: total })
        }
        b'S' => {
            let total = BATCH_SEQ_LEN;
            if buf.len() > 4 && buf[4] != WIRE_VERSION {
                return Err(Error::wire(format!("unsupported wire version {}", buf[4])));
            }
            for at in 5..8usize.min(buf.len()) {
                if buf[at] != 0 {
                    return Err(Error::wire("nonzero reserved bytes"));
                }
            }
            if buf.len() < total {
                return Ok(EnvelopeStep::NeedMore(total));
            }
            let token = u64::from_le_bytes(take8(buf, 8));
            let seq = u64::from_le_bytes(take8(buf, 16));
            Ok(EnvelopeStep::BatchSeq { token, seq, used: total })
        }
        other => Err(Error::wire(format!("unknown envelope type byte 0x{other:02x}"))),
    }
}

/// Decode a whole request body of back-to-back frames. Errors if any
/// frame is malformed or if trailing bytes remain after the last frame.
pub fn decode_stream(mut buf: &[u8]) -> Result<Vec<Frame>> {
    let mut frames = Vec::new();
    while !buf.is_empty() {
        let (frame, used) = Frame::from_bytes(buf)?;
        frames.push(frame);
        buf = &buf[used..];
    }
    Ok(frames)
}

fn take4(buf: &[u8], at: usize) -> [u8; 4] {
    buf[at..at + 4].try_into().expect("bounds checked by caller")
}

fn take8(buf: &[u8], at: usize) -> [u8; 8] {
    buf[at..at + 8].try_into().expect("bounds checked by caller")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame() -> Frame {
        Frame {
            patient: 42,
            modality: Modality::Ecg,
            sim_time: 12.375,
            values: [0.5, -1.25, 3.0].into(),
        }
    }

    #[test]
    fn roundtrip_single_frame() {
        let f = frame();
        let bytes = f.to_bytes();
        assert_eq!(bytes.len(), f.wire_len());
        let (g, used) = Frame::from_bytes(&bytes).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(g.patient, f.patient);
        assert_eq!(g.modality, f.modality);
        assert_eq!(g.sim_time.to_bits(), f.sim_time.to_bits());
        assert_eq!(g.values, f.values);
    }

    #[test]
    fn roundtrip_multi_frame_stream() {
        let mut body = Vec::new();
        for i in 0..5usize {
            let mut f = frame();
            f.patient = i;
            f.write_bytes(&mut body);
        }
        let frames = decode_stream(&body).unwrap();
        assert_eq!(frames.len(), 5);
        for (i, f) in frames.iter().enumerate() {
            assert_eq!(f.patient, i);
        }
    }

    #[test]
    fn every_truncation_errors_without_panic() {
        let bytes = frame().to_bytes();
        for cut in 0..bytes.len() {
            assert!(Frame::from_bytes(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn decode_step_resumes_at_every_cut_of_a_valid_frame() {
        let f = frame();
        let bytes = f.to_bytes();
        for cut in 0..bytes.len() {
            match decode_step(&bytes[..cut]).unwrap_or_else(|e| panic!("cut {cut}: {e}")) {
                DecodeStep::NeedMore(need) => {
                    assert!(need > cut, "cut {cut}: need {need} must exceed what we have");
                    assert!(need <= bytes.len(), "cut {cut}: need {need} within the frame");
                }
                DecodeStep::Frame(..) => panic!("cut {cut}: incomplete prefix decoded a frame"),
            }
        }
        match decode_step(&bytes).unwrap() {
            DecodeStep::Frame(g, used) => {
                assert_eq!(used, bytes.len());
                assert_eq!(g.patient, f.patient);
                assert_eq!(g.sim_time.to_bits(), f.sim_time.to_bits());
                assert_eq!(g.values, f.values);
            }
            DecodeStep::NeedMore(n) => panic!("complete frame reported NeedMore({n})"),
        }
    }

    #[test]
    fn decode_step_rejects_garbage_at_the_first_visible_byte() {
        // corrupt magic is refused with a single byte in the buffer
        assert!(decode_step(&[0xde]).is_err());
        // corrupt version / modality / reserved are refused as soon as
        // that byte arrives, well before the header completes
        let good = frame().to_bytes();
        for (at, bad) in [(4usize, 9u8), (5, 7), (6, 1), (7, 1)] {
            let mut b = good.clone();
            b[at] = bad;
            assert!(decode_step(&b[..at + 1]).is_err(), "byte {at} not rejected early");
            assert!(decode_step(&b).is_err(), "byte {at} not rejected in full");
        }
    }

    #[test]
    fn corrupt_header_fields_error() {
        let good = frame().to_bytes();
        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xff;
        assert!(Frame::from_bytes(&bad_magic).is_err());
        let mut bad_version = good.clone();
        bad_version[4] = 9;
        assert!(Frame::from_bytes(&bad_version).is_err());
        let mut bad_modality = good.clone();
        bad_modality[5] = 7;
        assert!(Frame::from_bytes(&bad_modality).is_err());
        let mut bad_len = good.clone();
        bad_len[24..28].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(Frame::from_bytes(&bad_len).is_err());
    }

    #[test]
    fn payload_wider_than_the_inline_buffer_is_rejected() {
        // hand-assemble a frame claiming MAX_WIRE_VALUES + 1 values,
        // with the payload bytes actually present: the length bound
        // itself must reject it, not a truncation check
        let n = MAX_WIRE_VALUES + 1;
        let mut body = Vec::new();
        body.extend_from_slice(&WIRE_MAGIC);
        body.push(WIRE_VERSION);
        body.push(Modality::Labs.wire_code());
        body.extend_from_slice(&[0u8; 2]);
        body.extend_from_slice(&7u64.to_le_bytes());
        body.extend_from_slice(&1.0f64.to_le_bytes());
        body.extend_from_slice(&(n as u32).to_le_bytes());
        for _ in 0..n {
            body.extend_from_slice(&1.0f32.to_le_bytes());
        }
        assert!(Frame::from_bytes(&body).is_err());
        // the full 8-value labs payload is exactly at the cap
        let labs = Frame {
            patient: 7,
            modality: Modality::Labs,
            sim_time: 1.0,
            values: [7.4, 1.0, 4.0, 140.0, 0.4, 12.0, 14.0, 9.0].into(),
        };
        let (back, _) = Frame::from_bytes(&labs.to_bytes()).unwrap();
        assert_eq!(back.values.len(), MAX_WIRE_VALUES);
    }

    #[test]
    fn patient_id_boundary_roundtrips_or_rejects() {
        // the largest locally-representable id always survives a trip
        let mut f = frame();
        f.patient = usize::MAX;
        let (g, _) = Frame::from_bytes(&f.to_bytes()).unwrap();
        assert_eq!(g.patient, usize::MAX);
        // a wire id beyond usize must be a decode error, never a
        // truncated alias of another patient
        let mut bytes = frame().to_bytes();
        bytes[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        if (usize::MAX as u64) < u64::MAX {
            // 32-bit target: u64::MAX is unrepresentable → rejected
            assert!(Frame::from_bytes(&bytes).is_err());
        } else {
            // 64-bit target: the whole u64 space round-trips exactly
            let (g, _) = Frame::from_bytes(&bytes).unwrap();
            assert_eq!(g.patient as u64, u64::MAX);
        }
    }

    #[test]
    fn nan_payload_is_rejected() {
        let mut f = frame();
        f.values = super::FrameValues::from_slice(&[0.5, f32::NAN, 3.0]).unwrap();
        assert!(Frame::from_bytes(&f.to_bytes()).is_err());
        f.values = super::FrameValues::from_slice(&[0.5, f32::INFINITY, 3.0]).unwrap();
        assert!(Frame::from_bytes(&f.to_bytes()).is_err());
    }

    #[test]
    fn trailing_garbage_in_stream_errors() {
        let mut body = frame().to_bytes();
        body.push(0x00);
        assert!(decode_stream(&body).is_err());
    }

    #[test]
    fn heartbeat_roundtrips_and_resumes() {
        let body = encode_heartbeat(0xDEAD_BEEF_0042);
        assert_eq!(body.len(), HEARTBEAT_LEN);
        match decode_envelope_step(&body).unwrap() {
            EnvelopeStep::Heartbeat { seq, used } => {
                assert_eq!(seq, 0xDEAD_BEEF_0042);
                assert_eq!(used, HEARTBEAT_LEN);
            }
            other => panic!("expected heartbeat, got {other:?}"),
        }
        for cut in 0..body.len() {
            match decode_envelope_step(&body[..cut]).unwrap_or_else(|e| panic!("cut {cut}: {e}")) {
                EnvelopeStep::NeedMore(need) => {
                    assert!(need > cut && need <= HEARTBEAT_LEN, "cut {cut}: need {need}");
                }
                other => panic!("cut {cut}: incomplete heartbeat decoded {other:?}"),
            }
        }
    }

    #[test]
    fn batch_seq_roundtrips_and_resumes() {
        let mut body = Vec::new();
        write_batch_seq(0xFACE_FEED_0001, 42, &mut body);
        assert_eq!(body.len(), BATCH_SEQ_LEN);
        match decode_envelope_step(&body).unwrap() {
            EnvelopeStep::BatchSeq { token, seq, used } => {
                assert_eq!(token, 0xFACE_FEED_0001);
                assert_eq!(seq, 42);
                assert_eq!(used, BATCH_SEQ_LEN);
            }
            other => panic!("expected batch seq, got {other:?}"),
        }
        for cut in 0..body.len() {
            match decode_envelope_step(&body[..cut]).unwrap_or_else(|e| panic!("cut {cut}: {e}")) {
                EnvelopeStep::NeedMore(need) => {
                    assert!(need > cut && need <= BATCH_SEQ_LEN, "cut {cut}: need {need}");
                }
                other => panic!("cut {cut}: incomplete batch seq decoded {other:?}"),
            }
        }
    }

    #[test]
    fn batch_envelope_header_roundtrips() {
        let mut body = Vec::new();
        write_batch_header(3, &mut body);
        assert_eq!(body.len(), BATCH_HEADER_LEN);
        for i in 0..3usize {
            let mut f = frame();
            f.patient = i;
            f.write_bytes(&mut body);
        }
        match decode_envelope_step(&body).unwrap() {
            EnvelopeStep::BatchStart { n_frames, used } => {
                assert_eq!(n_frames, 3);
                assert_eq!(used, BATCH_HEADER_LEN);
            }
            other => panic!("expected batch start, got {other:?}"),
        }
        // the frames that follow decode as plain envelope frames
        let mut at = BATCH_HEADER_LEN;
        for i in 0..3usize {
            match decode_envelope_step(&body[at..]).unwrap() {
                EnvelopeStep::Frame(f, used) => {
                    assert_eq!(f.patient, i);
                    at += used;
                }
                other => panic!("frame {i}: got {other:?}"),
            }
        }
        assert_eq!(at, body.len());
    }

    #[test]
    fn envelope_delegates_plain_frames_to_decode_step() {
        let f = frame();
        let bytes = f.to_bytes();
        match decode_envelope_step(&bytes).unwrap() {
            EnvelopeStep::Frame(g, used) => {
                assert_eq!(used, bytes.len());
                assert_eq!(g.patient, f.patient);
                assert_eq!(g.values, f.values);
            }
            other => panic!("expected frame, got {other:?}"),
        }
        for cut in 0..bytes.len() {
            assert!(
                matches!(decode_envelope_step(&bytes[..cut]).unwrap(), EnvelopeStep::NeedMore(_)),
                "cut {cut}"
            );
        }
    }

    #[test]
    fn envelope_rejects_garbage_at_the_first_visible_byte() {
        assert!(decode_envelope_step(&[0xde]).is_err());
        assert!(decode_envelope_step(b"HLX").is_err());
        // valid HLM prefix + unknown type byte
        assert!(decode_envelope_step(b"HLMZ").is_err());
        // heartbeat with corrupt version / reserved bytes, rejected as
        // soon as that byte arrives
        let good = encode_heartbeat(7);
        for (at, bad) in [(4usize, 9u8), (5, 1), (6, 1), (7, 1)] {
            let mut b = good.to_vec();
            b[at] = bad;
            assert!(decode_envelope_step(&b[..at + 1]).is_err(), "byte {at} not rejected early");
            assert!(decode_envelope_step(&b).is_err(), "byte {at} not rejected in full");
        }
        // same for the batch header
        let mut hdr = Vec::new();
        write_batch_header(2, &mut hdr);
        for (at, bad) in [(4usize, 9u8), (5, 1), (6, 1), (7, 1)] {
            let mut b = hdr.clone();
            b[at] = bad;
            assert!(decode_envelope_step(&b[..at + 1]).is_err(), "byte {at} not rejected early");
            assert!(decode_envelope_step(&b).is_err(), "byte {at} not rejected in full");
        }
        // same for the batch-sequence tag
        let mut tag = Vec::new();
        write_batch_seq(1, 1, &mut tag);
        for (at, bad) in [(4usize, 9u8), (5, 1), (6, 1), (7, 1)] {
            let mut b = tag.clone();
            b[at] = bad;
            assert!(decode_envelope_step(&b[..at + 1]).is_err(), "byte {at} not rejected early");
            assert!(decode_envelope_step(&b).is_err(), "byte {at} not rejected in full");
        }
    }

    #[test]
    fn modality_wire_codes_roundtrip() {
        for m in [Modality::Ecg, Modality::Vitals, Modality::Labs] {
            assert_eq!(Modality::from_wire_code(m.wire_code()).unwrap(), m);
        }
        assert!(Modality::from_wire_code(3).is_err());
    }
}
