//! Patient data ingest: synthetic bedside monitors (the paper's client
//! data generator), a virtual clock for accelerated long-horizon
//! experiments, and open-loop stream drivers.

pub mod clock;
pub mod scenario;
pub mod synth;
pub mod wire;

pub use clock::VirtualClock;
pub use synth::{PatientSim, PatientState, SynthConfig};
pub use wire::{
    decode_envelope_step, decode_stream, encode_heartbeat, write_batch_header, EnvelopeStep,
    BATCH_HEADER_LEN, BATCH_MAGIC, HEARTBEAT_LEN, HEARTBEAT_MAGIC, MAX_WIRE_VALUES,
    WIRE_HEADER_LEN, WIRE_MAGIC, WIRE_VERSION,
};

use std::str::FromStr;

use crate::json::Value;
use crate::{Error, Result};

/// Widest per-frame payload across all modalities: ECG carries 3 lead
/// samples, vitals 7 values, labs 8 — so 8 slots cover every frame the
/// system admits. The cap is what makes [`FrameValues`] (and therefore
/// [`Frame`]) a fixed-size inline value with **zero heap traffic**: at
/// 100 beds × 250 Hz the ingest edge moves ~25k frames/s, and a
/// `Vec<f32>` payload used to cost one allocation per frame on wire
/// decode, JSON decode, synth generation, and every channel hop.
pub const MAX_FRAME_VALUES: usize = 8;

/// Inline fixed-capacity payload buffer of a [`Frame`]: up to
/// [`MAX_FRAME_VALUES`] f32 values stored by value, no heap. Derefs to
/// `&[f32]` of the live length, so call sites read it like a slice.
#[derive(Clone, Copy, Default)]
pub struct FrameValues {
    len: u8,
    buf: [f32; MAX_FRAME_VALUES],
}

impl FrameValues {
    /// Empty payload (push values in with [`FrameValues::push`]).
    pub const fn new() -> Self {
        FrameValues { len: 0, buf: [0.0; MAX_FRAME_VALUES] }
    }

    /// Copy a slice in; errors if it exceeds [`MAX_FRAME_VALUES`].
    pub fn from_slice(values: &[f32]) -> Result<Self> {
        if values.len() > MAX_FRAME_VALUES {
            return Err(Error::json(format!(
                "frame carries {} values, max is {MAX_FRAME_VALUES}",
                values.len()
            )));
        }
        let mut out = FrameValues::new();
        out.buf[..values.len()].copy_from_slice(values);
        out.len = values.len() as u8;
        Ok(out)
    }

    /// Append one value; `false` (payload unchanged) when full.
    #[must_use]
    pub fn push(&mut self, v: f32) -> bool {
        if (self.len as usize) < MAX_FRAME_VALUES {
            self.buf[self.len as usize] = v;
            self.len += 1;
            true
        } else {
            false
        }
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.buf[..self.len as usize]
    }

    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Copy out to a `Vec` (window emission, CSVs — cold paths only).
    pub fn to_vec(&self) -> Vec<f32> {
        self.as_slice().to_vec()
    }
}

impl std::ops::Deref for FrameValues {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl std::fmt::Debug for FrameValues {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.as_slice().fmt(f)
    }
}

impl<'a> IntoIterator for &'a FrameValues {
    type Item = &'a f32;
    type IntoIter = std::slice::Iter<'a, f32>;

    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

/// Compares only the live prefix — slots past `len` are dont-care.
impl PartialEq for FrameValues {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<[f32]> for FrameValues {
    fn eq(&self, other: &[f32]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<Vec<f32>> for FrameValues {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

/// Infallible payloads from the fixed-arity generators (ECG [f32; 3],
/// vitals [f32; 7], labs [f32; 8]).
impl<const N: usize> From<[f32; N]> for FrameValues {
    fn from(values: [f32; N]) -> Self {
        const { assert!(N <= MAX_FRAME_VALUES, "payload wider than MAX_FRAME_VALUES") };
        let mut out = FrameValues::new();
        out.buf[..N].copy_from_slice(&values);
        out.len = N as u8;
        out
    }
}

/// One sample frame from a bedside monitor. `Copy`: the payload is an
/// inline fixed-capacity buffer ([`FrameValues`]), so moving a frame
/// through channels, shard queues, and decode loops is a ~64-byte
/// stack copy — never an allocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Frame {
    pub patient: usize,
    pub modality: Modality,
    /// Simulation timestamp, seconds since stream start.
    pub sim_time: f64,
    /// Sample payload: one ECG sample per lead, or the vitals vector.
    pub values: FrameValues,
}

impl Frame {
    /// JSON body of the HTTP `/ingest` endpoint.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("patient", Value::Num(self.patient as f64)),
            ("modality", Value::Str(self.modality.as_str().to_string())),
            ("sim_time", Value::Num(self.sim_time)),
            (
                "values",
                Value::Arr(self.values.iter().map(|&v| Value::Num(v as f64)).collect()),
            ),
        ])
    }

    /// Parse the JSON ingest body. The boundary is strict: `sim_time`
    /// must be finite and every payload value must be a finite f64 that
    /// stays finite as f32 — a silent `f64 → f32` cast used to admit
    /// NaN and turn out-of-range magnitudes into ±inf, poisoning every
    /// downstream score that touched the window. Values land straight
    /// in the frame's inline buffer (no intermediate `Vec`), and more
    /// than [`MAX_FRAME_VALUES`] of them is a malformed frame.
    pub fn from_json(v: &Value) -> Result<Frame> {
        let sim_time = v
            .req("sim_time")?
            .as_f64()
            .ok_or_else(|| Error::json("sim_time not a number"))?;
        if !sim_time.is_finite() {
            return Err(Error::json("sim_time not finite"));
        }
        let raw = v
            .req("values")?
            .as_arr()
            .ok_or_else(|| Error::json("values not an array"))?;
        let mut values = FrameValues::new();
        for (i, item) in raw.iter().enumerate() {
            let x = item.as_f64().ok_or_else(|| Error::json("expected number"))?;
            let y = x as f32;
            if !y.is_finite() {
                return Err(Error::json(format!(
                    "values[{i}] = {x} is not representable as a finite f32"
                )));
            }
            if !values.push(y) {
                return Err(Error::json(format!(
                    "frame carries more than {MAX_FRAME_VALUES} values"
                )));
            }
        }
        Ok(Frame {
            patient: v
                .req("patient")?
                .as_usize()
                .ok_or_else(|| Error::json("patient not a number"))?,
            modality: Modality::from_str(
                v.req("modality")?.as_str().ok_or_else(|| Error::json("modality not a string"))?,
            )?,
            sim_time,
            values,
        })
    }
}

/// Data modalities of the CICU cohort (paper §4.1.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Modality {
    /// 3-lead ECG waveform, 250 Hz.
    Ecg,
    /// 7 vital signs, 1 Hz.
    Vitals,
    /// 8 lab values, irregular (minutes–hours).
    Labs,
}

impl Modality {
    /// Nominal sampling frequency (Hz); labs are modelled at 1/300 Hz.
    pub fn frequency(&self) -> f64 {
        match self {
            Modality::Ecg => 250.0,
            Modality::Vitals => 1.0,
            Modality::Labs => 1.0 / 300.0,
        }
    }

    pub fn channels(&self) -> usize {
        match self {
            Modality::Ecg => 3,
            Modality::Vitals => 7,
            Modality::Labs => 8,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Modality::Ecg => "ecg",
            Modality::Vitals => "vitals",
            Modality::Labs => "labs",
        }
    }
}

impl std::str::FromStr for Modality {
    type Err = Error;

    fn from_str(s: &str) -> Result<Modality> {
        match s {
            "ecg" => Ok(Modality::Ecg),
            "vitals" => Ok(Modality::Vitals),
            "labs" => Ok(Modality::Labs),
            other => Err(Error::json(format!("unknown modality '{other}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_json_roundtrip() {
        let f = Frame {
            patient: 7,
            modality: Modality::Vitals,
            sim_time: 12.5,
            values: [1.0, 2.5, -0.25].into(),
        };
        let g = Frame::from_json(&Value::parse(&f.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(g.patient, 7);
        assert_eq!(g.modality, Modality::Vitals);
        assert_eq!(g.sim_time, 12.5);
        assert_eq!(g.values, vec![1.0, 2.5, -0.25]);
    }

    #[test]
    fn frame_values_inline_buffer_semantics() {
        let mut v = FrameValues::new();
        assert!(v.is_empty());
        for i in 0..MAX_FRAME_VALUES {
            assert!(v.push(i as f32), "push {i} fits");
        }
        assert!(!v.push(99.0), "push past capacity is refused");
        assert_eq!(v.len(), MAX_FRAME_VALUES);
        assert_eq!(v[3], 3.0, "deref indexes the live prefix");
        // equality ignores dead slots past len
        let a = FrameValues::from_slice(&[1.0, 2.0]).unwrap();
        let mut b = FrameValues::from_slice(&[1.0, 2.0, 7.0]).unwrap();
        assert_ne!(a, b);
        let c = FrameValues::from_slice(&[1.0, 2.0]).unwrap();
        assert_eq!(a, c);
        assert!(FrameValues::from_slice(&[0.0; MAX_FRAME_VALUES + 1]).is_err());
        // a frame is Copy: mutating the copy leaves the original alone
        let copy = b;
        assert!(b.push(8.0));
        assert_eq!(copy.len(), 3);
    }

    #[test]
    fn from_json_rejects_oversized_payload() {
        let wide: Vec<String> = (0..MAX_FRAME_VALUES + 1).map(|i| format!("{i}.0")).collect();
        let body = format!(
            r#"{{"patient":1,"modality":"labs","sim_time":0.5,"values":[{}]}}"#,
            wide.join(",")
        );
        assert!(Frame::from_json(&Value::parse(&body).unwrap()).is_err());
        // exactly MAX_FRAME_VALUES (a labs frame) is fine
        let body = format!(
            r#"{{"patient":1,"modality":"labs","sim_time":0.5,"values":[{}]}}"#,
            wide[..MAX_FRAME_VALUES].join(",")
        );
        let f = Frame::from_json(&Value::parse(&body).unwrap()).unwrap();
        assert_eq!(f.values.len(), MAX_FRAME_VALUES);
    }

    #[test]
    fn from_json_rejects_nan_and_out_of_range_values() {
        // NaN payload value
        let body = r#"{"patient":1,"modality":"ecg","sim_time":0.5,"values":[1.0,null,2.0]}"#;
        assert!(
            Value::parse(body).is_err()
                || Frame::from_json(&Value::parse(body).unwrap()).is_err()
        );
        // magnitude beyond f32 range would cast to +inf — rejected
        let big = r#"{"patient":1,"modality":"ecg","sim_time":0.5,"values":[1e39]}"#;
        assert!(Frame::from_json(&Value::parse(big).unwrap()).is_err());
        // non-finite sim_time encoded as a huge exponent
        let t = r#"{"patient":1,"modality":"ecg","sim_time":1e999,"values":[1.0]}"#;
        if let Ok(v) = Value::parse(t) {
            assert!(Frame::from_json(&v).is_err());
        }
    }

    #[test]
    fn modality_str_roundtrip() {
        for m in [Modality::Ecg, Modality::Vitals, Modality::Labs] {
            assert_eq!(Modality::from_str(m.as_str()).unwrap(), m);
        }
        assert!(Modality::from_str("xray").is_err());
    }

    #[test]
    fn modality_frequencies() {
        assert_eq!(Modality::Ecg.frequency(), 250.0);
        assert_eq!(Modality::Vitals.frequency(), 1.0);
        assert_eq!(Modality::Ecg.channels(), 3);
    }
}
