//! Patient data ingest: synthetic bedside monitors (the paper's client
//! data generator), a virtual clock for accelerated long-horizon
//! experiments, and open-loop stream drivers.

pub mod clock;
pub mod synth;
pub mod wire;

pub use clock::VirtualClock;
pub use synth::{PatientSim, PatientState, SynthConfig};
pub use wire::{decode_stream, MAX_WIRE_VALUES, WIRE_HEADER_LEN, WIRE_MAGIC, WIRE_VERSION};

use std::str::FromStr;

use crate::json::Value;
use crate::{Error, Result};

/// One sample frame from a bedside monitor.
#[derive(Debug, Clone)]
pub struct Frame {
    pub patient: usize,
    pub modality: Modality,
    /// Simulation timestamp, seconds since stream start.
    pub sim_time: f64,
    /// Sample payload: one ECG sample per lead, or the vitals vector.
    pub values: Vec<f32>,
}

impl Frame {
    /// JSON body of the HTTP `/ingest` endpoint.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("patient", Value::Num(self.patient as f64)),
            ("modality", Value::Str(self.modality.as_str().to_string())),
            ("sim_time", Value::Num(self.sim_time)),
            (
                "values",
                Value::Arr(self.values.iter().map(|&v| Value::Num(v as f64)).collect()),
            ),
        ])
    }

    /// Parse the JSON ingest body. The boundary is strict: `sim_time`
    /// must be finite and every payload value must be a finite f64 that
    /// stays finite as f32 — a silent `f64 → f32` cast used to admit
    /// NaN and turn out-of-range magnitudes into ±inf, poisoning every
    /// downstream score that touched the window.
    pub fn from_json(v: &Value) -> Result<Frame> {
        let sim_time = v
            .req("sim_time")?
            .as_f64()
            .ok_or_else(|| Error::json("sim_time not a number"))?;
        if !sim_time.is_finite() {
            return Err(Error::json("sim_time not finite"));
        }
        let raw = v.req("values")?.as_f64_vec()?;
        let mut values = Vec::with_capacity(raw.len());
        for (i, x) in raw.into_iter().enumerate() {
            let y = x as f32;
            if !y.is_finite() {
                return Err(Error::json(format!(
                    "values[{i}] = {x} is not representable as a finite f32"
                )));
            }
            values.push(y);
        }
        Ok(Frame {
            patient: v
                .req("patient")?
                .as_usize()
                .ok_or_else(|| Error::json("patient not a number"))?,
            modality: Modality::from_str(
                v.req("modality")?.as_str().ok_or_else(|| Error::json("modality not a string"))?,
            )?,
            sim_time,
            values,
        })
    }
}

/// Data modalities of the CICU cohort (paper §4.1.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Modality {
    /// 3-lead ECG waveform, 250 Hz.
    Ecg,
    /// 7 vital signs, 1 Hz.
    Vitals,
    /// 8 lab values, irregular (minutes–hours).
    Labs,
}

impl Modality {
    /// Nominal sampling frequency (Hz); labs are modelled at 1/300 Hz.
    pub fn frequency(&self) -> f64 {
        match self {
            Modality::Ecg => 250.0,
            Modality::Vitals => 1.0,
            Modality::Labs => 1.0 / 300.0,
        }
    }

    pub fn channels(&self) -> usize {
        match self {
            Modality::Ecg => 3,
            Modality::Vitals => 7,
            Modality::Labs => 8,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Modality::Ecg => "ecg",
            Modality::Vitals => "vitals",
            Modality::Labs => "labs",
        }
    }
}

impl std::str::FromStr for Modality {
    type Err = Error;

    fn from_str(s: &str) -> Result<Modality> {
        match s {
            "ecg" => Ok(Modality::Ecg),
            "vitals" => Ok(Modality::Vitals),
            "labs" => Ok(Modality::Labs),
            other => Err(Error::json(format!("unknown modality '{other}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_json_roundtrip() {
        let f = Frame {
            patient: 7,
            modality: Modality::Vitals,
            sim_time: 12.5,
            values: vec![1.0, 2.5, -0.25],
        };
        let g = Frame::from_json(&Value::parse(&f.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(g.patient, 7);
        assert_eq!(g.modality, Modality::Vitals);
        assert_eq!(g.sim_time, 12.5);
        assert_eq!(g.values, vec![1.0, 2.5, -0.25]);
    }

    #[test]
    fn from_json_rejects_nan_and_out_of_range_values() {
        // NaN payload value
        let body = r#"{"patient":1,"modality":"ecg","sim_time":0.5,"values":[1.0,null,2.0]}"#;
        assert!(
            Value::parse(body).is_err()
                || Frame::from_json(&Value::parse(body).unwrap()).is_err()
        );
        // magnitude beyond f32 range would cast to +inf — rejected
        let big = r#"{"patient":1,"modality":"ecg","sim_time":0.5,"values":[1e39]}"#;
        assert!(Frame::from_json(&Value::parse(big).unwrap()).is_err());
        // non-finite sim_time encoded as a huge exponent
        let t = r#"{"patient":1,"modality":"ecg","sim_time":1e999,"values":[1.0]}"#;
        if let Ok(v) = Value::parse(t) {
            assert!(Frame::from_json(&v).is_err());
        }
    }

    #[test]
    fn modality_str_roundtrip() {
        for m in [Modality::Ecg, Modality::Vitals, Modality::Labs] {
            assert_eq!(Modality::from_str(m.as_str()).unwrap(), m);
        }
        assert!(Modality::from_str("xray").is_err());
    }

    #[test]
    fn modality_frequencies() {
        assert_eq!(Modality::Ecg.frequency(), 250.0);
        assert_eq!(Modality::Vitals.frequency(), 1.0);
        assert_eq!(Modality::Ecg.channels(), 3);
    }
}
