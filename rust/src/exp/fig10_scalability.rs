//! Fig. 10: serving-latency scalability.
//!
//! Left panel: p95 end-to-end latency vs number of patients (device
//! workers fixed at 2). Right panel: latency vs number of "GPUs"
//! (workers) at the highest offered load.
//!
//! The HOLMES-selected servable ensemble is deployed on the real
//! pipeline; ensemble queries arrive open-loop at the aggregate rate
//! λ = patients / ΔT. ΔT is compressed from 30 s to 3 s so each setting
//! completes in seconds — λ and the service times are what queueing
//! depends on, so the scaling *shape* is preserved (EXPERIMENTS.md).

use std::path::Path;
use std::time::Instant;

use crate::composer::baselines::best_feasible;
use crate::config::ComposerConfig;
use crate::data;
use crate::ingest::synth::SynthConfig;
use crate::runtime::Engine;
use crate::serving::pipeline::{Pipeline, PipelineConfig, Query};
use crate::zoo::{Selector, Zoo};
use crate::Result;

use super::common::{Method, SearchContext};
use super::write_csv;

pub fn run(zoo: &Zoo, out: &Path, quick: bool) -> Result<()> {
    let ensemble = holmes_servable_ensemble(zoo, 0.2);
    println!("\n== Fig 10: latency scalability ==");
    println!(
        "serving ensemble ({} models): {:?}",
        ensemble.len(),
        ensemble.indices().iter().map(|&i| zoo.model(i).id.clone()).collect::<Vec<_>>()
    );
    let window_s = 3.0; // compressed ΔT (see module docs)
    let rounds = if quick { 3 } else { 5 };

    let mut rows = Vec::new();
    // ---- left: patients sweep at 2 workers
    let patients: Vec<usize> =
        if quick { vec![1, 8, 32, 64] } else { vec![1, 2, 4, 8, 16, 32, 64, 100] };
    {
        let engine = Engine::new(zoo, 2)?;
        warm(&engine, &ensemble)?;
        for &p in &patients {
            let (p50, p95, p99) =
                drive_open_loop(zoo, &engine, &ensemble, p, window_s, rounds)?;
            println!("  patients={p:>4} gpus=2 → p50 {p50:.4}s p95 {p95:.4}s");
            rows.push(format!("patients,{p},2,{p50:.6},{p95:.6},{p99:.6}"));
        }
    }
    // ---- right: worker sweep at max load
    let gpus: Vec<usize> = if quick { vec![1, 2] } else { vec![1, 2, 4] };
    let max_patients = *patients.last().unwrap();
    for &g in &gpus {
        let engine = Engine::new(zoo, g)?;
        warm(&engine, &ensemble)?;
        let (p50, p95, p99) =
            drive_open_loop(zoo, &engine, &ensemble, max_patients, window_s, rounds)?;
        println!("  patients={max_patients} gpus={g} → p50 {p50:.4}s p95 {p95:.4}s");
        rows.push(format!("gpus,{max_patients},{g},{p50:.6},{p95:.6},{p99:.6}"));
    }
    write_csv(out, "fig10.csv", "sweep,patients,gpus,p50_s,p95_s,p99_s", &rows)?;
    Ok(())
}

/// The ensemble HOLMES composes when restricted to servable models,
/// using engine-free analytic latency (calibrated coefficients).
pub fn holmes_servable_ensemble(zoo: &Zoo, budget: f64) -> Selector {
    let system = super::common::search_system();
    let ctx = SearchContext::new(zoo, system);
    let cfg = ComposerConfig {
        servable_only: true,
        iterations: 10,
        warm_start: 16,
        ..Default::default()
    };
    let r = ctx.run(Method::Holmes, budget, 0, &cfg);
    let best = best_feasible(&r.profile_set, budget);
    if best.selector.is_empty() {
        // degenerate fallback: best single servable model
        Selector::from_indices(zoo.n(), [zoo.servable_indices()[0]])
    } else {
        best.selector
    }
}

fn warm(engine: &Engine, ensemble: &Selector) -> Result<()> {
    for &m in ensemble.indices() {
        for &b in engine.batch_sizes() {
            engine.profile_model((m, b), 1)?;
        }
    }
    Ok(())
}

/// Open-loop burst driver: every window tick, all `patients` beds emit
/// their ensemble query together (phase-aligned worst case — the same
/// arrival model the analytic profiler's token bucket assumes, and the
/// regime where the paper's "latency scales linearly with ingest rate"
/// holds). Runs `rounds` windows; returns (p50, p95, p99) e2e seconds.
fn drive_open_loop(
    zoo: &Zoo,
    engine: &Engine,
    ensemble: &Selector,
    patients: usize,
    window_s: f64,
    rounds: usize,
) -> Result<(f64, f64, f64)> {
    let clip_len = zoo.manifest.clip_len;
    let cfg = SynthConfig::from(&zoo.manifest.calibration);
    // pre-generate a pool of windows (shared storage) to avoid synth
    // and copy cost in the loop
    let pool = data::make_clips(8, clip_len, 99, &cfg).shared();

    let pipeline = Pipeline::spawn(zoo, engine, PipelineConfig::new(ensemble.clone()))?;
    let start = Instant::now();
    let mut replies = Vec::with_capacity(rounds * patients);
    for round in 0..rounds {
        // absolute schedule: bursts keep coming even if the previous one
        // has not drained (open loop, non-blocking)
        let tick = std::time::Duration::from_secs_f64(round as f64 * window_s);
        if let Some(wait) = tick.checked_sub(start.elapsed()) {
            std::thread::sleep(wait);
        }
        for p in 0..patients {
            let q = Query {
                patient: p,
                window_id: round as u64,
                sim_end: round as f64 * window_s,
                leads: pool[p % pool.len()].clone(),
                emitted: Instant::now(),
            };
            replies.push(pipeline.submit(q)?);
        }
    }
    let mut e2e = Vec::with_capacity(replies.len());
    for r in replies {
        if let Ok(p) = r.recv() {
            e2e.push(p.e2e.as_secs_f64());
        }
    }
    Ok((
        crate::metrics::percentile(&e2e, 50.0),
        crate::metrics::percentile(&e2e, 95.0),
        crate::metrics::percentile(&e2e, 99.0),
    ))
}
