//! Deterministic adversarial cohort replay: `holmes replay`.
//!
//! Drives the full serving pipeline (shard plane → ensemble executor →
//! completer, optionally through the real HTTP ingest edge) with one of
//! the seeded fault scenarios from [`crate::ingest::scenario`], then
//! holds the run's telemetry against the scenario's precomputed
//! [`FaultBudget`] counter for counter. The point is not to *observe*
//! what churn, clock skew, or a hostile client does to the system — it
//! is to **assert** it: every scenario declares machine-checkable
//! invariants ("every admitted query resolves", "shed counters equal
//! the injected fault budget exactly", "the p95 is back under the SLO
//! after the fault clears", "the governor degraded when the tail
//! breached") and [`check_invariants`] turns any miss into a violation
//! the binary exits nonzero on. Four scenarios run seeded in CI beside
//! the bedside smokes.
//!
//! With `route_peers > 0` the cohort streams through the consistent-
//! hash [`Router`] into N independent serving stacks instead of one —
//! and `node-loss` scripts a mid-cohort peer kill + same-port restart
//! on top, holding the re-home/spill/reinstate counters against the
//! scenario's ring-mirror budget.
//!
//! Determinism contract: with the same `(scenario, seed)` the
//! accounting — shed/evict/window/prediction counts **and** the
//! prediction score fingerprint — is bit-identical across shard and
//! worker counts (property-tested in `tests/replay.rs`). This holds
//! because per-patient frame order is preserved end to end, per-patient
//! decisions depend only on that order, scores are bagged in
//! model-index order, and the one scenario that exercises cross-patient
//! state (churn's LRU eviction) drives all traffic from a single
//! monitor. Governed runs keep their *fault* accounting deterministic
//! but not their scores (a swap changes member sets mid-run), so the
//! determinism tests run ungoverned.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::ingest::scenario::{
    budget, monitors, FaultBudget, Scenario, ScenarioCfg, CHURN_CAP_TOTAL,
};
use crate::ingest::synth::SynthConfig;
use crate::ingest::VirtualClock;
use crate::profiler::ServiceTimes;
use crate::runtime::{Engine, SimBackend};
use crate::router::{HealthConfig, Ring, Router, RouterConfig};
use crate::serving::pipeline::{Pipeline, PipelineConfig, Query};
use crate::serving::shards::{ShardConfig, ShardRouter};
use crate::serving::{Governor, GovernorConfig, ShardSender, Telemetry};
use crate::zoo::Zoo;
use crate::{Error, Result};

/// Burst-storm service-time multiplier: heavy enough that the ghost
/// wave visibly backs the executor up, light enough that the backlog
/// drains and the recovery-phase p95 invariant can hold (the chaos
/// smoke's 32× is deliberately harsher — it *wants* an SLO breach).
pub const STORM_TIME_SCALE: f64 = 8.0;

/// Hostile-edge: corrupt/truncated/NaN wire bodies the byte-level
/// driver posts — every one must come back `400` without disturbing
/// the cohort.
pub const HOSTILE_BAD_BODIES: u64 = 12;

/// Hostile-edge: concurrent connections the flood phase opens against
/// the edge's connection cap.
pub const HOSTILE_FLOOD_CONNS: usize = 16;

/// Hostile-edge: slow-loris connections held half-open until the edge's
/// read-timeout sweep reaps them.
pub const HOSTILE_LORIS_CONNS: usize = 4;

#[derive(Debug, Clone)]
pub struct ReplayConfig {
    pub scenario: Scenario,
    pub seed: u64,
    /// Base cohort size (churn ignores this — its cohort is the
    /// [`CHURN_UNIVERSE`](crate::ingest::scenario::CHURN_UNIVERSE)).
    pub patients: usize,
    /// Simulated seconds (= scenario ticks).
    pub duration_s: u64,
    pub speedup: f64,
    pub gpus: usize,
    /// Aggregation shards; 0 = 2. Churn requires a divisor of
    /// [`CHURN_CAP_TOTAL`].
    pub shards: usize,
    /// Executor workers; 0 = hardware default for `gpus`.
    pub workers: usize,
    pub slo_ms: f64,
    /// Stream over the HTTP ingest edge instead of in-process channels.
    /// `hostile-edge` forces this on (auto-binding a loopback port)
    /// because its whole point is the wire boundary.
    pub http_addr: Option<String>,
    pub edge_threads: usize,
    /// Spawn the governor control plane; adds the degrade-on-breach
    /// invariant but makes scores nondeterministic across runs.
    pub govern: bool,
    /// Run the cohort through the router tier: N in-process peer
    /// stacks (own shard plane + executor pipeline + ingest edge on a
    /// loopback port) behind a consistent-hash [`Router`]. 0 = direct
    /// single-stack serving. `node-loss` forces this on (2 peers) —
    /// its whole point is the failover.
    pub route_peers: usize,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            scenario: Scenario::Churn,
            seed: 7,
            patients: 8,
            duration_s: 12,
            speedup: 16.0,
            gpus: 2,
            shards: 0,
            workers: 0,
            slo_ms: 1000.0,
            http_addr: None,
            edge_threads: 0,
            govern: false,
            route_peers: 0,
        }
    }
}

/// The deterministic half of a replay: everything here must reproduce
/// bit for bit for the same `(scenario, seed)` regardless of shard or
/// worker count.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ReplayAccounting {
    pub frames_sent: u64,
    /// Frames the shard plane received (`Telemetry::frames`).
    pub frames_ingested: u64,
    pub frames_dropped: u64,
    pub frames_dropped_malformed: u64,
    pub frames_dropped_overcap: u64,
    pub frames_stale: u64,
    pub patients_evicted: u64,
    pub queries_submitted: u64,
    pub predictions: u64,
    /// Admitted queries never accounted completed or failed — must be 0.
    pub unresolved: u64,
    /// Order-independent fold of `hash(patient, window_id, score_bits)`
    /// over every prediction — equal fingerprints mean the same windows
    /// produced the same scores, bit for bit.
    pub score_fingerprint: u64,
}

/// Client-side observations of the hostile-edge byte driver.
#[derive(Debug, Clone, Default)]
pub struct HostileOutcome {
    pub bad_bodies_sent: u64,
    /// `400`s the hostile client saw — must equal `bad_bodies_sent`.
    pub bad_bodies_rejected: u64,
    pub flood_conns: u64,
    /// `503`s the flood saw — must equal the edge's over-cap refusal
    /// counter (the flood is the scenario's only over-cap source).
    pub flood_refused: u64,
    /// Half-open connections held until the server reaped them.
    pub loris_conns: u64,
}

#[derive(Debug, Clone)]
pub struct ReplayReport {
    pub scenario: Scenario,
    pub seed: u64,
    pub shards: usize,
    pub workers: usize,
    pub govern: bool,
    pub http: bool,
    /// What the scenario injected (predicted by the dry-run mirror).
    pub budget: FaultBudget,
    /// What the live run observed.
    pub accounting: ReplayAccounting,
    pub slo_s: f64,
    /// Whole-run p95 (includes the fault window — may breach).
    pub e2e_p95: f64,
    /// p95 over predictions whose window ended after the fault cleared
    /// ([`ScenarioCfg::recovery_start_sim`]) — must be back under SLO.
    pub recovery_p95: f64,
    /// Predictions in the recovery phase (0 ⇒ `recovery_p95` vacuous).
    pub recovery_n: usize,
    pub client_reconnects: u64,
    pub conns_accepted: u64,
    pub conns_refused: u64,
    pub conns_refused_overcap: u64,
    pub conns_refused_handshake: u64,
    pub conns_reaped: u64,
    pub hostile: Option<HostileOutcome>,
    /// Peer count when the run went through the router tier; 0 = direct.
    pub route_peers: usize,
    /// Frames parked in link spill buffers while a peer was down
    /// (`router_spilled_total`).
    pub frames_spilled: u64,
    /// Stranded frames replayed through survivors at failover — must
    /// equal `frames_spilled`, or the spill lost data.
    pub spill_replayed: u64,
    /// Spill-cap overruns (dropped frames) — must be 0.
    pub spill_overflow: u64,
    /// Stranded frames the failover replay could not place within its
    /// deadline (every survivor saturated) — must be 0.
    pub replay_dropped: u64,
    /// Sticky owner-map rewrites at death/drain — must equal the
    /// budget's ring-mirror count exactly.
    pub patients_rehomed: u64,
    /// Canary-probe reinstatements of recovered peers.
    pub peers_reinstated: u64,
    pub governor_degraded_entered: u64,
    pub governor_swaps: u64,
    pub wall_s: f64,
    /// Invariant breaches ([`check_invariants`]); empty ⇒ replay passed.
    pub violations: Vec<String>,
}

/// FNV-1a over one prediction's identity; the accounting fingerprint is
/// the wrapping sum of these, so it is insensitive to completion order
/// but sensitive to any change in any window's score.
pub fn prediction_hash(patient: usize, window_id: u64, score: f64) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in (patient as u64)
        .to_le_bytes()
        .into_iter()
        .chain(window_id.to_le_bytes())
        .chain(score.to_bits().to_le_bytes())
    {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Evaluate every scenario invariant against a finished report and
/// return the breaches. Public (and pure) so the property suite can
/// both assert a clean run produces none and prove a fabricated
/// mismatch fires.
pub fn check_invariants(r: &ReplayReport) -> Vec<String> {
    let mut v = Vec::new();
    let a = &r.accounting;
    let b = &r.budget;
    let mut eq = |what: &str, got: u64, want: u64| {
        if got != want {
            v.push(format!("{what}: got {got}, budget says {want}"));
        }
    };
    eq("frames sent by drivers", a.frames_sent, b.frames_sent);
    eq("frames ingested", a.frames_ingested, b.frames_sent);
    eq("frames dropped (malformed)", a.frames_dropped_malformed, b.frames_malformed);
    eq("frames dropped (over cap)", a.frames_dropped_overcap, b.frames_overcap);
    eq("frames shed stale", a.frames_stale, b.frames_stale);
    eq(
        "frames dropped total vs per-cause sum",
        a.frames_dropped,
        b.frames_malformed + b.frames_overcap + b.frames_stale,
    );
    eq("patients evicted", a.patients_evicted, b.evictions);
    eq("queries submitted", a.queries_submitted, b.windows);
    eq("predictions resolved", a.predictions, b.windows);
    eq("unresolved queries at exit", a.unresolved, 0);
    if r.route_peers > 0 {
        eq("patients re-homed", r.patients_rehomed, b.rehomed_patients);
    }
    if r.route_peers > 0 {
        if r.spill_replayed != r.frames_spilled {
            v.push(format!(
                "{} frames spilled but {} replayed — frames lost in the spill buffer",
                r.frames_spilled, r.spill_replayed
            ));
        }
        if r.spill_overflow > 0 {
            v.push(format!("{} frames dropped to spill overflow", r.spill_overflow));
        }
        if r.replay_dropped > 0 {
            v.push(format!(
                "{} stranded frames dropped by the failover replay deadline",
                r.replay_dropped
            ));
        }
        if b.rehomed_patients > 0 {
            if r.frames_spilled == 0 {
                v.push(
                    "node loss spilled nothing — the kill landed after the cohort finished".into(),
                );
            }
            if r.peers_reinstated == 0 {
                v.push("the restarted peer was never reinstated by a canary probe".into());
            }
        }
    }
    if r.recovery_n > 0 && r.recovery_p95 > r.slo_s {
        v.push(format!(
            "recovery p95 {:.3}s still above the {:.3}s SLO after the fault cleared",
            r.recovery_p95, r.slo_s
        ));
    }
    if r.govern && r.e2e_p95 > r.slo_s && r.governor_degraded_entered == 0 {
        v.push(format!(
            "governor never degraded despite a whole-run p95 breach ({:.3}s > {:.3}s)",
            r.e2e_p95, r.slo_s
        ));
    }
    if r.http && b.severs > 0 && r.client_reconnects < b.severs {
        v.push(format!(
            "only {} client reconnects for {} injected link severs",
            r.client_reconnects, b.severs
        ));
    }
    if r.conns_refused != r.conns_refused_overcap + r.conns_refused_handshake {
        v.push(format!(
            "conns_refused {} is not over-cap {} + handshake {}",
            r.conns_refused, r.conns_refused_overcap, r.conns_refused_handshake
        ));
    }
    if let Some(h) = &r.hostile {
        if h.bad_bodies_rejected != h.bad_bodies_sent {
            v.push(format!(
                "hostile bodies: {} of {} rejected with 400",
                h.bad_bodies_rejected, h.bad_bodies_sent
            ));
        }
        if h.flood_refused != r.conns_refused_overcap {
            v.push(format!(
                "flood saw {} refusals but the edge counted {} over-cap",
                h.flood_refused, r.conns_refused_overcap
            ));
        }
        if h.flood_refused == 0 {
            v.push("connection flood was never refused — the cap did not hold".into());
        }
        if r.conns_reaped < h.loris_conns {
            v.push(format!(
                "only {} reaps for {} slow-loris connections",
                r.conns_reaped, h.loris_conns
            ));
        }
    }
    v
}

/// Run one scenario to completion and return the checked report (the
/// CLI exits nonzero when `violations` is non-empty).
pub fn run_replay(zoo: &Zoo, mut cfg: ReplayConfig) -> Result<ReplayReport> {
    if cfg.scenario == Scenario::NodeLoss && cfg.route_peers == 0 {
        // node loss IS a router scenario: the budget mirrors a 2-peer ring
        cfg.route_peers = 2;
    }
    if cfg.route_peers > 0 {
        return run_replay_routed(zoo, cfg);
    }
    let n_shards = if cfg.shards == 0 { 2 } else { cfg.shards };
    let n_workers =
        if cfg.workers == 0 { crate::serving::default_workers_for(cfg.gpus) } else { cfg.workers };
    let clip_len = zoo.manifest.clip_len;
    let scfg = ScenarioCfg {
        scenario: cfg.scenario,
        patients: cfg.patients,
        ticks: cfg.duration_s,
        seed: cfg.seed,
        window_samples: clip_len,
        synth: SynthConfig::from(&zoo.manifest.calibration),
    };
    // the shard patient cap the scenario runs against: churn squeezes
    // the plane to CHURN_CAP_TOTAL tracked patients split across shards
    // so the LRU eviction path actually fires
    let max_patients = if cfg.scenario == Scenario::Churn {
        if CHURN_CAP_TOTAL % n_shards != 0 {
            return Err(Error::config(format!(
                "churn needs shards dividing {CHURN_CAP_TOTAL}, got {n_shards}"
            )));
        }
        CHURN_CAP_TOTAL / n_shards
    } else {
        ShardConfig::default().max_patients
    };
    let expected = budget(&scfg, n_shards, max_patients);
    println!(
        "replay: scenario {} seed {} — {} patients, {} ticks, {} shards, {} workers, \
         speedup {}×, SLO {} ms{}{}",
        cfg.scenario.name(),
        cfg.seed,
        cfg.patients,
        cfg.duration_s,
        n_shards,
        n_workers,
        cfg.speedup,
        cfg.slo_ms,
        if cfg.http_addr.is_some() || cfg.scenario == Scenario::HostileEdge {
            ", over HTTP"
        } else {
            ""
        },
        if cfg.govern { ", governed" } else { "" },
    );
    println!(
        "fault budget: {} frames → {} windows | malformed {} stale {} overcap {} \
         evictions {} severs {}",
        expected.frames_sent,
        expected.windows,
        expected.frames_malformed,
        expected.frames_stale,
        expected.frames_overcap,
        expected.evictions,
        expected.severs,
    );

    let ensemble = super::fig10_scalability::holmes_servable_ensemble(zoo, 0.2);
    // burst-storm runs on a slowed scriptable backend so the ghost wave
    // genuinely saturates the device permits; everything else keeps the
    // calibrated service times
    let engine = if cfg.scenario == Scenario::BurstStorm {
        let times = ServiceTimes::from_macs(zoo, 5e-4, 2e10);
        let backend = SimBackend::with_times(times, STORM_TIME_SCALE);
        Engine::with_backend(zoo, cfg.gpus, Arc::new(backend))?
    } else {
        Engine::new(zoo, cfg.gpus)?
    };
    for &m in ensemble.indices() {
        for &b in engine.batch_sizes() {
            engine.profile_model((m, b), 1)?;
        }
    }

    let t_start = Instant::now();
    let slo = Duration::from_secs_f64((cfg.slo_ms / 1000.0).max(0.001));
    let pipeline = Pipeline::spawn(
        zoo,
        &engine,
        PipelineConfig::new(ensemble.clone()).with_workers(n_workers).with_slo(slo),
    )?;
    let telemetry = Arc::clone(pipeline.telemetry());
    let governor = if cfg.govern {
        Some(Governor::spawn(zoo, &pipeline, GovernorConfig { slo, ..GovernorConfig::default() })?)
    } else {
        None
    };

    let submitted = Arc::new(AtomicU64::new(0));
    let (pred_tx, pred_rx) = mpsc::channel::<(usize, u64, f64, f64, f64)>();
    let (shard_router, frame_tx) = ShardRouter::spawn(
        ShardConfig { shards: n_shards, max_patients, ..ShardConfig::default() },
        clip_len,
        Arc::clone(&telemetry),
        |_shard| {
            let pipeline = pipeline.clone();
            let pred_tx = pred_tx.clone();
            let submitted = Arc::clone(&submitted);
            move |window| {
                let q = Query::from_window(window);
                if let Ok(rx) = pipeline.submit(q) {
                    submitted.fetch_add(1, Ordering::Relaxed);
                    let pred_tx = pred_tx.clone();
                    std::thread::spawn(move || {
                        if let Ok(p) = rx.recv() {
                            let _ = pred_tx.send((
                                p.patient,
                                p.window_id,
                                p.sim_end,
                                p.score,
                                p.e2e.as_secs_f64(),
                            ));
                        }
                    });
                }
            }
        },
    )?;
    drop(pred_tx);

    // hostile-edge is about the wire boundary: force the HTTP edge on,
    // with a cap the flood can exceed and a read-timeout the loris
    // phase can trip inside the run
    let wall_total = cfg.duration_s as f64 / cfg.speedup;
    let mut http = None;
    let hostile_http = cfg.scenario == Scenario::HostileEdge && cfg.http_addr.is_none();
    if let Some(addr) =
        cfg.http_addr.clone().or_else(|| hostile_http.then(|| "127.0.0.1:0".to_string()))
    {
        let http_cfg = if cfg.scenario == Scenario::HostileEdge {
            crate::http::HttpConfig {
                max_connections: cfg.patients + 1 + HOSTILE_LORIS_CONNS + 2,
                read_timeout: Duration::from_secs_f64((wall_total / 4.0).clamp(0.2, 5.0)),
                edge_threads: cfg.edge_threads,
            }
        } else {
            crate::http::HttpConfig {
                edge_threads: cfg.edge_threads,
                ..crate::http::HttpConfig::default()
            }
        };
        let server =
            crate::http::serve_with(&addr, frame_tx.clone(), Arc::clone(&telemetry), http_cfg)?;
        println!("replay ingest edge on {} (binary /ingest.bin)", server.addr);
        http = Some(server);
    }
    let http_addr = http.as_ref().map(|s| s.addr);

    // one driver thread per monitor, paced by the virtual clock; frame
    // order within a patient is the monitor's emission order, which is
    // all the determinism contract needs
    let frames_sent = Arc::new(AtomicU64::new(0));
    let reconnects = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for mut mon in monitors(&scfg) {
        let tx = frame_tx.clone();
        let clock = VirtualClock::new(cfg.speedup);
        let ticks = cfg.duration_s;
        let frames_sent = Arc::clone(&frames_sent);
        let reconnects = Arc::clone(&reconnects);
        handles.push(std::thread::spawn(move || {
            let mut client = match http_addr {
                Some(addr) => match crate::http::IngestClient::connect(addr) {
                    Ok(c) => Some(c),
                    Err(e) => {
                        eprintln!("monitor {}: ingest connect failed: {e}", mon.index);
                        return;
                    }
                },
                None => None,
            };
            for t in 0..ticks {
                clock.sleep_until_sim(t as f64);
                let emit = mon.tick(t);
                if emit.sever {
                    // the monitor's link dies *before* this tick's batch
                    // leaves, so the redial resends nothing the server
                    // already admitted — delivery stays exactly-once and
                    // the fault budget stays exact
                    if let Some(c) = client.as_mut() {
                        c.sever();
                    }
                }
                if emit.frames.is_empty() {
                    continue;
                }
                frames_sent.fetch_add(emit.frames.len() as u64, Ordering::Relaxed);
                let delivered = match client.as_mut() {
                    Some(c) => c.send_frames(&emit.frames).is_ok(),
                    None => emit.frames.iter().all(|f| tx.send(*f).is_ok()),
                };
                if !delivered {
                    eprintln!("monitor {}: delivery failed at tick {t}", mon.index);
                    break;
                }
            }
            if let Some(c) = client.as_ref() {
                reconnects.fetch_add(c.reconnects(), Ordering::Relaxed);
            }
        }));
    }

    // the byte-level hostile client: never becomes a Frame, attacks the
    // HTTP boundary itself
    let mut hostile_handle = None;
    if cfg.scenario == Scenario::HostileEdge {
        let addr = http_addr.expect("hostile-edge forces the HTTP edge on");
        let clock = VirtualClock::new(cfg.speedup);
        let ticks = cfg.duration_s;
        hostile_handle = Some(std::thread::spawn(move || {
            hostile_byte_driver(addr, &clock, ticks)
        }));
    }
    drop(frame_tx);

    let sink = std::thread::spawn(move || {
        let mut rows: Vec<(usize, u64, f64, f64, f64)> = Vec::new();
        for r in pred_rx {
            rows.push(r);
        }
        rows
    });

    for h in handles {
        let _ = h.join();
    }
    let hostile = match hostile_handle {
        Some(h) => Some(h.join().map_err(|_| Error::serving("hostile driver panicked"))?),
        None => None,
    };
    // teardown order matters: the HTTP edge holds a ShardSender clone,
    // so it must stop before the shard join can see channel close; the
    // data plane drains before the control plane stops
    drop(http);
    shard_router.join()?;
    let drain_deadline = Instant::now() + Duration::from_secs(60);
    while pipeline.pending_len() > 0 && Instant::now() < drain_deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    if governor.is_some() {
        std::thread::sleep(GovernorConfig::default().tick * 4);
    }
    drop(governor);
    drop(pipeline);
    let rows = sink.join().map_err(|_| Error::serving("sink panicked"))?;

    let ordering = Ordering::Relaxed;
    let submitted_n = submitted.load(ordering);
    let resolved = telemetry.queries.load(ordering) + telemetry.failures.load(ordering);
    let fingerprint = rows
        .iter()
        .fold(0u64, |acc, &(p, w, _, s, _)| acc.wrapping_add(prediction_hash(p, w, s)));
    let recovery_start = scfg.recovery_start_sim();
    let recovery: Vec<f64> =
        rows.iter().filter(|r| r.2 >= recovery_start).map(|r| r.4).collect();
    let gov = telemetry.governor();
    let mut report = ReplayReport {
        scenario: cfg.scenario,
        seed: cfg.seed,
        shards: n_shards,
        workers: n_workers,
        govern: cfg.govern,
        http: http_addr.is_some(),
        budget: expected,
        accounting: ReplayAccounting {
            frames_sent: frames_sent.load(ordering),
            frames_ingested: telemetry.frames.load(ordering),
            frames_dropped: telemetry.frames_dropped.load(ordering),
            frames_dropped_malformed: telemetry.frames_dropped_malformed.load(ordering),
            frames_dropped_overcap: telemetry.frames_dropped_overcap.load(ordering),
            frames_stale: telemetry.frames_stale.load(ordering),
            patients_evicted: telemetry.patients_evicted.load(ordering),
            queries_submitted: submitted_n,
            predictions: rows.len() as u64,
            unresolved: submitted_n.saturating_sub(resolved),
            score_fingerprint: fingerprint,
        },
        slo_s: slo.as_secs_f64(),
        e2e_p95: telemetry.e2e.percentile(95.0),
        recovery_p95: crate::metrics::percentile(&recovery, 95.0),
        recovery_n: recovery.len(),
        client_reconnects: reconnects.load(ordering),
        conns_accepted: telemetry.conns_accepted.load(ordering),
        conns_refused: telemetry.conns_refused.load(ordering),
        conns_refused_overcap: telemetry.conns_refused_overcap.load(ordering),
        conns_refused_handshake: telemetry.conns_refused_handshake.load(ordering),
        conns_reaped: telemetry.conns_reaped.load(ordering),
        hostile,
        route_peers: 0,
        frames_spilled: 0,
        spill_replayed: 0,
        spill_overflow: 0,
        replay_dropped: 0,
        patients_rehomed: 0,
        peers_reinstated: 0,
        governor_degraded_entered: gov
            .map(|g| g.degraded_entered.load(ordering))
            .unwrap_or(0),
        governor_swaps: gov.map(|g| g.swaps.load(ordering)).unwrap_or(0),
        wall_s: t_start.elapsed().as_secs_f64(),
        violations: Vec::new(),
    };
    report.violations = check_invariants(&report);
    print_report(&report);
    Ok(report)
}

/// One downstream serving stack behind the router: its own shard
/// plane, executor pipeline, telemetry, and ingest edge on a loopback
/// port. The executor [`Engine`] (device permits, profiles) is shared
/// across peers — node loss is a serving-plane fault, not a device
/// fault.
struct PeerStack {
    server: crate::http::HttpServer,
    frame_tx: ShardSender,
    shard_router: ShardRouter,
    pipeline: Pipeline,
    telemetry: Arc<Telemetry>,
}

/// Two-phase rendezvous for the node-loss kill script: every monitor
/// checks in after delivering the kill tick, the script freezes and
/// tears down the victim on that (empty-fill) tick boundary, then
/// releases the cohort into the outage. This keeps the fault budget
/// exact — a wall-clock-raced kill could land mid-window and strand a
/// partial aggregation fill in the dying stack.
struct KillFence {
    /// (monitors past the kill tick, script done — cohort may resume)
    state: Mutex<(usize, bool)>,
    cv: Condvar,
}

impl KillFence {
    fn new() -> Self {
        KillFence { state: Mutex::new((0, false)), cv: Condvar::new() }
    }

    /// Monitor side: check in after the kill tick's frames are
    /// delivered, block until the script releases the cohort.
    fn check_in_and_wait(&self) {
        let mut st = self.state.lock().unwrap();
        st.0 += 1;
        self.cv.notify_all();
        while !st.1 {
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Script side: wait for every monitor to clear the kill tick.
    fn wait_all(&self, n: usize) {
        let mut st = self.state.lock().unwrap();
        while st.0 < n {
            st = self.cv.wait(st).unwrap();
        }
    }

    fn release(&self) {
        let mut st = self.state.lock().unwrap();
        st.1 = true;
        self.cv.notify_all();
    }
}

/// The routed replay: the cohort streams through a [`Router`] into
/// `route_peers` independent serving stacks, each owning a
/// consistent-hash share of the patients. For `node-loss` the driver
/// additionally runs the scripted chaos: kill the peer that owns
/// patient 0 a third of the way in (its frames spill, the heartbeat
/// prober declares it dead, its patients re-home to the survivor and
/// the spill replays), restart it on the **same port** two thirds in
/// (a canary probe reinstates it), and admit a second patient wave
/// that the returnee can pick up. Every count is then held against the
/// scenario's ring-mirror budget by [`check_invariants`].
fn run_replay_routed(zoo: &Zoo, cfg: ReplayConfig) -> Result<ReplayReport> {
    let n_peers = cfg.route_peers;
    match cfg.scenario {
        Scenario::Churn => {
            return Err(Error::config(
                "churn's LRU budget models one shard plane — it cannot run routed",
            ))
        }
        Scenario::HostileEdge => {
            return Err(Error::config(
                "hostile-edge attacks the direct ingest edge — it cannot run routed",
            ))
        }
        Scenario::NodeLoss if n_peers != 2 => {
            return Err(Error::config(
                "node-loss's fault budget mirrors a 2-peer ring; use --route-peers 2",
            ))
        }
        _ => {}
    }
    if cfg.govern {
        return Err(Error::config("--govern is per-stack; it is not supported routed"));
    }
    if cfg.http_addr.is_some() {
        return Err(Error::config(
            "routed replay drives the router sink in-process; use `holmes route` for a wire-level router tier",
        ));
    }

    let n_shards = if cfg.shards == 0 { 2 } else { cfg.shards };
    let n_workers =
        if cfg.workers == 0 { crate::serving::default_workers_for(cfg.gpus) } else { cfg.workers };
    let clip_len = zoo.manifest.clip_len;
    let scfg = ScenarioCfg {
        scenario: cfg.scenario,
        patients: cfg.patients,
        ticks: cfg.duration_s,
        seed: cfg.seed,
        window_samples: clip_len,
        synth: SynthConfig::from(&zoo.manifest.calibration),
    };
    let max_patients = ShardConfig::default().max_patients;
    let expected = budget(&scfg, n_shards, max_patients);
    println!(
        "replay: scenario {} seed {} — {} patients, {} ticks, routed over {} peers \
         ({} shards, {} workers each), speedup {}×, SLO {} ms",
        cfg.scenario.name(),
        cfg.seed,
        cfg.patients,
        cfg.duration_s,
        n_peers,
        n_shards,
        n_workers,
        cfg.speedup,
        cfg.slo_ms,
    );
    println!(
        "fault budget: {} frames → {} windows | malformed {} stale {} overcap {} \
         evictions {} severs {} re-homed {}",
        expected.frames_sent,
        expected.windows,
        expected.frames_malformed,
        expected.frames_stale,
        expected.frames_overcap,
        expected.evictions,
        expected.severs,
        expected.rehomed_patients,
    );

    let ensemble = super::fig10_scalability::holmes_servable_ensemble(zoo, 0.2);
    let engine = if cfg.scenario == Scenario::BurstStorm {
        let times = ServiceTimes::from_macs(zoo, 5e-4, 2e10);
        let backend = SimBackend::with_times(times, STORM_TIME_SCALE);
        Engine::with_backend(zoo, cfg.gpus, Arc::new(backend))?
    } else {
        Engine::new(zoo, cfg.gpus)?
    };
    for &m in ensemble.indices() {
        for &b in engine.batch_sizes() {
            engine.profile_model((m, b), 1)?;
        }
    }

    let t_start = Instant::now();
    let slo = Duration::from_secs_f64((cfg.slo_ms / 1000.0).max(0.001));
    let submitted = Arc::new(AtomicU64::new(0));
    let (pred_tx, pred_rx) = mpsc::channel::<(usize, u64, f64, f64, f64)>();

    // one full serving stack per peer; the closure is reused by the
    // node-loss rolling restart to rebuild the victim on its old port
    let spawn_stack = |listen: &str| -> Result<PeerStack> {
        let pipeline = Pipeline::spawn(
            zoo,
            &engine,
            PipelineConfig::new(ensemble.clone()).with_workers(n_workers).with_slo(slo),
        )?;
        let telemetry = Arc::clone(pipeline.telemetry());
        let (shard_router, frame_tx) = ShardRouter::spawn(
            ShardConfig { shards: n_shards, max_patients, ..ShardConfig::default() },
            clip_len,
            Arc::clone(&telemetry),
            |_shard| {
                let pipeline = pipeline.clone();
                let pred_tx = pred_tx.clone();
                let submitted = Arc::clone(&submitted);
                move |window| {
                    let q = Query::from_window(window);
                    if let Ok(rx) = pipeline.submit(q) {
                        submitted.fetch_add(1, Ordering::Relaxed);
                        let pred_tx = pred_tx.clone();
                        std::thread::spawn(move || {
                            if let Ok(p) = rx.recv() {
                                let _ = pred_tx.send((
                                    p.patient,
                                    p.window_id,
                                    p.sim_end,
                                    p.score,
                                    p.e2e.as_secs_f64(),
                                ));
                            }
                        });
                    }
                }
            },
        )?;
        let server = crate::http::serve_with(
            listen,
            frame_tx.clone(),
            Arc::clone(&telemetry),
            crate::http::HttpConfig {
                edge_threads: cfg.edge_threads,
                ..crate::http::HttpConfig::default()
            },
        )?;
        Ok(PeerStack { server, frame_tx, shard_router, pipeline, telemetry })
    };

    let mut stacks: Vec<Option<PeerStack>> = Vec::with_capacity(n_peers);
    for _ in 0..n_peers {
        stacks.push(Some(spawn_stack("127.0.0.1:0")?));
    }
    let peer_addrs: Vec<SocketAddr> =
        stacks.iter().map(|s| s.as_ref().expect("fresh stack").server.addr).collect();
    for (i, addr) in peer_addrs.iter().enumerate() {
        println!("routed peer {i} serving on {addr}");
    }

    // fast probe cadence so failure detection and canary reinstatement
    // fit inside a sped-up replay; dead_after 3 keeps a single dropped
    // probe from flapping a healthy peer out of the ring
    let health = HealthConfig {
        probe_interval: Duration::from_millis(10),
        dead_after: 3,
        backoff_init: 1,
        backoff_max: 4,
        connect_timeout: Duration::from_millis(100),
        io_timeout: Duration::from_millis(250),
    };
    let mut rcfg = RouterConfig::new(peer_addrs.clone());
    rcfg.health = health;
    let router = Router::new(&rcfg)?;
    let prober = router.spawn_prober(health);

    // the scripted chaos targets the peer that owns patient 0 — the
    // same victim the scenario's budget mirror computes its re-home
    // count for
    let kill_tick = cfg.duration_s / 3;
    let restart_tick = cfg.duration_s * 2 / 3;
    let victim = Ring::new(n_peers).route(0);
    let fence = (cfg.scenario == Scenario::NodeLoss).then(|| Arc::new(KillFence::new()));

    let frames_sent = Arc::new(AtomicU64::new(0));
    // anchored now, alongside the monitors' clocks — the kill script's
    // restart tick is measured from run start, not from the kill
    let script_clock = VirtualClock::new(cfg.speedup);
    let mut handles = Vec::new();
    for mut mon in monitors(&scfg) {
        let sink = router.sink();
        let clock = VirtualClock::new(cfg.speedup);
        let ticks = cfg.duration_s;
        let frames_sent = Arc::clone(&frames_sent);
        let fence = fence.clone();
        handles.push(std::thread::spawn(move || {
            for t in 0..ticks {
                clock.sleep_until_sim(t as f64);
                let emit = mon.tick(t);
                // emit.sever models the bedside TCP hop dying; routed
                // delivery is in-process, so there is no link to cut
                if !emit.frames.is_empty() {
                    frames_sent.fetch_add(emit.frames.len() as u64, Ordering::Relaxed);
                    for f in &emit.frames {
                        if let Err(e) = sink.deliver(*f) {
                            eprintln!("monitor {}: routed delivery failed at tick {t}: {e}", mon.index);
                            return;
                        }
                    }
                }
                if let Some(fence) = &fence {
                    if t == kill_tick {
                        fence.check_in_and_wait();
                    }
                }
            }
        }));
    }

    let mut retired_pipelines: Vec<Pipeline> = Vec::new();
    let mut retired_telemetry: Vec<Arc<Telemetry>> = Vec::new();
    if let Some(fence) = &fence {
        // ── the node-loss kill script ──
        fence.wait_all(handles.len());
        // freeze the victim's link on the tick boundary: everything up
        // to the kill tick flushes to the peer, everything after spills
        router.quiesce_peer(victim);
        // crash the victim's serving stack; its pipeline keeps
        // draining in the background so already-admitted queries still
        // resolve, and its telemetry stays in the books
        let PeerStack { server, frame_tx, shard_router, pipeline, telemetry } =
            stacks[victim].take().expect("victim stack");
        let victim_addr = server.addr;
        drop(server);
        drop(frame_tx);
        shard_router.join()?;
        retired_pipelines.push(pipeline);
        retired_telemetry.push(telemetry);
        println!("node-loss: killed peer {victim} ({victim_addr}) after tick {kill_tick}");
        // release the cohort into the outage
        fence.release();
        // the prober must observe the death and fail the cohort over
        // (re-home + spill replay) before a restart could mask it
        let deadline = Instant::now() + Duration::from_secs(30);
        while router.gauges().patients_rehomed.load(Ordering::Relaxed) == 0
            && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(2));
        }
        // rolling restart on the same port (`bind_reuse` re-claims it
        // through TIME_WAIT); the canary probe reinstates the peer
        script_clock.sleep_until_sim(restart_tick as f64);
        stacks[victim] = Some(spawn_stack(&victim_addr.to_string())?);
        println!("node-loss: restarted peer {victim} on {victim_addr} at tick {restart_tick}");
    }
    drop(spawn_stack);
    drop(pred_tx);

    for h in handles {
        let _ = h.join();
    }
    if cfg.scenario == Scenario::NodeLoss {
        // reinstatement must land before the books close
        let deadline = Instant::now() + Duration::from_secs(30);
        while router.gauges().peers_reinstated.load(Ordering::Relaxed) == 0
            && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    // stop probing before links start disappearing, then flush every
    // link while the peer edges are still up
    drop(prober);
    router.shutdown();

    let sink = std::thread::spawn(move || {
        let mut rows: Vec<(usize, u64, f64, f64, f64)> = Vec::new();
        for r in pred_rx {
            rows.push(r);
        }
        rows
    });

    let mut pipelines = retired_pipelines;
    let mut telemetries = retired_telemetry;
    for stack in stacks.into_iter().flatten() {
        let PeerStack { server, frame_tx, shard_router, pipeline, telemetry } = stack;
        drop(server);
        drop(frame_tx);
        shard_router.join()?;
        pipelines.push(pipeline);
        telemetries.push(telemetry);
    }
    let drain_deadline = Instant::now() + Duration::from_secs(60);
    while pipelines.iter().any(|p| p.pending_len() > 0) && Instant::now() < drain_deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    drop(pipelines);
    let rows = sink.join().map_err(|_| Error::serving("sink panicked"))?;

    let ordering = Ordering::Relaxed;
    let submitted_n = submitted.load(ordering);
    let resolved: u64 = telemetries
        .iter()
        .map(|t| t.queries.load(ordering) + t.failures.load(ordering))
        .sum();
    let sum = |field: fn(&Telemetry) -> &AtomicU64| -> u64 {
        telemetries.iter().map(|t| field(t).load(ordering)).sum()
    };
    let fingerprint = rows
        .iter()
        .fold(0u64, |acc, &(p, w, _, s, _)| acc.wrapping_add(prediction_hash(p, w, s)));
    let recovery_start = scfg.recovery_start_sim();
    let recovery: Vec<f64> =
        rows.iter().filter(|r| r.2 >= recovery_start).map(|r| r.4).collect();
    let all_e2e: Vec<f64> = rows.iter().map(|r| r.4).collect();
    let g = router.gauges();
    let mut report = ReplayReport {
        scenario: cfg.scenario,
        seed: cfg.seed,
        shards: n_shards,
        workers: n_workers,
        govern: false,
        http: false,
        budget: expected,
        accounting: ReplayAccounting {
            frames_sent: frames_sent.load(ordering),
            frames_ingested: sum(|t| &t.frames),
            frames_dropped: sum(|t| &t.frames_dropped),
            frames_dropped_malformed: sum(|t| &t.frames_dropped_malformed),
            frames_dropped_overcap: sum(|t| &t.frames_dropped_overcap),
            frames_stale: sum(|t| &t.frames_stale),
            patients_evicted: sum(|t| &t.patients_evicted),
            queries_submitted: submitted_n,
            predictions: rows.len() as u64,
            unresolved: submitted_n.saturating_sub(resolved),
            score_fingerprint: fingerprint,
        },
        slo_s: slo.as_secs_f64(),
        e2e_p95: crate::metrics::percentile(&all_e2e, 95.0),
        recovery_p95: crate::metrics::percentile(&recovery, 95.0),
        recovery_n: recovery.len(),
        client_reconnects: 0,
        conns_accepted: sum(|t| &t.conns_accepted),
        conns_refused: sum(|t| &t.conns_refused),
        conns_refused_overcap: sum(|t| &t.conns_refused_overcap),
        conns_refused_handshake: sum(|t| &t.conns_refused_handshake),
        conns_reaped: sum(|t| &t.conns_reaped),
        hostile: None,
        route_peers: n_peers,
        frames_spilled: g.spilled_total.load(ordering),
        spill_replayed: g.spill_replayed.load(ordering),
        spill_overflow: g.spill_overflow.load(ordering),
        replay_dropped: g.replay_dropped.load(ordering),
        patients_rehomed: g.patients_rehomed.load(ordering),
        peers_reinstated: g.peers_reinstated.load(ordering),
        governor_degraded_entered: 0,
        governor_swaps: 0,
        wall_s: t_start.elapsed().as_secs_f64(),
        violations: Vec::new(),
    };
    report.violations = check_invariants(&report);
    print_report(&report);
    Ok(report)
}

/// The raw-TCP hostile phases: corrupt bodies on a keep-alive
/// connection, a connection flood against the edge cap, slow-loris
/// holds until the sweep reaps them. Returns what the *client* observed
/// so the invariants can cross-check server counters against ground
/// truth.
fn hostile_byte_driver(addr: SocketAddr, clock: &VirtualClock, ticks: u64) -> HostileOutcome {
    let mut out = HostileOutcome::default();

    // phase 1 — malformed wire bodies, every one a 400, none fatal to
    // the connection or to the cohort streaming beside it
    clock.sleep_until_sim(1.0);
    let mut bodies: Vec<Vec<u8>> = Vec::new();
    for i in 0..8u8 {
        // corrupt magic, plausible header tail
        let mut b = b"XXX1".to_vec();
        b.extend_from_slice(&[1, 0, i, 3]);
        b.extend_from_slice(&[0u8; 20]);
        bodies.push(b);
    }
    let mut valid = Vec::new();
    crate::ingest::Frame {
        patient: 3,
        modality: crate::ingest::Modality::Ecg,
        sim_time: 1.0,
        values: [0.1, 0.2, 0.3].into(),
    }
    .write_bytes(&mut valid);
    for _ in 0..2 {
        // truncated: header promises 3 values, body ends early
        bodies.push(valid[..valid.len() - 4].to_vec());
    }
    for _ in 0..2 {
        // NaN payload in an otherwise valid frame
        let mut nan = Vec::new();
        crate::ingest::Frame {
            patient: 3,
            modality: crate::ingest::Modality::Ecg,
            sim_time: 1.0,
            values: crate::ingest::FrameValues::from_slice(&[f32::NAN, 0.0, 0.0])
                .expect("3 values fit"),
        }
        .write_bytes(&mut nan);
        bodies.push(nan);
    }
    let mut conn = TcpStream::connect(addr).ok();
    for body in &bodies {
        out.bad_bodies_sent += 1;
        let status = loop {
            match conn.as_mut().map(|c| post_raw(c, body)) {
                Some(Ok(s)) => break Some(s),
                // server may have closed the previous exchange — redial
                // once and retry; a second failure counts as no response
                _ => match TcpStream::connect(addr) {
                    Ok(c) => {
                        let fresh = conn.is_none();
                        conn = Some(c);
                        if fresh {
                            continue;
                        }
                        match post_raw(conn.as_mut().expect("just set"), body) {
                            Ok(s) => break Some(s),
                            Err(_) => break None,
                        }
                    }
                    Err(_) => break None,
                },
            }
        };
        if status == Some(400) {
            out.bad_bodies_rejected += 1;
        }
    }
    drop(conn);

    // phase 2 — connection flood: open everything at once and count the
    // edge's 503 refusals; accepted sockets are closed again untouched
    clock.sleep_until_sim((ticks / 3) as f64);
    let mut flood = Vec::new();
    for _ in 0..HOSTILE_FLOOD_CONNS {
        out.flood_conns += 1;
        if let Ok(s) = TcpStream::connect(addr) {
            flood.push(s);
        }
    }
    for s in &mut flood {
        let _ = s.set_read_timeout(Some(Duration::from_millis(500)));
        let mut buf = [0u8; 64];
        // a refused connection gets "503 …" pushed at accept; an
        // accepted one stays silent until a request arrives
        if let Ok(n) = s.read(&mut buf) {
            if n > 0 && parse_status(&buf[..n]) == Some(503) {
                out.flood_refused += 1;
            }
        }
    }
    drop(flood);

    // phase 3 — slow loris: send half a request head and hold the
    // socket; block until the read-timeout sweep reaps it (the server
    // closing on us IS the pass signal, so joins stay race-free)
    clock.sleep_until_sim((ticks / 2) as f64);
    let mut loris = Vec::new();
    for _ in 0..HOSTILE_LORIS_CONNS {
        if let Ok(mut s) = TcpStream::connect(addr) {
            if s.write_all(b"POST /ingest.bin HTTP/1.1\r\nContent-Le").is_ok() {
                out.loris_conns += 1;
                loris.push(s);
            }
        }
    }
    for s in &mut loris {
        let _ = s.set_read_timeout(Some(Duration::from_secs(20)));
        let mut buf = [0u8; 64];
        // EOF or error ⇒ the sweep reaped us
        while let Ok(n) = s.read(&mut buf) {
            if n == 0 {
                break;
            }
        }
    }
    out
}

/// POST one binary body and return the response status. Drains the
/// full response (headers + declared body) so the next request on the
/// same keep-alive connection starts on a clean stream.
fn post_raw(stream: &mut TcpStream, body: &[u8]) -> std::io::Result<u16> {
    let head = format!(
        "POST /ingest.bin HTTP/1.1\r\nHost: holmes\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let mut buf = Vec::new();
    let mut chunk = [0u8; 512];
    let header_end = loop {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "closed before response head",
            ));
        }
        buf.extend_from_slice(&chunk[..n]);
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos + 4;
        }
        if buf.len() > 8 * 1024 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "oversized response head",
            ));
        }
    };
    let status = parse_status(&buf)
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "bad status line"))?;
    let content_len = std::str::from_utf8(&buf[..header_end])
        .ok()
        .and_then(|h| {
            h.lines()
                .find(|l| l.to_ascii_lowercase().starts_with("content-length:"))
                .and_then(|l| l.split(':').nth(1))
                .and_then(|v| v.trim().parse::<usize>().ok())
        })
        .unwrap_or(0);
    while buf.len() < header_end + content_len {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
    }
    Ok(status)
}

fn parse_status(buf: &[u8]) -> Option<u16> {
    let line = buf.split(|&b| b == b'\r').next()?;
    let text = std::str::from_utf8(line).ok()?;
    let code = text.split_whitespace().nth(1)?;
    code.parse().ok()
}

fn print_report(r: &ReplayReport) {
    println!("\n── replay report: {} (seed {}) ───────────", r.scenario.name(), r.seed);
    let a = &r.accounting;
    let b = &r.budget;
    println!("frames sent          {:>12}  (budget {})", a.frames_sent, b.frames_sent);
    println!("frames ingested      {:>12}", a.frames_ingested);
    println!(
        "frames dropped       {:>12}  (malformed {} / over-cap {} / stale {})",
        a.frames_dropped, a.frames_dropped_malformed, a.frames_dropped_overcap, a.frames_stale
    );
    println!(
        "patients evicted     {:>12}  (budget {})",
        a.patients_evicted, b.evictions
    );
    println!(
        "windows → queries    {:>12} → {} submitted, {} predictions, {} unresolved",
        b.windows, a.queries_submitted, a.predictions, a.unresolved
    );
    println!("score fingerprint    {:>#12x}", a.score_fingerprint);
    if r.http {
        println!(
            "edge connections     {:>12}  (refused {} = over-cap {} + handshake {}, reaped {})",
            r.conns_accepted,
            r.conns_refused,
            r.conns_refused_overcap,
            r.conns_refused_handshake,
            r.conns_reaped
        );
        println!("client reconnects    {:>12}  (severs injected: {})", r.client_reconnects, b.severs);
    }
    if r.route_peers > 0 {
        println!(
            "router tier          {:>12}  peers — re-homed {} (budget {}), spilled {} / replayed {} / overflow {} / replay-dropped {}, reinstated {}",
            r.route_peers,
            r.patients_rehomed,
            r.budget.rehomed_patients,
            r.frames_spilled,
            r.spill_replayed,
            r.spill_overflow,
            r.replay_dropped,
            r.peers_reinstated
        );
    }
    if let Some(h) = &r.hostile {
        println!(
            "hostile client       {:>12}  bad bodies ({} rejected), {} flood conns ({} refused), {} loris",
            h.bad_bodies_sent, h.bad_bodies_rejected, h.flood_conns, h.flood_refused, h.loris_conns
        );
    }
    if r.govern {
        println!(
            "governor             {:>12}  swaps, degraded {}×",
            r.governor_swaps, r.governor_degraded_entered
        );
    }
    println!("e2e p95              {:>11.4}s  (SLO {:.1}s)", r.e2e_p95, r.slo_s);
    println!(
        "recovery p95         {:>11.4}s  over {} post-fault predictions",
        r.recovery_p95, r.recovery_n
    );
    println!("wall time            {:>11.1}s", r.wall_s);
    if r.violations.is_empty() {
        println!("REPLAY OK — every invariant held");
    } else {
        println!("REPLAY FAILED — {} invariant breach(es):", r.violations.len());
        for v in &r.violations {
            println!("  ✗ {v}");
        }
    }
}
