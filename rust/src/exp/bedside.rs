//! End-to-end bedside serving simulation: N patients stream 250 Hz ECG
//! (+1 Hz vitals) through the **sharded** per-patient aggregation plane
//! into the ensemble pipeline — the full Fig. 4 path, used by
//! `holmes serve` and the `bedside_sim` example, and the source of the
//! headline "64-bed, sub-second p95" number.
//!
//! Frames route through a [`ShardSender`] (`patient % shards`, bounded
//! per-shard queues) onto N aggregation workers, each owning its
//! patients' [`WindowAggregator`]s — no single thread touches every
//! frame. Completed windows are submitted straight into the pipeline
//! from the shard threads.
//!
//! With `http_addr` set the patient generators become real network
//! clients: each opens one keep-alive connection and streams its
//! frames as binary `POST /ingest.bin` bodies (one body per simulated
//! second — 251 wire frames), exercising the full 25k frames/s ingest
//! edge instead of an in-process channel.
//!
//! With `govern` set the run spawns the [`Governor`] control plane
//! over the pipeline; with `chaos` set it becomes the CI chaos smoke:
//! the sim backend runs with service times scaled up
//! ([`CHAOS_TIME_SCALE`]×) so load genuinely saturates the device
//! permits, a scripted backend fault kills the ensemble's first lane
//! just before the one-third mark, and a thundering herd of
//! [`CHAOS_GHOSTS_PER_PATIENT`]× ghost patients streams exactly one
//! window starting at that mark — driving the tail past the SLO. The
//! report then carries what the governor did about it (degrade swaps,
//! canary reinstatements) plus an `unresolved` count proving no
//! admitted query was dropped on the floor.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::ingest::synth::{PatientSim, SynthConfig};
use crate::ingest::{Frame, Modality, VirtualClock};
use crate::metrics::roc_auc;
use crate::profiler::ServiceTimes;
use crate::runtime::{Engine, SimBackend};
use crate::serving::pipeline::{Pipeline, PipelineConfig, Query};
use crate::serving::shards::{ShardConfig, ShardRouter};
use crate::serving::{Governor, GovernorConfig, Telemetry};
use crate::zoo::Zoo;
use crate::Result;

/// Chaos mode: multiplier on the sim backend's calibrated service
/// times. Large enough that the ghost storm's backlog drains over
/// ~1.5 s of wall (past a 1 s SLO → the governor must degrade), small
/// enough to stay clear of the pending arena's 2 s stale-evict
/// failsafe.
pub const CHAOS_TIME_SCALE: f64 = 32.0;

/// Chaos mode: ghost admission-storm size, as a multiple of the
/// configured patient count.
pub const CHAOS_GHOSTS_PER_PATIENT: usize = 4;

#[derive(Debug, Clone)]
pub struct BedsideConfig {
    pub patients: usize,
    pub gpus: usize,
    pub window_s: f64,
    pub speedup: f64,
    pub duration_s: f64,
    pub http_addr: Option<String>,
    /// Event-loop threads for the epoll ingest edge (`--edge-threads`;
    /// 0 = auto, cores/4). Only meaningful with `http_addr` set, and
    /// ignored by the thread-per-connection fallback.
    pub edge_threads: usize,
    pub seed: u64,
    /// Aggregation shards; 0 = core-count heuristic
    /// ([`crate::serving::default_shards`]).
    pub shards: usize,
    /// Executor pool threads; 0 = core-count default capped by the
    /// device-permit count ([`crate::serving::default_workers_for`]).
    /// Independent of the ensemble size — the point of the
    /// work-stealing executor.
    pub workers: usize,
    /// End-to-end latency SLO in milliseconds (`--slo-ms`; the paper's
    /// sub-second bound → 1000). Steers the adaptive deadline
    /// controller and is reported against the measured p95.
    pub slo_ms: f64,
    /// Replace the static batch fill deadline with the SLO-aware
    /// adaptive controller (`--adaptive-batch`).
    pub adaptive: bool,
    /// Spawn the ensemble governor control plane over the pipeline
    /// (`--govern`): live re-composition, degraded-mode floor, backend
    /// quarantine/recovery.
    pub govern: bool,
    /// Governor control-loop period in milliseconds
    /// (`--control-tick-ms`).
    pub control_tick_ms: f64,
    /// Degraded-mode accuracy floor — the minimum ensemble validation
    /// ROC-AUC the stepped-down member set must clear (`--floor-acc`).
    pub floor_acc: f64,
    /// Chaos harness (`--chaos`): scaled-up sim service times, a
    /// scripted mid-run backend fault, and a ghost admission storm —
    /// the CI smoke for degrade → quarantine → reinstate.
    pub chaos: bool,
    /// Root directory of this node's content-addressed artifact store
    /// (`--registry-root`). When set, the node publishes its zoo
    /// bundles into the store (warm node) or fetches the active
    /// ensemble's artifacts from `registry_peer` (cold node), serves
    /// `GET /artifact/<id>` from it, and backs its heartbeat residency
    /// claims with actual store contents.
    pub registry_root: Option<String>,
    /// `host:port` of a warm peer to pull missing artifacts from
    /// (`--registry`). Only meaningful with `registry_root` set; turns
    /// this node into a cold peer that must fetch before it may claim
    /// `"resident":true` on heartbeats.
    pub registry_peer: Option<String>,
}

impl Default for BedsideConfig {
    fn default() -> Self {
        BedsideConfig {
            patients: 64,
            gpus: 2,
            window_s: 30.0,
            speedup: 1.0,
            duration_s: 120.0,
            http_addr: None,
            edge_threads: 0,
            seed: 42,
            shards: 0,
            workers: 0,
            slo_ms: 1000.0,
            adaptive: false,
            govern: false,
            control_tick_ms: 100.0,
            floor_acc: 0.80,
            chaos: false,
            registry_root: None,
            registry_peer: None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct BedsideReport {
    pub predictions: usize,
    pub frames: u64,
    /// Frames the aggregation plane discarded (malformed / mismatched),
    /// summed over shards — nonzero means silent data loss upstream.
    pub frames_dropped: u64,
    /// Per-shard breakdown of `frames_dropped`.
    pub dropped_per_shard: Vec<u64>,
    /// `frames_dropped` split by cause: payload-arity rejects.
    pub frames_dropped_malformed: u64,
    /// `frames_dropped` split by cause: shard at capacity with every
    /// tracked aggregator mid-window.
    pub frames_dropped_overcap: u64,
    /// ECG frames shed for arriving behind the window position
    /// (out-of-order / skewed monitor clocks).
    pub frames_stale: u64,
    /// Transport-level reconnects the ingest clients performed (HTTP
    /// runs only — dropped monitor links redialing with backoff).
    pub client_reconnects: u64,
    /// Device batches executed by each executor pool worker — a skewed
    /// vector means the work-stealing pool was imbalanced.
    pub batches_per_worker: Vec<u64>,
    /// Batch fill deadline last armed per ensemble member, ns: the
    /// static policy timeout, or — under `--adaptive-batch` — where the
    /// controller had steered each model's window by end of run.
    pub fill_wait_ns_per_model: Vec<u64>,
    /// Connections accepted by the HTTP ingest edge (0 when the run
    /// ingested in-process).
    pub conns_accepted: u64,
    /// Connections refused at the edge's connection gate.
    pub conns_refused: u64,
    /// Stalled connections reaped by the read-timeout sweep.
    pub conns_reaped: u64,
    /// Readiness events handled per epoll event loop — empty when the
    /// run used in-process ingest or the thread-per-conn fallback. A
    /// healthy edge shows every loop nonzero (EPOLLEXCLUSIVE spreads
    /// accepts) under multi-connection load.
    pub edge_ready_events: Vec<u64>,
    /// The configured end-to-end SLO, seconds (p95 is judged against
    /// it in the printed report).
    pub slo_s: f64,
    pub e2e_p50: f64,
    pub e2e_p95: f64,
    pub e2e_p99: f64,
    pub roc_auc: f64,
    pub wall_s: f64,
    /// Idle patient aggregators evicted (least-recently-updated) to
    /// admit new patients past the shard cap — admission churn, not
    /// silent starvation.
    pub patients_evicted: u64,
    /// Transient backend errors absorbed by the bounded in-flush retry,
    /// summed over lanes.
    pub exec_retries: u64,
    /// Queries the shard plane successfully admitted into the pipeline.
    pub submitted: u64,
    /// Admitted queries never accounted as completed or failed — must
    /// be 0 on every run; anything else is a dropped in-flight query.
    pub unresolved: u64,
    /// Governor state at end of run (all zero on an ungoverned run).
    pub governor_epoch: u64,
    pub governor_swaps: u64,
    pub governor_degraded_entered: u64,
    pub governor_probes: u64,
    pub governor_reinstated: u64,
    pub governor_quarantined: u64,
    /// Artifact plane at end of run (all zero without `--registry-root`):
    /// how many artifacts the active ensemble demands, how many the
    /// local store holds, and the registry traffic both ways.
    pub artifacts_required: u64,
    pub artifacts_resident: u64,
    pub artifacts_fetched: u64,
    pub artifacts_served: u64,
    /// Shared compiled-executable cache counters (zero when the active
    /// backend routes compiles elsewhere). `compiles` staying at the
    /// distinct `(artifact, batch)` count while workers > 1 is the
    /// whole point of the process-wide cache.
    pub exec_cache_hits: u64,
    pub exec_cache_misses: u64,
    pub exec_cache_compiles: u64,
}

/// Run the simulation to completion and report latency + accuracy.
///
/// SIGTERM / ctrl-c triggers a graceful drain instead of a hard exit:
/// generators stop at the next tick, heartbeat responses advertise
/// `"draining":true` (so an upstream router re-homes this node's beds
/// before the edge closes), the shard queues and in-flight queries
/// drain through the normal teardown below, the final telemetry
/// snapshot prints, and the process exits 0.
pub fn run_bedside(zoo: &Zoo, cfg: BedsideConfig) -> Result<BedsideReport> {
    crate::signal::install_shutdown_handler();
    let ensemble = super::fig10_scalability::holmes_servable_ensemble(zoo, 0.2);
    let n_shards =
        if cfg.shards == 0 { crate::serving::default_shards() } else { cfg.shards };
    // same rule Executor::spawn applies for workers == 0: the hardware
    // heuristic capped at 2 threads per device permit
    let n_workers =
        if cfg.workers == 0 { crate::serving::default_workers_for(cfg.gpus) } else { cfg.workers };
    println!(
        "bedside sim: {} patients, {} gpus, {} aggregation shards, {} executor workers, \
         ΔT={}s, speedup {}×, {}s sim, batch deadlines {} (SLO {} ms)",
        cfg.patients,
        cfg.gpus,
        n_shards,
        n_workers,
        cfg.window_s,
        cfg.speedup,
        cfg.duration_s,
        if cfg.adaptive { "ADAPTIVE" } else { "static" },
        cfg.slo_ms
    );
    if cfg.govern || cfg.chaos {
        println!(
            "control plane: governor {} (tick {} ms, floor AUC {}), chaos {}",
            if cfg.govern { "ON" } else { "off" },
            cfg.control_tick_ms,
            cfg.floor_acc,
            if cfg.chaos { "ON" } else { "off" },
        );
    }
    println!(
        "ensemble ({} models): {:?}",
        ensemble.len(),
        ensemble.indices().iter().map(|&i| zoo.model(i).id.clone()).collect::<Vec<_>>()
    );
    // chaos mode swaps the default backend for a slowed, scriptable
    // one: service times scaled so load genuinely saturates the device
    // permits, plus a fault switch on the ensemble's first lane that a
    // driver thread flips across the storm window
    let fault_flag = Arc::new(AtomicBool::new(false));
    let engine = if cfg.chaos {
        let times = ServiceTimes::from_macs(zoo, 5e-4, 2e10);
        let backend = SimBackend::with_times(times, CHAOS_TIME_SCALE)
            .with_catalog(Arc::new(crate::runtime::ArtifactCatalog::from_zoo(zoo)))
            .faulty_when(ensemble.indices()[0], Arc::clone(&fault_flag));
        Engine::with_backend(zoo, cfg.gpus, Arc::new(backend))?
    } else {
        Engine::new(zoo, cfg.gpus)?
    };
    // warm compile outside the measured run
    for &m in ensemble.indices() {
        for &b in engine.batch_sizes() {
            engine.profile_model((m, b), 1)?;
        }
    }

    let clip_len = zoo.manifest.clip_len;
    let synth_cfg = SynthConfig::from(&zoo.manifest.calibration);
    let t_start = Instant::now();

    let mut policy = crate::serving::batcher::BatchPolicy::default();
    if cfg.adaptive {
        policy = policy.adaptive();
    }
    let slo = std::time::Duration::from_secs_f64((cfg.slo_ms / 1000.0).max(0.001));
    let pipeline = Pipeline::spawn(
        zoo,
        &engine,
        PipelineConfig::new(ensemble.clone())
            .with_workers(n_workers)
            .with_policy(policy)
            .with_slo(slo),
    )?;
    let telemetry = Arc::clone(pipeline.telemetry());

    // content-addressed artifact plane: a local registry store backs
    // this node's heartbeat residency claims and its /artifact edge.
    // A warm node (no --registry peer) publishes its own zoo bundles;
    // a cold node fetches what the active ensemble demands from the
    // peer — verified, with bounded retry while the peer boots — and
    // only then may it advertise "resident":true. Installed BEFORE the
    // governor spawns so its install path counts residency against the
    // real store.
    if let Some(root) = &cfg.registry_root {
        use crate::registry::{ArtifactBundle, HttpRegistry, LocalFs, Registry};
        let store = Arc::new(LocalFs::open(root.as_str())?);
        let catalog = Arc::clone(engine.artifact_catalog());
        let required = catalog.ids_for_models(ensemble.indices());
        match &cfg.registry_peer {
            None => {
                // warm node: the zoo on disk is the source of truth
                let mut published = 0usize;
                for (key, _) in catalog.known_entries() {
                    store.store(&ArtifactBundle::from_zoo(zoo, key.0, key.1)?)?;
                    published += 1;
                }
                println!("artifact registry {root}: published {published} zoo bundles");
            }
            Some(peer) => {
                let remote = HttpRegistry::new(peer.as_str());
                for &id in &required {
                    if store.has(id) {
                        continue;
                    }
                    let mut attempts = 0u32;
                    loop {
                        match remote.fetch(id) {
                            Ok(bundle) => {
                                store.store(&bundle)?;
                                telemetry.artifacts_fetched.fetch_add(1, Ordering::Relaxed);
                                break;
                            }
                            Err(e) => {
                                attempts += 1;
                                if attempts >= 40 {
                                    // verification failures and dead
                                    // peers end the same way: the
                                    // artifact stays non-resident and
                                    // the router keeps us quarantined
                                    telemetry
                                        .artifacts_verify_failed
                                        .fetch_add(1, Ordering::Relaxed);
                                    eprintln!("artifact {id} unavailable from {peer}: {e}");
                                    break;
                                }
                                std::thread::sleep(Duration::from_millis(250));
                            }
                        }
                    }
                }
                println!(
                    "artifact registry {root}: fetched {} bundles from {peer}",
                    telemetry.artifacts_fetched.load(Ordering::Relaxed)
                );
            }
        }
        let resident = required.iter().filter(|&&id| store.has(id)).count() as u64;
        telemetry.artifacts_required.store(required.len() as u64, Ordering::Relaxed);
        telemetry.artifacts_resident.store(resident, Ordering::Relaxed);
        telemetry.install_artifact_store(store);
        println!(
            "artifact residency: {resident}/{} required by the active ensemble",
            required.len()
        );
    }

    // the governor control plane: rides the running pipeline, stopped
    // (dropped) only after the data plane has fully drained below
    let governor = if cfg.govern {
        let gcfg = GovernorConfig {
            tick: Duration::from_secs_f64((cfg.control_tick_ms / 1000.0).max(0.001)),
            floor_acc: cfg.floor_acc,
            slo,
            ..GovernorConfig::default()
        };
        Some(Governor::spawn(zoo, &pipeline, gcfg)?)
    } else {
        None
    };

    // sharded aggregation front-end: each shard owns its patients'
    // aggregators and submits completed windows from its own thread;
    // replies are collected by small detached waiter threads so a shard
    // never blocks on inference
    let submitted = Arc::new(AtomicU64::new(0));
    let (pred_tx, pred_rx) = mpsc::channel::<(usize, f64)>();
    let (shard_router, frame_tx) = ShardRouter::spawn(
        ShardConfig { shards: n_shards, ..ShardConfig::default() },
        clip_len,
        Arc::clone(&telemetry),
        |_shard| {
            let pipeline = pipeline.clone();
            let pred_tx = pred_tx.clone();
            let submitted = Arc::clone(&submitted);
            move |window| {
                let q = Query::from_window(window);
                let patient = q.patient;
                if let Ok(rx) = pipeline.submit(q) {
                    submitted.fetch_add(1, Ordering::Relaxed);
                    let pred_tx = pred_tx.clone();
                    std::thread::spawn(move || {
                        if let Ok(p) = rx.recv() {
                            let _ = pred_tx.send((patient, p.score));
                        }
                    });
                }
            }
        },
    )?;
    drop(pred_tx); // live clones: shard sinks + in-flight waiters

    // optional HTTP ingest: generators stream binary wire frames over
    // keep-alive connections instead of the in-process shard sender
    let mut http = None;
    if let Some(addr) = &cfg.http_addr {
        let server = crate::http::serve_with(
            addr,
            frame_tx.clone(),
            Arc::clone(&telemetry),
            crate::http::HttpConfig {
                edge_threads: cfg.edge_threads,
                ..crate::http::HttpConfig::default()
            },
        )?;
        println!("HTTP ingest listening on {} (binary /ingest.bin)", server.addr);
        http = Some(server);
    }

    // patient stream generator threads (in-process clients, open loop)
    let mut labels: HashMap<usize, u8> = HashMap::new();
    let mut sims: Vec<PatientSim> = (0..cfg.patients)
        .map(|pid| PatientSim::new(pid, cfg.seed, synth_cfg.clone()))
        .collect();
    for sim in &sims {
        labels.insert(sim.id, sim.state.label);
    }
    let mut gen_handles = Vec::new();
    let http_addr = http.as_ref().map(|s| s.addr);
    let reconnects = Arc::new(AtomicU64::new(0));
    for mut sim in sims.drain(..) {
        let tx = frame_tx.clone();
        let clock = VirtualClock::new(cfg.speedup);
        let duration = cfg.duration_s;
        let reconnects = Arc::clone(&reconnects);
        gen_handles.push(std::thread::spawn(move || {
            // over-the-wire mode: one keep-alive binary ingest client
            // per bedside monitor, one POST per simulated second
            let mut client = match http_addr {
                Some(addr) => match crate::http::IngestClient::connect(addr) {
                    Ok(c) => Some(c),
                    Err(e) => {
                        eprintln!("patient {}: ingest connect failed: {e}", sim.id);
                        return;
                    }
                },
                None => None,
            };
            let mut batch: Vec<Frame> = Vec::with_capacity(251);
            let mut sim_t = 0.0f64;
            while sim_t < duration {
                if crate::signal::shutdown_requested() {
                    break; // SIGTERM: stop emitting, drain behind us
                }
                // one simulated second per tick: 250 ECG samples + 1 vitals
                clock.sleep_until_sim(sim_t);
                batch.clear();
                batch.extend(sim.ecg_frames(sim_t, 250));
                let v = sim.next_vitals();
                batch.push(Frame {
                    patient: sim.id,
                    modality: Modality::Vitals,
                    sim_time: sim_t,
                    values: v.into(),
                });
                let delivered = match client.as_mut() {
                    Some(c) => c.send_frames(&batch).is_ok(),
                    // frames are Copy: routing to a shard is a stack
                    // copy, never an allocation
                    None => batch.iter().all(|f| tx.send(*f).is_ok()),
                };
                if !delivered {
                    break;
                }
                sim_t += 1.0;
            }
            // count the monitor's redials even when it bailed early
            if let Some(c) = client.as_ref() {
                reconnects.fetch_add(c.reconnects(), Ordering::Relaxed);
            }
        }));
    }

    // chaos: a scripted backend fault just ahead of the one-third mark
    // (so a live window boundary faults the lane and the governor must
    // quarantine it), then a ghost thundering herd — 4× the bed count,
    // each streaming exactly one aggregation window starting at that
    // mark, all emitting their queries at the same instant
    if cfg.chaos {
        let storm_start = (cfg.duration_s / 3.0).floor().max(1.0);
        // one full window (clip_len samples at fs) plus a second of
        // margin, so every ghost completes exactly one query
        let storm_span = clip_len as f64 / zoo.manifest.fs as f64 + 1.0;
        for g in 0..CHAOS_GHOSTS_PER_PATIENT * cfg.patients {
            let mut sim = PatientSim::new(cfg.patients + g, cfg.seed, synth_cfg.clone());
            labels.insert(sim.id, sim.state.label);
            let tx = frame_tx.clone();
            let clock = VirtualClock::new(cfg.speedup);
            gen_handles.push(std::thread::spawn(move || {
                let mut batch: Vec<Frame> = Vec::with_capacity(251);
                let mut sim_t = storm_start;
                while sim_t < storm_start + storm_span {
                    if crate::signal::shutdown_requested() {
                        return;
                    }
                    clock.sleep_until_sim(sim_t);
                    batch.clear();
                    batch.extend(sim.ecg_frames(sim_t, 250));
                    let v = sim.next_vitals();
                    batch.push(Frame {
                        patient: sim.id,
                        modality: Modality::Vitals,
                        sim_time: sim_t,
                        values: v.into(),
                    });
                    if !batch.iter().all(|f| tx.send(*f).is_ok()) {
                        return;
                    }
                    sim_t += 1.0;
                }
            }));
        }
        let flag = Arc::clone(&fault_flag);
        let clock = VirtualClock::new(cfg.speedup);
        let fault_on = (storm_start - 1.5).max(0.0);
        let fault_off = storm_start + storm_span * 0.5;
        gen_handles.push(std::thread::spawn(move || {
            clock.sleep_until_sim(fault_on);
            flag.store(true, Ordering::Relaxed);
            clock.sleep_until_sim(fault_off);
            flag.store(false, Ordering::Relaxed);
        }));
    }
    drop(frame_tx);

    // prediction sink on this thread
    let sink = std::thread::spawn(move || {
        let mut rows: Vec<(usize, f64)> = Vec::new();
        for r in pred_rx {
            rows.push(r);
        }
        rows
    });

    for h in gen_handles {
        let _ = h.join();
    }
    // ingest-only node (`--patients 0`, e.g. a peer behind the router
    // tier): no local generators pace the run, so hold the edge open
    // until the configured duration elapses on the wall — or a shutdown
    // signal starts the drain early
    if cfg.patients == 0 && !cfg.chaos && http.is_some() {
        let wall_end = t_start + Duration::from_secs_f64(cfg.duration_s / cfg.speedup);
        while Instant::now() < wall_end && !crate::signal::shutdown_requested() {
            std::thread::sleep(Duration::from_millis(20));
        }
    }
    if crate::signal::shutdown_requested() {
        // graceful drain: advertise the drain on ingest heartbeats long
        // enough for an upstream router to flush its link and re-home
        // this node's beds, then fall through to the normal teardown
        // (shard join → pipeline drain → report) and exit 0
        telemetry.draining.store(true, Ordering::Relaxed);
        println!("shutdown requested: draining (heartbeats now advertise it)");
        if http.is_some() {
            std::thread::sleep(Duration::from_millis(600));
        }
    }
    // stop the HTTP server BEFORE joining the shard plane: its accept
    // thread holds a ShardSender clone, so the shard workers (and thus
    // the join below) would otherwise never see their channels close
    drop(http);
    let dropped_per_shard = shard_router.join()?;
    // drain the data plane BEFORE stopping the control plane: a chaos
    // storm leaves seconds of backlog behind the generators, and the
    // governor must keep observing (and reacting to) it to the end —
    // also guarantees every admitted query is accounted below
    let drain_deadline = Instant::now() + Duration::from_secs(60);
    while pipeline.pending_len() > 0 && Instant::now() < drain_deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    if governor.is_some() {
        // a few extra control ticks so the loop records the drained
        // end state before it is joined
        std::thread::sleep(Duration::from_secs_f64(
            (cfg.control_tick_ms / 1000.0).max(0.001) * 4.0,
        ));
    }
    drop(governor);
    drop(pipeline);
    let pred_rows = sink.join().map_err(|_| crate::Error::serving("sink panicked"))?;
    let frames = telemetry.frames.load(std::sync::atomic::Ordering::Relaxed);
    let frames_dropped =
        telemetry.frames_dropped.load(std::sync::atomic::Ordering::Relaxed);

    let wall_s = t_start.elapsed().as_secs_f64();
    // accuracy against ground-truth patient labels
    let mut labels_v = Vec::with_capacity(pred_rows.len());
    let mut scores_v = Vec::with_capacity(pred_rows.len());
    for (pid, score) in &pred_rows {
        // remotely ingested patients (a `--patients 0` node behind the
        // router tier) have no local ground truth — skip them in the AUC
        if let Some(&label) = labels.get(pid) {
            labels_v.push(label);
            scores_v.push(*score);
        }
    }
    let auc = roc_auc(&labels_v, &scores_v);
    let batches_per_worker = telemetry
        .executor()
        .map(|g| g.worker_batches())
        .unwrap_or_default();
    let fill_wait_ns_per_model = telemetry
        .executor()
        .map(|g| g.fill_waits_ns())
        .unwrap_or_default();
    // edge counters survive the server drop: the gauges live in the
    // shared telemetry, not in the event loops
    let ordering = std::sync::atomic::Ordering::Relaxed;
    let submitted_n = submitted.load(ordering);
    let resolved = telemetry.queries.load(ordering) + telemetry.failures.load(ordering);
    let exec_retries = telemetry
        .executor()
        .map(|g| g.retries().iter().sum::<u64>())
        .unwrap_or(0);
    let gov = telemetry.governor();
    let ec = telemetry.exec_cache();
    let report = BedsideReport {
        predictions: pred_rows.len(),
        frames,
        frames_dropped,
        dropped_per_shard,
        frames_dropped_malformed: telemetry.frames_dropped_malformed.load(ordering),
        frames_dropped_overcap: telemetry.frames_dropped_overcap.load(ordering),
        frames_stale: telemetry.frames_stale.load(ordering),
        client_reconnects: reconnects.load(ordering),
        batches_per_worker,
        fill_wait_ns_per_model,
        conns_accepted: telemetry.conns_accepted.load(ordering),
        conns_refused: telemetry.conns_refused.load(ordering),
        conns_reaped: telemetry.conns_reaped.load(ordering),
        edge_ready_events: telemetry.edge().map(|g| g.ready_events()).unwrap_or_default(),
        slo_s: slo.as_secs_f64(),
        e2e_p50: telemetry.e2e.percentile(50.0),
        e2e_p95: telemetry.e2e.percentile(95.0),
        e2e_p99: telemetry.e2e.percentile(99.0),
        roc_auc: auc,
        wall_s,
        patients_evicted: telemetry.patients_evicted.load(ordering),
        exec_retries,
        submitted: submitted_n,
        unresolved: submitted_n.saturating_sub(resolved),
        governor_epoch: gov.map(|g| g.epoch.load(ordering)).unwrap_or(0),
        governor_swaps: gov.map(|g| g.swaps.load(ordering)).unwrap_or(0),
        governor_degraded_entered: gov
            .map(|g| g.degraded_entered.load(ordering))
            .unwrap_or(0),
        governor_probes: gov.map(|g| g.probes.load(ordering)).unwrap_or(0),
        governor_reinstated: gov.map(|g| g.reinstated.load(ordering)).unwrap_or(0),
        governor_quarantined: gov.map(|g| g.quarantined.load(ordering) as u64).unwrap_or(0),
        artifacts_required: telemetry.artifacts_required.load(ordering),
        artifacts_resident: telemetry.artifacts_resident.load(ordering),
        artifacts_fetched: telemetry.artifacts_fetched.load(ordering),
        artifacts_served: telemetry.artifacts_served.load(ordering),
        exec_cache_hits: ec.map(|g| g.hits.load(ordering)).unwrap_or(0),
        exec_cache_misses: ec.map(|g| g.misses.load(ordering)).unwrap_or(0),
        exec_cache_compiles: ec.map(|g| g.compiles.load(ordering)).unwrap_or(0),
    };
    print_report(&report, &telemetry);
    Ok(report)
}

fn print_report(r: &BedsideReport, telemetry: &Telemetry) {
    println!("\n── bedside report ──────────────────────────");
    println!("frames ingested      {:>12}", r.frames);
    println!("frames dropped       {:>12}  (per shard: {:?})", r.frames_dropped, r.dropped_per_shard);
    println!(
        "  by cause           {:>12}  malformed, {} over-cap, {} stale",
        r.frames_dropped_malformed, r.frames_dropped_overcap, r.frames_stale
    );
    println!("patients evicted     {:>12}  (idle aggregators past the shard cap)", r.patients_evicted);
    println!("ensemble predictions {:>12}", r.predictions);
    println!(
        "queries admitted     {:>12}  (unresolved at exit: {})",
        r.submitted, r.unresolved
    );
    println!(
        "executor batches     {:>12}  (per worker: {:?})",
        r.batches_per_worker.iter().sum::<u64>(),
        r.batches_per_worker
    );
    if let Some(g) = telemetry.executor() {
        println!("model queue depths   {:>12?}  (end of run)", g.queue_depths());
        println!(
            "dead lanes           {:>12?}  (end of run; retries absorbed: {})",
            g.dead_lanes(),
            r.exec_retries
        );
    }
    if let Some(g) = telemetry.router() {
        let ordering = Ordering::Relaxed;
        println!(
            "router peers         {:>12?}  state (0 healthy / 1 suspect / 2 dead / 3 draining)",
            g.peer_states()
        );
        println!("  frames forwarded   {:>12?}  (per peer)", g.frames_forwarded());
        println!("  forward retries    {:>12?}  (per peer)", g.forward_retries());
        println!("  spill depth        {:>12?}  (per peer, end of run)", g.spill_depths());
        println!(
            "  patients re-homed  {:>12}  (spilled {}, replayed {}, overflow {}, reinstated {})",
            g.patients_rehomed.load(ordering),
            g.spilled_total.load(ordering),
            g.spill_replayed.load(ordering),
            g.spill_overflow.load(ordering),
            g.peers_reinstated.load(ordering)
        );
    }
    if r.artifacts_required > 0 || r.artifacts_fetched > 0 || r.artifacts_served > 0 {
        println!(
            "artifacts resident   {:>12}  of {} required (fetched {}, served {})",
            r.artifacts_resident, r.artifacts_required, r.artifacts_fetched, r.artifacts_served
        );
    }
    if r.exec_cache_compiles > 0 || r.exec_cache_hits > 0 {
        println!(
            "exec cache           {:>12}  hits  ({} misses, {} compiles shared by all workers)",
            r.exec_cache_hits, r.exec_cache_misses, r.exec_cache_compiles
        );
    }
    if telemetry.governor().is_some() {
        println!(
            "governor             {:>12}  swaps (epoch {}, degraded {}×, probes {}, reinstated {}, quarantined {})",
            r.governor_swaps,
            r.governor_epoch,
            r.governor_degraded_entered,
            r.governor_probes,
            r.governor_reinstated,
            r.governor_quarantined
        );
    }
    let waits_ms: Vec<f64> = r
        .fill_wait_ns_per_model
        .iter()
        .map(|&ns| (ns as f64 / 1e6 * 1000.0).round() / 1000.0)
        .collect();
    println!("fill deadlines (ms)  {:>12?}  (per model, last armed)", waits_ms);
    if r.conns_accepted > 0 || !r.edge_ready_events.is_empty() {
        println!(
            "edge connections     {:>12}  (refused: {}, reaped: {})",
            r.conns_accepted, r.conns_refused, r.conns_reaped
        );
        println!(
            "client reconnects    {:>12}  (monitor links redialed with backoff)",
            r.client_reconnects
        );
        if !r.edge_ready_events.is_empty() {
            println!("edge ready events    {:>12?}  (per event loop)", r.edge_ready_events);
        }
    }
    println!("e2e latency p50      {:>11.4}s", r.e2e_p50);
    println!(
        "e2e latency p95      {:>11.4}s  ({} the {:.1}s SLO)",
        r.e2e_p95,
        if r.e2e_p95 <= r.slo_s { "within" } else { "ABOVE" },
        r.slo_s
    );
    println!("e2e latency p99      {:>11.4}s", r.e2e_p99);
    println!("queueing p95         {:>11.4}s", telemetry.queueing.percentile(95.0));
    println!("exec mean            {:>11.4}s", telemetry.exec.mean());
    println!("ingest push p95      {:>11.6}s", telemetry.ingest.percentile(95.0));
    println!("prediction ROC-AUC   {:>11.4}", r.roc_auc);
    println!("wall time            {:>11.1}s", r.wall_s);
}
