//! Experiment harnesses — one per paper table/figure (DESIGN.md §5).
//!
//! Every harness regenerates the corresponding table rows / figure
//! series from scratch (workload generation → search / serving →
//! metrics) and writes `results/<id>.csv` plus a human-readable summary
//! to stdout. EXPERIMENTS.md records paper-vs-measured shape.

pub mod bedside;
pub mod common;
pub mod fig10_scalability;
pub mod fig13_window;
pub mod fig2_staleness;
pub mod fig9_timeline;
pub mod replay;
pub mod route;
pub mod search_suite;

use std::path::Path;

use crate::Result;

/// Write a CSV file (header + rows) under the results directory.
pub fn write_csv(
    out_dir: impl AsRef<Path>,
    name: &str,
    header: &str,
    rows: &[String],
) -> Result<std::path::PathBuf> {
    let dir = out_dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    let mut text = String::with_capacity(rows.len() * 64 + header.len() + 1);
    text.push_str(header);
    text.push('\n');
    for r in rows {
        text.push_str(r);
        text.push('\n');
    }
    std::fs::write(&path, text)?;
    Ok(path)
}

/// Run every experiment (the `exp all` CLI subcommand / `make results`).
pub fn run_all(artifacts: &Path, out: &Path, quick: bool) -> Result<()> {
    let zoo = crate::zoo::Zoo::load(artifacts)?;
    search_suite::run(&zoo, out, quick)?;
    fig2_staleness::run(&zoo, out, quick)?;
    fig9_timeline::run(&zoo, out, quick)?;
    fig10_scalability::run(&zoo, out, quick)?;
    fig13_window::run(&zoo, out, quick)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_csv_creates_file() {
        let dir = std::env::temp_dir().join("holmes_csv_test");
        let p = write_csv(&dir, "t.csv", "a,b", &["1,2".into(), "3,4".into()]).unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text, "a,b\n1,2\n3,4\n");
    }
}
