//! `holmes route` — the fault-tolerant router tier as a process.
//!
//! Owns the ingest edge, forwards decoded frames to N downstream
//! `holmes serve` peers through the consistent-hash [`Router`]
//! (64 vnodes/peer, sticky owners), and runs the heartbeat [`Prober`]
//! that drives death → re-home → spill-replay and drain → re-home
//! transitions. Two modes:
//!
//! * **plain** (`--peers a:p,b:p,...`): long-running router in front of
//!   externally managed peers. Serves `/ingest.bin` + `/stats` (the
//!   snapshot carries the per-peer `router_*` gauges), prints a
//!   per-peer line every 5 s, and drains cleanly on SIGTERM.
//! * **smoke** (`--spawn-peers N --patients B --kill-at T`): the CI
//!   chaos gate. Spawns N child `holmes serve --patients 0` processes
//!   (ingest-only peers on adjacent ports), streams a synthetic
//!   B-bed cohort through the ring, SIGKILLs the peer that owns bed 0
//!   mid-run, and exits nonzero unless the dead peer's beds re-home to
//!   survivors inside the recovery SLO, every spilled frame is
//!   replayed, frame conservation holds against each survivor's own
//!   telemetry, and every survivor's graceful drain (SIGTERM) resolves
//!   all admitted queries and exits 0.

use std::net::SocketAddr;
use std::process::{Child, Command};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::http::FrameSink;
use crate::ingest::synth::{PatientSim, SynthConfig};
use crate::ingest::{Frame, Modality, VirtualClock};
use crate::router::health::probe_once;
use crate::router::{HealthConfig, ProbeOutcome, Ring, Router, RouterConfig};
use crate::serving::Telemetry;
use crate::{Error, Result};

#[derive(Debug, Clone)]
pub struct RouteConfig {
    /// Ingest-edge listen address (`--http`).
    pub listen: String,
    /// Downstream peer ingest addresses (`--peers a,b,...`); empty in
    /// smoke mode, where peers are spawned as children instead.
    pub peers: Vec<String>,
    /// Event-loop threads for the epoll edge (`--edge-threads`).
    pub edge_threads: usize,
    /// Smoke mode: spawn this many child `serve --patients 0` peers on
    /// ports adjacent to the listen port (0 = plain mode).
    pub spawn_peers: usize,
    /// Smoke cohort size (beds streamed through the ring in-process).
    pub patients: usize,
    /// Smoke cohort length in simulated seconds; in plain mode, an
    /// optional wall-clock lifetime (0 = run until SIGTERM).
    pub duration_s: f64,
    pub speedup: f64,
    pub seed: u64,
    /// Smoke: SIGKILL the peer owning bed 0 at this simulated second
    /// (0 = healthy run, no kill).
    pub kill_at: f64,
    /// Smoke: crash → beds-re-homed recovery budget, milliseconds.
    pub slo_ms: f64,
    /// Smoke: cold-peer artifact admission variant (`--cold-peer`).
    /// The bed-0 owner becomes the *warm* peer (publishes its zoo
    /// bundles into a registry store); every other peer boots cold —
    /// an empty store plus `--registry <warm>` — and must fetch the
    /// active ensemble's artifacts, report `"resident":true`, and be
    /// admitted by the prober before the cohort streams. The warm peer
    /// is then killed, so the re-homed beds land on peers that proved
    /// artifact residency first.
    pub cold_peer: bool,
}

impl Default for RouteConfig {
    fn default() -> Self {
        RouteConfig {
            listen: "127.0.0.1:7171".into(),
            peers: Vec::new(),
            edge_threads: 0,
            spawn_peers: 0,
            patients: 8,
            duration_s: 12.0,
            speedup: 4.0,
            seed: 7,
            kill_at: 0.0,
            slo_ms: 3000.0,
            cold_peer: false,
        }
    }
}

/// Health tuning for the smoke: tight enough that crash detection is a
/// small fraction of the recovery SLO, loose enough not to flap on a
/// loaded CI runner.
fn smoke_health() -> HealthConfig {
    HealthConfig {
        probe_interval: Duration::from_millis(25),
        dead_after: 3,
        backoff_init: 2,
        backoff_max: 16,
        connect_timeout: Duration::from_millis(200),
        io_timeout: Duration::from_millis(500),
    }
}

pub fn run_route(cfg: RouteConfig) -> Result<()> {
    crate::signal::install_shutdown_handler();
    let smoke = cfg.spawn_peers > 0;
    let peer_addrs: Vec<SocketAddr> = if smoke {
        if !cfg.peers.is_empty() {
            return Err(Error::config("--spawn-peers and --peers are mutually exclusive"));
        }
        if cfg.spawn_peers < 2 {
            return Err(Error::config("--spawn-peers needs at least 2 peers"));
        }
        if cfg.patients == 0 {
            return Err(Error::config("the route smoke needs --patients > 0"));
        }
        if cfg.kill_at > 0.0 && cfg.kill_at >= cfg.duration_s {
            return Err(Error::config("--kill-at must land inside --duration"));
        }
        // child peers listen on the ports right after the router's
        let listen: SocketAddr = cfg.listen.parse().map_err(|_| {
            Error::config("--spawn-peers needs a concrete --http ip:port to derive peer ports")
        })?;
        if listen.port() == 0 {
            return Err(Error::config("--spawn-peers cannot derive peer ports from port 0"));
        }
        (0..cfg.spawn_peers)
            .map(|i| SocketAddr::new(listen.ip(), listen.port() + 1 + i as u16))
            .collect()
    } else {
        if cfg.peers.is_empty() {
            return Err(Error::config("route needs --peers a:port,b:port,... or --spawn-peers N"));
        }
        cfg.peers
            .iter()
            .map(|s| {
                s.parse::<SocketAddr>()
                    .map_err(|_| Error::config(format!("bad peer address {s:?} (want ip:port)")))
            })
            .collect::<Result<_>>()?
    };

    // cold-peer variant: the bed-0 owner (the smoke's later victim) is
    // the warm peer; its registry store seeds every other, cold, peer.
    // Ring::new is deterministic in the peer count, so this matches the
    // `victim` the smoke computes below.
    let warm_idx = Ring::new(peer_addrs.len()).route(0);
    let registry_scratch = if smoke && cfg.cold_peer {
        let dir = std::env::temp_dir()
            .join(format!("holmes-route-registry-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Some(dir)
    } else {
        None
    };

    let mut children: Vec<Child> = Vec::new();
    if smoke {
        let exe = std::env::current_exe()?;
        // children outlive the cohort; the smoke retires them itself
        let child_duration = cfg.duration_s + 10.0 * cfg.speedup;
        for (i, addr) in peer_addrs.iter().enumerate() {
            let mut args = vec![
                "serve".to_string(),
                "--http".to_string(),
                addr.to_string(),
                "--patients".to_string(),
                "0".to_string(),
                "--duration".to_string(),
                format!("{child_duration}"),
                "--speedup".to_string(),
                format!("{}", cfg.speedup),
                "--workers".to_string(),
                "2".to_string(),
            ];
            if let Some(root) = &registry_scratch {
                args.push("--registry-root".to_string());
                args.push(root.join(format!("peer-{i}")).display().to_string());
                if i != warm_idx {
                    // cold peer: empty store, must pull from the warm one
                    args.push("--registry".to_string());
                    args.push(peer_addrs[warm_idx].to_string());
                }
            }
            children.push(Command::new(&exe).args(&args).spawn()?);
        }
        // wait until every child's ingest edge answers a heartbeat;
        // NotReady (up, still fetching artifacts) keeps waiting — the
        // edge only answers Ok once the peer's store is resident
        let deadline = Instant::now() + Duration::from_secs(60);
        for (i, &addr) in peer_addrs.iter().enumerate() {
            loop {
                match probe_once(addr, 0, Duration::from_millis(200), Duration::from_millis(500))
                {
                    ProbeOutcome::Ok | ProbeOutcome::Draining => break,
                    ProbeOutcome::Fail | ProbeOutcome::NotReady
                        if Instant::now() < deadline =>
                    {
                        std::thread::sleep(Duration::from_millis(50));
                    }
                    outcome => {
                        reap(&mut children);
                        return Err(Error::serving(format!(
                            "peer {i} ({addr}) never became servable (last probe: {outcome:?})"
                        )));
                    }
                }
            }
        }
        println!("route smoke: {} child peers up: {:?}", children.len(), peer_addrs);
    }

    let health = if smoke { smoke_health() } else { HealthConfig::default() };
    let mut rcfg = RouterConfig::new(peer_addrs.clone());
    rcfg.health = health;
    let router = Router::new(&rcfg)?;
    let telemetry = Arc::new(Telemetry::default());
    telemetry.install_router(Arc::clone(router.gauges()));
    let server = crate::http::serve_with(
        &cfg.listen,
        router.sink(),
        Arc::clone(&telemetry),
        crate::http::HttpConfig {
            edge_threads: cfg.edge_threads,
            ..crate::http::HttpConfig::default()
        },
    )?;
    println!(
        "router ingest edge on {} → {} peers {:?}",
        server.addr,
        peer_addrs.len(),
        peer_addrs
    );
    let prober = router.spawn_prober(health);

    if !smoke {
        // plain mode: hold the edge open until SIGTERM (or an optional
        // wall-clock lifetime), printing a per-peer line every 5 s
        let t0 = Instant::now();
        let mut last_print = Instant::now();
        while !crate::signal::shutdown_requested() {
            if cfg.duration_s > 0.0 && t0.elapsed().as_secs_f64() >= cfg.duration_s {
                break;
            }
            if last_print.elapsed() >= Duration::from_secs(5) {
                last_print = Instant::now();
                let g = router.gauges();
                println!(
                    "router: states {:?} forwarded {:?} retries {:?} spill {:?} re-homed {} reinstated {}",
                    g.peer_states(),
                    g.frames_forwarded(),
                    g.forward_retries(),
                    g.spill_depths(),
                    g.patients_rehomed.load(Ordering::Relaxed),
                    g.peers_reinstated.load(Ordering::Relaxed),
                );
            }
            std::thread::sleep(Duration::from_millis(100));
        }
        println!("route: shutting down — flushing forwarding links");
        drop(server);
        drop(prober);
        router.shutdown();
        return Ok(());
    }

    // ── smoke: drive the cohort, crash the bed-0 owner, gate recovery ──
    let ring = Ring::new(peer_addrs.len());
    let victim = ring.route(0);
    let expected_rehomed =
        (0..cfg.patients).filter(|&p| ring.route(p) == victim).count() as u64;
    let kill_tick =
        if cfg.kill_at > 0.0 { cfg.kill_at.floor() as u64 } else { u64::MAX };
    let duration = cfg.duration_s.max(1.0) as u64;
    let mut failures: Vec<String> = Vec::new();
    let mut recovery_ms: Option<f64> = None;
    println!(
        "route smoke: {} beds over {} peers, {} sim s (speedup {}×), victim peer {} at t={}",
        cfg.patients, peer_addrs.len(), duration, cfg.speedup, victim, cfg.kill_at
    );

    // ── cold-peer admission gates: fetch → resident → admitted ──
    if cfg.cold_peer {
        debug_assert_eq!(victim, warm_idx);
        for (i, &addr) in peer_addrs.iter().enumerate() {
            if i == warm_idx {
                continue;
            }
            match peer_stats(addr) {
                Ok(stats) => {
                    let n = |k: &str| stats.get(k).and_then(|v| v.as_u64()).unwrap_or(0);
                    if n("artifacts_required") == 0 {
                        failures.push(format!("cold peer {i} reports no artifact demand"));
                    }
                    if n("artifacts_fetched") == 0 {
                        failures
                            .push(format!("cold peer {i} fetched nothing from the warm peer"));
                    }
                    if n("artifacts_resident") < n("artifacts_required") {
                        failures.push(format!(
                            "cold peer {i} not resident: {}/{} artifacts",
                            n("artifacts_resident"),
                            n("artifacts_required")
                        ));
                    }
                }
                Err(e) => failures.push(format!("cold peer {i} /stats unreachable: {e}")),
            }
        }
        // the prober must classify every peer healthy (not NotReady →
        // draining) before any bed is routed at it
        let g = router.gauges();
        let admit_deadline = Instant::now() + Duration::from_secs(10);
        while g.peer_states().iter().any(|&s| s != 0) && Instant::now() < admit_deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        let states = g.peer_states();
        if states.iter().any(|&s| s != 0) {
            failures
                .push(format!("peers not all admitted before the cohort: states {states:?}"));
        }
        if !failures.is_empty() {
            reap(&mut children);
            if let Some(dir) = &registry_scratch {
                let _ = std::fs::remove_dir_all(dir);
            }
            for f in &failures {
                eprintln!("ROUTE SMOKE FAIL: {f}");
            }
            return Err(Error::serving(format!("{} route smoke violations", failures.len())));
        }
        println!(
            "route smoke: cold peers fetched from warm peer {warm_idx}, resident, admitted"
        );
    }

    let sink = router.sink();
    let synth = SynthConfig::default();
    let mut sims: Vec<PatientSim> =
        (0..cfg.patients).map(|pid| PatientSim::new(pid, cfg.seed, synth.clone())).collect();
    let clock = VirtualClock::new(cfg.speedup);
    'cohort: for t in 0..duration {
        if crate::signal::shutdown_requested() {
            break;
        }
        clock.sleep_until_sim(t as f64);
        for sim in sims.iter_mut() {
            // one simulated second per bed: 250 ECG samples + 1 vitals
            for f in sim.ecg_frames(t as f64, 250) {
                if let Err(e) = sink.deliver(f) {
                    failures.push(format!("frame delivery failed at t={t}: {e}"));
                    break 'cohort;
                }
            }
            let v = sim.next_vitals();
            let f = Frame {
                patient: sim.id,
                modality: Modality::Vitals,
                sim_time: t as f64,
                values: v.into(),
            };
            if let Err(e) = sink.deliver(f) {
                failures.push(format!("frame delivery failed at t={t}: {e}"));
                break 'cohort;
            }
        }
        if t == kill_tick {
            // SIGKILL, not SIGTERM: a genuine crash the heartbeat
            // prober must detect organically
            let t_kill = Instant::now();
            let _ = children[victim].kill();
            let _ = children[victim].wait();
            println!("route smoke: crashed peer {victim} ({})", peer_addrs[victim]);
            let g = router.gauges();
            let deadline = Instant::now() + Duration::from_secs(30);
            while g.patients_rehomed.load(Ordering::Relaxed) == 0 && Instant::now() < deadline
            {
                std::thread::sleep(Duration::from_millis(2));
            }
            let ms = t_kill.elapsed().as_secs_f64() * 1e3;
            recovery_ms = Some(ms);
            println!(
                "route smoke: {} beds re-homed {:.0} ms after the crash",
                g.patients_rehomed.load(Ordering::Relaxed),
                ms
            );
        }
    }

    // freeze the tier: stop the edge and the prober, then flush and
    // close every link so the gauges are final
    drop(server);
    drop(prober);
    router.shutdown();
    let g = router.gauges();
    let rehomed = g.patients_rehomed.load(Ordering::Relaxed);
    let spilled = g.spilled_total.load(Ordering::Relaxed);
    let replayed = g.spill_replayed.load(Ordering::Relaxed);
    let overflow = g.spill_overflow.load(Ordering::Relaxed);
    let dropped = g.replay_dropped.load(Ordering::Relaxed);
    let forwarded = g.frames_forwarded();
    println!(
        "route smoke: forwarded {:?}, re-homed {rehomed}, spilled {spilled} / replayed {replayed} / overflow {overflow} / replay-dropped {dropped}",
        forwarded
    );

    if kill_tick != u64::MAX {
        if rehomed != expected_rehomed {
            failures.push(format!(
                "re-homed {rehomed} beds — the ring mirror says the victim owned {expected_rehomed}"
            ));
        }
        match recovery_ms {
            Some(ms) if ms <= cfg.slo_ms => {}
            Some(ms) => failures.push(format!(
                "recovery took {ms:.0} ms — over the {:.0} ms SLO",
                cfg.slo_ms
            )),
            None => failures.push("the kill tick never ran — cohort ended early".into()),
        }
        // replay covers the spill plus any queue remnants the crash
        // stranded, so replayed >= spilled; anything less lost frames
        if replayed < spilled {
            failures.push(format!("{spilled} frames spilled but only {replayed} replayed"));
        }
        if overflow > 0 {
            failures.push(format!("{overflow} frames lost to spill overflow"));
        }
        if dropped > 0 {
            failures.push(format!(
                "{dropped} stranded frames dropped by the failover replay deadline"
            ));
        }
        let states = g.peer_states();
        if states[victim] != 2 {
            failures.push(format!(
                "victim peer state {} at exit — expected dead (2)",
                states[victim]
            ));
        }
        // conservation over the wire: every frame the router counted as
        // forwarded to a survivor must be visible in that peer's own
        // telemetry, and the peer must have resolved queries from them
        for (i, &addr) in peer_addrs.iter().enumerate() {
            if i == victim {
                continue;
            }
            match peer_stats(addr) {
                Ok(stats) => {
                    let frames = stats.get("frames").and_then(|v| v.as_u64()).unwrap_or(0);
                    let queries = stats.get("queries").and_then(|v| v.as_u64()).unwrap_or(0);
                    if frames != forwarded[i] {
                        failures.push(format!(
                            "peer {i}: router forwarded {} frames but the peer ingested {frames}",
                            forwarded[i]
                        ));
                    }
                    if queries == 0 {
                        failures.push(format!("peer {i} resolved no queries"));
                    }
                }
                Err(e) => failures.push(format!("peer {i} /stats unreachable at exit: {e}")),
            }
        }
    }

    // retire the survivors with SIGTERM: their graceful drain must
    // resolve every admitted query and exit 0 (serve returns nonzero on
    // unresolved queries)
    for (i, child) in children.iter_mut().enumerate() {
        if kill_tick != u64::MAX && i == victim {
            continue; // already reaped at the kill tick
        }
        crate::signal::send_sigterm(child.id());
    }
    let drain_deadline = Instant::now() + Duration::from_secs(60);
    for (i, child) in children.iter_mut().enumerate() {
        if kill_tick != u64::MAX && i == victim {
            continue;
        }
        loop {
            match child.try_wait() {
                Ok(Some(status)) if status.success() => break,
                Ok(Some(status)) => {
                    failures.push(format!(
                        "peer {i} exited {status} from its graceful drain — admitted queries went unresolved"
                    ));
                    break;
                }
                Ok(None) if Instant::now() < drain_deadline => {
                    std::thread::sleep(Duration::from_millis(25));
                }
                Ok(None) => {
                    let _ = child.kill();
                    let _ = child.wait();
                    failures.push(format!("peer {i} failed to drain within 60 s of SIGTERM"));
                    break;
                }
                Err(e) => {
                    failures.push(format!("waiting on peer {i}: {e}"));
                    break;
                }
            }
        }
    }

    if let Some(dir) = &registry_scratch {
        let _ = std::fs::remove_dir_all(dir);
    }
    if failures.is_empty() {
        println!("ROUTE SMOKE PASS");
        Ok(())
    } else {
        for f in &failures {
            eprintln!("ROUTE SMOKE FAIL: {f}");
        }
        Err(Error::serving(format!("{} route smoke violations", failures.len())))
    }
}

/// Fetch and parse a peer's `/stats` snapshot.
fn peer_stats(addr: SocketAddr) -> Result<crate::json::Value> {
    use std::io::{Read, Write};
    let mut stream = std::net::TcpStream::connect_timeout(&addr, Duration::from_millis(500))?;
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    write!(stream, "GET /stats HTTP/1.0\r\nHost: holmes\r\n\r\n")?;
    let mut buf = String::new();
    stream.read_to_string(&mut buf)?;
    let Some((head, body)) = buf.split_once("\r\n\r\n") else {
        return Err(Error::serving("/stats: malformed response"));
    };
    let status = head.lines().next().unwrap_or("");
    if !status.contains(" 200 ") {
        return Err(Error::serving(format!("/stats: {status}")));
    }
    crate::json::Value::parse(body)
}

/// Kill and reap every child — the bail-out path.
fn reap(children: &mut [Child]) {
    for c in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
}
