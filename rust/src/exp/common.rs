//! Shared experiment plumbing: the five search methods of §4.2 under one
//! enum, default profiler construction, and method-run helpers.

use crate::composer::baselines::{greedy_search, npo_search, Greedy};
use crate::composer::{Composer, SearchResult};
use crate::config::{ComposerConfig, SystemConfig};
use crate::profiler::{AnalyticLatencyProfiler, ServiceTimes, ValidationAccuracyProfiler};
use crate::zoo::Zoo;

/// The methods compared throughout §4.2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Rd,
    Af,
    Lf,
    Npo,
    Holmes,
}

impl Method {
    pub const ALL: [Method; 5] = [Method::Rd, Method::Af, Method::Lf, Method::Npo, Method::Holmes];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Rd => "RD",
            Method::Af => "AF",
            Method::Lf => "LF",
            Method::Npo => "NPO",
            Method::Holmes => "HOLMES",
        }
    }
}

/// Default MACs-based service-time model (V100-class coefficients:
/// 0.5 ms dispatch overhead, 2×10¹⁰ MAC/s sustained), so the zoo spans
/// ~0.5–13 ms per model — the regime where a 200 ms budget holds a
/// ~10-model ensemble (the paper's operating point). Serving experiments
/// replace this with `ServiceTimes::calibrate` measurements.
pub fn default_service_times(zoo: &Zoo) -> ServiceTimes {
    ServiceTimes::from_macs(zoo, 5e-4, 2e10)
}

/// System configuration the search suite profiles under. The paper's
/// Table-2/Fig-6 searches operate at a lighter load than the Fig-10
/// stress sweep (a 10-model ensemble fits 200 ms during search but
/// shows 1.15 s p95 at the full 64-bed burst load); we profile the
/// composer at 16 concurrent patients and stress serving at 64–100.
pub fn search_system() -> SystemConfig {
    SystemConfig { gpus: 2, patients: 32, window_s: 30.0 }
}

/// One full search-experiment context.
pub struct SearchContext<'a> {
    pub zoo: &'a Zoo,
    pub acc: ValidationAccuracyProfiler,
    pub lat: AnalyticLatencyProfiler,
    pub system: SystemConfig,
}

impl<'a> SearchContext<'a> {
    pub fn new(zoo: &'a Zoo, system: SystemConfig) -> Self {
        SearchContext {
            zoo,
            acc: ValidationAccuracyProfiler::from_zoo(zoo),
            lat: AnalyticLatencyProfiler::new(default_service_times(zoo)),
            system,
        }
    }

    pub fn with_latency(mut self, lat: AnalyticLatencyProfiler) -> Self {
        self.lat = lat;
        self
    }

    /// Run one method with one seed under a latency budget.
    pub fn run(
        &self,
        method: Method,
        budget: f64,
        seed: u64,
        composer_cfg: &ComposerConfig,
    ) -> SearchResult {
        let servable_only = composer_cfg.servable_only;
        match method {
            Method::Rd => greedy_search(
                Greedy::Random,
                self.zoo,
                &self.acc,
                &self.lat,
                &self.system,
                budget,
                servable_only,
                seed,
            ),
            Method::Af => greedy_search(
                Greedy::AccuracyFirst,
                self.zoo,
                &self.acc,
                &self.lat,
                &self.system,
                budget,
                servable_only,
                seed,
            ),
            Method::Lf => greedy_search(
                Greedy::LatencyFirst,
                self.zoo,
                &self.acc,
                &self.lat,
                &self.system,
                budget,
                servable_only,
                seed,
            ),
            Method::Npo => {
                let seeds = self.greedy_seeds(budget, seed, servable_only);
                let budget_calls =
                    composer_cfg.warm_start + composer_cfg.iterations * composer_cfg.top_k;
                npo_search(
                    self.zoo,
                    &self.acc,
                    &self.lat,
                    &self.system,
                    budget,
                    budget_calls,
                    &seeds,
                    servable_only,
                    seed,
                )
            }
            Method::Holmes => {
                let seeds = self.greedy_seeds(budget, seed, servable_only);
                let mut cfg = composer_cfg.clone();
                cfg.latency_budget = budget;
                cfg.seed = seed;
                let composer =
                    Composer::new(self.zoo, &self.acc, &self.lat, cfg, self.system);
                composer.search(&seeds)
            }
        }
    }

    /// The paper seeds NPO and HOLMES with the RD/AF/LF solutions.
    fn greedy_seeds(&self, budget: f64, seed: u64, servable_only: bool) -> Vec<crate::zoo::Selector> {
        [Greedy::Random, Greedy::AccuracyFirst, Greedy::LatencyFirst]
            .into_iter()
            .map(|g| {
                greedy_search(
                    g,
                    self.zoo,
                    &self.acc,
                    &self.lat,
                    &self.system,
                    budget,
                    servable_only,
                    seed,
                )
                .best
                .selector
            })
            .collect()
    }
}
