//! Fig. 13: effect of the observation-window length on latency.
//!
//! `make artifacts` additionally lowers one trained model at a sweep of
//! input lengths (`artifacts/window_sweep/`). For each length we report
//! the paper's four legends:
//! * **Timeit** — raw model execution (plain PJRT execute loop, the
//!   paper's "Time in PyTorch"),
//! * **TS** — serving delay inside the system (Timeit + measured
//!   pipeline dispatch/batch overhead),
//! * **TQ** — worst-case queueing bound from network calculus at the
//!   64-bed load, and
//! * **TQ+TS** — the end-to-end estimate.

use std::path::Path;
use std::time::Instant;

use crate::netcalc::tq_periodic_sources;
use crate::runtime::{bench_hlo_file, Engine};
use crate::serving::pipeline::{Pipeline, PipelineConfig, Query};
use crate::zoo::{Selector, Zoo};
use crate::Result;

use super::fig2_staleness::best_trained_per_lead;
use super::write_csv;

pub fn run(zoo: &Zoo, out: &Path, quick: bool) -> Result<()> {
    let Some(sweep) = &zoo.manifest.window_sweep else {
        println!("fig13: no window_sweep artifacts — rebuild with `make artifacts`");
        return Ok(());
    };
    println!("\n== Fig 13: latency vs observation window ({}) ==", sweep.model_id);
    let reps = if quick { 5 } else { 15 };
    let gpus = 2usize;
    let patients = 64usize;
    let window_s = 30.0;

    // measured pipeline overhead at the native clip length
    let overhead = pipeline_overhead(zoo, if quick { 8 } else { 20 })?;
    println!("  measured pipeline overhead: {:.4} ms", overhead * 1e3);

    let mut lengths: Vec<usize> =
        sweep.artifacts.keys().filter_map(|k| k.parse().ok()).collect();
    lengths.sort_unstable();
    let mut rows = Vec::new();
    let mut modelled = false;
    for len in lengths {
        let rel = &sweep.artifacts[&len.to_string()];
        let path = zoo.root.join(rel);
        let bench = bench_hlo_file(&path, len, reps)?;
        modelled = bench.modelled;
        let timeit = bench.median().as_secs_f64();
        let ts = timeit + overhead;
        let mu = gpus as f64 / ts;
        let tq = tq_periodic_sources(patients, window_s, mu, ts);
        let secs = len as f64 / zoo.manifest.fs as f64;
        println!(
            "  window {secs:>6.1}s ({len:>5} samples): timeit {:.2}ms  ts {:.2}ms  tq {:.2}ms  ts+tq {:.2}ms",
            timeit * 1e3,
            ts * 1e3,
            tq * 1e3,
            (ts + tq) * 1e3
        );
        rows.push(format!(
            "{len},{secs:.2},{timeit:.6},{ts:.6},{tq:.6},{:.6},{modelled}",
            ts + tq
        ));
    }
    if modelled {
        println!("  note: timeit column is MODELLED (sim cost model) — rebuild with --features xla for measured times");
    }
    write_csv(
        out,
        "fig13.csv",
        "window_samples,window_s,timeit_s,ts_s,tq_s,ts_plus_tq_s,modelled",
        &rows,
    )?;
    Ok(())
}

/// Dispatch/batch overhead of the serving pipeline: mean(e2e) − mean(exec)
/// for sequential single-model queries at the native clip length.
fn pipeline_overhead(zoo: &Zoo, probes: usize) -> Result<f64> {
    let best = best_trained_per_lead(zoo)[0];
    let engine = Engine::new(zoo, 1)?;
    engine.profile_model((best, 1), 1)?;
    let clip_len = zoo.manifest.clip_len;
    let pipeline = Pipeline::spawn(
        zoo,
        &engine,
        PipelineConfig::new(Selector::from_indices(zoo.n(), [best])),
    )?;
    let leads = crate::serving::share_leads([
        vec![0.1; clip_len],
        vec![0.1; clip_len],
        vec![0.1; clip_len],
    ]);
    let mut diffs = Vec::with_capacity(probes);
    for w in 0..probes {
        let q = Query {
            patient: 0,
            window_id: w as u64,
            sim_end: 0.0,
            leads: leads.clone(),
            emitted: Instant::now(),
        };
        let p = pipeline.query(q)?;
        diffs.push(p.e2e.as_secs_f64());
    }
    let exec_mean = pipeline.telemetry().exec.mean();
    let e2e_mean = diffs.iter().sum::<f64>() / diffs.len().max(1) as f64;
    Ok((e2e_mean - exec_mean).max(1e-5))
}
