//! Composer-search experiment suite: Table 2 and Figures 1, 6, 7, 8, 11,
//! 12 all come from the same family of runs (five methods × seeds ×
//! latency budgets), so one harness generates them coherently.

use std::path::Path;

use crate::composer::{Delta, SearchResult};
use crate::config::ComposerConfig;
use crate::metrics::mean_std;
use crate::zoo::Zoo;
use crate::Result;

use super::common::{Method, SearchContext};
use super::write_csv;

pub fn run(zoo: &Zoo, out: &Path, quick: bool) -> Result<()> {
    let system = super::common::search_system();
    let ctx = SearchContext::new(zoo, system);
    let cfg = if quick {
        ComposerConfig { iterations: 8, warm_start: 12, explore_samples: 32, ..Default::default() }
    } else {
        ComposerConfig::default()
    };
    let budget = 0.2; // the paper's 200 ms constraint
    let seeds: Vec<u64> = if quick { (0..3).collect() } else { (0..10).collect() };

    // ---- all methods × seeds at the 200 ms budget
    println!("== search suite: {} methods × {} seeds @ {budget}s ==", 5, seeds.len());
    let mut runs: Vec<(Method, u64, SearchResult)> = Vec::new();
    for &m in &Method::ALL {
        for &s in &seeds {
            runs.push((m, s, ctx.run(m, budget, s, &cfg)));
        }
    }

    table2(&ctx, &runs, &seeds, out)?;
    fig1(&runs, out)?;
    fig6(&runs, budget, out)?;
    fig8(&runs, out)?;
    fig11(&runs, out)?;
    fig12(&runs, budget, out)?;
    fig7(&ctx, &cfg, &seeds, out, quick)?;
    Ok(())
}

/// Table 2: mean ± std of the four metrics per method. The spread pools
/// search-seed variance with validation-set bootstrap variance (the
/// paper's ± comes from its 10-patient test cohort's sampling noise).
fn table2(
    ctx: &SearchContext,
    runs: &[(Method, u64, SearchResult)],
    seeds: &[u64],
    out: &Path,
) -> Result<()> {
    use crate::metrics::{accuracy_at, bootstrap_metric, f1_at, pr_auc, roc_auc};
    let mut rows = Vec::new();
    println!("\nTable 2 (budget 200 ms, {} seeds):", seeds.len());
    println!("{:<8} {:>18} {:>18} {:>18} {:>18}", "Method", "ROC-AUC", "PR-AUC", "F1", "Accuracy");
    let labels = ctx.acc.labels().to_vec();
    for &m in &Method::ALL {
        let pick = |metric: fn(&[u8], &[f64]) -> f64| -> (f64, f64) {
            // pool bootstrap draws across seeds
            let mut means = Vec::new();
            let mut vars = Vec::new();
            for (_, s, r) in runs.iter().filter(|(mm, _, _)| *mm == m) {
                let scores = ctx.acc.ensemble_scores(&r.best.selector);
                let (mu, sd) = bootstrap_metric(&labels, &scores, metric, 64, 1000 + s);
                means.push(mu);
                vars.push(sd * sd);
            }
            let (mu, seed_sd) = mean_std(&means);
            let boot_var = vars.iter().sum::<f64>() / vars.len().max(1) as f64;
            (mu, (seed_sd * seed_sd + boot_var).sqrt())
        };
        let roc = pick(roc_auc);
        let pr = pick(pr_auc);
        let f1 = pick(|l, s| f1_at(l, s, 0.5));
        let acc = pick(|l, s| accuracy_at(l, s, 0.5));
        println!(
            "{:<8} {:>8.4} ±{:>6.4} {:>9.4} ±{:>6.4} {:>9.4} ±{:>6.4} {:>9.4} ±{:>6.4}",
            m.name(),
            roc.0,
            roc.1,
            pr.0,
            pr.1,
            f1.0,
            f1.1,
            acc.0,
            acc.1
        );
        rows.push(format!(
            "{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6}",
            m.name(),
            roc.0,
            roc.1,
            pr.0,
            pr.1,
            f1.0,
            f1.1,
            acc.0,
            acc.1
        ));
    }
    write_csv(
        out,
        "table2.csv",
        "method,roc_auc,roc_auc_std,pr_auc,pr_auc_std,f1,f1_std,accuracy,accuracy_std",
        &rows,
    )?;
    Ok(())
}

/// Fig. 1: final (latency, ROC-AUC) point per method per seed.
fn fig1(runs: &[(Method, u64, SearchResult)], out: &Path) -> Result<()> {
    let rows: Vec<String> = runs
        .iter()
        .map(|(m, s, r)| {
            format!("{},{},{:.6},{:.6}", m.name(), s, r.best.latency, r.best.accuracy.roc_auc)
        })
        .collect();
    write_csv(out, "fig1.csv", "method,seed,latency_s,roc_auc", &rows)?;
    Ok(())
}

/// Fig. 6: per-profiled-point trajectory (accuracy and latency of the
/// newly profiled point + incumbent), seed 0 only.
fn fig6(runs: &[(Method, u64, SearchResult)], budget: f64, out: &Path) -> Result<()> {
    let mut rows = Vec::new();
    for (m, s, r) in runs.iter().filter(|(_, s, _)| *s == 0) {
        let traj = r.trajectory(budget, Delta::HardStep);
        for (i, (p, (best_acc, best_lat))) in r.profile_set.iter().zip(&traj).enumerate() {
            rows.push(format!(
                "{},{},{},{:.6},{:.6},{:.6},{:.6}",
                m.name(),
                s,
                i,
                p.accuracy.roc_auc,
                p.latency,
                best_acc,
                best_lat
            ));
        }
    }
    write_csv(
        out,
        "fig6.csv",
        "method,seed,step,point_roc_auc,point_latency_s,best_roc_auc,best_latency_s",
        &rows,
    )?;
    Ok(())
}

/// Fig. 7: ROC-AUC distributions of HOLMES vs NPO across latency budgets.
fn fig7(
    ctx: &SearchContext,
    cfg: &ComposerConfig,
    seeds: &[u64],
    out: &Path,
    quick: bool,
) -> Result<()> {
    let budgets: Vec<f64> =
        if quick { vec![0.1, 0.2, 0.5] } else { vec![0.05, 0.1, 0.15, 0.2, 0.3, 0.5] };
    let mut rows = Vec::new();
    println!("\nFig 7 (ROC-AUC vs latency budget, HOLMES vs NPO):");
    for &b in &budgets {
        for &m in &[Method::Npo, Method::Holmes] {
            let aucs: Vec<f64> = seeds
                .iter()
                .map(|&s| {
                    let r = ctx.run(m, b, s, cfg);
                    rows.push(format!(
                        "{},{},{},{:.6},{:.6}",
                        m.name(),
                        b,
                        s,
                        r.best.accuracy.roc_auc,
                        r.best.latency
                    ));
                    r.best.accuracy.roc_auc
                })
                .collect();
            let (mu, sd) = mean_std(&aucs);
            println!("  L={b:>5}s {:<7} AUC {mu:.4} ± {sd:.4}", m.name());
        }
    }
    write_csv(out, "fig7.csv", "method,budget_s,seed,roc_auc,latency_s", &rows)?;
    Ok(())
}

/// Fig. 8: surrogate R² vs iteration (HOLMES runs, all seeds).
fn fig8(runs: &[(Method, u64, SearchResult)], out: &Path) -> Result<()> {
    let mut rows = Vec::new();
    for (_, s, r) in runs.iter().filter(|(m, _, _)| *m == Method::Holmes) {
        for &(it, r2a, r2l) in &r.surrogate_r2 {
            rows.push(format!("{s},{it},{r2a:.6},{r2l:.6}"));
        }
    }
    write_csv(out, "fig8.csv", "seed,iteration,r2_accuracy,r2_latency", &rows)?;
    Ok(())
}

/// Fig. 11: every explored point (latency, ROC-AUC) per algorithm, seed 0.
fn fig11(runs: &[(Method, u64, SearchResult)], out: &Path) -> Result<()> {
    let mut rows = Vec::new();
    for (m, s, r) in runs.iter().filter(|(_, s, _)| *s == 0) {
        for p in &r.profile_set {
            rows.push(format!(
                "{},{},{},{:.6},{:.6},{}",
                m.name(),
                s,
                p.iteration,
                p.latency,
                p.accuracy.roc_auc,
                p.selector.len()
            ));
        }
    }
    write_csv(
        out,
        "fig11.csv",
        "method,seed,iteration,latency_s,roc_auc,ensemble_size",
        &rows,
    )?;
    Ok(())
}

/// Fig. 12: utility-of-latency (budget − latency, clipped at 0) and
/// accuracy of each method's optimum under the 0.2 s constraint.
fn fig12(runs: &[(Method, u64, SearchResult)], budget: f64, out: &Path) -> Result<()> {
    let mut rows = Vec::new();
    for &m in &Method::ALL {
        let rs: Vec<&SearchResult> =
            runs.iter().filter(|(mm, _, _)| *mm == m).map(|(_, _, r)| r).collect();
        let lat_util: Vec<f64> =
            rs.iter().map(|r| (budget - r.best.latency).max(0.0)).collect();
        let acc: Vec<f64> = rs.iter().map(|r| r.best.accuracy.roc_auc).collect();
        let (lu, lus) = mean_std(&lat_util);
        let (au, aus) = mean_std(&acc);
        rows.push(format!("{},{lu:.6},{lus:.6},{au:.6},{aus:.6}", m.name()));
    }
    write_csv(
        out,
        "fig12.csv",
        "method,latency_headroom_s,latency_headroom_std,roc_auc,roc_auc_std",
        &rows,
    )?;
    Ok(())
}
