//! Fig. 2: prediction accuracy decreases with prediction delay.
//!
//! The paper evaluates step-down readiness on increasingly stale ECG
//! windows. Here the synthetic cohort drifts toward its end-state with a
//! 12 h time constant ([`crate::data::staleness_clips`]); clips observed
//! `delay` hours early are scored by the real AOT-compiled ensemble
//! (top trained model per lead) through the PJRT engine, and ROC-AUC is
//! reported per delay.

use std::path::Path;

use crate::data;
use crate::ingest::synth::SynthConfig;
use crate::metrics::roc_auc;
use crate::runtime::Engine;
use crate::zoo::Zoo;
use crate::Result;

use super::write_csv;

pub fn run(zoo: &Zoo, out: &Path, quick: bool) -> Result<()> {
    let delays: Vec<f64> =
        if quick { vec![0.0, 8.0, 24.0] } else { vec![0.0, 2.0, 4.0, 8.0, 16.0, 24.0, 36.0] };
    let n_clips = if quick { 60 } else { 200 };
    let engine = Engine::new(zoo, 2)?;
    let cfg = SynthConfig::from(&zoo.manifest.calibration);
    let clip_len = zoo.manifest.clip_len;

    // ensemble: best trained model per lead
    let members = best_trained_per_lead(zoo);
    println!("\n== Fig 2: accuracy vs prediction delay ==");
    println!(
        "ensemble: {:?}",
        members.iter().map(|&i| zoo.model(i).id.clone()).collect::<Vec<_>>()
    );

    let mut rows = Vec::new();
    let batch = engine.batch_for(8);
    // one persistent padded buffer for every scoring pass
    let mut input = crate::runtime::AlignedBatch::new();
    for &d in &delays {
        let set = data::staleness_clips(n_clips, clip_len, d, 77, &cfg);
        let mut scores = vec![0.0f64; set.len()];
        for &m in &members {
            let lead = zoo.model(m).lead;
            let mut i = 0;
            while i < set.len() {
                let take = (set.len() - i).min(batch);
                input.reset(batch * clip_len);
                for (slot, clip) in set.clips[i..i + take].iter().enumerate() {
                    input.pack_slot(slot, clip_len, &clip[lead]);
                }
                let outz = engine.execute_batch((m, batch), &mut input)?;
                for (slot, s) in scores[i..i + take].iter_mut().enumerate() {
                    *s += outz.scores[slot] as f64 / members.len() as f64;
                }
                i += take;
            }
        }
        let auc = roc_auc(&set.labels, &scores);
        println!("  delay {d:>5.1} h → ROC-AUC {auc:.4}");
        rows.push(format!("{d},{auc:.6},{n_clips}"));
    }
    write_csv(out, "fig2.csv", "delay_h,roc_auc,n_clips", &rows)?;
    Ok(())
}

/// Highest-validation-AUC trained model per ECG lead.
pub fn best_trained_per_lead(zoo: &Zoo) -> Vec<usize> {
    (0..3)
        .filter_map(|lead| {
            zoo.manifest
                .models
                .iter()
                .filter(|m| m.servable() && m.lead == lead)
                .max_by(|a, b| a.val_auc.partial_cmp(&b.val_auc).unwrap())
                .map(|m| m.index)
        })
        .collect()
}
