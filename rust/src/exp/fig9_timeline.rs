//! Fig. 9: end-to-end latency timeline, HOLMES online serving vs the
//! conventional hourly batch re-evaluation, one patient, 60 minutes.
//!
//! Time is compressed with the virtual clock (default 120×: the hour
//! runs in 30 wall-seconds; quick mode 600×) — inference latencies are
//! real wall-clock measurements, only the *pacing* between windows is
//! accelerated, which is sound because the system is idle between
//! events. Documented in EXPERIMENTS.md.

use std::path::Path;
use std::time::Instant;

use crate::ingest::synth::{PatientSim, SynthConfig};
use crate::ingest::VirtualClock;
use crate::runtime::Engine;
use crate::serving::pipeline::{Pipeline, PipelineConfig, Query};
use crate::zoo::{Selector, Zoo};
use crate::Result;

use super::fig2_staleness::best_trained_per_lead;
use super::write_csv;

pub fn run(zoo: &Zoo, out: &Path, quick: bool) -> Result<()> {
    let speedup = if quick { 600.0 } else { 120.0 };
    let horizon_s = 3600.0; // one hour of simulated monitoring
    let window_s = 30.0;
    let clip_len = zoo.manifest.clip_len;
    // "the highest accuracy model was chosen as the prediction model"
    let best = *best_trained_per_lead(zoo)
        .iter()
        .max_by(|&&a, &&b| zoo.model(a).val_auc.partial_cmp(&zoo.model(b).val_auc).unwrap())
        .expect("no trained models");
    let ensemble = Selector::from_indices(zoo.n(), [best]);
    println!("\n== Fig 9: online vs hourly-batch timeline (speedup {speedup}×) ==");
    println!("model: {}", zoo.model(best).id);

    let engine = Engine::new(zoo, 2)?;
    engine.profile_model((best, 1), 2)?; // warm compile out of the timeline

    let mut rows: Vec<String> = Vec::new();

    // ---- online: evaluate every 30 s window as it completes
    {
        let pipeline = Pipeline::spawn(zoo, &engine, PipelineConfig::new(ensemble.clone()))?;
        let cfg = SynthConfig::from(&zoo.manifest.calibration);
        let mut sim = PatientSim::new(0, 42, cfg);
        let clock = VirtualClock::new(speedup);
        let n_windows = (horizon_s / window_s) as usize;
        for w in 0..n_windows {
            let window_end = (w + 1) as f64 * window_s;
            // collect the window's samples (collection latency is measured
            // per simulated second of data, like the paper's small events)
            let mut leads: [Vec<f32>; 3] = Default::default();
            let per_sec = 250usize;
            let secs = (clip_len + per_sec - 1) / per_sec;
            for sec in 0..secs {
                let t0 = Instant::now();
                for _ in 0..per_sec.min(clip_len - sec * per_sec) {
                    let s = sim.next_ecg();
                    for (l, lead) in leads.iter_mut().enumerate() {
                        lead.push(s[l]);
                    }
                }
                rows.push(format!(
                    "online,{:.1},{:.6},collect",
                    window_end - window_s + (sec + 1) as f64 * window_s / secs as f64,
                    t0.elapsed().as_secs_f64()
                ));
            }
            clock.sleep_until_sim(window_end);
            let pred = pipeline.query(Query::from_vecs(0, w as u64, window_end, leads))?;
            rows.push(format!(
                "online,{window_end:.1},{:.6},infer",
                pred.e2e.as_secs_f64()
            ));
        }
    }

    // ---- batch: accumulate everything, evaluate once at the hour mark
    {
        let cfg = SynthConfig::from(&zoo.manifest.calibration);
        let mut sim = PatientSim::new(0, 42, cfg);
        let n_windows = (horizon_s / window_s) as usize;
        let mut windows: Vec<Vec<f32>> = Vec::with_capacity(n_windows);
        let lead = zoo.model(best).lead;
        for _ in 0..n_windows {
            let mut clip = Vec::with_capacity(clip_len);
            for _ in 0..clip_len {
                clip.push(sim.next_ecg()[lead]);
            }
            windows.push(clip);
        }
        // the hourly job: score the whole backlog in one batched pass
        // (one persistent padded buffer, recycled through the engine)
        let t0 = Instant::now();
        let batch = engine.batch_for(8);
        let mut input = crate::runtime::AlignedBatch::new();
        let mut i = 0;
        while i < windows.len() {
            let take = (windows.len() - i).min(batch);
            input.reset(batch * clip_len);
            for (slot, w) in windows[i..i + take].iter().enumerate() {
                input.pack_slot(slot, clip_len, w);
            }
            engine.execute_batch((best, batch), &mut input)?;
            i += take;
        }
        let total = t0.elapsed().as_secs_f64();
        rows.push(format!("batch,{horizon_s:.1},{total:.6},infer"));
        println!("  batch job at t=60min: {total:.3}s for {n_windows} windows");
    }

    // summary
    let online_infer: Vec<f64> = rows
        .iter()
        .filter(|r| r.starts_with("online") && r.ends_with("infer"))
        .filter_map(|r| r.split(',').nth(2)?.parse().ok())
        .collect();
    let mean_online = online_infer.iter().sum::<f64>() / online_infer.len().max(1) as f64;
    println!(
        "  online evals: {} windows, mean latency {:.4}s",
        online_infer.len(),
        mean_online
    );
    write_csv(out, "fig9.csv", "mode,sim_time_s,latency_s,kind", &rows)?;
    Ok(())
}
