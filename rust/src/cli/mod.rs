//! Tiny argument-parsing substrate for the `holmes` binary (clap is
//! unavailable in the offline build): positional subcommand + `--key
//! value` / `--flag` options with typed accessors.

use std::collections::HashMap;

use crate::{Error, Result};

/// Parsed command line: subcommand, positionals, options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positionals: Vec<String>,
    options: HashMap<String, Vec<String>>,
    flags: Vec<String>,
}

/// Which option names take a value (everything else is a boolean flag).
pub fn parse(argv: &[String], value_opts: &[&str]) -> Result<Args> {
    let mut args = Args::default();
    let mut it = argv.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let (name, inline) = match name.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (name, None),
            };
            if value_opts.contains(&name) {
                let v = match inline {
                    Some(v) => v,
                    None => it
                        .next()
                        .ok_or_else(|| Error::config(format!("--{name} needs a value")))?
                        .clone(),
                };
                args.options.entry(name.to_string()).or_default().push(v);
            } else if inline.is_some() {
                return Err(Error::config(format!("--{name} does not take a value")));
            } else {
                args.flags.push(name.to_string());
            }
        } else if args.subcommand.is_none() {
            args.subcommand = Some(a.clone());
        } else {
            args.positionals.push(a.clone());
        }
    }
    Ok(args)
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("--{name}: '{v}' is not an integer"))),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("--{name}: '{v}' is not a number"))),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("--{name}: '{v}' is not an integer"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_options_and_flags() {
        let a = parse(&argv("compose --budget 0.2 --servable-only --seed=9"), &["budget", "seed"])
            .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("compose"));
        assert_eq!(a.f64_or("budget", 0.0).unwrap(), 0.2);
        assert_eq!(a.u64_or("seed", 0).unwrap(), 9);
        assert!(a.flag("servable-only"));
        assert!(!a.flag("quick"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(&argv("x --budget"), &["budget"]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&argv("serve"), &["patients"]).unwrap();
        assert_eq!(a.usize_or("patients", 64).unwrap(), 64);
    }

    #[test]
    fn bad_numbers_are_errors() {
        let a = parse(&argv("x --n abc"), &["n"]).unwrap();
        assert!(a.usize_or("n", 1).is_err());
    }

    #[test]
    fn positionals_after_subcommand() {
        let a = parse(&argv("exp fig10 --quick"), &["out"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.positionals, vec!["fig10".to_string()]);
        assert!(a.flag("quick"));
    }
}
