//! Crate-wide error type.

use std::fmt;

/// Unified error for zoo loading, runtime execution and serving.
#[derive(Debug)]
pub enum Error {
    /// I/O failure (artifact files, result CSVs).
    Io(std::io::Error),
    /// Manifest / score-file / ingest-body JSON problems.
    Json2(String),
    /// PJRT / XLA failures surfaced by the `xla` crate.
    Xla(String),
    /// Artifact inventory problems (missing model, batch variant...).
    Artifact(String),
    /// Serving-pipeline failures (actor gone, channel closed...).
    Serving(String),
    /// Invalid configuration or argument.
    Config(String),
    /// Binary ingest wire-format problems (bad magic, truncation...).
    Wire(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json2(e) => write!(f, "json error: {e}"),
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Artifact(e) => write!(f, "artifact error: {e}"),
            Error::Serving(e) => write!(f, "serving error: {e}"),
            Error::Config(e) => write!(f, "config error: {e}"),
            Error::Wire(e) => write!(f, "wire error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    pub fn serving(msg: impl Into<String>) -> Self {
        Error::Serving(msg.into())
    }
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn json(msg: impl Into<String>) -> Self {
        Error::Json2(msg.into())
    }
    pub fn wire(msg: impl Into<String>) -> Self {
        Error::Wire(msg.into())
    }
}
