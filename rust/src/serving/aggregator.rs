//! Stateful data aggregator (paper §3.4 "Support for stateful compute").
//!
//! One aggregator per patient buffers the multi-rate streams (ECG
//! 250 Hz, vitals 1 Hz) and releases a synchronized ensemble query when
//! a full observation window ΔT has been collected — so every model in
//! the ensemble sees the *same* interval of time across sensors.

use std::sync::Arc;

use crate::ingest::{Frame, FrameValues, Modality};

/// Synchronized multi-modal window ready for the ensemble.
#[derive(Debug, Clone)]
pub struct WindowData {
    pub patient: usize,
    /// Monotone per-patient window sequence number.
    pub window_id: u64,
    /// Simulation time of the window end.
    pub sim_end: f64,
    /// ECG leads, `clip_len` samples each, in shared storage: the whole
    /// serving data plane (router fan-out, batchers) borrows these
    /// windows instead of cloning them per ensemble member.
    pub leads: [Arc<[f32]>; 3],
    /// Mean vitals over the window (7 values; empty if none arrived).
    pub vitals: Vec<f32>,
    /// Latest labs seen (8 values; empty if none arrived).
    pub labs: Vec<f32>,
}

/// Ring-buffering aggregator for one patient.
#[derive(Debug)]
pub struct WindowAggregator {
    patient: usize,
    /// ECG samples per emitted window (= clip_len of the zoo models).
    window_samples: usize,
    leads: [Vec<f32>; 3],
    vitals_acc: Vec<f64>,
    vitals_count: usize,
    last_labs: FrameValues,
    window_id: u64,
    dropped: u64,
}

impl WindowAggregator {
    pub fn new(patient: usize, window_samples: usize) -> Self {
        assert!(window_samples > 0);
        WindowAggregator {
            patient,
            window_samples,
            leads: [
                Vec::with_capacity(window_samples),
                Vec::with_capacity(window_samples),
                Vec::with_capacity(window_samples),
            ],
            vitals_acc: vec![0.0; 7],
            vitals_count: 0,
            last_labs: FrameValues::new(),
            window_id: 0,
            dropped: 0,
        }
    }

    pub fn patient(&self) -> usize {
        self.patient
    }

    /// Samples currently buffered toward the next window.
    pub fn fill(&self) -> usize {
        self.leads[0].len()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Push one frame; returns a completed window when ΔT fills up.
    pub fn push(&mut self, frame: &Frame) -> Option<WindowData> {
        if frame.patient != self.patient {
            self.dropped += 1;
            return None;
        }
        match frame.modality {
            Modality::Ecg => {
                if frame.values.len() != 3 {
                    self.dropped += 1;
                    return None;
                }
                for (lead, &v) in self.leads.iter_mut().zip(frame.values.iter()) {
                    lead.push(v);
                }
                if self.leads[0].len() >= self.window_samples {
                    return Some(self.emit(frame.sim_time));
                }
                None
            }
            Modality::Vitals => {
                if frame.values.len() == 7 {
                    for (a, &v) in self.vitals_acc.iter_mut().zip(frame.values.iter()) {
                        *a += v as f64;
                    }
                    self.vitals_count += 1;
                } else {
                    self.dropped += 1;
                }
                None
            }
            Modality::Labs => {
                if frame.values.len() == 8 {
                    // inline buffer: latching labs is a plain copy
                    self.last_labs = frame.values;
                } else {
                    self.dropped += 1;
                }
                None
            }
        }
    }

    fn emit(&mut self, sim_end: f64) -> WindowData {
        // move each collected lead into shared storage once; downstream
        // (router → every member's batcher) only clones the Arc handle
        let leads: [Arc<[f32]>; 3] = [
            Arc::from(std::mem::take(&mut self.leads[0])),
            Arc::from(std::mem::take(&mut self.leads[1])),
            Arc::from(std::mem::take(&mut self.leads[2])),
        ];
        for lead in self.leads.iter_mut() {
            lead.reserve(self.window_samples);
        }
        let vitals = if self.vitals_count > 0 {
            self.vitals_acc
                .iter()
                .map(|a| (*a / self.vitals_count as f64) as f32)
                .collect()
        } else {
            Vec::new()
        };
        self.vitals_acc.iter_mut().for_each(|a| *a = 0.0);
        self.vitals_count = 0;
        let id = self.window_id;
        self.window_id += 1;
        WindowData {
            patient: self.patient,
            window_id: id,
            sim_end,
            leads,
            vitals,
            labs: self.last_labs.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ecg_frame(patient: usize, t: f64, v: f32) -> Frame {
        Frame {
            patient,
            modality: Modality::Ecg,
            sim_time: t,
            values: [v, v + 1.0, v + 2.0].into(),
        }
    }

    #[test]
    fn emits_exactly_at_window_boundary() {
        let mut agg = WindowAggregator::new(0, 4);
        for i in 0..3 {
            assert!(agg.push(&ecg_frame(0, i as f64, i as f32)).is_none());
        }
        let w = agg.push(&ecg_frame(0, 3.0, 3.0)).expect("window due");
        assert_eq!(w.window_id, 0);
        assert_eq!(w.leads[0].as_ref(), &[0.0, 1.0, 2.0, 3.0][..]);
        assert_eq!(w.leads[2].as_ref(), &[2.0, 3.0, 4.0, 5.0][..]);
        assert_eq!(agg.fill(), 0, "buffer reset after emit");
    }

    #[test]
    fn windows_do_not_overlap() {
        let mut agg = WindowAggregator::new(0, 2);
        let w1 = [agg.push(&ecg_frame(0, 0.0, 0.0)), agg.push(&ecg_frame(0, 1.0, 1.0))];
        let w2 = [agg.push(&ecg_frame(0, 2.0, 2.0)), agg.push(&ecg_frame(0, 3.0, 3.0))];
        let w1 = w1[1].as_ref().unwrap();
        let w2 = w2[1].as_ref().unwrap();
        assert_eq!(w1.window_id + 1, w2.window_id);
        assert_eq!(w1.leads[0].as_ref(), &[0.0, 1.0][..]);
        assert_eq!(w2.leads[0].as_ref(), &[2.0, 3.0][..]);
    }

    #[test]
    fn wrong_patient_frames_are_dropped() {
        let mut agg = WindowAggregator::new(1, 2);
        assert!(agg.push(&ecg_frame(0, 0.0, 0.0)).is_none());
        assert_eq!(agg.dropped(), 1);
        assert_eq!(agg.fill(), 0);
    }

    #[test]
    fn vitals_are_averaged_per_window() {
        let mut agg = WindowAggregator::new(0, 2);
        agg.push(&Frame {
            patient: 0,
            modality: Modality::Vitals,
            sim_time: 0.0,
            values: [100.0, 70.0, 98.0, 20.0, 37.0, 6.0, 1.4].into(),
        });
        agg.push(&Frame {
            patient: 0,
            modality: Modality::Vitals,
            sim_time: 0.5,
            values: [110.0, 72.0, 97.0, 22.0, 37.2, 7.0, 1.2].into(),
        });
        agg.push(&ecg_frame(0, 0.0, 0.0));
        let w = agg.push(&ecg_frame(0, 1.0, 1.0)).unwrap();
        assert!((w.vitals[0] - 105.0).abs() < 1e-6);
        // next window starts with a fresh vitals accumulator
        agg.push(&ecg_frame(0, 2.0, 0.0));
        let w2 = agg.push(&ecg_frame(0, 3.0, 1.0)).unwrap();
        assert!(w2.vitals.is_empty());
    }

    #[test]
    fn malformed_frames_counted_dropped() {
        let mut agg = WindowAggregator::new(0, 4);
        agg.push(&Frame {
            patient: 0,
            modality: Modality::Ecg,
            sim_time: 0.0,
            values: [1.0].into(),
        });
        agg.push(&Frame {
            patient: 0,
            modality: Modality::Vitals,
            sim_time: 0.0,
            values: [1.0, 2.0].into(),
        });
        assert_eq!(agg.dropped(), 2);
    }

    #[test]
    fn labs_latched_across_windows() {
        let mut agg = WindowAggregator::new(0, 1);
        agg.push(&Frame {
            patient: 0,
            modality: Modality::Labs,
            sim_time: 0.0,
            values: [7.4, 1.0, 4.0, 140.0, 0.4, 12.0, 14.0, 9.0].into(),
        });
        let w1 = agg.push(&ecg_frame(0, 0.0, 0.0)).unwrap();
        let w2 = agg.push(&ecg_frame(0, 1.0, 0.0)).unwrap();
        assert_eq!(w1.labs.len(), 8);
        assert_eq!(w1.labs, w2.labs, "labs persist until a new result arrives");
    }
}
