//! Stateful data aggregator (paper §3.4 "Support for stateful compute").
//!
//! One aggregator per patient buffers the multi-rate streams (ECG
//! 250 Hz, vitals 1 Hz) and releases a synchronized ensemble query when
//! a full observation window ΔT has been collected — so every model in
//! the ensemble sees the *same* interval of time across sensors.
//!
//! Lead samples are written straight into recyclable [`LeadSlot`]
//! buffers (per-shard [`LeadPool`] slabs when constructed through
//! [`WindowAggregator::with_pool`]); emitting a window seals the slots
//! into shared [`WindowLease`]s without copying a sample, and the
//! buffers return to the pool when the last batcher drops them — the
//! steady-state aggregation plane does no per-window buffer allocation.

use super::arena::{LeadPool, LeadSlot, WindowLease};
use crate::ingest::{Frame, FrameValues, Modality};

/// Synchronized multi-modal window ready for the ensemble.
#[derive(Debug, Clone)]
pub struct WindowData {
    pub patient: usize,
    /// Monotone per-patient window sequence number.
    pub window_id: u64,
    /// Simulation time of the window end.
    pub sim_end: f64,
    /// ECG leads, `clip_len` samples each, as shared pooled leases: the
    /// whole serving data plane (router fan-out, executor workers)
    /// borrows these windows instead of cloning them per ensemble
    /// member, and the buffers recycle on last drop.
    pub leads: [WindowLease; 3],
    /// Mean vitals over the window (7 values; empty if none arrived).
    pub vitals: Vec<f32>,
    /// Latest labs seen (8 values; empty if none arrived).
    pub labs: Vec<f32>,
}

/// Ring-buffering aggregator for one patient.
#[derive(Debug)]
pub struct WindowAggregator {
    patient: usize,
    /// ECG samples per emitted window (= clip_len of the zoo models).
    window_samples: usize,
    /// Exclusive write-stage buffers for the window being collected.
    leads: [LeadSlot; 3],
    /// Samples written into each lead so far (all three fill in step).
    fill: usize,
    /// Where replacement buffers come from at emit time; `None` falls
    /// back to fresh owned buffers (tests, pool-less callers).
    pool: Option<LeadPool>,
    vitals_acc: Vec<f64>,
    vitals_count: usize,
    last_labs: FrameValues,
    window_id: u64,
    dropped: u64,
    /// Highest ECG `sim_time` accepted so far — the current window
    /// position. Frames strictly older than this (a monitor whose clock
    /// runs behind, or frames reordered in flight) would corrupt window
    /// packing if written at `fill`, so they are dropped and counted in
    /// `stale` instead.
    last_ecg_time: f64,
    stale: u64,
}

impl WindowAggregator {
    pub fn new(patient: usize, window_samples: usize) -> Self {
        Self::build(patient, window_samples, None)
    }

    /// Aggregator drawing its lead buffers from a shared (per-shard)
    /// pool instead of allocating per window.
    pub fn with_pool(patient: usize, window_samples: usize, pool: LeadPool) -> Self {
        assert_eq!(pool.samples(), window_samples, "pool buffer size must match the window");
        Self::build(patient, window_samples, Some(pool))
    }

    fn build(patient: usize, window_samples: usize, pool: Option<LeadPool>) -> Self {
        assert!(window_samples > 0);
        let mut fresh = || match &pool {
            Some(p) => p.slot(),
            None => LeadSlot::zeroed(window_samples),
        };
        let leads = [fresh(), fresh(), fresh()];
        WindowAggregator {
            patient,
            window_samples,
            leads,
            fill: 0,
            pool,
            vitals_acc: vec![0.0; 7],
            vitals_count: 0,
            last_labs: FrameValues::new(),
            window_id: 0,
            dropped: 0,
            last_ecg_time: f64::NEG_INFINITY,
            stale: 0,
        }
    }

    pub fn patient(&self) -> usize {
        self.patient
    }

    /// Samples currently buffered toward the next window.
    pub fn fill(&self) -> usize {
        self.fill
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// ECG frames rejected because their `sim_time` was strictly older
    /// than the newest accepted sample (out-of-order / skewed-clock
    /// arrivals). Disjoint from [`dropped`](Self::dropped).
    pub fn stale(&self) -> u64 {
        self.stale
    }

    /// Push one frame; returns a completed window when ΔT fills up.
    pub fn push(&mut self, frame: &Frame) -> Option<WindowData> {
        if frame.patient != self.patient {
            self.dropped += 1;
            return None;
        }
        match frame.modality {
            Modality::Ecg => {
                if frame.values.len() != 3 {
                    self.dropped += 1;
                    return None;
                }
                // a lagging monitor clock must not rewind the window:
                // samples land at `fill` regardless of timestamp, so an
                // older frame would splice stale signal into the current
                // interval. Equal timestamps are fine (two in-sync
                // monitors covering the same bed).
                if frame.sim_time < self.last_ecg_time {
                    self.stale += 1;
                    return None;
                }
                self.last_ecg_time = frame.sim_time;
                let at = self.fill;
                for (lead, &v) in self.leads.iter_mut().zip(frame.values.iter()) {
                    lead.as_mut_slice()[at] = v;
                }
                self.fill += 1;
                if self.fill >= self.window_samples {
                    return Some(self.emit(frame.sim_time));
                }
                None
            }
            Modality::Vitals => {
                if frame.values.len() == 7 {
                    for (a, &v) in self.vitals_acc.iter_mut().zip(frame.values.iter()) {
                        *a += v as f64;
                    }
                    self.vitals_count += 1;
                } else {
                    self.dropped += 1;
                }
                None
            }
            Modality::Labs => {
                if frame.values.len() == 8 {
                    // inline buffer: latching labs is a plain copy
                    self.last_labs = frame.values;
                } else {
                    self.dropped += 1;
                }
                None
            }
        }
    }

    fn emit(&mut self, sim_end: f64) -> WindowData {
        // seal each filled slot into a shared lease (no sample copy)
        // and stage a replacement buffer — recycled from the pool when
        // one is free, so steady state allocates nothing per window
        let mut fresh = || match &self.pool {
            Some(p) => p.slot(),
            None => LeadSlot::zeroed(self.window_samples),
        };
        let leads: [WindowLease; 3] = [
            std::mem::replace(&mut self.leads[0], fresh()).share(),
            std::mem::replace(&mut self.leads[1], fresh()).share(),
            std::mem::replace(&mut self.leads[2], fresh()).share(),
        ];
        self.fill = 0;
        let vitals = if self.vitals_count > 0 {
            self.vitals_acc
                .iter()
                .map(|a| (*a / self.vitals_count as f64) as f32)
                .collect()
        } else {
            Vec::new()
        };
        self.vitals_acc.iter_mut().for_each(|a| *a = 0.0);
        self.vitals_count = 0;
        let id = self.window_id;
        self.window_id += 1;
        WindowData {
            patient: self.patient,
            window_id: id,
            sim_end,
            leads,
            vitals,
            labs: self.last_labs.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ecg_frame(patient: usize, t: f64, v: f32) -> Frame {
        Frame {
            patient,
            modality: Modality::Ecg,
            sim_time: t,
            values: [v, v + 1.0, v + 2.0].into(),
        }
    }

    #[test]
    fn emits_exactly_at_window_boundary() {
        let mut agg = WindowAggregator::new(0, 4);
        for i in 0..3 {
            assert!(agg.push(&ecg_frame(0, i as f64, i as f32)).is_none());
        }
        let w = agg.push(&ecg_frame(0, 3.0, 3.0)).expect("window due");
        assert_eq!(w.window_id, 0);
        assert_eq!(&w.leads[0][..], &[0.0, 1.0, 2.0, 3.0][..]);
        assert_eq!(&w.leads[2][..], &[2.0, 3.0, 4.0, 5.0][..]);
        assert_eq!(agg.fill(), 0, "buffer reset after emit");
    }

    #[test]
    fn windows_do_not_overlap() {
        let mut agg = WindowAggregator::new(0, 2);
        let w1 = [agg.push(&ecg_frame(0, 0.0, 0.0)), agg.push(&ecg_frame(0, 1.0, 1.0))];
        let w2 = [agg.push(&ecg_frame(0, 2.0, 2.0)), agg.push(&ecg_frame(0, 3.0, 3.0))];
        let w1 = w1[1].as_ref().unwrap();
        let w2 = w2[1].as_ref().unwrap();
        assert_eq!(w1.window_id + 1, w2.window_id);
        assert_eq!(&w1.leads[0][..], &[0.0, 1.0][..]);
        assert_eq!(&w2.leads[0][..], &[2.0, 3.0][..]);
    }

    #[test]
    fn pooled_windows_recycle_and_stay_correct() {
        let pool = LeadPool::new(2);
        let mut agg = WindowAggregator::with_pool(0, 2, pool.clone());
        agg.push(&ecg_frame(0, 0.0, 0.0));
        let w1 = agg.push(&ecg_frame(0, 1.0, 1.0)).unwrap();
        assert_eq!(&w1.leads[0][..], &[0.0, 1.0][..]);
        drop(w1); // last drop → 3 lead buffers back on the free list
        assert_eq!(pool.free_len(), 3);
        // the next window reuses those buffers and still reads correctly
        agg.push(&ecg_frame(0, 2.0, 5.0));
        let w2 = agg.push(&ecg_frame(0, 3.0, 6.0)).unwrap();
        assert_eq!(&w2.leads[0][..], &[5.0, 6.0][..]);
        assert_eq!(&w2.leads[1][..], &[6.0, 7.0][..]);
        assert!(pool.reused() >= 3, "recycled buffers must be picked up");
    }

    #[test]
    fn wrong_patient_frames_are_dropped() {
        let mut agg = WindowAggregator::new(1, 2);
        assert!(agg.push(&ecg_frame(0, 0.0, 0.0)).is_none());
        assert_eq!(agg.dropped(), 1);
        assert_eq!(agg.fill(), 0);
    }

    #[test]
    fn vitals_are_averaged_per_window() {
        let mut agg = WindowAggregator::new(0, 2);
        agg.push(&Frame {
            patient: 0,
            modality: Modality::Vitals,
            sim_time: 0.0,
            values: [100.0, 70.0, 98.0, 20.0, 37.0, 6.0, 1.4].into(),
        });
        agg.push(&Frame {
            patient: 0,
            modality: Modality::Vitals,
            sim_time: 0.5,
            values: [110.0, 72.0, 97.0, 22.0, 37.2, 7.0, 1.2].into(),
        });
        agg.push(&ecg_frame(0, 0.0, 0.0));
        let w = agg.push(&ecg_frame(0, 1.0, 1.0)).unwrap();
        assert!((w.vitals[0] - 105.0).abs() < 1e-6);
        // next window starts with a fresh vitals accumulator
        agg.push(&ecg_frame(0, 2.0, 0.0));
        let w2 = agg.push(&ecg_frame(0, 3.0, 1.0)).unwrap();
        assert!(w2.vitals.is_empty());
    }

    #[test]
    fn malformed_frames_counted_dropped() {
        let mut agg = WindowAggregator::new(0, 4);
        agg.push(&Frame {
            patient: 0,
            modality: Modality::Ecg,
            sim_time: 0.0,
            values: [1.0].into(),
        });
        agg.push(&Frame {
            patient: 0,
            modality: Modality::Vitals,
            sim_time: 0.0,
            values: [1.0, 2.0].into(),
        });
        assert_eq!(agg.dropped(), 2);
    }

    #[test]
    fn skewed_two_monitor_interleave_drops_only_stale_frames() {
        // monitor A is on true time; monitor B's clock runs 2.5 sample
        // periods behind. Interleaving A/B sample-by-sample means every
        // B frame arrives with a timestamp older than the A frame just
        // accepted — each must be counted stale and must NOT advance
        // the window, while A's samples pack a correct window.
        let dt = 1.0 / 250.0;
        let skew = 2.5 * dt;
        let mut agg = WindowAggregator::new(0, 4);
        let mut accepted = Vec::new();
        for i in 0..10 {
            let (t, v) = if i % 2 == 0 {
                (i as f64 * dt, i as f32) // monitor A
            } else {
                (i as f64 * dt - skew, 1000.0 + i as f32) // monitor B, behind
            };
            if let Some(w) = agg.push(&ecg_frame(0, t, v)) {
                accepted.push(w);
            }
        }
        // i=1 is B's first frame: nothing accepted yet at a later time
        // except A's i=0 at t=0 vs B at 1·dt−2.5·dt < 0 → stale too.
        assert_eq!(agg.stale(), 5, "every B frame is behind the window position");
        assert_eq!(agg.dropped(), 0, "stale is its own cause, not 'malformed'");
        assert_eq!(accepted.len(), 1);
        let w = &accepted[0];
        assert_eq!(&w.leads[0][..], &[0.0, 2.0, 4.0, 6.0][..], "window holds A's stream only");
        assert_eq!(agg.fill(), 1, "A's 5th sample started the next window");
    }

    #[test]
    fn equal_timestamps_are_not_stale() {
        let mut agg = WindowAggregator::new(0, 2);
        agg.push(&ecg_frame(0, 1.0, 0.0));
        let w = agg.push(&ecg_frame(0, 1.0, 1.0)).expect("in-sync duplicate timestamps pack");
        assert_eq!(agg.stale(), 0);
        assert_eq!(&w.leads[0][..], &[0.0, 1.0][..]);
    }

    #[test]
    fn labs_latched_across_windows() {
        let mut agg = WindowAggregator::new(0, 1);
        agg.push(&Frame {
            patient: 0,
            modality: Modality::Labs,
            sim_time: 0.0,
            values: [7.4, 1.0, 4.0, 140.0, 0.4, 12.0, 14.0, 9.0].into(),
        });
        let w1 = agg.push(&ecg_frame(0, 0.0, 0.0)).unwrap();
        let w2 = agg.push(&ecg_frame(0, 1.0, 0.0)).unwrap();
        assert_eq!(w1.labs.len(), 8);
        assert_eq!(w1.labs, w2.labs, "labs persist until a new result arrives");
    }
}
