//! The ensemble **governor**: the control plane that closes the paper's
//! accuracy/latency feedback loop on a *running* [`Pipeline`].
//!
//! Six PRs of data-plane speed left the serving plane executing one
//! fixed ensemble until process death: the composer ran offline, a
//! panicked lane was dead forever, and sustained overload could only
//! breach the SLO. The governor is the supervisory loop that fixes all
//! three. Every control tick (`--control-tick-ms`) it:
//!
//! ```text
//!            ┌────────────── read live signals ───────────────┐
//!            │ pressure = (T_q.p95 + T_s.p95) / SLO           │
//!            │ dead-lane flags, per-lane exec-time EWMA       │
//!            └──────┬──────────────┬───────────────┬──────────┘
//!                   ▼              ▼               ▼
//!            ┌ degrade/recover ┌ quarantine ┌ recompose (every Nth tick)
//!            │ pressure ≥ 1 for│ dead lanes │ Composer::search seeded
//!            │ `overload_ticks`│ leave the  │ with {current, floor,
//!            │ → step down to  │ active set;│ healthy-universe}, scored
//!            │ the accuracy    │ canary re- │ against LIVE per-lane
//!            │ floor; ≤ 0.7 for│ probe with │ service times (EWMA) in
//!            │ `recover_ticks` │ exp backoff│ place of offline MACs
//!            │ → step back up  │ → reinstate│ estimates
//!            └──────┬──────────┴─────┬──────┴──────┬───────────
//!                   └────────────────┴─────────────┘
//!                                    ▼
//!                   Pipeline::install_membership(next)
//!                   (hot swap: FIFO-ordered vs admissions,
//!                    zero in-flight queries dropped)
//! ```
//!
//! ## Determinism
//!
//! The governor only ever *schedules* swaps; the swap itself rides the
//! router channel ([`Pipeline::install_membership`]), so queries
//! admitted under epoch E complete under E's member set bit-for-bit
//! regardless of worker count or tick timing. Given the same swap
//! schedule, predictions are bit-identical (`tests/governor.rs`).
//!
//! ## Split: pure core vs driver thread
//!
//! [`GovernorCore`] is a pure, clock-free state machine — `(pressure,
//! dead flags, candidate) → (install?, probes)` — unit-tested
//! exhaustively below without threads or sleeps. [`Governor`] is the
//! thin driver that owns the tick clock, reads telemetry, runs the
//! composer, fires canaries, and applies the core's plan to the
//! pipeline.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::control::DEFAULT_SLO;
use super::pipeline::Pipeline;
use super::telemetry::GovernorGauges;
use crate::composer::Composer;
use crate::config::{ComposerConfig, SystemConfig};
use crate::profiler::{
    AnalyticLatencyProfiler, LatencyProfiler, ServiceTimes, ValidationAccuracyProfiler,
};
use crate::profiler::AccuracyProfiler;
use crate::zoo::{Selector, Zoo};
use crate::{Error, Result};

/// Governor tuning knobs. The defaults are deliberately conservative:
/// two consecutive over-pressure ticks before degrading (a single burst
/// tail must not collapse the ensemble), five clean ticks before
/// recovering (hysteresis — flapping between floor and full set would
/// thrash the composer and the lanes' batch fill).
#[derive(Debug, Clone)]
pub struct GovernorConfig {
    /// Control-loop period (`--control-tick-ms`, default 100 ms).
    pub tick: Duration,
    /// Accuracy bar (ensemble validation ROC-AUC) the degraded-mode
    /// floor must still clear (`--floor-acc`, default 0.80).
    pub floor_acc: f64,
    /// End-to-end SLO pressure is measured against (`--slo-ms`).
    pub slo: Duration,
    /// Latency budget (seconds) handed to the composer's utility.
    pub latency_budget: f64,
    /// Consecutive ticks with pressure ≥ 1.0 before stepping down.
    pub overload_ticks: u32,
    /// Consecutive ticks with pressure ≤ `recover_pressure` before
    /// stepping back up (hysteresis width).
    pub recover_ticks: u32,
    /// Recovery threshold: strictly below the 1.0 overload line so the
    /// governor never oscillates on a pressure plateau.
    pub recover_pressure: f64,
    /// First canary re-probe delay for a quarantined lane, in ticks.
    pub backoff_init_ticks: u32,
    /// Exponential backoff cap, in ticks.
    pub backoff_max_ticks: u32,
    /// Run the composer every Nth tick (re-composition is ~ms of CPU;
    /// quarantine/degrade decisions stay per-tick).
    pub recompose_every: u32,
}

impl Default for GovernorConfig {
    fn default() -> Self {
        GovernorConfig {
            tick: Duration::from_millis(100),
            floor_acc: 0.80,
            slo: DEFAULT_SLO,
            latency_budget: 0.2,
            overload_ticks: 2,
            recover_ticks: 5,
            recover_pressure: 0.7,
            backoff_init_ticks: 2,
            backoff_max_ticks: 32,
            recompose_every: 10,
        }
    }
}

/// What one [`GovernorCore::on_tick`] decided.
#[derive(Debug, Default, PartialEq, Eq)]
pub struct TickPlan {
    /// Membership to install (lane positions), if it changed.
    pub install: Option<Vec<usize>>,
    /// Quarantined lanes due for a canary probe this tick.
    pub probes: Vec<usize>,
    /// The governor stepped down to the floor this tick.
    pub entered_degraded: bool,
    /// The governor stepped back up this tick.
    pub left_degraded: bool,
}

/// Quarantine ledger entry: exponential-backoff probe schedule.
#[derive(Debug, Clone, Copy)]
struct Backoff {
    /// Current wait between probes, in ticks (doubles per failure).
    wait: u32,
    /// Ticks until the next probe fires.
    next_in: u32,
}

/// The governor's pure decision core: no clocks, no threads, no I/O —
/// every input arrives as an argument, every decision leaves as a
/// [`TickPlan`]. Drives identically under test and under the real
/// driver.
#[derive(Debug)]
pub struct GovernorCore {
    /// Lane positions of the full spawn-time universe: `0..n_lanes`.
    n_lanes: usize,
    /// Degraded-mode member set (smallest set clearing the accuracy
    /// bar), ascending lane positions.
    floor: Vec<usize>,
    /// Current active membership (what the last install established).
    active: Vec<usize>,
    /// Quarantined lanes → probe backoff state.
    quarantine: BTreeMap<usize, Backoff>,
    /// Lanes whose canary succeeded, joining at the next tick's install.
    pending_join: Vec<usize>,
    /// Membership saved on entering degraded mode — what recovery steps
    /// back up to (a later recompose tick may refine it further).
    pre_degraded: Vec<usize>,
    degraded: bool,
    over_ticks: u32,
    under_ticks: u32,
    overload_ticks: u32,
    recover_ticks: u32,
    recover_pressure: f64,
    backoff_init: u32,
    backoff_max: u32,
}

impl GovernorCore {
    /// `floor` is validated against the universe and normalised
    /// (sorted, deduplicated); the core starts with the full universe
    /// active (epoch 0's member set).
    pub fn new(n_lanes: usize, mut floor: Vec<usize>, cfg: &GovernorConfig) -> Self {
        floor.sort_unstable();
        floor.dedup();
        assert!(!floor.is_empty(), "the degraded floor has at least one lane");
        assert!(floor.iter().all(|&p| p < n_lanes), "floor lanes must be in the universe");
        GovernorCore {
            n_lanes,
            floor,
            active: (0..n_lanes).collect(),
            quarantine: BTreeMap::new(),
            pending_join: Vec::new(),
            pre_degraded: Vec::new(),
            degraded: false,
            over_ticks: 0,
            under_ticks: 0,
            overload_ticks: cfg.overload_ticks.max(1),
            recover_ticks: cfg.recover_ticks.max(1),
            recover_pressure: cfg.recover_pressure,
            backoff_init: cfg.backoff_init_ticks.max(1),
            backoff_max: cfg.backoff_max_ticks.max(1),
        }
    }

    pub fn active(&self) -> &[usize] {
        &self.active
    }

    pub fn floor(&self) -> &[usize] {
        &self.floor
    }

    pub fn degraded(&self) -> bool {
        self.degraded
    }

    /// Lanes currently quarantined (ascending).
    pub fn quarantined(&self) -> Vec<usize> {
        self.quarantine.keys().copied().collect()
    }

    /// A lane is healthy when its backend is alive and it is not in
    /// quarantine (a lane stays quarantined until its canary passes,
    /// even after the dead flag clears).
    fn healthy(&self, dead: &[bool]) -> Vec<usize> {
        (0..self.n_lanes)
            .filter(|&p| !dead.get(p).copied().unwrap_or(false) && !self.quarantine.contains_key(&p))
            .collect()
    }

    fn intersect(a: &[usize], healthy: &[usize]) -> Vec<usize> {
        a.iter().copied().filter(|p| healthy.contains(p)).collect()
    }

    /// One control tick. `pressure` is the live tail-latency-to-SLO
    /// ratio (≥ 1.0 = the tail is at/over the SLO), `dead` the per-lane
    /// dead flags, `candidate` the composer's pick for this tick (lane
    /// positions; `None` on non-recompose ticks or when the search
    /// produced nothing valid).
    pub fn on_tick(&mut self, pressure: f64, dead: &[bool], candidate: Option<&[usize]>) -> TickPlan {
        let mut plan = TickPlan::default();

        // 1. quarantine newly dead lanes (active or not — a dead floor
        // lane must also heal before it can ever serve again)
        let mut fresh: Vec<usize> = Vec::new();
        for pos in 0..self.n_lanes {
            if dead.get(pos).copied().unwrap_or(false) && !self.quarantine.contains_key(&pos) {
                self.quarantine.insert(
                    pos,
                    Backoff { wait: self.backoff_init, next_in: self.backoff_init },
                );
                fresh.push(pos);
                // a lane that died after its canary passed but before it
                // rejoined must not rejoin
                self.pending_join.retain(|&p| p != pos);
            }
        }

        // 2. degradation state machine with hysteresis
        if pressure >= 1.0 {
            self.over_ticks += 1;
            self.under_ticks = 0;
            if self.over_ticks >= self.overload_ticks && !self.degraded {
                self.degraded = true;
                self.pre_degraded = self.active.clone();
                plan.entered_degraded = true;
            }
        } else if pressure <= self.recover_pressure {
            self.under_ticks += 1;
            self.over_ticks = 0;
            if self.under_ticks >= self.recover_ticks && self.degraded {
                self.degraded = false;
                plan.left_degraded = true;
            }
        } else {
            // dead band: neither counter advances, neither resets the
            // state — the hysteresis gap itself
            self.over_ticks = 0;
            self.under_ticks = 0;
        }

        // 3. target membership for this tick
        let healthy = self.healthy(dead);
        let mut target: Vec<usize> = if self.degraded {
            // the floor, minus whatever of it is unhealthy; reinstated
            // lanes stay parked in `pending_join` until recovery — the
            // floor is the minimal set on purpose
            Self::intersect(&self.floor, &healthy)
        } else {
            let joins = std::mem::take(&mut self.pending_join);
            let mut t = if let Some(cand) = candidate {
                // composer pick, defensively re-filtered against health
                Self::intersect(cand, &healthy)
            } else if plan.left_degraded {
                // step back up to the pre-degraded membership (a later
                // recompose tick may refine it)
                Self::intersect(&std::mem::take(&mut self.pre_degraded), &healthy)
            } else {
                // steady state: keep the active set, shedding newly
                // unhealthy lanes
                Self::intersect(&self.active, &healthy)
            };
            t.extend(joins.into_iter().filter(|p| healthy.contains(p)));
            t
        };
        target.sort_unstable();
        target.dedup();
        if target.is_empty() {
            // every preferred lane is unhealthy: serve with whatever is
            // healthy at all rather than installing an empty set (an
            // empty membership is not installable); with nothing
            // healthy, keep the current set — queries fail fast on the
            // dead lanes until a canary heals one
            target = healthy.clone();
        }
        if !target.is_empty() && target != self.active {
            self.active = target.clone();
            plan.install = Some(target);
        }

        // 4. canary probe schedule: `backoff_init = N` means the first
        // probe fires N full ticks after the death tick (freshly
        // quarantined lanes skip this tick's countdown)
        for (&pos, b) in self.quarantine.iter_mut() {
            if fresh.contains(&pos) {
                continue;
            }
            if b.next_in > 0 {
                b.next_in -= 1;
            }
            if b.next_in == 0 {
                plan.probes.push(pos);
            }
        }

        plan
    }

    /// Report a canary outcome for a quarantined lane. `ok` means the
    /// canary batch executed *and* the lane was revived — the lane
    /// joins the membership at the next tick. A failure doubles the
    /// probe backoff (capped).
    pub fn probe_result(&mut self, pos: usize, ok: bool) {
        if ok {
            if self.quarantine.remove(&pos).is_some() {
                self.pending_join.push(pos);
            }
        } else if let Some(b) = self.quarantine.get_mut(&pos) {
            b.wait = (b.wait.saturating_mul(2)).min(self.backoff_max);
            b.next_in = b.wait;
        }
    }
}

/// Compute the degraded-mode floor: the smallest member set (greedy by
/// descending member validation AUC) whose *ensemble* validation
/// ROC-AUC clears `floor_acc`. Falls back to the full universe when no
/// prefix clears the bar (the floor must never be better than nothing).
/// `lane_models[pos]` maps lane positions to zoo model indices.
pub fn compute_floor(
    zoo: &Zoo,
    acc: &ValidationAccuracyProfiler,
    lane_models: &[usize],
    floor_acc: f64,
) -> Vec<usize> {
    let mut order: Vec<usize> = (0..lane_models.len()).collect();
    order.sort_by(|&a, &b| {
        zoo.model(lane_models[b]).val_auc.total_cmp(&zoo.model(lane_models[a]).val_auc)
    });
    let mut picked: Vec<usize> = Vec::new();
    for pos in order {
        picked.push(pos);
        let sel = Selector::from_indices(zoo.n(), picked.iter().map(|&p| lane_models[p]));
        if acc.accuracy(&sel).roc_auc >= floor_acc {
            picked.sort_unstable();
            return picked;
        }
    }
    (0..lane_models.len()).collect()
}

/// Latency profiler for live re-composition: the analytic queueing
/// model over *live* per-lane service times, restricted to the
/// pipeline's lane universe — any selector reaching outside it (the
/// composer explores the whole zoo) profiles as unservable (+∞), so
/// the search can never pick a model without a lane.
struct LaneLatencyProfiler {
    inner: AnalyticLatencyProfiler,
    /// Zoo model indices that have a healthy lane right now.
    allowed: Vec<usize>,
}

impl LatencyProfiler for LaneLatencyProfiler {
    fn latency(&self, b: &Selector, c: &SystemConfig) -> f64 {
        if b.indices().iter().any(|i| !self.allowed.contains(i)) {
            return f64::INFINITY;
        }
        self.inner.latency(b, c)
    }
}

/// The governor driver: owns the control thread. Dropping it stops the
/// loop and joins the thread; the held [`Pipeline`] clone is released
/// on drop, so a governor never keeps a pipeline alive past its owner's
/// intent — drop the governor *before* the last pipeline handle.
pub struct Governor {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    gauges: Arc<GovernorGauges>,
}

impl Governor {
    /// Spawn the control loop over `pipeline`. The zoo is cloned for
    /// the composer's live re-composition searches.
    pub fn spawn(zoo: &Zoo, pipeline: &Pipeline, cfg: GovernorConfig) -> Result<Governor> {
        let gauges = Arc::new(GovernorGauges::default());
        pipeline.telemetry().install_governor(Arc::clone(&gauges));

        let acc = ValidationAccuracyProfiler::from_zoo(zoo);
        let lane_models: Vec<usize> = pipeline.ensemble().indices().to_vec();
        let floor = compute_floor(zoo, &acc, &lane_models, cfg.floor_acc);
        let core = GovernorCore::new(lane_models.len(), floor, &cfg);

        gauges.active_members.store(lane_models.len(), Ordering::Relaxed);
        // seed the heartbeat's residency evidence for the initial (full)
        // membership before the first probe can observe this node
        let all_positions: Vec<usize> = (0..lane_models.len()).collect();
        publish_artifact_demand(pipeline, &lane_models, &all_positions);

        let stop = Arc::new(AtomicBool::new(false));
        let handle = {
            let stop = Arc::clone(&stop);
            let gauges = Arc::clone(&gauges);
            let pipeline = pipeline.clone();
            let zoo = zoo.clone();
            std::thread::Builder::new()
                .name("governor".into())
                .spawn(move || {
                    govern_loop(zoo, pipeline, cfg, acc, lane_models, core, gauges, stop)
                })
                .map_err(Error::Io)?
        };
        Ok(Governor { stop, handle: Some(handle), gauges })
    }

    pub fn gauges(&self) -> &Arc<GovernorGauges> {
        &self.gauges
    }
}

impl Drop for Governor {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Live tail-latency pressure: (T_q.p95 + T_s.p95) / SLO. ≥ 1.0 means
/// the observed queueing + execution tail is at or past the SLO.
fn read_pressure(pipeline: &Pipeline, slo: Duration) -> f64 {
    let t = pipeline.telemetry();
    let tail = t.queueing.percentile_fast(95.0) + t.exec.percentile_fast(95.0);
    tail / slo.as_secs_f64().max(1e-9)
}

/// Live per-model service times: the analytic MACs estimate as a prior,
/// overwritten per lane by the executor's measured per-item execution
/// EWMA wherever one exists — the "live latency profiles in place of
/// offline MACs estimates" half of the tentpole.
fn live_service_times(
    zoo: &Zoo,
    pipeline: &Pipeline,
    lane_models: &[usize],
) -> ServiceTimes {
    let mut times = ServiceTimes::from_macs(zoo, 5e-4, 2e10);
    let ewma = pipeline.executor().exec_ewma_gauges();
    for (pos, &model) in lane_models.iter().enumerate() {
        let ns = ewma[pos].load(Ordering::Relaxed);
        if ns > 0 {
            times.seconds[model] = ns as f64 / 1e9;
        }
    }
    times
}

/// One re-composition: search the (healthy) lane universe with the
/// composer, seeded with the current set, the floor, and the full
/// healthy universe; returns the winning membership (lane positions)
/// if it is valid — healthy, clearing the accuracy bar, finite latency.
#[allow(clippy::too_many_arguments)]
fn recompose(
    zoo: &Zoo,
    pipeline: &Pipeline,
    cfg: &GovernorConfig,
    acc: &ValidationAccuracyProfiler,
    lane_models: &[usize],
    active: &[usize],
    floor: &[usize],
    healthy: &[usize],
) -> Option<Vec<usize>> {
    if healthy.is_empty() {
        return None;
    }
    let to_selector = |positions: &[usize]| {
        Selector::from_indices(zoo.n(), positions.iter().map(|&p| lane_models[p]))
    };
    let lat = LaneLatencyProfiler {
        inner: AnalyticLatencyProfiler::new(live_service_times(zoo, pipeline, lane_models)),
        allowed: healthy.iter().map(|&p| lane_models[p]).collect(),
    };
    let composer_cfg = ComposerConfig {
        latency_budget: cfg.latency_budget,
        // live loop: a handful of cheap iterations per recompose tick —
        // the search runs every few hundred ms, not once offline
        iterations: 3,
        warm_start: 8,
        explore_samples: 32,
        top_k: 4,
        seed: 13,
        ..Default::default()
    };
    let composer = Composer::new(zoo, acc, &lat, composer_cfg, SystemConfig::default());
    let seeds = [to_selector(active), to_selector(floor), to_selector(healthy)];
    let best = composer.search(&seeds).best;
    // validity gate: the winner must be servable right now and clear
    // the accuracy bar (or at least the floor's own AUC, when the floor
    // itself could not reach the bar)
    let bar = cfg.floor_acc.min(acc.accuracy(&seeds[1]).roc_auc);
    if !best.latency.is_finite() || best.accuracy.roc_auc < bar {
        return None;
    }
    let model_to_pos: BTreeMap<usize, usize> =
        lane_models.iter().enumerate().map(|(pos, &m)| (m, pos)).collect();
    let mut positions = Vec::with_capacity(best.selector.len());
    for &model in best.selector.indices() {
        positions.push(*model_to_pos.get(&model)?);
    }
    if positions.is_empty() || positions.iter().any(|p| !healthy.contains(p)) {
        return None;
    }
    Some(positions)
}

/// Publish the artifact demand of a membership: resolve `positions` →
/// zoo models → the [`crate::registry::ArtifactId`] set every batch
/// variant needs, then stamp `artifacts_required` / `artifacts_resident`
/// into telemetry. Those two counters are what the heartbeat's
/// `"resident"` field is computed from, so this is the exact point where
/// a membership swap changes what the router demands of this node.
///
/// When no artifact store is installed (in-process pipelines, tests) the
/// zoo on local disk *is* the artifact source, so residency is trivially
/// complete and the node must not advertise itself cold.
fn publish_artifact_demand(pipeline: &Pipeline, lane_models: &[usize], positions: &[usize]) {
    use crate::registry::Registry;
    let models: Vec<usize> = positions.iter().map(|&p| lane_models[p]).collect();
    let ids = pipeline.executor().engine().artifact_catalog().ids_for_models(&models);
    let telemetry = pipeline.telemetry();
    let required = ids.len() as u64;
    let resident = match telemetry.artifact_store() {
        Some(store) => ids.iter().filter(|&&id| store.has(id)).count() as u64,
        None => required,
    };
    telemetry.artifacts_required.store(required, Ordering::Relaxed);
    telemetry.artifacts_resident.store(resident, Ordering::Relaxed);
}

/// Fire one canary at a quarantined lane: execute a single-query batch
/// directly on the engine (bypassing the dead lane), and — only if the
/// backend answers — revive the lane. Returns whether the lane is back.
fn canary(pipeline: &Pipeline, lane_models: &[usize], pos: usize) -> bool {
    let executor = pipeline.executor();
    let engine = executor.engine();
    let batch = engine.batch_for(1);
    let input = vec![0.25f32; batch * pipeline.clip_len()];
    let ok = engine.execute_blocking((lane_models[pos], batch), input).is_ok();
    ok && executor.revive_lane(pos)
}

#[allow(clippy::too_many_arguments)]
fn govern_loop(
    zoo: Zoo,
    pipeline: Pipeline,
    cfg: GovernorConfig,
    acc: ValidationAccuracyProfiler,
    lane_models: Vec<usize>,
    mut core: GovernorCore,
    gauges: Arc<GovernorGauges>,
    stop: Arc<AtomicBool>,
) {
    let mut tick_no: u64 = 0;
    while !stop.load(Ordering::Relaxed) {
        let tick_started = Instant::now();
        let dead = pipeline.executor().dead_lanes();
        let pressure = read_pressure(&pipeline, cfg.slo);

        // re-composition on every Nth tick, skipped while degraded (the
        // floor IS the degraded answer; searching would fight it)
        let candidate = if !core.degraded()
            && cfg.recompose_every > 0
            && tick_no % u64::from(cfg.recompose_every) == 0
            && tick_no > 0
        {
            let healthy: Vec<usize> = (0..lane_models.len())
                .filter(|&p| !dead[p] && !core.quarantined().contains(&p))
                .collect();
            recompose(
                &zoo,
                &pipeline,
                &cfg,
                &acc,
                &lane_models,
                core.active(),
                core.floor(),
                &healthy,
            )
        } else {
            None
        };

        let plan = core.on_tick(pressure, &dead, candidate.as_deref());

        if plan.entered_degraded {
            gauges.degraded.store(1, Ordering::Relaxed);
            gauges.degraded_entered.fetch_add(1, Ordering::Relaxed);
        }
        if plan.left_degraded {
            gauges.degraded.store(0, Ordering::Relaxed);
        }
        if let Some(positions) = plan.install.as_deref() {
            match pipeline.install_membership(positions) {
                Ok(set) => {
                    gauges.epoch.store(set.epoch(), Ordering::Relaxed);
                    gauges.active_members.store(set.len(), Ordering::Relaxed);
                    gauges.swaps.fetch_add(1, Ordering::Relaxed);
                    // the member set changed, so the artifact demand
                    // advertised on heartbeats changes with it
                    publish_artifact_demand(&pipeline, &lane_models, positions);
                }
                Err(_) => break, // pipeline shut down under us
            }
        }
        for &pos in &plan.probes {
            gauges.probes.fetch_add(1, Ordering::Relaxed);
            let ok = canary(&pipeline, &lane_models, pos);
            if ok {
                gauges.reinstated.fetch_add(1, Ordering::Relaxed);
            }
            core.probe_result(pos, ok);
        }
        gauges.quarantined.store(core.quarantined().len(), Ordering::Relaxed);

        tick_no += 1;
        // sleep out the remainder of the tick in short slices so a stop
        // request (drop) is honoured within ~a millisecond
        let elapsed = tick_started.elapsed();
        let mut left = cfg.tick.saturating_sub(elapsed);
        while !left.is_zero() && !stop.load(Ordering::Relaxed) {
            let nap = left.min(Duration::from_millis(1));
            std::thread::sleep(nap);
            left = left.saturating_sub(nap);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo::testkit::toy_zoo_with;

    fn cfg() -> GovernorConfig {
        GovernorConfig::default()
    }

    #[test]
    fn overload_steps_down_within_bounded_ticks_and_recovers_with_hysteresis() {
        let c = cfg();
        let mut core = GovernorCore::new(4, vec![0, 1], &c);
        let dead = vec![false; 4];
        // one over-pressure tick: not yet (overload_ticks = 2)
        let p1 = core.on_tick(1.5, &dead, None);
        assert!(!p1.entered_degraded && p1.install.is_none());
        // second: degrade to the floor
        let p2 = core.on_tick(1.5, &dead, None);
        assert!(p2.entered_degraded);
        assert_eq!(p2.install.as_deref(), Some(&[0, 1][..]));
        assert!(core.degraded());
        // pressure in the dead band (0.7 < p < 1.0): stays degraded
        for _ in 0..10 {
            let p = core.on_tick(0.85, &dead, None);
            assert!(p.install.is_none() && !p.left_degraded);
        }
        // recovery needs `recover_ticks` consecutive clean ticks
        for i in 0..c.recover_ticks - 1 {
            let p = core.on_tick(0.1, &dead, None);
            assert!(!p.left_degraded, "tick {i} must not yet recover");
        }
        let p = core.on_tick(0.1, &dead, None);
        assert!(p.left_degraded);
        assert_eq!(p.install.as_deref(), Some(&[0, 1, 2, 3][..]));
        assert!(!core.degraded());
    }

    #[test]
    fn recovery_counter_resets_on_pressure_spike() {
        let c = cfg();
        let mut core = GovernorCore::new(2, vec![0], &c);
        let dead = vec![false; 2];
        core.on_tick(2.0, &dead, None);
        let p = core.on_tick(2.0, &dead, None);
        assert!(p.entered_degraded);
        // three clean ticks, then a spike: the clean streak must restart
        for _ in 0..3 {
            core.on_tick(0.1, &dead, None);
        }
        core.on_tick(1.2, &dead, None);
        for _ in 0..c.recover_ticks - 1 {
            assert!(!core.on_tick(0.1, &dead, None).left_degraded);
        }
        assert!(core.on_tick(0.1, &dead, None).left_degraded);
    }

    #[test]
    fn dead_lane_quarantined_probed_with_exponential_backoff_and_reinstated() {
        let c = cfg(); // backoff_init 2, max 32
        let mut core = GovernorCore::new(3, vec![0], &c);
        let mut dead = vec![false; 3];
        dead[1] = true;
        // death tick: lane 1 leaves the membership at once, no probe yet
        let p = core.on_tick(0.1, &dead, None);
        assert_eq!(p.install.as_deref(), Some(&[0, 2][..]));
        assert_eq!(core.quarantined(), vec![1]);
        assert!(p.probes.is_empty());
        // backoff 2: the probe fires on the second tick after death
        assert!(core.on_tick(0.1, &dead, None).probes.is_empty());
        let p = core.on_tick(0.1, &dead, None);
        assert_eq!(p.probes, vec![1]);
        // failed canary: wait doubles to 4
        core.probe_result(1, false);
        for i in 0..3 {
            assert!(core.on_tick(0.1, &dead, None).probes.is_empty(), "tick {i}");
        }
        let p = core.on_tick(0.1, &dead, None);
        assert_eq!(p.probes, vec![1]);
        // successful canary: the lane heals (flag cleared by revive) and
        // rejoins at the next tick
        dead[1] = false;
        core.probe_result(1, true);
        let p = core.on_tick(0.1, &dead, None);
        assert_eq!(p.install.as_deref(), Some(&[0, 1, 2][..]));
        assert!(core.quarantined().is_empty());
    }

    #[test]
    fn backoff_caps_at_configured_max() {
        let mut c = cfg();
        c.backoff_init_ticks = 2;
        c.backoff_max_ticks = 4;
        let mut core = GovernorCore::new(2, vec![0], &c);
        let mut dead = vec![false; 2];
        dead[1] = true;
        core.on_tick(0.1, &dead, None);
        // drive to the first probe, fail it thrice: wait 2 → 4 → 4
        for want_wait in [2u32, 4, 4] {
            let mut ticks = 0;
            loop {
                ticks += 1;
                if !core.on_tick(0.1, &dead, None).probes.is_empty() {
                    break;
                }
                assert!(ticks < 10, "probe must fire within the cap");
            }
            assert_eq!(ticks, want_wait, "probe cadence follows capped backoff");
            core.probe_result(1, false);
        }
    }

    #[test]
    fn degraded_floor_sheds_unhealthy_floor_lanes() {
        let c = cfg();
        let mut core = GovernorCore::new(4, vec![0, 1], &c);
        let mut dead = vec![false; 4];
        dead[0] = true; // half the floor is dead
        core.on_tick(2.0, &dead, None);
        let p = core.on_tick(2.0, &dead, None);
        assert!(p.entered_degraded);
        assert_eq!(p.install.as_deref(), Some(&[1][..]), "floor ∩ healthy");
    }

    #[test]
    fn all_preferred_dead_falls_back_to_any_healthy_lane() {
        let c = cfg();
        let mut core = GovernorCore::new(3, vec![0], &c);
        let mut dead = vec![false; 3];
        dead[0] = true;
        dead[1] = true;
        core.on_tick(2.0, &dead, None);
        let p = core.on_tick(2.0, &dead, None);
        // floor lane 0 is dead: serve with the only healthy lane left
        assert_eq!(p.install.as_deref(), Some(&[2][..]));
    }

    #[test]
    fn candidate_applies_only_when_not_degraded() {
        let c = cfg();
        let mut core = GovernorCore::new(4, vec![0], &c);
        let dead = vec![false; 4];
        let p = core.on_tick(0.1, &dead, Some(&[1, 2]));
        assert_eq!(p.install.as_deref(), Some(&[1, 2][..]));
        // degrade; a candidate while degraded must not override the floor
        core.on_tick(2.0, &dead, None);
        let p = core.on_tick(2.0, &dead, Some(&[1, 2, 3]));
        assert!(p.entered_degraded);
        assert_eq!(p.install.as_deref(), Some(&[0][..]));
    }

    #[test]
    fn floor_is_smallest_prefix_clearing_the_bar() {
        let zoo = toy_zoo_with(6, 64, 7, 16, &[1, 8]);
        let acc = ValidationAccuracyProfiler::from_zoo(&zoo);
        let lane_models: Vec<usize> = (0..zoo.n()).collect();
        // a bar below the best single member: the floor is one lane
        let best_single = (0..zoo.n())
            .map(|i| {
                acc.accuracy(&Selector::from_indices(zoo.n(), [i])).roc_auc
            })
            .fold(f64::MIN, f64::max);
        let floor = compute_floor(&zoo, &acc, &lane_models, best_single - 0.05);
        assert_eq!(floor.len(), 1);
        // an unreachable bar: the floor degrades to the full universe
        let floor = compute_floor(&zoo, &acc, &lane_models, 1.01);
        assert_eq!(floor, lane_models);
    }
}
