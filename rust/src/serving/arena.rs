//! Pooled window arenas: recycled lead buffers for the shard plane.
//!
//! The pre-pool aggregation plane paid three heap round-trips per
//! emitted window and per lead: a fresh `Vec<f32>` to collect into
//! (re-grown after every `mem::take`), an `Arc<[f32]>` allocation to
//! share it, and a full `clip_len` copy between the two. At the paper's
//! 64-bed / ΔT = 10 s working point that is ~19 windows/s × 3 leads of
//! ~10 KB churn — all of it avoidable, because a lead buffer becomes
//! reusable the instant the last batcher drops its reference.
//!
//! This module replaces that cycle with a **per-shard slab**:
//!
//! * [`LeadPool`] — a per-shard free list of fixed-size sample buffers
//!   (`Box<[f32]>`, one observation window each). Shards own one pool
//!   apiece, so the free list is touched by the shard thread (get) and
//!   by whichever data-plane thread drops the last lease (put) — never
//!   by other shards.
//! * [`LeadSlot`] — the *exclusive, writable* stage of a buffer's life:
//!   the aggregator fills samples in place through a plain `&mut [f32]`
//!   (no atomics on the 250 Hz push path). Not cloneable by
//!   construction, so sharing cannot begin before the window is sealed.
//! * [`WindowLease`] — the *shared, read-only* stage: created by
//!   [`LeadSlot::share`] when the window completes, cloned by the
//!   router to every ensemble member (reference fan-out, no copies),
//!   and `Deref<Target = [f32]>` everywhere a slice is expected. When
//!   the **last** clone drops — typically on a batcher worker after the
//!   batch is packed — the sample buffer returns to its pool.
//!
//! The last-drop handoff is [`Arc::into_inner`]: exactly one dropping
//! thread receives the buffer back, race-free, with no refcount
//! protocol of our own. Steady state, the only per-window allocation
//! left is the lease's small `Arc` control block; the sample payload
//! (the part proportional to `clip_len`) never touches the allocator
//! again. Load generators and tests that build windows from owned
//! vectors use [`WindowLease::from_vec`], which behaves identically but
//! simply frees on last drop (no pool).
//!
//! Pooling is invisible to the serving semantics: a buffer is fully
//! overwritten (every index `0..samples`) before it is ever shared, so
//! recycled contents cannot leak into a window, and the determinism
//! tests in `tests/executor.rs` prove pooled and fresh buffers produce
//! bit-for-bit identical ensemble scores.

use std::sync::{Arc, Mutex, Weak};

/// Default free-list bound per pool: buffers returned beyond this are
/// simply freed. 256 windows ≈ 2.5 MB at the paper's 2 500-sample clip
/// — ample for the in-flight depth of one shard's pipeline while
/// keeping a burst from pinning memory forever.
pub const DEFAULT_POOL_CAP: usize = 256;

struct PoolInner {
    free: Mutex<Vec<Box<[f32]>>>,
    samples: usize,
    cap: usize,
    reused: std::sync::atomic::AtomicU64,
    allocated: std::sync::atomic::AtomicU64,
}

/// Per-shard slab of recyclable lead buffers. Cheap to clone (handle).
#[derive(Clone)]
pub struct LeadPool {
    inner: Arc<PoolInner>,
}

impl LeadPool {
    /// Pool of `samples`-long buffers with the default free-list cap.
    pub fn new(samples: usize) -> Self {
        Self::with_cap(samples, DEFAULT_POOL_CAP)
    }

    pub fn with_cap(samples: usize, cap: usize) -> Self {
        assert!(samples > 0, "a lead window has at least one sample");
        LeadPool {
            inner: Arc::new(PoolInner {
                free: Mutex::new(Vec::new()),
                samples,
                cap,
                reused: std::sync::atomic::AtomicU64::new(0),
                allocated: std::sync::atomic::AtomicU64::new(0),
            }),
        }
    }

    /// Samples per buffer (= the zoo's `clip_len`).
    pub fn samples(&self) -> usize {
        self.inner.samples
    }

    /// Take an exclusive, writable buffer: recycled when the free list
    /// has one, freshly allocated (and counted) otherwise.
    pub fn slot(&self) -> LeadSlot {
        use std::sync::atomic::Ordering;
        let recycled = self.inner.free.lock().expect("lead pool poisoned").pop();
        let data = match recycled {
            Some(buf) => {
                self.inner.reused.fetch_add(1, Ordering::Relaxed);
                buf
            }
            None => {
                self.inner.allocated.fetch_add(1, Ordering::Relaxed);
                vec![0.0f32; self.inner.samples].into_boxed_slice()
            }
        };
        LeadSlot { data, pool: Some(Arc::downgrade(&self.inner)) }
    }

    /// Buffers handed out from the free list so far.
    pub fn reused(&self) -> u64 {
        self.inner.reused.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Buffers that had to be freshly allocated.
    pub fn allocated(&self) -> u64 {
        self.inner.allocated.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Buffers currently parked on the free list.
    pub fn free_len(&self) -> usize {
        self.inner.free.lock().expect("lead pool poisoned").len()
    }
}

impl PoolInner {
    fn put(&self, buf: Box<[f32]>) {
        debug_assert_eq!(buf.len(), self.samples);
        let mut free = self.free.lock().expect("lead pool poisoned");
        if free.len() < self.cap {
            free.push(buf);
        } // else: drop — the cap bounds parked memory
    }
}

/// Shared payload of a sealed window lease: the sample buffer plus the
/// pool (if any) it returns to on last drop.
struct LeadBuf {
    data: Box<[f32]>,
    /// Weak so a lease outliving its shard (pipeline drain after the
    /// shard plane exits) frees instead of resurrecting the pool.
    pool: Option<Weak<PoolInner>>,
}

/// Exclusive, writable stage of a lead buffer (aggregator-side). Fill
/// through [`LeadSlot::as_mut_slice`], then [`LeadSlot::share`] to seal
/// the window. Dropping an unshared slot also returns the buffer.
pub struct LeadSlot {
    data: Box<[f32]>,
    pool: Option<Weak<PoolInner>>,
}

impl LeadSlot {
    /// Pool-less slot over an owned zeroed buffer (tests, aggregators
    /// constructed without a shard pool).
    pub fn zeroed(samples: usize) -> Self {
        LeadSlot { data: vec![0.0f32; samples].into_boxed_slice(), pool: None }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Plain mutable access — the hot 250 Hz sample-push path; no
    /// atomics, no capacity checks beyond the slice bound.
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Seal the window: the buffer becomes a shared read-only lease the
    /// router can fan out to every ensemble member by reference.
    pub fn share(mut self) -> WindowLease {
        // Empty the slot before it drops: its Drop sees a taken pool and
        // a zero-length buffer and no-ops, so the buffer is returned (or
        // freed) exactly once — by the lease's last clone.
        let data = std::mem::take(&mut self.data);
        let pool = self.pool.take();
        WindowLease { buf: Some(Arc::new(LeadBuf { data, pool })) }
    }
}

impl Drop for LeadSlot {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take().and_then(|w| w.upgrade()) {
            pool.put(std::mem::take(&mut self.data));
        }
    }
}

/// Shared, read-only lease on one lead window. Clones are reference
/// fan-outs; the sample buffer returns to its pool when the last clone
/// drops. `Deref<Target = [f32]>` — use it anywhere a slice is read.
#[derive(Clone)]
pub struct WindowLease {
    /// `Option` purely so `Drop` can move the `Arc` out.
    buf: Option<Arc<LeadBuf>>,
}

impl WindowLease {
    /// Lease over an owned vector (load generators, tests,
    /// [`share_leads`](super::pipeline::share_leads)): shared exactly
    /// like a pooled lease, freed (not pooled) on last drop.
    pub fn from_vec(v: Vec<f32>) -> Self {
        WindowLease {
            buf: Some(Arc::new(LeadBuf { data: v.into_boxed_slice(), pool: None })),
        }
    }

    fn data(&self) -> &[f32] {
        &self.buf.as_ref().expect("lease not yet dropped").data
    }
}

impl std::ops::Deref for WindowLease {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        self.data()
    }
}

impl Drop for WindowLease {
    fn drop(&mut self) {
        let Some(arc) = self.buf.take() else { return };
        // exactly one dropping thread gets the payload back (the others
        // see None) — the race-free last-drop hook Arc provides for free
        if let Some(core) = Arc::into_inner(arc) {
            if let Some(pool) = core.pool.as_ref().and_then(Weak::upgrade) {
                pool.put(core.data);
            }
        }
    }
}

impl std::fmt::Debug for LeadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LeadPool")
            .field("samples", &self.inner.samples)
            .field("free", &self.free_len())
            .field("reused", &self.reused())
            .field("allocated", &self.allocated())
            .finish()
    }
}

impl std::fmt::Debug for WindowLease {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WindowLease").field("len", &self.data().len()).finish()
    }
}

impl std::fmt::Debug for LeadSlot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LeadSlot")
            .field("len", &self.data.len())
            .field("pooled", &self.pool.is_some())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_fill_share_read_roundtrip() {
        let pool = LeadPool::new(4);
        let mut slot = pool.slot();
        slot.as_mut_slice().copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let lease = slot.share();
        assert_eq!(&lease[..], &[1.0, 2.0, 3.0, 4.0]);
        let clone = lease.clone();
        assert_eq!(clone[2], 3.0);
    }

    #[test]
    fn buffer_returns_to_pool_on_last_drop_only() {
        let pool = LeadPool::new(8);
        let lease = pool.slot().share();
        let clone = lease.clone();
        drop(lease);
        assert_eq!(pool.free_len(), 0, "a live clone must keep the buffer out");
        drop(clone);
        assert_eq!(pool.free_len(), 1, "last drop returns the buffer");
        // and the next slot reuses it instead of allocating
        let _s = pool.slot();
        assert_eq!(pool.reused(), 1);
        assert_eq!(pool.allocated(), 1);
        assert_eq!(pool.free_len(), 0);
    }

    #[test]
    fn unshared_slot_drop_returns_buffer() {
        let pool = LeadPool::new(8);
        drop(pool.slot());
        assert_eq!(pool.free_len(), 1);
    }

    #[test]
    fn free_list_cap_bounds_parked_buffers() {
        let pool = LeadPool::with_cap(2, 1);
        let (a, b) = (pool.slot().share(), pool.slot().share());
        drop(a);
        drop(b);
        assert_eq!(pool.free_len(), 1, "over-cap returns are freed, not parked");
    }

    #[test]
    fn owned_lease_has_no_pool() {
        let lease = WindowLease::from_vec(vec![0.5; 3]);
        assert_eq!(lease.len(), 3);
        drop(lease.clone());
        drop(lease); // frees — nothing to assert beyond not crashing
    }

    #[test]
    fn lease_outliving_pool_frees_cleanly() {
        let pool = LeadPool::new(2);
        let lease = pool.slot().share();
        drop(pool);
        drop(lease); // weak upgrade fails → plain free
    }

    #[test]
    fn recycled_buffer_is_fully_overwritable() {
        let pool = LeadPool::new(3);
        let mut s = pool.slot();
        s.as_mut_slice().copy_from_slice(&[9.0, 9.0, 9.0]);
        drop(s.share());
        let mut s2 = pool.slot();
        // the aggregator overwrites every index before sharing; prove
        // the full range is writable and reads back what was written
        for (i, v) in s2.as_mut_slice().iter_mut().enumerate() {
            *v = i as f32;
        }
        assert_eq!(&s2.share()[..], &[0.0, 1.0, 2.0]);
    }
}
