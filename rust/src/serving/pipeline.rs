//! The ensemble serving pipeline: router + a **work-stealing model
//! executor** with **direct, collector-less completion** (Fig. 4).
//!
//! ## Data-plane architecture (zero-copy, lock-free, thread-count ∝ hardware)
//!
//! ```text
//!  Pipeline handles ──messages──► router thread ──items──► model lanes (one
//!        │  Query | Install(E+1)     │ membership epoch E     per UNIVERSE
//!        │                           │ (channel FIFO orders   member: lock-free
//!        │ leads: [WindowLease; 3]   │  hot swaps vs          injection queue +
//!        │ (pooled buffers, shared   │  admissions; fan out   flush deadline +
//!        │  by reference, recycled   │  to E's lanes only)    dead flag)
//!        │  on last drop)            ▼                            │ claim ready
//!        │                     pending slot arena                 ▼ lane (CAS)
//!        │                     (preallocated, generation-  ┌────────────────────┐
//!        │                     tagged; per-query MemberSet │ executor pool:     │
//!        │                     + atomic remaining +        │ --workers threads, │
//!        │                     per-member score cells)     │ each: persistent   │
//!        │                         ▲                       │ 64B-aligned arena, │
//!        │                         │ Completer::score      │ inline ExecBackend │
//!        │                         │ (atomic cell write;   │ DirectWorker under │
//!        │                         │ last member of the    │ n_gpus device      │
//!        ▼                         │ query's OWN epoch     │ permits            │
//!      reply rx ◄──────────────── finishes the slot INLINE └──▲────────▲────────┘
//!        │                                                    │ fill   │ revive/
//!        │ T_q/T_s percentiles                                │ dead-  │ canary
//!        ▼ (live: bucket-derived)                             │ line   │
//!   telemetry ────────────┬───────────────────────────────────┴─┐   ┌──┴───────┐
//!        ▲ queue depths,  │ DeadlineController (--adaptive-batch│   │ Governor │
//!        │ dead lanes,    │ --slo-ms): wait ∈ [min, max] from   │   │(--govern)│
//!        │ exec EWMA      │ SLO headroom × lane fill level      │   └──┬───────┘
//!        └────────────────┴─────────────────────────────────────┘      │
//!        └───────── live pressure + lane health + latency profiles ────┘
//!                   (recompose via Composer::search → Install, degrade
//!                    to the accuracy floor, quarantine/reinstate lanes)
//! ```
//!
//! * **Zero-copy, pooled windows** — the aggregator fills recycled lead
//!   buffers from its shard's [`LeadPool`](super::arena::LeadPool) and
//!   seals them into shared [`WindowLease`]s; the router hands every
//!   ensemble member a reference, the only copy on the plane is the
//!   single slot-write into a worker's aligned batch arena, and the
//!   buffer returns to its pool when the last lane drops it.
//! * **Work-stealing execution** — models no longer own threads. Each
//!   member has a *lane* (lock-free injection queue + fill deadline);
//!   a fixed pool of workers ([`PipelineConfig::workers`], core-count
//!   default) claims whichever lane has a due batch, packs it, executes
//!   inline through a [`DirectWorker`](crate::runtime::DirectWorker)
//!   (device parallelism still bounded by the engine's GPU-count
//!   permits), and completes the slots. Thread count is a hardware
//!   tunable, not a function of ensemble size — 16 models on 2 workers
//!   spawn 2 threads, not 16. See [`super::executor`].
//! * **SLO-aware fill deadlines** — each lane's batch fill window is
//!   armed by a [`super::control::DeadlineController`]
//!   (`--adaptive-batch`): bounded to
//!   `[timeout_min, timeout_max]`, shrinking toward immediate flush as
//!   queue depth grows or the observed T_q+T_s tail approaches the
//!   configured SLO (`--slo-ms`, default 1000), relaxing toward the cap
//!   under trickle load. Off by default — the static
//!   [`BatchPolicy::timeout`] then applies verbatim. Deadlines decide
//!   *when* a batch flushes, never how scores combine, so the
//!   bit-invariance guarantees below are unaffected. See
//!   [`super::control`].
//! * **Lock-free pending slots** — per-query bagging state lives in a
//!   preallocated arena of [`PENDING_SLOTS`] generation-tagged slots
//!   (`query_id & (PENDING_SLOTS-1)` picks the slot, `query_id + 1` is
//!   its generation tag). The router claims a slot with one CAS,
//!   executor workers update `remaining` and per-member score cells
//!   with atomics, and eviction is a CAS on the tag — no two threads
//!   ever block each other, even on the same query. See
//!   [`PendingSlots`] for the full protocol.
//! * **Collector-less completion** — there is no collector thread and
//!   no report channel: workers resolve items through each lane's
//!   [`Completer`], and whichever worker records the last outstanding
//!   member runs `finish()` (bagging mean, telemetry, reply delivery)
//!   inline. No single thread touches every score.
//! * **Membership epochs (hot swap)** — the router channel carries
//!   `Install` messages alongside queries, so a membership change is
//!   FIFO-ordered against admissions: every query admitted under epoch
//!   E fans out to, waits for, and is averaged over exactly E's member
//!   set (the [`MemberSet`] travels with the query in its pending
//!   slot), while the next admission already runs under E+1. No
//!   in-flight query is dropped, rescored, or re-averaged by a swap —
//!   [`Pipeline::install_membership`] returns only after the router
//!   has applied the new set. The universe of lanes is fixed at spawn
//!   (`cfg.ensemble`); epochs select a subset.
//! * **Deterministic bagging** — each member's score is written once
//!   into its own cell and the cells are summed in model-index order at
//!   completion (over the query's own member set), so a query's
//!   ensemble score is bit-for-bit identical regardless of batch
//!   composition, arrival order, worker count, which thread completes
//!   the slot, or when a swap landed relative to other queries
//!   (`tests/executor.rs`, `tests/governor.rs`).
//! * **Failure eviction** — when a member cannot score a query (engine
//!   error, dead lane), the slot is reclaimed via a tag CAS and the
//!   caller's reply channel drops, so `submit()` callers fail fast
//!   instead of leaking slots with `remaining > 0` forever.
//!
//! Shutdown is acyclic: dropping the last `Pipeline` handle closes the
//! query channel → the router exits and drops the lane sender → the
//! executor workers flush every lane's final partial batch and exit →
//! dropping the pipeline's executor handle joins them. No thread
//! outlives the pipeline.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::arena::WindowLease;
use super::batcher::{BatchItem, BatchPolicy};
use super::control::DEFAULT_SLO;
use super::executor::{Executor, LaneSender};
use super::telemetry::{ExecutorGauges, Telemetry};
use crate::runtime::Engine;
use crate::zoo::{Selector, Zoo};
use crate::{Error, Result};

/// Number of preallocated pending slots (power of two; a query lives in
/// slot `query_id & (PENDING_SLOTS - 1)`). Also the in-flight admission
/// bound: if the query that used a slot `PENDING_SLOTS` ids ago has not
/// completed yet, the router briefly yields instead of growing memory.
pub const PENDING_SLOTS: usize = 1024;

/// Move a triple of freshly collected lead windows into shared storage:
/// one lease per lead, after which every ensemble member borrows the
/// same samples (load generators and tests; the aggregation plane gets
/// its leases from the per-shard pools instead).
pub fn share_leads(leads: [Vec<f32>; 3]) -> [WindowLease; 3] {
    let [a, b, c] = leads;
    [WindowLease::from_vec(a), WindowLease::from_vec(b), WindowLease::from_vec(c)]
}

/// One ensemble query: a synchronized multi-lead observation window.
/// Leads are reference-counted leases shared across the whole data
/// plane — cloning a `Query` never copies samples, and pooled lease
/// buffers recycle when the last holder drops them.
#[derive(Debug, Clone)]
pub struct Query {
    pub patient: usize,
    pub window_id: u64,
    pub sim_end: f64,
    pub leads: [WindowLease; 3],
    /// Wall-clock emission instant (set by the aggregator).
    pub emitted: Instant,
}

impl Query {
    pub fn from_window(w: super::aggregator::WindowData) -> Self {
        Query {
            patient: w.patient,
            window_id: w.window_id,
            sim_end: w.sim_end,
            leads: w.leads,
            emitted: Instant::now(),
        }
    }

    /// Build a query from owned lead vectors (load generators, tests).
    pub fn from_vecs(patient: usize, window_id: u64, sim_end: f64, leads: [Vec<f32>; 3]) -> Self {
        Query {
            patient,
            window_id,
            sim_end,
            leads: share_leads(leads),
            emitted: Instant::now(),
        }
    }
}

/// Bagging-ensemble prediction (Eq. 5) with latency breakdown.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub patient: usize,
    pub window_id: u64,
    pub sim_end: f64,
    /// Mean probability over the ensemble members (summed in
    /// model-index order — deterministic across batchings).
    pub score: f64,
    pub n_models: usize,
    /// End-to-end: emission → all members scored (T_q + T_s).
    pub e2e: Duration,
    /// Min model queue-wait ≈ the queueing component T_q.
    pub queueing: Duration,
}

/// Receiver for one query's prediction (oneshot semantics).
pub type PredictionRx = mpsc::Receiver<Prediction>;

/// Pipeline construction parameters.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub ensemble: Selector,
    pub policy: BatchPolicy,
    /// Executor pool size; 0 = core-count default capped by the
    /// engine's device permits
    /// ([`super::executor::default_workers_for`]). Independent of the
    /// ensemble size by design.
    pub workers: usize,
    /// End-to-end latency SLO the adaptive deadline controller steers
    /// against (`--slo-ms`; [`DEFAULT_SLO`] = the paper's 1000 ms).
    /// Only consulted when `policy.adaptive` is set.
    pub slo: Duration,
}

impl PipelineConfig {
    pub fn new(ensemble: Selector) -> Self {
        PipelineConfig {
            ensemble,
            policy: BatchPolicy::default(),
            workers: 0,
            slo: DEFAULT_SLO,
        }
    }

    pub fn with_policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    pub fn with_slo(mut self, slo: Duration) -> Self {
        self.slo = slo;
        self
    }
}

// ---------------------------------------------------------------------------
// Membership epochs
// ---------------------------------------------------------------------------

/// One ensemble-membership epoch: the subset of executor lanes (member
/// positions in model-index order, ascending) that score the queries
/// admitted while the epoch is current. Epoch 0 is the spawn-time full
/// universe; each [`Pipeline::install_membership`] applied by the
/// router creates the next one. A query carries its admission epoch's
/// `Arc<MemberSet>` in its pending slot, so a hot swap never touches a
/// query already in flight.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemberSet {
    epoch: u64,
    /// Sorted ascending, deduplicated — the deterministic summation
    /// order for the bagging mean.
    positions: Vec<usize>,
}

impl MemberSet {
    /// Build a member set; positions are sorted and deduplicated (must
    /// be non-empty after dedup).
    pub fn new(epoch: u64, mut positions: Vec<usize>) -> Self {
        positions.sort_unstable();
        positions.dedup();
        assert!(!positions.is_empty(), "a member set has at least one lane");
        MemberSet { epoch, positions }
    }

    /// Epoch 0: every lane of an `n_lanes` universe.
    pub fn full(n_lanes: usize) -> Self {
        Self::new(0, (0..n_lanes).collect())
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Member lane positions, ascending.
    pub fn positions(&self) -> &[usize] {
        &self.positions
    }

    pub fn len(&self) -> usize {
        self.positions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.positions.is_empty()
    }

    pub fn contains(&self, pos: usize) -> bool {
        self.positions.binary_search(&pos).is_ok()
    }
}

/// What the pipeline handles send the router: a query to admit, or a
/// membership epoch to install. One channel for both is the whole
/// determinism story — swaps are FIFO-ordered against admissions, so
/// "admitted under epoch E" is defined by channel order alone, not by
/// thread timing.
enum RouterMsg {
    Query(Query, Option<mpsc::SyncSender<Prediction>>),
    Install { positions: Vec<usize>, ack: mpsc::SyncSender<Arc<MemberSet>> },
}

// ---------------------------------------------------------------------------
// Lock-free pending slot arena
// ---------------------------------------------------------------------------

/// Query metadata carried through a pending slot (everything the
/// completing thread needs to build the [`Prediction`]).
pub struct PendingMeta {
    pub patient: usize,
    pub window_id: u64,
    pub sim_end: f64,
    pub emitted: Instant,
    pub reply: Option<mpsc::SyncSender<Prediction>>,
}

/// What [`PendingSlots::score`] observed.
pub enum ScoreOutcome {
    /// No live generation for this query id (never inserted, already
    /// completed, or evicted) — the report is dropped.
    Absent,
    /// The score was recorded; other members are still outstanding.
    Accepted,
    /// This report was the last one: the caller now owns the completed
    /// query state and must deliver the prediction.
    Completed(CompletedQuery),
}

/// A fully scored query, handed to exactly one caller by
/// [`PendingSlots::score`].
pub struct CompletedQuery {
    pub meta: PendingMeta,
    /// Σ member scores, accumulated in model-index (cell) order over
    /// the query's own member set — the deterministic bagging
    /// numerator.
    pub score_sum: f64,
    /// Members of the query's admission epoch — the bagging
    /// denominator (a hot swap never changes it retroactively).
    pub n_members: usize,
    pub min_queue_wait: Duration,
}

/// Generation tag of a free slot.
const TAG_FREE: u64 = 0;
/// Transient tag while one thread owns the slot exclusively (router
/// filling it in, or the completer/evictor tearing it down).
const TAG_BUSY: u64 = u64::MAX;

/// One preallocated pending slot. The `tag` is the linearization point:
/// `query_id + 1` while the query is live, [`TAG_FREE`] when the slot
/// can be claimed, [`TAG_BUSY`] while exactly one thread owns it.
struct Slot {
    tag: AtomicU64,
    /// Score reporters currently inside their (write cell → decrement
    /// `remaining`) critical section. A slot is only recycled once this
    /// drains to zero, so a reporter can never write into the next
    /// generation's state.
    writers: AtomicU32,
    /// Members still outstanding for the live generation.
    remaining: AtomicU32,
    /// Min queue wait across members, nanoseconds (CAS-min).
    min_wait_ns: AtomicU64,
    /// One score cell per ensemble member, f32 bits, each written
    /// exactly once per generation; summed in cell (= model-index)
    /// order by the completer for deterministic bagging.
    scores: Box<[AtomicU32]>,
    /// Guarded by the tag protocol: only the thread that holds the
    /// `TAG_BUSY` claim touches this.
    meta: UnsafeCell<Option<PendingMeta>>,
    /// The admission epoch's member set (same tag-protocol guard as
    /// `meta`): `remaining` starts at its length and teardown sums only
    /// its positions, so a query completes under exactly the membership
    /// it was admitted with.
    members: UnsafeCell<Option<Arc<MemberSet>>>,
}

// SAFETY: `meta` and `members` are the only non-atomic fields. They are
// written while the slot's tag is TAG_BUSY, which exactly one thread can
// hold at a time (claimed by CAS), and read/taken only by the thread
// holding that claim; the Release store that publishes the live tag (and
// the Acquire CAS that reclaims it) order those accesses.
unsafe impl Send for Slot {}
unsafe impl Sync for Slot {}

/// Preallocated, generation-tagged pending-query arena — the lock-free
/// replacement for the old `Vec<Mutex<HashMap<u64, PendingQuery>>>`
/// striped table. Router (insert/evict) and the batcher threads
/// (score/evict, via their [`Completer`]s) coordinate purely through
/// per-slot atomics:
///
/// 1. **insert** — CAS the slot's tag `FREE → BUSY`, fill metadata,
///    reset `remaining` and the score cells, then publish with a
///    Release store of `query_id + 1`.
/// 2. **score** — check the tag, enter the writer window
///    (`writers += 1`, re-check the tag), write this member's score
///    cell, CAS-min the queue wait, decrement `remaining`, leave the
///    writer window. Whoever decrements `remaining` to zero claims the
///    slot (`tag: id+1 → BUSY`), waits out the writer window, sums the
///    cells in model-index order, frees the slot and returns
///    [`ScoreOutcome::Completed`].
/// 3. **evict** — CAS the tag `id+1 → BUSY`; on success wait out the
///    writer window, drop the metadata (hanging up the caller's reply
///    channel) and free the slot.
///
/// Score cells written before the `remaining` decrement are visible to
/// the completer through the release sequence on `remaining`, so the
/// deterministic model-index-order summation reads fully published
/// values.
pub struct PendingSlots {
    slots: Box<[Slot]>,
    mask: u64,
    n_models: usize,
    /// Epoch-0 full member set, used by the membership-agnostic
    /// [`Self::insert`] (direct executor users, benches, tests).
    full: Arc<MemberSet>,
    in_flight: AtomicUsize,
}

impl PendingSlots {
    /// Arena with the default [`PENDING_SLOTS`] capacity.
    pub fn new(n_models: usize) -> Self {
        Self::with_capacity(PENDING_SLOTS, n_models)
    }

    /// `capacity` must be a power of two (it is a mask, not a modulus).
    pub fn with_capacity(capacity: usize, n_models: usize) -> Self {
        assert!(capacity.is_power_of_two(), "slot capacity must be a power of two");
        assert!(n_models > 0, "an ensemble has at least one member");
        let slots = (0..capacity)
            .map(|_| Slot {
                tag: AtomicU64::new(TAG_FREE),
                writers: AtomicU32::new(0),
                remaining: AtomicU32::new(0),
                min_wait_ns: AtomicU64::new(u64::MAX),
                scores: (0..n_models).map(|_| AtomicU32::new(0)).collect(),
                meta: UnsafeCell::new(None),
                members: UnsafeCell::new(None),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        PendingSlots {
            slots,
            mask: capacity as u64 - 1,
            n_models,
            full: Arc::new(MemberSet::full(n_models)),
            in_flight: AtomicUsize::new(0),
        }
    }

    fn slot(&self, query_id: u64) -> &Slot {
        &self.slots[(query_id & self.mask) as usize]
    }

    /// Live tag for a query id (`u64::MAX` is reserved for BUSY, so ids
    /// may span the entire practical range).
    fn tag_of(query_id: u64) -> u64 {
        query_id.wrapping_add(1)
    }

    /// Universe size: score cells per slot (fixed for the pipeline's
    /// lifetime; membership epochs select subsets of it).
    pub fn n_models(&self) -> usize {
        self.n_models
    }

    /// How long `insert` backpressures on an occupied slot before
    /// concluding the occupant is stuck (a member report was lost) and
    /// force-evicting it. Orders of magnitude above any sane service
    /// time, so a legitimate in-flight query is never stolen.
    const STALE_EVICT_AFTER: Duration = Duration::from_secs(2);

    /// Register a query. If the slot is still held by the query from
    /// `capacity` ids ago, this spins (admission backpressure bounded
    /// by the arena size) — with 1024 slots and sub-second service
    /// times that path is effectively never taken. As a failsafe, an
    /// occupant that has not resolved after [`Self::STALE_EVICT_AFTER`]
    /// is evicted (its caller's reply channel drops), so a single lost
    /// member report degrades to one failed query instead of stalling
    /// admission forever once ids wrap the arena.
    ///
    /// Returns the number of stale occupants force-evicted while
    /// claiming the slot (0 in every healthy schedule) so the caller
    /// can account for the failed queries — eviction itself is
    /// telemetry-agnostic.
    pub fn insert(&self, query_id: u64, meta: PendingMeta) -> usize {
        self.insert_with(query_id, meta, Arc::clone(&self.full))
    }

    /// [`Self::insert`] under a specific membership epoch: `remaining`
    /// starts at the member count and completion sums exactly the
    /// member cells, so the query finishes under the set it was
    /// admitted with no matter what epochs follow.
    pub fn insert_with(&self, query_id: u64, meta: PendingMeta, members: Arc<MemberSet>) -> usize {
        debug_assert!(
            members.positions().iter().all(|&p| p < self.n_models),
            "member positions must index the universe"
        );
        let slot = self.slot(query_id);
        let mut wait_started: Option<Instant> = None;
        let mut force_evicted = 0usize;
        while slot
            .tag
            .compare_exchange(TAG_FREE, TAG_BUSY, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            let started = *wait_started.get_or_insert_with(Instant::now);
            if started.elapsed() >= Self::STALE_EVICT_AFTER {
                let occupant = slot.tag.load(Ordering::Acquire);
                if occupant != TAG_FREE
                    && occupant != TAG_BUSY
                    && self.evict(occupant.wrapping_sub(1))
                {
                    // tag = occupant id + 1; eviction is a no-op if the
                    // occupant resolves concurrently
                    force_evicted += 1;
                }
                wait_started = None; // re-arm for the next occupant
            }
            std::thread::yield_now();
        }
        slot.remaining.store(members.len() as u32, Ordering::Relaxed);
        slot.min_wait_ns.store(u64::MAX, Ordering::Relaxed);
        for cell in slot.scores.iter() {
            cell.store(0, Ordering::Relaxed);
        }
        // SAFETY: we hold the TAG_BUSY claim — no other thread touches
        // `meta`/`members` until the Release store below publishes the
        // live tag.
        unsafe { *slot.meta.get() = Some(meta) };
        unsafe { *slot.members.get() = Some(members) };
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        slot.tag.store(Self::tag_of(query_id), Ordering::Release);
        force_evicted
    }

    /// Record one member's score for `query_id`. `member_pos` is the
    /// member's position in model-index order (its score cell).
    pub fn score(
        &self,
        query_id: u64,
        member_pos: usize,
        score: f32,
        queue_wait: Duration,
    ) -> ScoreOutcome {
        debug_assert!(member_pos < self.n_models);
        let slot = self.slot(query_id);
        let tag = Self::tag_of(query_id);
        if slot.tag.load(Ordering::Acquire) != tag {
            return ScoreOutcome::Absent;
        }
        // writer window: once inside (and the tag re-checked), the slot
        // cannot be recycled under us — completer/evictor spin on
        // `writers == 0` before freeing. SeqCst on both sides of the
        // handshake (this fetch_add + re-load here, the claim CAS +
        // writers load in teardown) closes the store-buffering
        // interleaving where the reporter still sees the live tag while
        // the claimer already sees writers == 0.
        slot.writers.fetch_add(1, Ordering::SeqCst);
        if slot.tag.load(Ordering::SeqCst) != tag {
            slot.writers.fetch_sub(1, Ordering::Release);
            return ScoreOutcome::Absent;
        }
        slot.scores[member_pos].store(score.to_bits(), Ordering::Relaxed);
        let ns = u64::try_from(queue_wait.as_nanos()).unwrap_or(u64::MAX);
        let mut cur = slot.min_wait_ns.load(Ordering::Relaxed);
        while ns < cur {
            match slot.min_wait_ns.compare_exchange_weak(
                cur,
                ns,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        let was_remaining = slot.remaining.fetch_sub(1, Ordering::AcqRel);
        slot.writers.fetch_sub(1, Ordering::Release);
        debug_assert!(was_remaining >= 1);
        if was_remaining != 1 {
            return ScoreOutcome::Accepted;
        }
        // last member: claim the slot for completion (a concurrent
        // evictor may win instead, in which case the query is theirs)
        if slot
            .tag
            .compare_exchange(tag, TAG_BUSY, Ordering::SeqCst, Ordering::Relaxed)
            .is_err()
        {
            return ScoreOutcome::Accepted;
        }
        let completed = self.teardown(slot, true);
        ScoreOutcome::Completed(completed.expect("claimed live slot carries metadata"))
    }

    /// Evict a live query (member failure, dead batcher): reclaims the
    /// slot and drops the reply sender so blocked callers unhang.
    /// Returns false if the query was not live (already completed or
    /// evicted — eviction is idempotent).
    pub fn evict(&self, query_id: u64) -> bool {
        let slot = self.slot(query_id);
        if slot
            .tag
            .compare_exchange(Self::tag_of(query_id), TAG_BUSY, Ordering::SeqCst, Ordering::Relaxed)
            .is_err()
        {
            return false;
        }
        drop(self.teardown(slot, false));
        true
    }

    /// Shared tail of completion and eviction: the caller holds the
    /// TAG_BUSY claim. Waits for in-flight reporters to leave the
    /// writer window, extracts the state, and frees the slot.
    fn teardown(&self, slot: &Slot, completed: bool) -> Option<CompletedQuery> {
        // The writer window is a handful of instructions, so this spin
        // is normally zero iterations; yield after a short burst in
        // case a reporter thread was preempted inside the window.
        // SeqCst pairs with the reporter's fetch_add + tag re-load (see
        // `score`): in the single total order either our claim CAS
        // precedes the fetch_add (the reporter re-reads the tag and
        // backs out) or the fetch_add precedes this load (we observe
        // the reporter and wait).
        let mut spins = 0u32;
        while slot.writers.load(Ordering::SeqCst) != 0 {
            spins += 1;
            if spins < 64 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
        // SAFETY: TAG_BUSY claim is exclusive; reporters are all out of
        // the writer window.
        let meta = unsafe { (*slot.meta.get()).take() };
        let members = unsafe { (*slot.members.get()).take() };
        let out = if completed {
            // sum only the admission epoch's cells, in ascending
            // position (= model-index) order: the bagging numerator is
            // bit-identical for any swap schedule that admitted this
            // query under the same member set
            let members = members.expect("live slot carries its member set");
            let score_sum: f64 = members
                .positions()
                .iter()
                .map(|&p| f32::from_bits(slot.scores[p].load(Ordering::Relaxed)) as f64)
                .sum();
            let ns = slot.min_wait_ns.load(Ordering::Relaxed);
            let min_queue_wait =
                if ns == u64::MAX { Duration::MAX } else { Duration::from_nanos(ns) };
            meta.map(|meta| CompletedQuery {
                meta,
                score_sum,
                n_members: members.len(),
                min_queue_wait,
            })
        } else {
            drop(meta);
            drop(members);
            None
        };
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
        slot.tag.store(TAG_FREE, Ordering::Release);
        out
    }

    /// Queries currently registered and not yet completed/evicted.
    pub fn len(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// Direct (collector-less) completion
// ---------------------------------------------------------------------------

/// One ensemble member's direct-completion handle: the batcher-side
/// replacement for the old `ModelReport` channel + collector thread.
/// `score()` writes the member's cell in the pending arena and — when
/// this report was the last one outstanding — runs the query's
/// `finish()` (deterministic bagging mean, telemetry, reply delivery)
/// inline on the calling thread. `fail()` evicts the query and counts
/// the failure exactly once, no matter how many members fail it.
#[derive(Clone)]
pub struct Completer {
    pending: Arc<PendingSlots>,
    telemetry: Arc<Telemetry>,
    /// This member's position in model-index order (its score cell).
    member_pos: usize,
}

impl Completer {
    pub fn new(pending: Arc<PendingSlots>, telemetry: Arc<Telemetry>, member_pos: usize) -> Self {
        assert!(member_pos < pending.n_models(), "member_pos out of ensemble range");
        Completer { pending, telemetry, member_pos }
    }

    /// Record this member's score for `query_id`; completes the query
    /// inline if every other member has already reported.
    pub fn score(&self, query_id: u64, score: f32, queue_wait: Duration, exec_time: Duration) {
        self.telemetry.exec.record(exec_time);
        self.telemetry.model_jobs.fetch_add(1, Ordering::Relaxed);
        match self.pending.score(query_id, self.member_pos, score, queue_wait) {
            ScoreOutcome::Completed(done) => finish(done, &self.telemetry),
            ScoreOutcome::Accepted | ScoreOutcome::Absent => {}
        }
    }

    /// This member could not score the query (engine error, bad input):
    /// evict it so the blocked `submit()` caller errors out instead of
    /// hanging. Counts one failure per evicted query (not per failing
    /// member), and counts it BEFORE the eviction drops the reply
    /// sender, so the count is visible by the time the caller observes
    /// the hang-up; if another thread evicted first (and counted), the
    /// provisional count is undone.
    pub fn fail(&self, query_id: u64) {
        self.telemetry.failures.fetch_add(1, Ordering::Relaxed);
        if !self.pending.evict(query_id) {
            self.telemetry.failures.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

// ---------------------------------------------------------------------------
// Pipeline
// ---------------------------------------------------------------------------

/// Handle to a running pipeline. Cheap to clone. Dropping the last
/// handle shuts the pipeline down: the router drains, the executor
/// flushes every lane's final batch, and the workers are joined — so
/// "pipeline dropped" implies "every admitted query resolved".
#[derive(Clone)]
pub struct Pipeline {
    /// Declared before `executor` on purpose: dropping the last handle
    /// must close the query channel (router exits, lane sender drops)
    /// *before* the executor handle's drop joins the workers.
    tx: mpsc::Sender<RouterMsg>,
    telemetry: Arc<Telemetry>,
    pending: Arc<PendingSlots>,
    ensemble: Selector,
    clip_len: usize,
    /// Mirror of the router's current member set (the router updates it
    /// after applying each Install): read-only observability — the
    /// router's own copy is what admissions actually use.
    membership: Arc<Mutex<Arc<MemberSet>>>,
    executor: Arc<Executor>,
}

impl Pipeline {
    /// Spawn the pipeline for `ensemble` on the given engine. Every
    /// selected model must be servable (compiled artifacts present).
    pub fn spawn(zoo: &Zoo, engine: &Engine, cfg: PipelineConfig) -> Result<Pipeline> {
        if cfg.ensemble.is_empty() {
            return Err(Error::config("cannot serve an empty ensemble"));
        }
        for &i in cfg.ensemble.indices() {
            if !engine.has_model((i, engine.batch_for(1))) {
                return Err(Error::artifact(format!(
                    "ensemble member {} ({}) has no compiled artifact",
                    i,
                    zoo.model(i).id
                )));
            }
        }
        let telemetry = Arc::new(Telemetry::default());
        let pending = Arc::new(PendingSlots::new(cfg.ensemble.len()));

        // one executor lane per selected model, each holding its direct
        // Completer (member_pos = position in model-index order); a
        // fixed pool of workers serves every lane — no thread per model,
        // no collector thread, no report channel
        let members: Vec<(usize, Completer)> = cfg
            .ensemble
            .indices()
            .iter()
            .enumerate()
            .map(|(pos, &i)| {
                (i, Completer::new(Arc::clone(&pending), Arc::clone(&telemetry), pos))
            })
            .collect();
        // SLO-aware fill deadlines: the executor builds its deadline
        // controller from this same policy, reading the live T_q/T_s
        // split from this pipeline's telemetry and each lane's queue
        // depth at arm time; with a static policy it is inert (every
        // arm returns `policy.timeout`)
        let (executor, lanes) = Executor::spawn(
            engine,
            members,
            cfg.policy,
            cfg.workers,
            cfg.slo,
            Some(Arc::clone(&telemetry)),
        )?;
        telemetry.install_executor(ExecutorGauges::new(
            executor.lane_models(),
            executor.depth_gauges(),
            executor.batch_counters(),
            executor.controller().lane_waits(),
            executor.dead_gauges(),
            executor.retry_counters(),
        ));
        // surface the backend's shared compiled-executable cache in
        // /stats (absent on backends without one)
        if let Some(g) = engine.exec_cache_gauges() {
            telemetry.install_exec_cache(g);
        }

        // router thread; epoch 0 = the full spawn-time universe
        let membership: Arc<Mutex<Arc<MemberSet>>> =
            Arc::new(Mutex::new(Arc::new(MemberSet::full(cfg.ensemble.len()))));
        let (tx, query_rx) = mpsc::channel::<RouterMsg>();
        {
            let pending = Arc::clone(&pending);
            let telemetry = Arc::clone(&telemetry);
            let membership = Arc::clone(&membership);
            // lead index per lane (= member position in model-index order)
            let lane_leads: Vec<usize> =
                cfg.ensemble.indices().iter().map(|&i| zoo.model(i).lead).collect();
            let clip_len = zoo.manifest.clip_len;
            std::thread::Builder::new()
                .name("router".into())
                .spawn(move || {
                    router_loop(query_rx, lanes, lane_leads, clip_len, pending, telemetry, membership)
                })
                .map_err(Error::Io)?;
        }

        Ok(Pipeline {
            tx,
            telemetry,
            pending,
            ensemble: cfg.ensemble,
            clip_len: zoo.manifest.clip_len,
            membership,
            executor: Arc::new(executor),
        })
    }

    /// Executor pool size actually spawned.
    pub fn n_workers(&self) -> usize {
        self.executor.n_workers()
    }

    pub fn telemetry(&self) -> &Arc<Telemetry> {
        &self.telemetry
    }

    pub fn ensemble(&self) -> &Selector {
        &self.ensemble
    }

    pub fn clip_len(&self) -> usize {
        self.clip_len
    }

    /// Queries currently registered and not yet completed/evicted.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// The executor under this pipeline (lane health, revive, engine —
    /// the governor's control surface).
    pub fn executor(&self) -> &Arc<Executor> {
        &self.executor
    }

    /// The member set admissions currently run under (the router's
    /// mirror; epoch 0 until the first install).
    pub fn membership(&self) -> Arc<MemberSet> {
        Arc::clone(&self.membership.lock().expect("membership mirror poisoned"))
    }

    /// Hot-swap the ensemble membership to `positions` (lane positions
    /// in the spawn universe, any order; deduplicated). Blocks until
    /// the router has applied the new epoch and returns it: every query
    /// submitted before this call completes under its own admission
    /// epoch, every query submitted after it (or after the returned
    /// ack, for other threads) under the new one — nothing in flight is
    /// dropped or re-averaged. Deterministic by construction: the swap
    /// rides the same FIFO channel as admissions.
    pub fn install_membership(&self, positions: &[usize]) -> Result<Arc<MemberSet>> {
        let n = self.pending.n_models();
        if positions.is_empty() {
            return Err(Error::config("membership cannot be empty"));
        }
        if let Some(&bad) = positions.iter().find(|&&p| p >= n) {
            return Err(Error::config(format!(
                "membership position {bad} outside the {n}-lane universe"
            )));
        }
        let (ack_tx, ack_rx) = mpsc::sync_channel(1);
        self.tx
            .send(RouterMsg::Install { positions: positions.to_vec(), ack: ack_tx })
            .map_err(|_| Error::serving("pipeline shut down"))?;
        ack_rx.recv().map_err(|_| Error::serving("pipeline shut down before install applied"))
    }

    /// Submit a query; receive the prediction on the returned channel.
    /// If the query fails (a member's engine execution errors), the
    /// channel hangs up without a message.
    pub fn submit(&self, query: Query) -> Result<PredictionRx> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx
            .send(RouterMsg::Query(query, Some(tx)))
            .map_err(|_| Error::serving("pipeline shut down"))?;
        Ok(rx)
    }

    /// Submit a query and block for the prediction.
    pub fn query(&self, query: Query) -> Result<Prediction> {
        let rx = self.submit(query)?;
        rx.recv().map_err(|_| Error::serving("pipeline dropped query"))
    }

    /// Fire-and-forget submission (open-loop load generation); telemetry
    /// still records the prediction.
    pub fn submit_nowait(&self, query: Query) -> Result<()> {
        self.tx
            .send(RouterMsg::Query(query, None))
            .map_err(|_| Error::serving("pipeline shut down"))
    }
}

fn router_loop(
    rx: mpsc::Receiver<RouterMsg>,
    lanes: LaneSender,
    lane_leads: Vec<usize>,
    clip_len: usize,
    pending: Arc<PendingSlots>,
    telemetry: Arc<Telemetry>,
    membership: Arc<Mutex<Arc<MemberSet>>>,
) {
    // the router's copy is what admissions use; the mirror exists so
    // handles can observe the current epoch without racing admissions
    let mut current: Arc<MemberSet> =
        Arc::clone(&membership.lock().expect("membership mirror poisoned"));
    let mut epoch = current.epoch();
    // the admission sequence number is the query id; it picks the
    // pending slot (id mod capacity) and its generation tag (id + 1).
    // Installs do not consume ids, so the id stream is identical for any
    // swap schedule — membership only changes who scores a query.
    let mut seq = 0u64;
    for msg in rx {
        let (q, reply) = match msg {
            RouterMsg::Install { positions, ack } => {
                epoch += 1;
                let set = Arc::new(MemberSet::new(epoch, positions));
                current = Arc::clone(&set);
                *membership.lock().expect("membership mirror poisoned") = Arc::clone(&set);
                // ack after the swap is applied: once the installer's
                // call returns, every future admission (from any
                // handle) runs under the new epoch
                let _ = ack.send(set);
                continue;
            }
            RouterMsg::Query(q, reply) => (q, reply),
        };
        let id = seq;
        seq += 1;
        // reject malformed windows before registering anything: the
        // reply sender drops here, so the caller errors immediately and
        // no model lane ever sees a wrong-length input
        if q.leads.iter().any(|l| l.len() != clip_len) {
            telemetry.failures.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        let force_evicted = pending.insert_with(
            id,
            PendingMeta {
                patient: q.patient,
                window_id: q.window_id,
                sim_end: q.sim_end,
                emitted: q.emitted,
                reply,
            },
            Arc::clone(&current),
        );
        if force_evicted > 0 {
            // stale occupants killed by the arena's insert failsafe:
            // their callers saw a hang-up, so make the failures visible
            telemetry.failures.fetch_add(force_evicted as u64, Ordering::Relaxed);
        }
        for &pos in current.positions() {
            // zero-copy fan-out to the admission epoch's members only:
            // every member shares the same window
            let item = BatchItem {
                query_id: id,
                input: q.leads[lane_leads[pos]].clone(),
                enqueued: q.emitted,
            };
            if lanes.push(pos, item).is_err() {
                // dead lane (its model cannot execute): evict the
                // query; members already dispatched find a freed slot
                // and are skipped. Count the failure BEFORE evict()
                // drops the reply sender so it is visible by the time
                // the caller observes the hang-up; if a concurrent lane
                // eviction beat us to the slot (and counted it), undo
                // our count.
                telemetry.failures.fetch_add(1, Ordering::Relaxed);
                if !pending.evict(id) {
                    telemetry.failures.fetch_sub(1, Ordering::Relaxed);
                }
                break;
            }
        }
    }
    // router exit drops the lane sender → the executor drains and stops
}

/// Complete one query: deterministic bagging mean + telemetry + reply.
/// Runs inline on whichever batcher thread recorded the last member's
/// score (see [`Completer::score`]). The bagging denominator is the
/// query's own admission-epoch member count — a swap installed after
/// admission never re-averages it.
fn finish(done: CompletedQuery, telemetry: &Telemetry) {
    let e2e = done.meta.emitted.elapsed();
    telemetry.e2e.record(e2e);
    telemetry.queueing.record(done.min_queue_wait);
    telemetry.queries.fetch_add(1, Ordering::Relaxed);
    let prediction = Prediction {
        patient: done.meta.patient,
        window_id: done.meta.window_id,
        sim_end: done.meta.sim_end,
        score: done.score_sum / done.n_members as f64,
        n_models: done.n_members,
        e2e,
        queueing: done.min_queue_wait,
    };
    if let Some(reply) = done.meta.reply {
        let _ = reply.send(prediction);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> PendingMeta {
        PendingMeta {
            patient: 0,
            window_id: 0,
            sim_end: 0.0,
            emitted: Instant::now(),
            reply: None,
        }
    }

    #[test]
    fn single_thread_insert_score_complete() {
        let slots = PendingSlots::with_capacity(4, 3);
        slots.insert(7, meta());
        assert_eq!(slots.len(), 1);
        assert!(matches!(
            slots.score(7, 0, 0.25, Duration::from_millis(3)),
            ScoreOutcome::Accepted
        ));
        assert!(matches!(
            slots.score(7, 2, 0.5, Duration::from_millis(1)),
            ScoreOutcome::Accepted
        ));
        match slots.score(7, 1, 0.125, Duration::from_millis(2)) {
            ScoreOutcome::Completed(done) => {
                // cells summed in model-index order: 0.25 + 0.125 + 0.5
                let want = 0.25f32 as f64 + 0.125f32 as f64 + 0.5f32 as f64;
                assert_eq!(done.score_sum.to_bits(), want.to_bits());
                assert_eq!(done.min_queue_wait, Duration::from_millis(1));
            }
            _ => panic!("third member must complete the query"),
        }
        assert_eq!(slots.len(), 0);
        // late duplicate for the freed generation is dropped
        assert!(matches!(
            slots.score(7, 0, 0.9, Duration::ZERO),
            ScoreOutcome::Absent
        ));
    }

    #[test]
    fn evict_is_idempotent_and_drops_reply() {
        let slots = PendingSlots::with_capacity(4, 2);
        let (tx, rx) = mpsc::sync_channel::<Prediction>(1);
        slots.insert(
            3,
            PendingMeta {
                patient: 1,
                window_id: 2,
                sim_end: 0.0,
                emitted: Instant::now(),
                reply: Some(tx),
            },
        );
        assert!(matches!(slots.score(3, 0, 0.5, Duration::ZERO), ScoreOutcome::Accepted));
        assert!(slots.evict(3));
        assert!(!slots.evict(3), "second evict must be a no-op");
        assert_eq!(slots.len(), 0);
        // the reply sender dropped: the caller sees a hang-up
        assert!(rx.recv().is_err());
        // a straggler member score for the evicted query is dropped
        assert!(matches!(slots.score(3, 1, 0.5, Duration::ZERO), ScoreOutcome::Absent));
    }

    #[test]
    fn insert_with_completes_under_admission_member_set() {
        let slots = PendingSlots::with_capacity(4, 4);
        // admit under a 2-member epoch {1, 3} of a 4-lane universe
        let members = Arc::new(MemberSet::new(5, vec![3, 1]));
        assert_eq!(members.positions(), &[1, 3], "positions sort + dedup");
        slots.insert_with(9, meta(), members);
        assert!(matches!(
            slots.score(9, 3, 0.5, Duration::from_millis(2)),
            ScoreOutcome::Accepted
        ));
        match slots.score(9, 1, 0.25, Duration::from_millis(1)) {
            ScoreOutcome::Completed(done) => {
                let want = 0.25f32 as f64 + 0.5f32 as f64;
                assert_eq!(done.score_sum.to_bits(), want.to_bits());
                assert_eq!(done.n_members, 2, "denominator is the admission epoch's size");
                assert_eq!(done.min_queue_wait, Duration::from_millis(1));
            }
            _ => panic!("second member of a 2-member epoch must complete the query"),
        }
        assert_eq!(slots.len(), 0);
    }

    #[test]
    fn member_set_full_and_contains() {
        let full = MemberSet::full(3);
        assert_eq!(full.epoch(), 0);
        assert_eq!(full.positions(), &[0, 1, 2]);
        assert_eq!(full.len(), 3);
        let sub = MemberSet::new(2, vec![2, 0]);
        assert!(sub.contains(0) && !sub.contains(1) && sub.contains(2));
    }

    #[test]
    fn slot_reuse_across_generations() {
        let slots = PendingSlots::with_capacity(2, 1);
        // ids 0, 2, 4 all hash to slot 0; each generation completes
        // before the next insert, so reuse is immediate
        for g in 0..3u64 {
            let id = g * 2;
            slots.insert(id, meta());
            match slots.score(id, 0, g as f32, Duration::ZERO) {
                ScoreOutcome::Completed(done) => {
                    assert_eq!(done.score_sum, g as f64);
                }
                _ => panic!("single-member query completes on first score"),
            }
        }
        assert_eq!(slots.len(), 0);
    }
}
