//! The ensemble serving pipeline: router + per-model batcher actors +
//! bagging collector, wired over std channels (Fig. 4).
//!
//! Thread topology (the rust substitute for the paper's Ray actors):
//!
//! ```text
//!  Pipeline handles ──queries──► router thread ──items──► batcher threads
//!                                   │ register                │ scores
//!                                   ▼                         ▼
//!                         shared pending table ◄──── collector thread
//! ```
//!
//! Shutdown is acyclic: dropping the last `Pipeline` handle closes the
//! query channel → the router exits and drops the per-model item
//! senders → batchers drain and exit, dropping the score sender → the
//! collector exits. No thread outlives the pipeline.

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::batcher::{model_batch_loop, BatchItem, BatchPolicy, ModelScore};
use super::telemetry::Telemetry;
use crate::runtime::Engine;
use crate::zoo::{Selector, Zoo};
use crate::{Error, Result};

/// One ensemble query: a synchronized multi-lead observation window.
#[derive(Debug, Clone)]
pub struct Query {
    pub patient: usize,
    pub window_id: u64,
    pub sim_end: f64,
    pub leads: [Vec<f32>; 3],
    /// Wall-clock emission instant (set by the aggregator).
    pub emitted: Instant,
}

impl Query {
    pub fn from_window(w: super::aggregator::WindowData) -> Self {
        Query {
            patient: w.patient,
            window_id: w.window_id,
            sim_end: w.sim_end,
            leads: w.leads,
            emitted: Instant::now(),
        }
    }
}

/// Bagging-ensemble prediction (Eq. 5) with latency breakdown.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub patient: usize,
    pub window_id: u64,
    pub sim_end: f64,
    /// Mean probability over the ensemble members.
    pub score: f64,
    pub n_models: usize,
    /// End-to-end: emission → all members scored (T_q + T_s).
    pub e2e: Duration,
    /// Min model queue-wait ≈ the queueing component T_q.
    pub queueing: Duration,
}

/// Receiver for one query's prediction (oneshot semantics).
pub type PredictionRx = mpsc::Receiver<Prediction>;

/// Pipeline construction parameters.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub ensemble: Selector,
    pub policy: BatchPolicy,
}

impl PipelineConfig {
    pub fn new(ensemble: Selector) -> Self {
        PipelineConfig { ensemble, policy: BatchPolicy::default() }
    }
}

struct PendingQuery {
    patient: usize,
    window_id: u64,
    sim_end: f64,
    emitted: Instant,
    remaining: usize,
    sum: f64,
    n_models: usize,
    min_queue_wait: Duration,
    reply: Option<mpsc::SyncSender<Prediction>>,
}

type PendingTable = Arc<Mutex<HashMap<u64, PendingQuery>>>;

/// Handle to a running pipeline. Cheap to clone. Dropping all handles
/// shuts the pipeline down (batchers drain, engine stays alive).
#[derive(Clone)]
pub struct Pipeline {
    tx: mpsc::Sender<(Query, Option<mpsc::SyncSender<Prediction>>)>,
    telemetry: Arc<Telemetry>,
    ensemble: Selector,
    clip_len: usize,
}

impl Pipeline {
    /// Spawn the pipeline for `ensemble` on the given engine. Every
    /// selected model must be servable (compiled artifacts present).
    pub fn spawn(zoo: &Zoo, engine: &Engine, cfg: PipelineConfig) -> Result<Pipeline> {
        if cfg.ensemble.is_empty() {
            return Err(Error::config("cannot serve an empty ensemble"));
        }
        for &i in cfg.ensemble.indices() {
            if !engine.has_model((i, engine.batch_for(1))) {
                return Err(Error::artifact(format!(
                    "ensemble member {} ({}) has no compiled artifact",
                    i,
                    zoo.model(i).id
                )));
            }
        }
        let telemetry = Arc::new(Telemetry::default());
        let pending: PendingTable = Arc::new(Mutex::new(HashMap::new()));
        let (score_tx, score_rx) = mpsc::channel::<ModelScore>();

        // batcher actor per selected model
        let mut model_txs: HashMap<usize, mpsc::Sender<BatchItem>> = HashMap::new();
        for &i in cfg.ensemble.indices() {
            let (btx, brx) = mpsc::channel::<BatchItem>();
            model_txs.insert(i, btx);
            let engine = engine.clone();
            let policy = cfg.policy;
            let stx = score_tx.clone();
            std::thread::Builder::new()
                .name(format!("batcher-{i}"))
                .spawn(move || {
                    let out = |s: ModelScore| {
                        stx.send(s).map_err(|_| Error::serving("collector gone"))
                    };
                    if let Err(e) = model_batch_loop(i, engine, brx, out, policy) {
                        eprintln!("model batcher {i} exited: {e}");
                    }
                })
                .map_err(Error::Io)?;
        }
        drop(score_tx); // collector ends when the last batcher exits

        // collector thread
        {
            let pending = Arc::clone(&pending);
            let telemetry = Arc::clone(&telemetry);
            std::thread::Builder::new()
                .name("collector".into())
                .spawn(move || collector_loop(score_rx, pending, telemetry))
                .map_err(Error::Io)?;
        }

        // router thread
        let (tx, query_rx) =
            mpsc::channel::<(Query, Option<mpsc::SyncSender<Prediction>>)>();
        {
            let pending = Arc::clone(&pending);
            let leads: HashMap<usize, usize> =
                cfg.ensemble.indices().iter().map(|&i| (i, zoo.model(i).lead)).collect();
            let ensemble = cfg.ensemble.clone();
            std::thread::Builder::new()
                .name("router".into())
                .spawn(move || router_loop(query_rx, model_txs, leads, ensemble, pending))
                .map_err(Error::Io)?;
        }

        Ok(Pipeline {
            tx,
            telemetry,
            ensemble: cfg.ensemble,
            clip_len: zoo.manifest.clip_len,
        })
    }

    pub fn telemetry(&self) -> &Arc<Telemetry> {
        &self.telemetry
    }

    pub fn ensemble(&self) -> &Selector {
        &self.ensemble
    }

    pub fn clip_len(&self) -> usize {
        self.clip_len
    }

    /// Submit a query; receive the prediction on the returned channel.
    pub fn submit(&self, query: Query) -> Result<PredictionRx> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx
            .send((query, Some(tx)))
            .map_err(|_| Error::serving("pipeline shut down"))?;
        Ok(rx)
    }

    /// Submit a query and block for the prediction.
    pub fn query(&self, query: Query) -> Result<Prediction> {
        let rx = self.submit(query)?;
        rx.recv().map_err(|_| Error::serving("pipeline dropped query"))
    }

    /// Fire-and-forget submission (open-loop load generation); telemetry
    /// still records the prediction.
    pub fn submit_nowait(&self, query: Query) -> Result<()> {
        self.tx
            .send((query, None))
            .map_err(|_| Error::serving("pipeline shut down"))
    }
}

fn router_loop(
    rx: mpsc::Receiver<(Query, Option<mpsc::SyncSender<Prediction>>)>,
    model_txs: HashMap<usize, mpsc::Sender<BatchItem>>,
    leads: HashMap<usize, usize>,
    ensemble: Selector,
    pending: PendingTable,
) {
    let mut next_id: u64 = 0;
    for (q, reply) in rx {
        let id = next_id;
        next_id += 1;
        pending.lock().expect("pending table poisoned").insert(
            id,
            PendingQuery {
                patient: q.patient,
                window_id: q.window_id,
                sim_end: q.sim_end,
                emitted: q.emitted,
                remaining: ensemble.len(),
                sum: 0.0,
                n_models: ensemble.len(),
                min_queue_wait: Duration::MAX,
                reply,
            },
        );
        for &m in ensemble.indices() {
            let item = BatchItem {
                query_id: id,
                input: q.leads[leads[&m]].clone(),
                enqueued: q.emitted,
            };
            if model_txs[&m].send(item).is_err() {
                // batcher died: fail the query (reply hangs up on drop)
                pending.lock().expect("pending table poisoned").remove(&id);
                break;
            }
        }
    }
    // router exit drops model_txs → batchers drain and exit
}

fn collector_loop(rx: mpsc::Receiver<ModelScore>, pending: PendingTable, telemetry: Arc<Telemetry>) {
    for s in rx {
        telemetry.exec.record(s.exec_time);
        telemetry.model_jobs.fetch_add(1, Ordering::Relaxed);
        let done = {
            let mut table = pending.lock().expect("pending table poisoned");
            let Some(entry) = table.get_mut(&s.query_id) else { continue };
            entry.sum += s.score as f64;
            entry.remaining -= 1;
            if s.queue_wait < entry.min_queue_wait {
                entry.min_queue_wait = s.queue_wait;
            }
            if entry.remaining == 0 {
                table.remove(&s.query_id)
            } else {
                None
            }
        };
        if let Some(entry) = done {
            let e2e = entry.emitted.elapsed();
            telemetry.e2e.record(e2e);
            telemetry.queueing.record(entry.min_queue_wait);
            telemetry.queries.fetch_add(1, Ordering::Relaxed);
            let prediction = Prediction {
                patient: entry.patient,
                window_id: entry.window_id,
                sim_end: entry.sim_end,
                score: entry.sum / entry.n_models as f64,
                n_models: entry.n_models,
                e2e,
                queueing: entry.min_queue_wait,
            };
            if let Some(reply) = entry.reply {
                let _ = reply.send(prediction);
            }
        }
    }
}
