//! The ensemble serving pipeline: router + per-model batcher actors +
//! bagging collector, wired over std channels (Fig. 4).
//!
//! ## Data-plane architecture (zero-copy, shard-parallel)
//!
//! ```text
//!  Pipeline handles ──queries──► router thread ──items──► batcher threads
//!        │                          │ register               │  persistent
//!        │  leads: [Arc<[f32]>; 3]  │                        │  padded buffer
//!        │  (shared, never cloned)  ▼                        ▼
//!        │                 striped pending table        ExecBackend engine
//!        │               (N mutexes, keyed id % N)      (sim | pjrt workers)
//!        │                          ▲                        │ scores
//!        ▼                          │                        ▼
//!      reply rx ◄─────────── collector thread ◄──────────────┘
//! ```
//!
//! * **Zero-copy windows** — the aggregator emits each lead window once
//!   as `Arc<[f32]>`; the router hands every ensemble member a
//!   reference, and the only remaining copy is the single slot-write
//!   into the batcher's persistent padded batch buffer.
//! * **Striped pending table** — per-query bagging state is sharded
//!   over [`PENDING_STRIPES`] mutexes keyed by `query_id`, so the
//!   router (registering) and the collector (scoring) contend only when
//!   they touch the same stripe, not on one global lock.
//! * **Deterministic bagging** — member scores are accumulated per
//!   model and summed in model-index order at completion, so a query's
//!   ensemble score is bit-for-bit identical regardless of batch
//!   composition or arrival order.
//! * **Failure eviction** — when a member cannot score a query (engine
//!   error, dead batcher), the entry is evicted and the caller's reply
//!   channel drops, so `submit()` callers fail fast instead of leaking
//!   entries with `remaining > 0` forever.
//!
//! Shutdown is acyclic: dropping the last `Pipeline` handle closes the
//! query channel → the router exits and drops the per-model item
//! senders → batchers drain and exit, dropping the report sender → the
//! collector exits. No thread outlives the pipeline.

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::batcher::{model_batch_loop, BatchItem, BatchPolicy, ModelReport};
use super::telemetry::Telemetry;
use crate::runtime::Engine;
use crate::zoo::{Selector, Zoo};
use crate::{Error, Result};

/// Number of pending-table shards (power of two; a query lives in
/// stripe `query_id % PENDING_STRIPES`).
pub const PENDING_STRIPES: usize = 16;

/// Move a triple of freshly collected lead windows into shared storage:
/// one allocation per lead, after which every ensemble member borrows
/// the same samples.
pub fn share_leads(leads: [Vec<f32>; 3]) -> [Arc<[f32]>; 3] {
    let [a, b, c] = leads;
    [Arc::from(a), Arc::from(b), Arc::from(c)]
}

/// One ensemble query: a synchronized multi-lead observation window.
/// Leads are reference-counted slices shared across the whole data
/// plane — cloning a `Query` never copies samples.
#[derive(Debug, Clone)]
pub struct Query {
    pub patient: usize,
    pub window_id: u64,
    pub sim_end: f64,
    pub leads: [Arc<[f32]>; 3],
    /// Wall-clock emission instant (set by the aggregator).
    pub emitted: Instant,
}

impl Query {
    pub fn from_window(w: super::aggregator::WindowData) -> Self {
        Query {
            patient: w.patient,
            window_id: w.window_id,
            sim_end: w.sim_end,
            leads: w.leads,
            emitted: Instant::now(),
        }
    }

    /// Build a query from owned lead vectors (load generators, tests).
    pub fn from_vecs(patient: usize, window_id: u64, sim_end: f64, leads: [Vec<f32>; 3]) -> Self {
        Query {
            patient,
            window_id,
            sim_end,
            leads: share_leads(leads),
            emitted: Instant::now(),
        }
    }
}

/// Bagging-ensemble prediction (Eq. 5) with latency breakdown.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub patient: usize,
    pub window_id: u64,
    pub sim_end: f64,
    /// Mean probability over the ensemble members (summed in
    /// model-index order — deterministic across batchings).
    pub score: f64,
    pub n_models: usize,
    /// End-to-end: emission → all members scored (T_q + T_s).
    pub e2e: Duration,
    /// Min model queue-wait ≈ the queueing component T_q.
    pub queueing: Duration,
}

/// Receiver for one query's prediction (oneshot semantics).
pub type PredictionRx = mpsc::Receiver<Prediction>;

/// Pipeline construction parameters.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub ensemble: Selector,
    pub policy: BatchPolicy,
}

impl PipelineConfig {
    pub fn new(ensemble: Selector) -> Self {
        PipelineConfig { ensemble, policy: BatchPolicy::default() }
    }

    pub fn with_policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }
}

struct PendingQuery {
    patient: usize,
    window_id: u64,
    sim_end: f64,
    emitted: Instant,
    remaining: usize,
    /// (model index, score) per member already collected; summed in
    /// model-index order at completion for a deterministic bagging mean.
    member_scores: Vec<(usize, f32)>,
    n_models: usize,
    min_queue_wait: Duration,
    reply: Option<mpsc::SyncSender<Prediction>>,
}

/// Sharded pending-query table: router and collector operate on
/// different queries almost always, so striping removes the single
/// global lock from the hot path.
struct PendingTable {
    stripes: Vec<Mutex<HashMap<u64, PendingQuery>>>,
}

impl PendingTable {
    fn new() -> Self {
        PendingTable {
            stripes: (0..PENDING_STRIPES).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    fn stripe(&self, query_id: u64) -> &Mutex<HashMap<u64, PendingQuery>> {
        &self.stripes[(query_id % PENDING_STRIPES as u64) as usize]
    }

    fn insert(&self, query_id: u64, entry: PendingQuery) {
        self.stripe(query_id)
            .lock()
            .expect("pending stripe poisoned")
            .insert(query_id, entry);
    }

    fn remove(&self, query_id: u64) -> Option<PendingQuery> {
        self.stripe(query_id)
            .lock()
            .expect("pending stripe poisoned")
            .remove(&query_id)
    }

    /// Total in-flight queries (diagnostics + leak assertions in tests).
    fn len(&self) -> usize {
        self.stripes
            .iter()
            .map(|s| s.lock().expect("pending stripe poisoned").len())
            .sum()
    }
}

/// Handle to a running pipeline. Cheap to clone. Dropping all handles
/// shuts the pipeline down (batchers drain, engine stays alive).
#[derive(Clone)]
pub struct Pipeline {
    tx: mpsc::Sender<(Query, Option<mpsc::SyncSender<Prediction>>)>,
    telemetry: Arc<Telemetry>,
    pending: Arc<PendingTable>,
    ensemble: Selector,
    clip_len: usize,
}

impl Pipeline {
    /// Spawn the pipeline for `ensemble` on the given engine. Every
    /// selected model must be servable (compiled artifacts present).
    pub fn spawn(zoo: &Zoo, engine: &Engine, cfg: PipelineConfig) -> Result<Pipeline> {
        if cfg.ensemble.is_empty() {
            return Err(Error::config("cannot serve an empty ensemble"));
        }
        for &i in cfg.ensemble.indices() {
            if !engine.has_model((i, engine.batch_for(1))) {
                return Err(Error::artifact(format!(
                    "ensemble member {} ({}) has no compiled artifact",
                    i,
                    zoo.model(i).id
                )));
            }
        }
        let telemetry = Arc::new(Telemetry::default());
        let pending = Arc::new(PendingTable::new());
        let (report_tx, report_rx) = mpsc::channel::<ModelReport>();

        // batcher actor per selected model
        let mut model_txs: HashMap<usize, mpsc::Sender<BatchItem>> = HashMap::new();
        for &i in cfg.ensemble.indices() {
            let (btx, brx) = mpsc::channel::<BatchItem>();
            model_txs.insert(i, btx);
            let engine = engine.clone();
            let policy = cfg.policy;
            let stx = report_tx.clone();
            std::thread::Builder::new()
                .name(format!("batcher-{i}"))
                .spawn(move || {
                    let out = |r: ModelReport| {
                        stx.send(r).map_err(|_| Error::serving("collector gone"))
                    };
                    if let Err(e) = model_batch_loop(i, engine, brx, out, policy) {
                        eprintln!("model batcher {i} exited: {e}");
                    }
                })
                .map_err(Error::Io)?;
        }
        drop(report_tx); // collector ends when the last batcher exits

        // collector thread
        {
            let pending = Arc::clone(&pending);
            let telemetry = Arc::clone(&telemetry);
            std::thread::Builder::new()
                .name("collector".into())
                .spawn(move || collector_loop(report_rx, pending, telemetry))
                .map_err(Error::Io)?;
        }

        // router thread
        let (tx, query_rx) =
            mpsc::channel::<(Query, Option<mpsc::SyncSender<Prediction>>)>();
        {
            let pending = Arc::clone(&pending);
            let telemetry = Arc::clone(&telemetry);
            let leads: HashMap<usize, usize> =
                cfg.ensemble.indices().iter().map(|&i| (i, zoo.model(i).lead)).collect();
            let ensemble = cfg.ensemble.clone();
            let clip_len = zoo.manifest.clip_len;
            std::thread::Builder::new()
                .name("router".into())
                .spawn(move || {
                    router_loop(query_rx, model_txs, leads, ensemble, clip_len, pending, telemetry)
                })
                .map_err(Error::Io)?;
        }

        Ok(Pipeline {
            tx,
            telemetry,
            pending,
            ensemble: cfg.ensemble,
            clip_len: zoo.manifest.clip_len,
        })
    }

    pub fn telemetry(&self) -> &Arc<Telemetry> {
        &self.telemetry
    }

    pub fn ensemble(&self) -> &Selector {
        &self.ensemble
    }

    pub fn clip_len(&self) -> usize {
        self.clip_len
    }

    /// Queries currently registered and not yet completed/evicted.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Submit a query; receive the prediction on the returned channel.
    /// If the query fails (a member's engine execution errors), the
    /// channel hangs up without a message.
    pub fn submit(&self, query: Query) -> Result<PredictionRx> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx
            .send((query, Some(tx)))
            .map_err(|_| Error::serving("pipeline shut down"))?;
        Ok(rx)
    }

    /// Submit a query and block for the prediction.
    pub fn query(&self, query: Query) -> Result<Prediction> {
        let rx = self.submit(query)?;
        rx.recv().map_err(|_| Error::serving("pipeline dropped query"))
    }

    /// Fire-and-forget submission (open-loop load generation); telemetry
    /// still records the prediction.
    pub fn submit_nowait(&self, query: Query) -> Result<()> {
        self.tx
            .send((query, None))
            .map_err(|_| Error::serving("pipeline shut down"))
    }
}

fn router_loop(
    rx: mpsc::Receiver<(Query, Option<mpsc::SyncSender<Prediction>>)>,
    model_txs: HashMap<usize, mpsc::Sender<BatchItem>>,
    leads: HashMap<usize, usize>,
    ensemble: Selector,
    clip_len: usize,
    pending: Arc<PendingTable>,
    telemetry: Arc<Telemetry>,
) {
    let mut next_id: u64 = 0;
    for (q, reply) in rx {
        // reject malformed windows before registering anything: the
        // reply sender drops here, so the caller errors immediately and
        // no batcher ever sees a wrong-length input
        if q.leads.iter().any(|l| l.len() != clip_len) {
            telemetry.failures.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        let id = next_id;
        next_id += 1;
        let n_models = ensemble.len();
        pending.insert(
            id,
            PendingQuery {
                patient: q.patient,
                window_id: q.window_id,
                sim_end: q.sim_end,
                emitted: q.emitted,
                remaining: n_models,
                member_scores: Vec::with_capacity(n_models),
                n_models,
                min_queue_wait: Duration::MAX,
                reply,
            },
        );
        for &m in ensemble.indices() {
            // zero-copy fan-out: every member shares the same window
            let item = BatchItem {
                query_id: id,
                input: Arc::clone(&q.leads[leads[&m]]),
                enqueued: q.emitted,
            };
            if model_txs[&m].send(item).is_err() {
                // batcher died: evict the query; members already
                // dispatched find no entry and are skipped. Count before
                // dropping the entry so the failure is visible by the
                // time the caller's reply channel hangs up.
                let evicted = pending.remove(id);
                if evicted.is_some() {
                    telemetry.failures.fetch_add(1, Ordering::Relaxed);
                }
                drop(evicted);
                break;
            }
        }
    }
    // router exit drops model_txs → batchers drain and exit
}

fn collector_loop(
    rx: mpsc::Receiver<ModelReport>,
    pending: Arc<PendingTable>,
    telemetry: Arc<Telemetry>,
) {
    for report in rx {
        match report {
            ModelReport::Score(s) => {
                telemetry.exec.record(s.exec_time);
                telemetry.model_jobs.fetch_add(1, Ordering::Relaxed);
                let done = {
                    let mut table =
                        pending.stripe(s.query_id).lock().expect("pending stripe poisoned");
                    let Some(entry) = table.get_mut(&s.query_id) else { continue };
                    entry.member_scores.push((s.model_index, s.score));
                    entry.remaining -= 1;
                    if s.queue_wait < entry.min_queue_wait {
                        entry.min_queue_wait = s.queue_wait;
                    }
                    if entry.remaining == 0 {
                        table.remove(&s.query_id)
                    } else {
                        None
                    }
                };
                if let Some(entry) = done {
                    finish(entry, &telemetry);
                }
            }
            ModelReport::Failed { query_id, .. } => {
                // Evict: dropping the entry drops its reply sender, so a
                // blocked submit()/query() caller unblocks with an error
                // instead of waiting on `remaining > 0` forever. Count
                // one failure per evicted query (not per failing member),
                // and count before dropping so it is visible by the time
                // the caller observes the hang-up.
                let evicted = pending.remove(query_id);
                if evicted.is_some() {
                    telemetry.failures.fetch_add(1, Ordering::Relaxed);
                }
                drop(evicted);
            }
        }
    }
}

/// Complete one query: deterministic bagging mean + telemetry + reply.
fn finish(mut entry: PendingQuery, telemetry: &Telemetry) {
    let e2e = entry.emitted.elapsed();
    telemetry.e2e.record(e2e);
    telemetry.queueing.record(entry.min_queue_wait);
    telemetry.queries.fetch_add(1, Ordering::Relaxed);
    // sum in model-index order so the bagging mean does not depend on
    // score arrival order (f64 addition is not associative)
    entry.member_scores.sort_unstable_by_key(|&(m, _)| m);
    let sum: f64 = entry.member_scores.iter().map(|&(_, s)| s as f64).sum();
    let prediction = Prediction {
        patient: entry.patient,
        window_id: entry.window_id,
        sim_end: entry.sim_end,
        score: sum / entry.n_models as f64,
        n_models: entry.n_models,
        e2e,
        queueing: entry.min_queue_wait,
    };
    if let Some(reply) = entry.reply {
        let _ = reply.send(prediction);
    }
}
