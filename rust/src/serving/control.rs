//! SLO-aware adaptive batch-deadline controller.
//!
//! The paper's central claim is that ICU serving must navigate the
//! accuracy/latency tradeoff *under a latency SLO while load varies*.
//! Until this module, the executor's fill deadline was a compile-time
//! constant ([`BatchPolicy::timeout`], 1 ms): right for the average
//! case, wrong at both extremes — under a burst a partial tail batch
//! still waits the full fill window (pure added queueing), and under a
//! trickle the window is too short to amortize device launches.
//!
//! [`DeadlineController`] replaces the constant with a bounded dynamic
//! fill wait computed from **live** signals:
//!
//! * the lane's queue depth (the executor's [`ExecutorGauges`] counter,
//!   read at arm time) — a filling lane needs less patience, a full one
//!   none at all;
//! * the rolling T_q/T_s split (the `queueing`/`exec` histograms, whose
//!   percentiles stay live forever now that they fall back to the
//!   log-scale buckets once the sample reservoir saturates);
//! * the configured end-to-end SLO (`--slo-ms`, default 1000 ms — the
//!   paper's sub-second bound).
//!
//! ## Control law
//!
//! ```text
//!   pressure = (T_q(p95) + T_s(p95)) / SLO          observed tail vs budget
//!   scale    = clamp(1 − pressure, 0, 1)            1 = idle, 0 = at the SLO
//!   wait     = min + (max − min) · scale · (B − depth)/B
//! ```
//!
//! where `B` is the *effective* fill cap (the executor's `max_take` —
//! `policy.max_batch` clamped to the largest compiled batch size), and
//! the result is clamped to `[timeout_min, timeout_max]`: the moment
//! `depth ≥ B` the wait collapses to the floor, `timeout_min` (0 by
//! default — and the executor's due-check flushes a full batch
//! immediately regardless of the armed wait, so a nonzero floor only
//! shows up in the gauges, never as an actual full-batch delay). Under burst/overload
//! both factors collapse the wait toward immediate flush: queueing is
//! shed and batches grow to the fill cap on backlog alone. Under
//! trickle load the wait relaxes toward `timeout_max`, amortizing
//! device launches. The SLO term is refreshed at most once per
//! millisecond (a cached permille scale behind one atomic), so the
//! per-push cost is two relaxed loads.
//!
//! ## Determinism contract
//!
//! Adaptation changes *when* a lane's batch flushes — never which model
//! scores a query, the per-member score cells, or the model-index-order
//! summation. Predictions are bit-for-bit identical with adaptation on
//! or off, for any worker count (`tests/executor.rs`).
//!
//! With [`BatchPolicy::adaptive`] unset the controller is inert: every
//! query returns the static `timeout`, i.e. exactly the pre-controller
//! policy.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::batcher::BatchPolicy;
use super::telemetry::Telemetry;

/// The paper's end-to-end serving bound: sub-second predictions.
pub const DEFAULT_SLO: Duration = Duration::from_millis(1000);

/// How stale the cached SLO-pressure scale may get before a caller
/// recomputes it from the live histograms.
const REFRESH_NS: u64 = 1_000_000; // 1 ms

/// Per-lane adaptive fill-deadline controller (see the module docs for
/// the control law). One instance per executor; shared with the
/// pipeline so `/stats` and the bedside report can surface the adapted
/// deadlines per model.
pub struct DeadlineController {
    adaptive: bool,
    static_wait_ns: u64,
    min_ns: u64,
    max_ns: u64,
    /// Depth at which a batch is *actually* full — the executor's
    /// effective `max_take` (`policy.max_batch` clamped to the largest
    /// compiled batch size), not the nominal policy knob.
    max_fill: u64,
    slo_ns: u64,
    /// Live T_q/T_s source; `None` = depth-only adaptation (tests,
    /// benches driving the executor without a pipeline).
    telemetry: Option<Arc<Telemetry>>,
    epoch: Instant,
    /// Cached SLO-headroom scale, permille in `[0, 1000]`.
    scale_pm: AtomicU64,
    /// Nanos-since-epoch after which the scale must be recomputed.
    refresh_at_ns: AtomicU64,
    /// Last computed fill wait per lane, ns — the observability gauge
    /// behind `/stats` `fill_wait_ns_per_model` and the bedside report.
    lane_waits: Arc<[AtomicU64]>,
}

impl DeadlineController {
    /// Controller for `n_lanes` ensemble members under `policy`, with
    /// `slo` as the end-to-end budget. `max_fill` is the depth at which
    /// a batch really flushes full — callers inside the executor pass
    /// the effective `max_take` so the depth relaxation is calibrated
    /// to actual flush sizes, not the nominal `policy.max_batch`.
    /// `telemetry` feeds the rolling T_q/T_s split; without it the SLO
    /// term stays at full headroom and only queue depth adapts the
    /// wait.
    pub fn new(
        n_lanes: usize,
        policy: &BatchPolicy,
        max_fill: usize,
        slo: Duration,
        telemetry: Option<Arc<Telemetry>>,
    ) -> Self {
        let ns = |d: Duration| u64::try_from(d.as_nanos()).unwrap_or(u64::MAX);
        let static_wait_ns = ns(policy.timeout);
        let min_ns = ns(policy.timeout_min);
        // a cap below the floor would make the clamp range empty
        let max_ns = ns(policy.timeout_max).max(min_ns);
        let lane_waits: Arc<[AtomicU64]> = (0..n_lanes)
            .map(|_| AtomicU64::new(if policy.adaptive { max_ns } else { static_wait_ns }))
            .collect();
        DeadlineController {
            adaptive: policy.adaptive,
            static_wait_ns,
            min_ns,
            max_ns,
            max_fill: max_fill.max(1) as u64,
            slo_ns: ns(slo).max(1),
            telemetry,
            epoch: Instant::now(),
            scale_pm: AtomicU64::new(1000),
            refresh_at_ns: AtomicU64::new(0),
            lane_waits,
        }
    }

    /// Convenience for standalone callers (tests): nominal
    /// `policy.max_batch` fill cap, default SLO, no telemetry — static
    /// policies are exactly preserved and adaptive ones adapt on queue
    /// depth alone.
    pub fn for_policy(n_lanes: usize, policy: &BatchPolicy) -> Self {
        Self::new(n_lanes, policy, policy.max_batch, DEFAULT_SLO, None)
    }

    pub fn is_adaptive(&self) -> bool {
        self.adaptive
    }

    pub fn slo(&self) -> Duration {
        Duration::from_nanos(self.slo_ns)
    }

    /// Number of lanes this controller was built for.
    pub fn lanes(&self) -> usize {
        self.lane_waits.len()
    }

    /// Shared per-lane gauges of the last computed fill wait (ns).
    pub fn lane_waits(&self) -> Arc<[AtomicU64]> {
        Arc::clone(&self.lane_waits)
    }

    /// The fill wait (ns) to arm for `lane` given its current queue
    /// depth — the executor adds this to "now" to form the lane's flush
    /// deadline. Static policies return `timeout` unconditionally.
    pub fn fill_wait_ns(&self, lane: usize, depth: usize) -> u64 {
        if !self.adaptive {
            return self.static_wait_ns;
        }
        let wait = if depth as u64 >= self.max_fill {
            // a full batch flushes now (the clamp below restores the
            // configured floor if one is set)
            0
        } else {
            let scale = self.scale_pm();
            let span = self.max_ns - self.min_ns;
            let fill = self.max_fill - depth as u64;
            // headroom × linear depth relaxation, landing in [min, max]
            let scaled =
                span as u128 * scale as u128 * fill as u128 / (1000 * self.max_fill as u128);
            self.min_ns.saturating_add(scaled as u64)
        };
        let wait = wait.clamp(self.min_ns, self.max_ns);
        if let Some(g) = self.lane_waits.get(lane) {
            g.store(wait, Ordering::Relaxed);
        }
        wait
    }

    /// Cached SLO-headroom scale (permille), recomputed from the live
    /// histograms at most every [`REFRESH_NS`].
    fn scale_pm(&self) -> u64 {
        let Some(telemetry) = &self.telemetry else {
            return 1000;
        };
        let now = u64::try_from(self.epoch.elapsed().as_nanos()).unwrap_or(u64::MAX);
        let due = self.refresh_at_ns.load(Ordering::Relaxed);
        if now >= due
            && self
                .refresh_at_ns
                .compare_exchange(
                    due,
                    now.saturating_add(REFRESH_NS),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                )
                .is_ok()
        {
            // exactly one caller per refresh window walks the buckets
            let pm = Self::compute_scale_pm(telemetry, self.slo_ns);
            self.scale_pm.store(pm, Ordering::Relaxed);
            pm
        } else {
            self.scale_pm.load(Ordering::Relaxed)
        }
    }

    fn compute_scale_pm(telemetry: &Telemetry, slo_ns: u64) -> u64 {
        // rolling T_q/T_s split: queueing p95 + per-job execution p95.
        // Deliberately the bucket-only estimator: this runs on the
        // deadline-arm path, and the exact-reservoir path would clone +
        // sort up to 100k samples under the recorder mutex per refresh.
        if telemetry.queueing.count() == 0 && telemetry.exec.count() == 0 {
            return 1000; // no traffic observed yet: full headroom
        }
        let tail_s =
            telemetry.queueing.percentile_fast(95.0) + telemetry.exec.percentile_fast(95.0);
        let pressure = tail_s / (slo_ns as f64 / 1e9);
        ((1.0 - pressure).clamp(0.0, 1.0) * 1000.0) as u64
    }
}

impl std::fmt::Debug for DeadlineController {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DeadlineController")
            .field("adaptive", &self.adaptive)
            .field("static_wait_ns", &self.static_wait_ns)
            .field("min_ns", &self.min_ns)
            .field("max_ns", &self.max_ns)
            .field("max_fill", &self.max_fill)
            .field("slo_ns", &self.slo_ns)
            .field("scale_pm", &self.scale_pm.load(Ordering::Relaxed))
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adaptive_policy() -> BatchPolicy {
        BatchPolicy {
            max_batch: 8,
            timeout: Duration::from_millis(1),
            timeout_min: Duration::ZERO,
            timeout_max: Duration::from_millis(4),
            adaptive: true,
        }
    }

    #[test]
    fn static_policy_is_inert() {
        let policy = BatchPolicy { timeout: Duration::from_millis(3), ..BatchPolicy::default() };
        let ctrl = DeadlineController::for_policy(2, &policy);
        assert!(!ctrl.is_adaptive());
        for depth in [0usize, 4, 8, 100] {
            assert_eq!(ctrl.fill_wait_ns(0, depth), 3_000_000);
        }
    }

    #[test]
    fn trickle_relaxes_to_the_cap() {
        let ctrl = DeadlineController::for_policy(1, &adaptive_policy());
        // empty lane, no latency pressure: the full fill window
        assert_eq!(ctrl.fill_wait_ns(0, 0), 4_000_000);
        // and it is monotone non-increasing in depth
        let mut last = u64::MAX;
        for depth in 0..=8 {
            let w = ctrl.fill_wait_ns(0, depth);
            assert!(w <= last, "depth {depth}: {w} > {last}");
            assert!(w <= 4_000_000);
            last = w;
        }
    }

    #[test]
    fn burst_backlog_shrinks_the_deadline_to_zero() {
        let ctrl = DeadlineController::for_policy(1, &adaptive_policy());
        // a full (or over-full) batch never waits
        assert_eq!(ctrl.fill_wait_ns(0, 8), 0);
        assert_eq!(ctrl.fill_wait_ns(0, 64), 0);
        // near-full: only a sliver of the window remains
        assert!(ctrl.fill_wait_ns(0, 7) <= 4_000_000 / 8);
    }

    #[test]
    fn slo_pressure_shrinks_the_deadline_toward_immediate_flush() {
        let telemetry = Arc::new(Telemetry::default());
        // observed tail latency already AT the SLO: zero headroom
        for _ in 0..32 {
            telemetry.queueing.record(Duration::from_millis(900));
            telemetry.exec.record(Duration::from_millis(300));
        }
        let ctrl = DeadlineController::new(
            1,
            &adaptive_policy(),
            8,
            Duration::from_millis(1000),
            Some(Arc::clone(&telemetry)),
        );
        // even an empty lane flushes (nearly) immediately under
        // overload: wait collapses to timeout_min = 0
        assert_eq!(ctrl.fill_wait_ns(0, 0), 0, "overload must shed queueing");
    }

    #[test]
    fn slo_headroom_keeps_the_window_open() {
        let telemetry = Arc::new(Telemetry::default());
        for _ in 0..32 {
            telemetry.queueing.record(Duration::from_micros(50));
            telemetry.exec.record(Duration::from_micros(200));
        }
        let ctrl = DeadlineController::new(
            1,
            &adaptive_policy(),
            8,
            Duration::from_millis(1000),
            Some(telemetry),
        );
        // tail ≈ 250 µs of a 1 s budget: essentially full headroom
        assert!(ctrl.fill_wait_ns(0, 0) >= 3_900_000);
    }

    #[test]
    fn effective_fill_cap_overrides_the_nominal_policy_knob() {
        // policy asks for 32-deep batches but the engine only compiles
        // batch-8: the executor hands the controller max_take = 8, so
        // depth 7 is one item short of a REAL full flush — a sliver of
        // the window — and depth 8 waits nothing at all
        let policy = BatchPolicy { max_batch: 32, ..adaptive_policy() };
        let ctrl = DeadlineController::new(1, &policy, 8, DEFAULT_SLO, None);
        assert_eq!(ctrl.fill_wait_ns(0, 8), 0);
        assert!(ctrl.fill_wait_ns(0, 7) <= 4_000_000 / 8);
    }

    #[test]
    fn wait_is_always_inside_the_configured_bounds() {
        let policy = BatchPolicy {
            max_batch: 8,
            timeout: Duration::from_millis(1),
            timeout_min: Duration::from_micros(100),
            timeout_max: Duration::from_millis(2),
            adaptive: true,
        };
        let ctrl = DeadlineController::for_policy(1, &policy);
        for depth in 0..=16 {
            let w = ctrl.fill_wait_ns(0, depth);
            assert!((100_000..=2_000_000).contains(&w), "depth {depth}: {w}");
        }
    }

    #[test]
    fn lane_gauges_expose_the_adapted_wait() {
        let ctrl = DeadlineController::for_policy(2, &adaptive_policy());
        let gauges = ctrl.lane_waits();
        assert_eq!(gauges.len(), 2);
        ctrl.fill_wait_ns(1, 8);
        assert_eq!(gauges[1].load(Ordering::Relaxed), 0);
        ctrl.fill_wait_ns(1, 0);
        assert_eq!(gauges[1].load(Ordering::Relaxed), 4_000_000);
        // lane 0 untouched: still the construction-time default (cap)
        assert_eq!(gauges[0].load(Ordering::Relaxed), 4_000_000);
    }
}
