//! Real-time ensemble serving (paper §3.4, Fig. 4).
//!
//! The pipeline is the rust substitute for the Ray layer the paper
//! builds on — with one deliberate inversion: where the paper (and the
//! old plane here) dedicates an actor/thread per model, execution now
//! runs on a **fixed work-stealing pool**, so thread count follows the
//! hardware, not the ensemble:
//!
//! ```text
//!  bedside streams ──► router tier (optional, `holmes route --peers`)
//!        │     owns the ingest edge; a consistent-hash ring over
//!        │     patient id (crate::router::ring, 64 vnodes/peer) picks
//!        │     the owning `holmes serve` peer; per-peer links forward
//!        │     frame batches over the wire codec, a heartbeat prober
//!        │     quarantines dead peers (canary re-probe on backoff) and
//!        │     re-homes their patients to survivors, replaying the
//!        │     link's spill buffer — see crate::router
//!        ▼ (or directly, single-node)
//!  HTTP ingest edge / in-process ingest
//!        │     (epoll event loops, --edge-threads of them: keep-alive
//!        │      connections decode wire frames IN PLACE from their
//!        │      receive buffers — no body buffer, no per-frame alloc —
//!        │      see crate::http; gauges: conns_active/accepted/refused)
//!        │ 250 Hz ECG, 1 Hz vitals   (ShardSender: patient % N)
//!        ▼
//!  [stateful]  N aggregation shards, each owning its patients'
//!        │     WindowAggregators, filling pooled lead buffers
//!        │     (per-shard LeadPool slab; buffers recycle on last drop)
//!        │ one ensemble Query per ΔT window (WindowLease × 3)
//!        ▼
//!  dispatcher ──► per-model lanes ──► executor pool (--workers threads)
//!        │ epoch E's   (lock-free queues,   │ claim ready lane, pack,
//!        │ members     fill deadlines ◄─────│ execute inline (DirectWorker,
//!        │ only        armed by the         ▼ gpu-count device permits)
//!        │ ▲           DeadlineController)  │ compile once per ArtifactId
//!        │ │                                │ × batch via the process-wide
//!        │ │                                │ ExecCache (single-flight; all
//!        │ │                                │ workers share one executable)
//!        │ │ Install(E+1): hot swap, FIFO vs admissions
//!        │ │
//!        │ Governor (--govern): control ticks read live pressure
//!        │ (T_q+T_s tails vs SLO), recompose via Composer::search on
//!        │ live lane service times, degrade to the accuracy floor
//!        │ under overload (hysteresis back up), quarantine dead lanes
//!        │ and reinstate them after a canary batch succeeds; every
//!        │ membership install re-derives the ArtifactId demand through
//!        │ the engine's ArtifactCatalog and republishes the node's
//!        │ required/resident counts (the heartbeat's "resident" field)
//!        ▼
//!  [stateless]  Completer (direct, collector-less): whichever worker
//!               records a query's last member score finishes it
//!               inline: bagging mean (Eq. 5) over the query's OWN
//!               admission-epoch member set + telemetry
//! ```
//!
//! ## SLO-aware adaptive batch deadlines
//!
//! `holmes serve --adaptive-batch [--slo-ms 1000]` replaces the static
//! per-lane batch fill deadline ([`batcher::BatchPolicy::timeout`])
//! with a bounded dynamic wait from the [`control::DeadlineController`]:
//! live lane queue depth and the rolling T_q/T_s tail (kept live
//! forever by bucket-derived percentiles, [`LatencyHistogram`]) steer
//! the wait inside `[timeout_min, timeout_max]` against the configured
//! end-to-end SLO. Burst/overload → flush immediately and let backlog
//! fill batches; trickle → wait the full cap to amortize device
//! launches. Off by default; predictions are bit-for-bit identical with
//! adaptation on or off (`tests/executor.rs`). The adapted deadline per
//! model is observable via `/stats` (`fill_wait_ns_per_model`) and the
//! bedside report.
//!
//! ## The ensemble governor (live re-composition + failure recovery)
//!
//! `holmes serve --govern [--control-tick-ms 100] [--floor-acc 0.8]`
//! spawns the supervisory control plane of [`governor`]: each tick it
//! reads the live tail-latency pressure and lane health, re-scores
//! candidate ensembles with the paper's composer over *live* per-lane
//! service-time EWMAs, and hot-swaps membership through the router's
//! FIFO `Install` message — queries admitted under epoch E complete
//! under E's member set, bit-identically for any swap schedule
//! (`tests/governor.rs`). Sustained overload steps the ensemble down to
//! the smallest member set still clearing `--floor-acc` (and back up
//! with hysteresis); a lane whose backend fails is quarantined,
//! re-probed with exponentially backed-off canary batches, and
//! reinstated when the backend heals — previously it was dead forever.
//!
//! ## Adversarial scenario catalog (`holmes replay`)
//!
//! The serving plane's robustness claims are gated, not asserted in
//! prose: `holmes replay --scenario <name> --seed <n>` drives this
//! whole pipeline with a seeded fault scenario from
//! [`crate::ingest::scenario`] and exits nonzero unless every live
//! counter matches the scenario's precomputed fault budget and every
//! latency/recovery invariant holds ([`crate::exp::replay`]):
//!
//! | scenario | fault shape | gated invariants |
//! |---|---|---|
//! | `churn` | admission/discharge waves cycling a 2×-capacity id universe through the shard LRU | zero drops; evictions = admissions − capacity, identical on 1/2/8 shards; every admission's window predicts |
//! | `dropout-resync` | per-bed ECG dropout + TCP link sever mid-run, vitals continue | every window resolves; zero stale sheds on resync; client redials ≥ severs (HTTP) |
//! | `clock-skew` | two virtual monitors per bed, one clock 2.5 sample periods behind | stale sheds exactly equal the budget; windows unaffected on in-skew beds |
//! | `burst-storm` | 3×-bed ghost admission wave on a slowed backend | every admitted query resolves; p95 back under SLO after the storm (`recovery_p95`) |
//! | `hostile-edge` | malformed arities, absurd patient ids, corrupt/truncated/NaN wire bodies, conn flood, slow loris | all bad bodies 400'd; flood 503s = over-cap counter; loris conns reaped; cohort windows untouched |
//! | `vendor-skew` | one monitor vendor's clocks drift together (correlated, rate-ramped) | stale sheds exactly equal the drift-onset budget; the other vendor's beds untouched |
//! | `node-loss` | router + 2 peers; the peer owning patient 0 is killed mid-cohort and restarted later | every admitted query resolves; exactly the victim's patients re-home (ring mirror); spilled frames all replayed, zero overflow; peer canary-reinstated |
//!
//! The same seed reproduces the same shed/evict/window/prediction
//! accounting — including a score fingerprint — bit for bit across
//! shard and worker counts (`tests/replay.rs`); three scenarios run
//! seeded in CI beside the bedside smokes.
//!
//! ## One artifact identity from disk to device
//!
//! Every model executable is named by a content-addressed
//! [`ArtifactId`](crate::registry::ArtifactId) — a digest over the HLO
//! bytes plus the input shape and MACs profile — resolved through the
//! engine's [`ArtifactCatalog`](crate::runtime::ArtifactCatalog). The
//! serving tier threads that single identity end to end:
//!
//! * the executor compiles through the process-wide, single-flight
//!   [`ExecCache`](crate::runtime::ExecCache) keyed on
//!   `(ArtifactId, batch)`, so W workers share one compiled executable
//!   per distinct key (`exec_cache_{hits,misses,compiles}` in `/stats`);
//! * `holmes serve --registry-root DIR` opens a content-addressed
//!   [`LocalFs`](crate::registry::LocalFs) store, publishes the zoo's
//!   bundles, and serves them to peers over `GET /artifact/<id>`
//!   (every fetch re-digests — a corrupt blob is never served);
//! * a cold node (`--registry HOST:PORT`) pulls the active ensemble's
//!   artifacts from a warm peer before claiming `"resident":true` on
//!   heartbeats; the router treats a live-but-non-resident peer like a
//!   draining one — re-homed away from, not admitted — until residency
//!   is proven (`crate::router::health`, gated by the `--cold-peer`
//!   route smoke in CI).
//!
//! Stateful compute (aggregation) and stateless compute (model
//! inference) are separated exactly as the paper requires of its
//! serving platform.
//!
//! The data plane is zero-copy, lock-free, **fan-in free**, and
//! **allocation-recycling** end to end: no single thread touches every
//! frame (patients are sharded over N aggregation workers, [`shards`])
//! and no single thread touches every score (workers complete queries
//! directly through the lock-free pending arena,
//! [`pipeline::Completer`]). Aggregators fill recycled lead buffers
//! from per-shard slabs ([`arena::LeadPool`]) and seal them into shared
//! [`arena::WindowLease`]s; the dispatcher fans references (not copies)
//! to every member's lane; per-query bagging state lives in a
//! preallocated generation-tagged slot arena updated purely with
//! atomics ([`pipeline::PendingSlots`]); each executor worker packs
//! into one persistent 64-byte-aligned batch arena and executes inline
//! through [`DirectWorker`](crate::runtime::DirectWorker) under the
//! engine's device permits; and frames themselves carry their payload
//! inline ([`crate::ingest::FrameValues`]). Model-count no longer sets
//! the thread count: the executor pool size is a CLI tunable
//! (`--workers`), observable per lane and per worker through
//! [`telemetry::ExecutorGauges`]. See [`pipeline`] for the architecture
//! diagram and [`executor`] for the scheduling rules. Model execution
//! goes through the pluggable [`ExecBackend`](crate::runtime::ExecBackend)
//! (sim by default, PJRT with `--features xla`).

pub mod aggregator;
pub mod arena;
pub mod batcher;
pub mod control;
pub mod executor;
pub mod governor;
pub mod pipeline;
pub mod profile;
pub mod shards;
pub mod telemetry;

pub use aggregator::WindowAggregator;
pub use arena::{LeadPool, LeadSlot, WindowLease};
pub use control::{DeadlineController, DEFAULT_SLO};
pub use executor::{default_workers, default_workers_for};
pub use governor::{Governor, GovernorConfig, GovernorCore};
pub use pipeline::{
    share_leads, Completer, MemberSet, PendingSlots, Pipeline, PipelineConfig, Prediction, Query,
    ScoreOutcome,
};
pub use shards::{default_shards, ShardConfig, ShardRouter, ShardSender};
pub use telemetry::{
    EdgeGauges, ExecutorGauges, GovernorGauges, LatencyHistogram, RouterGauges, Telemetry,
};
