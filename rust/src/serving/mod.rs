//! Real-time ensemble serving (paper §3.4, Fig. 4).
//!
//! The pipeline is a set of actor threads — the rust substitute for the
//! Ray layer the paper builds on:
//!
//! ```text
//!  bedside streams ──► HTTP server / in-process ingest
//!        │ 250 Hz ECG, 1 Hz vitals   (ShardSender: patient % N)
//!        ▼
//!  [stateful]  N aggregation shards, each owning its patients'
//!        │     WindowAggregators (bounded per-shard frame queues)
//!        │ one ensemble Query per ΔT window
//!        ▼
//!  dispatcher ──► per-model Batcher actors ──► PJRT Engine workers
//!        │              │                         ("GPUs")
//!        ▼              ▼ Completer (direct, collector-less)
//!  [stateless]  whichever batcher records a query's last member score
//!               finishes it inline: bagging mean (Eq. 5) + telemetry
//! ```
//!
//! Stateful compute (aggregation) and stateless compute (model
//! inference) are separated exactly as the paper requires of its
//! serving platform.
//!
//! The data plane is zero-copy, lock-free, and **fan-in free** end to
//! end: no single thread touches every frame (patients are sharded over
//! N aggregation workers, [`shards`]) and no single thread touches
//! every score (batchers complete queries directly through the
//! lock-free pending arena, [`pipeline::Completer`] — the old collector
//! thread and its MPSC fan-in are gone). Aggregators emit lead windows
//! as `Arc<[f32]>`, the dispatcher fans references (not copies) to
//! every member's batcher, per-query bagging state lives in a
//! preallocated generation-tagged slot arena updated purely with
//! atomics ([`pipeline::PendingSlots`]), each batcher packs into one
//! persistent 64-byte-aligned batch arena, and frames themselves carry
//! their payload inline ([`crate::ingest::FrameValues`] — no per-frame
//! heap traffic anywhere). See [`pipeline`] for the architecture
//! diagram. Model execution goes through the pluggable
//! [`ExecBackend`](crate::runtime::ExecBackend) (sim by default, PJRT
//! with `--features xla`).

pub mod aggregator;
pub mod batcher;
pub mod pipeline;
pub mod profile;
pub mod shards;
pub mod telemetry;

pub use aggregator::WindowAggregator;
pub use pipeline::{
    share_leads, Completer, PendingSlots, Pipeline, PipelineConfig, Prediction, Query,
    ScoreOutcome,
};
pub use shards::{default_shards, ShardConfig, ShardRouter, ShardSender};
pub use telemetry::{LatencyHistogram, Telemetry};
