//! Real-time ensemble serving (paper §3.4, Fig. 4).
//!
//! The pipeline is a set of tokio actors — the rust substitute for the
//! Ray layer the paper builds on:
//!
//! ```text
//!  bedside streams ──► HTTP server / in-process ingest
//!        │ 250 Hz ECG, 1 Hz vitals
//!        ▼
//!  [stateful]  per-patient WindowAggregator actors
//!        │ one ensemble Query per ΔT window
//!        ▼
//!  dispatcher ──► per-model Batcher actors ──► PJRT Engine workers
//!        │                                        ("GPUs")
//!        ▼
//!  [stateless]  collector: bagging mean (Eq. 5) + telemetry
//! ```
//!
//! Stateful compute (aggregation) and stateless compute (model
//! inference) are separated exactly as the paper requires of its
//! serving platform.

pub mod aggregator;
pub mod batcher;
pub mod pipeline;
pub mod profile;
pub mod telemetry;

pub use aggregator::WindowAggregator;
pub use pipeline::{Pipeline, PipelineConfig, Prediction, Query};
pub use telemetry::{LatencyHistogram, Telemetry};
