//! Real-time ensemble serving (paper §3.4, Fig. 4).
//!
//! The pipeline is a set of tokio actors — the rust substitute for the
//! Ray layer the paper builds on:
//!
//! ```text
//!  bedside streams ──► HTTP server / in-process ingest
//!        │ 250 Hz ECG, 1 Hz vitals
//!        ▼
//!  [stateful]  per-patient WindowAggregator actors
//!        │ one ensemble Query per ΔT window
//!        ▼
//!  dispatcher ──► per-model Batcher actors ──► PJRT Engine workers
//!        │                                        ("GPUs")
//!        ▼
//!  [stateless]  collector: bagging mean (Eq. 5) + telemetry
//! ```
//!
//! Stateful compute (aggregation) and stateless compute (model
//! inference) are separated exactly as the paper requires of its
//! serving platform.
//!
//! The data plane is zero-copy and lock-free end to end: aggregators
//! emit lead windows as `Arc<[f32]>`, the dispatcher fans references
//! (not copies) to every member's batcher, per-query bagging state
//! lives in a preallocated generation-tagged slot arena updated purely
//! with atomics ([`pipeline::PendingSlots`]), and each batcher packs
//! into one persistent 64-byte-aligned batch arena — see [`pipeline`]
//! for the architecture diagram.
//! Model execution goes through the pluggable
//! [`ExecBackend`](crate::runtime::ExecBackend) (sim by default, PJRT
//! with `--features xla`).

pub mod aggregator;
pub mod batcher;
pub mod pipeline;
pub mod profile;
pub mod telemetry;

pub use aggregator::WindowAggregator;
pub use pipeline::{
    share_leads, PendingSlots, Pipeline, PipelineConfig, Prediction, Query, ScoreOutcome,
};
pub use telemetry::{LatencyHistogram, Telemetry};
