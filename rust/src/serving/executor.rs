//! Work-stealing model executor: a fixed pool of workers serving every
//! ensemble member, replacing the one-OS-thread-per-model batcher
//! actors.
//!
//! The paper's deployment runs one Ray actor per selected model; the
//! old rust analogue spawned one thread per model. That makes tail
//! latency a function of *ensemble size*: 16 models on 4 cores thrash,
//! 3 models on 64 cores idle. Here the thread count is a tunable
//! (`--workers`, core-count default) independent of how many models the
//! composer picked:
//!
//! * **Lanes** — one per ensemble member: a lock-free injection queue
//!   (Treiber stack, drained FIFO by the claiming worker), a staged
//!   batch (exclusive to the claim holder), a flush deadline, and the
//!   member's [`Completer`]. The router pushes items; it never blocks
//!   on a busy model.
//! * **Ready check** — a lane is claimable when it has work that is
//!   *due*: a full batch, an elapsed fill deadline ([`BatchPolicy`]
//!   semantics, per model, exactly as the actor loop enforced them),
//!   a dead lane with backlog to fail, or shutdown drain. The fill
//!   deadline itself comes from the lane's [`DeadlineController`]: the
//!   static `policy.timeout` by default, or — with `--adaptive-batch` —
//!   a bounded dynamic wait derived from the lane's live queue depth
//!   and the rolling T_q/T_s tail versus the configured SLO (see
//!   [`super::control`]). Adaptation moves *when* batches flush only;
//!   scores and their summation order are untouched, so worker-count
//!   (and adaptive-on/off) bit-invariance holds.
//! * **Claim → flush → release** — any worker CASes the lane's claim
//!   flag, drains the injection queue into the staged batch, packs into
//!   its own persistent 64-byte-aligned arena, executes **inline** on
//!   its [`DirectWorker`](crate::runtime::DirectWorker) handle
//!   (bounded by the engine's device
//!   permits, so the GPU-count resource model survives), and completes
//!   every slot directly through the lane's `Completer`. Crucially a
//!   worker never sleeps holding a lane: a partially filled batch gets
//!   a deadline and the worker moves to the next ready lane.
//!
//! Determinism: member scores land in per-model cells summed in
//! model-index order at completion, so predictions are bit-for-bit
//! identical for any worker count (`tests/executor.rs` proves 1, 2 and
//! 8 workers against the analytic reference).
//!
//! Failure: a *transient* execution error gets one bounded retry with a
//! jittered backoff inside [`flush_batch`] (counted per lane in
//! [`ExecutorGauges`](super::telemetry::ExecutorGauges)); a second
//! failure fails the flushed batch through [`Completer::fail`] (evicting
//! those queries), marks the lane dead, and fails its backlog — panics
//! skip the retry and fail fast. Subsequent router pushes to the dead
//! lane error so the router evicts exactly the affected queries — the
//! same contract the dying batcher thread used to provide. Dead is no
//! longer forever: the governor ([`super::governor`]) quarantines the
//! lane out of the active membership, re-probes the backend with a
//! canary batch under exponential backoff, and calls
//! [`Executor::revive_lane`] once the canary succeeds.
//!
//! Shutdown: dropping the last [`LaneSender`] (the router exiting)
//! closes the executor; workers drain every lane — partial batches
//! flush regardless of deadline (final-drain semantics) — and exit once
//! all lanes are empty.

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::ptr;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::batcher::{fail_front, flush_batch, largest_batch, BatchItem, BatchPolicy, FlushOutcome};
use super::control::DeadlineController;
use super::pipeline::Completer;
use super::telemetry::Telemetry;
use crate::runtime::{AlignedBatch, Engine};
use crate::{Error, Result};

/// Hardware-only core-count heuristic for the worker pool, clamped to
/// [1, 16]. This knows nothing about the engine — the pipeline's
/// *effective* default is [`default_workers_for`], which additionally
/// caps at the configured device-permit count.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 16)
}

/// Effective pool-size default for an engine configured with
/// `device_permits` device permits (the paper's `n_gpus`): at most two
/// workers per permit. Only `device_permits` backend executions ever
/// run concurrently, so extra threads can only overlap packing and
/// completion with execution — useful up to roughly one spare thread
/// per busy device, pure scheduler pressure beyond that. (The old
/// default clamped to a flat 16 while *claiming* permit saturation; a
/// 2-GPU engine on a 16-core box spawned 16 workers for 2 permits.)
///
/// This caps the **default** only: an explicit worker count
/// (`--workers N` / `PipelineConfig::workers`) is honored verbatim,
/// above or below the cap.
pub fn default_workers_for(device_permits: usize) -> usize {
    default_workers().clamp(1, (2 * device_permits).max(1))
}

// ---------------------------------------------------------------------------
// Lock-free injection queue
// ---------------------------------------------------------------------------

struct Node {
    item: BatchItem,
    next: *mut Node,
}

/// Treiber-stack MPSC injection queue: producers push with a CAS; the
/// (single, claim-holding) consumer detaches the whole stack with one
/// swap and replays it oldest-first. No locks anywhere on the path.
struct InjectQueue {
    head: AtomicPtr<Node>,
}

impl InjectQueue {
    fn new() -> Self {
        InjectQueue { head: AtomicPtr::new(ptr::null_mut()) }
    }

    fn push(&self, item: BatchItem) {
        let node = Box::into_raw(Box::new(Node { item, next: ptr::null_mut() }));
        let mut head = self.head.load(Ordering::Relaxed);
        loop {
            // SAFETY: `node` is ours until the CAS publishes it.
            unsafe { (*node).next = head };
            match self
                .head
                .compare_exchange_weak(head, node, Ordering::Release, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => head = seen,
            }
        }
    }

    /// Detach everything pushed so far and append it to `staged` in
    /// FIFO order; returns how many items moved. Allocation-free: the
    /// detached chain is reversed in place (the stack is newest-first)
    /// and then walked oldest-first.
    fn drain_into(&self, staged: &mut VecDeque<BatchItem>) -> usize {
        let mut p = self.head.swap(ptr::null_mut(), Ordering::Acquire);
        if p.is_null() {
            return 0;
        }
        // SAFETY (whole function): nodes were leaked by `push` and the
        // swap above gave this thread exclusive ownership of the chain.
        let mut prev: *mut Node = ptr::null_mut();
        while !p.is_null() {
            let next = unsafe { (*p).next };
            unsafe { (*p).next = prev };
            prev = p;
            p = next;
        }
        let mut n = 0;
        while !prev.is_null() {
            let node = unsafe { Box::from_raw(prev) };
            prev = node.next;
            staged.push_back(node.item);
            n += 1;
        }
        n
    }
}

impl Drop for InjectQueue {
    fn drop(&mut self) {
        let mut orphans = VecDeque::new();
        self.drain_into(&mut orphans); // frees the nodes; items drop here
    }
}

// ---------------------------------------------------------------------------
// Lanes
// ---------------------------------------------------------------------------

/// One ensemble member's work lane.
struct Lane {
    model_index: usize,
    queue: InjectQueue,
    /// Claim flag: the worker that CASes `false → true` owns `staged`
    /// (and the queue's consumer side) until it stores `false` back.
    claimed: AtomicBool,
    /// Flush deadline for the batch being filled, in nanos since the
    /// executor epoch; 0 = unset (an unset deadline on a non-empty lane
    /// means "due now" — see the scheduling notes on `lane_due`).
    deadline_ns: AtomicU64,
    /// Items drained but not yet flushed. Exclusive to the claim
    /// holder.
    staged: UnsafeCell<VecDeque<BatchItem>>,
    done: Completer,
}

// SAFETY: `staged` is the only non-Sync field. It is touched solely by
// the thread holding the `claimed` flag, which is acquired with an
// Acquire CAS and released with a Release store — exclusive, ordered
// access, same protocol the pending-slot arena uses for its metadata.
unsafe impl Send for Lane {}
unsafe impl Sync for Lane {}

// ---------------------------------------------------------------------------
// Shared executor state
// ---------------------------------------------------------------------------

struct Shared {
    lanes: Box<[Lane]>,
    /// Per-lane live depth: items admitted and not yet resolved
    /// (scored/failed). Also the `/stats` queue-depth gauge.
    depths: Arc<[AtomicUsize]>,
    /// Per-lane dead flags: set on execution failure; a dead lane fails
    /// everything it is handed instead of executing. Shared out (via
    /// [`Executor::dead_gauges`]) so the governor can observe lane
    /// health and [`Executor::revive_lane`] can clear it after a canary
    /// probe succeeds.
    dead: Arc<[AtomicBool]>,
    /// Per-lane transient-error retry counters (`/stats` gauge).
    retries: Arc<[AtomicU64]>,
    /// Per-lane EWMA of per-item execution nanos (α = 1/8; 0 = no
    /// sample yet) — the governor's *live* service-time profile, fed to
    /// the composer in place of the offline MACs estimate.
    exec_ewma_ns: Arc<[AtomicU64]>,
    /// Per-worker executed-batch counters (imbalance gauge).
    batches: Arc<[AtomicU64]>,
    engine: Engine,
    /// Fill-deadline source: static `policy.timeout` or the SLO-aware
    /// adaptive wait, per [`DeadlineController`].
    ctrl: Arc<DeadlineController>,
    /// Static policy with a zero timeout — lanes are always "due" and
    /// deadlines are never armed (precomputed fast path).
    never_waits: bool,
    max_take: usize,
    clip_len: usize,
    epoch: Instant,
    /// Live [`LaneSender`] clones; 0 ⇒ `closed`.
    producers: AtomicUsize,
    closed: AtomicBool,
    /// Workers whose backend state initialized; when the last one
    /// fails, every lane is marked dead so admitted queries are evicted
    /// instead of hanging (see `worker_loop`).
    live_workers: AtomicUsize,
    /// Eventcount generation: bumped (then the sleep mutex is touched)
    /// on every wake-worthy transition so a worker checking the
    /// generation under the mutex can never miss a signal.
    wake_gen: AtomicU64,
    sleep: Mutex<()>,
    wake: Condvar,
}

impl Shared {
    fn now_ns(&self) -> u64 {
        u64::try_from(self.epoch.elapsed().as_nanos()).unwrap_or(u64::MAX)
    }

    /// Flush deadline for lane `i`'s next batch: now + the controller's
    /// fill wait (static `policy.timeout`, or the adaptive wait derived
    /// from the lane's live queue depth and the rolling T_q/T_s-vs-SLO
    /// headroom).
    fn deadline_from(&self, i: usize, now_ns: u64) -> u64 {
        let depth = self.depths[i].load(Ordering::Acquire);
        let t = self.ctrl.fill_wait_ns(i, depth);
        now_ns.saturating_add(t).max(1) // 0 is the "unset" sentinel
    }

    fn wake_one(&self) {
        self.wake_gen.fetch_add(1, Ordering::SeqCst);
        drop(self.sleep.lock().expect("executor sleep lock poisoned"));
        self.wake.notify_one();
    }

    fn wake_all(&self) {
        self.wake_gen.fetch_add(1, Ordering::SeqCst);
        drop(self.sleep.lock().expect("executor sleep lock poisoned"));
        self.wake.notify_all();
    }

    /// Park until a wake signal, an optional deadline, or (as a
    /// safety net while draining) a short poll tick.
    fn park(&self, seen_gen: u64, until: Option<Duration>) {
        let guard = self.sleep.lock().expect("executor sleep lock poisoned");
        if self.wake_gen.load(Ordering::SeqCst) != seen_gen {
            return; // something happened since the scan started
        }
        match until {
            Some(d) => {
                let _ = self.wake.wait_timeout(guard, d);
            }
            None => {
                let _ = self.wake.wait(guard);
            }
        }
    }

    /// Is the lane claimable work right now?
    fn lane_due(&self, i: usize, now_ns: u64, closed: bool) -> bool {
        if self.depths[i].load(Ordering::Acquire) == 0 {
            return false;
        }
        let lane = &self.lanes[i];
        if self.dead[i].load(Ordering::Relaxed) || closed || self.never_waits {
            return true;
        }
        if self.depths[i].load(Ordering::Acquire) >= self.max_take {
            return true;
        }
        let d = lane.deadline_ns.load(Ordering::Acquire);
        d == 0 || now_ns >= d
    }

    fn all_empty(&self) -> bool {
        self.depths.iter().all(|d| d.load(Ordering::Acquire) == 0)
    }

    /// Fail (evict) everything currently visible on lane `i`, keeping
    /// the depth gauge honest — THE dead-lane drain, shared by the
    /// worker dead branch, the reaper, and the executor's final drop
    /// sweep so the accounting invariant lives in one place. The caller
    /// must hold the lane's claim flag. Returns how many items failed.
    fn fail_backlog(&self, i: usize) -> usize {
        let lane = &self.lanes[i];
        // SAFETY: the caller holds the claim flag.
        let staged = unsafe { &mut *lane.staged.get() };
        let mut total = 0;
        loop {
            lane.queue.drain_into(staged);
            if staged.is_empty() {
                return total;
            }
            let n = fail_front(staged, staged.len(), &lane.done);
            self.depths[i].fetch_sub(n, Ordering::AcqRel);
            total += n;
        }
    }

    /// Drain + flush one claimed lane until it is empty or its next
    /// batch is not yet due. Returns true if anything was resolved.
    /// Never sleeps: leftover partial batches get a deadline and the
    /// worker moves on.
    fn run_lane(
        &self,
        i: usize,
        wid: usize,
        dev: &mut crate::runtime::DirectWorker,
        buf: &mut AlignedBatch,
    ) -> bool {
        let lane = &self.lanes[i];
        // SAFETY: this worker holds the claim flag (see worker_loop).
        let staged = unsafe { &mut *lane.staged.get() };
        let mut did = false;
        loop {
            lane.queue.drain_into(staged);
            if staged.is_empty() {
                // depth may still be >0 for an in-flight push (counter
                // increments before the queue insert); the worker loop
                // re-checks after release so nothing starves
                return did;
            }
            if self.dead[i].load(Ordering::Relaxed) {
                // fails staged + re-drains until empty, so racing
                // pushes fail promptly too
                if self.fail_backlog(i) > 0 {
                    did = true;
                }
                return did;
            }
            let closed = self.closed.load(Ordering::SeqCst);
            let now = self.now_ns();
            let deadline = lane.deadline_ns.load(Ordering::Acquire);
            let due = closed
                || self.never_waits
                || staged.len() >= self.max_take
                || deadline == 0
                || now >= deadline;
            if !due {
                return did; // deadline stands; another worker (or we)
                            // will be back when it elapses
            }
            // A panicking backend (or completion callback) must not
            // wedge the pool: catch the unwind at the flush boundary and
            // treat it as a failed execution (lane goes dead below, the
            // dead branch fails the backlog, pushes start erroring).
            // `flush_batch` only removes items from `staged` via drains
            // that complete on unwind, so the before/after length gap is
            // exactly what left the lane — the depth gauge stays honest
            // and close-time `all_empty` still converges. Items the
            // unwound flush dequeued without resolving leak their
            // pending slots, precisely what the panicked per-model
            // thread used to leak.
            let staged_before = staged.len();
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                flush_batch(
                    lane.model_index,
                    dev,
                    self.clip_len,
                    staged,
                    buf,
                    &lane.done,
                    self.max_take,
                    Some(&self.retries[i]),
                )
            }));
            let out = caught.unwrap_or_else(|_| FlushOutcome::panicked(
                staged_before.saturating_sub(staged.len()),
                Error::serving(format!("model {} execution panicked", lane.model_index)),
            ));
            if out.resolved > 0 {
                self.depths[i].fetch_sub(out.resolved, Ordering::AcqRel);
                did = true;
            }
            if out.executed {
                self.batches[wid].fetch_add(1, Ordering::Relaxed);
                if out.exec_ns_per_item > 0 {
                    // α = 1/8 integer EWMA; only the claim holder writes,
                    // so a plain load/store pair is race-free
                    let cell = &self.exec_ewma_ns[i];
                    let old = cell.load(Ordering::Relaxed);
                    let next = if old == 0 {
                        out.exec_ns_per_item
                    } else {
                        old - old / 8 + out.exec_ns_per_item / 8
                    };
                    cell.store(next.max(1), Ordering::Relaxed);
                }
            }
            match out.result {
                Ok(()) => {
                    // the next batch's fill window starts at this flush
                    // (the old actor's bounded recv_timeout, restarted
                    // after each flush): covers the leftover partial AND
                    // a push that raced the flush — it read depth > 0 so
                    // it skipped arming, and must not inherit the
                    // just-flushed batch's elapsed deadline (premature
                    // size-1 flush). A full leftover loops straight into
                    // another flush regardless of the deadline. Under an
                    // adaptive policy the controller sees the lane's
                    // post-flush depth here — a deep backlog re-arms a
                    // near-zero window, a drained lane the full one.
                    if !self.never_waits {
                        lane.deadline_ns
                            .store(self.deadline_from(i, self.now_ns()), Ordering::Release);
                    }
                }
                Err(e) => {
                    if !self.dead[i].swap(true, Ordering::SeqCst) {
                        eprintln!("model lane {} (worker {wid}) failed: {e}", lane.model_index);
                    }
                    // loop continues: the dead branch fails the backlog
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Producer handle
// ---------------------------------------------------------------------------

/// Routing handle into the executor: one lane per ensemble member, in
/// model-index order. Cloneable; the executor drains and shuts down
/// when the last clone drops.
pub struct LaneSender {
    shared: Arc<Shared>,
}

impl LaneSender {
    /// Number of lanes (= ensemble members).
    pub fn lanes(&self) -> usize {
        self.shared.lanes.len()
    }

    /// Push one item onto lane `pos` (member position in model-index
    /// order). Errors if the lane is dead (its model cannot execute) —
    /// the caller must evict the query, exactly as it did when the
    /// per-model batcher thread had exited.
    pub fn push(&self, pos: usize, item: BatchItem) -> Result<()> {
        let shared = &self.shared;
        let lane = &shared.lanes[pos];
        if shared.dead[pos].load(Ordering::Acquire) {
            return Err(Error::serving(format!("model lane {} is dead", lane.model_index)));
        }
        let depth = &shared.depths[pos];
        // starting a fresh batch: arm its fill deadline BEFORE the item
        // becomes visible, so no worker can observe work without one
        if depth.load(Ordering::Acquire) == 0 && !shared.never_waits {
            lane.deadline_ns.store(shared.deadline_from(pos, shared.now_ns()), Ordering::Release);
        }
        // depth rises before the queue insert: a worker may transiently
        // see depth > queue (spurious scan, harmless) but never resolves
        // more than it admitted (no underflow)
        let prev = depth.fetch_add(1, Ordering::AcqRel);
        lane.queue.push(item);
        if prev == 0 || prev + 1 == self.shared.max_take || shared.never_waits {
            shared.wake_one();
        }
        Ok(())
    }
}

impl Clone for LaneSender {
    fn clone(&self) -> Self {
        self.shared.producers.fetch_add(1, Ordering::SeqCst);
        LaneSender { shared: Arc::clone(&self.shared) }
    }
}

impl Drop for LaneSender {
    fn drop(&mut self) {
        if self.shared.producers.fetch_sub(1, Ordering::SeqCst) == 1 {
            self.shared.closed.store(true, Ordering::SeqCst);
            self.shared.wake_all();
        }
    }
}

// ---------------------------------------------------------------------------
// Executor
// ---------------------------------------------------------------------------

/// Handle to the running worker pool. Dropping it joins the workers —
/// which return once every producer handle is gone and every lane has
/// drained, so a dropped pipeline leaves no thread behind.
pub struct Executor {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Executor {
    /// Spawn `workers` pool threads (0 = [`default_workers_for`] the
    /// engine's device-permit count) over one lane per
    /// `(model_index, completer)` pair, in member order.
    ///
    /// The fill-deadline [`DeadlineController`] is built HERE, from the
    /// same `policy` the lanes run and calibrated to the effective
    /// `max_take` (so a `max_batch` above the largest compiled batch
    /// size cannot miscalibrate the depth relaxation) — callers cannot
    /// hand the pool a controller derived from a different policy.
    /// `slo`/`telemetry` only matter for adaptive policies: `telemetry`
    /// feeds the controller's rolling T_q/T_s split (pass the
    /// pipeline's instance; `None` = depth-only adaptation, fine for
    /// benches and tests). Read it back via [`Executor::controller`].
    pub fn spawn(
        engine: &Engine,
        members: Vec<(usize, Completer)>,
        policy: BatchPolicy,
        workers: usize,
        slo: Duration,
        telemetry: Option<Arc<Telemetry>>,
    ) -> Result<(Executor, LaneSender)> {
        assert!(!members.is_empty(), "executor needs at least one lane");
        let n_workers =
            if workers == 0 { default_workers_for(engine.n_workers()) } else { workers };
        let max_take = policy.max_batch.min(largest_batch(engine)).max(1);
        let ctrl = Arc::new(DeadlineController::new(
            members.len(),
            &policy,
            max_take,
            slo,
            telemetry,
        ));
        let lanes: Box<[Lane]> = members
            .into_iter()
            .map(|(model_index, done)| Lane {
                model_index,
                queue: InjectQueue::new(),
                claimed: AtomicBool::new(false),
                deadline_ns: AtomicU64::new(0),
                staged: UnsafeCell::new(VecDeque::new()),
                done,
            })
            .collect();
        let depths: Arc<[AtomicUsize]> = (0..lanes.len()).map(|_| AtomicUsize::new(0)).collect();
        let dead: Arc<[AtomicBool]> = (0..lanes.len()).map(|_| AtomicBool::new(false)).collect();
        let retries: Arc<[AtomicU64]> = (0..lanes.len()).map(|_| AtomicU64::new(0)).collect();
        let exec_ewma_ns: Arc<[AtomicU64]> = (0..lanes.len()).map(|_| AtomicU64::new(0)).collect();
        let batches: Arc<[AtomicU64]> = (0..n_workers).map(|_| AtomicU64::new(0)).collect();
        let never_waits = policy.never_waits();
        let shared = Arc::new(Shared {
            lanes,
            depths,
            dead,
            retries,
            exec_ewma_ns,
            batches,
            engine: engine.clone(),
            ctrl,
            never_waits,
            max_take,
            clip_len: engine.clip_len(),
            epoch: Instant::now(),
            producers: AtomicUsize::new(1),
            closed: AtomicBool::new(false),
            live_workers: AtomicUsize::new(n_workers),
            wake_gen: AtomicU64::new(0),
            sleep: Mutex::new(()),
            wake: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(n_workers);
        for wid in 0..n_workers {
            let worker_shared = Arc::clone(&shared);
            let spawned = std::thread::Builder::new()
                .name(format!("exec-worker-{wid}"))
                .spawn(move || worker_loop(wid, worker_shared));
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // No LaneSender exists yet, so nothing else will ever
                    // close the pool: shut down the workers already
                    // running instead of leaking them parked forever.
                    shared.closed.store(true, Ordering::SeqCst);
                    shared.wake_all();
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(Error::Io(e));
                }
            }
        }
        Ok((
            Executor { shared: Arc::clone(&shared), workers: handles },
            LaneSender { shared },
        ))
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Zoo model index per lane, in member order.
    pub fn lane_models(&self) -> Vec<usize> {
        self.shared.lanes.iter().map(|l| l.model_index).collect()
    }

    /// Shared per-lane depth gauges (items admitted, not yet resolved).
    pub fn depth_gauges(&self) -> Arc<[AtomicUsize]> {
        Arc::clone(&self.shared.depths)
    }

    /// Shared per-worker executed-batch counters.
    pub fn batch_counters(&self) -> Arc<[AtomicU64]> {
        Arc::clone(&self.shared.batches)
    }

    /// The fill-deadline controller this pool consults.
    pub fn controller(&self) -> &Arc<DeadlineController> {
        &self.shared.ctrl
    }

    /// Shared per-lane dead flags (lane health, in member order).
    pub fn dead_gauges(&self) -> Arc<[AtomicBool]> {
        Arc::clone(&self.shared.dead)
    }

    /// Shared per-lane transient-error retry counters.
    pub fn retry_counters(&self) -> Arc<[AtomicU64]> {
        Arc::clone(&self.shared.retries)
    }

    /// Shared per-lane EWMA of per-item execution nanos (0 = no sample
    /// yet) — the governor's live service-time profile.
    pub fn exec_ewma_gauges(&self) -> Arc<[AtomicU64]> {
        Arc::clone(&self.shared.exec_ewma_ns)
    }

    /// The engine this pool executes on (canary-probe path for the
    /// governor: probes go through the engine's own job channel, never
    /// through the quarantined lane).
    pub fn engine(&self) -> &Engine {
        &self.shared.engine
    }

    /// Snapshot of the per-lane dead flags.
    pub fn dead_lanes(&self) -> Vec<bool> {
        self.shared.dead.iter().map(|d| d.load(Ordering::Acquire)).collect()
    }

    /// Bring a dead lane back to life after its backend healed (the
    /// governor calls this once a canary probe succeeds). Claims the
    /// lane, fails any backlog stranded while it was dead, clears the
    /// deadline and the dead flag, then releases and wakes the pool.
    /// Returns false — and leaves the lane dead — if the pool can no
    /// longer execute anything (closed, or zero live workers) or the
    /// claim could not be taken in bounded time.
    pub fn revive_lane(&self, pos: usize) -> bool {
        let shared = &self.shared;
        assert!(pos < shared.lanes.len(), "revive_lane: lane {pos} out of range");
        if shared.closed.load(Ordering::SeqCst) || shared.live_workers.load(Ordering::SeqCst) == 0
        {
            return false;
        }
        if !shared.dead[pos].load(Ordering::Acquire) {
            return true; // already live
        }
        let lane = &shared.lanes[pos];
        // bounded spin for the claim: holders of a dead lane only fail
        // backlog, which terminates promptly
        let deadline = Instant::now() + Duration::from_secs(5);
        while lane
            .claimed
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::yield_now();
        }
        // still dead here, so racing pushes keep erroring while we
        // clear out anything stranded before the flag flipped
        shared.fail_backlog(pos);
        lane.deadline_ns.store(0, Ordering::Release);
        shared.dead[pos].store(false, Ordering::Release);
        lane.claimed.store(false, Ordering::Release);
        shared.wake_all();
        true
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        // Workers exit on their own once every LaneSender is gone and
        // the lanes are empty; joining here makes "pipeline dropped" ⇒
        // "every in-flight query resolved" an actual guarantee.
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // A worker killed by a panic that escaped the flush-boundary
        // catch may have left admitted queries behind (its join above
        // returns immediately); fail them so the guarantee holds even
        // with zero surviving workers. No-op on the normal path, where
        // workers only exited once every lane was empty.
        for (i, lane) in self.shared.lanes.iter().enumerate() {
            if self.shared.depths[i].load(Ordering::Acquire) == 0 {
                continue;
            }
            if lane
                .claimed
                .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
            {
                self.shared.fail_backlog(i);
                lane.claimed.store(false, Ordering::Release);
            }
        }
    }
}

/// Accounts for a worker thread dying by panic (anything that escapes
/// `run_lane`'s flush-boundary catch, e.g. an `eprintln!` to a closed
/// stderr): decrements `live_workers`, and when the LAST live worker
/// dies this way marks every lane dead so pushes error (the router
/// evicts) instead of queueing onto a pool that can no longer execute.
/// The backlog itself is failed by surviving workers (dead-lane branch)
/// or, with none left, by `Executor::drop`'s final sweep. Normal exits
/// skip all of this — they only happen once every lane is drained.
struct WorkerGuard<'a> {
    shared: &'a Shared,
}

impl Drop for WorkerGuard<'_> {
    fn drop(&mut self) {
        if !std::thread::panicking() {
            return;
        }
        if self.shared.live_workers.fetch_sub(1, Ordering::SeqCst) == 1 {
            for d in self.shared.dead.iter() {
                d.store(true, Ordering::SeqCst);
            }
        }
        self.shared.wake_all();
    }
}

/// Releases a lane claim even if the holder unwinds. Execution panics
/// are caught at the flush boundary in `run_lane`; this covers anything
/// that escapes it — a panic that leaked the claim flag would otherwise
/// strand the lane's queries forever and deadlock `Executor::drop`. On
/// unwind the lane is also marked dead (pushes error → the router
/// evicts) and the peers are woken so one of them fails the backlog.
struct ClaimGuard<'a> {
    shared: &'a Shared,
    lane: usize,
}

impl Drop for ClaimGuard<'_> {
    fn drop(&mut self) {
        let lane = &self.shared.lanes[self.lane];
        if std::thread::panicking() {
            self.shared.dead[self.lane].store(true, Ordering::SeqCst);
        }
        lane.claimed.store(false, Ordering::Release);
        if std::thread::panicking() {
            self.shared.wake_all();
        }
    }
}

fn worker_loop(wid: usize, shared: Arc<Shared>) {
    let mut dev = match shared.engine.direct_worker(wid) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("exec-worker-{wid}: backend init failed: {e}");
            // a failed worker just shrinks the pool — unless it was the
            // last one, in which case nothing could ever execute: mark
            // every lane dead (pushes start erroring, so the router
            // evicts) and stay behind to fail the already-admitted
            // backlog instead of letting its callers hang forever
            if shared.live_workers.fetch_sub(1, Ordering::SeqCst) == 1 {
                for d in shared.dead.iter() {
                    d.store(true, Ordering::SeqCst);
                }
                reaper_loop(&shared);
            }
            shared.wake_all();
            return;
        }
    };
    let _death_watch = WorkerGuard { shared: shared.as_ref() };
    // the worker's persistent 64-byte-aligned batch arena: allocations
    // scale with the worker count, not the ensemble size
    let mut buf = AlignedBatch::new();
    let n = shared.lanes.len();
    let mut rotation = wid; // stagger scan starts across workers
    loop {
        let seen_gen = shared.wake_gen.load(Ordering::SeqCst);
        let closed = shared.closed.load(Ordering::SeqCst);
        let now = shared.now_ns();
        let mut did = false;
        for off in 0..n {
            let i = (rotation + off) % n;
            let lane = &shared.lanes[i];
            if !shared.lane_due(i, now, closed) {
                continue;
            }
            if lane
                .claimed
                .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
                .is_err()
            {
                continue; // another worker owns it — in good hands
            }
            let claim = ClaimGuard { shared: shared.as_ref(), lane: i };
            did |= shared.run_lane(i, wid, &mut dev, &mut buf);
            drop(claim);
            // an in-flight push may have raced our final drain (depth
            // rises before the queue insert): if depth is still
            // non-zero, stay hot so the item is picked up promptly
            if shared.depths[i].load(Ordering::Acquire) > 0 {
                did = true;
            }
        }
        rotation = rotation.wrapping_add(1);
        if did {
            continue;
        }
        if closed {
            if shared.all_empty() {
                break;
            }
            // other workers are finishing their lanes; poll briefly so
            // no exit signal is ever needed from them mid-drain
            shared.park(seen_gen, Some(Duration::from_millis(1)));
            continue;
        }
        // idle: sleep until a push signal or the nearest lane deadline
        let mut nearest: Option<u64> = None;
        let mut due_now = false;
        for (i, lane) in shared.lanes.iter().enumerate() {
            if shared.depths[i].load(Ordering::Acquire) == 0 {
                continue;
            }
            if lane.claimed.load(Ordering::Relaxed) {
                continue; // claim holder will re-arm or finish it
            }
            let d = lane.deadline_ns.load(Ordering::Acquire);
            if d == 0 || d <= now {
                due_now = true;
                break;
            }
            nearest = Some(nearest.map_or(d, |m: u64| m.min(d)));
        }
        if due_now {
            std::thread::yield_now(); // lost a claim race — rescan
            continue;
        }
        let until = nearest.map(|d| Duration::from_nanos(d.saturating_sub(now)));
        shared.park(seen_gen, until);
    }
    // wake any peers parked without a timeout so they re-check
    // closed + empty and exit too
    shared.wake_all();
}

/// Degraded-mode loop run by the last worker whose backend failed to
/// initialize: every lane is dead, so all this does is claim lanes with
/// backlog and fail their items (evicting the queries) until the
/// producers hang up and everything is drained. Keeps the "no admitted
/// query is ever left dangling" contract even with zero executable
/// workers.
fn reaper_loop(shared: &Shared) {
    loop {
        let seen_gen = shared.wake_gen.load(Ordering::SeqCst);
        let closed = shared.closed.load(Ordering::SeqCst);
        let mut did = false;
        for (i, lane) in shared.lanes.iter().enumerate() {
            if shared.depths[i].load(Ordering::Acquire) == 0 {
                continue;
            }
            if lane
                .claimed
                .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
                .is_err()
            {
                continue;
            }
            if shared.fail_backlog(i) > 0 {
                did = true;
            }
            lane.claimed.store(false, Ordering::Release);
        }
        if did {
            continue;
        }
        if closed && shared.all_empty() {
            return;
        }
        // short poll: failed-init is already the pathological path, and
        // a bounded tick also covers the depth-vs-queue push race
        shared.park(seen_gen, Some(Duration::from_millis(1)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SimBackend;
    use crate::serving::control::DEFAULT_SLO;
    use crate::serving::arena::WindowLease;
    use crate::serving::pipeline::{PendingMeta, PendingSlots, Prediction};
    use crate::serving::telemetry::Telemetry;
    use crate::zoo::testkit;

    fn harness(
        n_models: usize,
        workers: usize,
        policy: BatchPolicy,
    ) -> (Arc<PendingSlots>, Arc<Telemetry>, Executor, LaneSender, usize) {
        let zoo = testkit::toy_zoo_with(6, 16, 3, 40, &[1, 8]);
        let engine =
            Engine::with_backend(&zoo, 2, Arc::new(SimBackend::instant(&zoo))).unwrap();
        let pending = Arc::new(PendingSlots::new(n_models));
        let telemetry = Arc::new(Telemetry::default());
        let members = (0..n_models)
            .map(|pos| (pos, Completer::new(Arc::clone(&pending), Arc::clone(&telemetry), pos)))
            .collect();
        let (exec, tx) =
            Executor::spawn(&engine, members, policy, workers, DEFAULT_SLO, None).unwrap();
        let clip = engine.clip_len();
        (pending, telemetry, exec, tx, clip)
    }

    fn meta(reply: Option<std::sync::mpsc::SyncSender<Prediction>>) -> PendingMeta {
        PendingMeta {
            patient: 0,
            window_id: 0,
            sim_end: 0.0,
            emitted: Instant::now(),
            reply,
        }
    }

    #[test]
    fn default_pool_size_is_capped_by_device_permits() {
        // the hardware heuristic alone may be up to 16; with 2 device
        // permits the effective default must not exceed 4 workers
        assert!(default_workers_for(2) <= 4);
        assert_eq!(default_workers_for(2), default_workers().min(4));
        assert_eq!(default_workers_for(0), 1, "degenerate permit count still spawns");
        let zoo = testkit::toy_zoo_with(4, 16, 3, 40, &[1, 8]);
        let engine = Engine::with_backend(&zoo, 1, Arc::new(SimBackend::instant(&zoo))).unwrap();
        let pending = Arc::new(PendingSlots::new(1));
        let telemetry = Arc::new(Telemetry::default());
        let members =
            vec![(0usize, Completer::new(Arc::clone(&pending), Arc::clone(&telemetry), 0))];
        let policy = BatchPolicy::default();
        // workers = 0 → the pipeline default: ≤ 2×(1 device permit)
        let (exec, tx) =
            Executor::spawn(&engine, members, policy, 0, DEFAULT_SLO, None).unwrap();
        assert!(exec.n_workers() <= 2, "1-permit engine spawned {}", exec.n_workers());
        drop(tx);
        drop(exec);
    }

    #[test]
    fn pool_completes_queries_across_lanes() {
        let policy = BatchPolicy { max_batch: 8, timeout: Duration::ZERO, ..BatchPolicy::default() };
        let (pending, telemetry, exec, tx, clip) = harness(3, 2, policy);
        let mut replies = Vec::new();
        for id in 0..32u64 {
            let (ptx, prx) = std::sync::mpsc::sync_channel(1);
            pending.insert(id, meta(Some(ptx)));
            let lease = WindowLease::from_vec(vec![id as f32 * 0.01; clip]);
            for pos in 0..3 {
                tx.push(
                    pos,
                    BatchItem { query_id: id, input: lease.clone(), enqueued: Instant::now() },
                )
                .unwrap();
            }
            replies.push(prx);
        }
        for (id, rx) in replies.into_iter().enumerate() {
            let p = rx
                .recv_timeout(Duration::from_secs(30))
                .unwrap_or_else(|e| panic!("query {id}: {e:?}"));
            assert!((0.0..=1.0).contains(&p.score));
        }
        assert_eq!(pending.len(), 0);
        assert_eq!(telemetry.model_jobs.load(Ordering::Relaxed), 3 * 32);
        drop(tx);
        drop(exec); // joins: all gauges final
    }

    #[test]
    fn shutdown_drains_partial_batches() {
        // generous timeout: the items must flush on CLOSE, not deadline
        let policy = BatchPolicy {
            max_batch: 8,
            timeout: Duration::from_secs(60),
            ..BatchPolicy::default()
        };
        let (pending, _tel, exec, tx, clip) = harness(1, 1, policy);
        let (ptx, prx) = std::sync::mpsc::sync_channel(1);
        pending.insert(5, meta(Some(ptx)));
        let lease = WindowLease::from_vec(vec![0.25; clip]);
        tx.push(0, BatchItem { query_id: 5, input: lease, enqueued: Instant::now() })
            .unwrap();
        drop(tx); // close → final drain must flush the 1-item batch
        drop(exec);
        assert!(prx.try_recv().is_ok(), "final drain must score the staged item");
        assert_eq!(pending.len(), 0);
    }

    #[test]
    fn deadline_flushes_partial_batch_without_new_pushes() {
        let policy = BatchPolicy {
            max_batch: 8,
            timeout: Duration::from_millis(5),
            ..BatchPolicy::default()
        };
        let (pending, _tel, _exec, tx, clip) = harness(1, 2, policy);
        let (ptx, prx) = std::sync::mpsc::sync_channel(1);
        pending.insert(0, meta(Some(ptx)));
        let lease = WindowLease::from_vec(vec![0.5; clip]);
        tx.push(0, BatchItem { query_id: 0, input: lease, enqueued: Instant::now() })
            .unwrap();
        // no further pushes, no shutdown: the fill deadline alone must
        // flush the batch
        let p = prx.recv_timeout(Duration::from_secs(30)).expect("deadline flush");
        assert!((0.0..=1.0).contains(&p.score));
        assert_eq!(pending.len(), 0);
    }

    struct PanicBackend;

    impl crate::runtime::ExecBackend for PanicBackend {
        fn name(&self) -> &'static str {
            "panic"
        }

        fn worker(&self, _wid: usize) -> crate::Result<Box<dyn crate::runtime::ExecWorker>> {
            Ok(Box::new(PanicWorker))
        }
    }

    struct PanicWorker;

    impl crate::runtime::ExecWorker for PanicWorker {
        fn run(
            &mut self,
            _key: crate::runtime::ModelKey,
            _input: &[f32],
            _clip_len: usize,
        ) -> crate::Result<crate::runtime::BackendOutput> {
            panic!("injected backend panic")
        }
    }

    #[test]
    fn panicking_execution_marks_lane_dead_and_pool_survives() {
        let zoo = testkit::toy_zoo_with(4, 16, 3, 40, &[1, 8]);
        let engine = Engine::with_backend(&zoo, 1, Arc::new(PanicBackend)).unwrap();
        let pending = Arc::new(PendingSlots::new(1));
        let telemetry = Arc::new(Telemetry::default());
        let members =
            vec![(0usize, Completer::new(Arc::clone(&pending), Arc::clone(&telemetry), 0))];
        let policy = BatchPolicy { max_batch: 8, timeout: Duration::ZERO, ..BatchPolicy::default() };
        let (exec, tx) =
            Executor::spawn(&engine, members, policy, 1, DEFAULT_SLO, None).unwrap();
        let clip = engine.clip_len();
        pending.insert(0, meta(None));
        tx.push(
            0,
            BatchItem {
                query_id: 0,
                input: WindowLease::from_vec(vec![0.1; clip]),
                enqueued: Instant::now(),
            },
        )
        .unwrap();
        // the panic is caught at the flush boundary: the worker survives,
        // the lane goes dead, and pushes start erroring
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            pending.insert(1, meta(None));
            let r = tx.push(
                0,
                BatchItem {
                    query_id: 1,
                    input: WindowLease::from_vec(vec![0.2; clip]),
                    enqueued: Instant::now(),
                },
            );
            if r.is_err() {
                pending.evict(1);
                break;
            }
            assert!(Instant::now() < deadline, "lane never died after the panic");
            std::thread::sleep(Duration::from_millis(1));
        }
        drop(tx);
        drop(exec); // must not hang: claim released, depth reconciled
        assert_eq!(pending.len(), 0, "panicked batch must evict its queries");
        assert!(telemetry.failures.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn dead_lane_rejects_pushes_and_fails_backlog() {
        let zoo = testkit::toy_zoo_with(4, 16, 3, 40, &[1, 8]);
        let backend = SimBackend::instant(&zoo).failing_model(0);
        let engine = Engine::with_backend(&zoo, 1, Arc::new(backend)).unwrap();
        let pending = Arc::new(PendingSlots::new(1));
        let telemetry = Arc::new(Telemetry::default());
        let members =
            vec![(0usize, Completer::new(Arc::clone(&pending), Arc::clone(&telemetry), 0))];
        let policy = BatchPolicy { max_batch: 8, timeout: Duration::ZERO, ..BatchPolicy::default() };
        let (exec, tx) =
            Executor::spawn(&engine, members, policy, 1, DEFAULT_SLO, None).unwrap();
        let clip = engine.clip_len();
        pending.insert(0, meta(None));
        tx.push(
            0,
            BatchItem {
                query_id: 0,
                input: WindowLease::from_vec(vec![0.1; clip]),
                enqueued: Instant::now(),
            },
        )
        .unwrap();
        // the failing execution marks the lane dead; pushes start
        // erroring (the router's cue to evict)
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            pending.insert(1, meta(None));
            let r = tx.push(
                0,
                BatchItem {
                    query_id: 1,
                    input: WindowLease::from_vec(vec![0.2; clip]),
                    enqueued: Instant::now(),
                },
            );
            if r.is_err() {
                pending.evict(1); // the router's job on push failure
                break;
            }
            assert!(Instant::now() < deadline, "lane never died");
            std::thread::sleep(Duration::from_millis(1));
        }
        drop(tx);
        drop(exec);
        assert_eq!(pending.len(), 0, "every admitted query must be resolved");
        assert!(telemetry.failures.load(Ordering::Relaxed) >= 1);
    }
}
