//! Serving telemetry: latency histograms with percentile queries, stage
//! breakdown (queueing vs execution — the paper's T_q / T_s split), and
//! throughput counters. Lock-light: one mutex per histogram, updated
//! once per query.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Fixed-boundary log-scale histogram from 1 µs to ~100 s, plus an exact
/// reservoir of recent samples for precise percentiles in experiments.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
    samples: Mutex<Vec<f64>>, // seconds; capped reservoir
    /// Relaxed mirror of `samples.len()`: recorders check it before
    /// touching the mutex, so a full reservoir costs zero lock traffic
    /// on the (now multi-threaded, collector-less) completion path.
    sampled: AtomicUsize,
    cap: usize,
}

const BUCKETS_PER_DECADE: usize = 10;
const DECADES: usize = 8; // 1 µs .. 100 s

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new(100_000)
    }
}

impl LatencyHistogram {
    pub fn new(sample_cap: usize) -> Self {
        LatencyHistogram {
            buckets: (0..BUCKETS_PER_DECADE * DECADES).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
            samples: Mutex::new(Vec::new()),
            sampled: AtomicUsize::new(0),
            cap: sample_cap,
        }
    }

    fn bucket_index(ns: u64) -> usize {
        let us = (ns as f64 / 1000.0).max(1.0);
        let idx = (us.log10() * BUCKETS_PER_DECADE as f64) as usize;
        idx.min(BUCKETS_PER_DECADE * DECADES - 1)
    }

    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos() as u64;
        self.buckets[Self::bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
        // reservoir fast path: once full, recorders never take the lock
        // again (the authoritative cap check stays inside the lock —
        // `sampled` may lag behind, never run ahead)
        if self.sampled.load(Ordering::Relaxed) >= self.cap {
            return;
        }
        let mut s = self.samples.lock().expect("telemetry poisoned");
        if s.len() < self.cap {
            s.push(d.as_secs_f64());
            self.sampled.store(s.len(), Ordering::Relaxed);
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64 / 1e9
    }

    pub fn max(&self) -> f64 {
        self.max_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Exact percentile over the retained sample reservoir.
    pub fn percentile(&self, p: f64) -> f64 {
        let s = self.samples.lock().expect("telemetry poisoned");
        crate::metrics::percentile(&s, p)
    }

    /// Drain retained samples (for experiment CSVs); re-arms the
    /// reservoir.
    pub fn take_samples(&self) -> Vec<f64> {
        let mut s = self.samples.lock().expect("telemetry poisoned");
        let out = std::mem::take(&mut *s);
        self.sampled.store(0, Ordering::Relaxed);
        out
    }
}

/// Live gauges of the work-stealing executor, shared with its lanes
/// and workers (the counters themselves, not copies): per-model queue
/// depth makes a hot model visible, per-worker executed-batch counts
/// make pool imbalance visible. Installed into [`Telemetry`] by
/// `Pipeline::spawn` so `/stats` and the bedside report see them.
#[derive(Debug)]
pub struct ExecutorGauges {
    /// Zoo model index per lane, in member (model-index) order.
    models: Vec<usize>,
    /// Per-lane items admitted and not yet scored/failed.
    depths: Arc<[AtomicUsize]>,
    /// Per-worker device batches executed.
    batches: Arc<[AtomicU64]>,
}

impl ExecutorGauges {
    pub fn new(
        models: Vec<usize>,
        depths: Arc<[AtomicUsize]>,
        batches: Arc<[AtomicU64]>,
    ) -> Self {
        assert_eq!(models.len(), depths.len(), "one depth gauge per lane");
        ExecutorGauges { models, depths, batches }
    }

    pub fn models(&self) -> &[usize] {
        &self.models
    }

    /// Current queue depth per lane (same order as [`Self::models`]).
    pub fn queue_depths(&self) -> Vec<u64> {
        self.depths.iter().map(|d| d.load(Ordering::Relaxed) as u64).collect()
    }

    /// Batches executed per pool worker so far.
    pub fn worker_batches(&self) -> Vec<u64> {
        self.batches.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }
}

/// Pipeline-wide telemetry.
#[derive(Debug, Default)]
pub struct Telemetry {
    /// End-to-end: window emitted → prediction ready (T_q + T_s).
    pub e2e: LatencyHistogram,
    /// Queueing component: window emitted → first model starts executing.
    pub queueing: LatencyHistogram,
    /// Device execution per model job.
    pub exec: LatencyHistogram,
    /// Data-collection latency: frame ingest → aggregator push done.
    pub ingest: LatencyHistogram,
    pub queries: AtomicU64,
    pub model_jobs: AtomicU64,
    pub frames: AtomicU64,
    /// Frames the aggregation front-end discarded (malformed payload,
    /// wrong patient) — silent data loss made visible; per-shard
    /// breakdowns live on the shard router
    /// ([`super::shards::ShardRouter::dropped_per_shard`]).
    pub frames_dropped: AtomicU64,
    /// Queries evicted because a member could not score them.
    pub failures: AtomicU64,
    /// Executor gauges, installed once by `Pipeline::spawn` (absent for
    /// telemetry created outside a pipeline — benches, shard tests).
    executor: OnceLock<ExecutorGauges>,
}

impl Telemetry {
    /// Attach the executor's live gauges (once; later installs are
    /// ignored, matching a pipeline's one-executor lifetime).
    pub fn install_executor(&self, gauges: ExecutorGauges) {
        let _ = self.executor.set(gauges);
    }

    pub fn executor(&self) -> Option<&ExecutorGauges> {
        self.executor.get()
    }

    pub fn snapshot(&self) -> TelemetrySnapshot {
        let (models, queue_depths, worker_batches) = match self.executor.get() {
            Some(g) => (
                g.models().iter().map(|&m| m as u64).collect(),
                g.queue_depths(),
                g.worker_batches(),
            ),
            None => (Vec::new(), Vec::new(), Vec::new()),
        };
        TelemetrySnapshot {
            executor_models: models,
            queue_depth_per_model: queue_depths,
            batches_per_worker: worker_batches,
            queries: self.queries.load(Ordering::Relaxed),
            model_jobs: self.model_jobs.load(Ordering::Relaxed),
            frames: self.frames.load(Ordering::Relaxed),
            frames_dropped: self.frames_dropped.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
            e2e_mean: self.e2e.mean(),
            e2e_p50: self.e2e.percentile(50.0),
            e2e_p95: self.e2e.percentile(95.0),
            e2e_p99: self.e2e.percentile(99.0),
            e2e_max: self.e2e.max(),
            queueing_mean: self.queueing.mean(),
            queueing_p95: self.queueing.percentile(95.0),
            exec_mean: self.exec.mean(),
            ingest_p95: self.ingest.percentile(95.0),
        }
    }
}

/// Plain-old-data snapshot for the /stats endpoint and CSVs.
#[derive(Debug, Clone)]
pub struct TelemetrySnapshot {
    /// Zoo model index per executor lane (empty without a pipeline).
    pub executor_models: Vec<u64>,
    /// Live queue depth per lane, same order as `executor_models`.
    pub queue_depth_per_model: Vec<u64>,
    /// Device batches executed per executor worker.
    pub batches_per_worker: Vec<u64>,
    pub queries: u64,
    pub model_jobs: u64,
    pub frames: u64,
    pub frames_dropped: u64,
    pub failures: u64,
    pub e2e_mean: f64,
    pub e2e_p50: f64,
    pub e2e_p95: f64,
    pub e2e_p99: f64,
    pub e2e_max: f64,
    pub queueing_mean: f64,
    pub queueing_p95: f64,
    pub exec_mean: f64,
    pub ingest_p95: f64,
}

impl TelemetrySnapshot {
    pub fn to_json(&self) -> crate::json::Value {
        use crate::json::Value;
        let nums = |v: &[u64]| Value::Arr(v.iter().map(|&x| Value::Num(x as f64)).collect());
        Value::obj(vec![
            ("executor_models", nums(&self.executor_models)),
            ("queue_depth_per_model", nums(&self.queue_depth_per_model)),
            ("batches_per_worker", nums(&self.batches_per_worker)),
            ("queries", Value::Num(self.queries as f64)),
            ("model_jobs", Value::Num(self.model_jobs as f64)),
            ("frames", Value::Num(self.frames as f64)),
            ("frames_dropped", Value::Num(self.frames_dropped as f64)),
            ("failures", Value::Num(self.failures as f64)),
            ("e2e_mean", Value::Num(self.e2e_mean)),
            ("e2e_p50", Value::Num(self.e2e_p50)),
            ("e2e_p95", Value::Num(self.e2e_p95)),
            ("e2e_p99", Value::Num(self.e2e_p99)),
            ("e2e_max", Value::Num(self.e2e_max)),
            ("queueing_mean", Value::Num(self.queueing_mean)),
            ("queueing_p95", Value::Num(self.queueing_p95)),
            ("exec_mean", Value::Num(self.exec_mean)),
            ("ingest_p95", Value::Num(self.ingest_p95)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_mean() {
        let h = LatencyHistogram::default();
        h.record(Duration::from_millis(10));
        h.record(Duration::from_millis(30));
        assert_eq!(h.count(), 2);
        assert!((h.mean() - 0.020).abs() < 1e-9);
        assert!((h.max() - 0.030).abs() < 1e-9);
    }

    #[test]
    fn percentiles_from_reservoir() {
        let h = LatencyHistogram::default();
        for i in 1..=100 {
            h.record(Duration::from_millis(i));
        }
        assert!((h.percentile(50.0) - 0.0505).abs() < 0.002);
        assert!((h.percentile(95.0) - 0.09505).abs() < 0.002);
    }

    #[test]
    fn bucket_index_monotone_and_bounded() {
        let mut last = 0;
        for ns in [1u64, 1_000, 10_000, 1_000_000, 10_000_000_000, u64::MAX / 2] {
            let b = LatencyHistogram::bucket_index(ns);
            assert!(b >= last);
            assert!(b < BUCKETS_PER_DECADE * DECADES);
            last = b;
        }
    }

    #[test]
    fn reservoir_respects_cap() {
        let h = LatencyHistogram::new(10);
        for _ in 0..100 {
            h.record(Duration::from_micros(5));
        }
        assert_eq!(h.take_samples().len(), 10);
        assert_eq!(h.count(), 100);
    }

    #[test]
    fn snapshot_is_serializable() {
        let t = Telemetry::default();
        t.e2e.record(Duration::from_millis(1));
        let s = t.snapshot().to_json().to_string();
        assert!(s.contains("e2e_p95"));
        assert!(s.contains("queue_depth_per_model"));
        assert!(s.contains("batches_per_worker"));
    }

    #[test]
    fn executor_gauges_surface_in_snapshot() {
        let t = Telemetry::default();
        assert!(t.executor().is_none());
        let depths: Arc<[AtomicUsize]> = (0..2).map(|_| AtomicUsize::new(0)).collect();
        let batches: Arc<[AtomicU64]> = (0..3).map(|_| AtomicU64::new(0)).collect();
        t.install_executor(ExecutorGauges::new(
            vec![4, 7],
            Arc::clone(&depths),
            Arc::clone(&batches),
        ));
        depths[1].store(5, Ordering::Relaxed);
        batches[0].store(9, Ordering::Relaxed);
        let snap = t.snapshot();
        assert_eq!(snap.executor_models, vec![4, 7]);
        assert_eq!(snap.queue_depth_per_model, vec![0, 5]);
        assert_eq!(snap.batches_per_worker, vec![9, 0, 0]);
        // the gauges are live views, not copies
        depths[1].store(0, Ordering::Relaxed);
        assert_eq!(t.snapshot().queue_depth_per_model, vec![0, 0]);
    }
}
