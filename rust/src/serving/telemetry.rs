//! Serving telemetry: latency histograms with percentile queries, stage
//! breakdown (queueing vs execution — the paper's T_q / T_s split), and
//! throughput counters. Lock-light: one mutex per histogram, updated
//! once per query.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Fixed-boundary log-scale histogram from 1 µs to ~100 s, plus an exact
/// reservoir of recent samples for precise percentiles in experiments.
///
/// The buckets are a **rolling** estimator, not a lifetime tally: every
/// [`BUCKET_DECAY_EVERY`] records, all bucket counts are halved, so old
/// mass decays geometrically and a latency shift moves the bucket-derived
/// percentiles within a few decay periods instead of having to outvote
/// the server's entire history. `count`/`mean`/`max` stay cumulative
/// (they feed throughput and lifetime stats, not the tail estimate).
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    /// Records since the last bucket halving (see `record`).
    bucket_ops: AtomicU64,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
    samples: Mutex<Vec<f64>>, // seconds; capped reservoir
    /// Relaxed mirror of `samples.len()`: recorders check it before
    /// touching the mutex, so a full reservoir costs zero lock traffic
    /// on the (now multi-threaded, collector-less) completion path.
    sampled: AtomicUsize,
    cap: usize,
}

const BUCKETS_PER_DECADE: usize = 10;
const DECADES: usize = 8; // 1 µs .. 100 s

/// Halve every bucket after this many records: bounds the weight of
/// history in the bucket-derived percentiles to a geometric window of
/// roughly `2 × BUCKET_DECAY_EVERY` recent samples, whatever the
/// uptime. Count-based (not wall-clock) so the record path needs no
/// clock and idle servers keep their last known shape.
const BUCKET_DECAY_EVERY: u64 = 8192;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new(100_000)
    }
}

impl LatencyHistogram {
    pub fn new(sample_cap: usize) -> Self {
        LatencyHistogram {
            buckets: (0..BUCKETS_PER_DECADE * DECADES).map(|_| AtomicU64::new(0)).collect(),
            bucket_ops: AtomicU64::new(0),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
            samples: Mutex::new(Vec::new()),
            sampled: AtomicUsize::new(0),
            cap: sample_cap,
        }
    }

    fn bucket_index(ns: u64) -> usize {
        let us = (ns as f64 / 1000.0).max(1.0);
        let idx = (us.log10() * BUCKETS_PER_DECADE as f64) as usize;
        idx.min(BUCKETS_PER_DECADE * DECADES - 1)
    }

    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos() as u64;
        self.buckets[Self::bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        // rolling window: exactly one recorder per decay period wins the
        // CAS and halves the buckets (racing increments may be lost to a
        // concurrent halving — estimation-grade accuracy by design)
        let ops = self.bucket_ops.fetch_add(1, Ordering::Relaxed) + 1;
        if ops >= BUCKET_DECAY_EVERY
            && self
                .bucket_ops
                .compare_exchange(ops, 0, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
        {
            for bucket in &self.buckets {
                let v = bucket.load(Ordering::Relaxed);
                if v > 0 {
                    bucket.store(v / 2, Ordering::Relaxed);
                }
            }
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
        // reservoir fast path: once full, recorders never take the lock
        // again (the authoritative cap check stays inside the lock —
        // `sampled` may lag behind, never run ahead)
        if self.sampled.load(Ordering::Relaxed) >= self.cap {
            return;
        }
        let mut s = self.samples.lock().expect("telemetry poisoned");
        if s.len() < self.cap {
            s.push(d.as_secs_f64());
            self.sampled.store(s.len(), Ordering::Relaxed);
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64 / 1e9
    }

    pub fn max(&self) -> f64 {
        self.max_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Percentile of everything recorded so far. While the reservoir
    /// still holds every sample (short experiment runs) this is exact;
    /// once recording outruns the cap the reservoir is a frozen warm-up
    /// snapshot, so the estimate switches to the log-scale buckets —
    /// which every `record` keeps updating forever — with log-linear
    /// interpolation inside the covering bucket. A long-running server
    /// therefore reports *live* tail latencies, not its first 100k
    /// samples; resolution is one bucket (10 per decade, ≤ ~26%).
    pub fn percentile(&self, p: f64) -> f64 {
        let total = self.count();
        {
            let s = self.samples.lock().expect("telemetry poisoned");
            // `count` rises before the reservoir push, so `total` can
            // transiently exceed `s.len()` by in-flight recorders — the
            // bucket path absorbs that harmlessly
            if total <= s.len() as u64 {
                return crate::metrics::percentile(&s, p);
            }
        }
        self.percentile_from_buckets(p)
    }

    /// Bucket-only percentile estimate: one lock-free pass over the 80
    /// counters, never touching the reservoir mutex. This is the form
    /// the adaptive deadline controller reads on the arm hot path —
    /// until the reservoir saturates, [`Self::percentile`] holds the
    /// sample mutex through a clone + sort (O(n log n) near the 100k
    /// cap), which would stall recorders and the very tail the
    /// controller is steering; permille-resolution control only needs
    /// bucket accuracy anyway.
    pub fn percentile_fast(&self, p: f64) -> f64 {
        self.percentile_from_buckets(p)
    }

    /// Bucket-derived percentile over the decayed (rolling) bucket
    /// window: find the bucket covering the rank, interpolate linearly
    /// between its (log-spaced) boundaries by the rank's position
    /// within the bucket count. The rank base is the buckets' own sum —
    /// NOT the cumulative `count` — so halvings keep the estimate
    /// anchored to recent traffic.
    fn percentile_from_buckets(&self, p: f64) -> f64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        // same rank convention as `metrics::percentile`: p over [0, n-1]
        let rank = (p / 100.0).clamp(0.0, 1.0) * (total - 1) as f64;
        let mut below = 0u64;
        for (i, &n) in counts.iter().enumerate() {
            if n == 0 {
                continue;
            }
            if rank < (below + n) as f64 {
                let lo_us = 10f64.powf(i as f64 / BUCKETS_PER_DECADE as f64);
                let hi_us = 10f64.powf((i + 1) as f64 / BUCKETS_PER_DECADE as f64);
                let frac = ((rank - below as f64) / n as f64).clamp(0.0, 1.0);
                return (lo_us + frac * (hi_us - lo_us)) * 1e-6;
            }
            below += n;
        }
        // unreachable with the snapshot above (rank < total by
        // construction), kept as a defensive floor
        self.max()
    }

    /// Drain retained samples (for experiment CSVs); re-arms the
    /// reservoir.
    pub fn take_samples(&self) -> Vec<f64> {
        let mut s = self.samples.lock().expect("telemetry poisoned");
        let out = std::mem::take(&mut *s);
        self.sampled.store(0, Ordering::Relaxed);
        out
    }
}

/// Live gauges of the work-stealing executor, shared with its lanes
/// and workers (the counters themselves, not copies): per-model queue
/// depth makes a hot model visible, per-worker executed-batch counts
/// make pool imbalance visible. Installed into [`Telemetry`] by
/// `Pipeline::spawn` so `/stats` and the bedside report see them.
#[derive(Debug)]
pub struct ExecutorGauges {
    /// Zoo model index per lane, in member (model-index) order.
    models: Vec<usize>,
    /// Per-lane items admitted and not yet scored/failed.
    depths: Arc<[AtomicUsize]>,
    /// Per-worker device batches executed.
    batches: Arc<[AtomicU64]>,
    /// Per-lane fill wait last armed by the deadline controller, ns —
    /// the static `timeout` on a non-adaptive pipeline, the live
    /// adapted deadline under `--adaptive-batch`.
    fill_waits: Arc<[AtomicU64]>,
    /// Per-lane dead flags — a true entry is a lane whose backend
    /// failed and is (pending governor action) out of service.
    dead: Arc<[AtomicBool]>,
    /// Per-lane transient-error retries (the bounded in-flush retry).
    retries: Arc<[AtomicU64]>,
}

impl ExecutorGauges {
    pub fn new(
        models: Vec<usize>,
        depths: Arc<[AtomicUsize]>,
        batches: Arc<[AtomicU64]>,
        fill_waits: Arc<[AtomicU64]>,
        dead: Arc<[AtomicBool]>,
        retries: Arc<[AtomicU64]>,
    ) -> Self {
        assert_eq!(models.len(), depths.len(), "one depth gauge per lane");
        assert_eq!(models.len(), fill_waits.len(), "one fill-wait gauge per lane");
        assert_eq!(models.len(), dead.len(), "one dead flag per lane");
        assert_eq!(models.len(), retries.len(), "one retry counter per lane");
        ExecutorGauges { models, depths, batches, fill_waits, dead, retries }
    }

    pub fn models(&self) -> &[usize] {
        &self.models
    }

    /// Current queue depth per lane (same order as [`Self::models`]).
    pub fn queue_depths(&self) -> Vec<u64> {
        self.depths.iter().map(|d| d.load(Ordering::Relaxed) as u64).collect()
    }

    /// Batches executed per pool worker so far.
    pub fn worker_batches(&self) -> Vec<u64> {
        self.batches.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Last armed fill wait per lane, ns (same order as
    /// [`Self::models`]).
    pub fn fill_waits_ns(&self) -> Vec<u64> {
        self.fill_waits.iter().map(|w| w.load(Ordering::Relaxed)).collect()
    }

    /// Dead flag per lane (same order as [`Self::models`]).
    pub fn dead_lanes(&self) -> Vec<bool> {
        self.dead.iter().map(|d| d.load(Ordering::Relaxed)).collect()
    }

    /// Transient-error retries per lane (same order as
    /// [`Self::models`]).
    pub fn retries(&self) -> Vec<u64> {
        self.retries.iter().map(|r| r.load(Ordering::Relaxed)).collect()
    }
}

/// Live gauges of the ensemble governor's control loop: the current
/// membership epoch, how many members are active, swap/degrade/
/// quarantine counters. Installed into [`Telemetry`] by
/// `Governor::spawn`; absent on an ungoverned pipeline.
#[derive(Debug, Default)]
pub struct GovernorGauges {
    /// Membership epoch last installed (0 = the spawn-time full set).
    pub epoch: AtomicU64,
    /// Members in the active set under that epoch.
    pub active_members: AtomicUsize,
    /// Membership installs performed (recompose, degrade, quarantine,
    /// reinstate — every hot swap counts once).
    pub swaps: AtomicU64,
    /// 1 while serving from the degraded-mode floor, else 0.
    pub degraded: AtomicU64,
    /// Times the governor stepped down to the floor, lifetime.
    pub degraded_entered: AtomicU64,
    /// Lanes currently quarantined (dead and awaiting canary success).
    pub quarantined: AtomicUsize,
    /// Canary probes attempted, lifetime.
    pub probes: AtomicU64,
    /// Lanes revived after a successful canary, lifetime.
    pub reinstated: AtomicU64,
}

/// Live gauges of the router tier's peer links, shared with the
/// forwarders and the health prober (the counters themselves, not
/// copies). Installed into the *router process's* [`Telemetry`] by
/// `Router::spawn`; absent on a plain serve node.
///
/// Peer state encoding (`peer_states`): 0 = healthy, 1 = suspect,
/// 2 = dead, 3 = draining — mirrors `router::health::PeerState`.
#[derive(Debug)]
pub struct RouterGauges {
    /// Per-peer health state (see encoding above).
    pub peer_states: Vec<AtomicU8>,
    /// Per-peer frames delivered over the persistent link, lifetime.
    pub frames_forwarded: Vec<AtomicU64>,
    /// Per-peer transport retries (redial + backoff re-POST), lifetime.
    pub forward_retries: Vec<AtomicU64>,
    /// Per-peer frames currently parked in the link's spill buffer.
    pub spill_depth: Vec<AtomicU64>,
    /// Frames that ever entered a spill buffer, lifetime.
    pub spilled_total: AtomicU64,
    /// Spilled frames replayed to a survivor after failover, lifetime.
    pub spill_replayed: AtomicU64,
    /// Frames lost because a spill buffer overflowed its cap, lifetime
    /// (must stay 0 in every budgeted scenario).
    pub spill_overflow: AtomicU64,
    /// Stranded frames dropped because failover replay could not place
    /// them within its deadline — every survivor saturated or gone,
    /// lifetime (must stay 0 in every budgeted scenario).
    pub replay_dropped: AtomicU64,
    /// Patients re-homed off a dead or draining peer, lifetime.
    pub patients_rehomed: AtomicU64,
    /// Peers canary-probed back to healthy after death/drain, lifetime.
    pub peers_reinstated: AtomicU64,
    /// Per-peer artifact count last advertised on a heartbeat response
    /// (`"artifacts":N`) — how much of the model set each peer holds
    /// resident, as seen by the health prober.
    pub artifacts_resident: Vec<AtomicU64>,
}

impl RouterGauges {
    pub fn new(n_peers: usize) -> Self {
        RouterGauges {
            peer_states: (0..n_peers).map(|_| AtomicU8::new(0)).collect(),
            frames_forwarded: (0..n_peers).map(|_| AtomicU64::new(0)).collect(),
            forward_retries: (0..n_peers).map(|_| AtomicU64::new(0)).collect(),
            spill_depth: (0..n_peers).map(|_| AtomicU64::new(0)).collect(),
            spilled_total: AtomicU64::new(0),
            spill_replayed: AtomicU64::new(0),
            spill_overflow: AtomicU64::new(0),
            replay_dropped: AtomicU64::new(0),
            patients_rehomed: AtomicU64::new(0),
            peers_reinstated: AtomicU64::new(0),
            artifacts_resident: (0..n_peers).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn n_peers(&self) -> usize {
        self.peer_states.len()
    }

    pub fn peer_states(&self) -> Vec<u64> {
        self.peer_states.iter().map(|s| s.load(Ordering::Relaxed) as u64).collect()
    }

    pub fn frames_forwarded(&self) -> Vec<u64> {
        self.frames_forwarded.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    pub fn forward_retries(&self) -> Vec<u64> {
        self.forward_retries.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    pub fn spill_depths(&self) -> Vec<u64> {
        self.spill_depth.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    pub fn artifacts_resident(&self) -> Vec<u64> {
        self.artifacts_resident.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }
}

/// Live gauges of the event-driven ingest edge, shared with its event
/// loops (the counters themselves, not copies): per-loop ready-event
/// totals make loop imbalance visible the same way per-worker batch
/// counts expose executor imbalance. Installed into [`Telemetry`] by
/// the epoll edge at spawn.
#[derive(Debug)]
pub struct EdgeGauges {
    /// epoll_wait readiness events handled per event loop.
    ready_events: Arc<[AtomicU64]>,
}

impl EdgeGauges {
    pub fn new(ready_events: Arc<[AtomicU64]>) -> Self {
        EdgeGauges { ready_events }
    }

    /// Number of event-loop threads.
    pub fn loops(&self) -> usize {
        self.ready_events.len()
    }

    /// Readiness events handled so far, per loop.
    pub fn ready_events(&self) -> Vec<u64> {
        self.ready_events.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }
}

/// Pipeline-wide telemetry.
#[derive(Debug, Default)]
pub struct Telemetry {
    /// End-to-end: window emitted → prediction ready (T_q + T_s).
    pub e2e: LatencyHistogram,
    /// Queueing component: window emitted → first model starts executing.
    pub queueing: LatencyHistogram,
    /// Device execution per model job.
    pub exec: LatencyHistogram,
    /// Data-collection latency: frame ingest → aggregator push done.
    pub ingest: LatencyHistogram,
    pub queries: AtomicU64,
    pub model_jobs: AtomicU64,
    pub frames: AtomicU64,
    /// Frames the aggregation front-end discarded (malformed payload,
    /// wrong patient) — silent data loss made visible; per-shard
    /// breakdowns live on the shard router
    /// ([`super::shards::ShardRouter::dropped_per_shard`]).
    pub frames_dropped: AtomicU64,
    /// Per-cause breakdown of `frames_dropped` — the three causes
    /// partition the total exactly, so replay invariants can assert an
    /// injected fault budget against each one:
    /// malformed payload (bad lead arity, wrong patient),
    pub frames_dropped_malformed: AtomicU64,
    /// new patient refused because the shard was at
    /// `ShardConfig::max_patients` with no idle aggregator to evict,
    pub frames_dropped_overcap: AtomicU64,
    /// and ECG frames older than the window position (skewed monitor
    /// clocks / out-of-order arrival — see
    /// [`super::WindowAggregator::stale`]).
    pub frames_stale: AtomicU64,
    /// Duplicate batch deliveries dedupled on the ingest edge: a
    /// router retried an `HLMS`-tagged batch this node had already
    /// admitted (the response was lost, not the request). The frames
    /// are acknowledged but not re-delivered — exactly-once despite
    /// at-least-once transport.
    pub frames_deduped: AtomicU64,
    /// Last-admitted batch sequence per router link token (the `HLMS`
    /// dedupe state behind [`Self::admit_batch`]). One entry per link
    /// that ever forwarded here; tokens are random per link lifetime,
    /// so the map stays tiny.
    batch_seen: Mutex<HashMap<u64, u64>>,
    /// Queries evicted because a member could not score them.
    pub failures: AtomicU64,
    /// Idle patient aggregators evicted (least-recently-updated) to
    /// admit new patients past `ShardConfig::max_patients` — admission
    /// churn made visible instead of silently starving new patients.
    pub patients_evicted: AtomicU64,
    /// Live HTTP connections on the ingest edge. Doubles as the
    /// connection gate: both edges increment at accept and refuse with
    /// `503` past [`HttpConfig::max_connections`]
    /// (crate::http::HttpConfig), so the gate and the gauge can never
    /// disagree.
    pub conns_active: AtomicUsize,
    /// Connections accepted by the ingest edge, lifetime total.
    pub conns_accepted: AtomicU64,
    /// Connections refused with `503` at the gate, lifetime total
    /// (= `conns_refused_overcap` + `conns_refused_handshake`).
    pub conns_refused: AtomicU64,
    /// Refused because `conns_active` was at `max_connections`.
    pub conns_refused_overcap: AtomicU64,
    /// Accepted by the listener but torn down before serving a request
    /// because edge setup failed (epoll registration, handler spawn).
    pub conns_refused_handshake: AtomicU64,
    /// Connections reaped by the idle/read deadline (slow-loris sweep).
    pub conns_reaped: AtomicU64,
    /// Set by `POST /drain` (or SIGTERM on a serve node): this node is
    /// draining for a rolling upgrade. Heartbeat responses advertise it
    /// so the router re-homes this peer's patients *before* the process
    /// exits — zero dropped frames instead of a failover.
    pub draining: AtomicBool,
    /// Artifact bundles this node pulled from a peer registry over
    /// `GET /artifact/<id>` (digest-verified before counting).
    pub artifacts_fetched: AtomicU64,
    /// Artifact bundles this node served to peers from its local
    /// content-addressed store.
    pub artifacts_served: AtomicU64,
    /// Blobs rejected because their bytes did not re-digest to the
    /// requested [`crate::registry::ArtifactId`] — a corrupt or
    /// tampered bundle that was *not* served or installed.
    pub artifacts_verify_failed: AtomicU64,
    /// Artifacts the active member set requires on this node
    /// (recomputed by the governor on every membership install).
    pub artifacts_required: AtomicU64,
    /// Of [`Self::artifacts_required`], how many are resident locally.
    /// Heartbeat responses advertise `resident >= required` so the
    /// router can refuse to (re)admit a peer that cannot serve yet.
    pub artifacts_resident: AtomicU64,
    /// Executor gauges, installed once by `Pipeline::spawn` (absent for
    /// telemetry created outside a pipeline — benches, shard tests).
    executor: OnceLock<ExecutorGauges>,
    /// Ingest-edge gauges, installed once by the epoll edge (absent on
    /// the thread-per-conn fallback and for non-HTTP ingestion).
    edge: OnceLock<EdgeGauges>,
    /// Governor gauges, installed once by `Governor::spawn` (absent on
    /// an ungoverned pipeline).
    governor: OnceLock<Arc<GovernorGauges>>,
    /// Router-tier gauges, installed once by `Router::spawn` (absent
    /// on anything but a router process).
    router: OnceLock<Arc<RouterGauges>>,
    /// Shared compiled-executable cache gauges, installed once by
    /// `Pipeline::spawn` from the engine's backend (absent for
    /// telemetry created outside a pipeline, or on backends without a
    /// shared cache).
    exec_cache: OnceLock<Arc<crate::runtime::ExecCacheGauges>>,
    /// Local content-addressed artifact store, installed once by the
    /// serve path when `--registry-root` is given. The ingest edge
    /// serves `GET /artifact/<id>` straight out of it.
    artifact_store: OnceLock<Arc<crate::registry::LocalFs>>,
}

impl Telemetry {
    /// Attach the executor's live gauges (once; later installs are
    /// ignored, matching a pipeline's one-executor lifetime).
    pub fn install_executor(&self, gauges: ExecutorGauges) {
        let _ = self.executor.set(gauges);
    }

    pub fn executor(&self) -> Option<&ExecutorGauges> {
        self.executor.get()
    }

    /// Attach the ingest edge's live gauges (once; later installs are
    /// ignored, matching a server's one-edge lifetime).
    pub fn install_edge(&self, gauges: EdgeGauges) {
        let _ = self.edge.set(gauges);
    }

    pub fn edge(&self) -> Option<&EdgeGauges> {
        self.edge.get()
    }

    /// Attach the governor's live gauges (once; later installs are
    /// ignored, matching a pipeline's one-governor lifetime).
    pub fn install_governor(&self, gauges: Arc<GovernorGauges>) {
        let _ = self.governor.set(gauges);
    }

    pub fn governor(&self) -> Option<&Arc<GovernorGauges>> {
        self.governor.get()
    }

    /// Attach the router tier's live gauges (once; later installs are
    /// ignored, matching a process's one-router lifetime).
    pub fn install_router(&self, gauges: Arc<RouterGauges>) {
        let _ = self.router.set(gauges);
    }

    pub fn router(&self) -> Option<&Arc<RouterGauges>> {
        self.router.get()
    }

    /// Attach the shared executable cache's live gauges (once; later
    /// installs are ignored — one process-wide cache per backend).
    pub fn install_exec_cache(&self, gauges: Arc<crate::runtime::ExecCacheGauges>) {
        let _ = self.exec_cache.set(gauges);
    }

    pub fn exec_cache(&self) -> Option<&Arc<crate::runtime::ExecCacheGauges>> {
        self.exec_cache.get()
    }

    /// Attach the local content-addressed artifact store (once; later
    /// installs are ignored — one registry root per process). The HTTP
    /// edges use it to answer `GET /artifact/<id>`.
    pub fn install_artifact_store(&self, store: Arc<crate::registry::LocalFs>) {
        let _ = self.artifact_store.set(store);
    }

    pub fn artifact_store(&self) -> Option<&Arc<crate::registry::LocalFs>> {
        self.artifact_store.get()
    }

    /// `HLMS` idempotency check: admit a batch iff this (token, seq)
    /// is newer than the last batch admitted under that token. A link
    /// worker delivers batches strictly in sequence order and repeats
    /// a sequence only when the response (not the request) was lost,
    /// so `seq <= last` is always a retry of work already done —
    /// callers acknowledge it without re-delivering the frames and
    /// count it in [`Self::frames_deduped`].
    pub fn admit_batch(&self, token: u64, seq: u64) -> bool {
        let mut seen = self.batch_seen.lock().expect("telemetry poisoned");
        match seen.get(&token) {
            Some(&last) if seq <= last => false,
            _ => {
                seen.insert(token, seq);
                true
            }
        }
    }

    pub fn snapshot(&self) -> TelemetrySnapshot {
        let (models, queue_depths, worker_batches, fill_waits, dead_lanes, retries) =
            match self.executor.get() {
                Some(g) => (
                    g.models().iter().map(|&m| m as u64).collect(),
                    g.queue_depths(),
                    g.worker_batches(),
                    g.fill_waits_ns(),
                    g.dead_lanes().iter().map(|&d| u64::from(d)).collect(),
                    g.retries(),
                ),
                None => (Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new()),
            };
        let gov = self.governor.get();
        let rt = self.router.get();
        let ec = self.exec_cache.get();
        TelemetrySnapshot {
            executor_models: models,
            queue_depth_per_model: queue_depths,
            batches_per_worker: worker_batches,
            fill_wait_ns_per_model: fill_waits,
            dead_lanes,
            retries_per_model: retries,
            governor_epoch: gov.map(|g| g.epoch.load(Ordering::Relaxed)).unwrap_or(0),
            governor_active_members: gov
                .map(|g| g.active_members.load(Ordering::Relaxed) as u64)
                .unwrap_or(0),
            governor_swaps: gov.map(|g| g.swaps.load(Ordering::Relaxed)).unwrap_or(0),
            governor_degraded: gov.map(|g| g.degraded.load(Ordering::Relaxed)).unwrap_or(0),
            governor_degraded_entered: gov
                .map(|g| g.degraded_entered.load(Ordering::Relaxed))
                .unwrap_or(0),
            governor_quarantined: gov
                .map(|g| g.quarantined.load(Ordering::Relaxed) as u64)
                .unwrap_or(0),
            governor_probes: gov.map(|g| g.probes.load(Ordering::Relaxed)).unwrap_or(0),
            governor_reinstated: gov.map(|g| g.reinstated.load(Ordering::Relaxed)).unwrap_or(0),
            router_peer_states: rt.map(|g| g.peer_states()).unwrap_or_default(),
            router_frames_forwarded: rt.map(|g| g.frames_forwarded()).unwrap_or_default(),
            router_forward_retries: rt.map(|g| g.forward_retries()).unwrap_or_default(),
            router_spill_depth: rt.map(|g| g.spill_depths()).unwrap_or_default(),
            router_spilled_total: rt
                .map(|g| g.spilled_total.load(Ordering::Relaxed))
                .unwrap_or(0),
            router_spill_replayed: rt
                .map(|g| g.spill_replayed.load(Ordering::Relaxed))
                .unwrap_or(0),
            router_spill_overflow: rt
                .map(|g| g.spill_overflow.load(Ordering::Relaxed))
                .unwrap_or(0),
            router_replay_dropped: rt
                .map(|g| g.replay_dropped.load(Ordering::Relaxed))
                .unwrap_or(0),
            router_patients_rehomed: rt
                .map(|g| g.patients_rehomed.load(Ordering::Relaxed))
                .unwrap_or(0),
            router_peers_reinstated: rt
                .map(|g| g.peers_reinstated.load(Ordering::Relaxed))
                .unwrap_or(0),
            router_artifacts_resident: rt.map(|g| g.artifacts_resident()).unwrap_or_default(),
            exec_cache_hits: ec.map(|g| g.hits.load(Ordering::Relaxed)).unwrap_or(0),
            exec_cache_misses: ec.map(|g| g.misses.load(Ordering::Relaxed)).unwrap_or(0),
            exec_cache_compiles: ec.map(|g| g.compiles.load(Ordering::Relaxed)).unwrap_or(0),
            artifacts_fetched: self.artifacts_fetched.load(Ordering::Relaxed),
            artifacts_served: self.artifacts_served.load(Ordering::Relaxed),
            artifacts_verify_failed: self.artifacts_verify_failed.load(Ordering::Relaxed),
            artifacts_required: self.artifacts_required.load(Ordering::Relaxed),
            artifacts_resident: self.artifacts_resident.load(Ordering::Relaxed),
            draining: u64::from(self.draining.load(Ordering::Relaxed)),
            conns_active: self.conns_active.load(Ordering::Relaxed) as u64,
            conns_accepted: self.conns_accepted.load(Ordering::Relaxed),
            conns_refused: self.conns_refused.load(Ordering::Relaxed),
            conns_refused_overcap: self.conns_refused_overcap.load(Ordering::Relaxed),
            conns_refused_handshake: self.conns_refused_handshake.load(Ordering::Relaxed),
            conns_reaped: self.conns_reaped.load(Ordering::Relaxed),
            edge_ready_events: self.edge.get().map(|g| g.ready_events()).unwrap_or_default(),
            queries: self.queries.load(Ordering::Relaxed),
            model_jobs: self.model_jobs.load(Ordering::Relaxed),
            frames: self.frames.load(Ordering::Relaxed),
            frames_deduped: self.frames_deduped.load(Ordering::Relaxed),
            frames_dropped: self.frames_dropped.load(Ordering::Relaxed),
            frames_dropped_malformed: self.frames_dropped_malformed.load(Ordering::Relaxed),
            frames_dropped_overcap: self.frames_dropped_overcap.load(Ordering::Relaxed),
            frames_stale: self.frames_stale.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
            patients_evicted: self.patients_evicted.load(Ordering::Relaxed),
            e2e_mean: self.e2e.mean(),
            e2e_p50: self.e2e.percentile(50.0),
            e2e_p95: self.e2e.percentile(95.0),
            e2e_p99: self.e2e.percentile(99.0),
            e2e_max: self.e2e.max(),
            queueing_mean: self.queueing.mean(),
            queueing_p95: self.queueing.percentile(95.0),
            exec_mean: self.exec.mean(),
            ingest_p95: self.ingest.percentile(95.0),
        }
    }
}

/// Plain-old-data snapshot for the /stats endpoint and CSVs.
#[derive(Debug, Clone)]
pub struct TelemetrySnapshot {
    /// Zoo model index per executor lane (empty without a pipeline).
    pub executor_models: Vec<u64>,
    /// Live queue depth per lane, same order as `executor_models`.
    pub queue_depth_per_model: Vec<u64>,
    /// Device batches executed per executor worker.
    pub batches_per_worker: Vec<u64>,
    /// Last armed batch fill wait per lane, ns (static timeout, or the
    /// adapted deadline under `--adaptive-batch`).
    pub fill_wait_ns_per_model: Vec<u64>,
    /// 0/1 per lane: 1 = the lane's backend failed and the lane is out
    /// of service (quarantined until the governor revives it).
    pub dead_lanes: Vec<u64>,
    /// Transient-error retries per lane.
    pub retries_per_model: Vec<u64>,
    /// Governor state (all zero on an ungoverned pipeline).
    pub governor_epoch: u64,
    pub governor_active_members: u64,
    pub governor_swaps: u64,
    pub governor_degraded: u64,
    pub governor_degraded_entered: u64,
    pub governor_quarantined: u64,
    pub governor_probes: u64,
    pub governor_reinstated: u64,
    /// Router-tier state (all empty/zero on anything but a router
    /// process). Peer state encoding: 0 healthy, 1 suspect, 2 dead,
    /// 3 draining.
    pub router_peer_states: Vec<u64>,
    pub router_frames_forwarded: Vec<u64>,
    pub router_forward_retries: Vec<u64>,
    pub router_spill_depth: Vec<u64>,
    pub router_spilled_total: u64,
    pub router_spill_replayed: u64,
    pub router_spill_overflow: u64,
    pub router_replay_dropped: u64,
    pub router_patients_rehomed: u64,
    pub router_peers_reinstated: u64,
    /// Per-peer artifact count last advertised on a heartbeat (router
    /// processes only; same order as `router_peer_states`).
    pub router_artifacts_resident: Vec<u64>,
    /// Shared executable cache: lookup hits/misses and single-flight
    /// compiles (compiles ≤ misses; all zero without a shared cache).
    pub exec_cache_hits: u64,
    pub exec_cache_misses: u64,
    pub exec_cache_compiles: u64,
    /// Registry traffic: bundles pulled from peers / served to peers /
    /// rejected on digest verification, lifetime.
    pub artifacts_fetched: u64,
    pub artifacts_served: u64,
    pub artifacts_verify_failed: u64,
    /// Active member set's artifact demand vs what is resident locally.
    pub artifacts_required: u64,
    pub artifacts_resident: u64,
    /// 1 while this node is draining for a rolling upgrade.
    pub draining: u64,
    /// Live HTTP connections on the ingest edge.
    pub conns_active: u64,
    /// Connections accepted / refused (503) / idle-reaped, lifetime.
    pub conns_accepted: u64,
    pub conns_refused: u64,
    /// Per-cause refusal split: gate over `max_connections` vs accepted
    /// but torn down during edge setup.
    pub conns_refused_overcap: u64,
    pub conns_refused_handshake: u64,
    pub conns_reaped: u64,
    /// Readiness events handled per event loop (empty on the
    /// thread-per-conn fallback edge).
    pub edge_ready_events: Vec<u64>,
    pub queries: u64,
    pub model_jobs: u64,
    pub frames: u64,
    /// Duplicate-batch frames acknowledged without re-delivery (`HLMS`
    /// dedupe on the ingest edge).
    pub frames_deduped: u64,
    pub frames_dropped: u64,
    /// Per-cause drop split (malformed + overcap + stale =
    /// `frames_dropped`).
    pub frames_dropped_malformed: u64,
    pub frames_dropped_overcap: u64,
    pub frames_stale: u64,
    pub failures: u64,
    /// Idle patient aggregators evicted for admission churn.
    pub patients_evicted: u64,
    pub e2e_mean: f64,
    pub e2e_p50: f64,
    pub e2e_p95: f64,
    pub e2e_p99: f64,
    pub e2e_max: f64,
    pub queueing_mean: f64,
    pub queueing_p95: f64,
    pub exec_mean: f64,
    pub ingest_p95: f64,
}

impl TelemetrySnapshot {
    pub fn to_json(&self) -> crate::json::Value {
        use crate::json::Value;
        let nums = |v: &[u64]| Value::Arr(v.iter().map(|&x| Value::Num(x as f64)).collect());
        Value::obj(vec![
            ("executor_models", nums(&self.executor_models)),
            ("queue_depth_per_model", nums(&self.queue_depth_per_model)),
            ("batches_per_worker", nums(&self.batches_per_worker)),
            ("fill_wait_ns_per_model", nums(&self.fill_wait_ns_per_model)),
            ("dead_lanes", nums(&self.dead_lanes)),
            ("retries_per_model", nums(&self.retries_per_model)),
            ("governor_epoch", Value::Num(self.governor_epoch as f64)),
            ("governor_active_members", Value::Num(self.governor_active_members as f64)),
            ("governor_swaps", Value::Num(self.governor_swaps as f64)),
            ("governor_degraded", Value::Num(self.governor_degraded as f64)),
            ("governor_degraded_entered", Value::Num(self.governor_degraded_entered as f64)),
            ("governor_quarantined", Value::Num(self.governor_quarantined as f64)),
            ("governor_probes", Value::Num(self.governor_probes as f64)),
            ("governor_reinstated", Value::Num(self.governor_reinstated as f64)),
            ("router_peer_states", nums(&self.router_peer_states)),
            ("router_frames_forwarded", nums(&self.router_frames_forwarded)),
            ("router_forward_retries", nums(&self.router_forward_retries)),
            ("router_spill_depth", nums(&self.router_spill_depth)),
            ("router_spilled_total", Value::Num(self.router_spilled_total as f64)),
            ("router_spill_replayed", Value::Num(self.router_spill_replayed as f64)),
            ("router_spill_overflow", Value::Num(self.router_spill_overflow as f64)),
            ("router_replay_dropped", Value::Num(self.router_replay_dropped as f64)),
            ("router_patients_rehomed", Value::Num(self.router_patients_rehomed as f64)),
            ("router_peers_reinstated", Value::Num(self.router_peers_reinstated as f64)),
            ("router_artifacts_resident", nums(&self.router_artifacts_resident)),
            ("exec_cache_hits", Value::Num(self.exec_cache_hits as f64)),
            ("exec_cache_misses", Value::Num(self.exec_cache_misses as f64)),
            ("exec_cache_compiles", Value::Num(self.exec_cache_compiles as f64)),
            ("artifacts_fetched", Value::Num(self.artifacts_fetched as f64)),
            ("artifacts_served", Value::Num(self.artifacts_served as f64)),
            ("artifacts_verify_failed", Value::Num(self.artifacts_verify_failed as f64)),
            ("artifacts_required", Value::Num(self.artifacts_required as f64)),
            ("artifacts_resident", Value::Num(self.artifacts_resident as f64)),
            ("draining", Value::Num(self.draining as f64)),
            ("conns_active", Value::Num(self.conns_active as f64)),
            ("conns_accepted", Value::Num(self.conns_accepted as f64)),
            ("conns_refused", Value::Num(self.conns_refused as f64)),
            ("conns_refused_overcap", Value::Num(self.conns_refused_overcap as f64)),
            ("conns_refused_handshake", Value::Num(self.conns_refused_handshake as f64)),
            ("conns_reaped", Value::Num(self.conns_reaped as f64)),
            ("edge_ready_events", nums(&self.edge_ready_events)),
            ("queries", Value::Num(self.queries as f64)),
            ("model_jobs", Value::Num(self.model_jobs as f64)),
            ("frames", Value::Num(self.frames as f64)),
            ("frames_deduped", Value::Num(self.frames_deduped as f64)),
            ("frames_dropped", Value::Num(self.frames_dropped as f64)),
            ("frames_dropped_malformed", Value::Num(self.frames_dropped_malformed as f64)),
            ("frames_dropped_overcap", Value::Num(self.frames_dropped_overcap as f64)),
            ("frames_stale", Value::Num(self.frames_stale as f64)),
            ("failures", Value::Num(self.failures as f64)),
            ("patients_evicted", Value::Num(self.patients_evicted as f64)),
            ("e2e_mean", Value::Num(self.e2e_mean)),
            ("e2e_p50", Value::Num(self.e2e_p50)),
            ("e2e_p95", Value::Num(self.e2e_p95)),
            ("e2e_p99", Value::Num(self.e2e_p99)),
            ("e2e_max", Value::Num(self.e2e_max)),
            ("queueing_mean", Value::Num(self.queueing_mean)),
            ("queueing_p95", Value::Num(self.queueing_p95)),
            ("exec_mean", Value::Num(self.exec_mean)),
            ("ingest_p95", Value::Num(self.ingest_p95)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_mean() {
        let h = LatencyHistogram::default();
        h.record(Duration::from_millis(10));
        h.record(Duration::from_millis(30));
        assert_eq!(h.count(), 2);
        assert!((h.mean() - 0.020).abs() < 1e-9);
        assert!((h.max() - 0.030).abs() < 1e-9);
    }

    #[test]
    fn percentiles_from_reservoir() {
        let h = LatencyHistogram::default();
        for i in 1..=100 {
            h.record(Duration::from_millis(i));
        }
        assert!((h.percentile(50.0) - 0.0505).abs() < 0.002);
        assert!((h.percentile(95.0) - 0.09505).abs() < 0.002);
    }

    #[test]
    fn bucket_index_monotone_and_bounded() {
        let mut last = 0;
        for ns in [1u64, 1_000, 10_000, 1_000_000, 10_000_000_000, u64::MAX / 2] {
            let b = LatencyHistogram::bucket_index(ns);
            assert!(b >= last);
            assert!(b < BUCKETS_PER_DECADE * DECADES);
            last = b;
        }
    }

    #[test]
    fn reservoir_respects_cap() {
        let h = LatencyHistogram::new(10);
        for _ in 0..100 {
            h.record(Duration::from_micros(5));
        }
        assert_eq!(h.take_samples().len(), 10);
        assert_eq!(h.count(), 100);
    }

    #[test]
    fn percentiles_track_a_shifted_distribution_after_saturation() {
        // the frozen-percentile bug: the reservoir fills during warm-up
        // and /stats reports those latencies forever. Record cap samples
        // from a fast distribution, then cap more from a 50× slower one
        // — the tail must follow the shift via the live buckets.
        let h = LatencyHistogram::new(100);
        for _ in 0..100 {
            h.record(Duration::from_millis(10));
        }
        // reservoir exact and still authoritative at the boundary
        assert!((h.percentile(95.0) - 0.010).abs() < 0.004);
        for _ in 0..100 {
            h.record(Duration::from_millis(500));
        }
        let p95 = h.percentile(95.0);
        assert!(
            (0.3..0.8).contains(&p95),
            "p95 must land in the 500 ms bucket, not freeze at warm-up: {p95}"
        );
        // the low quartile still sees the warm-up mass (≤ one bucket of
        // log error above 10 ms)
        let p25 = h.percentile(25.0);
        assert!(p25 < 0.02, "p25 should stay near 10 ms: {p25}");
        // draining the reservoir must not resurrect stale exactness
        let drained = h.take_samples();
        assert_eq!(drained.len(), 100);
        let p95_after = h.percentile(95.0);
        assert!((0.3..0.8).contains(&p95_after), "bucket path after drain: {p95_after}");
    }

    #[test]
    fn bucket_window_decays_so_tails_follow_recent_traffic() {
        // lifetime-cumulative buckets would need the slow samples to
        // outvote the entire fast history before p95 moved; the rolling
        // (halving) window must follow the shift within a few periods
        let h = LatencyHistogram::new(4); // tiny reservoir: bucket path
        for _ in 0..3 * BUCKET_DECAY_EVERY {
            h.record(Duration::from_millis(1));
        }
        assert!(h.percentile(95.0) < 0.01, "fast-only history");
        for _ in 0..2 * BUCKET_DECAY_EVERY {
            h.record(Duration::from_millis(900));
        }
        let p95 = h.percentile(95.0);
        assert!(
            p95 > 0.5,
            "p95 must track the overload within two decay periods: {p95}"
        );
    }

    #[test]
    fn bucket_percentiles_are_monotone() {
        let h = LatencyHistogram::new(4); // saturate immediately
        for ms in [1u64, 2, 5, 10, 50, 100, 300, 900] {
            h.record(Duration::from_millis(ms));
        }
        let mut last = 0.0f64;
        for p in [10.0, 25.0, 50.0, 75.0, 90.0, 99.0] {
            let v = h.percentile(p);
            assert!(v >= last, "p{p}: {v} < {last}");
            last = v;
        }
        assert!(last <= h.max() * 1.3, "tail estimate stays near the true max");
    }

    #[test]
    fn snapshot_is_serializable() {
        let t = Telemetry::default();
        t.e2e.record(Duration::from_millis(1));
        let s = t.snapshot().to_json().to_string();
        assert!(s.contains("e2e_p95"));
        assert!(s.contains("queue_depth_per_model"));
        assert!(s.contains("batches_per_worker"));
        assert!(s.contains("fill_wait_ns_per_model"));
        assert!(s.contains("dead_lanes"));
        assert!(s.contains("retries_per_model"));
        assert!(s.contains("patients_evicted"));
        assert!(s.contains("governor_epoch"));
        assert!(s.contains("governor_reinstated"));
        assert!(s.contains("conns_active"));
        assert!(s.contains("conns_accepted"));
        assert!(s.contains("edge_ready_events"));
        // per-cause splits for the replay harness's budget assertions
        assert!(s.contains("frames_dropped_malformed"));
        assert!(s.contains("frames_dropped_overcap"));
        assert!(s.contains("frames_stale"));
        assert!(s.contains("conns_refused_overcap"));
        assert!(s.contains("conns_refused_handshake"));
        // router tier + rolling-upgrade drain flag
        assert!(s.contains("router_peer_states"));
        assert!(s.contains("router_frames_forwarded"));
        assert!(s.contains("router_forward_retries"));
        assert!(s.contains("router_spill_depth"));
        assert!(s.contains("router_spilled_total"));
        assert!(s.contains("router_spill_replayed"));
        assert!(s.contains("router_spill_overflow"));
        assert!(s.contains("router_replay_dropped"));
        assert!(s.contains("router_patients_rehomed"));
        assert!(s.contains("router_peers_reinstated"));
        assert!(s.contains("frames_deduped"));
        assert!(s.contains("\"draining\""));
        // artifact identity: shared exec cache + registry traffic
        assert!(s.contains("router_artifacts_resident"));
        assert!(s.contains("exec_cache_hits"));
        assert!(s.contains("exec_cache_misses"));
        assert!(s.contains("exec_cache_compiles"));
        assert!(s.contains("artifacts_fetched"));
        assert!(s.contains("artifacts_served"));
        assert!(s.contains("artifacts_verify_failed"));
        assert!(s.contains("artifacts_required"));
        assert!(s.contains("\"artifacts_resident\""));
    }

    #[test]
    fn admit_batch_dedupes_retried_sequences_per_token() {
        let t = Telemetry::default();
        assert!(t.admit_batch(100, 0));
        assert!(t.admit_batch(100, 1));
        // a retry of an admitted sequence is refused...
        assert!(!t.admit_batch(100, 1));
        assert!(!t.admit_batch(100, 0));
        // ...but delivery resumes at the next sequence
        assert!(t.admit_batch(100, 2));
        // tokens are independent (one per link lifetime)
        assert!(t.admit_batch(200, 0));
        assert!(!t.admit_batch(200, 0));
        assert!(t.admit_batch(100, 3));
    }

    #[test]
    fn router_gauges_surface_in_snapshot() {
        let t = Telemetry::default();
        assert!(t.router().is_none());
        assert!(t.snapshot().router_peer_states.is_empty());
        let g = Arc::new(RouterGauges::new(2));
        t.install_router(Arc::clone(&g));
        g.peer_states[1].store(2, Ordering::Relaxed);
        g.frames_forwarded[0].store(500, Ordering::Relaxed);
        g.forward_retries[1].store(3, Ordering::Relaxed);
        g.spill_depth[1].store(7, Ordering::Relaxed);
        g.spilled_total.store(9, Ordering::Relaxed);
        g.spill_replayed.store(9, Ordering::Relaxed);
        g.replay_dropped.store(2, Ordering::Relaxed);
        g.patients_rehomed.store(4, Ordering::Relaxed);
        g.peers_reinstated.store(1, Ordering::Relaxed);
        g.artifacts_resident[0].store(6, Ordering::Relaxed);
        t.draining.store(true, Ordering::Relaxed);
        let snap = t.snapshot();
        assert_eq!(snap.router_peer_states, vec![0, 2]);
        assert_eq!(snap.router_frames_forwarded, vec![500, 0]);
        assert_eq!(snap.router_forward_retries, vec![0, 3]);
        assert_eq!(snap.router_spill_depth, vec![0, 7]);
        assert_eq!(snap.router_spilled_total, 9);
        assert_eq!(snap.router_spill_replayed, 9);
        assert_eq!(snap.router_spill_overflow, 0);
        assert_eq!(snap.router_replay_dropped, 2);
        assert_eq!(snap.router_patients_rehomed, 4);
        assert_eq!(snap.router_peers_reinstated, 1);
        assert_eq!(snap.router_artifacts_resident, vec![6, 0]);
        assert_eq!(snap.draining, 1);
        // live view, not a copy
        g.frames_forwarded[1].store(10, Ordering::Relaxed);
        assert_eq!(t.snapshot().router_frames_forwarded, vec![500, 10]);
    }

    #[test]
    fn governor_gauges_surface_in_snapshot() {
        let t = Telemetry::default();
        assert!(t.governor().is_none());
        assert_eq!(t.snapshot().governor_swaps, 0);
        let g = Arc::new(GovernorGauges::default());
        t.install_governor(Arc::clone(&g));
        g.epoch.store(3, Ordering::Relaxed);
        g.active_members.store(2, Ordering::Relaxed);
        g.swaps.store(4, Ordering::Relaxed);
        g.degraded.store(1, Ordering::Relaxed);
        g.degraded_entered.store(1, Ordering::Relaxed);
        g.quarantined.store(1, Ordering::Relaxed);
        g.probes.store(5, Ordering::Relaxed);
        g.reinstated.store(1, Ordering::Relaxed);
        let snap = t.snapshot();
        assert_eq!(snap.governor_epoch, 3);
        assert_eq!(snap.governor_active_members, 2);
        assert_eq!(snap.governor_swaps, 4);
        assert_eq!(snap.governor_degraded, 1);
        assert_eq!(snap.governor_degraded_entered, 1);
        assert_eq!(snap.governor_quarantined, 1);
        assert_eq!(snap.governor_probes, 5);
        assert_eq!(snap.governor_reinstated, 1);
        // live view, not a copy
        g.swaps.store(9, Ordering::Relaxed);
        assert_eq!(t.snapshot().governor_swaps, 9);
    }

    #[test]
    fn exec_cache_and_artifact_gauges_surface_in_snapshot() {
        let t = Telemetry::default();
        assert!(t.exec_cache().is_none());
        assert_eq!(t.snapshot().exec_cache_hits, 0);
        let g = Arc::new(crate::runtime::ExecCacheGauges::default());
        t.install_exec_cache(Arc::clone(&g));
        g.hits.store(40, Ordering::Relaxed);
        g.misses.store(12, Ordering::Relaxed);
        g.compiles.store(12, Ordering::Relaxed);
        t.artifacts_fetched.store(5, Ordering::Relaxed);
        t.artifacts_served.store(7, Ordering::Relaxed);
        t.artifacts_verify_failed.store(1, Ordering::Relaxed);
        t.artifacts_required.store(12, Ordering::Relaxed);
        t.artifacts_resident.store(12, Ordering::Relaxed);
        let snap = t.snapshot();
        assert_eq!(snap.exec_cache_hits, 40);
        assert_eq!(snap.exec_cache_misses, 12);
        assert_eq!(snap.exec_cache_compiles, 12);
        assert_eq!(snap.artifacts_fetched, 5);
        assert_eq!(snap.artifacts_served, 7);
        assert_eq!(snap.artifacts_verify_failed, 1);
        assert_eq!(snap.artifacts_required, 12);
        assert_eq!(snap.artifacts_resident, 12);
        // live view, not a copy
        g.hits.store(41, Ordering::Relaxed);
        assert_eq!(t.snapshot().exec_cache_hits, 41);
    }

    #[test]
    fn edge_gauges_surface_in_snapshot() {
        let t = Telemetry::default();
        assert!(t.edge().is_none());
        assert!(t.snapshot().edge_ready_events.is_empty());
        let ready: Arc<[AtomicU64]> = (0..2).map(|_| AtomicU64::new(0)).collect();
        t.install_edge(EdgeGauges::new(Arc::clone(&ready)));
        t.conns_active.store(3, Ordering::Relaxed);
        t.conns_accepted.store(11, Ordering::Relaxed);
        t.conns_refused.store(2, Ordering::Relaxed);
        t.conns_reaped.store(1, Ordering::Relaxed);
        ready[1].store(42, Ordering::Relaxed);
        let snap = t.snapshot();
        assert_eq!(snap.conns_active, 3);
        assert_eq!(snap.conns_accepted, 11);
        assert_eq!(snap.conns_refused, 2);
        assert_eq!(snap.conns_reaped, 1);
        assert_eq!(snap.edge_ready_events, vec![0, 42]);
        // the gauges are live views, not copies
        ready[0].store(7, Ordering::Relaxed);
        assert_eq!(t.snapshot().edge_ready_events, vec![7, 42]);
    }

    #[test]
    fn executor_gauges_surface_in_snapshot() {
        let t = Telemetry::default();
        assert!(t.executor().is_none());
        let depths: Arc<[AtomicUsize]> = (0..2).map(|_| AtomicUsize::new(0)).collect();
        let batches: Arc<[AtomicU64]> = (0..3).map(|_| AtomicU64::new(0)).collect();
        let waits: Arc<[AtomicU64]> = (0..2).map(|_| AtomicU64::new(0)).collect();
        let dead: Arc<[AtomicBool]> = (0..2).map(|_| AtomicBool::new(false)).collect();
        let retries: Arc<[AtomicU64]> = (0..2).map(|_| AtomicU64::new(0)).collect();
        t.install_executor(ExecutorGauges::new(
            vec![4, 7],
            Arc::clone(&depths),
            Arc::clone(&batches),
            Arc::clone(&waits),
            Arc::clone(&dead),
            Arc::clone(&retries),
        ));
        depths[1].store(5, Ordering::Relaxed);
        batches[0].store(9, Ordering::Relaxed);
        waits[0].store(1_000_000, Ordering::Relaxed);
        dead[1].store(true, Ordering::Relaxed);
        retries[0].store(2, Ordering::Relaxed);
        let snap = t.snapshot();
        assert_eq!(snap.executor_models, vec![4, 7]);
        assert_eq!(snap.queue_depth_per_model, vec![0, 5]);
        assert_eq!(snap.batches_per_worker, vec![9, 0, 0]);
        assert_eq!(snap.fill_wait_ns_per_model, vec![1_000_000, 0]);
        assert_eq!(snap.dead_lanes, vec![0, 1]);
        assert_eq!(snap.retries_per_model, vec![2, 0]);
        // the gauges are live views, not copies
        depths[1].store(0, Ordering::Relaxed);
        assert_eq!(t.snapshot().queue_depth_per_model, vec![0, 0]);
    }
}
