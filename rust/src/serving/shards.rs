//! Sharded patient-aggregation front-end: the stage between frame
//! ingest and query admission, with **no thread that touches every
//! frame**.
//!
//! The pre-shard plane funneled every frame — 64 beds × 251 frames/s,
//! ~25k/s at the paper's 100-bed target — through one
//! `mpsc::Sender<Frame>` into one aggregation loop. That single
//! consumer capped ingest throughput regardless of core count. Here
//! patients are partitioned over N aggregation workers
//! (`patient % N`, N defaulting to a core-count heuristic); each shard
//! owns the [`WindowAggregator`]s of its patients — all filling pooled
//! lead buffers from the shard's own [`LeadPool`] slab, recycled when
//! the executor drops the emitted windows — and submits completed
//! windows straight into the serving pipeline via its sink. Producers
//! (HTTP connection threads, bedside generators) route frames through a
//! cheap clonable [`ShardSender`] onto **bounded** per-shard channels,
//! so a hot edge backpressures instead of ballooning memory.
//!
//! Sharding preserves the serving semantics bit for bit: a patient's
//! frames all land on one shard in arrival order, so window contents
//! and `window_id`s are identical for any shard count, and the
//! ensemble's deterministic model-index-order bagging makes the final
//! predictions independent of how windows were interleaved across
//! shards (see `tests/shards.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use super::aggregator::{WindowAggregator, WindowData};
use super::arena::LeadPool;
use super::telemetry::Telemetry;
use crate::ingest::Frame;
use crate::{Error, Result};

/// Default bound of each shard's frame queue: ~2 s of a busy shard's
/// traffic (8 shards × 64 beds × 251 frames/s ≈ 2k frames/s/shard).
/// A full queue blocks the producer — admission backpressure, not OOM.
pub const DEFAULT_SHARD_QUEUE: usize = 4096;

/// Default bound on distinct patients per shard. The aggregator map is
/// keyed by the **untrusted** wire patient id, and each aggregator
/// preallocates 3 × `window_samples` lead buffers (~30 KB at the
/// paper's clip length) — without a cap, one 4 MiB `/ingest.bin` body
/// of minimal frames with distinct ids could pin gigabytes. 1024
/// patients/shard is 10× the paper's 100-bed target even on a single
/// shard. A new id past the cap evicts the least-recently-updated
/// *idle* aggregator (one with no partially filled window) — admission
/// churn, counted in `Telemetry::patients_evicted` — so a discharged
/// bed's stale id can never starve a newly admitted patient forever;
/// only when every tracked patient is mid-window is the frame dropped.
pub const DEFAULT_SHARD_PATIENTS: usize = 1024;

/// Shard-plane construction parameters.
#[derive(Debug, Clone, Copy)]
pub struct ShardConfig {
    /// Number of aggregation workers; 0 = auto ([`default_shards`]).
    pub shards: usize,
    /// Capacity of each shard's bounded frame channel.
    pub queue_depth: usize,
    /// Max distinct patients tracked per shard; frames for further
    /// patient ids are dropped (and counted), bounding aggregator
    /// memory against hostile ids.
    pub max_patients: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            shards: 0,
            queue_depth: DEFAULT_SHARD_QUEUE,
            max_patients: DEFAULT_SHARD_PATIENTS,
        }
    }
}

/// Core-count heuristic for the shard count: half the available
/// parallelism (the other half belongs to batchers + engine workers),
/// clamped to [1, 8] — aggregation is cheap per frame, so more than 8
/// shards only adds channels.
pub fn default_shards() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2)
        .div_ceil(2)
        .clamp(1, 8)
}

/// Clonable routing handle held by every frame producer (HTTP
/// connection threads, bedside generators): `patient % shards` picks
/// the shard, and the send blocks on a full queue (bounded
/// backpressure). All clones dropping closes the shard channels and
/// lets the workers drain and exit.
#[derive(Clone)]
pub struct ShardSender {
    txs: Arc<[mpsc::SyncSender<Frame>]>,
}

impl ShardSender {
    /// Build from raw per-shard senders (tests and benches; production
    /// code gets one from [`ShardRouter::spawn`]).
    pub fn from_senders(txs: Vec<mpsc::SyncSender<Frame>>) -> Self {
        assert!(!txs.is_empty(), "at least one shard");
        ShardSender { txs: txs.into() }
    }

    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Route one frame to its patient's shard. Errors only when the
    /// shard plane has shut down.
    pub fn send(&self, frame: Frame) -> Result<()> {
        let shard = frame.patient % self.txs.len();
        self.txs[shard]
            .send(frame)
            .map_err(|_| Error::serving("aggregation shard closed"))
    }
}

/// Handle to the running shard workers. Dropping it does NOT stop the
/// workers (they run until every [`ShardSender`] clone is gone) — call
/// [`ShardRouter::join`] after dropping the senders to wait for the
/// drain and collect per-shard drop totals.
pub struct ShardRouter {
    workers: Vec<std::thread::JoinHandle<()>>,
    dropped: Arc<[AtomicU64]>,
}

impl ShardRouter {
    /// Spawn the shard plane. `make_sink(shard)` builds each worker's
    /// window sink, called once per shard at spawn time; the sink runs
    /// on the shard thread for every completed window.
    pub fn spawn<S, F>(
        cfg: ShardConfig,
        window_samples: usize,
        telemetry: Arc<Telemetry>,
        mut make_sink: F,
    ) -> Result<(ShardRouter, ShardSender)>
    where
        S: FnMut(WindowData) + Send + 'static,
        F: FnMut(usize) -> S,
    {
        let n = if cfg.shards == 0 { default_shards() } else { cfg.shards };
        let dropped: Arc<[AtomicU64]> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let mut txs = Vec::with_capacity(n);
        let mut workers = Vec::with_capacity(n);
        for shard in 0..n {
            let (tx, rx) = mpsc::sync_channel::<Frame>(cfg.queue_depth.max(1));
            txs.push(tx);
            let telemetry = Arc::clone(&telemetry);
            let dropped = Arc::clone(&dropped);
            let sink = make_sink(shard);
            let max_patients = cfg.max_patients.max(1);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("agg-shard-{shard}"))
                    .spawn(move || {
                        shard_loop(shard, rx, window_samples, max_patients, telemetry, dropped, sink)
                    })
                    .map_err(Error::Io)?,
            );
        }
        Ok((ShardRouter { workers, dropped }, ShardSender::from_senders(txs)))
    }

    pub fn shards(&self) -> usize {
        self.workers.len()
    }

    /// Live per-shard dropped/malformed frame totals (also summed into
    /// `Telemetry::frames_dropped` for the `/stats` snapshot).
    pub fn dropped_per_shard(&self) -> Vec<u64> {
        self.dropped.iter().map(|d| d.load(Ordering::Relaxed)).collect()
    }

    /// Wait for every worker to drain and exit (all [`ShardSender`]
    /// clones must be dropped first, or this blocks forever); returns
    /// the final per-shard dropped totals.
    pub fn join(self) -> Result<Vec<u64>> {
        for w in self.workers {
            w.join().map_err(|_| Error::serving("aggregation shard panicked"))?;
        }
        Ok(self.dropped.iter().map(|d| d.load(Ordering::Relaxed)).collect())
    }
}

/// One shard's loop: own the aggregators of `patient % shards == shard`
/// patients, push frames, hand completed windows to the sink.
fn shard_loop<S: FnMut(WindowData)>(
    shard: usize,
    rx: mpsc::Receiver<Frame>,
    window_samples: usize,
    max_patients: usize,
    telemetry: Arc<Telemetry>,
    dropped: Arc<[AtomicU64]>,
    mut sink: S,
) {
    // per-shard window arena: every aggregator on this shard fills
    // recycled lead buffers from one slab; the buffers come back when
    // the last executor lane drops the emitted lease, so steady state
    // does no per-window buffer allocation (and shards never contend
    // on each other's free lists)
    let pool = LeadPool::new(window_samples);
    let mut aggs: HashMap<usize, WindowAggregator> = HashMap::new();
    // recency ledger for the over-cap eviction policy: monotone
    // per-frame sequence, bumped for every frame a patient's aggregator
    // accepts. Separate from `aggs` so eviction scans stay allocation-
    // free.
    let mut last_touch: HashMap<usize, u64> = HashMap::new();
    let mut touch_seq: u64 = 0;
    for frame in rx {
        let t0 = Instant::now();
        telemetry.frames.fetch_add(1, Ordering::Relaxed);
        // bound aggregator state against hostile/garbage patient ids:
        // past `max_patients` distinct ids, a new id evicts the
        // least-recently-updated IDLE aggregator (no partial window in
        // flight — evicting mid-window would lose a real patient's
        // buffered samples). With every tracked patient mid-window the
        // frame is dropped and counted, as before.
        if !aggs.contains_key(&frame.patient) {
            if aggs.len() >= max_patients {
                let victim = aggs
                    .iter()
                    .filter(|(_, a)| a.fill() == 0)
                    .map(|(&p, _)| (last_touch.get(&p).copied().unwrap_or(0), p))
                    .min();
                match victim {
                    Some((_, victim)) => {
                        aggs.remove(&victim);
                        last_touch.remove(&victim);
                        telemetry.patients_evicted.fetch_add(1, Ordering::Relaxed);
                    }
                    None => {
                        dropped[shard].fetch_add(1, Ordering::Relaxed);
                        telemetry.frames_dropped.fetch_add(1, Ordering::Relaxed);
                        telemetry.frames_dropped_overcap.fetch_add(1, Ordering::Relaxed);
                        telemetry.ingest.record(t0.elapsed());
                        continue;
                    }
                }
            }
            aggs.insert(
                frame.patient,
                WindowAggregator::with_pool(frame.patient, window_samples, pool.clone()),
            );
        }
        touch_seq += 1;
        last_touch.insert(frame.patient, touch_seq);
        let agg = aggs.get_mut(&frame.patient).expect("inserted above");
        let dropped_before = agg.dropped();
        let stale_before = agg.stale();
        let window = agg.push(&frame);
        let malformed = agg.dropped() - dropped_before;
        if malformed > 0 {
            dropped[shard].fetch_add(malformed, Ordering::Relaxed);
            telemetry.frames_dropped.fetch_add(malformed, Ordering::Relaxed);
            telemetry.frames_dropped_malformed.fetch_add(malformed, Ordering::Relaxed);
        }
        // out-of-order ECG (skewed monitor clock) is its own drop cause
        // so replay invariants can match it against an injected budget
        let stale = agg.stale() - stale_before;
        if stale > 0 {
            dropped[shard].fetch_add(stale, Ordering::Relaxed);
            telemetry.frames_dropped.fetch_add(stale, Ordering::Relaxed);
            telemetry.frames_stale.fetch_add(stale, Ordering::Relaxed);
        }
        if let Some(w) = window {
            sink(w);
        }
        telemetry.ingest.record(t0.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ingest::Modality;
    use std::sync::Mutex;

    fn ecg(patient: usize, v: f32) -> Frame {
        Frame {
            patient,
            modality: Modality::Ecg,
            sim_time: 0.0,
            values: [v, v, v].into(),
        }
    }

    #[test]
    fn frames_route_by_patient_modulo_shards() {
        let tel = Arc::new(Telemetry::default());
        let windows = Arc::new(Mutex::new(Vec::new()));
        let (router, tx) = ShardRouter::spawn(
            ShardConfig { shards: 3, queue_depth: 16, ..ShardConfig::default() },
            2,
            Arc::clone(&tel),
            |shard| {
                let windows = Arc::clone(&windows);
                move |w: WindowData| windows.lock().unwrap().push((shard, w.patient, w.window_id))
            },
        )
        .unwrap();
        assert_eq!(tx.shards(), 3);
        assert_eq!(router.shards(), 3);
        // patients 0..6, two ECG frames each → one window per patient
        for v in 0..2 {
            for p in 0..6 {
                tx.send(ecg(p, v as f32)).unwrap();
            }
        }
        drop(tx);
        let dropped = router.join().unwrap();
        assert_eq!(dropped, vec![0, 0, 0]);
        let mut got = windows.lock().unwrap().clone();
        got.sort_unstable();
        // every patient produced window 0 on its home shard
        let mut want: Vec<(usize, usize, u64)> = (0..6).map(|p| (p % 3, p, 0)).collect();
        want.sort_unstable();
        assert_eq!(got, want);
        assert_eq!(tel.frames.load(Ordering::Relaxed), 12);
        assert_eq!(tel.frames_dropped.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn malformed_frames_count_per_shard_and_globally() {
        let tel = Arc::new(Telemetry::default());
        let (router, tx) = ShardRouter::spawn(
            ShardConfig { shards: 2, queue_depth: 16, ..ShardConfig::default() },
            4,
            Arc::clone(&tel),
            |_| |_w: WindowData| {},
        )
        .unwrap();
        // patient 1 → shard 1; a 1-value ECG frame is malformed
        let bad = Frame {
            patient: 1,
            modality: Modality::Ecg,
            sim_time: 0.0,
            values: [0.5].into(),
        };
        tx.send(bad).unwrap();
        tx.send(bad).unwrap();
        tx.send(ecg(0, 1.0)).unwrap(); // healthy frame on shard 0
        drop(tx);
        let dropped = router.join().unwrap();
        assert_eq!(dropped, vec![0, 2]);
        assert_eq!(tel.frames_dropped.load(Ordering::Relaxed), 2);
        assert_eq!(tel.frames_dropped_malformed.load(Ordering::Relaxed), 2);
        assert_eq!(tel.frames.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn stale_frames_count_per_shard_and_by_cause() {
        let tel = Arc::new(Telemetry::default());
        let (router, tx) = ShardRouter::spawn(
            ShardConfig { shards: 2, queue_depth: 16, ..ShardConfig::default() },
            4,
            Arc::clone(&tel),
            |_| |_w: WindowData| {},
        )
        .unwrap();
        let at = |t: f64| Frame {
            patient: 1, // shard 1
            modality: Modality::Ecg,
            sim_time: t,
            values: [1.0, 1.0, 1.0].into(),
        };
        tx.send(at(5.0)).unwrap();
        tx.send(at(3.0)).unwrap(); // behind the window position → stale
        tx.send(at(4.0)).unwrap(); // still behind → stale
        tx.send(at(5.0)).unwrap(); // equal is in-sync, accepted
        drop(tx);
        let dropped = router.join().unwrap();
        assert_eq!(dropped, vec![0, 2], "stale drops roll into the per-shard totals");
        assert_eq!(tel.frames_dropped.load(Ordering::Relaxed), 2);
        assert_eq!(tel.frames_stale.load(Ordering::Relaxed), 2);
        assert_eq!(tel.frames_dropped_malformed.load(Ordering::Relaxed), 0);
        assert_eq!(tel.frames_dropped_overcap.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn patient_cap_evicts_least_recently_updated_idle_aggregator() {
        let tel = Arc::new(Telemetry::default());
        let windows = Arc::new(Mutex::new(Vec::new()));
        let (router, tx) = ShardRouter::spawn(
            ShardConfig { shards: 1, queue_depth: 64, max_patients: 2 },
            1,
            Arc::clone(&tel),
            |_| {
                let windows = Arc::clone(&windows);
                move |w: WindowData| windows.lock().unwrap().push(w.patient)
            },
        )
        .unwrap();
        // patients 0 and 1 claim the two slots; window_samples = 1, so
        // every accepted ECG frame completes a window and leaves its
        // aggregator idle — each fresh id then evicts the LRU idle slot
        // instead of being starved forever
        for p in 0..2 {
            tx.send(ecg(p, 1.0)).unwrap();
        }
        for fresh in 100..140 {
            tx.send(ecg(fresh, 9.9)).unwrap();
        }
        // an evicted patient re-admits the same way (churn, not a ban)
        tx.send(ecg(0, 2.0)).unwrap();
        drop(tx);
        let dropped = router.join().unwrap();
        assert_eq!(dropped, vec![0], "idle eviction admits every new id — nothing dropped");
        assert_eq!(tel.frames_dropped.load(Ordering::Relaxed), 0);
        // 40 fresh ids + patient 0's re-admission each evicted one slot
        assert_eq!(tel.patients_evicted.load(Ordering::Relaxed), 41);
        let mut want: Vec<usize> = vec![0, 1];
        want.extend(100..140);
        want.push(0);
        assert_eq!(*windows.lock().unwrap(), want);
    }

    #[test]
    fn patient_cap_never_evicts_mid_window_aggregators() {
        let tel = Arc::new(Telemetry::default());
        let (router, tx) = ShardRouter::spawn(
            ShardConfig { shards: 1, queue_depth: 64, max_patients: 2 },
            4,
            Arc::clone(&tel),
            |_| |_w: WindowData| {},
        )
        .unwrap();
        // window_samples = 4: one frame each leaves patients 0 and 1
        // mid-window (fill = 1) — their buffered samples must survive a
        // hostile id flood, which is dropped as before
        for p in 0..2 {
            tx.send(ecg(p, 1.0)).unwrap();
        }
        for hostile in 100..140 {
            tx.send(ecg(hostile, 9.9)).unwrap();
        }
        drop(tx);
        let dropped = router.join().unwrap();
        assert_eq!(dropped, vec![40], "no idle victim → over-cap ids drop");
        assert_eq!(tel.frames_dropped.load(Ordering::Relaxed), 40);
        assert_eq!(tel.frames_dropped_overcap.load(Ordering::Relaxed), 40);
        assert_eq!(tel.patients_evicted.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn default_shard_count_is_sane() {
        let n = default_shards();
        assert!((1..=8).contains(&n));
    }
}
