//! Measured latency profiling (paper §3.4 "Latency profiling"):
//! exposes `f_l(V, c, b)` over the *real* pipeline.
//!
//! 1. **μ (throughput capacity)**: closed-loop inference on the deployed
//!    ensemble — `n_workers` closed loops on separate threads, each
//!    issuing the next query as soon as the previous returns, averaged
//!    over K queries.
//! 2. **T_s**: open-loop load at the configured ingest rate λ ≤ μ; the
//!    95th-percentile end-to-end latency.
//! 3. **T_q**: network-calculus bound from the arrival curve observed
//!    during the open-loop run and the rate-latency service curve
//!    (μ, T_s) — Fig. 5's construction.

use std::time::Instant;

use crate::config::SystemConfig;
use crate::data;
use crate::ingest::synth::SynthConfig;
use crate::netcalc::{queueing_bound, ArrivalCurve, ServiceCurve};
use crate::runtime::Engine;
use crate::serving::pipeline::{Pipeline, PipelineConfig, Query};
use crate::zoo::{Selector, Zoo};
use crate::{Error, Result};

/// Output of one measured profiling run.
#[derive(Debug, Clone, Copy)]
pub struct MeasuredLatency {
    /// Ensemble throughput capacity, queries/s.
    pub mu: f64,
    /// p95 end-to-end latency under open-loop load (seconds) — T_s.
    pub ts_p95: f64,
    /// Mean end-to-end latency under open-loop load.
    pub ts_mean: f64,
    /// Network-calculus queueing bound (seconds) — T_q.
    pub tq_bound: f64,
    /// The profiler's latency estimate T̂ = T_q + T_s.
    pub total: f64,
}

/// Profiling effort knobs.
#[derive(Debug, Clone, Copy)]
pub struct ProfileEffort {
    /// Closed-loop queries for μ.
    pub closed_loop_queries: usize,
    /// Open-loop queries for T_s / the arrival curve.
    pub open_loop_queries: usize,
}

impl Default for ProfileEffort {
    fn default() -> Self {
        ProfileEffort { closed_loop_queries: 24, open_loop_queries: 48 }
    }
}

/// Measure `f_l` for ensemble `b` under system configuration `c`.
pub fn profile_ensemble(
    zoo: &Zoo,
    engine: &Engine,
    b: &Selector,
    c: &SystemConfig,
    effort: ProfileEffort,
) -> Result<MeasuredLatency> {
    if b.is_empty() {
        return Err(Error::config("cannot profile an empty ensemble"));
    }
    let pipeline = Pipeline::spawn(zoo, engine, PipelineConfig::new(b.clone()))?;
    let clip_len = zoo.manifest.clip_len;
    // one representative clip in shared storage, reused (by reference)
    // for every probe query
    let clips = data::make_clips(1, clip_len, 1234, &SynthConfig::default());
    let leads = clips.shared().swap_remove(0);

    // warm compile every (model, batch) variant out of the measurement
    for &m in b.indices() {
        for &bs in engine.batch_sizes() {
            engine.profile_model((m, bs), 1)?;
        }
    }

    // ---- closed loop: throughput capacity μ
    let loops = engine.n_workers().max(1);
    let per_loop = (effort.closed_loop_queries / loops).max(1);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..loops {
            let pipeline = pipeline.clone();
            let leads = leads.clone();
            scope.spawn(move || {
                for w in 0..per_loop {
                    let q = Query {
                        patient: 0,
                        window_id: w as u64,
                        sim_end: 0.0,
                        leads: leads.clone(),
                        emitted: Instant::now(),
                    };
                    let _ = pipeline.query(q);
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let mu = (per_loop * loops) as f64 / elapsed.max(1e-9);

    // ---- open loop at λ = query_rate (capped at 0.9 μ, as the paper
    // requires λ ≤ μ) — collect e2e samples + arrival timestamps
    let lambda = c.query_rate().min(0.9 * mu).max(0.1);
    let gap = std::time::Duration::from_secs_f64(1.0 / lambda);
    let mut arrivals: Vec<f64> = Vec::with_capacity(effort.open_loop_queries);
    let start = Instant::now();
    let mut replies = Vec::new();
    for w in 0..effort.open_loop_queries {
        let q = Query {
            patient: w % c.patients.max(1),
            window_id: w as u64,
            sim_end: 0.0,
            leads: leads.clone(),
            emitted: Instant::now(),
        };
        arrivals.push(start.elapsed().as_secs_f64());
        replies.push(pipeline.submit(q)?);
        std::thread::sleep(gap);
    }
    let mut e2e: Vec<f64> = Vec::with_capacity(replies.len());
    for r in replies {
        if let Ok(p) = r.recv() {
            e2e.push(p.e2e.as_secs_f64());
        }
    }
    let ts_p95 = crate::metrics::percentile(&e2e, 95.0);
    let ts_mean = e2e.iter().sum::<f64>() / e2e.len().max(1) as f64;

    // ---- T_q via network calculus on the observed arrivals
    let arrival = ArrivalCurve::from_timestamps_exact(&arrivals);
    let service = ServiceCurve::new(mu.max(1e-6), ts_mean.max(1e-6));
    let tq_bound = queueing_bound(&arrival, &service);

    Ok(MeasuredLatency { mu, ts_p95, ts_mean, tq_bound, total: ts_p95 + tq_bound })
}
