//! Per-model dynamic batcher actor: coalesces queries from many patients
//! into one device batch (up to `max_batch`, or after `timeout`), pads
//! to the nearest compiled batch size, executes through the engine and
//! fans per-slot scores back to the collector.
//!
//! One OS thread per selected model — the rust analogue of the paper's
//! per-model Ray actor with its queue.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::runtime::Engine;
use crate::{Error, Result};

/// One unit of work for a model actor.
#[derive(Debug)]
pub struct BatchItem {
    pub query_id: u64,
    /// Raw (un-normalised) window for this model's lead; normalisation is
    /// baked into the HLO graph.
    pub input: Vec<f32>,
    /// When the parent query was emitted by its aggregator.
    pub enqueued: Instant,
}

/// Score report back to the collector.
#[derive(Debug, Clone)]
pub struct ModelScore {
    pub query_id: u64,
    pub model_index: usize,
    pub score: f32,
    /// Time the item waited before its batch started executing.
    pub queue_wait: Duration,
    /// Device execution time of the batch that carried the item.
    pub exec_time: Duration,
}

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub timeout: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // §Perf iteration 1: a 5 ms fill-wait dominated single-query
        // latency (measured 5.4 ms pipeline overhead on an 0.3 ms model).
        // Bursts arrive within µs of each other, so an immediate drain +
        // one short wait captures them; 1 ms caps the idle-path penalty.
        BatchPolicy { max_batch: 8, timeout: Duration::from_millis(1) }
    }
}

/// Run one model's batch loop until the input channel closes. `out` is
/// called once per scored item; it returns Err when the collector is
/// gone, which terminates the loop.
pub fn model_batch_loop(
    model_index: usize,
    engine: Engine,
    rx: mpsc::Receiver<BatchItem>,
    mut out: impl FnMut(ModelScore) -> Result<()>,
    policy: BatchPolicy,
) -> Result<()> {
    let clip_len = engine.clip_len();
    let max_take = policy.max_batch.min(largest_batch(&engine)).max(1);
    let mut pending: Vec<BatchItem> = Vec::with_capacity(max_take);
    loop {
        // fill phase: block for the first item, then wait up to `timeout`
        // for the batch to fill
        if pending.is_empty() {
            match rx.recv() {
                Ok(item) => pending.push(item),
                Err(_) => break, // channel closed, nothing buffered
            }
        }
        // fast path: drain whatever is already queued (bursts land in µs)
        let mut closed = false;
        while pending.len() < max_take {
            match rx.try_recv() {
                Ok(item) => pending.push(item),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    closed = true;
                    break;
                }
            }
        }
        // not full yet: ONE bounded wait for stragglers, then drain again
        if !closed && pending.len() < max_take && !policy.timeout.is_zero() {
            match rx.recv_timeout(policy.timeout) {
                Ok(item) => {
                    pending.push(item);
                    while pending.len() < max_take {
                        match rx.try_recv() {
                            Ok(item) => pending.push(item),
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                closed = true;
                                break;
                            }
                        }
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => closed = true,
            }
        }
        flush(model_index, &engine, clip_len, &mut pending, &mut out, max_take)?;
        if closed && pending.is_empty() {
            break;
        }
    }
    // final drain
    while !pending.is_empty() {
        flush(model_index, &engine, clip_len, &mut pending, &mut out, max_take)?;
    }
    Ok(())
}

fn flush(
    model_index: usize,
    engine: &Engine,
    clip_len: usize,
    pending: &mut Vec<BatchItem>,
    out: &mut impl FnMut(ModelScore) -> Result<()>,
    max_take: usize,
) -> Result<()> {
    if pending.is_empty() {
        return Ok(());
    }
    let take = pending.len().min(max_take);
    let items: Vec<BatchItem> = pending.drain(..take).collect();
    let batch = engine.batch_for(items.len());
    let mut input = vec![0.0f32; batch * clip_len];
    for (slot, item) in items.iter().enumerate() {
        if item.input.len() != clip_len {
            return Err(Error::config(format!(
                "batch item clip length {} != {}",
                item.input.len(),
                clip_len
            )));
        }
        input[slot * clip_len..(slot + 1) * clip_len].copy_from_slice(&item.input);
    }
    let started = Instant::now();
    let result = engine.execute_blocking((model_index, batch), input)?;
    for (slot, item) in items.into_iter().enumerate() {
        let report = ModelScore {
            query_id: item.query_id,
            model_index,
            score: result.scores[slot],
            queue_wait: started.duration_since(item.enqueued),
            exec_time: result.exec_time,
        };
        out(report)?;
    }
    Ok(())
}

fn largest_batch(engine: &Engine) -> usize {
    engine.batch_sizes().iter().copied().max().unwrap_or(1)
}
