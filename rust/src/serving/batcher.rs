//! Per-model dynamic batcher actor: coalesces queries from many patients
//! into one device batch (up to `max_batch`, or after `timeout`), packs
//! into a **persistent 64-byte-aligned** batch arena (reused across
//! flushes — the only copy on the whole data plane, chunked for SIMD;
//! see [`crate::runtime::AlignedBatch`]), executes through the engine
//! and fans per-slot scores back to the collector.
//!
//! One OS thread per selected model — the rust analogue of the paper's
//! per-model Ray actor with its queue. Items carry `Arc<[f32]>` windows
//! shared with every other member's batcher; nothing is cloned here.
//!
//! Failure semantics: when an execution fails, every item of the batch
//! is reported as [`ModelReport::Failed`] (the collector evicts the
//! queries so blocked `submit()` callers error out instead of hanging),
//! the still-queued backlog is drained and failed the same way, and the
//! loop exits with the original error.

use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::runtime::{AlignedBatch, Engine};
use crate::{Error, Result};

/// One unit of work for a model actor.
#[derive(Debug)]
pub struct BatchItem {
    pub query_id: u64,
    /// Raw (un-normalised) window for this model's lead, shared with the
    /// aggregator and the other members' batchers; normalisation is
    /// baked into the HLO graph.
    pub input: Arc<[f32]>,
    /// When the parent query was emitted by its aggregator.
    pub enqueued: Instant,
}

/// Score report back to the collector.
#[derive(Debug, Clone)]
pub struct ModelScore {
    pub query_id: u64,
    pub model_index: usize,
    pub score: f32,
    /// Time the item waited before its batch started executing.
    pub queue_wait: Duration,
    /// Device execution time of the batch that carried the item.
    pub exec_time: Duration,
}

/// One batcher → collector message.
#[derive(Debug, Clone)]
pub enum ModelReport {
    Score(ModelScore),
    /// The member could not score this query (engine error, bad input):
    /// the collector evicts the pending entry and fails the caller.
    Failed { query_id: u64, model_index: usize },
}

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub timeout: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // §Perf iteration 1: a 5 ms fill-wait dominated single-query
        // latency (measured 5.4 ms pipeline overhead on an 0.3 ms model).
        // Bursts arrive within µs of each other, so an immediate drain +
        // one short wait captures them; 1 ms caps the idle-path penalty.
        BatchPolicy { max_batch: 8, timeout: Duration::from_millis(1) }
    }
}

/// Why a flush could not complete.
enum FlushError {
    /// The collector hung up — pipeline shutdown, nothing to report.
    Sink,
    /// The engine (or input validation) failed; items were reported as
    /// Failed already.
    Exec(Error),
}

/// Run one model's batch loop until the input channel closes. `out` is
/// called once per item (score or failure); it returns Err when the
/// collector is gone, which terminates the loop.
pub fn model_batch_loop(
    model_index: usize,
    engine: Engine,
    rx: mpsc::Receiver<BatchItem>,
    mut out: impl FnMut(ModelReport) -> Result<()>,
    policy: BatchPolicy,
) -> Result<()> {
    let clip_len = engine.clip_len();
    let max_take = policy.max_batch.min(largest_batch(&engine)).max(1);
    let mut pending: Vec<BatchItem> = Vec::with_capacity(max_take);
    // persistent padded batch arena (64-byte-aligned): allocated once,
    // recycled through Engine::execute_batch on every flush
    let mut buf = AlignedBatch::new();
    loop {
        // fill phase: block for the first item, then wait up to `timeout`
        // for the batch to fill
        if pending.is_empty() {
            match rx.recv() {
                Ok(item) => pending.push(item),
                Err(_) => break, // channel closed, nothing buffered
            }
        }
        // fast path: drain whatever is already queued (bursts land in µs)
        let mut closed = false;
        while pending.len() < max_take {
            match rx.try_recv() {
                Ok(item) => pending.push(item),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    closed = true;
                    break;
                }
            }
        }
        // not full yet: ONE bounded wait for stragglers, then drain again
        if !closed && pending.len() < max_take && !policy.timeout.is_zero() {
            match rx.recv_timeout(policy.timeout) {
                Ok(item) => {
                    pending.push(item);
                    while pending.len() < max_take {
                        match rx.try_recv() {
                            Ok(item) => pending.push(item),
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                closed = true;
                                break;
                            }
                        }
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => closed = true,
            }
        }
        match flush(model_index, &engine, clip_len, &mut pending, &mut buf, &mut out, max_take) {
            Ok(()) => {}
            Err(FlushError::Sink) => return Err(Error::serving("collector gone")),
            Err(FlushError::Exec(e)) => {
                drain_and_fail(model_index, &mut pending, &rx, &mut out);
                return Err(e);
            }
        }
        if closed && pending.is_empty() {
            break;
        }
    }
    // final drain
    while !pending.is_empty() {
        match flush(model_index, &engine, clip_len, &mut pending, &mut buf, &mut out, max_take) {
            Ok(()) => {}
            Err(FlushError::Sink) => return Err(Error::serving("collector gone")),
            Err(FlushError::Exec(e)) => {
                drain_and_fail(model_index, &mut pending, &rx, &mut out);
                return Err(e);
            }
        }
    }
    Ok(())
}

fn flush(
    model_index: usize,
    engine: &Engine,
    clip_len: usize,
    pending: &mut Vec<BatchItem>,
    buf: &mut AlignedBatch,
    out: &mut impl FnMut(ModelReport) -> Result<()>,
    max_take: usize,
) -> std::result::Result<(), FlushError> {
    // weed out malformed items per item (cannot happen via Pipeline,
    // which validates lead lengths at the router; defensive for direct
    // users of model_batch_loop) — a bad query must not kill the member
    // or fail its co-batched neighbours
    let mut i = 0;
    while i < pending.len() {
        if pending[i].input.len() != clip_len {
            let item = pending.remove(i);
            let _ = out(ModelReport::Failed { query_id: item.query_id, model_index });
        } else {
            i += 1;
        }
    }
    if pending.is_empty() {
        return Ok(());
    }
    let take = pending.len().min(max_take);
    let batch = engine.batch_for(take);
    buf.reset(batch * clip_len);
    for (slot, item) in pending[..take].iter().enumerate() {
        buf.pack_slot(slot, clip_len, &item.input);
    }
    let started = Instant::now();
    match engine.execute_batch((model_index, batch), buf) {
        Ok(result) => {
            // a backend returning fewer scores than batch slots must
            // fail the batch, not panic the member thread: a dead
            // batcher with unreported dequeued items would leak live
            // pending-table entries (and stall their callers) forever
            if result.scores.len() < take {
                let e = Error::serving(format!(
                    "model {model_index}: backend returned {} scores for a batch of {take}",
                    result.scores.len()
                ));
                fail_batch(model_index, pending, take, out);
                return Err(FlushError::Exec(e));
            }
            for (slot, item) in pending.drain(..take).enumerate() {
                let report = ModelScore {
                    query_id: item.query_id,
                    model_index,
                    score: result.scores[slot],
                    queue_wait: started.duration_since(item.enqueued),
                    exec_time: result.exec_time,
                };
                out(ModelReport::Score(report)).map_err(|_| FlushError::Sink)?;
            }
            Ok(())
        }
        Err(e) => {
            fail_batch(model_index, pending, take, out);
            Err(FlushError::Exec(e))
        }
    }
}

/// Report the first `take` buffered items as failed (collector may
/// already be gone — ignore send errors, we are on the way out).
fn fail_batch(
    model_index: usize,
    pending: &mut Vec<BatchItem>,
    take: usize,
    out: &mut impl FnMut(ModelReport) -> Result<()>,
) {
    for item in pending.drain(..take) {
        let _ = out(ModelReport::Failed { query_id: item.query_id, model_index });
    }
}

/// Terminal eviction after an execution error: fail everything still
/// buffered plus everything that keeps arriving until the router hangs
/// up, so no registered query is left dangling in the pending table.
fn drain_and_fail(
    model_index: usize,
    pending: &mut Vec<BatchItem>,
    rx: &mpsc::Receiver<BatchItem>,
    out: &mut impl FnMut(ModelReport) -> Result<()>,
) {
    for item in pending.drain(..) {
        let _ = out(ModelReport::Failed { query_id: item.query_id, model_index });
    }
    for item in rx.iter() {
        let _ = out(ModelReport::Failed { query_id: item.query_id, model_index });
    }
}

fn largest_batch(engine: &Engine) -> usize {
    engine.batch_sizes().iter().copied().max().unwrap_or(1)
}
