//! Per-model dynamic batcher actor: coalesces queries from many patients
//! into one device batch (up to `max_batch`, or after `timeout`), packs
//! into a **persistent 64-byte-aligned** batch arena (reused across
//! flushes — the only copy on the whole data plane, chunked for SIMD;
//! see [`crate::runtime::AlignedBatch`]), executes through the engine
//! and completes each slot **directly** through the lock-free pending
//! arena via its [`Completer`] — there is no collector thread and no
//! report channel; the batcher thread that records the last member's
//! score finishes the query inline.
//!
//! One OS thread per selected model — the rust analogue of the paper's
//! per-model Ray actor with its queue. Items carry `Arc<[f32]>` windows
//! shared with every other member's batcher; nothing is cloned here.
//!
//! Failure semantics: when an execution fails, every item of the batch
//! is failed through [`Completer::fail`] (evicting the query from the
//! pending arena so blocked `submit()` callers error out instead of
//! hanging), the still-queued backlog is drained and failed the same
//! way, and the loop exits with the original error. Determinism is
//! unaffected by who completes a slot: member scores live in per-model
//! cells and are summed in model-index order, so the ensemble score is
//! bit-for-bit identical whether the last report lands on this batcher
//! thread or any other.

use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use super::pipeline::Completer;
use crate::runtime::{AlignedBatch, Engine};
use crate::{Error, Result};

/// One unit of work for a model actor.
#[derive(Debug)]
pub struct BatchItem {
    pub query_id: u64,
    /// Raw (un-normalised) window for this model's lead, shared with the
    /// aggregator and the other members' batchers; normalisation is
    /// baked into the HLO graph.
    pub input: Arc<[f32]>,
    /// When the parent query was emitted by its aggregator.
    pub enqueued: Instant,
}

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub timeout: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // §Perf iteration 1: a 5 ms fill-wait dominated single-query
        // latency (measured 5.4 ms pipeline overhead on an 0.3 ms model).
        // Bursts arrive within µs of each other, so an immediate drain +
        // one short wait captures them; 1 ms caps the idle-path penalty.
        BatchPolicy { max_batch: 8, timeout: Duration::from_millis(1) }
    }
}

/// Run one model's batch loop until the input channel closes. `done` is
/// this member's direct-completion handle into the pending arena (and
/// pipeline telemetry); every dequeued item is resolved through it
/// exactly once — scored, or failed (which evicts the query).
pub fn model_batch_loop(
    model_index: usize,
    engine: Engine,
    rx: mpsc::Receiver<BatchItem>,
    done: Completer,
    policy: BatchPolicy,
) -> Result<()> {
    let clip_len = engine.clip_len();
    let max_take = policy.max_batch.min(largest_batch(&engine)).max(1);
    let mut pending: Vec<BatchItem> = Vec::with_capacity(max_take);
    // persistent padded batch arena (64-byte-aligned): allocated once,
    // recycled through Engine::execute_batch on every flush
    let mut buf = AlignedBatch::new();
    loop {
        // fill phase: block for the first item, then wait up to `timeout`
        // for the batch to fill
        if pending.is_empty() {
            match rx.recv() {
                Ok(item) => pending.push(item),
                Err(_) => break, // channel closed, nothing buffered
            }
        }
        // fast path: drain whatever is already queued (bursts land in µs)
        let mut closed = false;
        while pending.len() < max_take {
            match rx.try_recv() {
                Ok(item) => pending.push(item),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    closed = true;
                    break;
                }
            }
        }
        // not full yet: ONE bounded wait for stragglers, then drain again
        if !closed && pending.len() < max_take && !policy.timeout.is_zero() {
            match rx.recv_timeout(policy.timeout) {
                Ok(item) => {
                    pending.push(item);
                    while pending.len() < max_take {
                        match rx.try_recv() {
                            Ok(item) => pending.push(item),
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                closed = true;
                                break;
                            }
                        }
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => closed = true,
            }
        }
        if let Err(e) = flush(model_index, &engine, clip_len, &mut pending, &mut buf, &done, max_take)
        {
            drain_and_fail(&mut pending, &rx, &done);
            return Err(e);
        }
        if closed && pending.is_empty() {
            break;
        }
    }
    // final drain
    while !pending.is_empty() {
        if let Err(e) = flush(model_index, &engine, clip_len, &mut pending, &mut buf, &done, max_take)
        {
            drain_and_fail(&mut pending, &rx, &done);
            return Err(e);
        }
    }
    Ok(())
}

fn flush(
    model_index: usize,
    engine: &Engine,
    clip_len: usize,
    pending: &mut Vec<BatchItem>,
    buf: &mut AlignedBatch,
    done: &Completer,
    max_take: usize,
) -> Result<()> {
    // weed out malformed items per item (cannot happen via Pipeline,
    // which validates lead lengths at the router; defensive for direct
    // users of model_batch_loop) — a bad query must not kill the member
    // or fail its co-batched neighbours
    let mut i = 0;
    while i < pending.len() {
        if pending[i].input.len() != clip_len {
            let item = pending.remove(i);
            done.fail(item.query_id);
        } else {
            i += 1;
        }
    }
    if pending.is_empty() {
        return Ok(());
    }
    let take = pending.len().min(max_take);
    let batch = engine.batch_for(take);
    buf.reset(batch * clip_len);
    for (slot, item) in pending[..take].iter().enumerate() {
        buf.pack_slot(slot, clip_len, &item.input);
    }
    let started = Instant::now();
    match engine.execute_batch((model_index, batch), buf) {
        Ok(result) => {
            // a backend returning fewer scores than batch slots must
            // fail the batch, not panic the member thread: a dead
            // batcher with unresolved dequeued items would leak live
            // pending-table entries (and stall their callers) forever
            if result.scores.len() < take {
                let e = Error::serving(format!(
                    "model {model_index}: backend returned {} scores for a batch of {take}",
                    result.scores.len()
                ));
                fail_batch(pending, take, done);
                return Err(e);
            }
            for (slot, item) in pending.drain(..take).enumerate() {
                // direct completion: write this member's score cell; if
                // that was the last outstanding member, finish() runs
                // right here on this batcher thread
                done.score(
                    item.query_id,
                    result.scores[slot],
                    started.duration_since(item.enqueued),
                    result.exec_time,
                );
            }
            Ok(())
        }
        Err(e) => {
            fail_batch(pending, take, done);
            Err(e)
        }
    }
}

/// Fail (evict) the first `take` buffered items.
fn fail_batch(pending: &mut Vec<BatchItem>, take: usize, done: &Completer) {
    for item in pending.drain(..take) {
        done.fail(item.query_id);
    }
}

/// Terminal eviction after an execution error: fail everything still
/// buffered plus everything that keeps arriving until the router hangs
/// up, so no registered query is left dangling in the pending table.
fn drain_and_fail(pending: &mut Vec<BatchItem>, rx: &mpsc::Receiver<BatchItem>, done: &Completer) {
    for item in pending.drain(..) {
        done.fail(item.query_id);
    }
    for item in rx.iter() {
        done.fail(item.query_id);
    }
}

fn largest_batch(engine: &Engine) -> usize {
    engine.batch_sizes().iter().copied().max().unwrap_or(1)
}
