//! Per-model dynamic batching: the policy knobs and the flush core the
//! work-stealing [`executor`](super::executor) runs on whichever pool
//! worker claims a model.
//!
//! Historically this module was an actor: one OS thread per selected
//! model looping recv → fill → flush (the rust analogue of the paper's
//! per-model Ray actor). That made the data plane's thread count
//! proportional to the *ensemble size* — oversubscribed with many
//! models on few cores, idle with few models on many. The loop is gone;
//! what remains is the part that was never per-thread state:
//!
//! * [`BatchItem`] — one unit of work (a shared [`WindowLease`] window,
//!   nothing cloned on the fan-out path);
//! * [`BatchPolicy`] — the fill/timeout knobs, enforced per model by
//!   the executor's lane deadlines exactly as the actor loop enforced
//!   them with its bounded `recv_timeout`;
//! * [`flush_batch`] — pack up to `max_take` staged items into the
//!   worker's persistent 64-byte-aligned arena, execute **inline** on
//!   the worker's [`DirectWorker`] handle, and resolve every dequeued
//!   item exactly once through the model's [`Completer`] (score, or
//!   fail → evict).
//!
//! Malformed items (wrong window length — impossible via `Pipeline`,
//! which validates at the router; defensive for direct users) are
//! weeded out with a single-pass, order-preserving `retain` that fails
//! each bad item exactly once — the old loop did this with
//! `Vec::remove` inside a scan, O(n²) on a pathological batch.
//!
//! Failure semantics: a *transient* backend error (an `Err` from
//! `execute`, not a panic) gets exactly one in-place retry after a
//! short jittered backoff — ICU monitors hiccup, and killing a lane
//! (evicting every co-batched query with it) over one blip is worse
//! than a 1–2 ms stall. The retry is counted per lane (surfaced in
//! `/stats` as `retries_per_model`). If the retry also fails, every
//! item of the batch is failed through [`Completer::fail`] (evicting
//! the query so blocked `submit()` callers error out instead of
//! hanging) and the error propagates to the executor, which marks the
//! model's lane dead and fails its backlog; the governor takes it from
//! there (quarantine → canary → reinstate). Panics never retry — they
//! unwind past this function to the executor's flush-boundary catch
//! and fail fast. Determinism is unaffected by who flushes a batch: member
//! scores live in per-model cells and are summed in model-index order,
//! so the ensemble score is bit-for-bit identical whichever worker ran
//! the model.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use super::arena::WindowLease;
use super::pipeline::Completer;
use crate::runtime::{AlignedBatch, DirectWorker, Engine};
use crate::{Error, Result};

/// One unit of work for a model lane.
#[derive(Debug)]
pub struct BatchItem {
    pub query_id: u64,
    /// Raw (un-normalised) window for this model's lead, shared with
    /// the aggregator and the other members' lanes; normalisation is
    /// baked into the HLO graph.
    pub input: WindowLease,
    /// When the parent query was emitted by its aggregator.
    pub enqueued: Instant,
}

/// Batching policy knobs.
///
/// With `adaptive` off (the default) only `max_batch` and `timeout`
/// matter — the original static policy, unchanged. With `adaptive` on,
/// `timeout` is replaced per arm by the
/// [`DeadlineController`](super::control::DeadlineController)'s dynamic
/// fill wait, bounded to `[timeout_min, timeout_max]` (burst/overload →
/// toward `timeout_min`, trickle → toward `timeout_max`).
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    /// Static fill deadline; also the behavior `adaptive = false`
    /// degrades to.
    pub timeout: Duration,
    /// Floor of the adaptive fill wait (0 = flush immediately under
    /// overload). Ignored when `adaptive` is off.
    pub timeout_min: Duration,
    /// Cap of the adaptive fill wait — what trickle load relaxes to.
    /// Ignored when `adaptive` is off.
    pub timeout_max: Duration,
    /// Consult the deadline controller instead of the static `timeout`.
    pub adaptive: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // §Perf iteration 1: a 5 ms fill-wait dominated single-query
        // latency (measured 5.4 ms pipeline overhead on an 0.3 ms model).
        // Bursts arrive within µs of each other, so an immediate drain +
        // one short wait captures them; 1 ms caps the idle-path penalty.
        // The adaptive bounds only engage with `--adaptive-batch`: the
        // controller may then wait up to 5 ms under trickle load (five
        // launch amortization windows) and not at all under pressure.
        BatchPolicy {
            max_batch: 8,
            timeout: Duration::from_millis(1),
            timeout_min: Duration::ZERO,
            timeout_max: Duration::from_millis(5),
            adaptive: false,
        }
    }
}

impl BatchPolicy {
    /// Builder: switch the policy to SLO-aware adaptive deadlines.
    pub fn adaptive(mut self) -> Self {
        self.adaptive = true;
        self
    }

    /// True when lanes never hold a fill window at all (static policy
    /// with a zero timeout) — the executor's flush-immediately fast
    /// path. An adaptive policy always goes through the controller,
    /// whose wait may be zero at times but is recomputed per arm.
    pub fn never_waits(&self) -> bool {
        !self.adaptive && self.timeout.is_zero()
    }
}

/// Largest compiled batch size — the hard ceiling on `max_batch`.
pub(crate) fn largest_batch(engine: &Engine) -> usize {
    engine.batch_sizes().iter().copied().max().unwrap_or(1)
}

/// What one [`flush_batch`] call did.
pub(crate) struct FlushOutcome {
    /// Items taken off `staged` (scored or failed) — keeps the lane's
    /// live depth gauge honest even on the error path.
    pub resolved: usize,
    /// Whether a device batch actually executed (per-worker gauge).
    pub executed: bool,
    /// Backend-reported execution nanos amortized per scored item (0
    /// when nothing executed) — feeds the lane's live service-time EWMA
    /// the governor recomposes against.
    pub exec_ns_per_item: u64,
    pub result: Result<()>,
}

impl FlushOutcome {
    fn new(resolved: usize, executed: bool, exec_ns_per_item: u64, result: Result<()>) -> Self {
        FlushOutcome { resolved, executed, exec_ns_per_item, result }
    }

    /// Outcome stand-in for a flush that panicked out from under the
    /// executor's catch boundary.
    pub fn panicked(resolved: usize, e: crate::Error) -> Self {
        FlushOutcome::new(resolved, false, 0, Err(e))
    }
}

/// Backoff before the single transient-error retry: 0.5–2 ms, jittered
/// off the clock's sub-microsecond bits so co-failing lanes don't
/// re-hit the device in lockstep. No RNG dependency.
fn retry_backoff() -> Duration {
    let noise = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| u64::from(d.subsec_nanos()))
        .unwrap_or(0);
    Duration::from_micros(500 + noise % 1500)
}

/// Flush one batch from the front of `staged`: weed malformed items
/// (single pass, each failed exactly once), pack up to `max_take` into
/// the worker's arena, execute inline (one bounded retry on a transient
/// error when `retries` is provided, counted there), complete each
/// flushed slot.
pub(crate) fn flush_batch(
    model_index: usize,
    dev: &mut DirectWorker,
    clip_len: usize,
    staged: &mut VecDeque<BatchItem>,
    buf: &mut AlignedBatch,
    done: &Completer,
    max_take: usize,
    retries: Option<&AtomicU64>,
) -> FlushOutcome {
    let mut resolved = 0usize;
    // single-pass, order-preserving weed-out: a bad query must not kill
    // the member or fail its co-batched neighbours
    staged.retain(|item| {
        if item.input.len() != clip_len {
            done.fail(item.query_id);
            resolved += 1;
            false
        } else {
            true
        }
    });
    if staged.is_empty() {
        return FlushOutcome::new(resolved, false, 0, Ok(()));
    }
    let take = staged.len().min(max_take);
    let engine = dev.engine();
    let batch = engine.batch_for(take);
    buf.reset(batch * clip_len);
    for (slot, item) in staged.iter().take(take).enumerate() {
        buf.pack_slot(slot, clip_len, &item.input);
    }
    let started = Instant::now();
    let executed = dev.execute((model_index, batch), buf).or_else(|first| {
        // one bounded retry for transient errors only — a panic would
        // have unwound right past this closure (fail-fast preserved)
        match retries {
            Some(counter) => {
                counter.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(retry_backoff());
                dev.execute((model_index, batch), buf)
            }
            None => Err(first),
        }
    });
    match executed {
        Ok(result) => {
            // a backend returning fewer scores than batch slots must
            // fail the batch, not panic the worker: unresolved dequeued
            // items would leak live pending-table entries (and stall
            // their callers) forever
            if result.scores.len() < take {
                let e = Error::serving(format!(
                    "model {model_index}: backend returned {} scores for a batch of {take}",
                    result.scores.len()
                ));
                resolved += fail_front(staged, take, done);
                return FlushOutcome::new(resolved, false, 0, Err(e));
            }
            let exec_ns =
                u64::try_from(result.exec_time.as_nanos()).unwrap_or(u64::MAX) / take as u64;
            for (slot, item) in staged.drain(..take).enumerate() {
                // direct completion: write this member's score cell; if
                // that was the last outstanding member, finish() runs
                // right here on this worker thread
                done.score(
                    item.query_id,
                    result.scores[slot],
                    started.duration_since(item.enqueued),
                    result.exec_time,
                );
                resolved += 1;
            }
            FlushOutcome::new(resolved, true, exec_ns, Ok(()))
        }
        Err(e) => {
            resolved += fail_front(staged, take, done);
            FlushOutcome::new(resolved, false, 0, Err(e))
        }
    }
}

/// Fail (evict) the first `take` staged items; returns how many.
pub(crate) fn fail_front(
    staged: &mut VecDeque<BatchItem>,
    take: usize,
    done: &Completer,
) -> usize {
    let take = take.min(staged.len());
    for item in staged.drain(..take) {
        done.fail(item.query_id);
    }
    take
}
