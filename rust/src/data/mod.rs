//! Rust-side cohort utilities: ECG clip datasets generated from the same
//! simulator the serving pipeline streams from (so profiled accuracy and
//! served accuracy agree), staleness datasets (Fig. 2), and tabular
//! vitals/labs datasets for the CPU side models.

use crate::ingest::synth::{severity_for_label, PatientSim, PatientState, SynthConfig};
use crate::rng::Rng;

/// A labelled set of 3-lead ECG clips.
#[derive(Debug, Clone)]
pub struct ClipSet {
    /// clips[i][lead] is a `clip_len`-long waveform.
    pub clips: Vec<[Vec<f32>; 3]>,
    pub labels: Vec<u8>,
    pub severities: Vec<f64>,
}

impl ClipSet {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Copy the clips into shared storage once; load generators then
    /// build [`crate::serving::Query`]s by cloning lease handles
    /// instead of waveforms.
    pub fn shared(&self) -> Vec<[crate::serving::WindowLease; 3]> {
        use crate::serving::WindowLease;
        self.clips
            .iter()
            .map(|c| {
                [
                    WindowLease::from_vec(c[0].clone()),
                    WindowLease::from_vec(c[1].clone()),
                    WindowLease::from_vec(c[2].clone()),
                ]
            })
            .collect()
    }
}

/// Generate `n` labelled clips of `clip_len` samples (one synthetic
/// patient per clip, like the python build-time cohort).
pub fn make_clips(n: usize, clip_len: usize, seed: u64, cfg: &SynthConfig) -> ClipSet {
    let mut rng = Rng::seed_from_u64(seed);
    let mut clips = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    let mut severities = Vec::with_capacity(n);
    for i in 0..n {
        let label = if rng.f64() < 0.45 { 1 } else { 0 };
        let severity = severity_for_label(&mut rng, label);
        let clip = clip_for_state(i, seed, cfg, PatientState { label, severity }, clip_len);
        clips.push(clip);
        labels.push(label);
        severities.push(severity);
    }
    ClipSet { clips, labels, severities }
}

/// One clip from a fresh simulator in the given state.
pub fn clip_for_state(
    id: usize,
    seed: u64,
    cfg: &SynthConfig,
    state: PatientState,
    clip_len: usize,
) -> [Vec<f32>; 3] {
    let mut sim = PatientSim::with_state(id, seed.wrapping_add(id as u64 * 7919), cfg.clone(), state);
    let mut leads: [Vec<f32>; 3] =
        [Vec::with_capacity(clip_len), Vec::with_capacity(clip_len), Vec::with_capacity(clip_len)];
    for _ in 0..clip_len {
        let s = sim.next_ecg();
        for (lead, l) in leads.iter_mut().enumerate() {
            l.push(s[lead]);
        }
    }
    leads
}

/// Fig. 2 substrate: clips observed `delay_h` hours before the label
/// time. Severity drifts toward the label's end-state with a 12-hour
/// time constant, so stale observations are less separable.
pub fn staleness_clips(
    n: usize,
    clip_len: usize,
    delay_h: f64,
    seed: u64,
    cfg: &SynthConfig,
) -> ClipSet {
    let mut rng = Rng::seed_from_u64(seed);
    let w = (-delay_h / 12.0_f64).exp();
    let mut clips = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    let mut severities = Vec::with_capacity(n);
    for i in 0..n {
        let label = if rng.f64() < 0.5 { 1 } else { 0 };
        let end_sev = severity_for_label(&mut rng, label);
        let init: f64 = rng.range_f64(0.3, 0.7); // undecided start state
        let sev = (w * end_sev + (1.0 - w) * init).clamp(0.0, 1.0);
        clips.push(clip_for_state(
            i,
            seed ^ (delay_h * 10.0) as u64,
            cfg,
            PatientState { label, severity: sev },
            clip_len,
        ));
        labels.push(label);
        severities.push(sev);
    }
    ClipSet { clips, labels, severities }
}

/// Tabular dataset for the CPU side models: (vitals-features, labs-features, labels).
pub struct TabularSet {
    pub vitals: Vec<Vec<f64>>,
    pub labs: Vec<Vec<f64>>,
    pub labels: Vec<u8>,
}

pub fn make_tabular(n: usize, seed: u64, cfg: &SynthConfig) -> TabularSet {
    let mut rng = Rng::seed_from_u64(seed);
    let mut vitals = Vec::with_capacity(n);
    let mut labs = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let label = if rng.f64() < 0.45 { 1 } else { 0 };
        let severity = severity_for_label(&mut rng, label);
        let mut sim = PatientSim::with_state(
            i,
            seed.wrapping_add(i as u64),
            cfg.clone(),
            PatientState { label, severity },
        );
        vitals.push(sim.next_vitals().iter().map(|&v| v as f64).collect());
        labs.push(sim.next_labs().iter().map(|&v| v as f64).collect());
        labels.push(label);
    }
    TabularSet { vitals, labs, labels }
}

/// Per-clip standardisation identical to the normalisation baked into
/// the HLO graphs (only needed when feeding the pure-rust side models).
pub fn standardize(clip: &[f32]) -> Vec<f32> {
    let n = clip.len() as f32;
    let mu: f32 = clip.iter().sum::<f32>() / n;
    let var: f32 = clip.iter().map(|x| (x - mu) * (x - mu)).sum::<f32>() / n;
    let sd = var.sqrt() + 1e-6;
    clip.iter().map(|x| (x - mu) / sd).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clipset_shapes() {
        let cs = make_clips(10, 200, 1, &SynthConfig::default());
        assert_eq!(cs.len(), 10);
        assert_eq!(cs.clips[0][0].len(), 200);
        assert!(cs.labels.iter().all(|&l| l <= 1));
    }

    #[test]
    fn clips_deterministic() {
        let a = make_clips(4, 100, 9, &SynthConfig::default());
        let b = make_clips(4, 100, 9, &SynthConfig::default());
        assert_eq!(a.clips[2][1], b.clips[2][1]);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn staleness_reduces_severity_separation() {
        let cfg = SynthConfig::default();
        let fresh = staleness_clips(200, 50, 0.0, 3, &cfg);
        let stale = staleness_clips(200, 50, 36.0, 3, &cfg);
        let gap = |cs: &ClipSet| {
            let (mut s0, mut n0, mut s1, mut n1) = (0.0, 0, 0.0, 0);
            for (sev, &l) in cs.severities.iter().zip(&cs.labels) {
                if l == 0 {
                    s0 += sev;
                    n0 += 1;
                } else {
                    s1 += sev;
                    n1 += 1;
                }
            }
            s0 / n0.max(1) as f64 - s1 / n1.max(1) as f64
        };
        assert!(gap(&fresh) > gap(&stale) + 0.1);
    }

    #[test]
    fn standardize_zero_mean_unit_std() {
        let clip: Vec<f32> = (0..100).map(|i| 3.0 + 0.5 * i as f32).collect();
        let z = standardize(&clip);
        let mu: f32 = z.iter().sum::<f32>() / 100.0;
        let sd: f32 = (z.iter().map(|x| x * x).sum::<f32>() / 100.0 - mu * mu).sqrt();
        assert!(mu.abs() < 1e-4 && (sd - 1.0).abs() < 1e-3);
    }

    #[test]
    fn tabular_set_severity_signal() {
        let t = make_tabular(300, 5, &SynthConfig::default());
        // mean lactate (labs[1]) must be higher in critical class
        let (mut c, mut nc, mut s, mut ns) = (0.0, 0, 0.0, 0);
        for (row, &l) in t.labs.iter().zip(&t.labels) {
            if l == 0 {
                c += row[1];
                nc += 1;
            } else {
                s += row[1];
                ns += 1;
            }
        }
        assert!(c / nc as f64 > s / ns as f64 + 0.5);
    }
}
