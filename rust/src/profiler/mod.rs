//! Accuracy and latency profilers — the two black boxes of Eq. (1):
//! `f_a(V, b)` and `f_l(V, c, b)`.
//!
//! * [`ValidationAccuracyProfiler`] computes the bagging-ensemble (Eq. 5)
//!   metrics over the per-model validation score vectors the python
//!   build exported — no python at search time.
//! * [`AnalyticLatencyProfiler`] is the fast in-search profiler: per-model
//!   service times (measured through the PJRT engine when available,
//!   otherwise a MACs-based cost model), LPT-makespan over the `g` device
//!   workers for `T_s`, and the network-calculus bound (Fig. 5) for `T_q`.
//!   `f_l = T_s + T_q`, mirroring the paper's `T̂ = T_q + T_s` breakdown.
//! * The fully *measured* end-to-end profiler drives the real serving
//!   pipeline and lives in [`crate::serving::profile`]; the analytic one
//!   is calibrated against it (integration test asserts agreement).

use std::collections::HashMap;

use crate::config::SystemConfig;
use crate::metrics;
use crate::netcalc;
use crate::runtime::Engine;
use crate::zoo::{Selector, Zoo};
use crate::Result;

/// The four Table-2 metrics of one ensemble on the validation split.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnsembleAccuracy {
    pub roc_auc: f64,
    pub pr_auc: f64,
    pub f1: f64,
    pub accuracy: f64,
}

/// `f_a(V, b)`: bagging-mean of the selected models' validation scores.
pub trait AccuracyProfiler {
    fn accuracy(&self, b: &Selector) -> EnsembleAccuracy;
}

/// `f_l(V, c, b)`: end-to-end serving latency of the ensemble (seconds).
pub trait LatencyProfiler {
    fn latency(&self, b: &Selector, c: &SystemConfig) -> f64;
}

// ---------------------------------------------------------------------------
// Accuracy
// ---------------------------------------------------------------------------

/// Score-matrix-backed accuracy profiler (labels + n×samples scores).
#[derive(Debug, Clone)]
pub struct ValidationAccuracyProfiler {
    labels: Vec<u8>,
    scores: Vec<Vec<f64>>, // [model][sample]
    /// Optional constant side-model score vector joined into every
    /// ensemble (the vitals/labs CPU models of §4.1.1).
    side_scores: Option<Vec<f64>>,
}

impl ValidationAccuracyProfiler {
    pub fn from_zoo(zoo: &Zoo) -> Self {
        ValidationAccuracyProfiler {
            labels: zoo.val.labels.clone(),
            scores: zoo.val.scores.clone(),
            side_scores: None,
        }
    }

    pub fn with_side_scores(mut self, side: Vec<f64>) -> Self {
        assert_eq!(side.len(), self.labels.len());
        self.side_scores = Some(side);
        self
    }

    /// Bagging scores of ensemble `b` (Eq. 5): sample-wise mean of the
    /// selected models (plus side models when configured).
    pub fn ensemble_scores(&self, b: &Selector) -> Vec<f64> {
        let n_samples = self.labels.len();
        let mut acc = vec![0.0f64; n_samples];
        let mut count = 0.0;
        for &i in b.indices() {
            for (a, s) in acc.iter_mut().zip(&self.scores[i]) {
                *a += s;
            }
            count += 1.0;
        }
        if let Some(side) = &self.side_scores {
            for (a, s) in acc.iter_mut().zip(side) {
                *a += s;
            }
            count += 1.0;
        }
        if count == 0.0 {
            return vec![0.5; n_samples]; // empty ensemble: chance scores
        }
        acc.iter().map(|a| a / count).collect()
    }

    pub fn labels(&self) -> &[u8] {
        &self.labels
    }
}

impl AccuracyProfiler for ValidationAccuracyProfiler {
    fn accuracy(&self, b: &Selector) -> EnsembleAccuracy {
        let scores = self.ensemble_scores(b);
        EnsembleAccuracy {
            roc_auc: metrics::roc_auc(&self.labels, &scores),
            pr_auc: metrics::pr_auc(&self.labels, &scores),
            f1: metrics::f1_at(&self.labels, &scores, 0.5),
            accuracy: metrics::accuracy_at(&self.labels, &scores, 0.5),
        }
    }
}

// ---------------------------------------------------------------------------
// Latency
// ---------------------------------------------------------------------------

/// Per-model service-time source for the analytic latency profiler.
#[derive(Debug, Clone)]
pub struct ServiceTimes {
    /// seconds per single-query (batch-1) execution, per zoo index.
    pub seconds: Vec<f64>,
}

impl ServiceTimes {
    /// MACs-based cost model: `t_i = overhead + macs_i / flops_rate`.
    /// Default coefficients are calibrated against PJRT-CPU measurements
    /// (see `calibrate`); used for zoo models without artifacts.
    pub fn from_macs(zoo: &Zoo, overhead_s: f64, macs_per_s: f64) -> Self {
        let seconds = zoo
            .manifest
            .models
            .iter()
            .map(|m| overhead_s + m.macs as f64 / macs_per_s)
            .collect();
        ServiceTimes { seconds }
    }

    /// Measure servable models through the engine (median of `reps`),
    /// then least-squares fit `t = a + b·macs` on the measured points and
    /// extrapolate to the untrained profiles.
    pub fn calibrate(zoo: &Zoo, engine: &Engine, reps: usize) -> Result<Self> {
        let mut measured: HashMap<usize, f64> = HashMap::new();
        for &idx in &zoo.servable_indices() {
            let d = engine.profile_model((idx, 1), reps)?;
            measured.insert(idx, d.as_secs_f64());
        }
        // least squares t = a + b*macs over measured points
        let pts: Vec<(f64, f64)> = measured
            .iter()
            .map(|(&i, &t)| (zoo.model(i).macs as f64, t))
            .collect();
        let (a, b) = fit_line(&pts);
        let seconds = zoo
            .manifest
            .models
            .iter()
            .map(|m| {
                measured
                    .get(&m.index)
                    .copied()
                    .unwrap_or_else(|| (a + b * m.macs as f64).max(1e-6))
            })
            .collect();
        Ok(ServiceTimes { seconds })
    }
}

/// Ordinary least squares y = a + b·x; falls back to mean when degenerate.
fn fit_line(pts: &[(f64, f64)]) -> (f64, f64) {
    let n = pts.len() as f64;
    if pts.is_empty() {
        return (1e-3, 1e-9);
    }
    let mx = pts.iter().map(|p| p.0).sum::<f64>() / n;
    let my = pts.iter().map(|p| p.1).sum::<f64>() / n;
    let sxx: f64 = pts.iter().map(|p| (p.0 - mx).powi(2)).sum();
    if sxx < 1e-12 {
        return (my, 0.0);
    }
    let sxy: f64 = pts.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
    let b = sxy / sxx;
    (my - b * mx, b)
}

/// Analytic `f_l`: LPT makespan + network-calculus queueing bound.
#[derive(Debug, Clone)]
pub struct AnalyticLatencyProfiler {
    pub times: ServiceTimes,
}

impl AnalyticLatencyProfiler {
    pub fn new(times: ServiceTimes) -> Self {
        AnalyticLatencyProfiler { times }
    }

    /// `T_s`: makespan of the selected models' service times over
    /// `gpus` workers, LPT (longest-processing-time-first) packing —
    /// each ensemble query fans out to every selected model.
    pub fn serving_time(&self, b: &Selector, gpus: usize) -> f64 {
        let mut ts: Vec<f64> = b.indices().iter().map(|&i| self.times.seconds[i]).collect();
        ts.sort_by(|a, b| b.total_cmp(a));
        let mut loads = vec![0.0f64; gpus.max(1)];
        for t in ts {
            // assign to least-loaded worker
            let k = loads
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap();
            loads[k] += t;
        }
        loads.into_iter().fold(0.0, f64::max)
    }

    /// Ensemble throughput capacity μ (queries/s): total work per query
    /// divided across workers.
    pub fn throughput(&self, b: &Selector, gpus: usize) -> f64 {
        let work: f64 = b.indices().iter().map(|&i| self.times.seconds[i]).sum();
        if work <= 0.0 {
            return f64::INFINITY;
        }
        gpus.max(1) as f64 / work
    }
}

impl LatencyProfiler for AnalyticLatencyProfiler {
    fn latency(&self, b: &Selector, c: &SystemConfig) -> f64 {
        if b.is_empty() {
            return 0.0;
        }
        let ts = self.serving_time(b, c.gpus);
        let mu = self.throughput(b, c.gpus);
        if !mu.is_finite() {
            return ts;
        }
        let tq = netcalc::tq_periodic_sources(c.patients, c.window_s, mu, ts);
        ts + tq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sel(n: usize, idx: &[usize]) -> Selector {
        Selector::from_indices(n, idx.iter().copied())
    }

    fn acc_profiler() -> ValidationAccuracyProfiler {
        // 2 models, 4 samples: model 0 perfect, model 1 inverted
        ValidationAccuracyProfiler {
            labels: vec![0, 0, 1, 1],
            scores: vec![vec![0.1, 0.2, 0.8, 0.9], vec![0.9, 0.8, 0.2, 0.1]],
            side_scores: None,
        }
    }

    #[test]
    fn bagging_mean_eq5() {
        let p = acc_profiler();
        let s = p.ensemble_scores(&sel(2, &[0, 1]));
        for v in s {
            assert!((v - 0.5).abs() < 1e-12);
        }
    }

    #[test]
    fn single_model_accuracy() {
        let p = acc_profiler();
        assert_eq!(p.accuracy(&sel(2, &[0])).roc_auc, 1.0);
        assert_eq!(p.accuracy(&sel(2, &[1])).roc_auc, 0.0);
    }

    #[test]
    fn empty_ensemble_is_chance() {
        let p = acc_profiler();
        let a = p.accuracy(&sel(2, &[]));
        assert_eq!(a.roc_auc, 0.5);
    }

    #[test]
    fn side_scores_join_the_mean() {
        let p = acc_profiler().with_side_scores(vec![1.0, 1.0, 1.0, 1.0]);
        let s = p.ensemble_scores(&sel(2, &[0]));
        assert!((s[0] - (0.1 + 1.0) / 2.0).abs() < 1e-12);
    }

    fn lat(times: Vec<f64>) -> AnalyticLatencyProfiler {
        AnalyticLatencyProfiler::new(ServiceTimes { seconds: times })
    }

    #[test]
    fn makespan_lpt_two_workers() {
        let p = lat(vec![0.4, 0.3, 0.3]);
        let b = sel(3, &[0, 1, 2]);
        // LPT on 2 workers: {0.4, 0.3} vs {0.3}? no: 0.4→w0, 0.3→w1, 0.3→w1=0.6? least-loaded: w1(0.3)+0.3=0.6 vs w0 0.4 → 0.3 goes to w0 → loads 0.7/0.3? Let's compute: sorted 0.4,0.3,0.3; w=[0,0]; 0.4→w0; 0.3→w1; 0.3→least=w1(0.3)? w1=0.3 < w0=0.4 → w1=0.6. makespan 0.6
        assert!((p.serving_time(&b, 2) - 0.6).abs() < 1e-12);
        assert!((p.serving_time(&b, 1) - 1.0).abs() < 1e-12);
        assert!((p.serving_time(&b, 3) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn throughput_scales_with_gpus() {
        let p = lat(vec![0.1, 0.1]);
        let b = sel(2, &[0, 1]);
        assert!((p.throughput(&b, 1) - 5.0).abs() < 1e-12);
        assert!((p.throughput(&b, 2) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn latency_monotone_in_patients() {
        let p = lat(vec![0.05; 6]);
        let b = sel(6, &[0, 1, 2, 3, 4, 5]);
        let c1 = SystemConfig { gpus: 2, patients: 4, window_s: 30.0 };
        let c2 = SystemConfig { gpus: 2, patients: 64, window_s: 30.0 };
        assert!(p.latency(&b, &c2) >= p.latency(&b, &c1));
    }

    #[test]
    fn latency_improves_with_more_gpus() {
        let p = lat(vec![0.05; 6]);
        let b = sel(6, &[0, 1, 2, 3, 4, 5]);
        let c1 = SystemConfig { gpus: 1, patients: 64, window_s: 30.0 };
        let c2 = SystemConfig { gpus: 2, patients: 64, window_s: 30.0 };
        assert!(p.latency(&b, &c2) < p.latency(&b, &c1));
    }

    #[test]
    fn empty_selector_zero_latency() {
        let p = lat(vec![0.1]);
        let c = SystemConfig::default();
        assert_eq!(p.latency(&sel(1, &[]), &c), 0.0);
    }

    #[test]
    fn fit_line_recovers_slope() {
        let pts: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, 2.0 + 3.0 * i as f64)).collect();
        let (a, b) = fit_line(&pts);
        assert!((a - 2.0).abs() < 1e-9 && (b - 3.0).abs() < 1e-9);
    }
}
